"""Optional-dependency shims for the test suite.

``hypothesis`` is an optional extra: when present, the property tests run
normally; when absent, only those tests skip (the rest of each module still
collects and runs, instead of the whole suite dying with collection errors).

Usage in test modules:

    from tests.compat import given, settings, st
"""
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    import pytest

    HAVE_HYPOTHESIS = False

    def given(*_a, **_k):
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(*_a, **_k):
        return lambda f: f

    class _StrategyStub:
        """Attribute sink so st.integers(...) etc. evaluate at import time."""

        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _StrategyStub()
