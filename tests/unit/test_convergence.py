"""Theorem 1 / Lemmas 1-4: closed-form convergence bounds + their structural
properties used by the optimizer."""
import numpy as np
import pytest
from tests.compat import given, settings, st

from repro.core import convergence as C
from repro.core.step_rules import ConstantRule, DiminishingRule, ExponentialRule

CONSTS = C.coefficients(L=0.084, sigma=33.18, G=33.63, f_gap=2.3, N=10)
QP = np.full(10, 0.04)


def test_constant_matches_arbitrary():
    """C_C (Lemma 1) must equal C_A (Thm 1) under a constant sequence."""
    K0, Kn, B, g = 50, np.array([3] * 10), 4, 0.01
    ca = C.c_arbitrary(K0, Kn, B, np.full(K0, g), CONSTS, QP)
    cc = C.c_constant(K0, Kn, B, g, CONSTS, QP)
    assert ca == pytest.approx(cc, rel=1e-12)


def test_exponential_matches_arbitrary():
    K0, Kn, B = 80, np.array([2] * 10), 8
    rule = ExponentialRule(0.02, 0.999)
    ca = C.c_arbitrary(K0, Kn, B, rule.sequence(K0), CONSTS, QP)
    ce = C.c_exponential(K0, Kn, B, 0.02, 0.999, CONSTS, QP)
    assert ca == pytest.approx(ce, rel=1e-9)


def test_diminishing_upper_bounds_arbitrary():
    """C_D (16) is an upper bound on C_A under the rule (15)."""
    K0, Kn, B = 120, np.array([4] * 10), 2
    rule = DiminishingRule(0.02, 600.0)
    ca = C.c_arbitrary(K0, Kn, B, rule.sequence(K0), CONSTS, QP)
    cd = C.c_diminishing(K0, Kn, B, 0.02, 600.0, CONSTS, QP)
    assert cd >= ca


def test_exponential_approaches_constant():
    """Sec. III-B: as rho_E -> 1 with gamma_E = gamma_C, C_E -> C_C."""
    K0, Kn, B, g = 60, np.array([3] * 10), 4, 0.01
    cc = C.c_constant(K0, Kn, B, g, CONSTS, QP)
    for rho, tol in ((0.999, 0.1), (0.99999, 1e-3)):
        ce = C.c_exponential(K0, Kn, B, g, rho, CONSTS, QP)
        assert ce == pytest.approx(cc, rel=tol)


@given(st.integers(10, 500), st.integers(1, 16), st.integers(1, 64))
@settings(max_examples=40, deadline=None)
def test_monotonicity(K0, Kv, B):
    """C_C decreases in K0 and in B (the structure the K0-search relies on)."""
    Kn = np.full(10, Kv)
    g = 0.01
    c0 = C.c_constant(K0, Kn, B, g, CONSTS, QP)
    assert C.c_constant(K0 + 1, Kn, B, g, CONSTS, QP) <= c0 + 1e-12
    assert C.c_constant(K0, Kn, B + 1, g, CONSTS, QP) <= c0 + 1e-12


def test_quantization_term_vanishes():
    """Remark 3: with s = infinity (q = 0) the bound loses its last term."""
    K0, Kn, B, g = 50, np.array([3] * 10), 4, 0.01
    with_q = C.c_constant(K0, Kn, B, g, CONSTS, QP)
    no_q = C.c_constant(K0, Kn, B, g, CONSTS, np.zeros(10))
    c1, c2, c3, c4 = CONSTS
    expected_gap = c4 * g * (QP * Kn**2).sum() / Kn.sum()
    assert with_q - no_q == pytest.approx(expected_gap, rel=1e-9)


def test_lemma4_constant_step_optimal():
    """Lemma 4: among sequences with the same sum S, the constant sequence
    minimizes C_A."""
    rng = np.random.default_rng(0)
    K0, Kn, B = 40, np.array([3] * 10), 4
    Ssum = 0.4
    const = C.c_arbitrary(K0, Kn, B, np.full(K0, Ssum / K0), CONSTS, QP)
    for _ in range(20):
        g = rng.uniform(0.2, 1.0, K0)
        g = g / g.sum() * Ssum
        assert C.c_arbitrary(K0, Kn, B, g, CONSTS, QP) >= const - 1e-12
