"""Rotation-preconditioned QSGD + error-feedback codecs (repro.compress).

The ISSUE-5 codec bars: the randomized-Hadamard preconditioner round-trips
exactly (orthonormal), the jnp and Pallas backends of the rotated codec are
bit-identical, ``wire_bits`` prices the padded levels + the 32-bit rotation
seed consistently with EdgeSystem, and the stateful EF codec satisfies the
telescoping contract while refusing to price Assumption-1's q_s.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compress as C


# ---------------------------------------------------------------------------
# the preconditioner
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n", [1, 7, 64, 1000, 1024])
def test_rotation_round_trip_and_norm(n):
    y = jax.random.normal(jax.random.PRNGKey(n), (n,))
    r = C.rotate(y, seed=11)
    assert r.shape == (C.next_pow2(n),)
    # orthonormal: norms agree, inverse recovers the input (fp tolerance)
    assert float(jnp.linalg.norm(r)) == pytest.approx(
        float(jnp.linalg.norm(y)), rel=1e-5)
    back = C.unrotate(r, seed=11, n=n)
    assert np.allclose(np.asarray(back), np.asarray(y), atol=1e-5)
    # a different seed is a different rotation
    if n > 1:
        assert not np.allclose(np.asarray(C.rotate(y, seed=12)),
                               np.asarray(r), atol=1e-5)


def test_next_pow2():
    assert [C.next_pow2(n) for n in (1, 2, 3, 8, 9, 1000)] == \
        [1, 2, 4, 8, 16, 1024]


# ---------------------------------------------------------------------------
# the rotated codec
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dim", [1000, 1024, 2053])
def test_rotated_codec_jnp_pallas_bit_identical(dim):
    """Both backends share the rotation verbatim and reach the same QSGD
    level math — outputs must be bitwise equal, not merely close."""
    y = jax.random.normal(jax.random.PRNGKey(0), (dim,)) * 3.0
    key = jax.random.PRNGKey(1)
    cj = C.RotatedQSGDCodec(s_levels=16, backend="jnp", seed=5)
    cp = C.RotatedQSGDCodec(s_levels=16, backend="pallas", seed=5)
    oj = cj.quantize_dequantize(y, key)
    op = cp.quantize_dequantize(y, key)
    assert oj.shape == y.shape
    assert jnp.array_equal(oj, op)
    # and encode itself agrees level-for-level on the padded message
    u = jax.random.uniform(key, (cj.padded_dim(dim),), jnp.float32)
    lj, nj = cj.encode(y, u)
    lp, np_ = cp.encode(y, u)
    assert jnp.array_equal(lj.astype(jnp.int8), lp)
    assert jnp.array_equal(nj, np_)


def test_rotated_codec_unbiased_and_bounded():
    """Assumption 1 holds for the rotated message: unbiased per coordinate
    and error**2 <= q_s * ||y||**2 at the padded dimension."""
    dim, s = 512, 8
    codec = C.make_codec(s, kind="rotated")
    y = jax.random.normal(jax.random.PRNGKey(2), (dim,))
    keys = jax.random.split(jax.random.PRNGKey(3), 300)
    samples = jnp.stack([codec.quantize_dequantize(y, k) for k in keys])
    err = float(((samples - y) ** 2).sum(1).mean() / (y**2).sum())
    assert err <= codec.variance_bound(dim) * 1.1
    bias = float(jnp.abs(samples.mean(0) - y).max())
    sd = float(((samples - y) ** 2).mean() ** 0.5)
    assert bias < 6.0 * sd / np.sqrt(len(keys)) + 1e-4


def test_rotated_codec_isotropizes():
    """What the preconditioner buys: the rotated message looks the same to
    the quantizer regardless of input structure — a 1-hot spike and a dense
    Gaussian of equal norm produce statistically equal realized error, and
    the spike's dominant coordinate collapses to the ~sqrt(2 log d / d)
    isotropic scale (the dynamic range fixed-grid wire formats care about).
    """
    dim, s = 4096, 4
    spiky = jnp.zeros(dim).at[17].set(10.0)
    dense = jax.random.normal(jax.random.PRNGKey(4), (dim,))
    dense = dense * (10.0 / jnp.linalg.norm(dense))
    r = C.rotate(spiky, seed=0)
    assert float(jnp.abs(r).max()) < 5.0 * np.sqrt(2 * np.log(dim) / dim) * 10

    rot = C.make_codec(s, kind="rotated")
    keys = jax.random.split(jax.random.PRNGKey(5), 50)

    def mean_err(y):
        return float(jnp.stack([
            ((rot.quantize_dequantize(y, k) - y) ** 2).sum()
            for k in keys]).mean())

    e_spiky, e_dense = mean_err(spiky), mean_err(dense)
    assert 0.5 < e_spiky / e_dense < 2.0
    assert e_spiky <= rot.variance_bound(dim) * 100.0 * 1.1   # ||y||^2 = 100


def test_rotated_wire_bits_and_edge_system_pricing():
    from repro.api import EdgeSystem
    dim = 1000                            # pads to 1024
    c = C.make_codec(16, kind="rotated")  # packed: 1 sign + 5 level bits
    assert c.wire_bits(dim) == 32 + 1024 * 6 + 32
    assert c.variance_bound(dim) == C.variance_bound(16, 1024)
    plain = C.make_codec(16)
    assert plain.wire_bits(dim) == 32 + 1000 * 6
    sys_p = EdgeSystem.paper_sec_vii(dim=dim, N=4)
    import dataclasses
    sys_r = dataclasses.replace(sys_p, codec_kind="rotated")
    assert sys_r.M_s0 == C.make_codec(sys_p.s0, kind="rotated").wire_bits(dim)
    assert sys_r.q_s0 == C.variance_bound(sys_p.s0, 1024)
    # the q the optimizer prices feeds q_pairs, so plans actually differ
    assert not np.array_equal(sys_r.q_pairs, sys_p.q_pairs)


def test_rotated_codec_validation_and_dispatch():
    with pytest.raises(ValueError, match="mutually exclusive"):
        C.RotatedQSGDCodec(s_levels=4, bucket=256)
    with pytest.raises(ValueError, match="kind"):
        C.make_codec(4, kind="wavelet")
    assert isinstance(C.make_codec(None, kind="rotated"), C.IdentityCodec)
    assert isinstance(C.make_codec(4, kind="rotated"), C.RotatedQSGDCodec)
    # memoized like every codec
    assert C.make_codec(4, kind="rotated") is C.make_codec(4, kind="rotated")
    assert C.make_codec(4, kind="rotated") != C.make_codec(4)


# ---------------------------------------------------------------------------
# error feedback
# ---------------------------------------------------------------------------
def test_ef_codec_telescoping_contract():
    """sum_t decode_t == sum_t y_t + e_0 - e_T: the cumulative applied
    update tracks the true sum to within the final residual exactly (up to
    f32 summation noise)."""
    dim = 256
    ef = C.ErrorFeedbackCodec(inner=C.make_codec(4))
    state = ef.init_state(dim)
    key = jax.random.PRNGKey(0)
    tot_in = jnp.zeros(dim)
    tot_out = jnp.zeros(dim)
    for t in range(40):
        y = jax.random.normal(jax.random.fold_in(key, t), (dim,))
        out, state = ef.quantize_dequantize(y, jax.random.fold_in(key, 99 + t),
                                            state)
        tot_in = tot_in + y
        tot_out = tot_out + out
    resid = np.asarray(tot_in - tot_out)
    assert np.allclose(resid, np.asarray(state), atol=1e-3)


def test_ef_codec_residual_stays_bounded():
    """Variance/contract property: with a contractive inner quantizer
    (q_s < 1) the compensated residual cannot grow without bound —
    ||e_t|| <= q/(1-q) * max_t ||y_t|| at stationarity."""
    dim, s = 64, 32                       # q = min(64/1024, 8/32) = 1/16
    q = C.variance_bound(s, dim)
    assert q < 1.0
    ef = C.ErrorFeedbackCodec(inner=C.make_codec(s))
    state = ef.init_state(dim)
    key = jax.random.PRNGKey(1)
    max_in, max_res = 0.0, 0.0
    for t in range(60):
        y = jax.random.normal(jax.random.fold_in(key, t), (dim,))
        _, state = ef.quantize_dequantize(y, jax.random.fold_in(key, 99 + t),
                                          state)
        max_in = max(max_in, float(jnp.linalg.norm(y)))
        max_res = max(max_res, float(jnp.linalg.norm(state)))
    assert max_res <= np.sqrt(q) / (1.0 - np.sqrt(q)) * max_in * 1.1


def test_ef_codec_stateful_encode_interface():
    dim = 100
    ef = C.ErrorFeedbackCodec(inner=C.make_codec(7, wire="int4"))
    y = jax.random.normal(jax.random.PRNGKey(5), (dim,))
    u = jax.random.uniform(jax.random.PRNGKey(6), (dim,))
    lvl, norm, state = ef.encode(y, u, ef.init_state(dim))
    assert lvl.shape == y.shape and state.shape == (dim,)
    # first step: state was zero, so the residual is the quantization error
    assert np.allclose(np.asarray(y - ef.decode(lvl, norm)),
                       np.asarray(state), atol=1e-6)
    assert ef.wire_bits(dim) == C.make_codec(7, wire="int4").wire_bits(dim)
    assert ef.s == 7 and ef.wire == "int4"


def test_ef_codec_refuses_optimizer_pricing():
    """The legality note, enforced: Assumption 1 fails under EF, so the
    cost layer must never price q_s for it (no shipped family's convergence
    block covers biased quantization)."""
    ef = C.ErrorFeedbackCodec(inner=C.make_codec(4))
    with pytest.raises(TypeError, match="biased"):
        ef.variance_bound(100)


def test_ef_around_rotated_inner():
    """EF composes with the rotated codec (state lives in model space)."""
    dim = 200
    ef = C.ErrorFeedbackCodec(inner=C.make_codec(8, kind="rotated"))
    state = ef.init_state(dim)
    y = jax.random.normal(jax.random.PRNGKey(8), (dim,))
    out, state = ef.quantize_dequantize(y, jax.random.PRNGKey(9), state)
    assert out.shape == y.shape and state.shape == (dim,)
    assert np.allclose(np.asarray(y - out), np.asarray(state), atol=1e-5)
