"""Attention/MoE/unroll building-block semantics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import blocks as B
from repro.models import moe as MOE
from repro.models import unroll
from repro.models.registry import get_config


def test_sliding_window_masks_old_tokens():
    """A token beyond the window must not influence attention output."""
    cfg = get_config("gemma3-4b", smoke=True)
    key = jax.random.PRNGKey(0)
    p = B.attn_init(key, cfg)
    Bt, S, W = 1, 128, cfg.window
    x = jax.random.normal(key, (Bt, S, cfg.d_model))
    pos = jnp.broadcast_to(jnp.arange(S), (Bt, S))
    y1, _ = B.attn_apply(p, x, cfg, pos, window=W)
    # perturb a token far outside the last query's window
    x2 = x.at[:, 0].set(x[:, 0] + 10.0)
    y2, _ = B.attn_apply(p, x2, cfg, pos, window=W)
    # last token (position 127, window 64): token 0 out of range -> unchanged
    np.testing.assert_allclose(np.asarray(y1[:, -1]), np.asarray(y2[:, -1]),
                               rtol=1e-4, atol=1e-5)
    # but an in-window perturbation does change it
    x3 = x.at[:, -2].set(x[:, -2] + 10.0)
    y3, _ = B.attn_apply(p, x3, cfg, pos, window=W)
    assert float(jnp.abs(y3[:, -1] - y1[:, -1]).max()) > 1e-3


def test_mrope_reduces_to_rope_on_equal_streams():
    """With t==h==w position streams, M-RoPE must equal standard RoPE."""
    cfg = get_config("qwen2-vl-7b", smoke=True)
    key = jax.random.PRNGKey(1)
    x = jax.random.normal(key, (2, 16, 4, cfg.d_head))
    pos = jnp.broadcast_to(jnp.arange(16), (2, 16))
    pos3 = jnp.stack([pos, pos, pos])
    a = B.apply_rope(x, pos, cfg.rope_theta)
    b = B.apply_mrope(x, pos3, cfg.rope_theta, cfg.mrope_sections)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6,
                               atol=1e-6)


def test_attention_unrolled_equals_scanned():
    """The roofline's unrolled trace must compute the same function."""
    cfg = get_config("qwen3-1.7b", smoke=True)
    key = jax.random.PRNGKey(2)
    p = B.attn_init(key, cfg)
    S = 4 * B.Q_CHUNK  # force the chunked path
    x = jax.random.normal(key, (1, S, cfg.d_model)) * 0.3
    pos = jnp.broadcast_to(jnp.arange(S), (1, S))
    y_scan, _ = B.attn_apply(p, x, cfg, pos)
    with unroll.unrolled():
        y_unr, _ = B.attn_apply(p, x, cfg, pos)
    np.testing.assert_allclose(np.asarray(y_scan), np.asarray(y_unr),
                               rtol=1e-4, atol=1e-5)


def test_moe_capacity_and_combine():
    cfg = get_config("olmoe-1b-7b", smoke=True)
    key = jax.random.PRNGKey(3)
    p = MOE.moe_init(key, cfg)
    x = jax.random.normal(key, (2, 32, cfg.d_model)) * 0.5
    y, probs = MOE.moe_apply(p, x, cfg)
    assert y.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(y)))
    assert probs.shape == (64, cfg.n_experts)
    np.testing.assert_allclose(np.asarray(probs.sum(-1)), 1.0, rtol=1e-5)


def test_router_aux_loss_uniform_is_one():
    E = 8
    probs = jnp.full((128, E), 1.0 / E)
    # argmax ties resolve to expert 0 -> f is one-hot; aux = E * sum(f*P) = 1
    val = float(MOE.router_aux_loss(probs))
    assert val == pytest.approx(1.0, rel=1e-5)


def test_moe_tokens_dropped_beyond_capacity():
    """With capacity_factor tiny, most contributions are dropped -> output
    is (near) pass-through of the residual (zeros here)."""
    import dataclasses
    cfg = dataclasses.replace(get_config("olmoe-1b-7b", smoke=True),
                              capacity_factor=0.01)
    key = jax.random.PRNGKey(4)
    p = MOE.moe_init(key, cfg)
    x = jax.random.normal(key, (2, 64, cfg.d_model))
    y, _ = MOE.moe_apply(p, x, cfg)
    # nearly all tokens dropped => tiny output norm vs a full-capacity run
    cfg_full = dataclasses.replace(cfg, capacity_factor=2.0)
    y_full, _ = MOE.moe_apply(p, x, cfg_full)
    assert float(jnp.linalg.norm(y)) < 0.5 * float(jnp.linalg.norm(y_full))


def test_collective_bytes_parser():
    from repro.roofline.analysis import collective_bytes
    hlo = """
  %ag = f32[4,8]{1,0} all-gather(%x), replica_groups={{0,1}}
  %ar.1 = bf16[16]{0} all-reduce-start(%y)
  %t = (f32[2,2]{1,0}, s8[4]{0}) all-to-all(%a, %b)
  %cp = u32[10]{0} collective-permute(%z)
  %not_a_coll = f32[99] add(%p, %q)
"""
    out = collective_bytes(hlo)
    assert out["bytes"]["all-gather"] == 4 * 8 * 4
    assert out["bytes"]["all-reduce"] == 16 * 2
    assert out["bytes"]["all-to-all"] == 2 * 2 * 4 + 4
    assert out["bytes"]["collective-permute"] == 10 * 4
    assert out["counts"]["all-gather"] == 1
    assert out["total_bytes"] == sum(out["bytes"].values())


def test_shardctx_noop_outside_context():
    from repro.models import shardctx
    x = jnp.ones((2, 3, 4))
    assert shardctx.constrain(x) is x
    assert shardctx.constrain_interior(x) is x
