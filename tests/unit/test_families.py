"""repro.families: the pluggable algorithm-family subsystem.

Covers the ISSUE-5 acceptance bar: the refactored genqsgd family is
*bit-identical* to the pre-family pipeline (neutral hooks select the exact
historical arithmetic), gqfedwavg optimizes and runs end-to-end with its
weighted aggregation / momentum / rotated-codec hooks, the legacy
``FAMILIES`` registry keeps working (mutation deprecated), and unknown
family names fail with nearest-match suggestions naming repro.families.
"""
import dataclasses
import warnings

import numpy as np
import pytest

from repro.api import (ConstantRule, DiminishingRule, EdgeSystem,
                       ExponentialRule, MLProblemConstants, Objective, Plan,
                       QuadraticTask, Scenario, FAMILIES, make_varmap,
                       register_family)
from repro.families import (AlgorithmFamily, GenQSGDFamily, GQFedWAvgFamily,
                            family_names, get_family, register)
from repro.opt import solve_param_opt, structure_signature
from repro.opt.problems import pm_varmap

CONSTS = MLProblemConstants(L=0.084, sigma=33.18, G=33.63, f_gap=2.3, N=4)


def _scenario(family, step=ConstantRule(0.01), C_max=0.25, dim=1024, N=4):
    sys_ = EdgeSystem.paper_sec_vii(dim=dim, N=N)
    return Scenario(system=sys_, consts=dataclasses.replace(CONSTS, N=N),
                    T_max=1e5, C_max=C_max, family=family, step=step)


#: a GQFedWAvg-machinery family whose every hook is numerically neutral —
#: uniform weights, no momentum, plain QSGD — i.e. GenQSGD spelled through
#: the general family code paths
_NEUTRAL = GQFedWAvgFamily(key="gqfedwavg-neutral", weights=(1.0,) * 4,
                           momentum=0.0, normalize=False, codec_kind="qsgd")


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------
def test_registry_contents_and_lookup():
    assert set(family_names()) >= {"genqsgd", "pm", "fa", "pr", "gqfedwavg"}
    fam = get_family("gqfedwavg")
    assert fam.codec_kind == "rotated" and fam.normalize
    assert isinstance(get_family("genqsgd"), GenQSGDFamily)


def test_unknown_family_suggests_and_names_registry():
    with pytest.raises(ValueError, match="repro.families"):
        get_family("sgd")
    with pytest.raises(ValueError, match="did you mean 'gqfedwavg'"):
        get_family("gqfedwvag")
    with pytest.raises(ValueError, match="gqfedwavg"):
        make_varmap("gqfedwvag", 4, False, 6000.0)
    with pytest.raises(ValueError, match="unknown family"):
        _scenario("gqfedwvag")


def test_families_shim_reads_and_deprecated_mutation():
    assert "genqsgd" in FAMILIES and len(FAMILIES) == len(family_names())
    vm = FAMILIES["pm"](4, False, 6000.0)
    assert vm.names == pm_varmap(4).names
    with pytest.raises(KeyError):
        FAMILIES["nope"]
    with pytest.warns(DeprecationWarning, match="deprecated"):
        FAMILIES["pm-clone"] = lambda N, we, spw: pm_varmap(N, with_extra=we)
    try:
        # the mutated entry is a full (GenQSGD-semantics) family
        plan = _scenario("pm-clone").optimize(max_iter=5)
        ref = _scenario("pm").optimize(max_iter=5)
        assert (plan.K0, plan.Kn, plan.B) == (ref.K0, ref.Kn, ref.B)
    finally:
        with pytest.warns(DeprecationWarning):
            del FAMILIES["pm-clone"]
    assert "pm-clone" not in FAMILIES


def test_register_family_accepts_instances_and_factories():
    register_family("pm-legacy", lambda N, we, spw: pm_varmap(N, with_extra=we))
    register_family("gq-variant", GQFedWAvgFamily(key="gq-variant",
                                                  momentum=0.25))
    try:
        assert isinstance(get_family("pm-legacy"), GenQSGDFamily)
        assert get_family("gq-variant").momentum == 0.25
    finally:
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")
            del FAMILIES["pm-legacy"], FAMILIES["gq-variant"]
    with pytest.raises(TypeError, match="AlgorithmFamily"):
        register("not a family")


# ---------------------------------------------------------------------------
# the tentpole guarantee: genqsgd through the interface is bit-identical
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("m", list(Objective))
def test_neutral_hooks_conv_block_bitwise(m):
    """eps = ones / unit scales produce the *same floats* as the historical
    unweighted arithmetic — per conv-block constraint, coefficient for
    coefficient (1.0·x is exact for every finite float)."""
    steps = {Objective.CONSTANT: ConstantRule(0.01),
             Objective.EXPONENTIAL: ExponentialRule(0.02, 0.9995),
             Objective.DIMINISHING: DiminishingRule(0.02, 600.0),
             Objective.JOINT: None}
    p_ref = _scenario("genqsgd", step=steps[m]).problem()
    p_neu = _scenario(_NEUTRAL, step=steps[m]).problem()
    z = p_ref.z_init()
    assert np.array_equal(z, p_neu.z_init())
    for a, b in zip(p_ref.conv_block(z), p_neu.conv_block(z)):
        assert np.array_equal(a.c, b.c)
        assert np.array_equal(a.A, b.A)


def test_neutral_hooks_full_solve_bitwise():
    """The whole scalar GIA (z_init, surrogates, integer recovery) lands on
    bitwise-identical results through the family interface."""
    r_ref = solve_param_opt(_scenario("genqsgd").problem())
    r_neu = solve_param_opt(_scenario(_NEUTRAL).problem())
    assert np.array_equal(r_ref.z, r_neu.z)
    assert (r_ref.K0, r_ref.B, r_ref.E) == (r_neu.K0, r_neu.B, r_neu.E)
    assert np.array_equal(r_ref.Kn, r_neu.Kn)
    assert r_ref.history == r_neu.history


def test_structure_signature_carries_family_key():
    pg = _scenario("genqsgd").problem()
    pw = _scenario("gqfedwavg").problem()
    assert structure_signature(pg) != structure_signature(pw)
    # coefficient-only hooks: the packed *shapes* still match
    assert structure_signature(pg)[:4] == structure_signature(pw)[:4]


# ---------------------------------------------------------------------------
# weighted aggregation: bound + runtime agree on the weighting
# ---------------------------------------------------------------------------
def test_weighted_conv_closed_form():
    from repro.core.convergence import c_constant
    fam = GQFedWAvgFamily(key="gq-w", weights=(4.0, 2.0, 1.0, 1.0),
                          momentum=0.0, codec_kind="qsgd")
    prob = _scenario(fam).problem()
    Kn = np.array([2.0, 3.0, 1.0, 4.0])
    got = prob.evaluate(100, Kn, 8, None)["C"]
    eps = fam.agg_eps(4)
    c1, c2, c3, c4 = CONSTS.c
    qp = prob.sys.q_pairs
    g = 0.01
    sum_K = float((eps * Kn).sum())
    ref = (c1 / (g * 100 * sum_K) + c2 * g**2 * Kn.max() ** 2
           + fam.c_scales(4)[1] * c3 * g / 8
           + c4 * g * (qp * (eps * Kn) ** 2).sum() / sum_K)
    assert got == pytest.approx(ref, rel=1e-12)
    assert c_constant(100, Kn, 8, g, prob._c_eff, qp, eps) == got


def test_runtime_weighted_aggregation_linearity():
    """x(w) = x̂ + γ Σ_n w_n Q(Δ_n) is affine in w: two complementary
    weightings must average to the uniform-mean round exactly."""
    import jax
    import jax.numpy as jnp
    from repro.core.genqsgd import GenQSGD, GenQSGDConfig

    task = QuadraticTask(dim=8)
    data = task.make_data(2)
    p0 = task.init_params(jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(7)

    def one_round(w):
        cfg = GenQSGDConfig(K0=1, Kn=(2, 3), B=4,
                            step_rule=ConstantRule(0.05), agg_weights=w)
        alg = GenQSGD(task.loss, task.sample, cfg)
        x, _ = alg._round(p0, data, key, jnp.float32(0.05))
        return np.asarray(x["w"])

    x_mean = one_round(None)
    xa = one_round((0.3, 0.7))
    xb = one_round((0.7, 0.3))
    assert np.allclose(xa + xb, 2 * x_mean, atol=1e-6)
    assert not np.allclose(xa, x_mean, atol=1e-6)   # the weights bite


def test_runtime_normalized_momentum_step_size():
    """normalize=True moves exactly γ per active local step, so each
    worker's delta norm is bounded by γ·K_n (triangle inequality) and the
    masked virtual steps contribute nothing."""
    import jax
    import jax.numpy as jnp
    from repro.core.genqsgd import GenQSGD, GenQSGDConfig

    task = QuadraticTask(dim=8)
    data = task.make_data(2)
    p0 = task.init_params(jax.random.PRNGKey(0))
    gamma = 0.05
    cfg = GenQSGDConfig(K0=1, Kn=(1, 4), B=4, step_rule=ConstantRule(gamma),
                        momentum=0.5, normalize=True)
    alg = GenQSGD(task.loss, task.sample, cfg)
    kn = jnp.asarray(cfg.Kn)
    local = jax.vmap(alg._local_train, in_axes=(None, 0, 0, None, 0))
    keys = jax.random.split(jax.random.PRNGKey(3), 2)
    xw = local(p0, data, keys, jnp.float32(gamma), kn)
    for i, k_n in enumerate(cfg.Kn):
        d = float(jnp.linalg.norm(xw["w"][i] - p0["w"]))
        assert 0.0 < d <= gamma * k_n * (1 + 1e-5), (i, d)


# ---------------------------------------------------------------------------
# gqfedwavg end-to-end: optimize -> run closes the loop exactly
# ---------------------------------------------------------------------------
def test_gqfedwavg_closed_loop_reference_backend():
    task = QuadraticTask(dim=8)
    sys_ = EdgeSystem.paper_sec_vii(dim=task.dim)
    consts = dataclasses.replace(CONSTS, N=10)
    scn = Scenario(system=sys_, consts=consts, T_max=1e5, C_max=0.25,
                   family="gqfedwavg")
    assert scn._priced_system.codec_kind == "rotated"
    # rotated pricing: pow2-padded levels + the 32-bit rotation seed
    # (dim=8 is already a power of two, so only the seed word is added)
    assert scn._priced_system.M_s0 == sys_.M_s0 + 32.0
    plan = scn.optimize()
    assert plan.feasible and plan.codec_kind == "rotated"
    assert plan.momentum == 0.5 and plan.normalize
    report = scn.run(plan, task=task)
    # measured comm-bits == K0 * round_bits at the rotated pricing, exactly
    assert report.comm_bits == report.predicted_comm_bits
    assert report.comm_bits_match
    # full-K0 cost-model measurements price the *family's* codec, so they
    # coincide with the predictions (internally consistent closed loop)
    assert report.measured_E == pytest.approx(plan.predicted_E)
    assert report.measured_T == pytest.approx(plan.predicted_T)
    assert report.final_metrics["err"] < 0.1


def test_gqfedwavg_on_bucketed_system_drops_q_dim():
    """A rotated family on a per-bucket-norm system must not crash deep in
    the optimizer: rotation isotropizes the whole message, so the priced
    system (and the Plan) drop q_dim instead."""
    sys_t = EdgeSystem.tpu_v5e_fleet(dim=1024, n_groups=4, chips_per_group=1)
    assert sys_t.q_dim is not None
    scn = Scenario(system=sys_t, consts=CONSTS, T_max=1e5, C_max=0.25,
                   family="gqfedwavg")
    assert scn._priced_system.q_dim is None
    plan = scn.optimize(max_iter=5)
    assert plan.q_dim is None and plan.codec_kind == "rotated"
    with pytest.raises(ValueError, match="mutually exclusive"):
        Plan.manual(K0=1, Kn=(1,), B=1, step_rule=ConstantRule(0.1),
                    codec_kind="rotated", q_dim=256)


def test_plan_agg_weights_positivity():
    """Plan enforces the same weight rules as both runtime configs (one
    shared validator), so a frozen Plan can never carry weights its
    runtimes would reject."""
    with pytest.raises(ValueError, match="positive"):
        Plan.manual(K0=1, Kn=(1, 1), B=1, step_rule=ConstantRule(0.1),
                    agg_weights=(0.0, 1.0))
    with pytest.raises(ValueError, match="2 aggregation weights"):
        Plan.manual(K0=1, Kn=(1, 1, 1), B=1, step_rule=ConstantRule(0.1),
                    agg_weights=(0.5, 0.5))


def test_rotated_plan_round_bits_wire_consistency():
    """Explicitly naming the Plan's own pricing wire must give the same
    answer as the default; a *different* wire names a runtime transport
    (per-tensor QSGD levels) and prices accordingly."""
    p = Plan.manual(K0=2, Kn=(1,) * 4, B=1, step_rule=ConstantRule(0.1),
                    s0=7, sn=7, dim=1000, codec_kind="rotated")
    assert p.round_bits() == p.round_bits(wire="packed")
    from repro.compress import make_codec
    up_down = 5 * make_codec(7, wire="f32").wire_bits(1000)
    assert p.round_bits(wire="f32") == up_down


def test_gqfedwavg_plan_derives_both_runtime_configs():
    fam = GQFedWAvgFamily(key="gq-cfg", weights=(3.0, 1.0),
                          codec_kind="qsgd")
    plan = Plan.manual(K0=4, Kn=(1, 2), B=2, step_rule=ConstantRule(0.01),
                       s0=16, sn=7, family="gq-cfg", codec_kind="qsgd",
                       agg_weights=fam.agg_weights(2), momentum=fam.momentum,
                       normalize=fam.normalize)
    cfg = plan.to_genqsgd_config()
    assert cfg.agg_weights == (0.75, 0.25)
    assert cfg.momentum == 0.5 and cfg.normalize
    fed = plan.to_fed_config(wire="int8")
    assert fed.agg_weights == (0.75, 0.25)
    assert fed.momentum == 0.5 and fed.normalize


def test_scenario_accepts_family_instances():
    fam = GQFedWAvgFamily(key="gq-inline", weights=(2.0, 1.0, 1.0, 1.0),
                          momentum=0.0, codec_kind="qsgd")
    plan = _scenario(fam).optimize(max_iter=10)
    assert plan.family == "gq-inline"
    assert plan.agg_weights == pytest.approx((0.4, 0.2, 0.2, 0.2))


def test_family_validation():
    with pytest.raises(ValueError, match="momentum"):
        GQFedWAvgFamily(key="bad", momentum=1.0)
    with pytest.raises(ValueError, match="positive"):
        GQFedWAvgFamily(key="bad", weights=(1.0, -1.0))
    fam = GQFedWAvgFamily(key="bad-n", weights=(1.0, 2.0))
    with pytest.raises(ValueError, match="N=4"):
        _scenario(fam).problem()
