"""T(K,B) (17) and E(K,B) (18) cost models."""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import EdgeSystem, energy_cost, time_cost


def test_paper_system_shape():
    s = EdgeSystem.paper_sec_vii()
    assert s.N == 10
    assert s.Fn[:5].mean() / s.Fn[5:].mean() == pytest.approx(10.0)
    assert (s.Fn[:5].mean() + s.Fn[5:].mean()) / 2 == pytest.approx(1e9)


def test_cost_formulas_manual():
    s = EdgeSystem.paper_sec_vii()
    K0, Kn, B = 10, np.array([2] * 10), 4
    T = time_cost(s, K0, Kn, B)
    expected_T = K0 * (B * np.max(s.Cn / s.Fn * Kn) + s.C0 / s.F0
                       + np.max(s.M_sn / s.rn) + s.M_s0 / s.r0)
    assert T == pytest.approx(expected_T)
    E = energy_cost(s, K0, Kn, B)
    expected_E = K0 * (B * np.sum(s.alphan * s.Cn * s.Fn**2 * Kn)
                       + s.alpha0 * s.C0 * s.F0**2
                       + s.p0 * s.M_s0 / s.r0
                       + np.sum(s.pn * s.M_sn / s.rn))
    assert E == pytest.approx(expected_E)


@given(st.integers(1, 1000), st.integers(1, 50), st.integers(1, 512))
@settings(max_examples=30, deadline=None)
def test_costs_linear_in_k0_and_monotone(K0, Kv, B):
    s = EdgeSystem.paper_sec_vii()
    Kn = np.full(10, Kv)
    assert time_cost(s, 2 * K0, Kn, B) == pytest.approx(
        2 * time_cost(s, K0, Kn, B))
    assert energy_cost(s, K0, Kn, B + 1) >= energy_cost(s, K0, Kn, B)
    assert time_cost(s, K0, Kn + 1, B) >= time_cost(s, K0, Kn, B)


def test_quantization_bits_affect_comm():
    lo = EdgeSystem.paper_sec_vii(s0=2**8)
    hi = EdgeSystem.paper_sec_vii(s0=2**20)
    assert hi.M_s0 > lo.M_s0
    assert hi.comm_time > lo.comm_time
    assert hi.q_s0 < lo.q_s0


def test_tpu_fleet_parameterization():
    s = EdgeSystem.tpu_v5e_fleet(dim=int(1e9), n_groups=2,
                                 chips_per_group=256)
    assert s.N == 2
    assert time_cost(s, 10, [1, 1], 1) > 0
    assert energy_cost(s, 10, [1, 1], 1) > 0
