"""T(K,B) (17) and E(K,B) (18) cost models."""
import numpy as np
import pytest
from tests.compat import given, settings, st

from repro.core import EdgeSystem, energy_cost, time_cost


def test_paper_system_shape():
    s = EdgeSystem.paper_sec_vii()
    assert s.N == 10
    assert s.Fn[:5].mean() / s.Fn[5:].mean() == pytest.approx(10.0)
    assert (s.Fn[:5].mean() + s.Fn[5:].mean()) / 2 == pytest.approx(1e9)


def test_cost_formulas_manual():
    s = EdgeSystem.paper_sec_vii()
    K0, Kn, B = 10, np.array([2] * 10), 4
    T = time_cost(s, K0, Kn, B)
    expected_T = K0 * (B * np.max(s.Cn / s.Fn * Kn) + s.C0 / s.F0
                       + np.max(s.M_sn / s.rn) + s.M_s0 / s.r0)
    assert T == pytest.approx(expected_T)
    E = energy_cost(s, K0, Kn, B)
    expected_E = K0 * (B * np.sum(s.alphan * s.Cn * s.Fn**2 * Kn)
                       + s.alpha0 * s.C0 * s.F0**2
                       + s.p0 * s.M_s0 / s.r0
                       + np.sum(s.pn * s.M_sn / s.rn))
    assert E == pytest.approx(expected_E)


@given(st.integers(1, 1000), st.integers(1, 50), st.integers(1, 512))
@settings(max_examples=30, deadline=None)
def test_costs_linear_in_k0_and_monotone(K0, Kv, B):
    s = EdgeSystem.paper_sec_vii()
    Kn = np.full(10, Kv)
    assert time_cost(s, 2 * K0, Kn, B) == pytest.approx(
        2 * time_cost(s, K0, Kn, B))
    assert energy_cost(s, K0, Kn, B + 1) >= energy_cost(s, K0, Kn, B)
    assert time_cost(s, K0, Kn + 1, B) >= time_cost(s, K0, Kn, B)


def test_quantization_bits_affect_comm():
    lo = EdgeSystem.paper_sec_vii(s0=2**8)
    hi = EdgeSystem.paper_sec_vii(s0=2**20)
    assert hi.M_s0 > lo.M_s0
    assert hi.comm_time > lo.comm_time
    assert hi.q_s0 < lo.q_s0


def _system_with(sn, s0, wire, dim=1000, q_dim=None):
    n = len(sn)
    return EdgeSystem(F0=1e9, C0=100.0, p0=1.0, r0=1e6, s0=s0, alpha0=1e-28,
                      Fn=np.full(n, 1e9), Cn=np.full(n, 1e8),
                      pn=np.full(n, 1.0), rn=np.full(n, 1e6), sn=sn,
                      alphan=np.full(n, 1e-28), dim=dim, q_dim=q_dim,
                      wire=wire)


def test_cost_model_matches_codec_for_every_runtime_wire():
    """The optimizer can never price a transport the runtime doesn't send:
    EdgeSystem's M_s / q_s equal codec.wire_bits / codec.variance_bound for
    every (s, wire) combination the runtime accepts."""
    from repro.compress import RUNTIME_WIRES, make_codec, wire_max_s
    dim, q_dim = 1000, 128
    for wire in RUNTIME_WIRES:
        cap = wire_max_s(wire)
        for s in (None, 1, 5, 7, 64, 127):
            over_cap = s is not None and cap is not None and s > cap
            exact_on_packing_wire = s is None and wire == "int4"
            if over_cap or exact_on_packing_wire:
                # unrepresentable on this wire: both the codec and the cost
                # layer must refuse, exactly like the runtime does
                with pytest.raises(ValueError):
                    make_codec(s, wire=wire).wire_bits(dim)
                with pytest.raises(ValueError):
                    _ = _system_with([s, s], s0=s, wire=wire, dim=dim,
                                     q_dim=q_dim).M_s0
                continue
            sys_ = _system_with([s, s], s0=s, wire=wire, dim=dim, q_dim=q_dim)
            codec = make_codec(s, wire=wire, bucket=q_dim)
            assert sys_.M_s0 == codec.wire_bits(dim), (s, wire)
            assert np.all(sys_.M_sn == codec.wire_bits(dim)), (s, wire)
            assert sys_.q_s0 == codec.variance_bound(dim), (s, wire)
            assert np.all(sys_.q_sn == codec.variance_bound(dim)), (s, wire)


def test_cost_model_rejects_unrepresentable_s():
    """An s the wire can't carry must fail at pricing time, not silently
    underestimate bytes."""
    sys_ = _system_with([64, 64], s0=64, wire="int4")
    with pytest.raises(ValueError):
        _ = sys_.M_s0


def test_int4_wire_prices_4_bits_per_coordinate():
    dim = 10_000
    sys_ = _system_with([7, 7], s0=7, wire="int4", dim=dim)
    assert sys_.M_s0 == 32 + 4 * dim
    sys8 = _system_with([7, 7], s0=7, wire="int8", dim=dim)
    assert sys8.M_s0 == 32 + 8 * dim
    assert sys_.comm_time < sys8.comm_time


def test_tpu_fleet_parameterization():
    s = EdgeSystem.tpu_v5e_fleet(dim=int(1e9), n_groups=2,
                                 chips_per_group=256)
    assert s.N == 2
    assert time_cost(s, 10, [1, 1], 1) > 0
    assert energy_cost(s, 10, [1, 1], 1) > 0
