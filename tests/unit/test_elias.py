"""The "elias" wire: gap-coded Elias-omega streams over QSGD levels.

Covers the coder (round-trip including empty/odd/boundary inputs,
hypothesis property tests), cross-backend payload bit-exactness (jnp and
Pallas levels produce the same stream), the pricing contract (realized
stream <= both wire_bits arms; omega_max_bits monotone), FedConfig
validation, EdgeSystem/FedConfig pricing agreement, and the acceptance
bar: GIA optimizes a Scenario priced on the elias wire end-to-end with
the reference run's comm-bits matching the Plan's prediction exactly.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from tests.compat import given, settings, st

from repro import compress as C
from repro.api import (ConstantRule, EdgeSystem, MLProblemConstants,
                       QuadraticTask, Scenario)
from repro.compress import elias as E
from repro.fed.runtime import FedConfig
from repro.train.trainer import round_comm_bits

CONSTS = MLProblemConstants(L=0.084, sigma=33.18, G=33.63, f_gap=2.3, N=10)


def _omega_ref(n):
    """Independent python Elias-omega reference (transmission order)."""
    bits = [0]
    while n > 1:
        group = [int(c) for c in bin(n)[2:]]
        bits = group + bits
        n = len(group) - 1
    return bits


def _stream_ref(levels):
    """Independent python reference of the gap-coded stream."""
    bits = []
    prev = -1
    for i, v in enumerate(levels):
        if v == 0:
            continue
        bits += _omega_ref(i - prev)
        bits += _omega_ref(abs(int(v)))
        bits.append(1 if v < 0 else 0)
        prev = i
    bits += _omega_ref(len(levels) - prev)
    return bits


def _words_ref(bits, cap):
    w = np.zeros(cap, np.uint32)
    for j, b in enumerate(bits):
        if b:
            w[j >> 5] |= np.uint32(1) << np.uint32(j & 31)
    return w


def _levels(d, pattern, rng):
    lv = np.zeros(d, np.int8)
    if d == 0:
        return lv
    if pattern == "dense":
        lv = rng.integers(-127, 128, d).astype(np.int8)
    elif pattern == "sparse":
        idx = rng.choice(d, max(1, d // 40), replace=False)
        lv[idx] = (rng.integers(1, 8, idx.size)
                   * rng.choice([-1, 1], idx.size)).astype(np.int8)
    elif pattern == "ends":
        lv[0], lv[-1] = 7, -7
    elif pattern == "boundary":
        lv[: min(d, 4)] = [127, -127, 1, -1][: min(d, 4)]
    return lv


# ---------------------------------------------------------------------------
# coder round-trip + reference bit-exactness
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("d", [0, 1, 2, 7, 63, 64, 4097])
@pytest.mark.parametrize("pattern",
                         ["zeros", "dense", "sparse", "ends", "boundary"])
def test_roundtrip_and_reference_bits(d, pattern):
    rng = np.random.default_rng(d * 31 + hash(pattern) % 997)
    lv = _levels(d, pattern, rng)
    words, nbits = jax.jit(E.encode_levels)(jnp.asarray(lv))
    back = jax.jit(lambda w: E.decode_levels(w, d))(words)
    assert np.array_equal(np.asarray(back), lv)
    ref_bits = _stream_ref(lv)
    assert int(nbits) == len(ref_bits)
    assert int(nbits) == int(E.stream_bits(jnp.asarray(lv)))
    assert np.array_equal(np.asarray(words),
                          _words_ref(ref_bits, E.word_capacity(d)))


@settings(max_examples=40, deadline=None)
@given(st.lists(st.integers(min_value=-127, max_value=127), max_size=257))
def test_roundtrip_property(levels):
    lv = np.asarray(levels, np.int8)
    words, nbits = E.encode_levels(jnp.asarray(lv))
    back = E.decode_levels(words, lv.size)
    assert np.array_equal(np.asarray(back), lv)
    assert int(nbits) == len(_stream_ref(lv))


def test_vmap_jit_compose():
    rng = np.random.default_rng(0)
    lv = np.stack([_levels(300, "sparse", rng) for _ in range(3)])
    words, nbits = jax.jit(jax.vmap(E.encode_levels))(jnp.asarray(lv))
    back = jax.vmap(lambda w: E.decode_levels(w, 300))(words)
    assert np.array_equal(np.asarray(back), lv)
    assert nbits.shape == (3,)


def test_payload_bit_exact_across_backends():
    """jnp- and Pallas-quantized levels feed the shared coder: the wire
    payload must be bit-identical word for word."""
    key = jax.random.PRNGKey(5)
    y = jax.random.normal(key, (40_000,))
    u = jax.random.uniform(jax.random.fold_in(key, 1), (40_000,))
    lvl_j, _ = C.backends.encode_jnp(y, 7, u)
    lvl_p, _ = C.backends.encode_pallas(y, 7, u, interpret=True)
    w_j, n_j = E.encode_levels(lvl_j.astype(jnp.int8))
    w_p, n_p = E.encode_levels(lvl_p.astype(jnp.int8))
    assert int(n_j) == int(n_p)
    assert np.array_equal(np.asarray(w_j), np.asarray(w_p))


# ---------------------------------------------------------------------------
# pricing
# ---------------------------------------------------------------------------
def test_omega_lengths_known_values():
    assert [E.omega_length(n) for n in (1, 2, 3, 4, 7, 8, 15, 16)] == \
        [1, 3, 3, 6, 6, 7, 7, 11]


def test_omega_max_bits_monotone():
    vals = [E.omega_max_bits(s) for s in range(1, 200)]
    assert all(b >= a for a, b in zip(vals, vals[1:]))
    assert E.omega_max_bits(7) == 8     # unit gap + omega(<=7) + sign
    assert E.omega_max_bits(127) == 15  # == MAX_COORD_BITS


@pytest.mark.parametrize("d,s", [(257, 1), (16387, 5), (65536, 7)])
def test_realized_bits_bounded_by_pricing(d, s):
    """Realized stream <= the worst-case arm always, and (on these seeds)
    <= the priced min(worst, expected) that wire_bits charges."""
    key = jax.random.PRNGKey(d + s)
    y = jax.random.normal(key, (d,))
    u = jax.random.uniform(jax.random.fold_in(key, 1), (d,))
    lvl, _ = C.encode_tensor(y, s, u)
    bits = int(E.stream_bits(lvl))
    worst = d * E.omega_max_bits(s) + E._TERM_BITS
    assert bits <= worst
    assert bits <= E.payload_bits(s, d)
    # and the pricing itself is the documented closed form
    assert C.wire_bits(s, d, "elias") == 32.0 + E.payload_bits(s, d)


def test_wire_caps_and_exact_fallthrough():
    assert C.wire_max_s("elias") is None          # pricing unbounded in s
    # sparse low-s messages price via Thm 3.2, far under any fixed width
    assert C.wire_bits(5, 10**6, "elias") < 0.1 * C.wire_bits(
        5, 10**6, "packed")
    # dense high-s messages fall back to the worst-case omega arm
    assert (C.wire_bits(2**14, 10**6, "elias")
            == 32.0 + 24.0 * 10**6 + E._TERM_BITS)
    assert C.wire_bits(None, 100, "elias") == 32.0 * 101  # exact rides f32


# ---------------------------------------------------------------------------
# FedConfig / EdgeSystem agreement
# ---------------------------------------------------------------------------
def test_fedconfig_elias_validation():
    FedConfig(n_workers=2, Kn=(1, 1), s0=127, sn=64, wire="elias")
    with pytest.raises(ValueError, match="127"):
        FedConfig(n_workers=2, Kn=(1, 1), s0=128, sn=64, wire="elias")
    with pytest.raises(ValueError, match="127"):
        FedConfig(n_workers=2, Kn=(1, 1), s0=7, sn=200, wire="elias")
    # exact (s=None) workers are allowed: they ride raw f32, as priced
    FedConfig(n_workers=2, Kn=(1, 1), s0=None, sn=None, wire="elias")


def test_round_comm_bits_matches_edge_system_elias():
    dim = 100_000
    fed = FedConfig(n_workers=4, Kn=(1,) * 4, s0=64, sn=16, wire="elias")
    sys_ = EdgeSystem(F0=1.0, C0=1.0, p0=1.0, r0=1.0, s0=64, alpha0=1.0,
                      Fn=np.ones(4), Cn=np.ones(4), pn=np.ones(4),
                      rn=np.ones(4), sn=[16] * 4, alphan=np.ones(4),
                      dim=dim, wire="elias")
    assert np.allclose([c.wire_bits(dim) for c in fed.codecs()], sys_.M_sn)
    assert fed.server_codec().wire_bits(dim) == sys_.M_s0
    assert round_comm_bits(fed, dim) == float(np.sum(sys_.M_sn) + sys_.M_s0)


# ---------------------------------------------------------------------------
# the acceptance bar: GIA end-to-end on a Scenario priced on elias
# ---------------------------------------------------------------------------
def test_gia_optimizes_elias_scenario_end_to_end():
    task = QuadraticTask(dim=8)
    sys_ = dataclasses.replace(EdgeSystem.paper_sec_vii(dim=task.dim),
                               wire="elias")
    scn = Scenario(system=sys_, consts=CONSTS, T_max=1e5, C_max=0.25)
    plan = scn.optimize()
    assert plan.feasible and plan.wire == "elias"
    report = scn.run(plan, task=task)
    assert report.rounds == plan.K0
    # measured comm-bits == K0 * (sum_n M_sn + M_s0), priced on elias
    assert report.comm_bits == plan.K0 * (float(np.sum(sys_.M_sn))
                                          + sys_.M_s0)
    assert report.comm_bits == report.predicted_comm_bits
    assert report.comm_bits_match
    assert report.final_metrics["err"] < 0.05
