"""Assumption 1 (unbiasedness + variance bound) for the QSGD quantizer —
statistical tests for both the reference implementation (repro.core) and the
distributed runtime's counter-RNG variant (repro.fed.runtime)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import quantizer as Q
from repro.fed import runtime as RT


@pytest.mark.parametrize("s", [1, 4, 16, 127])
def test_unbiased_and_variance_bound(s):
    key = jax.random.PRNGKey(0)
    dim = 256
    y = jax.random.normal(key, (dim,)) * 2.0
    n = 4000
    qs = Q.variance_bound(s, dim)
    samples = jax.vmap(lambda k: Q.quantize_dequantize(y, s, k))(
        jax.random.split(key, n))
    err = samples - y
    # unbiasedness: per-coordinate mean error within 6 sigma, using the
    # ANALYTIC Bernoulli variance (norm/s)^2 frac(1-frac) — the empirical
    # estimate degenerates for rare-event coordinates at small s.
    norm = jnp.linalg.norm(y)
    u = s * jnp.abs(y) / norm
    frac = u - jnp.floor(u)
    coord_var = (norm / s) ** 2 * frac * (1 - frac)
    z = jnp.abs(samples.mean(0) - y) / (jnp.sqrt(coord_var / n) + 1e-9)
    assert float(jnp.max(z)) < 6.0
    # variance bound: E||Q(y)-y||^2 <= q_s ||y||^2
    ratio = float((err**2).sum(1).mean() / (y**2).sum())
    assert ratio <= qs * 1.05


def test_identity_when_s_none():
    y = jnp.arange(8.0)
    out = Q.quantize_dequantize(y, None, jax.random.PRNGKey(0))
    assert jnp.array_equal(out, y)


def test_levels_in_range():
    key = jax.random.PRNGKey(1)
    y = jax.random.normal(key, (512,)) * 10
    for s in (2, 8, 64):
        lvl, norm = Q.quantize(y, s, key)
        assert int(jnp.max(jnp.abs(lvl))) <= s
        assert float(norm) == pytest.approx(float(jnp.linalg.norm(y)),
                                            rel=1e-6)


@given(st.integers(min_value=1, max_value=127),
       st.integers(min_value=2, max_value=2048))
@settings(max_examples=30, deadline=None)
def test_bits_and_variance_monotone(s, dim):
    """M_s grows with s; q_s shrinks with s (the paper's trade-off axis)."""
    assert Q.bits_per_message(s + 1, dim) >= Q.bits_per_message(s, dim) - 1e-9
    assert Q.variance_bound(s + 1, dim) <= Q.variance_bound(s, dim) + 1e-12
    assert Q.variance_bound(s, dim) <= min(dim / s**2, np.sqrt(dim) / s) + 1e-12


def test_q_pair():
    assert Q.q_pair(0.0, 0.0) == 0.0
    assert Q.q_pair(0.5, 0.2) == pytest.approx(0.5 + 0.2 + 0.1)


# --- runtime (counter-RNG) variant -----------------------------------------
def test_runtime_quantizer_unbiased():
    dim, s, n = 128, 8, 3000
    key = jax.random.PRNGKey(2)
    y = jax.random.normal(key, (dim,))
    norm = jnp.linalg.norm(y)

    def one(i):
        u = RT.uniform_like(y, RT._seed_from(jax.random.PRNGKey(i), 0))
        lvl, nrm = RT.quantize_tensor(y, s, u)
        return RT.dequantize_tensor(lvl, nrm, s)

    samples = jnp.stack([one(i) for i in range(n)])
    err = samples - y
    per_coord_std = jnp.sqrt((err**2).mean(0)) / np.sqrt(n)
    assert float(jnp.max(jnp.abs(samples.mean(0) - y)
                         / (per_coord_std + 1e-9))) < 6.0
    ratio = float((err**2).sum(1).mean() / (y**2).sum())
    assert ratio <= Q.variance_bound(s, dim) * 1.05


def test_counter_rng_uniformity():
    x = jnp.zeros(200_000)
    u = np.asarray(RT.uniform_like(x, jnp.uint32(1234)))
    assert 0.0 <= u.min() and u.max() < 1.0
    assert abs(u.mean() - 0.5) < 0.005
    assert abs(np.var(u) - 1 / 12) < 0.002
    hist, _ = np.histogram(u, bins=16, range=(0, 1))
    assert hist.min() > 0.9 * len(u) / 16
