"""Assumption 1 (unbiasedness + variance bound) for the QSGD codec —
statistical tests for the single level implementation in repro.compress,
exercised both through jax.random noise (codec path) and the distributed
runtime's counter-RNG."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from tests.compat import given, settings, st

from repro import compress as C
from repro.fed import runtime as RT


@pytest.mark.parametrize("s", [1, 4, 16, 127])
def test_unbiased_and_variance_bound(s):
    key = jax.random.PRNGKey(0)
    dim = 256
    y = jax.random.normal(key, (dim,)) * 2.0
    n = 4000
    qs = C.variance_bound(s, dim)
    codec = C.make_codec(s)
    samples = jax.vmap(lambda k: codec.quantize_dequantize(y, k))(
        jax.random.split(key, n))
    err = samples - y
    # unbiasedness: per-coordinate mean error within 6 sigma, using the
    # ANALYTIC Bernoulli variance (norm/s)^2 frac(1-frac) — the empirical
    # estimate degenerates for rare-event coordinates at small s.
    norm = jnp.linalg.norm(y)
    u = s * jnp.abs(y) / norm
    frac = u - jnp.floor(u)
    coord_var = (norm / s) ** 2 * frac * (1 - frac)
    z = jnp.abs(samples.mean(0) - y) / (jnp.sqrt(coord_var / n) + 1e-9)
    assert float(jnp.max(z)) < 6.0
    # variance bound: E||Q(y)-y||^2 <= q_s ||y||^2
    ratio = float((err**2).sum(1).mean() / (y**2).sum())
    assert ratio <= qs * 1.05


def test_identity_codec_exact():
    y = jnp.arange(8.0)
    codec = C.make_codec(None)
    assert codec.is_identity and codec.variance_bound(8) == 0.0
    assert jnp.array_equal(codec.quantize_dequantize(y, jax.random.PRNGKey(0)),
                           y)
    lvl, norm = codec.encode(y, jnp.zeros_like(y))
    assert jnp.array_equal(codec.decode(lvl, norm), y)


def test_levels_in_range():
    key = jax.random.PRNGKey(1)
    y = jax.random.normal(key, (512,)) * 10
    for s in (2, 8, 64):
        codec = C.make_codec(s)
        u = jax.random.uniform(key, y.shape, jnp.float32)
        lvl, norm = codec.encode(y, u)
        assert lvl.dtype == jnp.int8
        assert int(jnp.max(jnp.abs(lvl.astype(jnp.int32)))) <= s
        assert float(norm) == pytest.approx(float(jnp.linalg.norm(y)),
                                            rel=1e-6)


def test_wide_quantizer_level_container():
    """s > 127 (the paper's s0 = 2^14) needs the int32 level container."""
    key = jax.random.PRNGKey(2)
    y = jax.random.normal(key, (128,))
    codec = C.make_codec(2**14)
    u = jax.random.uniform(key, y.shape, jnp.float32)
    lvl, norm = codec.encode(y, u)
    assert lvl.dtype == jnp.int32
    assert int(jnp.max(jnp.abs(lvl))) <= 2**14
    # stochastic rounding is within one quantization step per coordinate
    step = float(norm) / 2**14
    np.testing.assert_allclose(np.asarray(codec.decode(lvl, norm)),
                               np.asarray(y), rtol=0, atol=step * 1.001)


@given(st.integers(min_value=1, max_value=127),
       st.integers(min_value=2, max_value=2048))
@settings(max_examples=30, deadline=None)
def test_bits_and_variance_monotone(s, dim):
    """M_s grows with s; q_s shrinks with s (the paper's trade-off axis)."""
    assert C.bits_per_message(s + 1, dim) >= C.bits_per_message(s, dim) - 1e-9
    assert C.variance_bound(s + 1, dim) <= C.variance_bound(s, dim) + 1e-12
    assert C.variance_bound(s, dim) <= min(dim / s**2, np.sqrt(dim) / s) + 1e-12


def test_q_pair():
    assert C.q_pair(0.0, 0.0) == 0.0
    assert C.q_pair(0.5, 0.2) == pytest.approx(0.5 + 0.2 + 0.1)


# --- runtime (counter-RNG) noise through the same implementation -----------
def test_runtime_noise_unbiased():
    dim, s, n = 128, 8, 3000
    key = jax.random.PRNGKey(2)
    y = jax.random.normal(key, (dim,))

    def one(i):
        u = RT.uniform_like(y, RT._seed_from(jax.random.PRNGKey(i), 0))
        lvl, nrm = C.encode_tensor(y, s, u)
        return C.decode_tensor(lvl, nrm, s)

    samples = jnp.stack([one(i) for i in range(n)])
    err = samples - y
    per_coord_std = jnp.sqrt((err**2).mean(0)) / np.sqrt(n)
    assert float(jnp.max(jnp.abs(samples.mean(0) - y)
                         / (per_coord_std + 1e-9))) < 6.0
    ratio = float((err**2).sum(1).mean() / (y**2).sum())
    assert ratio <= C.variance_bound(s, dim) * 1.05


def test_traced_s_matches_static():
    """encode_tensor with a traced scalar s (heterogeneous vmap path) must
    agree exactly with the static-s codec."""
    key = jax.random.PRNGKey(3)
    y = jax.random.normal(key, (300,))
    u = jax.random.uniform(key, y.shape, jnp.float32)
    for s in (3, 64):
        lvl_static, n_static = C.make_codec(s).encode(y, u)
        lvl_traced, n_traced = jax.jit(
            lambda ss: C.encode_tensor(y, ss, u))(jnp.float32(s))
        assert jnp.array_equal(lvl_static, lvl_traced)
        assert float(n_static) == float(n_traced)


def test_counter_rng_uniformity():
    x = jnp.zeros(200_000)
    u = np.asarray(RT.uniform_like(x, jnp.uint32(1234)))
    assert 0.0 <= u.min() and u.max() < 1.0
    assert abs(u.mean() - 0.5) < 0.005
    assert abs(np.var(u) - 1 / 12) < 0.002
    hist, _ = np.histogram(u, bins=16, range=(0, 1))
    assert hist.min() > 0.9 * len(u) / 16
