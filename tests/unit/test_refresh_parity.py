"""Device-refresh parity: the jnp surrogate condensation of
``repro.opt.refresh`` must match ``condense.py``'s NumPy constructors (via
``ParamOptProblem.conv_block``) at the ulp level in log-space, across the
full (m, family, step-rule) grid.

The AM-GM / Taylor arithmetic is mirrored operation for operation, so the
C / D / J refreshes agree to <= 1 ulp (empirically bitwise on CPU).  The
m=E refresh routes two z-dependent scalars through ``exp``/``log`` twice,
where XLA's transcendental kernels may legally differ from libm by an ulp
each — those slots are allowed <= 4 ulp.
"""
import numpy as np
import pytest

from repro.api import (ConstantRule, DiminishingRule, EdgeSystem,
                       ExponentialRule, MLProblemConstants, Objective,
                       Scenario, family_names)
from repro.opt.condense import amgm_monomial, taylor_xlog1x
from repro.opt.posy import Posy
from repro.opt.refresh import RefreshPlan, make_refresh
from repro.opt.structure import PAD_LOGC

CONSTS = MLProblemConstants(L=0.084, sigma=33.18, G=33.63, f_gap=2.3, N=4)

STEPS = {
    Objective.CONSTANT: ConstantRule(0.01),
    Objective.EXPONENTIAL: ExponentialRule(0.02, 0.9995),
    Objective.DIMINISHING: DiminishingRule(0.02, 600.0),
    Objective.JOINT: None,
}

#: ulp budget per objective (log-space); see module docstring
ULP_BUDGET = {m: (4.0 if m is Objective.EXPONENTIAL else 1.0)
              for m in Objective}


def _problems(family, m, budgets=(0.22, 0.25, 0.3)):
    sys_ = EdgeSystem.paper_sec_vii(dim=1024, N=4)
    return [Scenario(system=sys_, consts=CONSTS, T_max=1e5, C_max=c,
                     family=family, step=STEPS[m]).problem()
            for c in budgets]


def _ulps(got, ref):
    denom = np.spacing(np.maximum(np.abs(got), np.abs(ref)))
    return np.abs(got - ref) / denom


def _device_refresh(probs, zs):
    import jax
    from jax.experimental import enable_x64

    plan = RefreshPlan.build(probs)
    refresh = make_refresh(plan.m, plan.n, plan.caps)
    with enable_x64():
        logc, A = jax.jit(jax.vmap(refresh, in_axes=(0, 0)))(
            np.stack(zs), plan.arrays)
        return plan, np.asarray(logc), np.asarray(A)


@pytest.mark.parametrize("family", family_names())
@pytest.mark.parametrize("m", list(Objective))
def test_device_refresh_matches_condense(family, m):
    """Full-grid parity of the fused coefficient refresh: per-constraint
    packed (log c, A) from the device equal conv_block's surrogates to the
    ulp budget, padding slots carry exactly PAD_LOGC, and exponent rows
    agree to float64 resolution."""
    probs = _problems(family, m)
    # expansion points along a GIA trajectory, not just z_init: the scalar
    # loop supplies realistic later-iteration points
    zs = []
    for p in probs:
        z = p.project_expansion(p.z_init())
        zs.append(z)
    plan, logc_d, A_d = _device_refresh(probs, zs)
    budget = ULP_BUDGET[m]
    for i, p in enumerate(probs):
        conv = p.conv_block(zs[i])
        assert len(conv) == len(plan.caps)
        off = 0
        for cap, c in zip(plan.caps, conv):
            k = c.n_terms
            assert k <= cap
            got_logc = logc_d[i, off:off + k]
            got_A = A_d[i, off:off + k]
            ref_logc = np.log(c.c)
            assert np.all(_ulps(got_logc, ref_logc) <= budget), (
                m, family, _ulps(got_logc, ref_logc).max())
            assert np.abs(got_A - c.A).max(initial=0.0) <= 4e-15
            # padding slots contribute exactly 0.0 to every log-sum-exp
            assert np.all(logc_d[i, off + k:off + cap] == PAD_LOGC)
            off += cap


def test_device_refresh_tracks_scalar_gia_trajectory():
    """Parity holds at later expansion points too — replay two scalar GIA
    steps and compare the refresh at each visited point."""
    from repro.opt.gp import solve_gp

    p = _problems("genqsgd", Objective.CONSTANT, budgets=(0.25,))[0]
    z = p.project_expansion(p.z_init())
    for _ in range(2):
        plan, logc_d, _ = _device_refresh([p], [z])
        ref = np.concatenate([np.log(c.c) for c in p.conv_block(z)])
        got = np.concatenate([logc_d[0, o:o + c.n_terms] for o, c in zip(
            np.cumsum((0,) + plan.caps[:-1]), p.conv_block(z))])
        assert np.all(_ulps(got, ref) <= 1.0)
        res = solve_gp(p.build(z), z)
        z = p.project_expansion(res.z)


# ---------------------------------------------------------------------------
# condense.py hardening (satellite): stable AM-GM weights, taylor signature
# ---------------------------------------------------------------------------
def test_amgm_monomial_extreme_z_no_inf():
    """Zero-weight terms must not inject -inf/nan into the condensed
    monomial: at extreme expansion points some term weights underflow to
    exactly 0.0 (and the term values themselves would overflow a naive
    u/u.sum())."""
    p = Posy(np.array([1.0, 2.0, 3.0]),
             np.array([[1.0, 0.0], [0.0, 1.0], [1.0, 1.0]]))
    for z in (np.array([800.0, -800.0]), np.array([-800.0, 800.0]),
              np.array([710.0, 710.0])):
        mono = amgm_monomial(p, z)
        assert np.isfinite(np.log(mono.c[0]))
        assert np.all(np.isfinite(mono.A))
        # property (ii): equality at the expansion point (log-space)
        assert mono.logvalue(z) == pytest.approx(p.logvalue(z), abs=1e-9)


def test_taylor_xlog1x_signature_and_bound():
    a, b = taylor_xlog1x(0.5)
    xs = np.linspace(1e-6, 0.999999, 64)
    phi = xs * np.log(1.0 / xs)
    assert np.all(phi <= a * xs + b + 1e-12)
    assert 0.5 * np.log(1.0 / 0.5) == pytest.approx(a * 0.5 + b)
