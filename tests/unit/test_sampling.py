"""repro.sampling: client sampling / partial participation, end-to-end.

Covers the ISSUE-7 acceptance bar:

  * **S=N reduction** — routing a neutral model (``full`` /
    ``uniform(S=N)``) through the sampling interface is *bit-identical*
    to the historical pipeline across an (m, family) grid: structure
    signature, z_init, conv-block coefficients, the whole GIA history,
    and the reference RunReport;
  * **S < N wins** — in a high-compute-energy regime the free-``S`` GP
    picks a strict sub-cohort with strictly lower expected energy than
    full participation, on both the scalar reference and the fused
    backend (which must agree exactly);
  * **closed loop** — a sampled reference run's realized per-round comm
    bits equal the Plan's expected bits (uniform cohorts, homogeneous
    quantizers), and same-seed runs reproduce bit-identical reports;
  * the runtime draw (systematic PPS) hits its inclusion probabilities,
    the Horvitz-Thompson reweighting is unbiased, and malformed models /
    configs fail loudly.
"""
import dataclasses

import numpy as np
import pytest

from repro.api import (ConstantRule, DiminishingRule, EdgeSystem,
                       ExponentialRule, MLProblemConstants, QuadraticTask,
                       Scenario, uniform, importance)
from repro.core.genqsgd import GenQSGDConfig
from repro.opt import solve_param_opt, structure_signature
from repro.opt.gia import solve_param_opt_batched
from repro.sampling import (SamplingModel, check_probs, cohort_weights,
                            draw_cohort, draw_cohort_weights, get_sampling,
                            sampling_names)

pytestmark = pytest.mark.sampling

N = 4
CONSTS = MLProblemConstants(L=0.084, sigma=33.18, G=33.63, f_gap=2.3, N=N)
#: the paper's Sec.-VII system — the regime where full participation wins
SYS = EdgeSystem.paper_sec_vii(dim=64, N=N)
#: homogeneous workers with 10x the paper's compute energy coefficient —
#: per-step energy is high enough that K-amortization stops paying and a
#: sub-cohort strictly lowers expected energy (the sampling-wins regime)
SYS_HOT = dataclasses.replace(
    EdgeSystem.paper_sec_vii(dim=64, N=N, F_ratio=1.0),
    alphan=np.full(N, 2e-27))

_STEP = {"C": dict(step=ConstantRule(0.01)),
         "J": dict(step=None),
         "E": dict(step=ExponentialRule(0.05, 0.9995)),
         "D": dict(step=DiminishingRule(0.02, 600.0))}


def _scenario(m="C", family="genqsgd", sampling="full", sys_=SYS,
              T_max=1e5, C_max=0.25):
    return Scenario(system=sys_, consts=CONSTS, T_max=T_max, C_max=C_max,
                    family=family, sampling=sampling, **_STEP[m])


def _hot(sampling="full", m="C"):
    kw = dict(_STEP[m])
    if m == "C":
        kw = dict(step=ConstantRule(3e-4))
    return Scenario(system=SYS_HOT, consts=CONSTS, T_max=1e7, C_max=0.25,
                    sampling=sampling, **kw)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------
def test_registry_contents():
    assert set(sampling_names()) >= {"full", "uniform"}
    assert get_sampling("full").is_neutral(N)
    assert isinstance(get_sampling("uniform"), SamplingModel)
    with pytest.raises(ValueError, match="unknown sampling model"):
        get_sampling("nope")


# ---------------------------------------------------------------------------
# S=N reduction: bit-identical to the historical pipeline
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("m,family", [
    ("C", "genqsgd"), ("J", "genqsgd"), ("E", "genqsgd"), ("D", "genqsgd"),
    ("C", "gqfedwavg"), ("J", "gqfedwavg")])
def test_neutral_reduction_bitwise(m, family):
    pf = _scenario(m, family).problem()
    pn = _scenario(m, family, sampling=uniform(S=N)).problem()
    assert structure_signature(pf) == structure_signature(pn)
    zf, zn = pf.z_init(), pn.z_init()
    assert np.array_equal(zf, zn)
    for cf, cn in zip(pf.conv_block(zf), pn.conv_block(zn)):
        assert np.array_equal(cf.c, cn.c) and np.array_equal(cf.A, cn.A)
    rf = solve_param_opt(pf, verbose=False)
    rn = solve_param_opt(pn, verbose=False)
    assert rf.K0 == rn.K0 and np.array_equal(rf.Kn, rn.Kn)
    assert rf.B == rn.B and rf.E == rn.E and rf.C == rn.C
    assert rf.history == rn.history       # every GIA iterate, bitwise
    assert rn.S is None


def test_neutral_plan_and_runreport_identical():
    full = _scenario("C").optimize()
    neut = _scenario("C", sampling=uniform(S=N)).optimize()
    assert neut == full                   # including sampling="full" fields
    task = QuadraticTask(dim=16)
    r_full = _scenario("C").run(full, task=task, seed=7, max_rounds=4)
    r_neut = _scenario("C", sampling=uniform(S=N)).run(
        neut, task=task, seed=7, max_rounds=4)
    norm = lambda r: dataclasses.replace(r, wall_time_s=0.0)  # noqa: E731
    assert norm(r_full) == norm(r_neut)
    assert r_neut.round_bits_trace == ()  # neutral = the historical path


# ---------------------------------------------------------------------------
# free S: the GP picks a strict sub-cohort where sampling wins
# ---------------------------------------------------------------------------
def test_free_S_picks_smaller_cohort_with_lower_energy():
    full = _hot().optimize()
    samp = _hot(sampling=uniform()).optimize()
    assert samp.feasible and samp.converged
    assert samp.cohort_S is not None and samp.cohort_S < N
    assert samp.predicted_E < full.predicted_E
    # the reported bound is the exact inflated one at the integer cohort
    prob = _hot(sampling=uniform()).problem()
    assert samp.predicted_C <= _hot().C_max + 1e-9
    assert prob.feasible(samp.K0, np.asarray(samp.Kn), samp.B,
                         S=samp.cohort_S)


@pytest.mark.parametrize("samp", [uniform(), uniform(S=2),
                                  importance((0.4, 0.3, 0.2, 0.1))])
def test_fused_backend_matches_reference(samp):
    p_ref = _hot(sampling=samp).problem()
    r_ref = solve_param_opt(p_ref, verbose=False)
    p_fused = _hot(sampling=samp).problem()
    r_fused = solve_param_opt_batched([p_fused], backend="jnp-fused")[0]
    assert r_ref.K0 == r_fused.K0 and np.array_equal(r_ref.Kn, r_fused.Kn)
    assert r_ref.B == r_fused.B and r_ref.S == r_fused.S
    assert np.isclose(r_ref.E, r_fused.E, rtol=1e-9)
    assert r_ref.feasible == r_fused.feasible


def test_sweep_N_axis_with_free_S():
    base = _hot(sampling=uniform())
    rep = base.sweep(over={"N": [4, 8]}, backend="numpy")
    assert [r["N"] for r in rep.rows] == [4, 8]
    for r in rep.rows:
        assert r["feasible"] and r["S"] is not None and r["S"] < r["N"]


# ---------------------------------------------------------------------------
# closed loop: plan bits == realized run bits, seeded reproducibility
# ---------------------------------------------------------------------------
def test_reference_run_realizes_expected_comm_bits():
    scn = _hot(sampling=uniform())
    plan = scn.optimize()
    task = QuadraticTask(dim=16)
    rep = scn.run(plan, task=task, seed=11, max_rounds=8)
    assert len(rep.round_bits_trace) == 8
    # uniform cohorts over homogeneous quantizers: realized == expected,
    # exactly, every round — so the whole-run bits close the loop too
    exp = plan.expected_round_bits(dim=rep.model_dim)
    assert all(b == exp for b in rep.round_bits_trace)
    assert rep.comm_bits == 8 * exp
    # and the Plan's own prediction uses the same expectation
    assert plan.predicted_comm_bits == plan.K0 * plan.expected_round_bits()


def test_same_seed_runs_are_identical():
    scn = _hot(sampling=uniform())
    plan = scn.optimize()
    task = QuadraticTask(dim=16)
    norm = lambda r: dataclasses.replace(r, wall_time_s=0.0)  # noqa: E731
    r1 = scn.run(plan, task=task, seed=5, max_rounds=6)
    r2 = scn.run(plan, task=task, seed=5, max_rounds=6)
    assert norm(r1) == norm(r2)
    # the cohort draws themselves are the seeded part: same seed, same
    # cohorts; different seed, (almost surely) different cohorts
    cfg1 = plan.to_genqsgd_config(max_K0=1, seed=5)
    rng_a = np.random.default_rng(cfg1.seed)
    rng_b = np.random.default_rng(cfg1.seed)
    a = [draw_cohort(rng_a, N, cfg1.sampling_S)[0] for _ in range(20)]
    b = [draw_cohort(rng_b, N, cfg1.sampling_S)[0] for _ in range(20)]
    assert all(np.array_equal(x, y) for x, y in zip(a, b))


def test_plan_expected_and_cohort_bits():
    scn = _hot(sampling=uniform())
    plan = scn.optimize()
    S = plan.cohort_S
    ups, down = plan._up_down()
    assert plan.expected_round_bits() == S * sum(ups) / N + down
    assert plan.cohort_round_bits(range(S)) == sum(ups[:S]) + down
    # full participation: expected bits ARE the historical round bits
    full = _hot().optimize()
    assert full.expected_round_bits() == full.round_bits()
    assert full.predicted_comm_bits == full.K0 * full.round_bits()


# ---------------------------------------------------------------------------
# runtime draw: inclusion probabilities + unbiased reweighting
# ---------------------------------------------------------------------------
def test_systematic_pps_hits_inclusion_probabilities():
    rng = np.random.default_rng(0)
    p = np.array([0.4, 0.3, 0.2, 0.1])
    S, trials = 2, 4000
    counts = np.zeros(N)
    for _ in range(trials):
        idx, pi = draw_cohort(rng, N, S, p)
        assert len(idx) == S and len(set(idx.tolist())) == S
        counts[idx] += 1
    assert np.allclose(counts / trials, S * p, atol=0.03)


def test_horvitz_thompson_unbiased():
    rng = np.random.default_rng(1)
    d = np.array([3.0, -1.0, 2.0, 5.0])        # per-worker "deltas"
    w = np.array([0.1, 0.2, 0.3, 0.4])         # family aggregation weights
    target = float(np.sum(w * d))
    acc = 0.0
    trials = 6000
    for _ in range(trials):
        idx, u = draw_cohort_weights(rng, N, 2, p=None, agg_weights=w)
        acc += float(np.sum(u * d))
    assert acc / trials == pytest.approx(target, abs=0.05)
    # the weight vector masks exactly the cohort
    idx, u = draw_cohort_weights(rng, N, 2)
    assert np.count_nonzero(u) == 2 and set(np.flatnonzero(u)) == set(idx)


def test_reference_runtime_cohort_trace_and_unbiased_full_S():
    """sampling_S=N with uniform p gives pi_n=1 and u_n=w_n — the sampled
    round computes the exact full aggregation."""
    idx, u = draw_cohort_weights(np.random.default_rng(0), N, N)
    assert np.array_equal(np.sort(idx), np.arange(N))
    assert np.allclose(u, 1.0 / N)


# ---------------------------------------------------------------------------
# validation: malformed models / configs fail loudly
# ---------------------------------------------------------------------------
def test_validation_errors():
    with pytest.raises(ValueError, match="sum to 1"):
        importance((0.5, 0.2, 0.2, 0.2))
    with pytest.raises(ValueError, match="positive"):
        importance((1.2, -0.2, 0.0, 0.0))
    with pytest.raises(ValueError, match="outside"):
        _scenario("C", sampling=uniform(S=9))
    with pytest.raises(ValueError, match="probabilities"):
        _scenario("C", sampling=importance((0.5, 0.5)))
    with pytest.raises(ValueError, match="above 1"):
        _scenario("C", sampling=importance((0.7, 0.1, 0.1, 0.1), S=2))
    with pytest.raises(ValueError, match="sampling_p"):
        GenQSGDConfig(K0=1, Kn=(1,) * N, B=1, step_rule=ConstantRule(0.01),
                      sampling_p=(0.25,) * N)
    with pytest.raises(ValueError, match="outside"):
        GenQSGDConfig(K0=1, Kn=(1,) * N, B=1, step_rule=ConstantRule(0.01),
                      sampling_S=9)


def test_fed_config_wire_compat():
    from repro.fed.runtime import FedConfig
    ok = FedConfig(n_workers=N, Kn=(1,) * N, s0=3, sn=3, wire="f32",
                   sampling_S=2, seed=0)
    assert ok.sampling_S == 2
    # bucketed level wires aggregate outside shard_map: supported
    FedConfig(n_workers=N, Kn=(1,) * N, s0=3, sn=3, wire="int8", bucket=16,
              sampling_S=2)
    with pytest.raises(ValueError, match="sampling"):
        FedConfig(n_workers=N, Kn=(1,) * N, s0=3, sn=3, wire="rs_ag",
                  sampling_S=2)
    with pytest.raises(ValueError, match="sampling"):
        FedConfig(n_workers=N, Kn=(1,) * N, s0=3, sn=3, wire="int8",
                  sampling_S=2)           # non-bucketed int8: inside shard_map
