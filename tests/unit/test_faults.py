"""repro.faults: seeded fault injection + deadline-HT aggregation.

Covers the ISSUE-9 acceptance bar:

  * **no-fault reduction** — a neutral fault model (``none`` / an
    all-zero ``edge_faults()``) routed through the fault interface is
    *bit-identical* to the historical pipeline across an (m, family)
    grid: structure signature, z_init, conv-block coefficients, the
    whole GIA history, the frozen Plan and the reference RunReport;
  * **determinism** — a (seed, model) pair reproduces the bit-identical
    ``FaultTrace`` run over run; different seeds diverge;
  * **unbiasedness** — the deadline-HT aggregation vector is an unbiased
    estimator of the full blocking aggregate under dropout, alone and
    composed with client sampling;
  * **planning** — availability inflates the convergence coefficients by
    the exact ``pi_n -> a_n pi_n`` joint form, the worst-case margins
    derate only the time constraint (bitwise no-ops at zero margin), and
    the frozen plan carries a correct fault contract;
  * checksum-detected corruption, and malformed models / specs / configs
    fail loudly.
"""
import dataclasses

import numpy as np
import pytest

from repro.api import (ConstantRule, DiminishingRule, EdgeSystem,
                       ExponentialRule, MLProblemConstants, QuadraticTask,
                       Scenario, edge_faults, uniform)
from repro.core.cost import time_cost
from repro.faults import (EdgeFaults, FaultDriver, FaultModel, FaultSpec,
                          FaultTrace, NoFaults, fault_names, fault_rng,
                          flip_bits, get_faults, payload_checksum)
from repro.opt import solve_param_opt, structure_signature
from repro.sampling.base import draw_cohort

pytestmark = pytest.mark.faults

N = 4
CONSTS = MLProblemConstants(L=0.084, sigma=33.18, G=33.63, f_gap=2.3, N=N)
SYS = EdgeSystem.paper_sec_vii(dim=64, N=N)

_STEP = {"C": dict(step=ConstantRule(0.01)),
         "J": dict(step=None),
         "E": dict(step=ExponentialRule(0.05, 0.9995)),
         "D": dict(step=DiminishingRule(0.02, 600.0))}

#: a genuinely faulty fleet: stragglers + 2-round crashes + corruption
FAULTY = edge_faults(straggler_prob=0.3, straggler_factor=4.0,
                     crash_prob=0.1, crash_rounds=2, corrupt_prob=0.05,
                     deadline_slack=1.5)


def _scenario(m="C", family="genqsgd", faults="none", sampling="full",
              T_max=1e6, C_max=1.0):
    return Scenario(system=SYS, consts=CONSTS, T_max=T_max, C_max=C_max,
                    family=family, sampling=sampling, faults=faults,
                    **_STEP[m])


def _spec(model, t=1.0, slack=None):
    """A FaultSpec over homogeneous worker times (driver-level tests)."""
    wt = np.full(N, float(t))
    deadline = (model.deadline_slack if slack is None else slack) * float(t)
    return FaultSpec(model=model, worker_times=tuple(wt),
                     deadline=float(deadline),
                     deliver_p=tuple(model.deliver_prob(wt, deadline)))


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------
def test_registry_contents():
    assert set(fault_names()) >= {"none", "edge"}
    assert get_faults("none").is_neutral(N)
    assert isinstance(get_faults("edge"), FaultModel)
    with pytest.raises(ValueError, match="unknown fault model"):
        get_faults("nope")


# ---------------------------------------------------------------------------
# no-fault reduction: bit-identical to the historical pipeline
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("m,family", [
    ("C", "genqsgd"), ("J", "genqsgd"), ("E", "genqsgd"), ("D", "genqsgd"),
    ("C", "gqfedwavg"), ("J", "gqfedwavg")])
@pytest.mark.parametrize("neutral", [NoFaults(), edge_faults()])
def test_neutral_reduction_bitwise(m, family, neutral):
    assert neutral.is_neutral(N)
    p0 = _scenario(m, family).problem()
    pn = _scenario(m, family, faults=neutral).problem()
    assert structure_signature(p0) == structure_signature(pn)
    z0, zn = p0.z_init(), pn.z_init()
    assert np.array_equal(z0, zn)
    for c0, cn in zip(p0.conv_block(z0), pn.conv_block(zn)):
        assert np.array_equal(c0.c, cn.c) and np.array_equal(c0.A, cn.A)
    r0 = solve_param_opt(p0, verbose=False)
    rn = solve_param_opt(pn, verbose=False)
    assert r0.K0 == rn.K0 and np.array_equal(r0.Kn, rn.Kn)
    assert r0.B == rn.B and r0.E == rn.E and r0.C == rn.C
    assert r0.history == rn.history       # every GIA iterate, bitwise


def test_neutral_plan_and_runreport_identical():
    base = _scenario("C").optimize()
    neut = _scenario("C", faults=edge_faults()).optimize()
    assert neut == base                   # including faults=None
    assert neut.faults is None
    task = QuadraticTask(dim=16)
    r_base = _scenario("C").run(base, task=task, seed=7, max_rounds=4)
    r_neut = _scenario("C", faults=edge_faults()).run(
        neut, task=task, seed=7, max_rounds=4)
    norm = lambda r: dataclasses.replace(r, wall_time_s=0.0)  # noqa: E731
    assert norm(r_base) == norm(r_neut)
    assert r_neut.fault_trace is None     # neutral = the historical path


def test_faulty_signature_differs_and_keys_faults():
    p0 = _scenario("C").problem()
    pf = _scenario("C", faults=FAULTY).problem()
    sig0, sigf = structure_signature(p0), structure_signature(pf)
    assert sig0 != sigf
    assert sigf[-1] == FAULTY.signature(N) and sig0[-1] == ("none",)
    # two different fault models never share a signature pool
    other = dataclasses.replace(FAULTY, straggler_prob=0.4)
    assert structure_signature(_scenario("C", faults=other).problem()) != sigf


# ---------------------------------------------------------------------------
# seeded determinism: same (seed, model) => bit-identical FaultTrace
# ---------------------------------------------------------------------------
def test_reference_run_fault_trace_deterministic():
    scn = _scenario("C", faults=FAULTY)
    plan = scn.optimize()
    assert plan.faults is not None and plan.faults.model == FAULTY
    task = QuadraticTask(dim=16)
    r1 = scn.run(plan, task=task, seed=3, max_rounds=12)
    r2 = scn.run(plan, task=task, seed=3, max_rounds=12)
    assert isinstance(r1.fault_trace, FaultTrace)
    assert len(r1.fault_trace) == 12
    assert r1.fault_trace == r2.fault_trace          # bitwise, all records
    norm = lambda r: dataclasses.replace(r, wall_time_s=0.0)  # noqa: E731
    assert norm(r1) == norm(r2)                      # whole report too
    r3 = scn.run(plan, task=task, seed=4, max_rounds=12)
    assert r3.fault_trace != r1.fault_trace          # seeds matter


def test_fault_rng_stream_is_salted():
    # the fault stream must not alias the cohort stream built from the
    # same user seed, or sampling+faults runs would correlate draws
    a = fault_rng(7).random(8)
    b = np.random.default_rng(7).random(8)
    assert not np.allclose(a, b)
    assert np.array_equal(a, fault_rng(7).random(8))


def test_crash_markov_chain_holds_down_rounds():
    """crash_rounds=R keeps a crashed worker down exactly R consecutive
    rounds; the chain's realized up-fraction approaches the stationary
    value availability() plans with."""
    fm = edge_faults(crash_prob=0.2, crash_rounds=3)
    drv = FaultDriver(_spec(fm), N)
    rng = fault_rng(0)
    rounds = 4000
    for r in range(rounds):
        drv.step(rng, r)
    down = np.zeros((rounds, N), bool)
    for r, rec in enumerate(drv.records):
        down[r, list(rec.crashed)] = True
    # every down-spell lasts >= min(R, remaining rounds): a worker crashed
    # at r while up at r-1 stays down at r+1 and r+2
    starts = down[1:] & ~down[:-1]
    for r, n in zip(*np.nonzero(starts)):
        spell = down[r + 1:r + 4, n]
        assert spell[:min(3, rounds - r - 1)].all()
    up_frac = 1.0 - down.mean()
    assert up_frac == pytest.approx(fm._up_frac, abs=0.02)
    assert fm.availability(N)[0] == pytest.approx(fm._up_frac)


# ---------------------------------------------------------------------------
# deadline-HT aggregation: exclusion + unbiasedness
# ---------------------------------------------------------------------------
def test_deadline_excludes_stragglers():
    fm = edge_faults(straggler_prob=0.4, straggler_factor=4.0,
                     deadline_slack=1.5)
    spec = _spec(fm, t=1.0)               # deadline 1.5, straggler arrival 4
    assert spec.deliver_p == (0.6,) * N
    drv = FaultDriver(spec, N)
    rng = fault_rng(1)
    saw_straggler = False
    for r in range(200):
        u = drv.step(rng, r)
        rec = drv.last
        assert set(rec.delivered).isdisjoint(rec.straggled)
        assert np.all(np.flatnonzero(u) == np.asarray(rec.delivered))
        if rec.straggled:
            saw_straggler = True
            assert rec.t_blocking == pytest.approx(4.0)
            assert rec.t_round == pytest.approx(1.5)   # cut at the deadline
        else:
            assert rec.t_round == pytest.approx(1.0)   # nominal round
    assert saw_straggler


def test_blocking_fallback_waits_for_stragglers():
    # slack=inf: nobody is excluded and the round waits for the slowest
    fm = edge_faults(straggler_prob=0.4, straggler_factor=4.0)
    drv = FaultDriver(_spec(fm), N)
    rng = fault_rng(1)
    for r in range(50):
        u = drv.step(rng, r)
        rec = drv.last
        assert rec.delivered == rec.cohort and not rec.n_dropped
        assert rec.t_round == rec.t_blocking
        assert np.allclose(u, 1.0 / N)    # deliver_p = 1: plain weights


def test_deadline_ht_unbiased_under_dropout():
    """E[sum_n u_n d_n] = sum_n w_n d_n over the fault draw (iid crashes
    + corruption), the core deadline-HT guarantee."""
    fm = edge_faults(crash_prob=0.25, corrupt_prob=0.1)
    w = np.array([0.1, 0.2, 0.3, 0.4])
    drv = FaultDriver(_spec(fm), N, agg_weights=w)
    assert np.allclose(drv._dp, 0.75 * 0.9)
    d = np.array([3.0, -1.0, 2.0, 5.0])
    target = float(np.sum(w * d))
    rng = fault_rng(2)
    trials = 8000
    acc = sum(float(np.sum(drv.step(rng, r) * d)) for r in range(trials))
    assert acc / trials == pytest.approx(target, abs=0.05)


def test_deadline_ht_composes_with_client_sampling():
    """Faults x sampling: u = cohort_weights / deliver_p stays unbiased
    over BOTH the cohort draw and the fault draw."""
    fm = edge_faults(straggler_prob=0.3, straggler_factor=4.0,
                     crash_prob=0.2, deadline_slack=1.5)
    drv = FaultDriver(_spec(fm, t=1.0), N)
    d = np.array([3.0, -1.0, 2.0, 5.0])
    target = float(np.mean(d))
    crng = np.random.default_rng(0)
    frng = fault_rng(0)
    trials = 8000
    acc = 0.0
    for r in range(trials):
        idx, pi = draw_cohort(crng, N, 2)
        u = drv.step(frng, r, idx, pi)
        assert set(np.flatnonzero(u)) <= set(int(i) for i in idx)
        acc += float(np.sum(u * d))
    assert acc / trials == pytest.approx(target, abs=0.08)
    # the attempted cohort recorded each round is the sampled one
    assert all(len(rec.cohort) == 2 for rec in drv.records)


# ---------------------------------------------------------------------------
# payload corruption: checksum-detected bit flips
# ---------------------------------------------------------------------------
def test_checksum_detects_bit_flips():
    rng = np.random.default_rng(0)
    payload = rng.standard_normal(256).astype(np.float32)
    ref = payload_checksum(payload)
    assert ref == payload_checksum(payload.copy())   # content-addressed
    for _ in range(20):
        bad = flip_bits(payload, rng)
        assert payload_checksum(bad) != ref
    assert payload_checksum(payload) == ref          # input untouched
    many = flip_bits(payload, rng, n_flips=8)
    assert payload_checksum(many) != ref


def test_corrupt_workers_are_excluded_but_recorded():
    fm = edge_faults(corrupt_prob=0.3)
    drv = FaultDriver(_spec(fm), N)
    rng = fault_rng(3)
    corrupt_seen = 0
    for r in range(100):
        u = drv.step(rng, r)
        rec = drv.last
        corrupt_seen += len(rec.corrupt)
        assert set(rec.delivered).isdisjoint(rec.corrupt)
        assert np.all(u[list(rec.corrupt)] == 0.0)
        # a corrupt upload still arrives: it never inflates round time
        assert rec.t_round == pytest.approx(1.0)
    assert corrupt_seen > 0


# ---------------------------------------------------------------------------
# planning: availability coefficients + worst-case margins
# ---------------------------------------------------------------------------
def test_availability_inflates_conv_coeffs_exactly():
    """Full participation with availability a: q_eff = (q+1-a)/a and
    c3 scales by 1/a — the sampling ratio form with pi_n -> a_n."""
    fm = edge_faults(crash_prob=0.3)      # R=1: availability is exact
    a = fm.availability(N)[0]
    assert a == pytest.approx(0.7)
    p0 = _scenario("C").problem()
    pf = _scenario("C", faults=fm).problem()
    c0, q0 = p0._conv_coeffs()
    cf, qf = pf._conv_coeffs()
    assert np.allclose(np.asarray(qf), (np.asarray(q0) + 1.0 - a) / a)
    assert cf[2] == pytest.approx(c0[2] / a)
    assert cf[0] == c0[0] and cf[1] == c0[1] and cf[3] == c0[3]
    # planning for dropout costs rounds: the faulted plan runs more K0
    b0 = _scenario("C").optimize()
    bf = _scenario("C", faults=fm).optimize()
    assert bf.K0 > b0.K0
    # direct EdgeSystem(an=...) is the same arithmetic, no model needed
    sys_a = dataclasses.replace(SYS, an=np.full(N, a))
    pa = dataclasses.replace(_scenario("C"), system=sys_a).problem()
    ca, qa = pa._conv_coeffs()
    assert np.array_equal(np.asarray(qa), np.asarray(qf)) and ca == cf


def test_availability_composes_with_pinned_sampling():
    """uniform(S=2) x availability a: q_eff = (q+1-a pi)/(a pi)."""
    fm = edge_faults(crash_prob=0.3)
    a = fm.availability(N)[0]
    pi = 2.0 / N
    p0 = _scenario("C").problem()
    pf = _scenario("C", faults=fm, sampling=uniform(S=2)).problem()
    _, q0 = p0._conv_coeffs()
    cf, qf = pf._conv_coeffs()
    assert np.allclose(np.asarray(qf),
                       (np.asarray(q0) + 1.0 - a * pi) / (a * pi))
    assert cf[2] == pytest.approx(p0._conv_coeffs()[0][2] / (a * pi))


def test_worst_case_margins_derate_time_only():
    base = _scenario("C").optimize()
    fm = edge_faults(freq_margin=0.2, rate_margin=0.2)
    assert not fm.is_neutral(N) and not fm.runtime_active(N)
    marg = _scenario("C", faults=fm).optimize()
    assert marg.faults is None            # margin-only: no runtime driver
    # the margins price a slower fleet: predicted T at the SAME decision
    # variables is strictly larger, energy arithmetic is untouched
    sys_m = dataclasses.replace(SYS, freq_margin=0.2, rate_margin=0.2)
    t_nom = time_cost(SYS, base.K0, base.Kn, base.B)
    t_wc = time_cost(sys_m, base.K0, base.Kn, base.B, worst_case=True)
    assert t_wc > t_nom
    assert time_cost(sys_m, base.K0, base.Kn, base.B) == t_nom
    # zero margins return the SAME cached objects — bitwise guarantee
    assert SYS.comp_time_coeff_wc is SYS.comp_time_coeff
    assert SYS.comm_time_wc == SYS.comm_time
    assert not np.array_equal(sys_m.comp_time_coeff_wc,
                              sys_m.comp_time_coeff)
    assert sys_m.comm_time_wc > sys_m.comm_time


def test_plan_carries_fault_contract():
    scn = _scenario("C", faults=FAULTY)
    plan = scn.optimize()
    spec = plan.faults
    sys = scn._priced_system
    wt = plan.B * sys.comp_time_coeff * np.asarray(plan.Kn) \
        + sys.M_sn / sys.rn
    round_t = plan.B * float(np.max(sys.comp_time_coeff
                                    * np.asarray(plan.Kn))) + sys.comm_time
    assert spec.N == N
    assert np.allclose(spec.worker_times, wt)
    assert spec.deadline == pytest.approx(1.5 * round_t)
    assert np.allclose(spec.deliver_p,
                       FAULTY.deliver_prob(wt, spec.deadline))
    assert "faults=edge" in plan.describe()
    # the spec survives the runtime-config handoff (the fed-config side is
    # covered by test_fed_config_faults_wire_compat: this plan's quantizer
    # is too wide for the f32 wire, which is orthogonal to faults)
    assert plan.to_genqsgd_config(seed=0).faults is spec


# ---------------------------------------------------------------------------
# validation: malformed models / specs / configs fail loudly
# ---------------------------------------------------------------------------
def test_validation_errors():
    with pytest.raises(ValueError, match="straggler_prob"):
        _scenario("C", faults=edge_faults(straggler_prob=1.2))
    with pytest.raises(ValueError, match="deadline_slack"):
        _scenario("C", faults=edge_faults(deadline_slack=0.5))
    with pytest.raises(ValueError, match="straggler_factor"):
        _scenario("C", faults=edge_faults(straggler_prob=0.1,
                                          straggler_factor=0.5))
    with pytest.raises(ValueError, match="crash_rounds"):
        edge_faults(crash_prob=0.1, crash_rounds=0).validate(N)
    with pytest.raises(ValueError, match="freq_margin"):
        _scenario("C", faults=edge_faults(freq_margin=1.0))
    with pytest.raises(ValueError, match="delivery probabilities"):
        FaultSpec(model=EdgeFaults(), worker_times=(1.0,) * N,
                  deadline=1.0, deliver_p=(0.0,) * N)
    with pytest.raises(ValueError, match="delivery probabilities"):
        FaultSpec(model=EdgeFaults(), worker_times=(1.0, 1.0),
                  deadline=1.0, deliver_p=(0.5,))
    with pytest.raises(ValueError, match="workers"):
        FaultDriver(_spec(EdgeFaults()), N + 1)


def test_fed_config_faults_wire_compat():
    from repro.fed.runtime import FedConfig
    spec = _spec(edge_faults(crash_prob=0.1))
    ok = FedConfig(n_workers=N, Kn=(1,) * N, s0=3, sn=3, wire="f32",
                   faults=spec, seed=0)
    assert ok.faults is spec
    FedConfig(n_workers=N, Kn=(1,) * N, s0=3, sn=3, wire="int8", bucket=16,
              faults=spec)
    with pytest.raises(ValueError, match="fault"):
        FedConfig(n_workers=N, Kn=(1,) * N, s0=3, sn=3, wire="rs_ag",
                  faults=spec)
    with pytest.raises(ValueError, match="fault"):
        FedConfig(n_workers=N, Kn=(1,) * N, s0=3, sn=3, wire="int8",
                  faults=spec)            # non-bucketed: inside shard_map


# ---------------------------------------------------------------------------
# adaptive deadline: EMA-tracked tau (frozen stays the default)
# ---------------------------------------------------------------------------
ADAPTIVE = edge_faults(straggler_prob=0.3, straggler_factor=4.0,
                       crash_prob=0.1, crash_rounds=2, corrupt_prob=0.05,
                       deadline_slack=1.5, deadline="adaptive",
                       ema_alpha=0.3)


def test_adaptive_default_frozen_and_signature_invariant():
    assert edge_faults(deadline_slack=1.5).deadline == "frozen"
    # deadline mode is a runtime aggregation policy, not GP structure:
    # adaptive and frozen models share the structure signature (and hence
    # PlanServer batching pools and fused-engine executables)
    assert ADAPTIVE.signature(N) == FAULTY.signature(N)


def test_adaptive_round0_is_frozen_tau_bitwise():
    # the EMA is seeded at the plan's predicted round time, so the first
    # adaptive tau IS the frozen tau and round 0 is bitwise identical
    d_frozen = FaultDriver(_spec(FAULTY), N)
    d_adapt = FaultDriver(_spec(ADAPTIVE), N)
    u_f = d_frozen.step(fault_rng(0), 0)
    u_a = d_adapt.step(fault_rng(0), 0)
    assert d_adapt.records[0].deadline == d_frozen.records[0].deadline
    assert d_adapt.records[0] == d_frozen.records[0]
    assert np.array_equal(u_a, u_f)


def test_adaptive_tau_replays_censored_ema():
    # heterogeneous fleet; spec deadline = slack x predicted round time
    wt = np.array([0.5, 0.8, 1.0, 2.0])
    slack = ADAPTIVE.deadline_slack
    deadline = slack * float(wt.max())
    spec = FaultSpec(model=ADAPTIVE, worker_times=tuple(wt),
                     deadline=float(deadline),
                     deliver_p=tuple(ADAPTIVE.deliver_prob(wt, deadline)))
    drv = FaultDriver(spec, N)
    rng = fault_rng(123)
    for k in range(60):
        drv.step(rng, k)
    # replay the EMA by hand: tau_k = max(slack * ema_{k-1}, max_n t_n),
    # ema updated with the *censored* realized time (t_round <= tau_k)
    tau_floor = float(wt.max())
    ema = deadline / slack
    taus = set()
    for rec in drv.records:
        assert rec.deadline == max(slack * ema, tau_floor)   # exact floats
        assert rec.deadline >= tau_floor
        assert rec.t_round <= rec.deadline
        ema += ADAPTIVE.ema_alpha * (rec.t_round - ema)
        taus.add(rec.deadline)
    assert len(taus) > 5                  # tau genuinely tracks the regime


def test_adaptive_trace_deterministic_and_seed_sensitive():
    spec = _spec(ADAPTIVE)

    def trace(seed):
        drv = FaultDriver(spec, N)
        rng = fault_rng(seed)
        for k in range(40):
            drv.step(rng, k)
        return drv.trace()

    assert trace(5) == trace(5)
    assert trace(5) != trace(6)


def test_adaptive_scenario_run_varies_tau_frozen_does_not():
    task = QuadraticTask(dim=16)
    scn_a = _scenario("C", faults=ADAPTIVE)
    rep_a = scn_a.run(scn_a.optimize(), task=task, seed=7, max_rounds=25)
    assert len({r.deadline for r in rep_a.fault_trace.records}) > 1
    scn_f = _scenario("C", faults=FAULTY)
    rep_f = scn_f.run(scn_f.optimize(), task=task, seed=7, max_rounds=25)
    assert len({r.deadline for r in rep_f.fault_trace.records}) == 1


def test_adaptive_validation_errors():
    with pytest.raises(ValueError, match="'frozen' or 'adaptive'"):
        edge_faults(deadline="bogus").validate(N)
    with pytest.raises(ValueError, match="finite deadline_slack"):
        edge_faults(deadline="adaptive").validate(N)
    with pytest.raises(ValueError, match="ema_alpha"):
        edge_faults(deadline="adaptive", deadline_slack=1.5,
                    ema_alpha=0.0).validate(N)
    with pytest.raises(ValueError, match="ema_alpha"):
        edge_faults(deadline="adaptive", deadline_slack=1.5,
                    ema_alpha=1.5).validate(N)
