"""Posynomial algebra property tests (hypothesis)."""
import numpy as np
import pytest
from tests.compat import given, settings, st

from repro.opt.posy import Posy, const, monomial, var


def _rand_posy(rng, n=3, k=4):
    return Posy(rng.uniform(0.1, 3.0, k), rng.uniform(-2, 2, (k, n)))


@given(st.integers(0, 100))
@settings(max_examples=25, deadline=None)
def test_add_mul_values(seed):
    rng = np.random.default_rng(seed)
    n = 3
    p, q = _rand_posy(rng), _rand_posy(rng)
    z = rng.normal(size=n)
    assert (p + q).value(z) == pytest.approx(p.value(z) + q.value(z),
                                             rel=1e-9)
    assert (p * q).value(z) == pytest.approx(p.value(z) * q.value(z),
                                             rel=1e-9)
    assert (p * 2.5).value(z) == pytest.approx(2.5 * p.value(z), rel=1e-9)


@given(st.integers(0, 100))
@settings(max_examples=25, deadline=None)
def test_monomial_division_and_powers(seed):
    rng = np.random.default_rng(seed)
    n = 3
    p = _rand_posy(rng)
    m = monomial(1.7, {0: 1.0, 2: -0.5}, n)
    z = rng.normal(size=n)
    assert (p / m).value(z) == pytest.approx(p.value(z) / m.value(z),
                                             rel=1e-9)
    assert (3.0 / m).value(z) == pytest.approx(3.0 / m.value(z), rel=1e-9)
    assert (m ** 2.5).value(z) == pytest.approx(m.value(z) ** 2.5, rel=1e-9)
    assert (p ** 2).value(z) == pytest.approx(p.value(z) ** 2, rel=1e-8)


def test_grad_hess_match_finite_differences():
    rng = np.random.default_rng(0)
    n = 3
    p = _rand_posy(rng)
    z = rng.normal(size=n) * 0.3
    f, g, H = p.grad_hess_log(z)
    eps = 1e-5
    for i in range(n):
        dz = np.zeros(n)
        dz[i] = eps
        fd = (p.logvalue(z + dz) - p.logvalue(z - dz)) / (2 * eps)
        assert g[i] == pytest.approx(fd, abs=1e-6)
        for j in range(n):
            dj = np.zeros(n)
            dj[j] = eps
            fd2 = ((p.logvalue(z + dz + dj) - p.logvalue(z + dz - dj)
                    - p.logvalue(z - dz + dj) + p.logvalue(z - dz - dj))
                   / (4 * eps * eps))
            assert H[i, j] == pytest.approx(fd2, abs=1e-4)


def test_coefficients_must_be_positive():
    with pytest.raises(ValueError):
        Posy(np.array([1.0, -0.1]), np.zeros((2, 2)))


def test_division_by_posynomial_rejected():
    p = const(1.0, 2) + var(0, 2)
    with pytest.raises(ValueError):
        _ = var(1, 2) / p
