"""PlanServer: signature micro-batching, the warm-start plan cache, and
the one-compile-per-signature guarantee.

The fast subset uses a single small signature (N=4, dim=1024) so the one
fused compile it pays is shared across every test in the module via the
process-level executable cache.  Stream-scale behavior (mixed signatures,
LRU eviction under pressure) is marked ``serve`` + ``slow``.
"""
import dataclasses

import numpy as np
import pytest

from repro.api import (ConstantRule, EdgeSystem, MLProblemConstants,
                       Objective, Scenario)
from repro.serve import (PlanCache, PlanServer, fingerprint,
                         fingerprint_distance)
from repro.serve.planserver import _CacheEntry, _quantize

CONSTS = MLProblemConstants(L=0.084, sigma=33.18, G=33.63, f_gap=2.3, N=4)
SYS = EdgeSystem.paper_sec_vii(dim=1024, N=4)


def _scenario(C_max=0.25, T_max=1e5, family="genqsgd", step=ConstantRule(0.01)):
    return Scenario(system=SYS, consts=CONSTS, T_max=T_max, C_max=C_max,
                    family=family, step=step)


def _server(**kw):
    kw.setdefault("max_batch", 4)
    kw.setdefault("window_s", 0.01)
    return PlanServer(**kw)


# ---------------------------------------------------------------------------
# fingerprints
# ---------------------------------------------------------------------------
def test_fingerprint_identity_and_distance():
    a = fingerprint(_scenario(C_max=0.25).problem())
    a2 = fingerprint(_scenario(C_max=0.25).problem())
    b = fingerprint(_scenario(C_max=0.2501).problem())
    far = fingerprint(_scenario(C_max=0.4).problem())
    assert np.array_equal(a, a2)
    assert _quantize(a) == _quantize(a2)
    assert _quantize(a) != _quantize(b)
    assert fingerprint_distance(a, a) == 0.0
    # a 0.04% budget nudge is a *near* neighbor, a 60% change is not
    assert 0.0 < fingerprint_distance(b, a) < 1e-3
    assert fingerprint_distance(far, a) > 0.05


def test_plan_cache_lru_eviction():
    cache = PlanCache(maxsize=2)
    vecs = [np.array([float(i)]) for i in range(3)]
    for i, v in enumerate(vecs):
        cache.put(("sig",), _quantize(v), _CacheEntry(v, result=i))
    assert len(cache) == 2
    assert cache.get(("sig",), _quantize(vecs[0])) is None      # evicted
    assert cache.get(("sig",), _quantize(vecs[2])).result == 2
    # touching an entry protects it from the next eviction
    cache.get(("sig",), _quantize(vecs[1]))
    v3 = np.array([3.0])
    cache.put(("sig",), _quantize(v3), _CacheEntry(v3, result=3))
    assert cache.get(("sig",), _quantize(vecs[1])) is not None
    assert cache.get(("sig",), _quantize(vecs[2])) is None
    # nearest() only sees surviving entries of the signature
    near, d = cache.nearest(("sig",), np.array([2.9]))
    assert near.result == 3 and d == pytest.approx(0.1 / 4.0)
    assert cache.nearest(("other",), v3)[0] is None


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------
def test_server_micro_batches_one_signature_one_compile():
    """Concurrent same-signature requests coalesce into micro-batches,
    solve in one padded fused dispatch each, and the whole stream pays at
    most one trace of the fused program (the executable may even be
    inherited from an earlier test — hence <=, asserted not measured)."""
    budgets = [0.22, 0.24, 0.26, 0.3]
    with _server(window_s=0.05) as srv:
        handles = [srv.submit(_scenario(C_max=c)) for c in budgets]
        plans = [h.result(timeout=300) for h in handles]
    for c, p, h in zip(budgets, plans, handles):
        assert p.feasible and p.converged
        assert h.source == "cold" and h.batch_size == 4
        ref = _scenario(C_max=c).optimize()
        assert (p.K0, p.B, p.Kn) == (ref.K0, ref.B, ref.Kn)
    st = srv.stats()
    assert st["submitted"] == 4 and st["cold"] == 4 and st["batches"] == 1
    assert all(c <= 1 for c in srv.compile_counts().values())


def test_exact_hit_serves_cached_plan_without_solving():
    with _server() as srv:
        p1 = srv.solve(_scenario(C_max=0.25))
        h = srv.submit(_scenario(C_max=0.25))    # identical fingerprint
        assert h.done() and h.source == "hit"
        p2 = h.result()
        assert dataclasses.asdict(p1) == dataclasses.asdict(p2)
        st = srv.stats()
        assert st["hits"] == 1 and st["batches"] == 1    # no second solve
        assert st["hit_rate"] == pytest.approx(0.5)


def test_warm_request_seeds_from_neighbor_and_matches_cold():
    with _server(tol=1e-8) as srv:
        cold = srv.solve(_scenario(C_max=0.25))
        h = srv.submit(_scenario(C_max=0.25005))  # 0.02% away: warm
        warm = h.result(timeout=300)
        assert h.source == "warm" and h.warm_dist < 1e-3
        assert h.z0 is not None
        # a from-scratch solve of the same scenario agrees exactly
        ref = _scenario(C_max=0.25005).optimize(backend="jnp-fused",
                                                tol=1e-8)
        assert (warm.K0, warm.B, warm.Kn) == (ref.K0, ref.B, ref.Kn)
        assert warm.predicted_E == pytest.approx(ref.predicted_E, rel=1e-6)
        assert cold.feasible and warm.feasible


def test_optimize_server_kwarg_routes_through_server():
    with _server() as srv:
        direct = _scenario(C_max=0.27).optimize()
        served = _scenario(C_max=0.27).optimize(server=srv)
        assert (served.K0, served.B, served.Kn) == (direct.K0, direct.B,
                                                    direct.Kn)
        assert srv.stats()["submitted"] == 1


def test_closed_server_rejects_and_drains():
    srv = _server(window_s=5.0)                  # window >> test: close()
    h = srv.submit(_scenario(C_max=0.25))        # must force the drain
    srv.close()
    assert h.done() and h.result().feasible
    with pytest.raises(RuntimeError, match="closed"):
        srv.submit(_scenario(C_max=0.3))


def test_failed_batch_resolves_handles_with_error():
    """A solver exception must resolve every handle of the batch with the
    error message — never leave a caller blocked on a dead batch."""
    import collections

    from repro.opt.structure import structure_signature
    from repro.serve.planserver import PlanHandle

    s = _scenario(C_max=0.25)
    prob = s.problem(Objective.CONSTANT)
    bad = PlanHandle(s, Objective.CONSTANT, prob,
                     structure_signature(prob), fingerprint(prob), b"x")
    bad.source = "warm"
    bad.z0 = np.zeros(3)                         # wrong-shape seed: solver
    with _server() as srv:                       # raises inside the batch
        with srv._cond:
            srv._queues.setdefault(bad.sig,
                                   collections.deque()).append(bad)
            srv._cond.notify_all()
        with pytest.raises(RuntimeError):
            bad.result(timeout=300)
        assert bad.error is not None


def test_poison_request_batch_resolves_healthy_peers():
    """THE fault-isolation guarantee: one poison row in a micro-batch
    (corrupt warm seed — the fused solver raises on it) must not take its
    healthy batch peers down.  The dispatcher bisects the batch, every
    healthy row solves, and the poison row is quarantined (solo retries
    keep failing on the same bad seed) before erroring its handle."""
    import collections

    from repro.opt.structure import structure_signature
    from repro.serve.planserver import PlanHandle

    budgets = [0.22, 0.25, 0.3]
    s = _scenario(C_max=0.27)
    prob = s.problem(Objective.CONSTANT)
    bad = PlanHandle(s, Objective.CONSTANT, prob,
                     structure_signature(prob), fingerprint(prob), b"x")
    bad.source = "warm"
    bad.z0 = np.zeros(3)                         # wrong-shape seed: poison
    srv = _server(window_s=0.2, retry_base_s=0.001, retry_cap_s=0.01,
                  start=False)
    healthy = [srv.submit(_scenario(C_max=c)) for c in budgets]
    with srv._cond:                              # same queue, same batch
        srv._queues[bad.sig].insert(1, bad)
    with srv:
        plans = [h.result(timeout=300) for h in healthy]
        with pytest.raises(RuntimeError):
            bad.result(timeout=300)
    for c, p, h in zip(budgets, plans, healthy):
        assert p.feasible and h.converged
        ref = _scenario(C_max=c).optimize()
        assert (p.K0, p.B, p.Kn) == (ref.K0, ref.B, ref.Kn)
    st = srv.stats()
    assert st["bisections"] >= 1                 # the batch was split
    assert st["quarantined"] == 1 and st["poisoned"] == 1
    assert bad.error is not None and not bad.converged


def test_cancel_pending_request_skipped_and_counted():
    srv = _server(window_s=0.2, start=False)     # dispatcher not running:
    keep = srv.submit(_scenario(C_max=0.24))     # both requests stay queued
    drop = srv.submit(_scenario(C_max=0.26))
    assert drop.cancel() is True
    assert drop.done() and drop.cancelled
    with pytest.raises(RuntimeError, match="cancelled"):
        drop.result()
    with srv:
        plan = keep.result(timeout=300)
    assert plan.feasible and keep.cancel() is False   # too late to cancel
    st = srv.stats()
    assert st["cancelled"] == 1
    assert st["batches"] == 1 and st["mean_batch"] == 1.0   # solo batch


def test_converged_surfaces_on_handle_and_stats():
    with _server() as srv:
        h1 = srv.submit(_scenario(C_max=0.25))
        h1.result(timeout=300)
        assert h1.converged is True
        h2 = srv.submit(_scenario(C_max=0.25))   # exact hit: cached result
        assert h2.source == "hit" and h2.converged is True
        assert srv.stats()["non_converged"] == 0
    # a solve stopped before convergence is surfaced, not cached
    with _server(max_iter=1) as srv:
        h = srv.submit(_scenario(C_max=0.25))
        p = h.result(timeout=300)
        assert h.converged is False and p.converged is False
        st = srv.stats()
        assert st["non_converged"] == 1 and st["cache_entries"] == 0


@pytest.mark.serve
@pytest.mark.slow
def test_stream_mixed_signatures_and_joint_warm():
    """An interleaved stream over three signatures (m=C, m=J, gqfedwavg):
    every request returns its scenario's own plan, signatures never share a
    batch, and the trace pays <=1 fused compile per signature."""
    scens = []
    for c in (0.22, 0.25, 0.3):
        scens.append(_scenario(C_max=c))
        scens.append(_scenario(C_max=c, step=None))            # m=J
        scens.append(_scenario(C_max=c, family="gqfedwavg"))
    with _server(max_batch=3, window_s=0.05) as srv:
        handles = [srv.submit(s) for s in scens]
        plans = [h.result(timeout=600) for h in handles]
        # warm round: jitter every budget by 0.1%
        warm_handles = [srv.submit(dataclasses.replace(
            s, C_max=s.C_max * 1.001)) for s in scens]
        warm_plans = [h.result(timeout=600) for h in warm_handles]
    for s, p in zip(scens, plans):
        ref = s.optimize()
        assert (p.K0, p.B) == (ref.K0, ref.B)
    assert all(h.source == "warm" for h in warm_handles)
    assert all(h.batch_size <= 3 for h in handles + warm_handles)
    for p in warm_plans:
        assert p.feasible
    st = srv.stats()
    assert st["signatures"] == 3
    assert all(c <= 1 for c in srv.compile_counts().values())


# ---------------------------------------------------------------------------
# client sampling: sampled and full plans never share a pool
# ---------------------------------------------------------------------------
def test_sampled_and_full_scenarios_key_separate_pools():
    """A sampled Scenario (same m/family/N) must get its own signature,
    queue, and cache pool: identical budgets on a full and a uniform(S=2)
    scenario may NOT cross-serve each other's cached plans."""
    from repro.api import uniform
    from repro.opt.structure import structure_signature

    full = _scenario(C_max=0.25)
    samp = dataclasses.replace(full, sampling=uniform(S=2))
    sig_f = structure_signature(full.problem())
    sig_s = structure_signature(samp.problem())
    assert sig_f != sig_s
    # the fingerprints live in different pools, so no exact-hit crossover
    with _server(backend="numpy") as srv:
        p_full = srv.solve(full)
        h = srv.submit(samp)                 # same budgets, sampled model
        p_samp = h.result(timeout=300)
        assert h.source == "cold"            # NOT served from the full pool
        assert p_full.cohort_S is None and p_samp.cohort_S == 2
        st = srv.stats()
        assert st["signatures"] == 2 and st["hits"] == 0
    # neutral uniform(S=N) folds back into the full pool (("full",) key)
    neut = dataclasses.replace(full, sampling=uniform(S=4))
    assert structure_signature(neut.problem()) == sig_f
