"""repro.obs: spans, metrics, the drift ledger — and the observer-effect
guarantee.

Covers the ISSUE-10 acceptance bar:

  * **inert when off** — with the global switch down (the default), every
    instrument drops its sample after one attribute check, ``span()``
    returns a shared no-op, and nothing is buffered;
  * **observer effect = none** — enabling observability leaves the Plan,
    the RunReport (modulo its wall-clock field — real time differs
    between *any* two runs) and the FaultTrace bit-identical across an
    (m, family) grid, and flipping it on over a warm fused cache re-traces
    nothing (one-compile-per-signature still holds);
  * **ledger purity** — ``RunReport.drift()`` is a pure function of the
    frozen report: identical object whether obs is on or off, exact
    cumulative sums, JSONL round-trip;
  * **PlanServer stats as a registry view** — per-source latency
    summaries, queue depth / inflight gauges, balanced queue→solve async
    span pairs in the Chrome export.
"""
import dataclasses
import json
import math
import os

import numpy as np
import pytest

from repro import obs
from repro.api import (ConstantRule, EdgeSystem, MLProblemConstants,
                       QuadraticTask, Scenario, edge_faults)
from repro.obs.bench import ENVELOPE_KEYS, write_bench
from repro.obs.ledger import RunLedger
from repro.obs.metrics import MetricsRegistry, Switch
from repro.obs.trace import Tracer

pytestmark = pytest.mark.obs

N = 4
CONSTS = MLProblemConstants(L=0.084, sigma=33.18, G=33.63, f_gap=2.3, N=N)
SYS = EdgeSystem.paper_sec_vii(dim=64, N=N)
#: same signature as test_planserver, so the one fused compile is shared
SYS_1024 = EdgeSystem.paper_sec_vii(dim=1024, N=N)

FAULTY = edge_faults(straggler_prob=0.3, straggler_factor=4.0,
                     crash_prob=0.1, crash_rounds=2, corrupt_prob=0.05,
                     deadline_slack=1.5)


def _scenario(m="C", family="genqsgd", faults="none", system=SYS,
              C_max=1.0):
    step = None if m == "J" else ConstantRule(0.01)
    return Scenario(system=system, consts=CONSTS, T_max=1e6, C_max=C_max,
                    family=family, faults=faults, step=step)


def _strip_wall(report):
    """RunReport modulo its one genuinely non-deterministic field."""
    return dataclasses.replace(report, wall_time_s=0.0)


@pytest.fixture(autouse=True)
def _obs_off_after():
    """Every test starts and ends with observability off and clean."""
    obs.disable()
    yield
    obs.disable()
    obs.TRACER.clear()
    obs.REGISTRY.reset()


# ---------------------------------------------------------------------------
# metrics primitives
# ---------------------------------------------------------------------------
def test_instruments_inert_when_off():
    reg = MetricsRegistry(Switch(False))
    c, g, h = reg.counter("c"), reg.gauge("g"), reg.histogram("h")
    c.inc(5)
    g.set(3.0)
    g.add(1.0)
    h.observe(1.0)
    assert c.value == 0.0 and g.value == 0.0 and h.count == 0
    assert h.summary() == {"count": 0}
    # the global registry is gated on the global switch (down by default)
    obs.REGISTRY.counter("test.never").inc()
    assert obs.REGISTRY.counter("test.never").value == 0.0


def test_counter_gauge_histogram_record():
    reg = MetricsRegistry()                      # own switch: always on
    c = reg.counter("solves", backend="numpy")
    c.inc()
    c.inc(2.5)
    assert c.value == 3.5
    g = reg.gauge("depth")
    g.set(4)
    g.add(-1)
    assert g.value == 3.0
    h = reg.histogram("lat")
    for v in range(100):
        h.observe(float(v))
    assert h.count == 100 and h.total == 4950.0
    assert h.vmin == 0.0 and h.vmax == 99.0 and h.mean == 49.5
    # exact linear-interpolation percentiles over the retained samples
    assert h.percentile(50) == pytest.approx(49.5)
    assert h.percentile(99) == pytest.approx(98.01)
    s = h.summary()
    assert s["count"] == 100 and s["p95"] == pytest.approx(94.05)


def test_registry_get_or_create_and_labels():
    reg = MetricsRegistry()
    a = reg.counter("x", backend="jnp")
    b = reg.counter("x", backend="jnp")
    c = reg.counter("x", backend="numpy")
    assert a is b and a is not c
    assert a.full_name == 'x{backend="jnp"}'
    assert len(reg) == 2
    reg.reset()
    assert len(reg) == 0


def test_prometheus_and_snapshot():
    reg = MetricsRegistry()
    reg.counter("gia.solves", backend="jnp-fused").inc(3)
    reg.gauge("planserver.queue_depth").set(2)
    reg.histogram("lat").observe(1.0)
    text = reg.to_prometheus()
    assert 'gia_solves{backend="jnp-fused"} 3' in text
    assert "# TYPE gia_solves counter" in text
    assert "planserver_queue_depth 2" in text
    assert "lat_count 1" in text and 'quantile="0.50"' in text
    snap = reg.snapshot()
    assert snap['gia.solves{backend="jnp-fused"}'] == 3.0
    assert snap["lat"]["count"] == 1


# ---------------------------------------------------------------------------
# tracer
# ---------------------------------------------------------------------------
def test_tracer_noop_when_off():
    tr = Tracer(Switch(False))
    with tr.span("a"):
        pass
    tr.add_span("b", 0.0, 1.0)
    tr.async_span("c", 1, 0.0, 1.0)
    tr.instant("d")
    assert len(tr) == 0
    # the no-op context manager is shared: zero per-call allocation
    assert tr.span("a") is tr.span("b")


def test_tracer_spans_and_chrome_export(tmp_path):
    import time

    tr = Tracer()
    with tr.span("outer", note="warm"):
        with tr.span("inner"):
            pass
    t = time.perf_counter()
    tr.async_span("req", span_id=7, t_start=t, t_end=t + 0.5, cat="srv",
                  source="hit")
    tr.instant("mark")
    evs = tr.events()
    assert [e["ph"] for e in evs] == ["X", "X", "b", "e", "i"]
    assert evs[0]["name"] == "inner"             # inner exits first
    assert evs[1]["args"] == {"note": "warm"}
    assert evs[2]["id"] == 7 and evs[2]["cat"] == "srv"
    assert all(e["ts"] >= 0 for e in evs)
    doc = tr.to_chrome()
    assert doc["displayTimeUnit"] == "ms" and len(doc["traceEvents"]) == 5
    path = tr.save(str(tmp_path / "trace.json"))
    assert json.load(open(path)) == doc
    tr.clear()
    assert len(tr) == 0


# ---------------------------------------------------------------------------
# bench envelope
# ---------------------------------------------------------------------------
def test_write_bench_uniform_envelope(tmp_path):
    p = str(tmp_path / "BENCH_x.json")
    doc = write_bench(p, "x", {"speedup": 2.0}, smoke=True)
    loaded = json.load(open(p))
    assert loaded == doc
    for k in ENVELOPE_KEYS:
        assert k in loaded
    assert loaded["bench"] == "x" and loaded["smoke"] is True
    assert loaded["bench_schema"] == 2 and loaded["speedup"] == 2.0
    assert loaded["machine"]["cpus"] >= 1
    with pytest.raises(ValueError, match="shadow"):
        write_bench(p, "x", {"machine": {}})


def test_repo_bench_artifacts_share_schema():
    """Every committed BENCH_*.json rides the uniform envelope."""
    import glob

    root = os.path.join(os.path.dirname(__file__), "..", "..")
    paths = sorted(glob.glob(os.path.join(root, "BENCH_*.json")))
    assert paths, "no BENCH_*.json artifacts at the repo root"
    for p in paths:
        doc = json.load(open(p))
        missing = [k for k in ENVELOPE_KEYS if k not in doc]
        assert not missing, f"{os.path.basename(p)} missing {missing}"
        assert doc["bench_schema"] == 2, os.path.basename(p)


# ---------------------------------------------------------------------------
# drift ledger
# ---------------------------------------------------------------------------
def test_ledger_rows_and_cumulative_sums(tmp_path):
    scn = _scenario(faults=FAULTY)
    plan = scn.optimize("C")
    rep = scn.run(plan, task=QuadraticTask(dim=8), seed=3, max_rounds=12)
    led = rep.drift()
    assert isinstance(led, RunLedger) and len(led) == rep.rounds
    assert led.backend == "reference" and led.family == "genqsgd"
    # per-round predictions are the plan totals amortized over K0
    r0 = led.rows[0]
    assert r0.predicted_time_s == pytest.approx(plan.predicted_T / plan.K0)
    assert r0.predicted_energy_j == pytest.approx(plan.predicted_E / plan.K0)
    assert r0.predicted_bits == pytest.approx(plan.expected_round_bits())
    # measured round times come from the fault trace, cut at the deadline
    for row, rec in zip(led.rows, rep.fault_trace.records):
        assert row.measured_time_s == pytest.approx(rec.t_round)
        assert row.measured_time_s <= plan.faults.deadline + 1e-12
    # cumulative columns are exact running sums; drift matches by hand
    last = led.rows[-1]
    assert last.cum_measured_time_s == pytest.approx(
        sum(r.measured_time_s for r in led.rows))
    assert last.drift_time == pytest.approx(
        last.cum_measured_time_s / last.cum_predicted_time_s - 1.0)
    assert led.cumulative()["drift_time"] == last.drift_time
    assert "cumulative drift" in led.summary()
    # JSONL round-trip, summary line included
    path = led.to_jsonl(str(tmp_path / "ledger.jsonl"))
    assert RunLedger.load_jsonl(path) == led
    lines = [json.loads(l) for l in open(path)]
    assert len(lines) == len(led) + 1 and lines[-1]["summary"] is True


def test_ledger_is_pure_function_of_report():
    scn = _scenario(faults=FAULTY)
    plan = scn.optimize("C")
    task = QuadraticTask(dim=8)
    obs.disable()
    rep_off = scn.run(plan, task=task, seed=3, max_rounds=10)
    obs.enable(reset=True)
    rep_on = scn.run(plan, task=task, seed=3, max_rounds=10)
    obs.disable()
    assert rep_on.drift() == rep_off.drift()


def test_empty_ledger_cumulative_is_nan():
    c = RunLedger().cumulative()
    assert all(math.isnan(v) for v in c.values())


# ---------------------------------------------------------------------------
# observer effect: enabling obs changes no result, adds no compile
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("m", ["C", "J"])
@pytest.mark.parametrize("family", ["genqsgd", "gqfedwavg"])
def test_plans_bit_identical_on_off(m, family):
    scn = _scenario(m, family, faults=FAULTY, C_max=0.5)
    obs.disable()
    p_off = scn.optimize(backend="numpy")
    obs.enable(reset=True)
    p_on = scn.optimize(backend="numpy")
    assert p_on == p_off
    # the instrumentation did record while on (the scalar numpy engine is
    # wrapped by the scenario.optimize span, not the batched-dispatch hooks)
    assert any(e["name"] == "scenario.optimize"
               for e in obs.TRACER.events())


def test_run_report_and_fault_trace_bit_identical_on_off():
    scn = _scenario(faults=FAULTY)
    plan = scn.optimize("C")
    task = QuadraticTask(dim=8)
    obs.disable()
    rep_off = scn.run(plan, task=task, seed=7, max_rounds=10)
    obs.enable(reset=True)
    rep_on = scn.run(plan, task=task, seed=7, max_rounds=10)
    obs.disable()
    # == compares every field including FaultTrace and history; only the
    # wall-clock field may differ (it differs between ANY two runs)
    assert _strip_wall(rep_on) == _strip_wall(rep_off)
    assert rep_on.fault_trace == rep_off.fault_trace


def test_enabling_obs_adds_no_fused_compile():
    from repro.opt import gia_jax

    scn = _scenario(system=SYS_1024, C_max=0.25)
    obs.disable()
    p_off = scn.optimize(backend="jnp-fused")    # pays the compile (or warm)
    warm = sum(gia_jax.TRACE_COUNTS.values())
    obs.enable(reset=True)
    p_on = scn.optimize(backend="jnp-fused")
    obs.disable()
    assert sum(gia_jax.TRACE_COUNTS.values()) == warm, \
        "enabling obs re-traced the fused engine"
    assert p_on == p_off
    # the dispatch span was stamped after the solve's own host sync
    names = {e["name"] for e in obs.TRACER.events()}
    assert "gia.fused_dispatch" in names


def test_scenario_run_writes_ledger_artifact(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_OBS_DIR", str(tmp_path))
    scn = _scenario(faults=FAULTY)
    plan = scn.optimize("C")
    obs.enable(reset=True)
    rep = scn.run(plan, task=QuadraticTask(dim=8), seed=3, max_rounds=8)
    obs.disable()
    path = tmp_path / "ledger_genqsgd_reference_seed3.jsonl"
    assert path.exists()
    assert RunLedger.load_jsonl(str(path)) == rep.drift()


# ---------------------------------------------------------------------------
# PlanServer: stats() as a registry view + span export
# ---------------------------------------------------------------------------
def test_planserver_stats_and_spans():
    from repro.serve import PlanServer

    obs.enable(reset=True)
    try:
        with PlanServer(max_batch=4, window_s=0.01) as srv:
            h1 = srv.submit(_scenario(system=SYS_1024, C_max=0.25))
            h1.result(timeout=300)
            h2 = srv.submit(_scenario(system=SYS_1024, C_max=0.25))  # hit
            h2.result(timeout=300)
            st = srv.stats()
    finally:
        obs.disable()

    # historical keys survive the registry-view rewrite
    for k in ("submitted", "hits", "warm", "cold", "hit_rate", "batches",
              "mean_batch", "cancelled", "bisections", "quarantined",
              "poisoned", "non_converged", "signatures", "cache_entries",
              "compiles"):
        assert k in st, k
    assert st["submitted"] == 2 and st["hits"] == 1
    # new: live gauges (drained server: all idle) + latency summaries
    assert st["queue_depth"] == 0 and st["inflight"] == 0
    assert isinstance(st["queue_depth"], int)
    assert st["latency_s"]["all"]["count"] == 2
    assert st["latency_s"]["hit"]["count"] == 1
    assert st["latency_s"]["hit"]["p50"] <= st["latency_s"]["all"]["max"]
    assert st["queue_wait_s"]["count"] >= 1

    # queue -> solve async pairs are balanced (Perfetto drops unbalanced
    # tracks) and the batch span is present
    evs = obs.TRACER.events()
    names = {e["name"] for e in evs}
    assert {"planserver.queue", "planserver.solve",
            "planserver.batch"} <= names
    for nm in ("planserver.queue", "planserver.solve"):
        b = sum(1 for e in evs if e["name"] == nm and e["ph"] == "b")
        e_ = sum(1 for e in evs if e["name"] == nm and e["ph"] == "e")
        assert b == e_ > 0, (nm, b, e_)
    assert any(e["name"] == "planserver.hit" and e["ph"] == "i"
               for e in evs)


def test_planserver_measures_even_with_global_obs_off():
    """stats() is public API: the server's own registry is always on."""
    from repro.serve import PlanServer

    assert not obs.enabled()
    with PlanServer(max_batch=2, window_s=0.01) as srv:
        srv.solve(_scenario(system=SYS_1024, C_max=0.25))
        st = srv.stats()
    assert st["submitted"] == 1 and st["latency_s"]["all"]["count"] == 1
    # ...but the global tracer stayed empty (no span leaks while off)
    assert len(obs.TRACER) == 0
