"""repro.compress codec subsystem: wire formats, bucketed norms, and the
int4-transport == f32-transport bit-identity the runtime relies on."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compress as C


def test_make_codec_dispatch():
    assert isinstance(C.make_codec(None), C.IdentityCodec)
    assert isinstance(C.make_codec(8), C.QSGDCodec)
    with pytest.raises(ValueError):
        C.make_codec(0)
    with pytest.raises(ValueError):
        C.make_codec(8, wire="int4")          # cap: int4 carries s <= 7
    with pytest.raises(ValueError):
        C.make_codec(200, wire="int8")        # cap: int8 carries s <= 127
    with pytest.raises(ValueError):
        C.make_codec(8, backend="cuda")
    with pytest.raises(ValueError):
        C.make_codec(300, backend="pallas")  # int8 kernel container
    with pytest.raises(ValueError):
        C.encode_tensor(jnp.ones(4), 300, jnp.zeros(4))


def test_wire_bits_table():
    dim = 1000
    assert C.wire_bits(None, dim) == 32.0 * (dim + 1)
    assert C.wire_bits(7, dim, "int4") == 32 + 4 * dim
    assert C.wire_bits(127, dim, "int8") == 32 + 8 * dim
    assert C.wire_bits(64, dim, "f32") == 32.0 * dim
    assert C.wire_bits(64, dim, "rs_ag") == 32.0 * dim
    assert C.wire_bits(64, dim, "packed") == 32 + dim * (1 + 7)
    # bucketing adds one 32-bit norm word per bucket
    assert C.wire_bits(7, dim, "int4", bucket=100) == 10 * 32 + 4 * dim
    with pytest.raises(ValueError):
        C.wire_bits(8, dim, "int4")
    with pytest.raises(ValueError):
        C.wire_bits(64, dim, "carrier_pigeon")


def test_int4_wire_bit_identical_to_f32_transport():
    """The acceptance bar: for s <= 7 the packed int4 payload dequantizes to
    the SAME aggregated mean as the f32 transport — packing is lossless."""
    key = jax.random.PRNGKey(0)
    n_workers, dim = 4, 2053                      # odd dim: exercises padding
    sn = (7, 5, 3, 7)                             # heterogeneous codecs
    deltas = jax.random.normal(key, (n_workers, dim)) * 2.0
    noise = jax.random.uniform(jax.random.fold_in(key, 1), (n_workers, dim))

    f32_terms, int4_terms = [], []
    for w, s in enumerate(sn):
        codec = C.make_codec(s, wire="int4")
        lvl, norm = codec.encode(deltas[w], noise[w])
        # f32 transport: dequantized values travel
        f32_terms.append(codec.decode(lvl, norm))
        # int4 transport: packed levels travel, dequantize at the receiver
        wire_payload = C.pack_int4(lvl)
        assert wire_payload.size == (dim + 1) // 2  # 2x fewer bytes than int8
        lvl_rx = C.unpack_int4(wire_payload, dim)
        int4_terms.append(codec.decode(lvl_rx, norm))

    mean_f32 = jnp.stack(f32_terms).mean(0)
    mean_int4 = jnp.stack(int4_terms).mean(0)
    assert jnp.array_equal(mean_f32, mean_int4)
    # and the cost layer prices the 4-bit M_s for this wire
    assert C.make_codec(7, wire="int4").wire_bits(dim) == 32 + 4 * dim


def test_bucketed_codec_matches_cost_layer_q():
    """Per-bucket norms: decode error obeys the bucket-dim variance bound,
    and codec.variance_bound reports the bucket-dim q_s the cost layer uses."""
    key = jax.random.PRNGKey(2)
    dim, bucket, s = 4096, 256, 16
    codec = C.make_codec(s, bucket=bucket)
    assert codec.variance_bound(dim) == C.variance_bound(s, bucket)
    assert codec.variance_bound(dim) < C.variance_bound(s, dim)
    y = jax.random.normal(key, (dim,))
    n = 400
    keys = jax.random.split(key, n)
    samples = jnp.stack([codec.quantize_dequantize(y, k) for k in keys])
    ratio = float(((samples - y) ** 2).sum(1).mean() / (y**2).sum())
    assert ratio <= codec.variance_bound(dim) * 1.1
    # unbiased per coordinate, against the ANALYTIC per-bucket Bernoulli
    # variance (norm_b/s)^2 frac(1-frac); rare-event coordinates (frac near
    # 0/1) make any z-test degenerate at finite n, so only well-conditioned
    # fractions are checked per coordinate.
    y2 = y.reshape(dim // bucket, bucket)
    norms = jnp.linalg.norm(y2, axis=1, keepdims=True)
    u = s * jnp.abs(y2) / norms
    frac = u - jnp.floor(u)
    coord_sd = jnp.sqrt((norms / s) ** 2 * frac * (1 - frac) / n)
    z = jnp.abs(samples.mean(0).reshape(y2.shape) - y2) / (coord_sd + 1e-9)
    ok = (frac > 0.1) & (frac < 0.9)
    assert int(ok.sum()) > dim // 4          # the check has real coverage
    assert float(jnp.max(jnp.where(ok, z, 0.0))) < 6.0


def test_bucketed_encode_decode_shapes():
    key = jax.random.PRNGKey(3)
    y = jax.random.normal(key, (777,))            # ragged vs bucket=256
    u = jax.random.uniform(jax.random.fold_in(key, 1), y.shape)
    codec = C.make_codec(64, bucket=256)
    lvl, norms = codec.encode(y, u)
    assert lvl.shape == y.shape and norms.shape == (4,)
    out = codec.decode(lvl, norms)
    assert out.shape == y.shape
    assert float(jnp.abs(out - y).max()) < float(jnp.linalg.norm(y)) / 8


def test_codec_equality_and_hetero_sets():
    """Frozen dataclasses: equal parameters == equal codecs (the reference
    algorithm uses set() to detect the homogeneous fast path)."""
    assert C.make_codec(8) == C.make_codec(8)
    assert C.make_codec(None) == C.make_codec(None)
    assert len({C.make_codec(8), C.make_codec(8), C.make_codec(16)}) == 2


def test_level_dtype_boundary():
    assert C.level_dtype(127) == jnp.int8
    assert C.level_dtype(128) == jnp.int32


def test_fedconfig_rejects_unrepresentable_codecs():
    """Transport validation happens at construction, with ValueError (not
    assert, so it survives python -O): over-cap s, mixed exact+quantized
    workers (the int8 level container can't carry a passthrough), and
    all-exact workers on the packing wire."""
    from repro.fed.runtime import FedConfig
    FedConfig(n_workers=2, Kn=(1, 1), s0=7, sn=(7, 5), wire="int4")
    FedConfig(n_workers=2, Kn=(1, 1), s0=None, sn=None, wire="rs_ag")
    with pytest.raises(ValueError):
        FedConfig(n_workers=2, Kn=(1, 1), s0=64, sn=64, wire="int4")
    with pytest.raises(ValueError):
        FedConfig(n_workers=2, Kn=(1, 1), s0=64, sn=(None, 8), wire="f32")
    with pytest.raises(ValueError):
        FedConfig(n_workers=2, Kn=(1, 1), s0=None, sn=None, wire="int4")
    with pytest.raises(ValueError):
        FedConfig(n_workers=2, Kn=(1, 1), s0=64, sn=64, wire="carrier_pigeon")


def test_exact_server_on_int4_wire_is_priced_as_f32():
    """s0=None with quantized int4 workers is a legal config (the server
    multicast is a local f32 passthrough); bit accounting must price it
    instead of raising."""
    from repro.fed.runtime import FedConfig
    from repro.train.trainer import round_comm_bits
    fed = FedConfig(n_workers=2, Kn=(1, 1), s0=None, sn=7, wire="int4")
    dim = 1000
    assert fed.server_codec().wire_bits(dim) == 32.0 * (dim + 1)
    up = 2 * (32 + 4 * dim)
    assert round_comm_bits(fed, dim) == up + 32.0 * (dim + 1)
