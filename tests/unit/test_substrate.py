"""Data pipeline, checkpointing, step rules, configs — substrate sanity."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import INPUT_SHAPES
from repro.core.step_rules import (ConstantRule, DiminishingRule,
                                   ExponentialRule, make_rule)
from repro.data.federated import partition_iid
from repro.data.synthetic import mnist_like, token_batches
from repro.models.registry import ARCH_IDS, get_config
from repro.train import checkpoint as CKPT


def test_step_rules():
    assert np.allclose(ConstantRule(0.1).sequence(5), 0.1)
    e = ExponentialRule(0.02, 0.9).sequence(4)
    assert np.allclose(e, [0.02, 0.018, 0.0162, 0.01458])
    d = DiminishingRule(0.02, 600.0).sequence(3)
    assert np.allclose(d, [600 * 0.02 / (k + 600) for k in (1, 2, 3)])
    with pytest.raises(ValueError):
        ExponentialRule(0.02, 1.5)
    assert isinstance(make_rule("c", 0.1), ConstantRule)


def test_mnist_like_deterministic():
    X1, y1 = mnist_like(n=500, seed=3)
    X2, y2 = mnist_like(n=500, seed=3)
    assert np.array_equal(X1, X2) and np.array_equal(y1, y2)
    assert X1.shape == (500, 784) and set(np.unique(y1)) <= set(range(10))
    # classes are separable: a centered template matcher nails them
    Xb, yb = mnist_like(n=2000, seed=3)
    Xc = Xb - Xb.mean(0)
    templates = np.stack([Xc[yb == c].mean(0) for c in range(10)])
    pred = np.argmax(Xc @ templates.T, axis=1)
    assert (pred == yb).mean() > 0.9


def test_partition_iid():
    X, y = mnist_like(n=1000, seed=0)
    Xw, yw = partition_iid(X, y, 10)
    assert len(Xw) == 10 and all(len(a) == 100 for a in Xw)
    flat = np.concatenate([a for a in yw])
    assert sorted(flat.tolist()) == sorted(y[:1000].tolist())


def test_token_stream_has_structure():
    it = token_batches(seed=0, batch=4, seq=64, vocab=128)
    b = next(it)
    assert b["tokens"].shape == (4, 64)
    assert jnp.array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_checkpoint_roundtrip():
    tree = {"a": jnp.arange(6.0).reshape(2, 3),
            "nested": {"b": jnp.ones((4,), jnp.bfloat16)},
            "c": [jnp.zeros((2,), jnp.int32), jnp.float32(3.0)]}
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "x.ckpt")
        CKPT.save(path, tree, {"round": 7})
        out, meta = CKPT.load(path, like=tree)
        assert meta["round"] == 7
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
            assert a.dtype == b.dtype
            assert jnp.array_equal(jnp.asarray(a, jnp.float32),
                                   jnp.asarray(b, jnp.float32))


def test_all_configs_exact_shapes():
    """The assigned table: exact published dims in every full config."""
    expect = {
        "qwen3-1.7b": (28, 2048, 16, 8, 6144, 151936),
        "mistral-large-123b": (88, 12288, 96, 8, 28672, 32768),
        "gemma3-4b": (34, 2560, 8, 4, 10240, 262144),
        "qwen2-vl-7b": (28, 3584, 28, 4, 18944, 152064),
        "olmoe-1b-7b": (16, 2048, 16, 16, 1024, 50304),
        "llama3-405b": (126, 16384, 128, 8, 53248, 128256),
        "xlstm-1.3b": (48, 2048, 4, 4, 0, 50304),
        "zamba2-2.7b": (54, 2560, 32, 32, 10240, 32000),
        "whisper-tiny": (4, 384, 6, 6, 1536, 51865),
        "phi3.5-moe-42b-a6.6b": (32, 4096, 32, 8, 6400, 32064),
    }
    for arch, (L, D, H, KV, F, V) in expect.items():
        c = get_config(arch)
        assert (c.n_layers, c.d_model, c.n_heads, c.n_kv, c.d_ff,
                c.vocab) == (L, D, H, KV, F, V), arch
    # moe extras
    assert get_config("olmoe-1b-7b").n_experts == 64
    assert get_config("olmoe-1b-7b").top_k == 8
    assert get_config("phi3.5-moe-42b-a6.6b").n_experts == 16
    assert get_config("phi3.5-moe-42b-a6.6b").top_k == 2
    assert get_config("zamba2-2.7b").ssm_state == 64
    assert get_config("qwen3-1.7b").qk_norm
    assert get_config("qwen2-vl-7b").mrope


def test_input_shapes_table():
    t = INPUT_SHAPES
    assert (t["train_4k"].seq_len, t["train_4k"].global_batch) == (4096, 256)
    assert (t["prefill_32k"].seq_len, t["prefill_32k"].global_batch) == (32768, 32)
    assert (t["decode_32k"].seq_len, t["decode_32k"].global_batch) == (32768, 128)
    assert (t["long_500k"].seq_len, t["long_500k"].global_batch) == (524288, 1)


def test_long_context_eligibility():
    eligible = {a for a in ARCH_IDS
                if get_config(a).supports_long_context()}
    assert eligible == {"gemma3-4b", "xlstm-1.3b", "zamba2-2.7b"}


def test_mesh_layout_math():
    from repro.configs.base import MeshLayout
    ml = MeshLayout(fl_sub=4, tp=16)
    assert ml.logical_shape(2, 16, 16) == (8, 4, 16)


def test_sharding_rules_valid_for_every_arch():
    """System invariant: every PartitionSpec produced by the rules divides
    its dimension on the production train/serve meshes (no invalid specs)."""
    import os
    import subprocess
    import sys
    import textwrap
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
        import jax
        from repro.launch.specs import build_case
        from repro.models.registry import ARCH_IDS
        from repro.configs.base import INPUT_SHAPES
        from repro.launch.specs import case_supported
        from repro.models.registry import get_config
        import numpy as np
        for arch in ARCH_IDS:
            for shape in ("train_4k", "decode_32k"):
                if case_supported(get_config(arch), INPUT_SHAPES[shape]):
                    continue
                case = build_case(arch, shape)
                sizes = dict(zip(case.mesh.axis_names,
                                 case.mesh.devices.shape))
                def check(sds):
                    spec = getattr(sds, "sharding", None)
                    if spec is None:
                        return
                    for dim, ax in zip(sds.shape, spec.spec):
                        if ax is None:
                            continue
                        axes = ax if isinstance(ax, tuple) else (ax,)
                        n = int(np.prod([sizes[a] for a in axes]))
                        assert dim % n == 0, (arch, shape, sds.shape,
                                              spec.spec)
                jax.tree.map(check, case.args,
                             is_leaf=lambda x: hasattr(x, "sharding"))
        print("SHARDING_OK")
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "..", "src"))
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=900)
    assert "SHARDING_OK" in r.stdout, r.stdout + r.stderr[-2000:]
