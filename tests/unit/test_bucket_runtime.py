"""Per-bucket-norm quantization through the runtime-facing functional API:
``encode_tensor``/``decode_tensor`` (bucket=...) must match what
``QSGDCodec(bucket=...)`` computes and what ``EdgeSystem(q_dim=...)``
prices — the ROADMAP's "per-bucket norms in the SPMD runtime" gap."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compress as C
from repro.api import ConstantRule, EdgeSystem
from repro.core.genqsgd import GenQSGD, GenQSGDConfig
from repro.fed.runtime import FedConfig
from repro.train.trainer import round_comm_bits


@pytest.mark.parametrize("bucket", [16, 64, 1000])
def test_encode_tensor_bucketed_matches_codec(bucket):
    key = jax.random.PRNGKey(0)
    y = jax.random.normal(key, (37, 11))
    u = jax.random.uniform(jax.random.fold_in(key, 1), y.shape)
    codec = C.make_codec(7, bucket=bucket)
    lvl_c, nrm_c = codec.encode(y, u)
    lvl_f, nrm_f = C.encode_tensor(y, 7, u, bucket=bucket)
    assert np.array_equal(np.asarray(lvl_f), np.asarray(lvl_c))
    assert np.array_equal(np.asarray(nrm_f), np.asarray(nrm_c))
    d_f = C.decode_tensor(lvl_f, nrm_f, 7, bucket=bucket)
    assert np.array_equal(np.asarray(d_f), np.asarray(codec.decode(lvl_c,
                                                                   nrm_c)))
    # traced-s path (heterogeneous workers vectorize through vmap)
    lv = jax.vmap(lambda s: C.encode_tensor(y, s, u, bucket=bucket)[0])(
        jnp.asarray([7.0, 7.0]))
    assert np.array_equal(np.asarray(lv[0]), np.asarray(lvl_c))


def test_fed_config_bucket_prices_like_edge_system():
    dim = 100_000
    fed = FedConfig(n_workers=4, Kn=(1,) * 4, s0=64, sn=16, wire="int8",
                    bucket=4096)
    sys_ = EdgeSystem(F0=1.0, C0=1.0, p0=1.0, r0=1.0, s0=64, alpha0=1.0,
                      Fn=np.ones(4), Cn=np.ones(4), pn=np.ones(4),
                      rn=np.ones(4), sn=[16] * 4, alphan=np.ones(4),
                      dim=dim, q_dim=4096, wire="int8")
    assert np.allclose([c.wire_bits(dim) for c in fed.codecs()], sys_.M_sn)
    assert fed.server_codec().wire_bits(dim) == sys_.M_s0
    assert round_comm_bits(fed, dim) == float(np.sum(sys_.M_sn) + sys_.M_s0)
    # variance bounds (what the optimizer's q_pairs sees) match too
    assert np.allclose([c.variance_bound(dim) for c in fed.codecs()],
                       sys_.q_sn)


def test_fed_config_bucket_validation():
    with pytest.raises(ValueError, match="bucket"):
        FedConfig(n_workers=2, Kn=(1, 1), s0=7, sn=7, bucket=0)


def _toy(key, N=4, per=32, dim=24):
    X = jax.random.normal(key, (N, per, dim))
    w = jax.random.normal(jax.random.fold_in(key, 7), (dim,))
    T = X @ w
    return (X, T)


def _loss(params, batch):
    x, t = batch
    return ((x @ params["w"] - t) ** 2).mean()


def _sample(worker_data, key, B):
    x, t = worker_data
    idx = jax.random.randint(key, (B,), 0, x.shape[0])
    return x[idx], t[idx]


def test_genqsgd_reference_bucket():
    """bucket >= dim is one whole-tensor bucket -> bit-identical to
    bucket=None; a smaller bucket changes the realized quantization."""
    key = jax.random.PRNGKey(3)
    data = _toy(key)
    x0 = {"w": jnp.zeros(24)}

    def one_round(bucket):
        cfg = GenQSGDConfig(K0=1, Kn=(2,) * 4, B=8,
                            step_rule=ConstantRule(0.05), s0=8, sn=[8] * 4,
                            bucket=bucket)
        alg = GenQSGD(_loss, _sample, cfg)
        x1, _ = alg._round(x0, data, jax.random.PRNGKey(4), jnp.float32(0.05))
        return np.asarray(x1["w"])

    whole = one_round(None)
    assert np.array_equal(one_round(1 << 20), whole)
    assert not np.array_equal(one_round(8), whole)
