"""GP solver + GIA/CGP machinery: known optima, KKT residuals,
condensation properties (Marks-Wright (i)-(iii))."""
import numpy as np
import pytest
from tests.compat import given, settings, st

from repro.core import EdgeSystem, MLProblemConstants
from repro.opt import (GP, Objective, ParamOptProblem, amgm_monomial,
                       solve_gp,
                       solve_param_opt)
from repro.opt.posy import Posy, const, var

CONSTS = MLProblemConstants(L=0.084, sigma=33.18, G=33.63, f_gap=2.3, N=10)


def _sys():
    return EdgeSystem.paper_sec_vii()


def test_gp_known_optimum():
    # min xy s.t. 2/x + 3/y <= 1  ->  x=4, y=6, obj=24
    n = 2
    obj = var(0, n) * var(1, n)
    con = 2.0 * var(0, n, power=-1) + 3.0 * var(1, n, power=-1)
    res = solve_gp(GP(obj, [con]), np.zeros(n) + 2)
    assert res.feasible
    assert res.obj == pytest.approx(24.0, rel=1e-4)
    assert np.allclose(res.x, [4.0, 6.0], rtol=1e-3)


def test_gp_monomial_equality_like():
    # min x s.t. 5/x <= 1 -> x = 5
    n = 1
    res = solve_gp(GP(var(0, n), [5.0 * var(0, n, power=-1)]), np.zeros(1))
    assert res.x[0] == pytest.approx(5.0, rel=1e-5)


@given(st.integers(2, 6), st.integers(1, 5))
@settings(max_examples=25, deadline=None)
def test_amgm_condensation_properties(n_terms, seed):
    """Marks-Wright: M(x) <= p(x) everywhere, equality + gradient match at
    the expansion point."""
    rng = np.random.default_rng(seed)
    n = 3
    p = Posy(rng.uniform(0.5, 2.0, n_terms),
             rng.uniform(-2, 2, (n_terms, n)))
    z0 = rng.normal(size=n) * 0.5
    m = amgm_monomial(p, z0)
    # (ii) equality at expansion point
    assert m.value(z0) == pytest.approx(p.value(z0), rel=1e-9)
    # (i) global under-approximation
    for _ in range(50):
        z = rng.normal(size=n)
        assert m.value(z) <= p.value(z) * (1 + 1e-9)
    # (iii) gradient match (of log-values; equivalent at the touch point)
    _, gm, _ = m.grad_hess_log(z0)
    _, gp_, _ = p.grad_hess_log(z0)
    assert np.allclose(gm, gp_, atol=1e-8)


@pytest.mark.parametrize("m,kw", [
    (Objective.CONSTANT, dict(gamma=0.01)),
    (Objective.DIMINISHING, dict(gamma=0.02, rho=600.0)),
    (Objective.JOINT, dict()),
])
def test_param_opt_feasible_and_active(m, kw):
    prob = ParamOptProblem(sys=_sys(), consts=CONSTS, T_max=1e5, C_max=0.25,
                           m=m, **kw)
    r = solve_param_opt(prob)
    assert r.feasible, (m, r)
    # the convergence-error constraint should be (near-)active at the optimum
    assert r.C <= 0.25 * (1 + 1e-6)
    assert r.C >= 0.25 * 0.8
    assert r.T <= 1e5
    if m == "J":
        assert r.gamma is not None and 0 < r.gamma <= 1 / CONSTS.L + 1e-9


def test_param_opt_kkt_stationarity_continuous():
    """At the continuous GIA point, the true constraints hold and tightening
    C_max strictly increases energy (monotone trade-off, Fig. 5a)."""
    es = []
    for cmax in (0.22, 0.3):
        prob = ParamOptProblem(sys=_sys(), consts=CONSTS, T_max=1e5,
                               C_max=cmax, m=Objective.CONSTANT, gamma=0.01)
        es.append(solve_param_opt(prob).E)
    assert es[0] > es[1]


def test_infeasible_detected():
    prob = ParamOptProblem(sys=_sys(), consts=CONSTS, T_max=10.0,
                           C_max=1e-6, m=Objective.CONSTANT, gamma=0.01)
    r = solve_param_opt(prob)
    assert not r.feasible


def test_param_opt_exponential_rule():
    """m=E (Problem 5 / Algorithm 3): X0 = rho^K0 sandwich handled via the
    projected-expansion GIA; result feasible and near the error budget."""
    prob = ParamOptProblem(sys=_sys(), consts=CONSTS, T_max=1e5, C_max=0.25,
                           m=Objective.EXPONENTIAL, gamma=0.02, rho=0.9995)
    r = solve_param_opt(prob)
    assert r.feasible
    assert 0.15 <= r.C <= 0.25 * (1 + 1e-6)
    # near-optimality: within 25% of the constant-rule solution (they share
    # the gamma scale; Lemma 1 vs Lemma 2 differ only in a-coefficients)
    rc = solve_param_opt(ParamOptProblem(sys=_sys(), consts=CONSTS,
                                         T_max=1e5, C_max=0.25, m=Objective.CONSTANT,
                                         gamma=0.01))
    assert r.E <= rc.E * 1.35


def test_extrapolation_math():
    from repro.roofline.analysis import extrapolate
    c1 = {"flops": 10.0, "bytes": 100.0}
    c2 = {"flops": 16.0, "bytes": 130.0}
    out = extrapolate(c1, c2, 5.0)
    assert out["flops"] == pytest.approx(10 + 4 * 6)
    assert out["bytes"] == pytest.approx(100 + 4 * 30)
    # per-rep deltas clamp at zero (noise robustness)
    out2 = extrapolate({"x": 5.0}, {"x": 4.0}, 10.0)
    assert out2["x"] == 5.0


def test_roofline_terms_dominance():
    from repro.roofline.analysis import roofline_terms, TPU_V5E
    t = roofline_terms(flops=197e12, bytes_accessed=819e9 * 3,
                       coll_bytes=50e9, chips=1)
    assert t["compute_s"] == pytest.approx(1.0)
    assert t["memory_s"] == pytest.approx(3.0)
    assert t["collective_s"] == pytest.approx(1.0)
    assert t["dominant"] == "memory"
    assert t["bound_s"] == pytest.approx(3.0)
