"""repro.api facade: Scenario -> Plan -> RunReport.

Covers the ISSUE-2 acceptance bar: z_init feasibility and Plan round-tripping
over every (objective m, family varmap) combination, config derivation with
cross-validation, and the end-to-end closed loop whose measured comm-bits
equal the Plan-predicted K0 * (sum_n M_{s_n} + M_{s_0}) exactly.
"""
import dataclasses
import warnings

import numpy as np
import pytest

from repro.api import (ConstantRule, DiminishingRule, EdgeSystem,
                       ExponentialRule, MLProblemConstants, Objective, Plan,
                       QuadraticTask, Scenario, family_names, make_step_rule)
from repro.opt import ParamOptProblem
from repro.opt.gia import _extract

CONSTS = MLProblemConstants(L=0.084, sigma=33.18, G=33.63, f_gap=2.3, N=4)

STEPS = {
    Objective.CONSTANT: ConstantRule(0.01),
    Objective.EXPONENTIAL: ExponentialRule(0.02, 0.9995),
    Objective.DIMINISHING: DiminishingRule(0.02, 600.0),
    Objective.JOINT: None,
}

# problem feasibility at the (T_max=1e5, C_max=0.25) operating point with
# the Sec.-VII N=4 system: FedAvg's tied K_n = l*I_n/B cannot meet the
# budgets (the paper's Sec.-VII claim), and PR-SGD's B=1 starves the
# exponential rule.
INFEASIBLE = {("fa", m) for m in Objective} | {("pr", Objective.EXPONENTIAL)}


def _scenario(family, m, dim=1024, N=4):
    sys_ = EdgeSystem.paper_sec_vii(dim=dim, N=N)
    consts = dataclasses.replace(CONSTS, N=N)
    return Scenario(system=sys_, consts=consts, T_max=1e5, C_max=0.25,
                    family=family, step=STEPS[m])


# ---------------------------------------------------------------------------
# z_init feasibility + optimized-Plan round trip, full (m, family) grid
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("family", family_names())
@pytest.mark.parametrize("m", list(Objective))
def test_z_init_and_plan_feasibility_grid(family, m):
    scn = _scenario(family, m)
    prob = scn.problem()
    z = prob.z_init()
    assert z.shape == (prob.vmap.n,) and np.all(np.isfinite(z))
    K0, Kn, B, extra, _S = _extract(prob, z)
    init_feasible = prob.feasible(
        K0, Kn, B, extra if m is Objective.JOINT else None)
    if (family, m) not in INFEASIBLE and m is not Objective.JOINT:
        # Algorithms 2-4 line 1: z_init must deliver a feasible start
        # wherever the original problem is feasible (m=J's grid search may
        # miss and rely on the solver's phase-I recovery).
        assert init_feasible

    plan = scn.optimize()
    # the GIAResult feasibility flag must agree with the true constraint
    # check at the Plan's integer point — the core round-trip property
    assert plan.feasible == prob.feasible(
        plan.K0, np.asarray(plan.Kn), plan.B,
        plan.gamma if m is Objective.JOINT else None)
    if (family, m) in INFEASIBLE:
        assert not plan.feasible
    else:
        assert plan.feasible
        assert plan.predicted_C <= scn.C_max * (1 + 1e-6)
        assert plan.predicted_T <= scn.T_max * (1 + 1e-6)
    assert plan.objective is m and plan.family == family

    # Plan -> GenQSGDConfig carries every parameter through unchanged
    cfg = plan.to_genqsgd_config()
    assert (cfg.K0, cfg.Kn, cfg.B) == (plan.K0, plan.Kn, plan.B)
    assert cfg.s0 == plan.s0 and tuple(cfg.sn) == plan.sn
    assert cfg.step_rule == plan.step_rule
    if m is Objective.JOINT:
        assert isinstance(plan.step_rule, ConstantRule)
        assert plan.gamma <= 1.0 / CONSTS.L * (1 + 1e-9)


# ---------------------------------------------------------------------------
# cross-validation: inconsistent (s, wire) pairs are rejected
# ---------------------------------------------------------------------------
def test_plan_fed_config_rejects_inconsistent_wire():
    plan = _scenario("genqsgd", Objective.CONSTANT).optimize(max_iter=5)
    assert plan.s0 == 2**14            # Sec.-VII server quantizer
    for wire in ("f32", "int8", "int4", "rs_ag"):
        with pytest.raises(ValueError, match="cannot ride"):
            plan.to_fed_config(wire=wire)
    with pytest.raises(ValueError):
        plan.to_fed_config(wire="carrier_pigeon")


def test_plan_fed_config_roundtrip_small_s():
    p = Plan.manual(K0=10, Kn=(1, 2), B=4, step_rule=ConstantRule(0.05),
                    s0=64, sn=(16, 127), q_dim=256)
    fed = p.to_fed_config(wire="int8")
    assert fed.n_workers == p.N == 2
    assert fed.Kn == p.Kn and fed.s0 == 64 and fed.sn_tuple() == (16, 127)
    assert fed.bucket == 256
    with pytest.raises(ValueError, match="cannot ride"):
        p.to_fed_config(wire="int4")   # s=127 > int4's cap of 7
    # mixed exact/quantized workers rejected at FedConfig validation
    p2 = Plan.manual(K0=1, Kn=(1, 1), B=1, step_rule=ConstantRule(0.1),
                     s0=7, sn=(7, None))
    with pytest.raises(ValueError, match="mixed exact"):
        p2.to_fed_config(wire="int8")


def test_plan_round_bits_mirrors_runtime_pricing():
    """An exact server multicast (s0=None) rides raw f32 on every transport
    — round_bits must price it the way FedConfig.server_codec sends it."""
    from repro.train.trainer import round_comm_bits
    p = Plan.manual(K0=2, Kn=(1, 1), B=1, step_rule=ConstantRule(0.1),
                    s0=None, sn=(7, 7), dim=128)
    for wire in ("f32", "int8", "int4", "rs_ag"):
        fed = p.to_fed_config(wire=wire)
        assert p.round_bits(wire=wire) == round_comm_bits(fed, 128), wire


def test_plan_defaults_and_custom_rule():
    p = Plan(K0=1, Kn=(1, 2), B=1, step_rule=ConstantRule(0.1))
    assert p.sn == (None, None)          # default: exact communication

    @dataclasses.dataclass(frozen=True)
    class WarmupRule:
        gamma: float
        name = "W"

        def sequence(self, n):
            return np.full(n, self.gamma)

    p2 = Plan.manual(K0=1, Kn=(1,), B=1, step_rule=WarmupRule(0.1))
    assert p2.objective is Objective.CONSTANT


def test_plan_validation():
    with pytest.raises(ValueError, match="sn has"):
        Plan(K0=1, Kn=(1, 1), B=1, step_rule=ConstantRule(0.1), sn=(7,))
    with pytest.raises(ValueError, match=">= 1"):
        Plan.manual(K0=0, Kn=(1,), B=1, step_rule=ConstantRule(0.1))
    p = Plan.manual(K0=3, Kn=(1, 2), B=2, step_rule=ConstantRule(0.1),
                    s0=8, sn=4, dim=100)
    assert p.sn == (4, 4)
    assert np.isnan(p.predicted_E)
    # bit accounting matches the codec table: 2 uploads at s=4 + multicast
    from repro.compress import make_codec
    per_round = 2 * make_codec(4).wire_bits(100) + make_codec(8).wire_bits(100)
    assert p.round_bits() == per_round
    assert p.predicted_comm_bits == 3 * per_round


# ---------------------------------------------------------------------------
# scenario validation + registries
# ---------------------------------------------------------------------------
def test_scenario_validation():
    sys_ = EdgeSystem.paper_sec_vii(dim=64, N=4)
    with pytest.raises(ValueError, match="unknown family"):
        Scenario(system=sys_, consts=CONSTS, T_max=1e5, C_max=0.25,
                 family="sgd")
    with pytest.raises(ValueError, match="N=10"):
        Scenario(system=sys_, consts=dataclasses.replace(CONSTS, N=10),
                 T_max=1e5, C_max=0.25)
    scn = Scenario(system=sys_, consts=CONSTS, T_max=1e5, C_max=0.25,
                   step=ConstantRule(0.01))
    with pytest.raises(ValueError, match="jointly optimizes"):
        scn.optimize(m=Objective.JOINT)
    with pytest.raises(ValueError, match="needs step"):
        scn.optimize(m=Objective.EXPONENTIAL)
    assert scn.objective is Objective.CONSTANT


def test_step_rule_registry():
    assert isinstance(make_step_rule("C", 0.01), ConstantRule)
    assert isinstance(make_step_rule(Objective.EXPONENTIAL, 0.02, 0.9),
                      ExponentialRule)
    assert isinstance(make_step_rule("D", 0.02, 600.0), DiminishingRule)
    assert isinstance(make_step_rule(Objective.JOINT, 0.05), ConstantRule)


def test_stringly_m_is_deprecated_but_works():
    sys_ = EdgeSystem.paper_sec_vii(dim=64, N=4)
    with pytest.warns(DeprecationWarning, match="stringly-typed"):
        prob = ParamOptProblem(sys=sys_, consts=CONSTS, T_max=1e5,
                               C_max=0.25, m="C", gamma=0.01)
    assert prob.m is Objective.CONSTANT
    assert prob.m == "C"               # str-enum: old comparisons keep working
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        ParamOptProblem(sys=sys_, consts=CONSTS, T_max=1e5, C_max=0.25,
                        m=Objective.CONSTANT, gamma=0.01)


# ---------------------------------------------------------------------------
# end-to-end: optimize -> run closes the loop with exact bit accounting
# ---------------------------------------------------------------------------
def test_scenario_run_closes_loop_exactly():
    task = QuadraticTask(dim=8)
    sys_ = EdgeSystem.paper_sec_vii(dim=task.dim)
    consts = dataclasses.replace(CONSTS, N=10)
    scn = Scenario(system=sys_, consts=consts, T_max=1e5, C_max=0.25)
    plan = scn.optimize()
    assert plan.feasible
    report = scn.run(plan, task=task)
    assert report.backend == "reference" and report.rounds == plan.K0
    # the acceptance criterion: measured comm-bits == K0*(sum M_sn + M_s0)
    assert report.comm_bits == plan.K0 * (float(np.sum(sys_.M_sn))
                                          + sys_.M_s0)
    assert report.comm_bits == report.predicted_comm_bits
    assert report.comm_bits_match
    # cost-model measurements at full K0 coincide with the predictions
    assert report.measured_E == pytest.approx(plan.predicted_E)
    assert report.measured_T == pytest.approx(plan.predicted_T)
    # and the optimized parameters actually learn the quadratic
    assert report.final_metrics["err"] < 0.05
    assert "EXACT" in report.summary()


def test_scenario_run_capped_reports_partial_bits():
    task = QuadraticTask(dim=8)
    sys_ = EdgeSystem.paper_sec_vii(dim=task.dim)
    consts = dataclasses.replace(CONSTS, N=10)
    scn = Scenario(system=sys_, consts=consts, T_max=1e5, C_max=0.25)
    plan = scn.optimize()
    cap = max(1, plan.K0 // 7)
    report = scn.run(plan, task=task, max_rounds=cap)
    assert report.rounds == cap
    assert report.comm_bits == cap * plan.round_bits()
    assert not report.comm_bits_match
