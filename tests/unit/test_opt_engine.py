"""Batched solver engine: padded-structure packing, NumPy-vs-jnp backend
parity, lockstep-batched GIA vs the scalar loop, bisection integer
recovery, and the Scenario sweep / Pareto API.

The fast subset runs in tier-1; the full (m, family) grid parity sweep is
marked slow (it compiles one jnp program per structure signature).
"""
import dataclasses

import numpy as np
import pytest

from repro.api import (ConstantRule, DiminishingRule, EdgeSystem,
                       ExponentialRule, MLProblemConstants, Objective,
                       Scenario, SweepReport, family_names, sweep_scenarios)
from repro.opt import (GPStructure, ParamOptProblem, min_feasible_K0,
                       min_feasible_K0_joint, solve_gp, solve_gp_batch,
                       solve_param_opt, solve_param_opt_batched,
                       structure_signature)
from repro.opt.gp import _Batched

CONSTS = MLProblemConstants(L=0.084, sigma=33.18, G=33.63, f_gap=2.3, N=4)

STEPS = {
    Objective.CONSTANT: ConstantRule(0.01),
    Objective.EXPONENTIAL: ExponentialRule(0.02, 0.9995),
    Objective.DIMINISHING: DiminishingRule(0.02, 600.0),
    Objective.JOINT: None,
}


def _scenario(family, m, C_max=0.25, T_max=1e5):
    sys_ = EdgeSystem.paper_sec_vii(dim=1024, N=4)
    return Scenario(system=sys_, consts=CONSTS, T_max=T_max, C_max=C_max,
                    family=family, step=STEPS[m])


def _problems(family, m, budgets=(0.22, 0.25, 0.3)):
    return [_scenario(family, m, C_max=c).problem() for c in budgets]


# ---------------------------------------------------------------------------
# structure packing
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("m", list(Objective))
def test_packed_system_matches_unpadded(m):
    """Padding terms contribute exactly zero: per-constraint log-values of
    the packed arrays equal the unpadded reference for every instance."""
    probs = _problems("genqsgd", m)
    zs = [p.z_init() for p in probs]
    st = GPStructure(probs[0])
    pack = st.pack_batch(probs, zs)
    assert pack.batch == len(probs)
    for i, gp in enumerate(pack.gps):
        ref = _Batched(gp)
        z = pack.z0[i]
        t = pack.con_logc[i] + pack.con_A[i] @ z
        mx = np.full(pack.m_cons, -np.inf)
        np.maximum.at(mx, pack.seg, t)
        s = np.zeros(pack.m_cons)
        np.add.at(s, pack.seg, np.exp(t - mx[pack.seg]))
        g_packed = mx + np.log(s)
        assert np.allclose(g_packed, ref.g(z), rtol=1e-12, atol=1e-12)


def test_structure_signature_grouping():
    pc = _scenario("genqsgd", Objective.CONSTANT).problem()
    pc2 = _scenario("genqsgd", Objective.CONSTANT, C_max=0.4).problem()
    pe = _scenario("genqsgd", Objective.EXPONENTIAL).problem()
    pm = _scenario("pm", Objective.CONSTANT).problem()
    assert structure_signature(pc) == structure_signature(pc2)
    assert structure_signature(pc) != structure_signature(pe)
    assert structure_signature(pc) != structure_signature(pm)
    with pytest.raises(ValueError, match="structure"):
        GPStructure(pc).pack_batch([pe], [pe.z_init()])
    with pytest.raises(ValueError, match="signature"):
        solve_param_opt_batched([pc, pe], backend="numpy")


# ---------------------------------------------------------------------------
# backend parity: one batched GP solve
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("m", [Objective.CONSTANT, Objective.JOINT])
def test_gp_backends_agree_fast(m):
    probs = _problems("genqsgd", m)
    st = GPStructure(probs[0])
    pack = st.pack_batch(probs, [p.z_init() for p in probs])
    rn = solve_gp_batch(pack, backend="numpy")
    rj = solve_gp_batch(pack, backend="jnp")
    assert np.array_equal(rn.feasible, rj.feasible)
    assert np.allclose(rn.z, rj.z, atol=1e-6)
    assert np.allclose(rn.obj, rj.obj, rtol=1e-8)


# ---------------------------------------------------------------------------
# fused device-resident GIA (backend="jnp-fused")
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("family,m", [
    ("genqsgd", Objective.CONSTANT),
    ("genqsgd", Objective.EXPONENTIAL),
    ("pm", Objective.DIMINISHING),
    ("genqsgd", Objective.JOINT),
    ("gqfedwavg", Objective.CONSTANT),
    ("gqfedwavg", Objective.JOINT),
])
def test_fused_gia_matches_numpy_fast(family, m):
    """The fused single-while-loop engine lands on the NumPy reference:
    same feasibility, same GIA iteration counts and history length, same
    integer recovery, continuous point to 1e-5."""
    rn = solve_param_opt_batched(_problems(family, m), backend="numpy")
    rf = solve_param_opt_batched(_problems(family, m), backend="jnp-fused")
    for a, b in zip(rn, rf):
        assert a.feasible == b.feasible
        assert a.iterations == b.iterations
        assert np.allclose(a.z, b.z, atol=1e-5)
        if a.feasible:
            assert (a.K0, a.B) == (b.K0, b.B)
            assert np.array_equal(a.Kn, b.Kn)
            assert b.E == pytest.approx(a.E, rel=1e-9)
        assert b.history == pytest.approx(a.history, rel=1e-9)


def test_fused_one_compile_per_signature():
    """Re-solving a same-signature batch reuses the compiled fused program —
    the whole GIA (refresh included) stays on device with zero host round
    trips per outer iteration, so the trace counter must not move."""
    from repro.opt import gia_jax
    from repro.opt.refresh import RefreshPlan

    probs = _problems("genqsgd", Objective.CONSTANT)
    key = RefreshPlan.build(probs).signature_key
    solve_param_opt_batched(probs, backend="jnp-fused")
    n1 = gia_jax.trace_count(key)
    assert n1 >= 1
    solve_param_opt_batched(
        _problems("genqsgd", Objective.CONSTANT, budgets=(0.21, 0.26, 0.31)),
        backend="jnp-fused")
    assert gia_jax.trace_count(key) == n1


def test_fused_stalled_instance_regression():
    """A hopeless instance inside a fused batch (budgets no point can meet;
    its GIA stalls out through phase-I retries) must neither crash the
    device-side refresh nor stretch the healthy instances' lockstep: the
    healthy row's iterations, history, and solution match its solo solve."""
    healthy = _scenario("genqsgd", Objective.CONSTANT, C_max=0.25).problem()
    hopeless = _scenario("genqsgd", Objective.CONSTANT, C_max=1e-9,
                         T_max=10.0).problem()
    solo = solve_param_opt_batched([healthy], backend="jnp-fused")[0]
    bad, good = solve_param_opt_batched([hopeless, healthy],
                                        backend="jnp-fused")
    assert not bad.feasible and not bad.converged
    assert good.feasible == solo.feasible
    assert good.iterations == solo.iterations
    # rows are independent up to XLA's batch-shape-dependent vectorization
    assert good.history == pytest.approx(solo.history, rel=1e-12)
    assert np.allclose(good.z, solo.z, atol=1e-9)
    assert (good.K0, good.B) == (solo.K0, solo.B)
    assert np.array_equal(good.Kn, solo.Kn)


def _assert_warm_matches_cold(family, m, tol, z_atol):
    """Warm-starting a solve at its own cold solution must reach the same
    KKT point: 1-3 GIA iterations (no cold phase-I), continuous point to
    ``z_atol``, identical integer recovery."""
    budgets = (0.22, 0.25, 0.3)
    cold = solve_param_opt_batched(_problems(family, m, budgets),
                                   backend="jnp-fused", tol=tol)
    warm = solve_param_opt_batched(_problems(family, m, budgets),
                                   z0s=[r.z for r in cold],
                                   backend="jnp-fused", tol=tol,
                                   joint_restart=False)
    for c, w in zip(cold, warm):
        if not c.converged:
            continue                  # nothing cached seeds from such a row
        assert w.converged
        assert 1 <= w.iterations <= 3
        assert np.allclose(w.z, c.z, atol=z_atol)
        assert c.feasible == w.feasible
        if c.feasible:
            assert (c.K0, c.B) == (w.K0, w.B)
            assert np.array_equal(c.Kn, w.Kn)
            assert w.E == pytest.approx(c.E, rel=1e-9)


@pytest.mark.parametrize("family,m", [
    ("genqsgd", Objective.CONSTANT),
    ("genqsgd", Objective.JOINT),
])
def test_warm_start_reaches_cold_kkt_fast(family, m):
    # measured fixed-point accuracy at tol=1e-8: C/D/J ~1e-9, E ~4e-9
    _assert_warm_matches_cold(family, m, tol=1e-8, z_atol=1e-8)


@pytest.mark.slow
@pytest.mark.serve
@pytest.mark.families
@pytest.mark.parametrize("family", family_names())
@pytest.mark.parametrize("m", list(Objective))
def test_warm_start_reaches_cold_kkt_full_grid(family, m):
    """Warm-start correctness over the whole (m, family) grid: the plan
    cache may only ever hand out seeds that re-converge to the cold
    answer."""
    _assert_warm_matches_cold(family, m, tol=1e-8, z_atol=1e-8)


def test_fused_mixed_warm_cold_with_stalled_row():
    """PR-4 stalled-row regression, extended with warm/cold mixing: a
    stalled/infeasible row inside a mixed warm/cold micro-batch must not
    perturb the healthy rows — the warm row still converges in 1-3
    iterations onto its cold KKT point, the cold row matches its solo
    solve, and the padding rows of a fixed-shape dispatch change nothing."""
    healthy = _scenario("genqsgd", Objective.CONSTANT, C_max=0.25).problem()
    other = _scenario("genqsgd", Objective.CONSTANT, C_max=0.3).problem()
    hopeless = _scenario("genqsgd", Objective.CONSTANT, C_max=1e-9,
                         T_max=10.0).problem()
    solo_h = solve_param_opt_batched([healthy], backend="jnp-fused")[0]
    solo_o = solve_param_opt_batched([other], backend="jnp-fused")[0]

    mixed = solve_param_opt_batched(
        [_scenario("genqsgd", Objective.CONSTANT, C_max=0.25).problem(),
         _scenario("genqsgd", Objective.CONSTANT, C_max=1e-9,
                   T_max=10.0).problem(),
         _scenario("genqsgd", Objective.CONSTANT, C_max=0.3).problem()],
        z0s=[solo_h.z, None, None], backend="jnp-fused", pad_to=8)
    warm, bad, cold = mixed
    assert not bad.feasible and not bad.converged
    assert warm.converged and 1 <= warm.iterations <= 3
    assert np.allclose(warm.z, solo_h.z, atol=1e-6)
    assert (warm.K0, warm.B) == (solo_h.K0, solo_h.B)
    assert np.array_equal(warm.Kn, solo_h.Kn)
    assert cold.iterations == solo_o.iterations
    assert cold.history == pytest.approx(solo_o.history, rel=1e-12)
    assert np.allclose(cold.z, solo_o.z, atol=1e-9)
    assert (cold.K0, cold.B) == (solo_o.K0, solo_o.B)


def test_fused_pad_to_rows_bitwise_unchanged():
    """Padding a fused batch to a fixed shape (the serving path) is a
    bitwise no-op for the real rows."""
    ref = solve_param_opt_batched(
        _problems("genqsgd", Objective.CONSTANT), backend="jnp-fused")
    pad = solve_param_opt_batched(
        _problems("genqsgd", Objective.CONSTANT), backend="jnp-fused",
        pad_to=8)
    assert len(pad) == 3
    for a, b in zip(ref, pad):
        assert np.array_equal(a.z, b.z)
        assert a.history == b.history
        assert a.iterations == b.iterations
        assert (a.K0, a.B, a.E) == (b.K0, b.B, b.E)


def test_optimize_fused_compile_cache_is_process_level():
    """Repeated ``Scenario.optimize(backend='jnp-fused')`` calls across
    *distinct* Scenario objects reuse the compiled fused executable: the
    cache is the process-level LRU in repro.opt.gia_jax, keyed by structure
    signature — not tied to any Scenario / sweep / GPStructure instance —
    so the trace counter must stay flat after the first call."""
    from repro.opt import gia_jax
    from repro.opt.refresh import RefreshPlan

    key = RefreshPlan.build(
        [_scenario("genqsgd", Objective.CONSTANT).problem()]).signature_key
    p1 = _scenario("genqsgd", Objective.CONSTANT,
                   C_max=0.24).optimize(backend="jnp-fused")
    n1 = gia_jax.trace_count(key)
    assert n1 >= 1
    p2 = _scenario("genqsgd", Objective.CONSTANT,
                   C_max=0.28).optimize(backend="jnp-fused")
    assert gia_jax.trace_count(key) == n1
    assert p1.feasible and p2.feasible
    # and the scalar reference agrees with the fused single-row solve
    ref = _scenario("genqsgd", Objective.CONSTANT, C_max=0.28).optimize()
    assert p2.K0 == ref.K0 and p2.B == ref.B and p2.Kn == ref.Kn


def test_gp_batch_numpy_rows_equal_scalar_solver():
    probs = _problems("genqsgd", Objective.CONSTANT)
    st = GPStructure(probs[0])
    pack = st.pack_batch(probs, [p.z_init() for p in probs])
    rb = solve_gp_batch(pack, backend="numpy")
    for i, gp in enumerate(pack.gps):
        r = solve_gp(gp, pack.z0[i])
        assert np.array_equal(r.z, rb.z[i])
        assert r.obj == rb.obj[i] and r.feasible == rb.feasible[i]


def test_unknown_backend_rejected():
    probs = _problems("genqsgd", Objective.CONSTANT)
    st = GPStructure(probs[0])
    pack = st.pack_batch(probs, [p.z_init() for p in probs])
    with pytest.raises(ValueError, match="unknown GP backend"):
        solve_gp_batch(pack, backend="cvxpy")


# ---------------------------------------------------------------------------
# batched GIA vs the scalar loop
# ---------------------------------------------------------------------------
def test_batched_numpy_gia_identical_to_sequential():
    """backend="numpy" lockstep is the scalar loop row-for-row (bitwise)."""
    for m in (Objective.CONSTANT, Objective.DIMINISHING):
        seq = [solve_param_opt(p) for p in _problems("genqsgd", m)]
        bat = solve_param_opt_batched(_problems("genqsgd", m),
                                      backend="numpy")
        for r, b in zip(seq, bat):
            assert np.array_equal(r.z, b.z)
            assert (r.K0, r.B, r.feasible, r.converged, r.iterations) == \
                (b.K0, b.B, b.feasible, b.converged, b.iterations)
            assert np.array_equal(r.Kn, b.Kn)
            assert r.E == b.E and r.history == b.history


@pytest.mark.parametrize("family,m", [
    ("genqsgd", Objective.CONSTANT),
    ("genqsgd", Objective.JOINT),
    ("pm", Objective.DIMINISHING),
])
def test_batched_jnp_gia_matches_scalar_fast(family, m):
    seq = [solve_param_opt(p) for p in _problems(family, m)]
    bat = solve_param_opt_batched(_problems(family, m), backend="jnp")
    for r, b in zip(seq, bat):
        assert r.feasible == b.feasible
        assert np.allclose(r.z, b.z, atol=1e-5)
        assert (r.K0, r.B) == (b.K0, b.B)
        assert np.array_equal(r.Kn, b.Kn)
        assert b.E == pytest.approx(r.E, rel=1e-9)


@pytest.mark.slow
@pytest.mark.families
@pytest.mark.parametrize("backend", ["jnp", "jnp-fused"])
@pytest.mark.parametrize("family", family_names())
@pytest.mark.parametrize("m", list(Objective))
def test_batched_jnp_gia_matches_scalar_full_grid(backend, family, m):
    """Property over the full (m, family) grid — gqfedwavg included: both
    device engines land on the scalar NumPy reference's solution — same
    feasibility verdict, same integer recovery, matching continuous point
    and costs — including the infeasible (fa, *) / (pr, E) combinations."""
    probs = _problems(family, m, budgets=(0.25, 0.3))
    seq = [solve_param_opt(p) for p in _problems(family, m,
                                                 budgets=(0.25, 0.3))]
    bat = solve_param_opt_batched(probs, backend=backend)
    for r, b in zip(seq, bat):
        assert r.feasible == b.feasible
        assert np.allclose(r.z, b.z, atol=1e-4)
        if r.feasible:
            assert (r.K0, r.B) == (b.K0, b.B)
            assert np.array_equal(r.Kn, b.Kn)
            assert b.E == pytest.approx(r.E, rel=1e-6)
            assert b.C == pytest.approx(r.C, rel=1e-6)
            if r.gamma is not None:
                assert b.gamma == pytest.approx(r.gamma, rel=1e-6)


# ---------------------------------------------------------------------------
# integer recovery bisection
# ---------------------------------------------------------------------------
def test_min_feasible_K0_matches_linear_scan():
    prob = _scenario("genqsgd", Objective.CONSTANT).problem()
    Kn = np.array([2, 2, 3, 3], dtype=np.int64)
    for B in (1, 4, 16):
        K0, ok = min_feasible_K0(prob, Kn, B)
        # brute force the same definition
        k, ok_ref = 1, False
        while k < 10**7:
            ev = prob.evaluate(k, Kn, B, None)
            if ev["C"] <= prob.C_max * (1 + 1e-9):
                ok_ref = ev["T"] <= prob.T_max * (1 + 1e-9)
                break
            if ev["T"] > prob.T_max:
                break
            k += 1
        assert ok == ok_ref
        if ok:
            assert K0 == k


def test_min_feasible_K0_infeasible_budget():
    prob = _scenario("genqsgd", Objective.CONSTANT, C_max=1e-9,
                     T_max=10.0).problem()
    _, ok = min_feasible_K0(prob, np.array([1, 1, 1, 1]), 1)
    assert not ok


def test_min_feasible_K0_joint_beats_any_fixed_gamma():
    """The closed-form gamma-optimized recovery: for fixed (Kn, B) it finds
    a (K0, gamma) meeting the error budget with K0 no larger than the best
    K0 any gamma on a fine grid achieves (E is increasing in K0 and
    gamma-independent, so smaller K0 == better joint integer point)."""
    prob = _scenario("genqsgd", Objective.JOINT).problem()
    cap = 1.0 / CONSTS.L
    for Kn_v, B in ((2, 2), (4, 1), (3, 4)):
        Kn = np.full(4, Kn_v, dtype=np.int64)
        K0, g, ok = min_feasible_K0_joint(prob, Kn, B)
        assert ok and 0 < g <= cap * (1 + 1e-12)
        assert prob.evaluate(K0, Kn, B, g)["C"] <= prob.C_max * (1 + 1e-9)
        best_grid = None
        for gg in np.exp(np.linspace(np.log(1e-4 * cap), np.log(cap), 160)):
            k, okk = min_feasible_K0(prob, Kn, B, extra=float(gg))
            if okk:
                best_grid = k if best_grid is None else min(best_grid, k)
        assert best_grid is not None and K0 <= best_grid


def test_joint_restart_keeps_gen_o_at_or_below_gen_c():
    """Lemma 4 / Table-claim guard: with the Gen-C-seeded restart and the
    gamma-optimizing integer recovery, the jointly-optimized objective never
    lands above the fixed-constant-rule solution at the same budgets."""
    rc = _scenario("genqsgd", Objective.CONSTANT).optimize()
    ro = _scenario("genqsgd", Objective.JOINT).optimize()
    assert ro.feasible and rc.feasible
    assert ro.predicted_E <= rc.predicted_E * (1 + 1e-3)


# ---------------------------------------------------------------------------
# Scenario.sweep / SweepReport
# ---------------------------------------------------------------------------
def test_scenario_sweep_matches_pointwise_optimize():
    scn = _scenario("genqsgd", Objective.CONSTANT)
    grid = [0.22, 0.3]
    rep = scn.sweep(over={"cmax": grid}, backend="jnp")
    assert len(rep) == 2 and rep.backend == "jnp" and rep.n_groups == 1
    for c, row, plan in zip(grid, rep.rows, rep.plans):
        ref = dataclasses.replace(scn, C_max=c).optimize()
        assert row["C_max"] == c and row["feasible"] and plan.feasible
        assert (plan.K0, plan.Kn, plan.B) == (ref.K0, ref.Kn, ref.B)
        assert plan.predicted_E == pytest.approx(ref.predicted_E, rel=1e-9)
    # tighter budget costs more energy (Fig. 5a monotonicity)
    assert rep.rows[0]["E"] > rep.rows[1]["E"]


def test_sweep_heterogeneous_groups_and_names():
    scns = [_scenario("genqsgd", Objective.CONSTANT),
            _scenario("genqsgd", Objective.JOINT),
            _scenario("genqsgd", Objective.CONSTANT, C_max=0.3)]
    rep = sweep_scenarios(scns, names=["a", "b", "c"], backend="numpy",
                          parallel=False)
    assert [r["name"] for r in rep] == ["a", "b", "c"]
    assert rep.n_groups == 2         # C-budget pair batches, J solos
    assert [r["m"] for r in rep] == ["C", "J", "C"]


def test_sweep_over_validation():
    scn = _scenario("genqsgd", Objective.CONSTANT)
    with pytest.raises(ValueError, match="cannot sweep over"):
        scn.sweep(over={"warp_factor": [9]})
    with pytest.raises(ValueError, match="duplicate"):
        scn.sweep(over={"cmax": [0.2], "C_max": [0.3]})


def _report_from(points):
    rows = tuple({"name": f"p{i}", "E": e, "T": t, "C": c, "feasible": f}
                 for i, (e, t, c, f) in enumerate(points))
    return SweepReport(rows=rows, plans=(None,) * len(rows),
                       backend="numpy", n_groups=1, wall_time_s=0.0)


def test_pareto_front_dominance():
    rep = _report_from([
        (1.0, 1.0, 1.0, True),     # kept
        (2.0, 2.0, 2.0, True),     # dominated by p0
        (0.5, 3.0, 1.0, True),     # kept: better E, worse T
        (1.0, 1.0, 1.0, True),     # tie with p0: both survive
        (0.1, 0.1, 0.1, False),    # infeasible: filtered by default
    ])
    front = rep.pareto_front()
    assert [r["name"] for r in front] == ["p0", "p2", "p3"]
    assert [r["name"] for r in rep.pareto_front(feasible_only=False)] == \
        ["p4"]
    row, _ = rep.best()
    assert row["name"] == "p2"
    with pytest.raises(ValueError, match="no feasible"):
        _report_from([(1, 1, 1, False)]).best()


def test_sweep_report_csv(tmp_path):
    rep = _report_from([(1.0, 2.0, 3.0, True)])
    rep = dataclasses.replace(
        rep, rows=({**rep.rows[0], "Kn": (1, 2, 3)},))
    path = rep.to_csv(str(tmp_path / "s.csv"))
    lines = open(path).read().splitlines()
    assert lines[0].split(",")[:2] == ["name", "E"]
    assert "1|2|3" in lines[1]
