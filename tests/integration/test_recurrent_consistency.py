"""Train-form vs decode-form consistency for the recurrent mixers: the
chunked/parallel training paths must agree with the per-token recurrences
the serving stack uses."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import mamba2 as M2
from repro.models import xlstm as XL
from repro.models.registry import get_config


def test_mlstm_chunked_matches_quadratic_and_recurrent():
    cfg = get_config("xlstm-1.3b", smoke=True)
    key = jax.random.PRNGKey(0)
    p = XL.mlstm_init(key, cfg)
    B, S = 2, 512  # multiple of the 256 chunk -> chunked path
    x = jax.random.normal(key, (B, S, cfg.d_model)) * 0.5
    y_chunk = XL._mlstm_chunked(p, x, cfg)
    y_quad = XL._mlstm_quadratic(p, x, cfg)
    np.testing.assert_allclose(np.asarray(y_chunk, np.float32),
                               np.asarray(y_quad, np.float32),
                               rtol=1e-3, atol=1e-4)


def test_mlstm_prefill_state_matches_decode():
    """State handed off by the chunked prefill must continue identically to
    running the recurrence token by token."""
    cfg = get_config("xlstm-1.3b", smoke=True)
    key = jax.random.PRNGKey(1)
    p = XL.mlstm_init(key, cfg)
    B, S = 1, 512
    x = jax.random.normal(key, (B, S + 1, cfg.d_model)) * 0.5
    _, st_chunk = XL.mlstm_apply(p, x[:, :S], cfg, return_state=True)
    y1, _ = XL.mlstm_decode(p, x[:, S:S + 1], cfg, st_chunk)
    # reference: recurrent state from the quadratic path
    _, st_quad = XL._mlstm_quadratic(p, x[:, :S], cfg, return_state=True)
    y2, _ = XL.mlstm_decode(p, x[:, S:S + 1], cfg, st_quad)
    np.testing.assert_allclose(np.asarray(y1, np.float32),
                               np.asarray(y2, np.float32),
                               rtol=1e-3, atol=1e-4)


def test_mamba2_train_matches_stepwise_decode():
    cfg = get_config("zamba2-2.7b", smoke=True)
    key = jax.random.PRNGKey(2)
    p = M2.mamba2_init(key, cfg)
    B, S = 1, 32  # < CHUNK so a single chunk; still exercises the SSD path
    x = jax.random.normal(key, (B, S, cfg.d_model)) * 0.3
    y_train = M2.mamba2_apply(p, x, cfg)
    st = M2.make_ssm_state(cfg, B)
    ys = []
    for t in range(S):
        yt, st = M2.mamba2_decode(p, x[:, t:t + 1], cfg, st)
        ys.append(yt)
    y_dec = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_train, np.float32),
                               np.asarray(y_dec, np.float32),
                               rtol=2e-3, atol=2e-3)


def test_mamba2_chunked_matches_single_chunk():
    """Multi-chunk SSD must equal the single-chunk computation."""
    cfg = get_config("zamba2-2.7b", smoke=True)
    key = jax.random.PRNGKey(3)
    p = M2.mamba2_init(key, cfg)
    B = 1
    S = 2 * M2.CHUNK
    x = jax.random.normal(key, (B, S, cfg.d_model)) * 0.3
    y_multi = M2.mamba2_apply(p, x, cfg)  # S % CHUNK == 0 -> chunked
    # stepwise oracle
    st = M2.make_ssm_state(cfg, B)
    ys = []
    for t in range(S):
        yt, st = M2.mamba2_decode(p, x[:, t:t + 1], cfg, st)
        ys.append(yt)
    y_dec = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_multi, np.float32),
                               np.asarray(y_dec, np.float32),
                               rtol=2e-3, atol=2e-3)


def test_slstm_train_matches_stepwise():
    cfg = get_config("xlstm-1.3b", smoke=True)
    key = jax.random.PRNGKey(4)
    p = XL.slstm_init(key, cfg)
    B, S = 2, 16
    x = jax.random.normal(key, (B, S, cfg.d_model)) * 0.5
    y_train, st_train = XL.slstm_apply(p, x, cfg, return_state=True)
    st = XL.make_slstm_state(cfg, B)
    ys = []
    for t in range(S):
        yt, st = XL.slstm_decode(p, x[:, t:t + 1], cfg, st)
        ys.append(yt)
    y_dec = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_train, np.float32),
                               np.asarray(y_dec, np.float32),
                               rtol=1e-4, atol=1e-5)
    for k in st_train:
        np.testing.assert_allclose(np.asarray(st_train[k]),
                                   np.asarray(st[k]), rtol=1e-4, atol=1e-5)
