"""Cross-backend fault determinism: a (seed, fault model) pair produces
the bit-identical ``FaultTrace`` on the SPMD runtime and the reference
runtime — the ISSUE-9 determinism bar.

Both runtimes construct the same ``FaultDriver`` from the Plan's frozen
``FaultSpec`` and the same salted ``fault_rng(seed)`` stream, so the
per-round fault draws (straggler latencies, crash chain, corruption) are
a pure function of (seed, model) — independent of backend, mesh shape,
and model architecture (subprocess: the host device count is locked at
first jax init)."""
import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, numpy as np
    from repro.api import (ConstantRule, EdgeSystem, MLProblemConstants,
                           Plan, QuadraticTask, Scenario, SpmdTask,
                           edge_faults)
    from repro.compat import make_mesh
    from repro.faults import FaultSpec, FaultTrace
    from repro.models.registry import get_config, model_api

    devs = np.array(jax.devices()).reshape(2, 2, 2)
    mesh = make_mesh(devs, ("fl", "fsdp", "tp"))
    cfg = get_config("qwen3-1.7b", smoke=True)
    api = model_api(cfg)
    FL, B, S = 2, 4, 32
    Kn = (1, 2)

    fm = edge_faults(straggler_prob=0.5, straggler_factor=4.0,
                     crash_prob=0.3, crash_rounds=1, corrupt_prob=0.1,
                     deadline_slack=1.5)
    wt = (0.8, 1.0)
    deadline = 1.5 * 1.0
    spec = FaultSpec(model=fm, worker_times=wt, deadline=deadline,
                     deliver_p=tuple(fm.deliver_prob(np.asarray(wt),
                                                     deadline)))
    plan = Plan.manual(K0=4, Kn=Kn, B=B, step_rule=ConstantRule(0.01),
                       s0=64, sn=16, dim=4096, faults=spec)

    sys_ = EdgeSystem.paper_sec_vii(dim=4096, N=FL)
    consts = MLProblemConstants(L=0.084, sigma=33.18, G=33.63, f_gap=2.3,
                                N=FL)
    scn = Scenario(system=sys_, consts=consts, T_max=1e5, C_max=0.25)

    def batches(key):
        while True:
            key, k = jax.random.split(key)
            yield {"tokens": jax.random.randint(
                       k, (FL, max(Kn), B, S), 0, cfg.vocab),
                   "labels": jax.random.randint(
                       k, (FL, max(Kn), B, S), 0, cfg.vocab)}

    def spmd_run(seed):
        task = SpmdTask(api=api, arch=cfg, mesh=mesh,
                        batches=batches(jax.random.PRNGKey(0)))
        return scn.run(plan, task=task, backend="spmd", wire="f32",
                       seed=seed, log_every=1)

    r1 = spmd_run(11)
    r2 = spmd_run(11)
    assert isinstance(r1.fault_trace, FaultTrace)
    assert len(r1.fault_trace) == plan.K0
    assert r1.fault_trace == r2.fault_trace       # bitwise, all records
    assert r1.fault_trace.workers_dropped > 0     # the model really fired

    # the reference runtime replays the SAME trace from the same seed —
    # the fault stream is a pure function of (seed, model), not of the
    # backend, the task, or the model architecture
    ref = scn.run(plan, task=QuadraticTask(dim=8), seed=11,
                  max_rounds=plan.K0)
    assert ref.fault_trace == r1.fault_trace

    r3 = spmd_run(12)
    assert r3.fault_trace != r1.fault_trace       # seeds matter
    print("SPMD_FAULTS_OK")
""")


@pytest.mark.slow
@pytest.mark.faults
def test_spmd_fault_trace_matches_reference_8dev():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "..", "src"))
    r = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                       capture_output=True, text=True, timeout=900)
    assert "SPMD_FAULTS_OK" in r.stdout, r.stdout + r.stderr
