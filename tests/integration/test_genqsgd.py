"""GenQSGD algorithm behaviour: convergence, special-case reductions
(Remark 2), and the single-process reference vs distributed-runtime
equivalence (s = infinity)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ConstantRule, GenQSGD, GenQSGDConfig
from repro.data.federated import sample_minibatch
from repro.models import mlp


def _toy_problem(key, N=4, per=64, dim=8):
    true_w = jax.random.normal(key, (dim,))
    X = jax.random.normal(jax.random.fold_in(key, 1), (N, per, dim))
    T = X @ true_w + 0.01 * jax.random.normal(jax.random.fold_in(key, 2),
                                              (N, per))
    return true_w, (X, T)


def _loss(params, batch):
    x, t = batch
    return ((x @ params["w"] - t) ** 2).mean()


def _sample(worker_data, key, B):
    x, t = worker_data
    idx = jax.random.randint(key, (B,), 0, x.shape[0])
    return x[idx], t[idx]


def test_converges_quadratic():
    key = jax.random.PRNGKey(0)
    true_w, data = _toy_problem(key)
    cfg = GenQSGDConfig(K0=40, Kn=(3, 3, 5, 5), B=8,
                        step_rule=ConstantRule(0.05), s0=64, sn=[64] * 4)
    alg = GenQSGD(_loss, _sample, cfg)
    xf, hist = alg.run({"w": jnp.zeros(8)}, data, key,
                       eval_fn=lambda p: {"err": float(
                           jnp.linalg.norm(p["w"] - true_w))})
    assert hist[-1]["err"] < 0.1 * hist[0]["err"]


def test_quantization_error_decreases_with_s():
    """Coarser quantizers give larger deviation from the unquantized run."""
    key = jax.random.PRNGKey(1)
    _, data = _toy_problem(key)

    def run_with(s):
        cfg = GenQSGDConfig(K0=10, Kn=(2,) * 4, B=8,
                            step_rule=ConstantRule(0.05), s0=s, sn=[s] * 4)
        alg = GenQSGD(_loss, _sample, cfg)
        xf, _ = alg.run({"w": jnp.zeros(8)}, data, key)
        return xf["w"]

    exact = run_with(None)
    err2 = float(jnp.linalg.norm(run_with(2) - exact))
    err64 = float(jnp.linalg.norm(run_with(64) - exact))
    assert err64 < err2


def test_pm_sgd_reduction():
    """Remark 2: GenQSGD with K_n = 1, s = inf is parallel mini-batch SGD —
    one round must equal one global step of averaged mini-batch gradients."""
    key = jax.random.PRNGKey(2)
    _, data = _toy_problem(key)
    gamma = 0.05
    cfg = GenQSGDConfig(K0=1, Kn=(1,) * 4, B=8, step_rule=ConstantRule(gamma),
                        s0=None, sn=None)
    alg = GenQSGD(_loss, _sample, cfg)
    x0 = {"w": jnp.zeros(8)}
    # reproduce the round's exact per-worker mini-batches
    key_run = jax.random.PRNGKey(3)
    x1, _ = alg.run(x0, data, key_run, eval_fn=None)
    # manual PM-SGD with the same RNG pattern
    k_round = jax.random.split(key_run, 1 + 1)[1] if False else None
    # (we re-run the round function directly to share the RNG)
    key2, rkey = jax.random.split(key_run)
    x1b, _ = alg._round(x0, data, rkey, jnp.float32(gamma))
    keys = jax.random.split(rkey, cfg.N + 1)
    grads = []
    for n in range(4):
        wd = jax.tree.map(lambda a: a[n], data)
        kb = jax.random.split(keys[n])[1]
        batch = _sample(wd, kb, 8)
        grads.append(jax.grad(_loss)(x0, batch)["w"])
    expected = x0["w"] - gamma * jnp.mean(jnp.stack(grads), axis=0)
    np.testing.assert_allclose(np.asarray(x1b["w"]), np.asarray(expected),
                               rtol=1e-5, atol=1e-6)


def test_heterogeneous_kn_virtual_updates():
    """Workers with K_n < K_max must contribute exactly K_n real updates."""
    key = jax.random.PRNGKey(4)
    _, data = _toy_problem(key)
    # K = (1, 3): worker 0 stops after 1 local step
    cfg_h = GenQSGDConfig(K0=1, Kn=(1, 3, 1, 3), B=64,
                          step_rule=ConstantRule(0.01), s0=None, sn=None)
    alg = GenQSGD(_loss, _sample, cfg_h)
    x0 = {"w": jnp.zeros(8)}
    x1, _ = alg._round(x0, data, jax.random.PRNGKey(5), jnp.float32(0.01))
    # against manual simulation
    keys = jax.random.split(jax.random.PRNGKey(5), cfg_h.N + 1)
    deltas = []
    for n, kn in enumerate((1, 3, 1, 3)):
        wd = jax.tree.map(lambda a: a[n], data)
        p = dict(x0)
        kk = keys[n]
        for step in range(3):
            kk, kb = jax.random.split(kk)
            batch = _sample(wd, kb, 64)
            g = jax.grad(_loss)(p, batch)["w"]
            if step < kn:
                p = {"w": p["w"] - 0.01 * g}
        deltas.append((p["w"] - x0["w"]) / 0.01)
    expected = x0["w"] + 0.01 * jnp.mean(jnp.stack(deltas), 0)
    np.testing.assert_allclose(np.asarray(x1["w"]), np.asarray(expected),
                               rtol=1e-5, atol=1e-6)


def test_mlp_paper_model_trains():
    """The Sec.-VII MLP under GenQSGD improves accuracy on MNIST-like data."""
    from repro.data.synthetic import mnist_like
    from repro.data.federated import partition_iid
    X, y = mnist_like(n=4000, seed=1)
    Xw, yw = partition_iid(X[:3000], y[:3000], 5)
    data = (jnp.stack([jnp.asarray(a) for a in Xw]),
            jnp.stack([jnp.asarray(a) for a in yw]))
    cfg = GenQSGDConfig(K0=30, Kn=(4,) * 5, B=16,
                        step_rule=ConstantRule(0.5), s0=2**14, sn=[2**14] * 5)
    alg = GenQSGD(mlp.loss, sample_minibatch, cfg)
    p0 = mlp.init_params(jax.random.PRNGKey(0))
    acc0 = mlp.accuracy(p0, jnp.asarray(X[3000:]), jnp.asarray(y[3000:]))
    pf, _ = alg.run(p0, data, jax.random.PRNGKey(1))
    acc1 = mlp.accuracy(pf, jnp.asarray(X[3000:]), jnp.asarray(y[3000:]))
    assert acc1 > max(acc0 + 0.2, 0.5), (acc0, acc1)
