"""Batched serving engine: slot reuse + cross-slot isolation (a request
served alongside others must produce the same tokens as served alone)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models.registry import get_config, model_api
from repro.serve import Request, ServeEngine


def _setup():
    cfg = get_config("qwen3-1.7b", smoke=True)
    api = model_api(cfg)
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_slot_reuse_and_completion():
    cfg, params = _setup()
    eng = ServeEngine(params, cfg, slots=2, max_len=64,
                      cache_dtype=jnp.float32)
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab, 12).astype(np.int32),
                    max_new_tokens=6) for _ in range(5)]
    out = eng.run(reqs)
    assert all(r.done and len(r.output) == 6 for r in out)


def test_submit_rejects_overlong_request():
    """A request that can never fit the KV cache is rejected (marked
    failed), not assert-crashed: the engine and every other in-flight
    request keep going."""
    cfg, params = _setup()
    eng = ServeEngine(params, cfg, slots=2, max_len=64,
                      cache_dtype=jnp.float32)
    rng = np.random.default_rng(2)
    ok1 = Request(prompt=rng.integers(0, cfg.vocab, 10).astype(np.int32),
                  max_new_tokens=6)
    bad = Request(prompt=rng.integers(0, cfg.vocab, 60).astype(np.int32),
                  max_new_tokens=6)          # 60 + 6 > 64: impossible
    ok2 = Request(prompt=rng.integers(0, cfg.vocab, 10).astype(np.int32),
                  max_new_tokens=6)
    out = eng.run([ok1, bad, ok2])
    assert bad.done and bad.failed and bad.output == []
    assert "max_len" in bad.error
    assert bad.slot == -1                    # never occupied a slot
    for r in (ok1, ok2):
        assert r.done and not r.failed and len(r.output) == 6
    # the rejected request's tokens never entered a cache: ok2 alone agrees
    eng1 = ServeEngine(params, cfg, slots=1, max_len=64,
                       cache_dtype=jnp.float32)
    rng = np.random.default_rng(2)
    _ = rng.integers(0, cfg.vocab, 10)
    _ = rng.integers(0, cfg.vocab, 60)
    alone = Request(prompt=rng.integers(0, cfg.vocab, 10).astype(np.int32),
                    max_new_tokens=6)
    assert eng1.run([alone])[0].output == ok2.output


def test_cross_slot_isolation():
    """Mixed prompt lengths in one batch must not interfere (per-row cache
    cursors)."""
    cfg, params = _setup()
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab, n).astype(np.int32)
               for n in (7, 19, 13)]

    # served together
    eng = ServeEngine(params, cfg, slots=3, max_len=64,
                      cache_dtype=jnp.float32)
    together = eng.run([Request(prompt=p, max_new_tokens=5)
                        for p in prompts])

    # each served alone
    for i, p in enumerate(prompts):
        eng1 = ServeEngine(params, cfg, slots=1, max_len=64,
                           cache_dtype=jnp.float32)
        alone = eng1.run([Request(prompt=p, max_new_tokens=5)])[0]
        assert alone.output == together[i].output, (
            f"request {i}: {alone.output} vs {together[i].output}")
