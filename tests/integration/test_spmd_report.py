"""spmd RunReport parity: ``Scenario.run(backend="spmd")`` fills the same
measured energy/time/comm-bits fields as the reference backend, for both
shipped families (genqsgd and gqfedwavg), on the simulated 8-device mesh.

The measured comm-bits must equal ``rounds * plan.round_bits(dim=model_dim,
wire=wire)`` — the transport actually used — and the cost-model energy/time
must evaluate the closed forms at the executed round count, exactly like
the reference backend's report (subprocess: the host device count is locked
at first jax init)."""
import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses, math
    import jax, numpy as np
    from repro.api import (ConstantRule, EdgeSystem, MLProblemConstants,
                           Plan, Scenario, SpmdTask)
    from repro.compat import make_mesh
    from repro.core.cost import energy_cost, time_cost
    from repro.models.registry import get_config, model_api

    devs = np.array(jax.devices()).reshape(2, 2, 2)
    mesh = make_mesh(devs, ("fl", "fsdp", "tp"))
    cfg = get_config("qwen3-1.7b", smoke=True)
    api = model_api(cfg)
    FL, B, S = 2, 4, 32
    Kn = (1, 2)

    def batches(key):
        while True:
            key, k = jax.random.split(key)
            yield {"tokens": jax.random.randint(
                       k, (FL, max(Kn), B, S), 0, cfg.vocab),
                   "labels": jax.random.randint(
                       k, (FL, max(Kn), B, S), 0, cfg.vocab)}

    sys_ = EdgeSystem.paper_sec_vii(dim=4096, N=FL)
    consts = MLProblemConstants(L=0.084, sigma=33.18, G=33.63, f_gap=2.3,
                                N=FL)
    plans = {
        "genqsgd": Plan.manual(K0=3, Kn=Kn, B=B,
                               step_rule=ConstantRule(0.01), s0=64, sn=16,
                               dim=4096),
        # gqfedwavg on the spmd backend: weighted aggregation + normalized
        # momentum ride through FedConfig; the transport moves plain QSGD
        # levels (rotation is a whole-model-vector preconditioner)
        "gqfedwavg": Plan.manual(K0=3, Kn=Kn, B=B,
                                 step_rule=ConstantRule(0.01), s0=64, sn=16,
                                 dim=4096, family="gqfedwavg",
                                 agg_weights=(0.7, 0.3), momentum=0.5,
                                 normalize=True),
    }
    for fam, plan in plans.items():
        scn = Scenario(system=sys_, consts=consts, T_max=1e5, C_max=0.25,
                       family=fam)
        task = SpmdTask(api=api, arch=cfg, mesh=mesh,
                        batches=batches(jax.random.PRNGKey(0)))
        rep = scn.run(plan, task=task, backend="spmd", wire="int8",
                      log_every=1)
        assert rep.backend == "spmd" and rep.rounds == plan.K0, fam
        assert rep.model_dim > 0, fam
        # the parity bar: spmd fills the same measured fields the reference
        # backend fills, through the same pricing/cost-model code paths
        assert rep.comm_bits == rep.rounds * plan.round_bits(
            dim=rep.model_dim, wire="int8"), fam
        # cost-model measurements evaluate on the scenario's *priced*
        # system (the family's codec), matching predicted_E/T semantics
        psys = scn._priced_system
        assert rep.measured_E == energy_cost(psys, rep.rounds,
                                             np.asarray(plan.Kn), plan.B), fam
        assert rep.measured_T == time_cost(psys, rep.rounds,
                                           np.asarray(plan.Kn), plan.B), fam
        assert rep.wall_time_s > 0 and math.isfinite(rep.wall_time_s), fam
        assert rep.history and math.isfinite(rep.history[-1]["loss"]), fam
        assert rep.final_metrics, fam
    print("SPMD_REPORT_OK")
""")


@pytest.mark.slow
@pytest.mark.families
def test_spmd_run_report_parity_8dev():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "..", "src"))
    r = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                       capture_output=True, text=True, timeout=900)
    assert "SPMD_REPORT_OK" in r.stdout, r.stdout + r.stderr
