"""Distributed GenQSGD runtime on a simulated 8-device mesh (subprocess —
the host device count is locked at first jax init, so these run isolated)."""
import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np, re
    from repro.compat import make_mesh
    from repro.models.registry import get_config, model_api
    from repro.fed.runtime import FedConfig, make_round_fn
    from repro.fed import sharding as SH

    devs = np.array(jax.devices()).reshape(2, 2, 2)
    mesh = make_mesh(devs, ("fl", "fsdp", "tp"))
    cfg = get_config("qwen3-1.7b", smoke=True)
    api = model_api(cfg)
    key = jax.random.PRNGKey(0)
    params = api.init_params(key, cfg)
    FL, K, B, S = 2, 2, 4, 32
    batch = {"tokens": jax.random.randint(key, (FL, K, B, S), 0, cfg.vocab),
             "labels": jax.random.randint(key, (FL, K, B, S), 0, cfg.vocab)}
    outs = {}
    for wire in ("f32", "int8", "rs_ag"):
        fed = FedConfig(n_workers=FL, Kn=(1, 2), s0=64, sn=(16, 127),
                        wire=wire)
        rnd = make_round_fn(api, cfg, fed, mesh)
        pshard = SH.shardings(SH.param_specs(params, mesh), mesh)
        bshard = SH.shardings(SH.batch_specs(batch, mesh, "fl_train"), mesh)
        pp = jax.device_put(params, pshard)
        bb = jax.device_put(batch, bshard)
        f = jax.jit(rnd, in_shardings=(pshard, bshard, None, None),
                    out_shardings=(pshard, None))
        x_new, m = f(pp, bb, jax.random.PRNGKey(1), jnp.float32(0.05))
        assert np.isfinite(float(m["loss"])), wire
        txt = f.lower(pp, bb, jax.random.PRNGKey(1),
                      jnp.float32(0.05)).compile().as_text()
        outs[wire] = (np.asarray(jax.tree.leaves(x_new)[0]), txt)
    # int8 wire must put s8 all-gathers on the wire
    assert len(re.findall(r"s8\\[[^\\]]*\\][^\\n]*all-gather",
                          outs["int8"][1])) > 0
    # all wires agree bitwise (levels are exact integers either way)
    assert np.array_equal(outs["f32"][0], outs["int8"][0])
    assert np.array_equal(outs["f32"][0], outs["rs_ag"][0])
    # rs_ag actually reduce-scatters on the wire
    assert "reduce-scatter" in outs["rs_ag"][1]
    # int4 wire (s <= 7): packed payload, bit-identical to the f32 transport
    outs4 = {}
    for wire in ("f32", "int4"):
        fed = FedConfig(n_workers=FL, Kn=(1, 2), s0=7, sn=(7, 5), wire=wire)
        rnd = make_round_fn(api, cfg, fed, mesh)
        f = jax.jit(rnd, in_shardings=(pshard, bshard, None, None),
                    out_shardings=(pshard, None))
        x_new, m = f(pp, bb, jax.random.PRNGKey(1), jnp.float32(0.05))
        assert np.isfinite(float(m["loss"])), wire
        outs4[wire] = np.asarray(jax.tree.leaves(x_new)[0])
    assert np.array_equal(outs4["f32"], outs4["int4"])
    # per-bucket norms (FedConfig.bucket): the compact payload still rides
    # the level transport; cross-wire agreement is ulp-level (the decode
    # sits in a different fusion context), not bitwise.
    outsb = {}
    for wire in ("f32", "int8"):
        fed = FedConfig(n_workers=FL, Kn=(1, 2), s0=64, sn=(16, 127),
                        wire=wire, bucket=256)
        rnd = make_round_fn(api, cfg, fed, mesh)
        f = jax.jit(rnd, in_shardings=(pshard, bshard, None, None),
                    out_shardings=(pshard, None))
        x_new, m = f(pp, bb, jax.random.PRNGKey(1), jnp.float32(0.05))
        assert np.isfinite(float(m["loss"])), ("bucket", wire)
        txt = f.lower(pp, bb, jax.random.PRNGKey(1),
                      jnp.float32(0.05)).compile().as_text()
        outsb[wire] = (np.asarray(jax.tree.leaves(x_new)[0]), txt)
    assert np.allclose(outsb["f32"][0], outsb["int8"][0], atol=1e-6, rtol=0)
    assert len(re.findall(r"s8\\[[^\\]]*\\][^\\n]*all-gather",
                          outsb["int8"][1])) > 0
    assert not np.array_equal(outsb["f32"][0], outs["f32"][0])  # bucketing bites
    print("DISTRIBUTED_OK")
""")


@pytest.mark.slow
def test_distributed_round_8dev():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "..", "src"))
    r = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                       capture_output=True, text=True, timeout=900)
    assert "DISTRIBUTED_OK" in r.stdout, r.stdout + r.stderr


_ELIAS_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.compat import make_mesh
    from repro.models.registry import get_config, model_api
    from repro.fed.runtime import FedConfig, make_round_fn
    from repro.fed import sharding as SH
    from repro.compress import elias as E

    devs = np.array(jax.devices()).reshape(2, 2, 2)
    mesh = make_mesh(devs, ("fl", "fsdp", "tp"))
    cfg = get_config("qwen3-1.7b", smoke=True)
    api = model_api(cfg)
    key = jax.random.PRNGKey(0)
    params = api.init_params(key, cfg)
    FL, K, B, S = 2, 2, 4, 32
    batch = {"tokens": jax.random.randint(key, (FL, K, B, S), 0, cfg.vocab),
             "labels": jax.random.randint(key, (FL, K, B, S), 0, cfg.vocab)}
    outs = {}
    for wire in ("f32", "elias"):
        fed = FedConfig(n_workers=FL, Kn=(1, 2), s0=7, sn=(5, 7), wire=wire)
        rnd = make_round_fn(api, cfg, fed, mesh)
        pshard = SH.shardings(SH.param_specs(params, mesh), mesh)
        bshard = SH.shardings(SH.batch_specs(batch, mesh, "fl_train"), mesh)
        pp = jax.device_put(params, pshard)
        bb = jax.device_put(batch, bshard)
        f = jax.jit(rnd, in_shardings=(pshard, bshard, None, None),
                    out_shardings=(pshard, None))
        x_new, m = f(pp, bb, jax.random.PRNGKey(1), jnp.float32(0.05))
        flat = np.concatenate([np.asarray(l).reshape(-1)
                               for l in jax.tree.leaves(x_new)])
        outs[wire] = (flat, {k: np.asarray(v) for k, v in m.items()})

    # the gap coder is lossless on levels, so the elias transport's
    # aggregation is BIT-identical to the f32 wire's
    assert np.array_equal(outs["f32"][0], outs["elias"][0])
    assert "elias_bits" not in outs["f32"][1]
    bits = int(outs["elias"][1]["elias_bits"])
    dim = outs["f32"][0].size
    # 2 worker uploads + 1 server multicast, each bounded by the
    # worst-case pricing arm at its quantizer (omega_max_bits(7) covers
    # both s=5 and s=7 by monotonicity)
    worst = 3 * (dim * E.omega_max_bits(7) + E._TERM_BITS)
    assert 0 < bits < worst, (bits, worst)
    print("ELIAS_OK")
""")


@pytest.mark.slow
def test_distributed_elias_wire_8dev():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "..", "src"))
    r = subprocess.run([sys.executable, "-c", _ELIAS_SCRIPT], env=env,
                       capture_output=True, text=True, timeout=900)
    assert "ELIAS_OK" in r.stdout, r.stdout + r.stderr


_DRYRUN_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
    from repro.launch.dryrun import run_case
    rec = run_case("qwen3-1.7b", "decode_32k", multi_pod=True, verbose=False)
    assert rec["status"] == "ok", rec
    assert rec["memory"]["temp_bytes"] > 0
    assert rec["collectives"]["total_bytes"] > 0
    print("DRYRUN_OK")
""")


@pytest.mark.slow
def test_dryrun_case_multipod():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "..", "src"))
    r = subprocess.run([sys.executable, "-c", _DRYRUN_SCRIPT], env=env,
                       capture_output=True, text=True, timeout=900)
    assert "DRYRUN_OK" in r.stdout, r.stdout + r.stderr


@pytest.mark.slow
def test_train_launcher_cli():
    src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                       "..", "src"))
    env = dict(os.environ)
    env["PYTHONPATH"] = src
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch", "qwen3-1.7b",
         "--smoke", "--rounds", "3", "--batch", "4", "--seq", "64",
         "--wire", "int8"],
        env=env, capture_output=True, text=True, timeout=900)
    assert "[train] done" in r.stdout, r.stdout + r.stderr[-2000:]
