"""Per-architecture smoke tests (deliverable f): a REDUCED variant of each
assigned family runs one forward/train step and one decode step on CPU;
output shapes + finiteness asserted."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.registry import ARCH_IDS, get_config, model_api


def _batch(cfg, key, B=2, S=64):
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab),
             "labels": jax.random.randint(key, (B, S), 0, cfg.vocab)}
    if cfg.family == "vlm":
        npatch = int(S * cfg.vision_patches_frac)
        batch["patch_embeds"] = jax.random.normal(key, (B, npatch,
                                                        cfg.d_model))
        pos = jnp.broadcast_to(jnp.arange(S), (B, S))
        batch["positions3"] = jnp.stack([pos, pos, pos])
    if cfg.encdec:
        batch["frames"] = jax.random.normal(
            key, (B, cfg.max_source_positions, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    cfg = get_config(arch, smoke=True)
    assert cfg.d_model <= 512
    assert cfg.n_layers <= 2 * len(cfg.pattern) + 1
    if cfg.n_experts:
        assert cfg.n_experts <= 4
    api = model_api(cfg)
    key = jax.random.PRNGKey(0)
    params = api.init_params(key, cfg)
    batch = _batch(cfg, key)
    loss, grads = jax.value_and_grad(
        lambda p: api.loss_train(p, cfg, batch))(params)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), arch
    leaves = jax.tree.leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in leaves), arch
    # one SGD step moves the loss
    p2 = jax.tree.map(lambda w, g: w - 0.1 * g, params, grads)
    loss2 = api.loss_train(p2, cfg, batch)
    assert bool(jnp.isfinite(loss2))
    assert float(loss2) < float(loss) + 0.5


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_prefill_decode(arch):
    cfg = get_config(arch, smoke=True)
    api = model_api(cfg)
    key = jax.random.PRNGKey(0)
    params = api.init_params(key, cfg)
    B, S = 2, 64
    batch = _batch(cfg, key, B, S)
    logits, caches = api.prefill(params, cfg, batch, cache_len=S + 8)
    assert logits.shape == (B, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits))), arch
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    pos = jnp.full((B, 1), S, jnp.int32)
    logits2, caches2 = api.decode_step(params, cfg, tok, caches, pos)
    assert logits2.shape == (B, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits2))), arch


def test_param_counts_sane():
    # full configs should be in the advertised ballpark
    approx = {
        "qwen3-1.7b": (1.2e9, 2.6e9),
        "mistral-large-123b": (1.0e11, 1.4e11),
        "gemma3-4b": (3e9, 6e9),
        "llama3-405b": (3.6e11, 4.4e11),
        "olmoe-1b-7b": (5e9, 9e9),
        "phi3.5-moe-42b-a6.6b": (3.4e11 / 10, 6e10),
        "zamba2-2.7b": (1.8e9, 4e9),
        "xlstm-1.3b": (0.8e9, 2.4e9),
        "whisper-tiny": (2e7, 8e7),
        "qwen2-vl-7b": (6e9, 9.5e9),
    }
    for arch, (lo, hi) in approx.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, (arch, f"{n:.3g}")


def test_decode_matches_prefill_continuation():
    """Greedy decode after prefill must equal running the longer sequence
    through prefill (cache correctness), for a dense arch."""
    cfg = get_config("qwen3-1.7b", smoke=True)
    api = model_api(cfg)
    key = jax.random.PRNGKey(3)
    params = api.init_params(key, cfg)
    B, S = 2, 32
    toks = jax.random.randint(key, (B, S + 1), 0, cfg.vocab)
    batch_s = {"tokens": toks[:, :S], "labels": toks[:, :S]}
    logits_s, caches = api.prefill(params, cfg, batch_s, cache_len=S + 4,
                                   cache_dtype=jnp.float32)
    pos = jnp.full((B, 1), S, jnp.int32)
    logits_d, _ = api.decode_step(params, cfg, toks[:, S:S + 1], caches, pos)
    batch_l = {"tokens": toks, "labels": toks}
    logits_l, _ = api.prefill(params, cfg, batch_l, cache_len=S + 4,
                              cache_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(logits_d), np.asarray(logits_l),
                               rtol=2e-3, atol=2e-3)
