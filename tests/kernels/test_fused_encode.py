"""One-pass fused encode: bit-identity against the staged reference.

The fused kernel (norm + quantize + int4 pack in one pallas_call, plus
the fused-rotate variant) must produce byte-for-byte the payload of the
staged composition it replaced — levels AND packed bytes, on both
backends, odd lengths and all.  Norms are bit-equal on single-block
in-kernel paths and 1-ulp-close on grid-accumulated ones (pre-existing
backend contract).  Also: the Codec payload entry points dispatch to the
fused paths without changing the wire bytes, and pack_int4/unpack_int4
round-trip on boundary/odd/empty inputs.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from tests.compat import given, settings, st

from repro import compress as C
from repro.compress import backends as B
from repro.compress import rotation as R
from repro.kernels.qsgd import FUSED_ROTATE_MAX_DIM

SIZES = [1, 2, 127, 1024, 40_000, 2**16, 2**16 + 3]


def _yu(n, seed=0):
    key = jax.random.PRNGKey(seed)
    y = jax.random.normal(key, (n,)) * 3
    u = jax.random.uniform(jax.random.fold_in(key, 1), (n,))
    return y, u


@pytest.mark.parametrize("n", SIZES)
@pytest.mark.parametrize("pack", [False, True])
def test_fused_kernel_matches_staged_composition(n, pack):
    """Fused pallas_call == encode_pallas + pack_int4, byte for byte."""
    s = 7 if pack else 64
    y, u = _yu(n)
    payload, norm = B.encode_fused(y, s, u, pack=pack, interpret=True)
    lvl_ref, norm_ref = B.encode_pallas(y, s, u, interpret=True)
    ref = (C.pack_int4(lvl_ref.astype(jnp.int8))[:(n + 1) // 2] if pack
           else lvl_ref.astype(jnp.int8))
    assert payload.dtype == ref.dtype
    assert np.array_equal(np.asarray(payload), np.asarray(ref))
    assert np.allclose(norm, norm_ref, rtol=1e-6)


@pytest.mark.parametrize("n", SIZES)
def test_fused_jnp_matches_staged_composition(n):
    """The reference backend's one-jit pipeline: same payload contract."""
    y, u = _yu(n, seed=1)
    payload, norm = B.encode_fused_jnp(y, 7, u, pack=True)
    lvl_ref, norm_ref = B.encode_jnp(y, 7, u)
    ref = C.pack_int4(lvl_ref.astype(jnp.int8))[:(n + 1) // 2]
    assert np.array_equal(np.asarray(payload), np.asarray(ref))
    assert np.array_equal(np.asarray(norm), np.asarray(norm_ref))


@pytest.mark.parametrize("n", [64, 1000, FUSED_ROTATE_MAX_DIM,
                               FUSED_ROTATE_MAX_DIM + 1, 100_000])
@pytest.mark.parametrize("pack", [False, True])
def test_fused_rotate_matches_rotate_then_encode(n, pack):
    """Fused-rotate == rotate + fused encode on the padded message, both
    in-kernel (d <= FUSED_ROTATE_MAX_DIM) and via the FWHT fallback."""
    s = 7 if pack else 64
    d = R.next_pow2(n)
    y, _ = _yu(n, seed=2)
    u = jax.random.uniform(jax.random.PRNGKey(99), (d,))
    payload, norm = B.encode_rotated_fused(y, s, u, seed=5, pack=pack,
                                           interpret=True)
    r = R.rotate(y, 5)
    lvl_ref, norm_ref = B.encode_pallas(r, s, u, interpret=True)
    ref = (C.pack_int4(lvl_ref.astype(jnp.int8))[:d // 2] if pack
           else lvl_ref.astype(jnp.int8))
    assert payload.shape[0] == (d // 2 if pack else d)
    assert np.array_equal(np.asarray(payload), np.asarray(ref))
    if d <= FUSED_ROTATE_MAX_DIM:
        # single-block in-kernel path: the norm is the same f32 reduction
        assert np.array_equal(np.asarray(norm), np.asarray(norm_ref))
    else:
        assert np.allclose(norm, norm_ref, rtol=1e-6)


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
@pytest.mark.parametrize("wire", ["int4", "int8", "elias"])
@pytest.mark.parametrize("kind", ["qsgd", "rotated"])
def test_codec_payload_roundtrip_all_paths(backend, wire, kind):
    """encode_payload -> decode_payload reproduces decode(encode(y)) for
    every (backend, wire, kind) dispatch — the pipeline the runtime uses."""
    n = 2049
    s = 7
    codec = C.make_codec(s, wire=wire, backend=backend, kind=kind,
                         interpret=True)
    y, _ = _yu(n, seed=3)
    d = R.next_pow2(n) if kind == "rotated" else n
    u = jax.random.uniform(jax.random.PRNGKey(7), (d,))
    payload, norm, nbits = codec.encode_payload(y, u)
    out = codec.decode_payload(payload, norm, d, jnp.float32)
    lvl, nrm2 = codec.encode(y, u)
    ref = codec.decode(lvl, nrm2)
    # levels are bit-identical on every path; norms may differ by 1 ulp
    # between fused and staged sumsq accumulation orders (pre-existing
    # backend contract), so decoded values compare at that tolerance
    if wire == "int4":
        got_lvl = C.unpack_int4(payload, d)
    elif wire == "elias":
        from repro.compress import elias as E
        got_lvl = E.decode_levels(payload, d)
    else:
        got_lvl = payload
    assert np.array_equal(np.asarray(got_lvl),
                          np.asarray(lvl.astype(jnp.int8)))
    assert np.allclose(norm, nrm2, rtol=1e-6)
    assert np.allclose(np.asarray(out), np.asarray(ref), rtol=1e-5,
                       atol=1e-5)
    assert nbits is not None


@pytest.mark.parametrize("n", [0, 1, 2, 3, 7, 8, 255])
def test_pack_unpack_boundary_and_odd(n):
    rng = np.random.default_rng(n)
    lv = rng.integers(-7, 8, n).astype(np.int8)
    if n >= 2:
        lv[0], lv[1] = 7, -7  # nibble boundary levels
    packed = C.pack_int4(jnp.asarray(lv))
    assert packed.shape[0] == (n + 1) // 2 or n == 0
    back = C.unpack_int4(packed, n)
    assert np.array_equal(np.asarray(back), lv)


@settings(max_examples=30, deadline=None)
@given(st.lists(st.integers(min_value=-7, max_value=7), max_size=129))
def test_pack_unpack_property(levels):
    lv = np.asarray(levels, np.int8)
    back = C.unpack_int4(C.pack_int4(jnp.asarray(lv)), lv.size)
    assert np.array_equal(np.asarray(back), lv)
