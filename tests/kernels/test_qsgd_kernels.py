"""Backend equivalence: the Pallas kernels (interpret mode) must be
bit-identical to the reference jnp backend for the same noise tensor, plus
int4 wire pack/unpack round-trips and the fused dequant-apply."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from tests.compat import given, settings, st

from repro import compress as C
from repro.compress import backends as B

SHAPES = [(127,), (1024,), (512, 1024), (3, 5, 77), (2**16 + 3,)]
DTYPES = [jnp.float32, jnp.bfloat16]
S_VALUES = [1, 7, 64, 127]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("s", S_VALUES)
def test_backends_bit_identical(shape, dtype, s):
    """Pallas and reference backends: identical int8 levels AND norms."""
    key = jax.random.PRNGKey(hash((shape, s)) % 2**31)
    y = (jax.random.normal(key, shape) * 3).astype(dtype)
    u = jax.random.uniform(jax.random.fold_in(key, 1), shape, jnp.float32)
    lvl_p, norm_p = C.make_codec(s, wire="int8", backend="pallas").encode(y, u)
    lvl_j, norm_j = C.make_codec(s, wire="int8", backend="jnp").encode(y, u)
    assert lvl_p.dtype == jnp.int8 and lvl_j.dtype == jnp.int8
    assert jnp.array_equal(lvl_p, lvl_j), (shape, dtype, s)
    np.testing.assert_allclose(float(norm_p), float(norm_j), rtol=1e-6)
    assert int(jnp.max(jnp.abs(lvl_p.astype(jnp.int32)))) <= s


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_dequant_apply_matches_ref(shape, dtype):
    s = 64
    key = jax.random.PRNGKey(0)
    y = (jax.random.normal(key, shape)).astype(dtype)
    x = (jax.random.normal(jax.random.fold_in(key, 1), shape)).astype(dtype)
    u = jax.random.uniform(jax.random.fold_in(key, 2), shape, jnp.float32)
    pallas = C.make_codec(s, wire="int8", backend="pallas")
    ref = C.make_codec(s, wire="int8", backend="jnp")
    lvl, norm = pallas.encode(y, u)
    out = pallas.decode_apply(x, lvl, norm, 0.05)
    out_ref = ref.decode_apply(x, lvl, norm, 0.05)
    atol = 1e-6 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(out_ref, np.float32),
                               rtol=1e-5, atol=atol)
    assert out.dtype == x.dtype


@pytest.mark.parametrize("n", [1, 2, 7, 128, 2**12 + 5])
def test_int4_pack_unpack_roundtrip(n):
    key = jax.random.PRNGKey(n)
    lvl = jax.random.randint(key, (n,), -7, 8, jnp.int32).astype(jnp.int8)
    packed = C.pack_int4(lvl)
    assert packed.dtype == jnp.int8 and packed.shape[0] == (n + 1) // 2
    got = C.unpack_int4(packed, n)
    assert got.dtype == jnp.int8
    assert jnp.array_equal(got, lvl), n


def test_int4_roundtrip_through_encode():
    """pack/unpack composed with a real s<=7 encode is the identity."""
    key = jax.random.PRNGKey(9)
    y = jax.random.normal(key, (4097,))
    u = jax.random.uniform(jax.random.fold_in(key, 1), y.shape, jnp.float32)
    codec = C.make_codec(7, wire="int4")
    lvl, norm = codec.encode(y, u)
    lvl2 = C.unpack_int4(C.pack_int4(lvl), y.size).reshape(y.shape)
    assert jnp.array_equal(lvl, lvl2)
    assert jnp.array_equal(codec.decode(lvl, norm), codec.decode(lvl2, norm))


@given(st.integers(min_value=1, max_value=2**18))
@settings(max_examples=20, deadline=None)
def test_norm_kernel_any_length(n):
    y = jnp.arange(n, dtype=jnp.float32) / max(n, 1)
    got = float(B.tensor_norm_pallas(y))
    want = float(jnp.linalg.norm(y))
    assert got == pytest.approx(want, rel=1e-5, abs=1e-6)


def test_quantize_roundtrip_error_bound():
    """dequant(quant(y)) error satisfies Assumption 1's bound (kernel path)."""
    key = jax.random.PRNGKey(7)
    for s in (4, 16, 64):
        y = jax.random.normal(key, (4096,))
        u = jax.random.uniform(jax.random.fold_in(key, s), y.shape)
        codec = C.make_codec(s, wire="int8", backend="pallas")
        lvl, norm = codec.encode(y, u)
        deq = codec.decode_apply(jnp.zeros_like(y), lvl, norm, 1.0)
        err = float(jnp.sum((deq - y) ** 2))
        qs = min(4096 / s**2, np.sqrt(4096) / s)
        # single-draw bound (holds in expectation; allow slack)
        assert err <= 3.0 * qs * float(jnp.sum(y**2))
