"""Pallas kernel validation: interpret-mode execution vs the pure-jnp oracle
across a shape × dtype × s sweep (per-kernel allclose requirement)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.kernels import ops, ref
from repro.kernels.ops import _to_grid2d

SHAPES = [(127,), (1024,), (512, 1024), (3, 5, 77), (2**16 + 3,)]
DTYPES = [jnp.float32, jnp.bfloat16]
S_VALUES = [1, 7, 64, 127]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("s", S_VALUES)
def test_quantize_matches_ref(shape, dtype, s):
    key = jax.random.PRNGKey(hash((shape, s)) % 2**31)
    y = (jax.random.normal(key, shape) * 3).astype(dtype)
    lvl, norm = ops.qsgd_quantize(y, key, s=s)
    y2d, n = _to_grid2d(y.reshape(-1).astype(jnp.float32))
    u = jax.random.uniform(key, y2d.shape, jnp.float32)
    ref_norm = jnp.sqrt(ref.sumsq_ref(y))
    lvl_ref = ref.qsgd_quantize_ref(
        y2d, u, s, ref_norm).reshape(-1)[:n].reshape(shape)
    np.testing.assert_allclose(float(norm), float(ref_norm), rtol=1e-5)
    assert jnp.array_equal(lvl, lvl_ref), (shape, dtype, s)
    assert lvl.dtype == jnp.int8
    assert int(jnp.max(jnp.abs(lvl.astype(jnp.int32)))) <= s


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_dequant_apply_matches_ref(shape, dtype):
    s = 64
    key = jax.random.PRNGKey(0)
    y = (jax.random.normal(key, shape)).astype(dtype)
    x = (jax.random.normal(jax.random.fold_in(key, 1), shape)).astype(dtype)
    lvl, norm = ops.qsgd_quantize(y, key, s=s)
    out = ops.qsgd_dequant_apply(x, lvl, norm, 0.05, s=s)
    out_ref = ref.qsgd_dequant_apply_ref(x, lvl, norm, s, 0.05)
    atol = 1e-6 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(out_ref, np.float32),
                               rtol=1e-5, atol=atol)
    assert out.dtype == x.dtype


@given(st.integers(min_value=1, max_value=2**18))
@settings(max_examples=20, deadline=None)
def test_norm_kernel_any_length(n):
    y = jnp.arange(n, dtype=jnp.float32) / max(n, 1)
    got = float(ops.tensor_norm(y))
    want = float(jnp.linalg.norm(y))
    assert got == pytest.approx(want, rel=1e-5, abs=1e-6)


def test_quantize_roundtrip_error_bound():
    """dequant(quant(y)) error satisfies Assumption 1's bound (kernel path)."""
    key = jax.random.PRNGKey(7)
    for s in (4, 16, 64):
        y = jax.random.normal(key, (4096,))
        lvl, norm = ops.qsgd_quantize(y, key, s=s)
        deq = ops.qsgd_dequant_apply(jnp.zeros_like(y), lvl, norm, 1.0, s=s)
        err = float(jnp.sum((deq - y) ** 2))
        qs = min(4096 / s**2, np.sqrt(4096) / s)
        # single-draw bound (holds in expectation; allow slack)
        assert err <= 3.0 * qs * float(jnp.sum(y**2))
