"""Flash-decode kernel: interpret-mode sweep vs the plain-softmax oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_decode import BLOCK_C, flash_decode_call


def _oracle(q, k, v, valid):
    dh = q.shape[-1]
    s = jnp.einsum("bhgd,bchd->bhgc", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / np.sqrt(dh)
    s = jnp.where(valid[:, None, None, :] > 0, s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhgc,bchd->bhgd", w, v.astype(jnp.float32))


@pytest.mark.parametrize("B,KV,G,dh,nb", [
    (1, 1, 1, 64, 1),
    (2, 4, 2, 64, 2),
    (2, 2, 8, 128, 4),
    (1, 8, 1, 256, 2),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_decode_sweep(B, KV, G, dh, nb, dtype):
    C = nb * BLOCK_C
    key = jax.random.PRNGKey(B * 31 + KV * 7 + dh)
    q = (jax.random.normal(key, (B, KV, G, dh)) * 2).astype(dtype)
    k = jax.random.normal(jax.random.fold_in(key, 1),
                          (B, C, KV, dh)).astype(dtype)
    v = jax.random.normal(jax.random.fold_in(key, 2),
                          (B, C, KV, dh)).astype(dtype)
    lens = jax.random.randint(jax.random.fold_in(key, 3), (B,), 1, C + 1)
    valid = (jnp.arange(C)[None] < lens[:, None]).astype(jnp.float32)
    out = flash_decode_call(q, k, v, valid)
    ref = _oracle(q, k, v, valid)
    tol = 1e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)
    assert out.dtype == dtype


def test_flash_decode_single_valid_token():
    """Degenerate cache (one valid entry) -> output == that V row."""
    B, KV, G, dh, C = 1, 2, 2, 64, BLOCK_C
    key = jax.random.PRNGKey(9)
    q = jax.random.normal(key, (B, KV, G, dh))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, C, KV, dh))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, C, KV, dh))
    valid = jnp.zeros((B, C)).at[:, 0].set(1.0)
    out = flash_decode_call(q, k, v, valid)
    expect = jnp.broadcast_to(v[:, 0][:, :, None, :], out.shape)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-5, atol=1e-6)
