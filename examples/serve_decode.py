"""Serving example: prefill a batch of prompts, then greedy-decode with the
KV-cache serve step — the same decode_step the decode_32k / long_500k
dry-run shapes lower.  With --engine, requests run through the slot-based
continuous-batching engine instead (more requests than slots).

    PYTHONPATH=src python examples/serve_decode.py --arch qwen3-1.7b --steps 16
    PYTHONPATH=src python examples/serve_decode.py --engine --requests 6
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.registry import ARCH_IDS, get_config, model_api
from repro.serve import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b", choices=ARCH_IDS)
    ap.add_argument("--steps", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--engine", action="store_true",
                    help="serve via the slot-based batching engine")
    ap.add_argument("--requests", type=int, default=6)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)  # reduced family on CPU
    api = model_api(cfg)
    key = jax.random.PRNGKey(0)
    params = api.init_params(key, cfg)
    B, S = args.batch, args.prompt_len

    if args.engine:
        rng = np.random.default_rng(0)
        eng = ServeEngine(params, cfg, slots=args.batch,
                          max_len=S + args.steps + 8)
        reqs = [Request(prompt=rng.integers(0, cfg.vocab,
                                            rng.integers(4, S)).astype(
                            np.int32),
                        max_new_tokens=args.steps)
                for _ in range(args.requests)]
        out = eng.run(reqs)
        for i, r in enumerate(out):
            print(f"  req {i} ({len(r.prompt)}-token prompt): {r.output}")
        print(f"served {args.requests} requests over {args.batch} slots")
        return

    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab),
             "labels": jnp.zeros((B, S), jnp.int32)}
    if cfg.family == "vlm":
        npatch = int(S * cfg.vision_patches_frac)
        batch["patch_embeds"] = jax.random.normal(key, (B, npatch,
                                                        cfg.d_model))
        pos = jnp.broadcast_to(jnp.arange(S), (B, S))
        batch["positions3"] = jnp.stack([pos, pos, pos])
    if cfg.encdec:
        batch["frames"] = jax.random.normal(
            key, (B, cfg.max_source_positions, cfg.d_model))

    print(f"prefill {args.arch} (smoke config): batch={B} prompt={S}")
    logits, caches = jax.jit(
        lambda p, b: api.prefill(p, cfg, b,
                                 cache_len=S + args.steps))(params, batch)

    step = jax.jit(lambda p, t, c, po: api.decode_step(p, cfg, t, c, po))
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    out = [tok]
    for i in range(args.steps - 1):
        pos = jnp.full((B, 1), S + i, jnp.int32)
        logits, caches = step(params, tok, caches, pos)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        out.append(tok)
    gen = jnp.concatenate(out, axis=1)
    print("generated token ids:")
    for b in range(B):
        print(f"  seq {b}: {gen[b].tolist()}")
    print(f"decoded {args.steps} tokens x {B} sequences with a "
          f"{S + args.steps}-slot KV cache")


if __name__ == "__main__":
    main()
