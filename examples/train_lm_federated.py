"""End-to-end driver: federated training of a ~100M-parameter LM with the
DISTRIBUTED GenQSGD runtime (the same code the multi-pod dry-run lowers) on
a simulated 8-device mesh (fl=2 workers x fsdp=2 x tp=2).

The run is parameterized through a repro.api :class:`Plan` — the same object
``Scenario.optimize`` produces — so the FedConfig derives from one validated
source of truth (a hand-built Plan here, since the demo picks its knobs from
the CLI rather than from the optimizer).

    PYTHONPATH=src python examples/train_lm_federated.py --rounds 20
    PYTHONPATH=src python examples/train_lm_federated.py --rounds 300 --full

--full uses the ~100M config (slow on CPU); the default is a ~10M variant
so the example finishes in a couple of minutes.
"""
import argparse
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import numpy as np

from repro.api import ConstantRule, GenQSGDTrainer, Plan, round_comm_bits
from repro.configs.base import ArchConfig
from repro.data.federated import round_batches
from repro.data.synthetic import token_batches
from repro.models import lm


def small_cfg(full: bool) -> ArchConfig:
    if full:  # ~100M params
        return ArchConfig(name="lm-100m", family="dense", citation="example",
                          n_layers=12, d_model=768, n_heads=12, n_kv=4,
                          d_ff=3072, vocab=8192, d_head=64)
    return ArchConfig(name="lm-10m", family="dense", citation="example",
                      n_layers=4, d_model=256, n_heads=4, n_kv=2,
                      d_ff=1024, vocab=2048, d_head=64)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8, help="per-worker batch")
    ap.add_argument("--k-local", type=int, default=2)
    from repro.compress import RUNTIME_WIRES, wire_max_s
    ap.add_argument("--wire", default="int8", choices=list(RUNTIME_WIRES))
    ap.add_argument("--s", type=int, default=None,
                    help="quantization parameter s0=sn (default: 64, "
                         "clamped to the wire's cap)")
    ap.add_argument("--bucket", type=int, default=None,
                    help="per-bucket-norm quantization bucket size")
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()
    s_q = args.s if args.s is not None else min(64, wire_max_s(args.wire) or 64)

    cfg = small_cfg(args.full)
    from repro.compat import make_mesh
    devs = np.array(jax.devices()).reshape(2, 2, 2)
    mesh = make_mesh(devs, ("fl", "fsdp", "tp"))
    fl = 2
    plan = Plan.manual(K0=args.rounds, Kn=(args.k_local,) * fl, B=args.batch,
                       step_rule=ConstantRule(0.01), s0=s_q, sn=s_q,
                       q_dim=args.bucket)
    fed = plan.to_fed_config(wire=args.wire)
    trainer = GenQSGDTrainer(lm, cfg, fed, mesh, step_rule=plan.step_rule,
                             checkpoint_dir=args.ckpt)
    state = trainer.init(jax.random.PRNGKey(0))
    n_params = sum(int(np.prod(l.shape))
                   for l in jax.tree.leaves(state.params))
    print(f"model {cfg.name}: {n_params/1e6:.1f}M params | "
          f"mesh fl=2 fsdp=2 tp=2 | wire={args.wire} | "
          f"{round_comm_bits(fed, n_params)/8e6:.1f} MB/round")

    stream = token_batches(seed=0, batch=args.batch, seq=args.seq,
                           vocab=cfg.vocab)
    batches = round_batches(stream, fl, fed.K_max)
    state = trainer.run(state, batches, jax.random.PRNGKey(1),
                        n_rounds=args.rounds, log_every=max(1, args.rounds // 10),
                        ckpt_every=0 if not args.ckpt else args.rounds // 2)
    first, last = state.history[0]["loss"], state.history[-1]["loss"]
    print(f"loss: {first:.3f} -> {last:.3f} over {args.rounds} rounds "
          f"({'improved' if last < first else 'NOT improved'})")


if __name__ == "__main__":
    main()
