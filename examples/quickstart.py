"""Quickstart: the paper end-to-end in ~a minute.

1. Estimate the ML-problem constants (L, sigma, G) by pre-training probes.
2. Optimize ALL GenQSGD parameters (K_0, K_n, B, gamma) with Algorithm 5.
3. Run GenQSGD (Algorithm 1) with the optimized parameters on the MNIST-like
   federated task and report test accuracy.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ConstantRule, EdgeSystem, GenQSGD, GenQSGDConfig, \
    MLProblemConstants
from repro.data.federated import partition_iid, sample_minibatch
from repro.data.synthetic import mnist_like
from repro.models import mlp
from repro.opt import ParamOptProblem, solve_param_opt


def main():
    print("== 1. data + pre-training constants ==")
    X, y = mnist_like()
    Xtr, ytr, Xte, yte = X[:50000], y[:50000], X[50000:], y[50000:]
    consts_d = mlp.estimate_constants(X, y, jax.random.PRNGKey(0),
                                      n_iters=120)
    print(f"   L={consts_d['L']:.3g} sigma={consts_d['sigma']:.3g} "
          f"G={consts_d['G']:.3g} f_gap={consts_d['f_gap']:.3g}")
    consts = MLProblemConstants(L=consts_d["L"], sigma=consts_d["sigma"],
                                G=consts_d["G"], f_gap=consts_d["f_gap"],
                                N=10)

    print("== 2. optimize (K, B, gamma) — Algorithm 5 ==")
    sys_ = EdgeSystem.paper_sec_vii(dim=mlp.PARAM_DIM)
    prob = ParamOptProblem(sys=sys_, consts=consts, T_max=1e5, C_max=0.25,
                           m="J")
    r = solve_param_opt(prob)
    print(f"   K0={r.K0}  Kn={r.Kn[0]}  B={r.B}  gamma={r.gamma:.4g}")
    print(f"   predicted energy {r.E:.4g} J, time {r.T:.4g} s, "
          f"error bound {r.C:.4g}")

    print("== 3. run GenQSGD with the optimized parameters ==")
    Xw, yw = partition_iid(Xtr, ytr, 10)
    data = (jnp.stack([jnp.asarray(a) for a in Xw]),
            jnp.stack([jnp.asarray(a) for a in yw]))
    K0 = min(r.K0, 400)  # cap for the quickstart
    cfg = GenQSGDConfig(K0=K0, Kn=tuple(int(k) for k in r.Kn), B=r.B,
                        step_rule=ConstantRule(float(r.gamma)),
                        s0=sys_.s0, sn=list(sys_.sn))
    alg = GenQSGD(mlp.loss, sample_minibatch, cfg)
    p0 = mlp.init_params(jax.random.PRNGKey(1))
    Xte_j, yte_j = jnp.asarray(Xte), jnp.asarray(yte)

    def eval_fn(p):
        return {"acc": mlp.accuracy(p, Xte_j, yte_j)}

    pf, hist = alg.run(p0, data, jax.random.PRNGKey(2), eval_fn=eval_fn,
                       eval_every=max(1, K0 // 8))
    for h in hist:
        print(f"   round {h['k0']:4d}  test acc {h['acc']:.3f}")
    print(f"== done: final accuracy {hist[-1]['acc']:.3f} "
          f"(K0 capped at {K0} of {r.K0}) ==")


if __name__ == "__main__":
    main()
