"""Quickstart: the paper end-to-end in ~a minute, entirely through repro.api.

1. Estimate the ML-problem constants (L, sigma, G) by pre-training probes.
2. Optimize ALL GenQSGD parameters (K_0, K_n, B, gamma) with Algorithm 5.
3. Run GenQSGD (Algorithm 1) with *exactly* the optimized parameters on the
   MNIST-like federated task and compare measured cost against predictions.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.api import EdgeSystem, MNISTTask, Scenario


def main():
    task = MNISTTask()

    print("== 1. data + pre-training constants ==")
    consts = task.estimate_constants(N=10, n_iters=120)
    print(f"   L={consts.L:.3g} sigma={consts.sigma:.3g} "
          f"G={consts.G:.3g} f_gap={consts.f_gap:.3g}")

    print("== 2. optimize (K, B, gamma) — Algorithm 5 ==")
    scenario = Scenario(system=EdgeSystem.paper_sec_vii(dim=task.dim),
                        consts=consts, T_max=1e5, C_max=0.25)
    plan = scenario.optimize()
    print("   " + plan.describe())

    print("== 3. run GenQSGD with the optimized parameters ==")
    report = scenario.run(plan, task=task, max_rounds=400,
                          eval_every=max(1, min(plan.K0, 400) // 8))
    for h in report.history:
        print(f"   round {h['k0']:4d}  test acc {h['test_acc']:.3f}")
    print(report.summary())
    print(f"== done: final accuracy {report.final_metrics['test_acc']:.3f} "
          f"({report.rounds} of {plan.K0} planned rounds) ==")


if __name__ == "__main__":
    main()
