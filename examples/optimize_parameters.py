"""The optimization framework as a standalone tool: solve the paper's
Problems 2/9 for any (T_max, C_max, system) and compare against PM-SGD /
FedAvg / PR-SGD parameterizations.

    PYTHONPATH=src python examples/optimize_parameters.py --cmax 0.25 --tmax 1e5
    PYTHONPATH=src python examples/optimize_parameters.py --tpu  # v5e fleet
"""
import argparse

from repro.core import EdgeSystem, MLProblemConstants
from repro.models import mlp
from repro.opt import (ParamOptProblem, fa_varmap, pm_varmap, pr_varmap,
                       solve_param_opt)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cmax", type=float, default=0.25)
    ap.add_argument("--tmax", type=float, default=1e5)
    ap.add_argument("--tpu", action="store_true",
                    help="use the TPU v5e fleet cost model instead of the "
                         "paper's Sec.-VII edge system")
    args = ap.parse_args()

    if args.tpu:
        sys_ = EdgeSystem.tpu_v5e_fleet(dim=405_000_000_000, n_groups=2,
                                        chips_per_group=256, s0=1024, sn=1024,
                                        flops_per_sample_step=6 * 405e9 * 4096)
        consts = MLProblemConstants(L=0.05, sigma=4.0, G=5.0, f_gap=3.0, N=2)
        args.cmax, args.tmax = 0.5, 3 * 24 * 3600.0
    else:
        sys_ = EdgeSystem.paper_sec_vii(dim=mlp.PARAM_DIM)
        consts = MLProblemConstants(L=0.084, sigma=33.18, G=33.63,
                                    f_gap=2.3, N=10)

    print(f"T_max={args.tmax:.3g}s  C_max={args.cmax}")
    print(f"{'algorithm':14s} {'K0':>7s} {'Kn':>5s} {'B':>5s} "
          f"{'gamma':>9s} {'E':>11s} {'T':>10s} {'C':>7s}  feasible")

    def show(name, prob):
        r = solve_param_opt(prob)
        print(f"{name:14s} {r.K0:7d} {int(r.Kn[0]):5d} {r.B:5d} "
              f"{(r.gamma or 0):9.4g} {r.E:11.4g} {r.T:10.4g} {r.C:7.4g}  "
              f"{r.feasible}")

    N = sys_.N
    show("GenQSGD (opt)", ParamOptProblem(sys=sys_, consts=consts,
                                          T_max=args.tmax, C_max=args.cmax,
                                          m="J"))
    show("Gen-C g=.01", ParamOptProblem(sys=sys_, consts=consts,
                                        T_max=args.tmax, C_max=args.cmax,
                                        m="C", gamma=0.01))
    show("PM-SGD", ParamOptProblem(sys=sys_, consts=consts, T_max=args.tmax,
                                   C_max=args.cmax, m="C", gamma=0.01,
                                   vmap=pm_varmap(N)))
    show("PR-SGD", ParamOptProblem(sys=sys_, consts=consts, T_max=args.tmax,
                                   C_max=args.cmax, m="C", gamma=0.01,
                                   vmap=pr_varmap(N)))
    if not args.tpu:
        show("FedAvg", ParamOptProblem(sys=sys_, consts=consts,
                                       T_max=args.tmax, C_max=args.cmax,
                                       m="C", gamma=0.01,
                                       vmap=fa_varmap(N, [6000.0] * N)))


if __name__ == "__main__":
    main()
