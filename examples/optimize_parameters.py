"""The optimization framework as a standalone tool: solve the paper's
Problems 2/9 for any (T_max, C_max, system) and compare against PM-SGD /
FedAvg / PR-SGD parameterizations — all through the repro.api facade.

    PYTHONPATH=src python examples/optimize_parameters.py --cmax 0.25 --tmax 1e5
    PYTHONPATH=src python examples/optimize_parameters.py --tpu  # v5e fleet
"""
import argparse

from repro.api import (ConstantRule, EdgeSystem, MLProblemConstants, Scenario)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cmax", type=float, default=0.25)
    ap.add_argument("--tmax", type=float, default=1e5)
    ap.add_argument("--tpu", action="store_true",
                    help="use the TPU v5e fleet cost model instead of the "
                         "paper's Sec.-VII edge system")
    args = ap.parse_args()

    if args.tpu:
        sys_ = EdgeSystem.tpu_v5e_fleet(dim=405_000_000_000, n_groups=2,
                                        chips_per_group=256, s0=1024, sn=1024,
                                        flops_per_sample_step=6 * 405e9 * 4096)
        consts = MLProblemConstants(L=0.05, sigma=4.0, G=5.0, f_gap=3.0, N=2)
        args.cmax, args.tmax = 0.5, 3 * 24 * 3600.0
    else:
        from repro.api import MNISTTask
        sys_ = EdgeSystem.paper_sec_vii(dim=MNISTTask.dim)
        consts = MLProblemConstants(L=0.084, sigma=33.18, G=33.63,
                                    f_gap=2.3, N=10)

    print(f"T_max={args.tmax:.3g}s  C_max={args.cmax}")
    print(f"{'algorithm':14s} {'K0':>7s} {'Kn':>5s} {'B':>5s} "
          f"{'gamma':>9s} {'E':>11s} {'T':>10s} {'C':>7s}  feasible")

    def show(name, scenario):
        p = scenario.optimize()
        print(f"{name:14s} {p.K0:7d} {p.Kn[0]:5d} {p.B:5d} "
              f"{p.gamma:9.4g} {p.predicted_E:11.4g} {p.predicted_T:10.4g} "
              f"{p.predicted_C:7.4g}  {p.feasible}")

    def scenario(family="genqsgd", step=None):
        return Scenario(system=sys_, consts=consts, T_max=args.tmax,
                        C_max=args.cmax, family=family, step=step)

    show("GenQSGD (opt)", scenario())
    show("Gen-C g=.01", scenario(step=ConstantRule(0.01)))
    show("PM-SGD", scenario("pm", ConstantRule(0.01)))
    show("PR-SGD", scenario("pr", ConstantRule(0.01)))
    if not args.tpu:
        show("FedAvg", scenario("fa", ConstantRule(0.01)))


if __name__ == "__main__":
    main()
