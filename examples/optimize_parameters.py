"""The optimization framework as a standalone tool: solve the paper's
Problems 2/9 for any (T_max, C_max, system) and compare against PM-SGD /
FedAvg / PR-SGD parameterizations — all through the repro.api facade.

Every comparison is one ``sweep_scenarios`` call: the scenarios group by
(m, family) structure and each group solves through the batched jnp GP
engine.  ``--pareto`` additionally sweeps the C_max budget axis and prints
the non-dominated (E, T, C) frontier.

    PYTHONPATH=src python examples/optimize_parameters.py --cmax 0.25 --tmax 1e5
    PYTHONPATH=src python examples/optimize_parameters.py --pareto
    PYTHONPATH=src python examples/optimize_parameters.py --tpu  # v5e fleet
"""
import argparse

from repro.api import (ConstantRule, EdgeSystem, MLProblemConstants, Scenario,
                       sweep_scenarios)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cmax", type=float, default=0.25)
    ap.add_argument("--tmax", type=float, default=1e5)
    ap.add_argument("--backend", default="auto",
                    help="GP solver backend: auto | jnp | numpy")
    ap.add_argument("--pareto", action="store_true",
                    help="sweep the C_max axis too and print the Pareto "
                         "front of (E, T, C)")
    ap.add_argument("--tpu", action="store_true",
                    help="use the TPU v5e fleet cost model instead of the "
                         "paper's Sec.-VII edge system")
    args = ap.parse_args()

    if args.tpu:
        sys_ = EdgeSystem.tpu_v5e_fleet(dim=405_000_000_000, n_groups=2,
                                        chips_per_group=256, s0=1024, sn=1024,
                                        flops_per_sample_step=6 * 405e9 * 4096)
        consts = MLProblemConstants(L=0.05, sigma=4.0, G=5.0, f_gap=3.0, N=2)
        args.cmax, args.tmax = 0.5, 3 * 24 * 3600.0
    else:
        from repro.api import MNISTTask
        sys_ = EdgeSystem.paper_sec_vii(dim=MNISTTask.dim)
        consts = MLProblemConstants(L=0.084, sigma=33.18, G=33.63,
                                    f_gap=2.3, N=10)

    def scenario(family="genqsgd", step=None):
        return Scenario(system=sys_, consts=consts, T_max=args.tmax,
                        C_max=args.cmax, family=family, step=step)

    table = [("GenQSGD (opt)", scenario()),
             ("Gen-C g=.01", scenario(step=ConstantRule(0.01))),
             ("PM-SGD", scenario("pm", ConstantRule(0.01))),
             ("PR-SGD", scenario("pr", ConstantRule(0.01)))]
    if not args.tpu:
        table.append(("FedAvg", scenario("fa", ConstantRule(0.01))))

    rep = sweep_scenarios([s for _, s in table], names=[n for n, _ in table],
                          backend=args.backend)
    print(f"T_max={args.tmax:.3g}s  C_max={args.cmax}  "
          f"[{rep.backend} backend, {rep.n_groups} structure groups, "
          f"{rep.wall_time_s:.1f}s]")
    print(f"{'algorithm':14s} {'K0':>7s} {'Kn':>5s} {'B':>5s} "
          f"{'gamma':>9s} {'E':>11s} {'T':>10s} {'C':>7s}  feasible")
    for row in rep:
        print(f"{row['name']:14s} {row['K0']:7d} {row['Kn'][0]:5d} "
              f"{row['B']:5d} {row['gamma']:9.4g} {row['E']:11.4g} "
              f"{row['T']:10.4g} {row['C']:7.4g}  {row['feasible']}")

    if args.pareto:
        grid = [args.cmax * f for f in (0.8, 0.9, 1.0, 1.2, 1.6, 2.4)]
        front = scenario().sweep(over={"cmax": grid},
                                 backend=args.backend).pareto_front()
        print(f"\nPareto front over C_max in {[round(c, 4) for c in grid]} "
              f"(jointly optimized step size):")
        for row in front:
            print(f"  C_max={row['C_max']:<8.4g} E={row['E']:<12.4g} "
                  f"T={row['T']:<12.4g} C={row['C']:.4g}")


if __name__ == "__main__":
    main()
