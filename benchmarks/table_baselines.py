"""Baseline comparison table at the default operating point
(C_max = 0.25, T_max = 1e5): GenQSGD (C/E/D/O) vs PM/FA/PR × {opt, fix} —
plus automatic validation of the paper's qualitative claims."""
from __future__ import annotations

import time

from .common import (ALL_ALGOS, RESULTS, get_constants, paper_system,
                     run_algorithm, write_csv)


def run(tag="table_baselines"):
    consts = get_constants()
    sys_ = paper_system()
    rows, t0 = [], time.time()
    for name in ALL_ALGOS:
        r = run_algorithm(name, sys_, consts, T_max=1e5, C_max=0.25)
        rows.append(r)
        print(f"  {name:12s} E={r['E']:.4g} T={r['T']:.4g} C={r['C']:.4g} "
              f"feasible={r['feasible']}", flush=True)
    path = write_csv(f"{RESULTS}/benchmarks/{tag}.csv", rows,
                     ["name", "K0", "Kn", "B", "gamma", "E", "T", "C",
                      "feasible", "dt"])

    by = {r["name"]: r for r in rows}
    feas = lambda n: by[n]["feasible"]
    E = lambda n: by[n]["E"]
    checks = {
        # Lemma 4 + Sec. VII: optimizing the step size can only help
        "Gen-O <= Gen-C": E("Gen-O") <= E("Gen-C") * 1.001,
        "Gen-O <= Gen-E": E("Gen-O") <= E("Gen-E") * 1.001,
        "Gen-O <= Gen-D": E("Gen-O") <= E("Gen-D") * 1.001,
        # Gen-m beats the m-baselines that are feasible (more free params)
        "Gen-C <= PM-C-opt": (not feas("PM-C-opt"))
        or E("Gen-C") <= E("PM-C-opt") * 1.001,
        "Gen-C <= PR-C-opt": (not feas("PR-C-opt"))
        or E("Gen-C") <= E("PR-C-opt") * 1.001,
        "Gen-E <= PM-E-opt": (not feas("PM-E-opt"))
        or E("Gen-E") <= E("PM-E-opt") * 1.001,
        "Gen-D <= PM-D-opt": (not feas("PM-D-opt"))
        or E("Gen-D") <= E("PM-D-opt") * 1.001,
        # opt beats fix wherever both are feasible
        "PM-C-opt <= PM-C-fix": (not (feas("PM-C-opt") and feas("PM-C-fix")))
        or E("PM-C-opt") <= E("PM-C-fix") * 1.001,
        "PR-C-opt <= PR-C-fix": (not (feas("PR-C-opt") and feas("PR-C-fix")))
        or E("PR-C-opt") <= E("PR-C-fix") * 1.001,
    }
    n_pass = sum(checks.values())
    for k, v in checks.items():
        print(f"  [{'PASS' if v else 'FAIL'}] {k}")
    return {"rows": len(rows), "csv": path,
            "derived": f"{n_pass}/{len(checks)}_claims",
            "dt": time.time() - t0, "checks": checks}


if __name__ == "__main__":
    print(run())
