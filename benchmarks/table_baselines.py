"""Baseline comparison table at the default operating point
(C_max = 0.25, T_max = 1e5): GenQSGD (C/E/D/O) vs PM/FA/PR × {opt, fix} —
plus automatic validation of the paper's qualitative claims.

The 13 ``-opt`` columns solve as one heterogeneous sweep (grouped into
batched GIA calls per (m, family) structure); the ``-fix`` columns are
closed-form K0 bisections on preset parameters.
"""
from __future__ import annotations

import time

from .common import (ALL_ALGOS, RESULTS, get_constants, make_scenario,
                     paper_system, run_algorithm, sweep_records, write_csv)


def run(tag="table_baselines", backend="auto"):
    consts = get_constants()
    sys_ = paper_system()
    t0 = time.time()
    opt_names = [n for n in ALL_ALGOS if not n.endswith("-fix")]
    scenarios = [make_scenario(n, sys_, consts, T_max=1e5, C_max=0.25)[0]
                 for n in opt_names]
    opt_rows, _ = sweep_records(scenarios, opt_names, backend=backend)
    by_name = {r["name"]: r for r in opt_rows}
    rows = []
    for name in ALL_ALGOS:
        r = by_name.get(name)
        if r is None:   # -fix baselines: no GIA, just the K0 bisection
            r = run_algorithm(name, sys_, consts, T_max=1e5, C_max=0.25)
        rows.append(r)
        print(f"  {name:12s} E={r['E']:.4g} T={r['T']:.4g} C={r['C']:.4g} "
              f"feasible={r['feasible']}", flush=True)
    path = write_csv(f"{RESULTS}/benchmarks/{tag}.csv", rows,
                     ["name", "K0", "Kn", "B", "gamma", "E", "T", "C",
                      "feasible", "dt"])

    by = {r["name"]: r for r in rows}
    feas = lambda n: by[n]["feasible"]
    E = lambda n: by[n]["E"]
    checks = {
        # Lemma 4 + Sec. VII: optimizing the step size can only help
        "Gen-O <= Gen-C": E("Gen-O") <= E("Gen-C") * 1.001,
        "Gen-O <= Gen-E": E("Gen-O") <= E("Gen-E") * 1.001,
        "Gen-O <= Gen-D": E("Gen-O") <= E("Gen-D") * 1.001,
        # Gen-m beats the m-baselines that are feasible (more free params)
        "Gen-C <= PM-C-opt": (not feas("PM-C-opt"))
        or E("Gen-C") <= E("PM-C-opt") * 1.001,
        "Gen-C <= PR-C-opt": (not feas("PR-C-opt"))
        or E("Gen-C") <= E("PR-C-opt") * 1.001,
        "Gen-E <= PM-E-opt": (not feas("PM-E-opt"))
        or E("Gen-E") <= E("PM-E-opt") * 1.001,
        "Gen-D <= PM-D-opt": (not feas("PM-D-opt"))
        or E("Gen-D") <= E("PM-D-opt") * 1.001,
        # opt beats fix wherever both are feasible
        "PM-C-opt <= PM-C-fix": (not (feas("PM-C-opt") and feas("PM-C-fix")))
        or E("PM-C-opt") <= E("PM-C-fix") * 1.001,
        "PR-C-opt <= PR-C-fix": (not (feas("PR-C-opt") and feas("PR-C-fix")))
        or E("PR-C-opt") <= E("PR-C-fix") * 1.001,
    }
    n_pass = sum(checks.values())
    for k, v in checks.items():
        print(f"  [{'PASS' if v else 'FAIL'}] {k}")
    return {"rows": len(rows), "csv": path,
            "derived": f"{n_pass}/{len(checks)}_claims",
            "dt": time.time() - t0, "checks": checks}


if __name__ == "__main__":
    print(run())
