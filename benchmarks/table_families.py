"""Cross-family benchmark: GenQSGD vs GQFedWAvg on the Fig.-5 grid.

Expands the Fig.-5 (C_max, step-rule) grid over both shipped algorithm
families (:mod:`repro.families`) and solves everything through the fused
device-resident backend (``jnp-fused``: one compiled program per
(m, family) structure signature, surrogate refresh included).  Reports per
family the feasible count, the energy/time Pareto front, and the
minimum-energy plan per budget — the cross-family trade-off the GQFedWAvg
generalization exposes (momentum tightens the drift term's budget share;
the rotated codec pays pow2-padded messages + a seed word for
input-independent quantization error).

Writes ``BENCH_families.json`` at the repo root (schema mirroring
``BENCH_opt.json``: grid size, warm solves/sec, per-family Pareto rows) and
a tidy CSV under ``results/benchmarks/``.

    PYTHONPATH=src python -m benchmarks.table_families           # full grid
    PYTHONPATH=src python -m benchmarks.table_families --smoke   # CI subset
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import time

from repro.obs.bench import write_bench

from .common import RESULTS, get_constants, make_scenario, paper_system, \
    write_csv
from .opt_bench import _enable_compilation_cache

BENCH_JSON = os.environ.get("REPRO_BENCH_FAMILIES_JSON",
                            "BENCH_families.json")
FAMILY_GRID = ("genqsgd", "gqfedwavg")
ALGOS = ("Gen-C", "Gen-E", "Gen-D", "Gen-O")
C_GRID = (0.2, 0.25, 0.3, 0.4, 0.6)


def _scenarios(sys_, consts, algos, c_grid):
    scns, names = [], []
    for family in FAMILY_GRID:
        for cmax in c_grid:
            for algo in algos:
                scn, _ = make_scenario(algo, sys_, consts, T_max=1e5,
                                       C_max=cmax)
                scns.append(dataclasses.replace(scn, family=family))
                names.append(f"{family}/{algo}")
    return scns, names


def _family_summary(rows):
    feas = [r for r in rows if r["feasible"]]
    front = sorted(({"name": r["name"], "C_max": r["C_max"], "m": r["m"],
                     "E": r["E"], "T": r["T"], "C": r["C"]}
                    for r in feas), key=lambda r: r["E"])
    # non-dominated in (E, T) among feasible points
    pareto, best_T = [], float("inf")
    for r in front:
        if r["T"] < best_T:
            pareto.append(r)
            best_T = r["T"]
    min_e = {}
    for r in feas:
        c = r["C_max"]
        if c not in min_e or r["E"] < min_e[c]["E"]:
            min_e[c] = {"E": r["E"], "T": r["T"], "m": r["m"]}
    return {"points": len(rows), "feasible": len(feas),
            "pareto_ET": pareto, "min_E_per_budget": min_e}


def run(tag="table_families", smoke=False):
    from repro.api import sweep_scenarios

    cache_dir = _enable_compilation_cache()
    consts = get_constants()
    sys_ = paper_system()
    algos = ("Gen-C", "Gen-O") if smoke else ALGOS
    c_grid = C_GRID[:2] if smoke else C_GRID
    if smoke:
        tag = f"{tag}_smoke"
    scns, names = _scenarios(sys_, consts, algos, c_grid)
    n = len(scns)

    t0 = time.time()
    sweep_scenarios(scns, names=names, backend="jnp-fused")
    t_cold = time.time() - t0
    t0 = time.time()
    rep = sweep_scenarios(scns, names=names, backend="jnp-fused")
    t_warm = time.time() - t0

    by_family = {f: [r for r in rep.rows if r["family"] == f]
                 for f in FAMILY_GRID}
    families = {f: _family_summary(rows) for f, rows in by_family.items()}

    print(f"  {n} points ({len(FAMILY_GRID)} families x {len(algos)} algos "
          f"x {len(c_grid)} budgets), {rep.n_groups} structure groups, "
          f"warm {t_warm:.2f}s ({n / t_warm:.2f} solves/s)")
    for f in FAMILY_GRID:
        s = families[f]
        print(f"  {f:10s} feasible {s['feasible']}/{s['points']}, "
              f"Pareto(E,T): " + " ".join(
                  f"[{p['m']}@{p['C_max']}: E={p['E']:.4g} T={p['T']:.4g}]"
                  for p in s["pareto_ET"][:4]))
    ratios = {}
    for c in c_grid:
        eg = families["genqsgd"]["min_E_per_budget"].get(c)
        ew = families["gqfedwavg"]["min_E_per_budget"].get(c)
        if eg and ew:
            ratios[str(c)] = round(ew["E"] / eg["E"], 4)
            print(f"  C_max={c}: min-E gqfedwavg/genqsgd = {ratios[str(c)]}")

    csv_rows = [{**r, "Kn": "|".join(str(k) for k in r["Kn"])}
                for r in rep.rows]
    path = write_csv(f"{RESULTS}/benchmarks/{tag}.csv", csv_rows,
                     ["name", "family", "m", "C_max", "K0", "Kn", "B",
                      "gamma", "E", "T", "C", "feasible", "iterations"])
    write_bench(BENCH_JSON, "families", {
        "grid": {"points": n, "families": list(FAMILY_GRID),
                 "algos": list(algos), "c_grid": list(c_grid)},
        "backend": {"name": "jnp-fused", "structure_groups": rep.n_groups,
                    "cold_s": round(t_cold, 2), "warm_s": round(t_warm, 2),
                    "warm_solves_per_s": round(n / t_warm, 3)},
        "families": families,
        "min_E_ratio_gqfedwavg_over_genqsgd": ratios,
        "compilation_cache_dir": cache_dir,
    }, smoke=smoke)
    return {"rows": n, "csv": path, "json": BENCH_JSON,
            "derived": "_".join(f"{f}:{families[f]['feasible']}/"
                                f"{families[f]['points']}"
                                for f in FAMILY_GRID),
            "dt": round(t_cold + t_warm, 2)}


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="8-point grid for CI smoke runs")
    args = ap.parse_args()
    print(run(smoke=args.smoke))
