"""Client sampling: the expected-energy-vs-N participation frontier.

For a ladder of worker counts N, solve the same scenario twice — full
participation vs a free-cohort ``uniform()`` sampling model whose cohort
size ``S`` is a GP decision variable — and record the frontier
``E_full(N)`` vs ``E_sampled(N)`` with the chosen ``S``.

The regime is chosen so sampling *should* win (and the bench asserts it
does): the paper's Sec.-VII system made homogeneous (``F_ratio=1``) with a
10x compute-energy coefficient (``alpha_n = 2e-27``), where per-step
energy is high enough that amortizing fixed round costs over many local
steps stops paying — the optimizer caps ``K_n`` at 1 and a strict
sub-cohort strictly lowers expected energy.  On the paper's original
heterogeneous system full participation genuinely dominates (cheap
workers + K-amortization), which the honesty note in ROADMAP.md records.

Hard assertions:

  * every sampled solve is feasible + converged, picks ``S < N``, and
    strictly lowers expected energy vs the full solve of the same N;
  * the whole grid pays **<= 1 fused trace per distinct structure
    signature** (the free-S conv-block layouts batch and fuse like any
    other problem).

Results land in ``BENCH_sampling.json`` at the repo root.

    PYTHONPATH=src python -m benchmarks.sampling_bench           # full grid
    PYTHONPATH=src python -m benchmarks.sampling_bench --smoke   # CI smoke
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import numpy as np

from repro.api import (ConstantRule, EdgeSystem, MLProblemConstants,
                       Scenario, sweep_scenarios, uniform)
from repro.obs.bench import write_bench
from repro.opt import gia_jax

from .opt_bench import _enable_compilation_cache

import os

BENCH_JSON = os.environ.get("REPRO_BENCH_JSON", "BENCH_sampling.json")

#: Sec.-VII ML-problem constants (N is re-stamped per grid point)
CONSTS = MLProblemConstants(L=0.084, sigma=33.18, G=33.63, f_gap=2.3, N=4)

FULL_GRID = (4, 8, 16, 32)
SMOKE_GRID = (4, 8)


def hot_system(N: int, dim: int = 1024) -> EdgeSystem:
    """Homogeneous Sec.-VII system with 10x compute energy (alpha=2e-27):
    the high-compute-energy regime where partial participation wins."""
    return dataclasses.replace(
        EdgeSystem.paper_sec_vii(dim=dim, N=N, F_ratio=1.0),
        alphan=np.full(N, 2e-27))


def scenarios_for(grid, sampling):
    return [Scenario(system=hot_system(N), consts=dataclasses.replace(
                         CONSTS, N=N),
                     T_max=1e7, C_max=0.25, step=ConstantRule(3e-4),
                     sampling=sampling)
            for N in grid]


def run(smoke: bool) -> dict:
    cache_dir = _enable_compilation_cache()
    grid = SMOKE_GRID if smoke else FULL_GRID
    scns = scenarios_for(grid, "full") + scenarios_for(grid, uniform())
    traces0 = sum(gia_jax.TRACE_COUNTS.values())
    t0 = time.time()
    rep = sweep_scenarios(scns, backend="jnp-fused")
    wall = time.time() - t0
    new_traces = sum(gia_jax.TRACE_COUNTS.values()) - traces0
    # one fused program per structure signature across the whole grid
    # (<=: the persistent XLA cache may have pre-paid some)
    assert new_traces <= rep.n_groups, (new_traces, rep.n_groups)

    rows = []
    full_rows, samp_rows = rep.rows[:len(grid)], rep.rows[len(grid):]
    for N, rf, rs in zip(grid, full_rows, samp_rows):
        assert rf["feasible"] and rs["feasible"] and rs["converged"]
        assert rs["S"] is not None and rs["S"] < N, (N, rs["S"])
        assert rs["E"] < rf["E"], (N, rs["E"], rf["E"])
        rows.append({
            "N": N, "S": rs["S"],
            "E_full": round(rf["E"], 2), "E_sampled": round(rs["E"], 2),
            "saving_pct": round(100.0 * (1.0 - rs["E"] / rf["E"]), 1),
            "K0_full": rf["K0"], "K0_sampled": rs["K0"],
        })
        print(f"  N={N:>3}: full E={rf['E']:.5g} (K0={rf['K0']}) | "
              f"S={rs['S']} E={rs['E']:.5g} (K0={rs['K0']}) "
              f"-> {rows[-1]['saving_pct']}% saved")

    bench = write_bench(BENCH_JSON, "sampling", {
        "regime": "paper_sec_vii(F_ratio=1) + alpha_n=2e-27, "
                  "gamma=3e-4, C_max=0.25, T_max=1e7",
        "grid": list(grid), "frontier": rows,
        "wall_s": round(wall, 2), "n_groups": rep.n_groups,
        "new_fused_traces": new_traces, "backend": rep.backend,
        "xla_cache": cache_dir,
    }, smoke=smoke)
    print(f"wrote {BENCH_JSON} ({rep.n_groups} signatures, "
          f"{new_traces} new fused traces, {wall:.1f}s)")
    return bench


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="CI-sized grid")
    run(ap.parse_args().smoke)
