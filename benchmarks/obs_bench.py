"""Observability overhead + observer-effect gate (``repro.obs``).

Two workloads, each timed with observability disabled and enabled:

  * ``fig5`` — warm fused solves of a Fig.-5 (budget, algo) grid through
    ``sweep_scenarios(backend="jnp-fused")``, the PlanServer's hot path;
  * ``train`` — the reference training loop (``Scenario.run``) on a
    seeded quadratic task under the edge-fleet fault model.

Hard assertions (the ISSUE-10 acceptance bar):

  * **<2% overhead** on both paths, measured as the median over ``reps``
    ABBA blocks (off, on, on, off) of the per-block on/off time ratio —
    adjacent-in-time pairing cancels machine throughput drift that
    whole-run min-of-reps cannot (a 2 ms floor absorbs timer granularity
    on sub-100ms paths).  **Full mode only** (the serve_bench pattern):
    every ``Scenario.run`` pays a ~190 ms jit-trace whose run-to-run
    jitter is several percent of a smoke-sized sample, so the smoke run
    records the ratios (with a loose 25% sanity ceiling) instead of
    gating them — the deterministic observer-effect assertions below
    still run in both modes;
  * **observer effect = none**: the Plan, the RunReport (modulo its
    wall-clock field — real time differs between *any* two runs), and the
    FaultTrace are bit-identical with obs enabled vs disabled, and the
    fused engine's per-signature trace counter does not move when obs is
    flipped on over a warm cache (zero extra compiles);
  * the PlanServer span trace exports as Chrome-trace JSON containing the
    queue -> batch -> solve span hierarchy (open the artifact at
    ui.perfetto.dev).

Artifacts: ``BENCH_obs.json`` at the repo root (uniform bench envelope)
and ``trace_planserver.json`` under the obs artifact dir.

    PYTHONPATH=src python -m benchmarks.obs_bench           # full reps
    PYTHONPATH=src python -m benchmarks.obs_bench --smoke   # CI smoke
"""
from __future__ import annotations

import argparse
import dataclasses
import gc
import os
import statistics
import time

from repro import obs
from repro.api import (ConstantRule, EdgeSystem, MLProblemConstants,
                       QuadraticTask, Scenario, edge_faults,
                       sweep_scenarios)
from repro.obs.bench import write_bench
from repro.opt import gia_jax
from repro.serve import PlanServer

from .common import get_constants, make_scenario, paper_system
from .opt_bench import _enable_compilation_cache

BENCH_JSON = os.environ.get("REPRO_BENCH_JSON", "BENCH_obs.json")

#: overhead gate: enabled must stay within 2% (+2ms timer floor) of disabled
OVERHEAD_FRAC = 0.02
OVERHEAD_ABS_S = 2e-3

FULL = dict(algos=("Gen-C", "Gen-E", "Gen-D", "Gen-O"),
            c_grid=(0.2, 0.25, 0.3, 0.4, 0.6), reps=9, rounds=300,
            task_dim=2048, local_k=16)
SMOKE = dict(algos=("Gen-C", "Gen-O"), c_grid=(0.25, 0.4), reps=3,
             rounds=360, task_dim=512, local_k=8)

#: smoke-mode sanity ceiling: overhead is recorded, not gated, but a
#: blow-up past this still fails CI (catches e.g. an accidental sync)
SMOKE_CEILING = 0.25

N_TRAIN = 4
TRAIN_CONSTS = MLProblemConstants(L=0.084, sigma=33.18, G=33.63,
                                  f_gap=2.3, N=N_TRAIN)
TRAIN_FAULTS = edge_faults(straggler_prob=0.3, straggler_factor=4.0,
                           crash_prob=0.1, crash_rounds=2,
                           corrupt_prob=0.05, deadline_slack=1.5)


def _strip_wall(report):
    """RunReport modulo its one genuinely non-deterministic field."""
    return dataclasses.replace(report, wall_time_s=0.0)


def _canon(rows):
    """Sweep rows with numpy leaves lowered to plain lists/scalars, so
    two row lists compare with ``==`` (ndarray __eq__ is elementwise)."""
    return [{k: (v.tolist() if hasattr(v, "tolist") else v)
             for k, v in r.items()} for r in rows]


def _ab_timings(fn, reps):
    """Drift-robust A/B timings: (min_off_s, min_on_s, median_ratio).

    Each rep is one ABBA block (off, on, on, off); the block ratio
    ``(on1+on2) / (off1+off2)`` cancels linear throughput drift (thermal,
    noisy neighbours) to first order because both modes sample the same
    window.  The gate runs on the **median** block ratio — robust to a
    single slow block in either direction — while the min timings are
    reported for context."""
    t_off, t_on, ratios = [], [], []
    for _ in range(reps):
        block = {False: [], True: []}
        for on in (False, True, True, False):
            obs.enable() if on else obs.disable()
            gc.collect()             # keep GC pauses out of both samples
            t0 = time.perf_counter()
            fn()
            block[on].append(time.perf_counter() - t0)
        t_off += block[False]
        t_on += block[True]
        ratios.append(sum(block[True]) / sum(block[False]))
    obs.disable()
    return min(t_off), min(t_on), statistics.median(ratios)


def _gate(name, off_s, on_s, ratio, smoke):
    overhead = ratio - 1.0
    mode = "recorded, smoke" if smoke else "gated"
    print(f"  {name:6s} off {off_s * 1e3:8.1f}ms  on {on_s * 1e3:8.1f}ms  "
          f"overhead {overhead:+.2%} (median block ratio, {mode})")
    if smoke:
        # smoke samples are dominated by per-run jit-trace jitter (see
        # module docstring): record the ratio, only catch blow-ups
        assert overhead <= SMOKE_CEILING, \
            f"{name}: obs overhead {overhead:.2%} past even the smoke " \
            f"sanity ceiling ({SMOKE_CEILING:.0%})"
    else:
        assert overhead <= OVERHEAD_FRAC + OVERHEAD_ABS_S / off_s, \
            f"{name}: obs overhead {overhead:.2%} breaches the " \
            f"{OVERHEAD_FRAC:.0%} gate (off {off_s:.4f}s, on {on_s:.4f}s)"
    return overhead


def _fig5_overhead(cfg, smoke):
    consts = get_constants()
    sys_ = paper_system()
    scns = [make_scenario(a, sys_, consts, T_max=1e5, C_max=c)[0]
            for c in cfg["c_grid"] for a in cfg["algos"]]

    sweep = lambda: sweep_scenarios(scns, backend="jnp-fused")
    sweep()                                    # pay every compile up front
    traces_warm = sum(gia_jax.TRACE_COUNTS.values())

    off_s, on_s, ratio = _ab_timings(sweep, cfg["reps"])

    # observer effect on the engine: flipping obs on over a warm cache
    # must not re-trace anything (zero extra compiles)
    new_traces = sum(gia_jax.TRACE_COUNTS.values()) - traces_warm
    assert new_traces == 0, \
        f"enabling obs re-traced the fused engine ({new_traces} new)"

    # observer effect on results: identical plans either way
    obs.disable()
    rows_off = sweep_scenarios(scns, backend="jnp-fused").rows
    obs.enable(reset=True)
    rows_on = sweep_scenarios(scns, backend="jnp-fused").rows
    obs.disable()
    assert _canon(rows_on) == _canon(rows_off), \
        "observer effect on sweep rows"

    return {"points": len(scns), "reps": cfg["reps"],
            "off_s": round(off_s, 4), "on_s": round(on_s, 4),
            "overhead": round(_gate("fig5", off_s, on_s, ratio, smoke), 4),
            "new_fused_traces": new_traces}


def _train_overhead(cfg, smoke):
    # gamma small enough that Kn local steps on the task stay finite — a
    # diverged run reports err=NaN and NaN breaks the == identity checks
    scn = Scenario(system=EdgeSystem.paper_sec_vii(dim=64, N=N_TRAIN),
                   consts=TRAIN_CONSTS, T_max=1e6, C_max=1.0,
                   step=ConstantRule(0.001), faults=TRAIN_FAULTS)
    # a realistically-sized round: the GP picks Kn=1 for this toy system,
    # which makes every round a single ~0.5ms dispatch — a degenerate
    # denominator that grades us-scale instrumentation as percent-scale
    # overhead.  Grade against a round that does real local work instead
    # (paper regimes run tens-to-hundreds of local steps per round).
    plan = dataclasses.replace(scn.optimize("C"),
                               Kn=(cfg["local_k"],) * N_TRAIN)
    task = QuadraticTask(dim=cfg["task_dim"], per_worker=256)
    rounds = cfg["rounds"]
    run = lambda: scn.run(plan, task=task, seed=3, max_rounds=rounds)
    run()                                                        # warm-up

    off_s, on_s, ratio = _ab_timings(run, cfg["reps"])

    # bit-identity: report + fault trace modulo the wall-clock field
    obs.disable()
    rep_off = run()
    obs.enable(reset=True)
    rep_on = run()
    obs.disable()
    assert _strip_wall(rep_on) == _strip_wall(rep_off), \
        "observer effect on RunReport/FaultTrace"
    drift = rep_on.drift()
    assert drift == rep_off.drift() and len(drift.rows) == rep_on.rounds

    return {"rounds": rep_on.rounds, "reps": cfg["reps"],
            "off_s": round(off_s, 4), "on_s": round(on_s, 4),
            "overhead": round(_gate("train", off_s, on_s, ratio, smoke), 4),
            "cumulative_drift": drift.cumulative()}


def _planserver_trace(cfg):
    """One obs-enabled serving burst -> Chrome-trace artifact with the
    queue -> batch -> solve span hierarchy."""
    consts = get_constants()
    sys_ = paper_system()
    scns = [make_scenario(a, sys_, consts, T_max=1e5, C_max=c)[0]
            for c in cfg["c_grid"] for a in cfg["algos"]]
    obs.enable(reset=True)
    try:
        with PlanServer(max_batch=8, window_s=0.02) as srv:
            handles = [srv.submit(s) for s in scns + scns]  # repeats -> hits
            for h in handles:
                h.result(timeout=600)
            stats = srv.stats()
    finally:
        obs.disable()

    events = obs.TRACER.to_chrome()["traceEvents"]
    names = {e["name"] for e in events}
    for want in ("planserver.queue", "planserver.solve", "planserver.batch",
                 "gia.fused_dispatch"):
        assert want in names, f"missing span {want!r} in {sorted(names)}"
    # async queue/solve pairs must be balanced or Perfetto drops the track
    for nm in ("planserver.queue", "planserver.solve"):
        b = sum(1 for e in events if e["name"] == nm and e["ph"] == "b")
        e_ = sum(1 for e in events if e["name"] == nm and e["ph"] == "e")
        assert b == e_ > 0, (nm, b, e_)

    path = obs.artifact_path("trace_planserver.json")
    obs.TRACER.save(path)
    print(f"  planserver trace: {len(events)} events -> {path} "
          f"(open at ui.perfetto.dev)")
    return {"events": len(events), "path": path,
            "requests": len(handles), "hit_rate": stats["hit_rate"],
            "queue_depth": stats["queue_depth"],
            "inflight": stats["inflight"]}


def run(smoke=False):
    cfg = SMOKE if smoke else FULL
    _enable_compilation_cache()
    obs.disable()                    # measure from a known-off baseline
    t0 = time.time()
    fig5 = _fig5_overhead(cfg, smoke)
    train = _train_overhead(cfg, smoke)
    trace = _planserver_trace(cfg)
    bench = write_bench(BENCH_JSON, "obs", {
        "overhead_gate": {"frac": OVERHEAD_FRAC, "abs_s": OVERHEAD_ABS_S},
        "fig5": fig5,
        "train": train,
        "planserver_trace": trace,
        "wall_s": round(time.time() - t0, 2),
    }, smoke=smoke)
    print(f"wrote {BENCH_JSON} (fig5 {fig5['overhead']:+.2%}, "
          f"train {train['overhead']:+.2%}"
          + (", recorded only" if smoke
             else f", both under {OVERHEAD_FRAC:.0%}") + ")")
    return bench


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="CI-sized run")
    print_keys = run(ap.parse_args().smoke)
