"""Fig. 5: minimum energy cost of Gen-C/E/D/O versus C_max (a) and T_max (b)
— the time/energy/convergence-error trade-off surface."""
from __future__ import annotations

import time

from .common import RESULTS, get_constants, paper_system, run_algorithm, \
    write_csv

ALGOS = ("Gen-C", "Gen-E", "Gen-D", "Gen-O")
C_GRID = (0.2, 0.25, 0.3, 0.4, 0.6)
# low end chosen so the time constraint actually binds (T* ~ 6-10e3 s at the
# measured constants); the paper's 0.5-3e5 grid leaves it slack everywhere
T_GRID = (6e3, 8e3, 1.2e4, 5e4, 1e5)


def run(tag="fig5"):
    consts = get_constants()
    sys_ = paper_system()
    rows = []
    t0 = time.time()
    for cmax in C_GRID:
        for name in ALGOS:
            r = run_algorithm(name, sys_, consts, T_max=1e5, C_max=cmax)
            rows.append({"panel": "a", "x": cmax, **r})
    for tmax in T_GRID:
        for name in ALGOS:
            r = run_algorithm(name, sys_, consts, T_max=tmax, C_max=0.25)
            rows.append({"panel": "b", "x": tmax, **r})
    path = write_csv(f"{RESULTS}/benchmarks/{tag}.csv", rows,
                     ["panel", "x", "name", "K0", "Kn", "B", "gamma", "E",
                      "T", "C", "feasible"])
    final = [r for r in rows if r["panel"] == "a" and r["x"] == 0.25]
    gen_o = next(r["E"] for r in final if r["name"] == "Gen-O")
    return {"rows": len(rows), "csv": path, "derived": gen_o,
            "dt": time.time() - t0}


if __name__ == "__main__":
    print(run())
