"""Fig. 5: minimum energy cost of Gen-C/E/D/O versus C_max (a) and T_max (b)
— the time/energy/convergence-error trade-off surface.

Runs as one :func:`repro.api.sweep_scenarios` call: the 40 (budget, algo)
points group into four batched GIA paths (one per objective m) instead of
40 sequential solves, and the report's ``pareto_front()`` gives the
non-dominated (E, T, C) frontier of the whole surface.
"""
from __future__ import annotations

import time

from .common import (RESULTS, get_constants, make_scenario, paper_system,
                     sweep_records, write_csv)

ALGOS = ("Gen-C", "Gen-E", "Gen-D", "Gen-O")
C_GRID = (0.2, 0.25, 0.3, 0.4, 0.6)
# low end chosen so the time constraint actually binds (T* ~ 6-10e3 s at the
# measured constants); the paper's 0.5-3e5 grid leaves it slack everywhere
T_GRID = (6e3, 8e3, 1.2e4, 5e4, 1e5)


def run(tag="fig5", backend="auto"):
    consts = get_constants()
    sys_ = paper_system()
    t0 = time.time()
    scenarios, names, meta = [], [], []
    for panel, budgets in (("a", [(1e5, c) for c in C_GRID]),
                           ("b", [(t, 0.25) for t in T_GRID])):
        for tmax, cmax in budgets:
            for name in ALGOS:
                scn, _ = make_scenario(name, sys_, consts, T_max=tmax,
                                       C_max=cmax)
                scenarios.append(scn)
                names.append(name)
                meta.append({"panel": panel,
                             "x": cmax if panel == "a" else tmax})
    recs, rep = sweep_records(scenarios, names, backend=backend)
    rows = [{**m, **r} for m, r in zip(meta, recs)]
    path = write_csv(f"{RESULTS}/benchmarks/{tag}.csv", rows,
                     ["panel", "x", "name", "K0", "Kn", "B", "gamma", "E",
                      "T", "C", "feasible"])
    front = rep.pareto_front()
    final = [r for r in rows if r["panel"] == "a" and r["x"] == 0.25]
    gen_o = next(r["E"] for r in final if r["name"] == "Gen-O")
    return {"rows": len(rows), "csv": path, "derived": gen_o,
            "backend": rep.backend, "groups": rep.n_groups,
            "pareto_points": len(front), "dt": time.time() - t0}


if __name__ == "__main__":
    print(run())
