"""Fig. 4: realized training loss / test accuracy versus the convergence-
error limit C_max — demonstrating that the constraint in (36) actually
controls the achieved model quality (the paper's "C_A effectively
characterizes training loss and test accuracy" claim)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import ConstantRule, GenQSGD, GenQSGDConfig
from repro.data.federated import partition_iid, sample_minibatch
from repro.data.synthetic import mnist_like
from repro.models import mlp

from .common import RESULTS, get_constants, paper_system, run_algorithm, \
    write_csv

C_GRID = (0.2, 0.3, 0.5, 0.8)
MAX_K0 = 1500


def run(tag="fig4"):
    consts = get_constants()
    sys_ = paper_system()
    X, y = mnist_like()
    Xtr, ytr, Xte, yte = X[:50000], y[:50000], X[50000:], y[50000:]
    N = 10
    Xw, yw = partition_iid(Xtr, ytr, N)
    data = (jnp.stack([jnp.asarray(a) for a in Xw]),
            jnp.stack([jnp.asarray(a) for a in yw]))
    Xte_j, yte_j = jnp.asarray(Xte), jnp.asarray(yte)
    rows, t0 = [], time.time()
    for cmax in C_GRID:
        rec = run_algorithm("Gen-O", sys_, consts, T_max=1e5, C_max=cmax)
        K0 = min(int(rec["K0"]), MAX_K0)
        cfg = GenQSGDConfig(K0=K0, Kn=(int(rec["Kn"]),) * N, B=int(rec["B"]),
                            step_rule=ConstantRule(float(rec["gamma"])),
                            s0=sys_.s0, sn=list(sys_.sn))
        alg = GenQSGD(mlp.loss, sample_minibatch, cfg)
        pf, _ = alg.run(mlp.init_params(jax.random.PRNGKey(1)), data,
                        jax.random.PRNGKey(2))
        loss = float(mlp.loss(pf, (Xte_j[:4096], yte_j[:4096])))
        acc = mlp.accuracy(pf, Xte_j, yte_j)
        rows.append({"C_max": cmax, "K0_opt": rec["K0"], "K0_run": K0,
                     "Kn": rec["Kn"], "B": rec["B"],
                     "gamma": rec["gamma"], "test_loss": round(loss, 4),
                     "test_acc": round(acc, 4)})
        print(f"  C_max={cmax}: K0={K0} -> loss={loss:.3f} acc={acc:.3f}",
              flush=True)
    path = write_csv(f"{RESULTS}/benchmarks/{tag}.csv", rows,
                     ["C_max", "K0_opt", "K0_run", "Kn", "B", "gamma",
                      "test_loss", "test_acc"])
    # the claim: tighter C_max -> no worse loss (monotone control)
    losses = [r["test_loss"] for r in rows]
    monotone = all(losses[i] <= losses[i + 1] + 0.05
                   for i in range(len(losses) - 1))
    return {"rows": len(rows), "csv": path,
            "derived": f"loss@0.2={losses[0]} monotone={monotone}",
            "dt": time.time() - t0}


if __name__ == "__main__":
    print(run())
