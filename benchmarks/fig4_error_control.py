"""Fig. 4: realized training loss / test accuracy versus the convergence-
error limit C_max — demonstrating that the constraint in (36) actually
controls the achieved model quality (the paper's "C_A effectively
characterizes training loss and test accuracy" claim).  Runs the optimized
Plans through ``Scenario.run`` on the Sec.-VII task."""
from __future__ import annotations

import time

from repro.api import MNISTTask

from .common import (RESULTS, get_constants, make_scenario, paper_system,
                     write_csv)

C_GRID = (0.2, 0.3, 0.5, 0.8)
MAX_K0 = 1500


def run(tag="fig4"):
    consts = get_constants()
    sys_ = paper_system()
    task = MNISTTask(eval_samples=4096)
    rows, t0 = [], time.time()
    for cmax in C_GRID:
        scn, _ = make_scenario("Gen-O", sys_, consts, T_max=1e5, C_max=cmax)
        plan = scn.optimize()
        rep = scn.run(plan, task=task, max_rounds=MAX_K0)
        loss = rep.final_metrics["eval_loss"]
        acc = rep.final_metrics["test_acc"]
        rows.append({"C_max": cmax, "K0_opt": plan.K0, "K0_run": rep.rounds,
                     "Kn": plan.Kn[0], "B": plan.B,
                     "gamma": plan.gamma, "test_loss": round(loss, 4),
                     "test_acc": round(acc, 4)})
        print(f"  C_max={cmax}: K0={rep.rounds} -> loss={loss:.3f} "
              f"acc={acc:.3f}", flush=True)
    path = write_csv(f"{RESULTS}/benchmarks/{tag}.csv", rows,
                     ["C_max", "K0_opt", "K0_run", "Kn", "B", "gamma",
                      "test_loss", "test_acc"])
    # the claim: tighter C_max -> no worse loss (monotone control)
    losses = [r["test_loss"] for r in rows]
    monotone = all(losses[i] <= losses[i + 1] + 0.05
                   for i in range(len(losses) - 1))
    return {"rows": len(rows), "csv": path,
            "derived": f"loss@0.2={losses[0]} monotone={monotone}",
            "dt": time.time() - t0}


if __name__ == "__main__":
    print(run())
