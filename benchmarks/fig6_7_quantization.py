"""Figs. 6 & 7: energy cost of ALL algorithms versus the server quantization
parameter log2(s0) (Fig. 6) and the worker parameter log2(sn) (Fig. 7), at
C_max=0.25, T_max=1e5.  The U-shape (coarse quantization inflates K0;  fine
quantization inflates per-round bits) is the paper's headline quantization
insight."""
from __future__ import annotations

import time

from .common import (MAIN_ALGOS, RESULTS, get_constants, paper_system,
                     run_algorithm, write_csv)

LOG2_GRID = (8, 10, 12, 14, 16, 18, 20)
ALGOS = ("Gen-C", "Gen-E", "Gen-D", "Gen-O",
         "PM-C-opt", "FA-C-opt", "PR-C-opt",
         "PM-C-fix", "FA-C-fix", "PR-C-fix")


def run(tag="fig6_7"):
    consts = get_constants()
    rows = []
    t0 = time.time()
    for panel, knob in (("fig6_s0", "s0"), ("fig7_sn", "sn")):
        for lg in LOG2_GRID:
            if knob == "s0":
                sys_ = paper_system(s0=2**lg)
            else:
                import dataclasses
                sys_ = dataclasses.replace(paper_system(), sn=[2**lg] * 10)
            for name in ALGOS:
                r = run_algorithm(name, sys_, consts, T_max=1e5, C_max=0.25)
                rows.append({"panel": panel, "log2_s": lg, **r})
        print(f"  {panel} done", flush=True)
    path = write_csv(f"{RESULTS}/benchmarks/{tag}.csv", rows,
                     ["panel", "log2_s", "name", "K0", "Kn", "B", "E", "T",
                      "C", "feasible"])
    mid = [r for r in rows if r["panel"] == "fig6_s0"
           and r["name"] == "Gen-O"]
    return {"rows": len(rows), "csv": path,
            "derived": min(r["E"] for r in mid), "dt": time.time() - t0}


if __name__ == "__main__":
    print(run())
