"""Figs. 6 & 7: energy cost of ALL algorithms versus the server quantization
parameter log2(s0) (Fig. 6) and the worker parameter log2(sn) (Fig. 7), at
C_max=0.25, T_max=1e5.  The U-shape (coarse quantization inflates K0;  fine
quantization inflates per-round bits) is the paper's headline quantization
insight.

All ``-opt`` points across both panels solve as one heterogeneous sweep —
the quantization knob only changes cost-model coefficients, so every
(m, family) line batches into a single GIA call path over its 14 systems.
"""
from __future__ import annotations

import dataclasses
import time

from .common import (RESULTS, get_constants, make_scenario, paper_system,
                     run_algorithm, sweep_records, write_csv)

LOG2_GRID = (8, 10, 12, 14, 16, 18, 20)
ALGOS = ("Gen-C", "Gen-E", "Gen-D", "Gen-O",
         "PM-C-opt", "FA-C-opt", "PR-C-opt",
         "PM-C-fix", "FA-C-fix", "PR-C-fix")


def run(tag="fig6_7", backend="auto"):
    consts = get_constants()
    t0 = time.time()
    points = []                            # (meta, name, system) in row order
    for panel, knob in (("fig6_s0", "s0"), ("fig7_sn", "sn")):
        for lg in LOG2_GRID:
            if knob == "s0":
                sys_ = paper_system(s0=2**lg)
            else:
                sys_ = dataclasses.replace(paper_system(), sn=[2**lg] * 10)
            for name in ALGOS:
                points.append(({"panel": panel, "log2_s": lg}, name, sys_))
    opt_idx = [i for i, (_, name, _) in enumerate(points)
               if not name.endswith("-fix")]
    scns = [make_scenario(points[i][1], points[i][2], consts,
                          T_max=1e5, C_max=0.25)[0] for i in opt_idx]
    recs, _ = sweep_records(scns, [points[i][1] for i in opt_idx],
                            backend=backend)
    rows = [None] * len(points)
    for i, rec in zip(opt_idx, recs):
        rows[i] = {**points[i][0], **rec}
    for i, (meta, name, sys_) in enumerate(points):
        if rows[i] is None:                # -fix: K0 bisection, no GIA
            rows[i] = {**meta, **run_algorithm(name, sys_, consts,
                                               T_max=1e5, C_max=0.25)}
    path = write_csv(f"{RESULTS}/benchmarks/{tag}.csv", rows,
                     ["panel", "log2_s", "name", "K0", "Kn", "B", "E", "T",
                      "C", "feasible"])
    mid = [r for r in rows if r["panel"] == "fig6_s0"
           and r["name"] == "Gen-O"]
    return {"rows": len(rows), "csv": path,
            "derived": min(r["E"] for r in mid), "dt": time.time() - t0}


if __name__ == "__main__":
    print(run())
