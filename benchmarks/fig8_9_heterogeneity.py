"""Figs. 8 & 9: energy cost versus worker heterogeneity — the computation
ratio F^(1)/F^(2) (Fig. 8) and the quantization ratio s^(1)/s^(2) (Fig. 9),
at C_max=0.25, T_max=1e5."""
from __future__ import annotations

import time

from .common import RESULTS, get_constants, paper_system, run_algorithm, \
    write_csv

RATIOS = (1.0, 2.0, 4.0, 8.0, 10.0)
ALGOS = ("Gen-C", "Gen-E", "Gen-D", "Gen-O",
         "PM-C-opt", "FA-C-opt", "PR-C-opt")


def run(tag="fig8_9"):
    consts = get_constants()
    rows = []
    t0 = time.time()
    for panel, knob in (("fig8_F", "F_ratio"), ("fig9_s", "s_ratio")):
        for ratio in RATIOS:
            sys_ = paper_system(**{knob: ratio})
            for name in ALGOS:
                r = run_algorithm(name, sys_, consts, T_max=1e5, C_max=0.25)
                rows.append({"panel": panel, "ratio": ratio, **r})
        print(f"  {panel} done", flush=True)
    path = write_csv(f"{RESULTS}/benchmarks/{tag}.csv", rows,
                     ["panel", "ratio", "name", "K0", "Kn", "B", "E", "T",
                      "C", "feasible"])
    gen_o = [r for r in rows if r["panel"] == "fig8_F"
             and r["name"] == "Gen-O"]
    return {"rows": len(rows), "csv": path,
            "derived": gen_o[-1]["E"] / max(gen_o[0]["E"], 1e-9),
            "dt": time.time() - t0}


if __name__ == "__main__":
    print(run())
