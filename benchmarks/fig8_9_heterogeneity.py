"""Figs. 8 & 9: energy cost versus worker heterogeneity — the computation
ratio F^(1)/F^(2) (Fig. 8) and the quantization ratio s^(1)/s^(2) (Fig. 9),
at C_max=0.25, T_max=1e5.

Every point is an ``-opt`` solve, so the whole two-panel figure is one
heterogeneous sweep: 7 (m, family) structure groups, each batching its 10
heterogeneity settings through one GIA call path.
"""
from __future__ import annotations

import time

from .common import (RESULTS, get_constants, make_scenario, paper_system,
                     sweep_records, write_csv)

RATIOS = (1.0, 2.0, 4.0, 8.0, 10.0)
ALGOS = ("Gen-C", "Gen-E", "Gen-D", "Gen-O",
         "PM-C-opt", "FA-C-opt", "PR-C-opt")


def run(tag="fig8_9", backend="auto"):
    consts = get_constants()
    t0 = time.time()
    scenarios, names, meta = [], [], []
    for panel, knob in (("fig8_F", "F_ratio"), ("fig9_s", "s_ratio")):
        for ratio in RATIOS:
            sys_ = paper_system(**{knob: ratio})
            for name in ALGOS:
                scn, _ = make_scenario(name, sys_, consts,
                                       T_max=1e5, C_max=0.25)
                scenarios.append(scn)
                names.append(name)
                meta.append({"panel": panel, "ratio": ratio})
    recs, _ = sweep_records(scenarios, names, backend=backend)
    rows = [{**m, **r} for m, r in zip(meta, recs)]
    path = write_csv(f"{RESULTS}/benchmarks/{tag}.csv", rows,
                     ["panel", "ratio", "name", "K0", "Kn", "B", "E", "T",
                      "C", "feasible"])
    gen_o = [r for r in rows if r["panel"] == "fig8_F"
             and r["name"] == "Gen-O"]
    return {"rows": len(rows), "csv": path,
            "derived": gen_o[-1]["E"] / max(gen_o[0]["E"], 1e-9),
            "dt": time.time() - t0}


if __name__ == "__main__":
    print(run())
