"""Kernel benchmarks for the one-pass encode pipeline, roofline-gated.

For each (kernel, wire, size) this times the fused one-pass encode
(norm + quantize + pack in a single expression / pallas_call) against the
staged multi-pass reference pipeline it replaced (sumsq pass, quantize
pass materializing f32 levels, pack pass), attributes bytes moved per
pass via :func:`repro.roofline.analysis.encode_bytes`, and reports
achieved-vs-peak bandwidth against this host's measured copy bandwidth.

Results land in ``BENCH_kernels.json`` at the repo root (plus the usual
CSV under ``results/``, untracked).  Hard gates — asserted here so the
CI perf-smoke job fails loudly:

  * payload bit-identity: the fused pipeline's packed bytes and norm
    equal the reference composition's, on both the jnp and (interpreted)
    Pallas backends, at every size;
  * roofline floor: the model predicts fused >= 1.6x multipass encode
    throughput in the memory-bound regime (bytes ratio, exact from the
    pass structure) — asserted at every size, including >= 2^22;
  * wall-clock floor: measured fused >= 1.6x multipass at >= 2^22, only
    when a Pallas-capable accelerator is present.  A CPU host is not
    memory-bound at these sizes (the wall ratio there measures XLA CPU
    codegen, not bytes), so CPU runs record the measured ratio but gate
    on the roofline model alone.
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp

from repro.compress import backends as B
from repro.compress import elias as E
from repro.compress import pack_int4, wire_bits
from repro.kernels.flash_decode import BLOCK_C, flash_decode_call
from repro.kernels.qsgd import default_interpret
from repro.obs.bench import write_bench
from repro.roofline.analysis import (achieved_bandwidth, encode_bytes,
                                     host_peak_bandwidth)

from .common import RESULTS, write_csv

BENCH_JSON = os.environ.get("REPRO_BENCH_JSON", "BENCH_kernels.json")

SIZES = (2**16, 2**20, 2**22)
SMOKE_SIZES = (2**16,)
WIRES = ("int4", "int8")
SPEEDUP_FLOOR = 1.6
FLOOR_SIZE = 2**22


def _time_us(fn, *args, reps=5):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def _multipass(wire, s, n):
    """The staged pre-fused pipeline: three separately dispatched stages
    with the f32 level materialization the reference backend's contract
    implies (``encode_jnp`` -> levels f32; the pack pass re-reads them)."""
    j_norm = jax.jit(lambda y: jnp.sqrt(jnp.sum(jnp.square(y))))
    j_quant = jax.jit(lambda y, u, nrm: B.qsgd_levels(y, u, s, jnp.where(
        nrm > 0, nrm, 1.0)))
    if wire == "int4":
        j_pack = jax.jit(
            lambda lvl: pack_int4(lvl.astype(jnp.int8))[:(n + 1) // 2])
    else:
        j_pack = jax.jit(lambda lvl: lvl.astype(jnp.int8))

    def run(y, u):
        nrm = j_norm(y)
        lvl = j_quant(y, u, nrm)
        return j_pack(lvl), nrm
    return run


def _encode_rows(sizes, reps, interp):
    rows, gates = [], []
    for wire in WIRES:
        s = 7 if wire == "int4" else 64
        pack = wire == "int4"
        fused = jax.jit(
            lambda y, u, s=s, pack=pack: B.encode_fused_jnp(y, s, u,
                                                            pack=pack))
        for n in sizes:
            key = jax.random.PRNGKey(n)
            y = jax.random.normal(key, (n,))
            u = jax.random.uniform(jax.random.fold_in(key, 1), (n,))
            multi = _multipass(wire, s, n)
            p_ref, nrm_ref = multi(y, u)
            if not pack:
                p_ref = p_ref  # int8 levels are the payload
            p_f, nrm_f = fused(y, u)
            assert jnp.array_equal(p_f, p_ref), (wire, n, "payload")
            assert jnp.array_equal(nrm_f, nrm_ref), (wire, n, "norm")
            # the Pallas kernel (interpreted off-TPU) packs bit-identically
            p_k, nrm_k = B.encode_fused(y, s, u, pack=pack, interpret=interp)
            if not pack:
                p_k = p_k.astype(jnp.int8)
            assert jnp.array_equal(p_k, p_ref), (wire, n, "kernel payload")

            us_f = _time_us(fused, y, u, reps=reps)
            us_m = _time_us(lambda: multi(y, u), reps=reps)
            mb_f = encode_bytes(n, wire, "fused")["total_bytes"]
            mb_m = encode_bytes(n, wire, "multipass")["total_bytes"]
            model_x = mb_m / mb_f
            measured_x = us_m / us_f
            row = {"kernel": "fused_encode", "wire": wire, "n": n,
                   "fused_us": round(us_f, 1),
                   "multipass_us": round(us_m, 1),
                   "model_bytes_fused": mb_f,
                   "model_bytes_multipass": mb_m,
                   "model_speedup": round(model_x, 3),
                   "measured_speedup": round(measured_x, 3),
                   "achieved_bw_gbs": round(
                       achieved_bandwidth(mb_f, us_f * 1e-6) / 1e9, 2)}
            rows.append(row)
            assert model_x >= SPEEDUP_FLOOR, (
                f"roofline floor broken: {wire} n={n} model {model_x:.2f}x")
            if not interp and n >= FLOOR_SIZE:
                gates.append((wire, n, measured_x))
    for wire, n, x in gates:
        assert x >= SPEEDUP_FLOOR, (
            f"wall-clock floor broken on accelerator: {wire} n={n} {x:.2f}x")
    return rows


def _elias_rows(n, reps):
    s = 7
    key = jax.random.PRNGKey(3)
    y = jax.random.normal(key, (n,))
    u = jax.random.uniform(jax.random.fold_in(key, 1), (n,))
    lvl, _ = B.encode_tensor(y, s, u)
    enc = jax.jit(E.encode_levels)
    dec = jax.jit(lambda w: E.decode_levels(w, n))
    words, nbits = enc(lvl)
    assert jnp.array_equal(dec(words), lvl), "elias round-trip broken"
    us_e = _time_us(enc, lvl, reps=reps)
    us_d = _time_us(dec, words, reps=reps)
    priced = wire_bits(s, n, "elias") - 32.0  # minus the norm word
    bits = int(nbits)
    assert bits <= priced, (bits, priced)
    return {"kernel": "elias_coder", "n": n, "s": s,
            "encode_us": round(us_e, 1), "decode_us": round(us_d, 1),
            "realized_bits": bits, "priced_bits": round(priced, 1),
            "int4_bits": int(4 * n),
            "encode_mcoord_s": round(n / us_e, 2),
            "decode_mcoord_s": round(n / us_d, 2)}


def run(tag="kernel_bench", smoke=False):
    t0 = time.time()
    interp = default_interpret()
    reps = 2 if smoke else 5
    sizes = SMOKE_SIZES if smoke else SIZES
    peak = host_peak_bandwidth()
    enc_rows = _encode_rows(sizes, reps, interp)
    for r in enc_rows:
        r["peak_fraction"] = round(r["achieved_bw_gbs"] * 1e9 / peak, 4)
    el_row = _elias_rows(min(sizes[-1], 2**20), reps)

    # flash-decode kernel at a 4k-deep cache (unchanged shape)
    B_, KV, G, dh, C = 2, 4, 2, 128, (1 if smoke else 8) * BLOCK_C
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (B_, KV, G, dh))
    k = jax.random.normal(key, (B_, C, KV, dh))
    v = jax.random.normal(key, (B_, C, KV, dh))
    valid = jnp.ones((B_, C))
    fd = jax.jit(lambda *a: flash_decode_call(*a))
    fd_row = {"kernel": "flash_decode", "n": C,
              "decode_us": round(_time_us(lambda: fd(q, k, v, valid),
                                          reps=reps), 1)}

    write_bench(BENCH_JSON, "kernels", {
        "backend": "interpret" if interp else "pallas",
        "host_peak_bw_gbs": round(peak / 1e9, 2),
        "speedup_floor": SPEEDUP_FLOOR,
        "wall_floor_enforced": not interp,
        "encode": enc_rows, "elias": el_row, "flash_decode": fd_row,
    }, smoke=smoke)
    csv_rows = enc_rows + [el_row, fd_row]
    header = ["kernel", "wire", "n", "fused_us", "multipass_us",
              "model_speedup", "measured_speedup", "achieved_bw_gbs",
              "peak_fraction", "encode_us", "decode_us", "realized_bits",
              "priced_bits"]
    path = write_csv(f"{RESULTS}/benchmarks/{tag}.csv", csv_rows, header)
    return {"rows": len(csv_rows), "csv": path, "json": BENCH_JSON,
            "dt": round(time.time() - t0, 1)}


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="single small size, fewer reps (CI perf-smoke)")
    args = ap.parse_args()
    print(run(smoke=args.smoke))
