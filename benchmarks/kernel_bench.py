"""Codec micro-benchmarks: Pallas backend (interpret mode on CPU — semantics,
not TPU wall-time) vs the reference jnp backend, plus the int4 wire
pack/unpack and the flash-decode kernel."""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.compress import make_codec, pack_int4, unpack_int4
from repro.kernels.flash_decode import BLOCK_C, flash_decode_call

from .common import RESULTS, write_csv

SIZES = (2**16, 2**20, 2**22)
SMOKE_SIZES = (2**16,)


def _time(fn, *args, reps=5):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def run(tag="kernel_bench", smoke=False):
    key = jax.random.PRNGKey(0)
    c_pallas = make_codec(64, wire="int8", backend="pallas")
    c_ref = make_codec(64, wire="int8", backend="jnp")
    enc_pallas = jax.jit(lambda yy, uu: c_pallas.encode(yy, uu))
    enc_ref = jax.jit(lambda yy, uu: c_ref.encode(yy, uu))
    apply_pallas = jax.jit(
        lambda xx, ll, nn: c_pallas.decode_apply(xx, ll, nn, 0.01))
    pack = jax.jit(lambda ll: unpack_int4(pack_int4(ll), ll.size))
    reps = 2 if smoke else 5
    rows = []
    t0 = time.time()
    for n in SMOKE_SIZES if smoke else SIZES:
        y = jax.random.normal(key, (n,))
        u = jax.random.uniform(key, (n,))
        lvl, norm = enc_pallas(y, u)
        assert jnp.array_equal(lvl, enc_ref(y, u)[0]), "backends diverge"
        us_q = _time(enc_pallas, y, u, reps=reps)
        us_d = _time(apply_pallas, y, lvl, norm, reps=reps)
        us_ref = _time(enc_ref, y, u, reps=reps)
        us_pk = _time(pack, jnp.clip(lvl, -7, 7), reps=reps)
        rows.append({"n": n, "quantize_us": round(us_q, 1),
                     "dequant_apply_us": round(us_d, 1),
                     "ref_us": round(us_ref, 1),
                     "int4_roundtrip_us": round(us_pk, 1)})
    # flash-decode kernel at a 4k-deep cache
    B, KV, G, dh, C = 2, 4, 2, 128, (1 if smoke else 8) * BLOCK_C
    q = jax.random.normal(key, (B, KV, G, dh))
    k = jax.random.normal(key, (B, C, KV, dh))
    v = jax.random.normal(key, (B, C, KV, dh))
    valid = jnp.ones((B, C))
    fd = jax.jit(lambda *a: flash_decode_call(*a))
    us_fd = _time(lambda: fd(q, k, v, valid), reps=reps)
    rows.append({"n": f"flash_decode_C{C}", "quantize_us": round(us_fd, 1),
                 "dequant_apply_us": "", "ref_us": "",
                 "int4_roundtrip_us": ""})
    path = write_csv(f"{RESULTS}/benchmarks/{tag}.csv", rows,
                     ["n", "quantize_us", "dequant_apply_us", "ref_us",
                      "int4_roundtrip_us"])
    return {"rows": len(rows), "csv": path,
            "derived": rows[-1]["quantize_us"], "dt": time.time() - t0}


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="single small size, fewer reps (CI verify recipe)")
    args = ap.parse_args()
    print(run(smoke=args.smoke))
