"""Pallas kernel micro-benchmarks (interpret mode on CPU — semantics, not
TPU wall-time) + the pure-jnp oracle timings for reference."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref
from repro.kernels.flash_decode import BLOCK_C, flash_decode_call

from .common import RESULTS, write_csv

SIZES = (2**16, 2**20, 2**22)


def _time(fn, *args, reps=5):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def run(tag="kernel_bench"):
    key = jax.random.PRNGKey(0)
    rows = []
    t0 = time.time()
    for n in SIZES:
        y = jax.random.normal(key, (n,))
        lvl, norm = ops.qsgd_quantize(y, key, s=64)
        us_q = _time(lambda: ops.qsgd_quantize(y, key, s=64))
        us_d = _time(lambda: ops.qsgd_dequant_apply(y, lvl, norm, 0.01, s=64))
        ref_q = jax.jit(lambda yy, u: ref.qsgd_quantize_ref(
            yy, u, 64, jnp.sqrt(ref.sumsq_ref(yy))))
        u = jax.random.uniform(key, (n,))
        us_ref = _time(lambda: ref_q(y, u))
        rows.append({"n": n, "quantize_us": round(us_q, 1),
                     "dequant_apply_us": round(us_d, 1),
                     "ref_us": round(us_ref, 1)})
    # flash-decode kernel at a 4k-deep cache
    B, KV, G, dh, C = 2, 4, 2, 128, 8 * BLOCK_C
    q = jax.random.normal(key, (B, KV, G, dh))
    k = jax.random.normal(key, (B, C, KV, dh))
    v = jax.random.normal(key, (B, C, KV, dh))
    valid = jnp.ones((B, C))
    fd = jax.jit(lambda *a: flash_decode_call(*a))
    us_fd = _time(lambda: fd(q, k, v, valid))
    rows.append({"n": f"flash_decode_C{C}", "quantize_us": round(us_fd, 1),
                 "dequant_apply_us": "", "ref_us": ""})
    path = write_csv(f"{RESULTS}/benchmarks/{tag}.csv", rows,
                     ["n", "quantize_us", "dequant_apply_us", "ref_us"])
    return {"rows": len(rows), "csv": path,
            "derived": rows[-1]["quantize_us"], "dt": time.time() - t0}


if __name__ == "__main__":
    print(run())
