"""Benchmark orchestrator — one module per paper figure/table.

Prints the ``name,us_per_call,derived`` CSV summary (us_per_call = wall time
of the whole benchmark; derived = its headline metric) and writes detailed
CSVs under results/benchmarks/.

Usage:
  python -m benchmarks.run                 # everything
  python -m benchmarks.run --only fig5,kernel
  python -m benchmarks.run --quick         # skip the training-based figures
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated substrings of benchmark names")
    ap.add_argument("--quick", action="store_true",
                    help="skip the (slow) training-based figures")
    args = ap.parse_args()

    from . import (fig3_convergence, fig4_error_control, fig5_tradeoff,
                   fig6_7_quantization, fig8_9_heterogeneity, kernel_bench,
                   opt_bench, table_baselines, tpu_autotune)

    suite = [
        ("table_baselines", table_baselines.run),
        ("fig5_tradeoff", fig5_tradeoff.run),
        ("opt_bench", opt_bench.run),
        ("fig6_7_quantization", fig6_7_quantization.run),
        ("fig8_9_heterogeneity", fig8_9_heterogeneity.run),
        ("tpu_autotune", tpu_autotune.run),
        ("kernel_bench", kernel_bench.run),
        ("fig3_convergence", fig3_convergence.run),
        ("fig4_error_control", fig4_error_control.run),
    ]
    if args.quick:
        suite = [s for s in suite
                 if s[0] not in ("fig3_convergence", "fig4_error_control")]
    if args.only:
        keys = args.only.split(",")
        suite = [s for s in suite if any(k in s[0] for k in keys)]

    print("name,us_per_call,derived")
    failures = 0
    for name, fn in suite:
        print(f"[bench] {name}", file=sys.stderr, flush=True)
        t0 = time.time()
        try:
            out = fn()
            us = (time.time() - t0) * 1e6
            print(f"{name},{us:.0f},{out.get('derived')}", flush=True)
        except Exception:
            traceback.print_exc()
            print(f"{name},FAILED,", flush=True)
            failures += 1
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
