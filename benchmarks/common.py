"""Shared harness for the paper-reproduction benchmarks (Sec. VII setup).

Provides: cached pre-training constants, the Sec.-VII EdgeSystem/task, and
the 13-algorithm suite (Gen-C/E/D/O + {PM,FA,PR}-{C,E,D}-opt and -fix) —
all expressed through the repro.api Scenario facade (algorithm names map to
(family, step-rule) Scenarios; no direct ParamOptProblem construction).
"""
from __future__ import annotations

import json
import os
import time
from typing import Dict, Tuple

import numpy as np

from repro.api import (EdgeSystem, MLProblemConstants, MNISTTask, Scenario,
                       make_step_rule, sweep_scenarios)
from repro.opt.gia import min_feasible_K0

RESULTS = os.environ.get("REPRO_RESULTS", "results")
CONST_PATH = os.path.join(RESULTS, "paper_constants.json")

# Sec.-VII step-size parameters
GAMMAS = {"C": dict(gamma=0.01), "E": dict(gamma=0.02, rho=0.9995),
          "D": dict(gamma=0.02, rho=600.0)}
I_N = 6000.0  # samples per worker (60k over N=10)

#: benchmark algorithm prefix -> repro.api family registry key
FAMILY_OF = {"Gen": "genqsgd", "PM": "pm", "FA": "fa", "PR": "pr"}

_TASK = None


def get_task() -> MNISTTask:
    """The Sec.-VII MNIST-like task (shared/cached across figures)."""
    global _TASK
    if _TASK is None:
        _TASK = MNISTTask()
    return _TASK


def get_constants(force: bool = False) -> MLProblemConstants:
    os.makedirs(RESULTS, exist_ok=True)
    if os.path.exists(CONST_PATH) and not force:
        d = json.load(open(CONST_PATH))
        return MLProblemConstants(L=d["L"], sigma=d["sigma"], G=d["G"],
                                  f_gap=d["f_gap"], N=10)
    consts = get_task().estimate_constants(N=10)
    json.dump({"L": consts.L, "sigma": consts.sigma, "G": consts.G,
               "f_gap": consts.f_gap}, open(CONST_PATH, "w"), indent=2)
    return consts


def paper_system(**kw) -> EdgeSystem:
    return EdgeSystem.paper_sec_vii(dim=MNISTTask.dim, **kw)


def make_scenario(name: str, sys_: EdgeSystem, consts, T_max: float,
                  C_max: float) -> Tuple[Scenario, str]:
    """Map a benchmark algorithm name ('Gen-O', 'PM-E-opt', 'FA-C-fix', ...)
    to a (Scenario, mode) pair; mode is 'opt' or 'fix'."""
    parts = name.split("-")
    algo = parts[0]
    m = "J" if (algo == "Gen" and parts[1] == "O") else parts[1]
    step = None if m == "J" else make_step_rule(m, **GAMMAS[m])
    scn = Scenario(system=sys_, consts=consts, T_max=T_max, C_max=C_max,
                   family=FAMILY_OF[algo], step=step, samples_per_worker=I_N)
    return scn, (parts[2] if len(parts) > 2 else "opt")


def plan_record(name: str, plan, dt: float) -> Dict:
    """Flatten a Plan into the benchmark CSV row shape."""
    return {"name": name, "K0": plan.K0, "Kn": int(plan.Kn[0]), "B": plan.B,
            "gamma": plan.gamma, "E": plan.predicted_E,
            "T": plan.predicted_T, "C": plan.predicted_C,
            "feasible": bool(plan.feasible), "dt": dt}


def sweep_records(scenarios, names, backend: str = "auto"):
    """Optimize scenarios through the batched engine; benchmark row shape.

    Returns (rows, SweepReport); ``dt`` is the whole sweep's wall clock
    amortized per point (the points no longer solve one by one)."""
    rep = sweep_scenarios(scenarios, names=names, backend=backend)
    dt = rep.wall_time_s / max(1, len(rep))
    rows = []
    for row in rep:
        r = dict(row)
        r["Kn"] = int(row["Kn"][0])
        r["dt"] = dt
        rows.append(r)
    return rows, rep


def _fixed_eval(prob, Kn_val: float, B: int) -> Dict:
    """-fix baselines: parameters preset, K0 = smallest meeting C_max
    (monotone bisection via :func:`repro.opt.gia.min_feasible_K0`)."""
    Kn = np.full(10, max(1, int(round(Kn_val))), dtype=np.int64)
    K0, ok = min_feasible_K0(prob, Kn, B, ctol=0.0, ttol=0.0)
    ev = prob.evaluate(K0, Kn, B, None)
    return {"K0": K0, "Kn": int(Kn[0]), "B": B, "E": ev["E"], "T": ev["T"],
            "C": ev["C"], "feasible": bool(ok), "gamma": prob.gamma}


def run_algorithm(name: str, sys_: EdgeSystem, consts, T_max: float,
                  C_max: float) -> Dict:
    """name: e.g. 'Gen-C', 'Gen-O', 'PM-E-opt', 'FA-D-fix', 'PR-C-opt'."""
    t0 = time.time()
    parts = name.split("-")
    if len(parts) < 3 or parts[2] == "opt":
        scn, _ = make_scenario(name, sys_, consts, T_max, C_max)
        return plan_record(name, scn.optimize(), time.time() - t0)
    # -fix: PM: Kn=1,B=32; FA: l=1 (Kn=I/B), B=600; PR: B=1, Kn=4 —
    # evaluated on the free-variable (genqsgd) problem of the same m.
    algo, m, _ = parts
    gen_scn, _ = make_scenario(f"Gen-{m}", sys_, consts, T_max, C_max)
    fixed = {"PM": (1, 32), "FA": (I_N / 600.0, 600), "PR": (4, 1)}[algo]
    rec = _fixed_eval(gen_scn.problem(), *fixed)
    rec.update({"name": name, "dt": time.time() - t0})
    return rec


ALL_ALGOS = (["Gen-C", "Gen-E", "Gen-D", "Gen-O"]
             + [f"{a}-{m}-{x}" for a in ("PM", "FA", "PR")
                for m in ("C", "E", "D") for x in ("opt", "fix")])
MAIN_ALGOS = (["Gen-C", "Gen-E", "Gen-D", "Gen-O"]
              + [f"{a}-{m}-opt" for a in ("PM", "FA", "PR")
                 for m in ("C", "E", "D")])


def write_csv(path: str, rows, header):
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        f.write(",".join(header) + "\n")
        for row in rows:
            f.write(",".join(str(row.get(h, "")) for h in header) + "\n")
    return path
