"""Shared harness for the paper-reproduction benchmarks (Sec. VII setup).

Provides: cached pre-training constants, the Sec.-VII EdgeSystem, and the
13-algorithm suite (Gen-C/E/D/O + {PM,FA,PR}-{C,E,D}-opt and -fix).
"""
from __future__ import annotations

import json
import math
import os
import time
from typing import Dict, Optional

import numpy as np

from repro.core import EdgeSystem, MLProblemConstants, make_rule
from repro.core.convergence import c_m
from repro.core.cost import energy_cost, time_cost
from repro.data.synthetic import mnist_like
from repro.models import mlp
from repro.opt import (ParamOptProblem, fa_varmap, identity_varmap, pm_varmap,
                       pr_varmap, solve_param_opt)

RESULTS = os.environ.get("REPRO_RESULTS", "results")
CONST_PATH = os.path.join(RESULTS, "paper_constants.json")

# Sec.-VII step-size parameters
GAMMAS = {"C": dict(gamma=0.01), "E": dict(gamma=0.02, rho=0.9995),
          "D": dict(gamma=0.02, rho=600.0)}
I_N = 6000.0  # samples per worker (60k over N=10)


def get_constants(force: bool = False) -> MLProblemConstants:
    os.makedirs(RESULTS, exist_ok=True)
    if os.path.exists(CONST_PATH) and not force:
        d = json.load(open(CONST_PATH))
    else:
        import jax
        X, y = mnist_like()
        d = mlp.estimate_constants(X, y, jax.random.PRNGKey(0))
        json.dump(d, open(CONST_PATH, "w"), indent=2)
    return MLProblemConstants(L=d["L"], sigma=d["sigma"], G=d["G"],
                              f_gap=d["f_gap"], N=10)


def paper_system(**kw) -> EdgeSystem:
    return EdgeSystem.paper_sec_vii(dim=mlp.PARAM_DIM, **kw)


def _fixed_eval(prob: ParamOptProblem, Kn_val: float, B: int,
                max_k0: int = 200_000) -> Dict:
    """-fix baselines: parameters preset, K0 = smallest meeting C_max."""
    Kn = np.full(10, max(1, int(round(Kn_val))), dtype=np.int64)
    K0, ok = 1, False
    while K0 <= max_k0:
        ev = prob.evaluate(K0, Kn, B, None)
        if ev["C"] <= prob.C_max:
            ok = ev["T"] <= prob.T_max
            break
        if ev["T"] > prob.T_max:
            break
        K0 = int(math.ceil(K0 * 1.25))
    ev = prob.evaluate(K0, Kn, B, None)
    return {"K0": K0, "Kn": int(Kn[0]), "B": B, "E": ev["E"], "T": ev["T"],
            "C": ev["C"], "feasible": bool(ok), "gamma": prob.gamma}


def run_algorithm(name: str, sys_: EdgeSystem, consts, T_max: float,
                  C_max: float) -> Dict:
    """name: e.g. 'Gen-C', 'Gen-O', 'PM-E-opt', 'FA-D-fix', 'PR-C-opt'."""
    parts = name.split("-")
    t0 = time.time()
    if parts[0] == "Gen":
        if parts[1] == "O":
            prob = ParamOptProblem(sys=sys_, consts=consts, T_max=T_max,
                                   C_max=C_max, m="J")
        else:
            prob = ParamOptProblem(sys=sys_, consts=consts, T_max=T_max,
                                   C_max=C_max, m=parts[1],
                                   **GAMMAS[parts[1]])
        r = solve_param_opt(prob)
        return {"name": name, "K0": r.K0, "Kn": int(r.Kn[0]), "B": r.B,
                "gamma": r.gamma, "E": r.E, "T": r.T, "C": r.C,
                "feasible": bool(r.feasible), "dt": time.time() - t0}
    algo, m, mode = parts
    we = (m == "E")
    vm = {"PM": lambda: pm_varmap(10, with_extra=we),
          "FA": lambda: fa_varmap(10, [I_N] * 10, with_extra=we),
          "PR": lambda: pr_varmap(10, with_extra=we)}[algo]()
    prob = ParamOptProblem(sys=sys_, consts=consts, T_max=T_max, C_max=C_max,
                           m=m, vmap=vm, **GAMMAS[m])
    if mode == "opt":
        r = solve_param_opt(prob)
        return {"name": name, "K0": r.K0, "Kn": int(r.Kn[0]), "B": r.B,
                "gamma": r.gamma, "E": r.E, "T": r.T, "C": r.C,
                "feasible": bool(r.feasible), "dt": time.time() - t0}
    # -fix: PM: Kn=1,B=32; FA: l=1 (Kn=I/B), B=600; PR: B=1, Kn=4
    prob_id = ParamOptProblem(sys=sys_, consts=consts, T_max=T_max,
                              C_max=C_max, m=m, **GAMMAS[m])
    fixed = {"PM": (1, 32), "FA": (I_N / 600.0, 600), "PR": (4, 1)}[algo]
    rec = _fixed_eval(prob_id, *fixed)
    rec.update({"name": name, "dt": time.time() - t0})
    return rec


ALL_ALGOS = (["Gen-C", "Gen-E", "Gen-D", "Gen-O"]
             + [f"{a}-{m}-{x}" for a in ("PM", "FA", "PR")
                for m in ("C", "E", "D") for x in ("opt", "fix")])
MAIN_ALGOS = (["Gen-C", "Gen-E", "Gen-D", "Gen-O"]
              + [f"{a}-{m}-opt" for a in ("PM", "FA", "PR")
                 for m in ("C", "E", "D")])


def write_csv(path: str, rows, header):
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        f.write(",".join(header) + "\n")
        for row in rows:
            f.write(",".join(str(row.get(h, "")) for h in header) + "\n")
    return path
