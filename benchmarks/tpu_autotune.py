"""The paper's optimizer applied to the TPU fleet itself (§Perf iteration 3
for the llama3-405b training pair).

The measured roofline showed the cross-pod GenQSGD aggregation is already
cheap next to intra-pod FSDP traffic *because* it happens once per K_n local
steps — this benchmark closes the loop: parameterize T(K,B)/E(K,B) with the
TPU fleet constants (per-group FLOP/s from the measured compute term, the
50 GB/s ICI cross-pod link, QSGD bits M_s) and let Algorithm 5 choose
(K_0, K_n, B, γ).  As the cross-pod link slows (DCN-like regimes), the
optimizer raises K_n — reducing the per-step collective term exactly as the
paper's edge analysis predicts.
"""
from __future__ import annotations

import time

import numpy as np

from repro.api import EdgeSystem, MLProblemConstants, Scenario

from .common import RESULTS, write_csv

# llama3-405b training job on 2 pods (one FL worker per pod)
DIM = 405_000_000_000
TOKENS_PER_SAMPLE = 4096
FLOPS_PER_SAMPLE = 6 * DIM * TOKENS_PER_SAMPLE  # 6ND per 4k-token "sample"
LINK_GRID = (400e9, 100e9, 50e9, 12.5e9, 3.1e9)  # bytes/s cross-pod


def run(tag="tpu_autotune"):
    t0 = time.time()
    # ML constants: scaled-down surrogate of the LM problem (exact constants
    # would come from pre-training probes; the *trend* vs link speed is the
    # object of study here)
    consts = MLProblemConstants(L=0.05, sigma=4.0, G=5.0, f_gap=3.0, N=2)
    rows = []
    for link in LINK_GRID:
        sys_ = EdgeSystem.tpu_v5e_fleet(
            dim=DIM, n_groups=2, chips_per_group=256,
            s0=1024, sn=1024, link_bw=link * 8,  # rn is in bits/s
            flops_per_sample_step=FLOPS_PER_SAMPLE)
        scn = Scenario(system=sys_, consts=consts, T_max=3 * 24 * 3600.0,
                       C_max=0.5)
        p = scn.optimize()
        rows.append({"link_GBps": link / 1e9, "K0": p.K0, "Kn": p.Kn[0],
                     "B": p.B, "gamma": p.gamma, "E_J": p.predicted_E,
                     "T_s": p.predicted_T, "C": p.predicted_C,
                     "feasible": p.feasible})
        print(f"  link={link/1e9:7.1f} GB/s -> K0={p.K0} Kn={p.Kn[0]} "
              f"B={p.B} T={p.predicted_T:.3g}s feasible={p.feasible}",
              flush=True)
    path = write_csv(f"{RESULTS}/benchmarks/{tag}.csv", rows,
                     ["link_GBps", "K0", "Kn", "B", "gamma", "E_J", "T_s",
                      "C", "feasible"])
    kn_fast = rows[0]["Kn"]
    kn_slow = rows[-1]["Kn"]
    # the paper's prediction: slower links -> more local steps
    trend_ok = kn_slow >= kn_fast
    return {"rows": len(rows), "csv": path,
            "derived": f"Kn {kn_fast}->{kn_slow} trend_ok={trend_ok}",
            "dt": time.time() - t0}


if __name__ == "__main__":
    print(run())
