"""Solver-engine throughput: batched (jnp) vs sequential (scalar NumPy) GIA.

Measures the Fig.-5 grid — (budget, algo) points over Gen-C/E/D/O — solved
two ways:

  * ``sequential``: the historical loop, one scalar ``Scenario.optimize()``
    per point (pure-NumPy interior point);
  * ``batched``: one ``sweep_scenarios`` call — points group into one
    batched GIA call path per objective, each group's GP instances solving
    in single jitted+vmapped jnp calls, groups in parallel threads.

The batched engine is timed twice: cold (includes XLA compile of each
structure, paid once per process) and warm (the steady-state cost that
matters for big sweeps).  Rows land in results/benchmarks/ so the speedup
is tracked in the perf trajectory.

    PYTHONPATH=src python -m benchmarks.opt_bench           # full Fig.5 grid
    PYTHONPATH=src python -m benchmarks.opt_bench --smoke   # tiny CI subset
"""
from __future__ import annotations

import argparse
import time

from repro.api import sweep_scenarios

from .common import RESULTS, get_constants, make_scenario, paper_system, \
    write_csv

ALGOS = ("Gen-C", "Gen-E", "Gen-D", "Gen-O")
C_GRID = (0.2, 0.25, 0.3, 0.4, 0.6)


def _scenarios(sys_, consts, algos, c_grid):
    scns, names = [], []
    for cmax in c_grid:
        for name in algos:
            scn, _ = make_scenario(name, sys_, consts, T_max=1e5, C_max=cmax)
            scns.append(scn), names.append(name)
    return scns, names


def run(tag="opt_bench", smoke=False):
    consts = get_constants()
    sys_ = paper_system()
    algos = ("Gen-C", "Gen-O") if smoke else ALGOS
    c_grid = C_GRID[:2] if smoke else C_GRID
    if smoke:
        tag = f"{tag}_smoke"       # don't clobber the full-grid artifact
    scns, names = _scenarios(sys_, consts, algos, c_grid)
    n = len(scns)

    t0 = time.time()
    seq_plans = [s.optimize() for s in scns]
    t_seq = time.time() - t0

    t0 = time.time()
    rep_cold = sweep_scenarios(scns, names=names, backend="jnp")
    t_cold = time.time() - t0
    t0 = time.time()
    rep = sweep_scenarios(scns, names=names, backend="jnp")
    t_warm = time.time() - t0

    # parity sanity on the fly — report, don't abort: cross-backend float
    # divergence can legally move an integer by one on knife-edge points
    # (the test suite owns the strict parity assertions)
    mismatch = sum(
        p.feasible != row["feasible"]
        or abs(p.predicted_E - row["E"]) > 1e-3 * max(abs(p.predicted_E), 1)
        for p, row in zip(seq_plans, rep.rows))
    if mismatch:
        print(f"  WARNING: {mismatch}/{n} points differ between sequential "
              f"and batched beyond 0.1% — inspect before trusting timings")

    rows = [{
        "grid_points": n, "mode": mode, "wall_s": round(t, 4),
        "solves_per_s": round(n / t, 3), "speedup_vs_seq": round(t_seq / t, 2),
        "groups": rep.n_groups,
    } for mode, t in [("sequential", t_seq), ("batched_cold", t_cold),
                      ("batched_warm", t_warm)]]
    path = write_csv(f"{RESULTS}/benchmarks/{tag}.csv", rows,
                     ["grid_points", "mode", "wall_s", "solves_per_s",
                      "speedup_vs_seq", "groups"])
    for r in rows:
        print(f"  {r['mode']:14s} {r['wall_s']:8.2f}s "
              f"{r['solves_per_s']:8.3f} solves/s "
              f"speedup {r['speedup_vs_seq']:5.2f}x")
    return {"rows": len(rows), "csv": path,
            "derived": rows[-1]["speedup_vs_seq"], "dt": t_seq + t_cold + t_warm}


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="4-point subset for CI smoke runs")
    args = ap.parse_args()
    print(run(smoke=args.smoke))
