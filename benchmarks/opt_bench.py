"""Solver-engine throughput: fused / batched (jnp) vs sequential GIA.

Two workloads, three engines:

  * ``fig5`` — the 20-point Fig.-5 grid ((budget, algo) over Gen-C/E/D/O),
    solved sequentially (one scalar ``Scenario.optimize()`` per point, pure
    NumPy), through the per-iteration jitted backend (``jnp``: one vmapped
    GP solve per GIA iteration, host-side surrogate refresh), and through
    the fused device-resident backend (``jnp-fused``: the whole GIA —
    refresh included — is one ``lax.while_loop`` program per structure
    signature, zero host syncs per outer iteration);
  * ``sweep1024`` — a 1024-point ``Scenario.sweep`` (32 C_max x 32
    constant-rule gammas, one structure signature), the north-star
    sweep-scale workload: one compile, one device call, asserted via the
    fused engine's trace counter.

Device backends are timed cold (includes XLA compile, paid once per
structure signature per process — the JAX persistent compilation cache is
enabled below, so later processes skip it) and warm (steady state).  Rows
land in results/benchmarks/ as before, and the perf trajectory is written
to ``BENCH_opt.json`` at the repo root (schema: grid size, backend, warm
solves/sec, compile time).

    PYTHONPATH=src python -m benchmarks.opt_bench           # full run
    PYTHONPATH=src python -m benchmarks.opt_bench --smoke   # tiny CI subset
"""
from __future__ import annotations

import argparse
import os
import time

import numpy as np

from repro.obs.bench import write_bench

from .common import RESULTS, get_constants, make_scenario, paper_system, \
    write_csv

BENCH_JSON = os.environ.get("REPRO_BENCH_JSON", "BENCH_opt.json")
ALGOS = ("Gen-C", "Gen-E", "Gen-D", "Gen-O")
C_GRID = (0.2, 0.25, 0.3, 0.4, 0.6)


def _enable_compilation_cache():
    """Persistent XLA compilation cache: one compile per structure signature
    per *machine*, not per process (cold numbers below still report the
    first in-process call, which may be served from this cache)."""
    import jax

    path = os.environ.get("JAX_COMPILATION_CACHE_DIR",
                          os.path.join(RESULTS, "xla_cache"))
    os.makedirs(path, exist_ok=True)
    jax.config.update("jax_compilation_cache_dir", path)
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 0)
    return path


def _scenarios(sys_, consts, algos, c_grid):
    scns, names = [], []
    for cmax in c_grid:
        for name in algos:
            scn, _ = make_scenario(name, sys_, consts, T_max=1e5, C_max=cmax)
            scns.append(scn), names.append(name)
    return scns, names


def _fig5(sys_, consts, algos, c_grid):
    from repro.api import sweep_scenarios

    scns, names = _scenarios(sys_, consts, algos, c_grid)
    n = len(scns)

    t0 = time.time()
    seq_plans = [s.optimize() for s in scns]
    t_seq = time.time() - t0

    modes = [("sequential", t_seq, 0.0, None)]
    for backend in ("jnp", "jnp-fused"):
        t0 = time.time()
        sweep_scenarios(scns, names=names, backend=backend)
        t_cold = time.time() - t0
        t0 = time.time()
        rep = sweep_scenarios(scns, names=names, backend=backend)
        t_warm = time.time() - t0
        modes.append((backend, t_warm, max(0.0, t_cold - t_warm), rep))

    # parity sanity on the fly — report, don't abort: cross-backend float
    # divergence can legally move an integer by one on knife-edge points
    # (the test suite owns the strict parity assertions)
    rep = modes[-1][3]
    mismatch = sum(
        p.feasible != row["feasible"]
        or abs(p.predicted_E - row["E"]) > 1e-3 * max(abs(p.predicted_E), 1)
        for p, row in zip(seq_plans, rep.rows))
    if mismatch:
        print(f"  WARNING: {mismatch}/{n} points differ between sequential "
              f"and fused beyond 0.1% — inspect before trusting timings")

    rows = []
    for mode, t_warm, compile_s, _ in modes:
        rows.append({
            "grid_points": n, "mode": mode, "wall_s": round(t_warm, 4),
            "solves_per_s": round(n / t_warm, 3),
            "speedup_vs_seq": round(t_seq / t_warm, 2),
            "compile_s": round(compile_s, 2),
        })
        print(f"  {mode:14s} {t_warm:8.2f}s {n / t_warm:8.3f} solves/s "
              f"speedup {t_seq / t_warm:5.2f}x (compile {compile_s:.1f}s)")
    return rows


def _sweep1024(sys_, consts, n_cmax, n_gamma):
    """One-signature sweep at 1e3+-point scale: C_max x constant-rule gamma.

    Sequential rate is measured on an evenly-spaced subsample (a full scalar
    pass would take minutes and adds no information — the per-point cost is
    flat across the grid).
    """
    import dataclasses

    from repro.api import ConstantRule
    from repro.api.sweep import sweep_scenarios
    from repro.opt import RefreshPlan
    from repro.opt import gia_jax

    base, _ = make_scenario("Gen-C", sys_, consts, T_max=1e5, C_max=0.25)
    scns = [dataclasses.replace(base, C_max=float(c),
                                step=ConstantRule(float(g)))
            for c in np.linspace(0.2, 0.6, n_cmax)
            for g in np.geomspace(0.004, 0.02, n_gamma)]
    n = len(scns)
    key = RefreshPlan.build([scns[0].problem()]).signature_key
    base = gia_jax.trace_count(key)

    t0 = time.time()
    sweep_scenarios(scns, backend="jnp-fused", parallel=False)
    t_cold = time.time() - t0
    traces_cold = gia_jax.trace_count(key) - base
    t0 = time.time()
    rep = sweep_scenarios(scns, backend="jnp-fused", parallel=False)
    t_warm = time.time() - t0
    compiles = gia_jax.trace_count(key) - base

    sub = scns[:: max(1, n // 16)]
    t0 = time.time()
    for s in sub:
        s.optimize()
    seq_per_pt = (time.time() - t0) / len(sub)

    feasible = sum(r["feasible"] for r in rep.rows)
    out = {
        "points": n, "signatures": rep.n_groups,
        "compiles": int(compiles), "cold_s": round(t_cold, 2),
        "warm_s": round(t_warm, 2),
        "warm_solves_per_s": round(n / t_warm, 2),
        "sequential_s_per_point": round(seq_per_pt, 4),
        "sequential_points_sampled": len(sub),
        "speedup_vs_seq": round(seq_per_pt * n / t_warm, 2),
        "feasible_points": int(feasible),
    }
    print(f"  sweep{n}: warm {t_warm:.2f}s ({n / t_warm:.1f} solves/s), "
          f"{out['speedup_vs_seq']}x vs sequential "
          f"({seq_per_pt * 1e3:.0f} ms/pt on {len(sub)}-pt subsample), "
          f"{compiles} compile(s) across both passes "
          f"({traces_cold} cold)")
    return out


def run(tag="opt_bench", smoke=False):
    cache_dir = _enable_compilation_cache()
    consts = get_constants()
    sys_ = paper_system()
    algos = ("Gen-C", "Gen-O") if smoke else ALGOS
    c_grid = C_GRID[:2] if smoke else C_GRID
    if smoke:
        tag = f"{tag}_smoke"       # don't clobber the full-grid artifact
    t_all = time.time()
    rows = _fig5(sys_, consts, algos, c_grid)
    sweep = _sweep1024(sys_, consts, *( (8, 8) if smoke else (32, 32) ))
    path = write_csv(f"{RESULTS}/benchmarks/{tag}.csv", rows,
                     ["grid_points", "mode", "wall_s", "solves_per_s",
                      "speedup_vs_seq", "compile_s"])

    write_bench(BENCH_JSON, "opt", {
        "fig5_grid": {"grid_points": rows[0]["grid_points"],
                      "backends": rows},
        "sweep": sweep,
        "compilation_cache_dir": cache_dir,
    }, smoke=smoke)
    fused = rows[-1]
    return {"rows": len(rows), "csv": path, "json": BENCH_JSON,
            "derived": f"{fused['speedup_vs_seq']}x_fig5_"
                       f"{sweep['speedup_vs_seq']}x_sweep",
            "dt": time.time() - t_all}


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="4-point grid + 64-point sweep for CI smoke runs")
    args = ap.parse_args()
    print(run(smoke=args.smoke))
