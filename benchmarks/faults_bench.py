"""Deadline-HT aggregation vs blocking sync under stragglers.

One straggler-prone fleet (every worker independently 4x slower with
probability 0.3), two aggregation disciplines over the *same* seeded
fault draws:

  blocking   ``deadline_slack=inf`` — every round waits for its slowest
             attempted worker (the historical synchronous semantics);
  deadline   ``deadline_slack=1.5`` — the round is cut at 1.5x the
             Plan's predicted round time, late workers are excluded and
             the survivors reweighted with unbiased Horvitz-Thompson
             weights (``repro.faults``).

The fault model is straggler-only, so it leaves the GP untouched — both
scenarios freeze the *identical* decision variables ``(K0, Kn, B)`` and
run the identical round count: convergence budgets are matched by
construction, and the seeded runs verify the realized task error agrees
to a few percent (the HT estimator is unbiased; its variance inflation
is the price of not waiting).  Wall-clock round time comes from the
runs' ``FaultTrace`` (realized ``min(tau, blocking)`` per round).

Hard assertions (the ISSUE-9 acceptance bar):

  * deadline-HT realized wall-clock is **strictly lower** than blocking
    sync over the same draws;
  * the deadline run's final error stays within ``ERR_TOL`` of the
    blocking run's (fixed convergence error);
  * the two frozen plans are identical (matched convergence budgets).

Results land in ``BENCH_faults.json`` at the repo root.

    PYTHONPATH=src python -m benchmarks.faults_bench           # full
    PYTHONPATH=src python -m benchmarks.faults_bench --smoke   # CI smoke
"""
from __future__ import annotations

import argparse
import os
import time

from repro.api import (ConstantRule, EdgeSystem, MLProblemConstants,
                       QuadraticTask, Scenario, edge_faults)
from repro.obs.bench import write_bench

from .opt_bench import _enable_compilation_cache

BENCH_JSON = os.environ.get("REPRO_BENCH_JSON", "BENCH_faults.json")

N = 4
CONSTS = MLProblemConstants(L=0.084, sigma=33.18, G=33.63, f_gap=2.3, N=N)

STRAGGLER = dict(straggler_prob=0.3, straggler_factor=4.0)
SLACK = 1.5
SEED = 0
FULL_ROUNDS = 300
SMOKE_ROUNDS = 60
#: allowed relative degradation of the deadline run's final error vs the
#: blocking run's — the unbiased HT estimator's variance price (the 300
#: round run plateaus at a ~10% noise-floor gap for a ~2.2x time win)
ERR_TOL = 0.15


def _scenario(slack: float) -> Scenario:
    return Scenario(system=EdgeSystem.paper_sec_vii(dim=1024, N=N),
                    consts=CONSTS, T_max=1e6, C_max=1.0,
                    step=ConstantRule(0.01),
                    faults=edge_faults(deadline_slack=slack, **STRAGGLER))


def run(smoke: bool) -> dict:
    cache_dir = _enable_compilation_cache()
    rounds = SMOKE_ROUNDS if smoke else FULL_ROUNDS
    task = QuadraticTask(dim=16, per_worker=64, noise=0.01, seed=0)

    t0 = time.time()
    scn_b = _scenario(float("inf"))
    scn_d = _scenario(SLACK)
    plan_b, plan_d = scn_b.optimize(), scn_d.optimize()
    # straggler-only faults leave the GP untouched: both disciplines run
    # the identical frozen decisions, so convergence budgets are matched
    assert (plan_b.K0, plan_b.Kn, plan_b.B) == \
        (plan_d.K0, plan_d.Kn, plan_d.B), (plan_b, plan_d)

    rep_b = scn_b.run(plan_b, task=task, seed=SEED, max_rounds=rounds)
    rep_d = scn_d.run(plan_d, task=task, seed=SEED, max_rounds=rounds)
    tr_b, tr_d = rep_b.fault_trace, rep_d.fault_trace
    rounds = rep_d.rounds              # executed = min(requested, plan K0)
    assert rep_b.rounds == rounds and len(tr_d) == rounds
    wall = time.time() - t0

    # same seed => the two runs realize the SAME straggler draws; the
    # disciplines differ only in what they wait for
    assert [r.straggled for r in tr_b.records] == \
        [r.straggled for r in tr_d.records]
    err_b = float(rep_b.final_metrics["err"])
    err_d = float(rep_d.final_metrics["err"])
    t_round_b = tr_b.realized_time / rounds
    t_round_d = tr_d.realized_time / rounds

    # THE acceptance bar: strictly lower wall-clock at matched error
    assert tr_d.realized_time < tr_b.realized_time, (tr_d.realized_time,
                                                     tr_b.realized_time)
    assert err_d <= err_b * (1.0 + ERR_TOL), (err_d, err_b)
    assert tr_b.workers_dropped == 0          # blocking never drops anyone
    assert tr_d.workers_dropped > 0           # the deadline actually bites

    speedup = tr_b.realized_time / tr_d.realized_time
    print(f"  blocking: {t_round_b:.4g} s/round, err={err_b:.5g}")
    print(f"  deadline: {t_round_d:.4g} s/round, err={err_d:.5g} "
          f"({tr_d.workers_dropped} worker-rounds dropped, "
          f"{tr_d.rounds_degraded}/{rounds} rounds degraded)")
    print(f"  speedup: {speedup:.2f}x wall-clock at matched convergence")

    bench = write_bench(BENCH_JSON, "faults", {
        "regime": f"paper_sec_vii N={N}, straggler_prob=0.3 factor=4.0, "
                  f"slack={SLACK} vs blocking, gamma=0.01, seed={SEED}",
        "rounds": rounds,
        "plan": {"K0": plan_d.K0, "Kn": list(plan_d.Kn), "B": plan_d.B,
                 "deadline_s": plan_d.faults.deadline},
        "blocking": {"round_s": round(t_round_b, 6), "err": err_b,
                     "total_s": round(tr_b.realized_time, 4)},
        "deadline": {"round_s": round(t_round_d, 6), "err": err_d,
                     "total_s": round(tr_d.realized_time, 4),
                     "worker_rounds_dropped": tr_d.workers_dropped,
                     "rounds_degraded": tr_d.rounds_degraded},
        "speedup_x": round(speedup, 3),
        "err_ratio": round(err_d / err_b, 4),
        "wall_s": round(wall, 2),
        "xla_cache": cache_dir,
    }, smoke=smoke)
    print(f"wrote {BENCH_JSON} ({speedup:.2f}x speedup, "
          f"err ratio {bench['err_ratio']}, {wall:.1f}s)")
    return bench


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="CI-sized run")
    run(ap.parse_args().smoke)
