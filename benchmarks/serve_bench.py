"""PlanServer throughput/latency under an open-loop Poisson request trace.

The serving-layer benchmark: a seeded synthetic trace of heterogeneous
``Scenario.optimize`` requests (several structure signatures; early
requests are unique budgets = cold solves, later ones revisit a hot set —
exact repeats land in the plan cache, 0.2%-jittered near-duplicates
warm-start) is submitted open-loop at Poisson arrivals to a
:class:`repro.serve.PlanServer`.  Measured per source class (hit / warm /
cold): request latency p50/p99/mean; end-to-end solves/sec over the whole
trace; cache hit-rate; fused traces per signature.

Hard assertions (the serving contract, not just numbers to eyeball):

  * **<= 1 fused trace/compile per distinct signature** across the whole
    trace — micro-batches are padded to ``max_batch`` rows, so every
    dispatch of a signature reuses one executable (both modes);
  * warm cache-hit solves **>= 3x lower mean latency than cold** in the
    same trace, and end-to-end solves/sec **>= the PR-4 fig5 warm fused
    baseline** (11.9 solves/s) — full mode only; the smoke trace is too
    small to make the ratios meaningful, so it records them instead.

Results land in ``BENCH_serve.json`` at the repo root.

    PYTHONPATH=src python -m benchmarks.serve_bench           # full trace
    PYTHONPATH=src python -m benchmarks.serve_bench --smoke   # CI smoke
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import tempfile
import time

import numpy as np

from repro.obs.bench import write_bench
from repro.serve import PlanServer

from .common import get_constants, make_scenario, paper_system

BENCH_JSON = os.environ.get("REPRO_BENCH_JSON", "BENCH_serve.json")

#: PR-4 fig5 warm fused throughput (solves/s) — the bar the serving layer
#: must clear end-to-end, admission queueing and cache lookups included.
BASELINE_SOLVES_S = 11.9

FULL = dict(algos=("Gen-C", "Gen-E", "Gen-D", "Gen-O"), n_unique=8,
            n_total=480, rate_per_s=400.0, max_batch=16, window_s=0.02)
SMOKE = dict(algos=("Gen-C", "Gen-O"), n_unique=3, n_total=24,
             rate_per_s=400.0, max_batch=8, window_s=0.02)


def build_trace(rng, sys_, consts, algos, n_unique, n_total):
    """Seeded two-phase request trace: ``(populate, tail)``.

    The populate phase is every unique (algo, budget) scenario — all cold.
    The tail re-asks an earlier scenario verbatim (exact fingerprint ->
    cache hit) or with the budget jittered by ~0.2% (near-duplicate ->
    warm-started solve), 50/50.  The phases are submitted with a barrier
    between them: open-loop *within* each phase, but the tail only starts
    once the populate solves have landed in the cache — otherwise a fast
    trace outruns its own cache and every repeat is reclassified cold.
    """
    pool = []
    for algo in algos:
        for c in np.linspace(0.22, 0.45, n_unique):
            scn, _ = make_scenario(algo, sys_, consts, T_max=1e5,
                                   C_max=float(c))
            pool.append(scn)
    rng.shuffle(pool)
    tail = []
    while len(pool) + len(tail) < n_total:
        base = pool[rng.integers(len(pool))]
        if rng.random() < 0.5:
            tail.append(base)                        # exact repeat: hit
        else:
            jitter = 1.0 + rng.uniform(-2e-3, 2e-3)
            tail.append(dataclasses.replace(         # near-duplicate: warm
                base, C_max=base.C_max * jitter))
    return pool, tail


def _ms(summary):
    """Millisecond view of a ``PlanServer.stats()['latency_s']`` summary."""
    if not summary or not summary.get("count"):
        return {"count": 0}
    return {"count": summary["count"],
            "mean_ms": round(summary["mean"] * 1e3, 3),
            "p50_ms": round(summary["p50"] * 1e3, 3),
            "p99_ms": round(summary["p99"] * 1e3, 3)}


def _isolated_compilation_cache():
    """Per-run XLA cache in a fresh temp dir — *not* the machine-shared
    cache the other benchmarks use.  The warm-vs-cold latency ratio is a
    statement about first-ever solves of a signature; against a shared
    persistent cache "cold" quietly stops including compilation as soon
    as any earlier run has seen the signature, and the ratio measures
    cache luck instead of the serving contract."""
    import jax

    path = tempfile.mkdtemp(prefix="serve_bench_xla_")
    jax.config.update("jax_compilation_cache_dir", path)
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 0)
    return path


def run(smoke=False, seed=0):
    cfg = SMOKE if smoke else FULL
    _isolated_compilation_cache()
    rng = np.random.default_rng(seed)
    consts = get_constants()
    sys_ = paper_system()
    populate, tail = build_trace(rng, sys_, consts, cfg["algos"],
                                 cfg["n_unique"], cfg["n_total"])
    n = len(populate) + len(tail)
    gaps = rng.exponential(1.0 / cfg["rate_per_s"], size=n)

    with PlanServer(max_batch=cfg["max_batch"],
                    window_s=cfg["window_s"]) as srv:
        handles = []
        t0 = time.perf_counter()
        for phase in (populate, tail):               # open-loop within each
            for scn in phase:                        # phase, barrier between
                time.sleep(gaps[len(handles)])
                handles.append(srv.submit(scn))
            for h in handles:
                h.result(timeout=600)
        wall = time.perf_counter() - t0
        stats = srv.stats()
        compiles = {"/".join(map(str, sig)): c
                    for sig, c in srv.compile_counts().items()}

    # per-source latency now lives in the server itself (repro.obs registry
    # view); the bench just reshapes seconds -> ms for the artifact
    lat = {s: _ms(stats["latency_s"].get(s))
           for s in ("hit", "warm", "cold", "all")}
    solves_per_s = len(handles) / wall
    ratio = (lat["cold"]["mean_ms"] / lat["warm"]["mean_ms"]
             if lat["warm"]["count"] and lat["cold"]["count"] else None)

    assert all(c <= 1 for c in compiles.values()), \
        f"fused engine re-traced a signature: {compiles}"
    if not smoke:
        assert ratio is not None and ratio >= 3.0, \
            f"warm mean latency only {ratio:.2f}x better than cold"
        assert solves_per_s >= BASELINE_SOLVES_S, \
            f"{solves_per_s:.1f} solves/s < fig5 warm fused baseline " \
            f"({BASELINE_SOLVES_S})"

    payload = {
        "trace": {"requests": len(handles), "seed": seed,
                  "rate_per_s": cfg["rate_per_s"],
                  "signatures": stats["signatures"],
                  "algos": list(cfg["algos"]),
                  "max_batch": cfg["max_batch"],
                  "window_s": cfg["window_s"]},
        "latency_ms": lat,
        "queue_wait_s": stats["queue_wait_s"],
        "solves_per_s": round(solves_per_s, 2),
        "baseline_fig5_warm_fused_solves_per_s": BASELINE_SOLVES_S,
        "warm_vs_cold_latency_ratio": round(ratio, 2) if ratio else None,
        "hit_rate": round(stats["hit_rate"], 4),
        "sources": {s: lat[s]["count"] for s in ("hit", "warm", "cold")},
        "mean_batch": round(stats["mean_batch"], 2),
        "batches": stats["batches"],
        "compiles_per_signature": compiles,
    }
    write_bench(BENCH_JSON, "serve", payload, smoke=smoke)
    print(f"  {len(handles)} requests in {wall:.2f}s "
          f"({solves_per_s:.1f} solves/s, hit rate "
          f"{stats['hit_rate']:.0%}); mean latency "
          f"cold {lat['cold'].get('mean_ms', 0):.0f}ms / warm "
          f"{lat['warm'].get('mean_ms', 0):.0f}ms / hit "
          f"{lat['hit'].get('mean_ms', 0):.2f}ms"
          + (f"; warm {ratio:.1f}x faster than cold" if ratio else "")
          + f"; {sum(compiles.values())} compiles "
            f"over {stats['signatures']} signatures")
    return {"json": BENCH_JSON, "solves_per_s": round(solves_per_s, 2),
            "hit_rate": round(stats["hit_rate"], 3), "wall_s": round(wall, 2)}


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="24-request 2-signature trace for CI smoke runs")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    print(run(smoke=args.smoke, seed=args.seed))
