"""Fig. 3: training loss / test accuracy of optimization-based GenQSGD
(Gen-C/E/D/O) vs global iteration, at C_max=0.25, T_max=1e5.

Optimizes each scenario and executes the resulting Plan on the REAL GenQSGD
(Algorithm 1) via ``Scenario.run`` — entirely through the repro.api facade.
"""
from __future__ import annotations

import time

from .common import (RESULTS, get_constants, get_task, make_scenario,
                     paper_system, write_csv)

MAX_K0 = 1200  # cap on executed global iterations (curves flatten well before)


def run(tag="fig3"):
    consts = get_constants()
    sys_ = paper_system()
    task = get_task()
    rows = []
    t0 = time.time()
    for name in ("Gen-C", "Gen-E", "Gen-D", "Gen-O"):
        scn, _ = make_scenario(name, sys_, consts, T_max=1e5, C_max=0.25)
        plan = scn.optimize()
        rep = scn.run(plan, task=task, max_rounds=MAX_K0, eval_every=25)
        for h in rep.history:
            rows.append({"algo": name, **h})
        print(f"  {name}: K0={plan.K0} Kn={plan.Kn[0]} B={plan.B} "
              f"final acc={rep.final_metrics['test_acc']:.3f}", flush=True)
    path = write_csv(f"{RESULTS}/benchmarks/{tag}.csv", rows,
                     ["algo", "k0", "eval_loss", "test_acc", "delta_norm",
                      "update_norm"])
    return {"rows": len(rows), "csv": path,
            "derived": rows[-1]["test_acc"], "dt": time.time() - t0}


if __name__ == "__main__":
    print(run())
