"""Fig. 3: training loss / test accuracy of optimization-based GenQSGD
(Gen-C/E/D/O) vs global iteration, at C_max=0.25, T_max=1e5.

Runs the REAL GenQSGD (Algorithm 1) on the synthetic MNIST-like task with the
(K, B, Γ) produced by Algorithms 2-5.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ConstantRule, GenQSGD, GenQSGDConfig, make_rule
from repro.data.federated import partition_iid, sample_minibatch
from repro.data.synthetic import mnist_like
from repro.models import mlp

from .common import (GAMMAS, RESULTS, get_constants, paper_system,
                     run_algorithm, write_csv)

MAX_K0 = 1200  # cap on executed global iterations (curves flatten well before)


def _train(params_rec, X, y, Xte, yte, s0, sn, eval_every=25, max_k0=MAX_K0):
    N = 10
    Xw, yw = partition_iid(X, y, N)
    data = (jnp.stack([jnp.asarray(x) for x in Xw]),
            jnp.stack([jnp.asarray(v) for v in yw]))
    K0 = min(int(params_rec["K0"]), max_k0)
    rule_name = params_rec.get("rule", "C")
    if params_rec["name"] == "Gen-O":
        rule = ConstantRule(float(params_rec["gamma"]))
    else:
        m = params_rec["name"].split("-")[1]
        rule = make_rule(m, **GAMMAS[m])
    cfg = GenQSGDConfig(K0=K0, Kn=(int(params_rec["Kn"]),) * N,
                        B=int(params_rec["B"]), step_rule=rule,
                        s0=s0, sn=[sn] * N)
    alg = GenQSGD(mlp.loss, sample_minibatch, cfg)
    p0 = mlp.init_params(jax.random.PRNGKey(1))
    Xte_j, yte_j = jnp.asarray(Xte), jnp.asarray(yte)

    def eval_fn(p):
        return {"train_loss": float(mlp.loss(p, (Xte_j[:2048], yte_j[:2048]))),
                "test_acc": mlp.accuracy(p, Xte_j, yte_j)}

    _, hist = alg.run(p0, data, jax.random.PRNGKey(2), eval_fn=eval_fn,
                      eval_every=eval_every)
    return hist


def run(tag="fig3"):
    consts = get_constants()
    sys_ = paper_system()
    X, y = mnist_like()
    Xtr, ytr, Xte, yte = X[:50000], y[:50000], X[50000:], y[50000:]
    rows = []
    t0 = time.time()
    for name in ("Gen-C", "Gen-E", "Gen-D", "Gen-O"):
        rec = run_algorithm(name, sys_, consts, T_max=1e5, C_max=0.25)
        hist = _train(rec, Xtr, ytr, Xte, yte, s0=sys_.s0, sn=sys_.sn[0])
        for h in hist:
            rows.append({"algo": name, **h})
        print(f"  {name}: K0={rec['K0']} Kn={rec['Kn']} B={rec['B']} "
              f"final acc={hist[-1]['test_acc']:.3f}", flush=True)
    path = write_csv(f"{RESULTS}/benchmarks/{tag}.csv", rows,
                     ["algo", "k0", "train_loss", "test_acc", "delta_norm",
                      "update_norm"])
    return {"rows": len(rows), "csv": path,
            "derived": rows[-1]["test_acc"], "dt": time.time() - t0}


if __name__ == "__main__":
    print(run())
