#!/usr/bin/env bash
# One-step verify recipe: tier-1 test suite + a fast kernel-bench smoke run.
#
#   ./scripts/check.sh                             # everything
#   SKIP_BENCH=1 ./scripts/check.sh
#   PYTEST_ARGS='-m "not slow"' ./scripts/check.sh # fast (blocking-CI) subset
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1: pytest ${PYTEST_ARGS:-} =="
eval python -m pytest -x -q ${PYTEST_ARGS:-}

if [ -z "${SKIP_BENCH:-}" ]; then
  echo "== kernel_bench --smoke =="
  python -m benchmarks.kernel_bench --smoke
fi

echo "== check.sh OK =="
