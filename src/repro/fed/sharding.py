"""PartitionSpec rules: map every param / cache / batch leaf to mesh axes.

Logical axes (see ``repro.launch.mesh.logical_mesh``):
  fl   — federated-worker replicas (GenQSGD aggregation axis)
  fsdp — intra-worker parameter & batch sharding
  tp   — tensor parallelism

Param rules are name-based on the trailing dimensions (stacked layer leading
dims are padded with None), with divisibility checks: an axis is only used if
it divides the dimension — otherwise that dim is replicated (keeps e.g. 4-KV-
head caches legal on a 16-way tp axis).
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from ..configs.base import ArchConfig

__all__ = ["param_specs", "param_shardings", "batch_specs", "cache_specs",
           "with_fl", "shardings"]


# name -> spec of the TRAILING dims (None-padded on the left to leaf ndim)
_PARAM_RULES = {
    # embeddings / head: vocab replicated, d_model sharded over tp ONLY —
    # the token gather is then cleanly partitionable (offset-dim pass-through)
    # and its backward scatter produces a (V, D/tp) shard, not a replicated
    # full f32 embedding gradient (measured: 7.8 GiB/device at llama3-405b
    # with fsdp in the mix).  The tied LM head becomes row-parallel (psum
    # over tp).
    "embed": (None, "tp"),
    "lm_head": ("tp", None),
    # attention
    "wq": ("fsdp", "tp"),
    "wk": ("fsdp", "tp"),
    "wv": ("fsdp", "tp"),
    "wo": ("tp", "fsdp"),
    # dense mlp
    "w_gate": ("fsdp", "tp"),
    "w_up": ("fsdp", "tp"),
    "w_down": ("tp", "fsdp"),
    # moe (expert-major leaves, matched under a "moe" parent)
    "moe/router": ("fsdp", None),
    "moe/w_gate": ("tp", "fsdp", None),
    "moe/w_up": ("tp", "fsdp", None),
    "moe/w_down": ("tp", None, "fsdp"),
    # mamba2
    "in_proj": ("fsdp", "tp"),
    "out_proj": ("tp", "fsdp"),
    # xlstm
    "w_if": ("fsdp", None),
    "w_gates": ("fsdp", "tp"),
    "r_gates": (None, None, None),
    "ff_up": ("fsdp", "tp"),
    "ff_down": ("tp", "fsdp"),
}


def _path_names(path) -> list:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "idx"):
            out.append(str(p.idx))
    return out


def _axis_ok(mesh_sizes: dict, axis, dim: int):
    """axis may be a name or a tuple of names (sharded over the product).
    Falls back to progressively shorter prefixes when sizes don't divide."""
    if axis is None:
        return None
    if isinstance(axis, tuple):
        for k in range(len(axis), 0, -1):
            sub = axis[:k]
            size = int(np.prod([mesh_sizes.get(a, 1) for a in sub]))
            if size > 1 and dim % size == 0:
                return sub if len(sub) > 1 else sub[0]
        return None
    size = mesh_sizes.get(axis, 1)
    return axis if (size > 1 and dim % size == 0) else None


def _spec_for_leaf(names: list, leaf, mesh_sizes: dict, rules=None) -> P:
    rules = rules or _PARAM_RULES
    shape = leaf.shape
    if len(shape) == 0:
        return P()
    name = names[-1] if names else ""
    parent = names[-2] if len(names) >= 2 else ""
    rule = rules.get(f"{parent}/{name}") or rules.get(name)
    if rule is None:
        return P(*([None] * len(shape)))
    k = len(rule)
    if len(shape) < k:   # e.g. biases picked up by a 2D rule
        return P(*([None] * len(shape)))
    pad = len(shape) - k
    spec = [None] * pad + [_axis_ok(mesh_sizes, ax, shape[pad + i])
                           for i, ax in enumerate(rule)]
    return P(*spec)


def param_specs(params, mesh: Mesh, fsdp_weights: bool = True,
                moe_tp_only: bool = False):
    """PartitionSpec pytree for a param pytree (no fl axis — one replica).

    fsdp_weights=False drops the 'fsdp' axis from weight rules (pure tensor
    parallelism).  Small models (<~20B params) fit comfortably when sharded
    over tp alone, and contraction-dim fsdp sharding makes the partitioner
    emit partial-sum all-reduces of full activations (measured 8 GiB each at
    xlstm prefill_32k); giants keep FSDP.
    """
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    if not fsdp_weights:
        sizes = {**sizes, "fsdp": 1}
    rules = _PARAM_RULES
    if moe_tp_only:
        # §Perf (phi3.5-moe): shard the EXPERT dim over (tp, fsdp) jointly —
        # no contraction-dim sharding (kills the fsdp partial-k all-reduces,
        # bound 24.2s -> 13.6s at train_4k) while params stay fully sharded
        # (pure tp-only replication measured 59.8 GiB/device temps).
        rules = {**rules, "moe/w_gate": ("tp", None, "fsdp"),
                 "moe/w_up": ("tp", None, "fsdp"),
                 "moe/w_down": ("tp", "fsdp", None)}
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _spec_for_leaf(_path_names(path), leaf, sizes,
                                          rules),
        params)


def with_fl(spec_tree):
    """Prefix every spec with an 'fl' leading axis (per-worker replicas)."""
    return jax.tree.map(
        lambda s: P("fl", *s) if isinstance(s, P) else s, spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def param_shardings(params, mesh: Mesh, fl: bool = False):
    specs = param_specs(params, mesh)
    if fl:
        specs = with_fl(specs)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# batches
# ---------------------------------------------------------------------------
def batch_specs(batch, mesh: Mesh, kind: str):
    """kind: 'fl_train' (leading (fl, steps, batch, ...) dims) or 'serve'.

    fl_train leaves: (fl, K_steps, B_local, ...) -> P('fl', None, 'fsdp', ...)
    serve leaves:    (B, ...)                    -> P(('fl','fsdp'), ...) when
    the batch divides, else replicated batch (long_500k's B=1).
    """
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def spec(path, leaf):
        names = _path_names(path)
        nd = len(leaf.shape)
        if kind == "fl_train":
            if names and names[-1] == "positions3":  # (fl,K,3,B,S)
                rest = [None] * (nd - 4)
                return P("fl", None, None,
                         _axis_ok(sizes, "fsdp", leaf.shape[3]), *rest)
            rest = [None] * (nd - 3)
            return P("fl", None, _axis_ok(sizes, "fsdp", leaf.shape[2]), *rest)
        # serve
        if names and names[-1] == "positions3":      # (3,B,S)
            bdim = leaf.shape[1]
            ax = _batch_axes(sizes, bdim)
            return P(None, ax, *([None] * (nd - 2)))
        bdim = leaf.shape[0]
        ax = _batch_axes(sizes, bdim)
        return P(ax, *([None] * (nd - 1)))

    return jax.tree_util.tree_map_with_path(spec, batch)


def _batch_axes(sizes, bdim):
    """Largest prefix of ('fl','fsdp') that divides the batch dim."""
    both = sizes.get("fl", 1) * sizes.get("fsdp", 1)
    if bdim % both == 0 and both > 1:
        return ("fl", "fsdp")
    if bdim % sizes.get("fl", 1) == 0 and sizes.get("fl", 1) > 1:
        return ("fl",)
    return None


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------
def cache_specs(caches, mesh: Mesh, cfg: ArchConfig, batch: int):
    """Decode-cache shardings.

    KV leaves are (count, B, C, KV, dh).  Batched decode shards B over
    (fl, fsdp) and KV heads over tp.  For B too small to shard (long_500k),
    the *sequence* dim C is sharded over (fl, fsdp) instead — attention's
    softmax reduction over C is then partitioned by GSPMD (distributed
    flash-decode), the memory win that makes a 512k cache fit.
    SSM/xLSTM state leaves are (count, B, ...heads/dims...): batch over
    (fl, fsdp) when possible, feature dims over tp when divisible.
    """
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    batch_ax = _batch_axes(sizes, batch)

    def spec(path, leaf):
        names = _path_names(path)
        nd = len(leaf.shape)
        name = names[-1] if names else ""
        if name in ("k", "v"):             # (count, B, C, KV, dh)
            _, Bd, Cd, KVd, _ = leaf.shape[-5:] if nd >= 5 else (1,) + leaf.shape
            kv_ax = _axis_ok(sizes, "tp", KVd)
            if batch_ax is not None:
                return P(None, batch_ax, None, kv_ax, None)
            seq_ax = ("fl", "fsdp") if Cd % (sizes.get("fl", 1) * sizes.get("fsdp", 1)) == 0 else None
            return P(None, None, seq_ax, kv_ax, None)
        if name == "pos":                  # (count, B, C)
            if batch_ax is not None:
                return P(None, batch_ax, None)
            Cd = leaf.shape[-1]
            seq_ax = ("fl", "fsdp") if Cd % (sizes.get("fl", 1) * sizes.get("fsdp", 1)) == 0 else None
            return P(None, None, seq_ax)
        if name == "idx":
            return P(*([None] * nd))
        if name == "enc":                  # whisper encoder states (B, F, D)
            return P(_batch_axes(sizes, leaf.shape[0]), None, None)
        # SSM / xLSTM states: (count, B, ...) — shard batch; try tp on the
        # largest trailing dim.
        spec_dims = [None] * nd
        if nd >= 2:
            spec_dims[1] = batch_ax
        if nd >= 3:
            # shard the largest remaining dim over tp if divisible
            trail = list(range(2, nd))
            best = max(trail, key=lambda i: leaf.shape[i])
            spec_dims[best] = _axis_ok(sizes, "tp", leaf.shape[best])
        return P(*spec_dims)

    return jax.tree_util.tree_map_with_path(spec, caches)


def shardings(tree_specs, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_specs,
                        is_leaf=lambda x: isinstance(x, P))
