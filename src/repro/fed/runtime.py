"""Distributed GenQSGD runtime: Algorithm 1 mapped onto the (fl, fsdp, tp) mesh.

One *round* = the body of Algorithm 1's global iteration:
  1. every fl worker group starts from the shared global model x̂,
  2. runs K_max local mini-batch SGD steps (workers with K_n < K_max do the
     paper's "virtual" masked updates, eqs. (6)-(8)),
  3. encodes its normalized model delta (x_n - x̂)/γ per tensor with its
     codec (Assumption 1 holds per tensor, hence for the concatenation with
     q = max_t q_t) — or per bucket of ``FedConfig.bucket`` coordinates
     (QSGD bucketing, matching what ``EdgeSystem(q_dim=...)`` prices),
  4. aggregation: the server mean of quantized deltas (5), re-quantized with
     the server codec and applied by every node (3).

The runtime splits the communication concern along the codec/transport axis
of :mod:`repro.compress`:

  * the *codec* (what is sent) is QSGD with per-worker ``s_n`` — possibly
    heterogeneous — or the identity (``s=None``), evaluated through the
    package's single level implementation (``compress.encode_tensor`` /
    ``decode_tensor``, traced-``s`` capable so heterogeneous workers
    vectorize through vmap);
  * the *transport* (how it travels) is ``FedConfig.wire``, one of
    ``compress.RUNTIME_WIRES``:

    wire="f32"   — paper-faithful math: quantized *values* travel as f32
                   (mean over fl => an XLA all-reduce of f32).
    wire="int8"  — QSGD levels travel as int8 via an explicit all-gather
                   inside shard_map; dequantize + average locally.  4x fewer
                   collective bytes on the fl (cross-pod) axis; bit-identical
                   results to "f32" (levels are exact integers in both).
    wire="int4"  — two levels packed per byte (``compress.pack_int4``) before
                   the all-gather: 8x fewer bytes than f32, 2x fewer than
                   int8, for the paper's low-s regime (s_n <= 7).  Packing is
                   lossless, so results stay bit-identical to "f32".
    wire="rs_ag" — reduce-scatter + all-gather decomposition of the f32 mean
                   (each fl member owns 1/fl of the delta): ~2x fewer wire
                   bytes than a ring all-reduce of the same payload, exact
                   f32 math.
    wire="elias" — QSGD levels Elias-omega gap-coded per worker
                   (:mod:`repro.compress.elias`, the paper's tighter M_s
                   bound).  Variable-length streams cannot ride SPMD
                   collectives, so this is a *reference* transport like
                   "f32": each worker's levels round-trip through the real
                   coder outside the shard_map, the realized stream
                   lengths land in ``metrics["elias_bits"]``, and the
                   aggregation math stays bit-identical to "f32" (the
                   coder is lossless on levels).

  The cost layer (:class:`repro.core.cost.EdgeSystem`) prices ``M_s`` through
  the same ``codec.wire_bits`` table, so the (K, B, s) the optimizer picks
  refer to exactly the bytes these transports move.

Local steps are vmapped over an explicit leading fl axis sharded P('fl', ...)
— GSPMD keeps each worker group's replica resident on its own (fsdp, tp)
sub-grid and the ONLY fl-axis traffic is the aggregation, exactly the paper's
communication pattern.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from ..compat import shard_map
from ..compress import (RUNTIME_WIRES, decode_tensor, elias, encode_tensor,
                        make_codec, pack_int4, unpack_int4, wire_max_s)
from ..configs.base import ArchConfig
from . import sharding as SH

__all__ = ["FedConfig", "make_round_fn"]


@dataclasses.dataclass(frozen=True)
class FedConfig:
    """Static GenQSGD runtime parameters for one training job."""
    n_workers: int                       # fl axis size
    Kn: tuple                            # per-worker local steps (len == fl)
    s0: Optional[int]                    # server quantizer (None = exact)
    sn: object = None                    # worker quantizer: int (homogeneous),
                                         # tuple of per-worker ints, or None
    wire: str = "f32"                    # one of compress.RUNTIME_WIRES
    bucket: object = None                # per-bucket-norm quantization: bucket
                                         # size (EdgeSystem's q_dim), or None
    aux_weight: float = 0.01
    microbatch: int = 1                  # grad-accumulation splits per local step
    agg_weights: object = None           # per-worker aggregation weights w_n
                                         # (tuple, len fl; None = plain mean)
    momentum: float = 0.0                # local-update momentum beta
    normalize: bool = False              # normalized local updates (GQFedWAvg)
    sampling_S: object = None            # per-round cohort size (None = full)
    sampling_p: object = None            # per-worker base probabilities
                                         # (tuple, len fl; None = uniform)
    seed: object = None                  # cohort/fault rng seed (trainer side)
    faults: object = None                # repro.faults.FaultSpec (None = no
                                         # faults — the historical path)

    def __post_init__(self):
        if self.wire not in RUNTIME_WIRES:
            raise ValueError(f"wire must be one of {RUNTIME_WIRES}, "
                             f"got {self.wire!r}")
        from ..families import check_agg_weights, check_momentum  # cycle
        if self.agg_weights is not None:
            object.__setattr__(self, "agg_weights",
                               check_agg_weights(self.agg_weights,
                                                 self.n_workers))
        check_momentum(self.momentum)
        if self.sampling_p is not None and self.sampling_S is None:
            raise ValueError("sampling_p given without sampling_S")
        if self.sampling_S is not None:
            from ..sampling.base import check_probs  # cycle
            S = int(self.sampling_S)
            if not 1 <= S <= self.n_workers:
                raise ValueError(
                    f"sampling_S={S} outside [1, N={self.n_workers}]")
            object.__setattr__(self, "sampling_S", S)
            if self.sampling_p is not None:
                p = check_probs(self.sampling_p, self.n_workers)
                if S * max(p) > 1.0 + 1e-9:
                    raise ValueError(
                        f"inclusion probability S*max(p)={S * max(p):.4g} "
                        f"exceeds 1")
                object.__setattr__(self, "sampling_p", p)
            # the per-round HT weight vector u is a traced round input, so
            # sampling needs an aggregation that runs OUTSIDE shard_map:
            # the f32 transport, or the bucketed level wires (whose decode
            # + combine already run on logical-global arrays).
            if not (self.wire == "f32"
                    or (self.bucket is not None
                        and self.wire in ("int8", "int4"))):
                raise ValueError(
                    f"client sampling is not supported on wire="
                    f"{self.wire!r}" + ("" if self.bucket is not None
                                        else " without bucketing")
                    + "; use wire='f32' or a bucketed int8/int4 wire")
        if self.faults is not None:
            from ..faults import FaultSpec  # cycle
            if not isinstance(self.faults, FaultSpec):
                raise TypeError(f"faults must be a repro.faults.FaultSpec, "
                                f"got {type(self.faults)}")
            if self.faults.N != self.n_workers:
                raise ValueError(f"FaultSpec describes {self.faults.N} "
                                 f"workers, config has {self.n_workers}")
            # deadline-HT aggregation rides the same traced per-round u
            # vector as client sampling, with the same wire restriction
            if not (self.wire == "f32"
                    or (self.bucket is not None
                        and self.wire in ("int8", "int4"))):
                raise ValueError(
                    f"fault injection is not supported on wire="
                    f"{self.wire!r}" + ("" if self.bucket is not None
                                        else " without bucketing")
                    + "; use wire='f32' or a bucketed int8/int4 wire")
        if self.bucket is not None and int(self.bucket) <= 0:
            raise ValueError(f"bucket must be positive, got {self.bucket}")
        cap = wire_max_s(self.wire)
        if self.wire == "elias":
            # pricing is unbounded in s (cap is None), but the runtime
            # coder reads levels from an int8 container like every other
            # level transport
            cap = elias.MAX_RUNTIME_S
        for s in self.sn_tuple() + (self.s0,):
            if s is not None and cap is not None and s > cap:
                raise ValueError(
                    f"wire {self.wire!r} carries s <= {cap}, got {s}")
        sn = self.sn_tuple()
        if not self.sn_exact and any(s is None for s in sn):
            # the level transports carry every worker's delta in the same
            # integer container, which cannot represent an exact passthrough
            raise ValueError("mixed exact (s=None) and quantized workers are "
                             "not supported: set s_n for every worker, or "
                             "None for all")
        if self.wire == "int4" and self.sn_exact:
            raise ValueError("int4 wire packs quantized levels; exact "
                             "(s=None) workers need the f32 or rs_ag wire")

    @property
    def K_max(self) -> int:
        return int(max(self.Kn))

    def sn_tuple(self) -> tuple:
        """Per-worker quantization parameters (heterogeneous allowed)."""
        if isinstance(self.sn, (tuple, list)):
            assert len(self.sn) == self.n_workers
            return tuple(self.sn)
        return (self.sn,) * self.n_workers

    @property
    def sn_exact(self) -> bool:
        return all(s is None for s in self.sn_tuple())

    def codecs(self) -> tuple:
        """Per-worker codec views (cost accounting / introspection)."""
        return tuple(make_codec(s, wire=self.wire, bucket=self.bucket)
                     for s in self.sn_tuple())

    def server_codec(self):
        """An exact server multicast (s0=None) is raw f32 regardless of the
        worker wire — the packing wire can't carry it, but the runtime never
        packs the server update anyway."""
        wire = self.wire if self.s0 is not None else "f32"
        return make_codec(self.s0, wire=wire, bucket=self.bucket)


# ---------------------------------------------------------------------------
# counter-based uniform noise (murmur3 finalizer) — jax.random's threefry
# emits reshape/concat patterns GSPMD cannot partition (measured: full f32
# noise tensors replicated per device at 405B scale), so quantization noise
# comes from a pure elementwise index hash instead.  Avalanche quality is
# ample for stochastic rounding; uniformity/unbiasedness are unit-tested.
# ---------------------------------------------------------------------------
def _mix32(z: jax.Array) -> jax.Array:
    z = (z ^ (z >> 16)) * jnp.uint32(0x85EBCA6B)
    z = (z ^ (z >> 13)) * jnp.uint32(0xC2B2AE35)
    return z ^ (z >> 16)


def uniform_like(x: jax.Array, seed: jax.Array) -> jax.Array:
    """U(0,1) f32 tensor shaped like x, from a counter hash (partitionable)."""
    n = int(np.prod(x.shape)) if x.shape else 1
    idx = jnp.arange(n, dtype=jnp.uint32).reshape(x.shape)
    z = idx * jnp.uint32(0x9E3779B9) + seed.astype(jnp.uint32)
    z = _mix32(_mix32(z) + jnp.uint32(0x27D4EB2F))
    return (z >> jnp.uint32(8)).astype(jnp.float32) * jnp.float32(1.0 / (1 << 24))


def _seed_from(key: jax.Array, salt: int) -> jax.Array:
    data = jax.random.key_data(key) if jnp.issubdtype(key.dtype, jax.dtypes.prng_key) \
        else key
    words = data.reshape(-1).astype(jnp.uint32)
    seed = jnp.uint32(salt * 0x9E3779B9 & 0xFFFFFFFF)
    for i in range(words.shape[0]):
        seed = _mix32(seed ^ words[i])
    return seed


# ---------------------------------------------------------------------------
# round function
# ---------------------------------------------------------------------------
def make_round_fn(api, cfg: ArchConfig, fed: FedConfig, mesh: Mesh,
                  fsdp_weights: bool = True, moe_tp_only: bool = False):
    """Build genqsgd_round(x_hat, batch, noise_key) -> (x_hat', metrics).

    x_hat: param pytree sharded (fsdp, tp), replicated over fl.
    batch: leaves (fl, K_max, B_local, ...), sharded P('fl', None, 'fsdp', ...).
    """
    Kn = jnp.asarray(fed.Kn, jnp.int32)

    def _grad_sharding(tree):
        """Pin weight-gradient shardings to the param layout — otherwise the
        partitioner materializes full unsharded f32 dW tensors and all-reduces
        them (measured 7 x 3.25 GiB concurrent at 405B) instead of
        reduce-scattering."""
        specs = SH.param_specs(tree, mesh, fsdp_weights,
                               moe_tp_only=moe_tp_only)
        return jax.tree.map(
            lambda g, sp: jax.lax.with_sharding_constraint(
                g, NamedSharding(mesh, sp)), tree, specs)

    use_momentum = fed.momentum > 0.0 or fed.normalize
    beta = jnp.float32(fed.momentum)

    def local_train(x_hat, data, kn, gamma):
        def loss_grad(pp, micro):
            l, g = jax.value_and_grad(
                lambda q: api.loss_train(q, cfg, micro,
                                         aux_weight=fed.aux_weight))(pp)
            return l, _grad_sharding(g)

        def eval_grad(p, batch_k):
            # mixed precision: forward/backward in bf16 against a bf16 view,
            # the update applied to the (possibly f32) master copy.
            p_half = jax.tree.map(
                lambda w: w.astype(jnp.bfloat16)
                if w.dtype == jnp.float32 else w, p)
            M = fed.microbatch
            if M > 1:
                # grad accumulation: activations scale with B/M, not B
                micro_tree = jax.tree.map(
                    lambda a: a.reshape((M, a.shape[0] // M) + a.shape[1:])
                    if a.ndim >= 1 and a.shape[0] % M == 0
                    else jnp.broadcast_to(a, (M,) + a.shape), batch_k)
                if "positions3" in batch_k:  # (3, B, S) -> split on B
                    micro_tree["positions3"] = jnp.moveaxis(
                        batch_k["positions3"].reshape(
                            3, M, batch_k["positions3"].shape[1] // M, -1),
                        1, 0)

                def acc_body(acc, micro):
                    g_acc, l_acc = acc
                    l, g = loss_grad(p_half, micro)
                    g_acc = jax.tree.map(
                        lambda a, gg: a + gg.astype(a.dtype) / M, g_acc, g)
                    return (g_acc, l_acc + l / M), None

                zeros = jax.tree.map(
                    lambda w: jnp.zeros(w.shape, w.dtype), p_half)
                (g, loss), _ = jax.lax.scan(acc_body,
                                            (zeros, jnp.zeros(())),
                                            micro_tree)
            else:
                loss, g = loss_grad(p_half, batch_k)
            return loss, g

        def body(carry, inp):
            p, step = carry
            loss, g = eval_grad(p, inp)
            active = (step < kn).astype(jnp.float32)
            p = jax.tree.map(
                lambda w, gg: (w.astype(jnp.float32)
                               - (gamma * active) * gg.astype(jnp.float32)
                               ).astype(w.dtype), p, g)
            return (p, step + 1), loss

        def body_momentum(carry, inp):
            # GQFedWAvg local update: v ← β v + (1-β) g on active steps,
            # move along v (unit-normalized over the whole model when
            # fed.normalize); virtual steps leave both x and v untouched.
            p, v, step = carry
            loss, g = eval_grad(p, inp)
            active = (step < kn).astype(jnp.float32)
            v = jax.tree.map(
                lambda vv, gg: vv + active * (beta * vv + (1.0 - beta)
                                              * gg.astype(jnp.float32) - vv),
                v, g)
            if fed.normalize:
                vn = jnp.sqrt(sum(jnp.sum(jnp.square(l))
                                  for l in jax.tree.leaves(v)))
                scale = (gamma * active) / jnp.maximum(vn, 1e-12)
            else:
                scale = gamma * active
            p = jax.tree.map(
                lambda w, vv: (w.astype(jnp.float32) - scale * vv)
                .astype(w.dtype), p, v)
            return (p, v, step + 1), loss

        if use_momentum:
            v0 = jax.tree.map(lambda w: jnp.zeros(w.shape, jnp.float32),
                              x_hat)
            (p, _, _), losses = jax.lax.scan(
                body_momentum, (x_hat, v0, jnp.int32(0)), data)
        else:
            (p, _), losses = jax.lax.scan(body, (x_hat, jnp.int32(0)), data)
        return p, losses.mean()

    sn_arr = (None if fed.sn_exact
              else jnp.asarray([s or 0 for s in fed.sn_tuple()], jnp.float32))

    bucket = None if fed.bucket is None else int(fed.bucket)

    w_agg = None
    if fed.agg_weights is not None:
        _w = np.asarray(fed.agg_weights, np.float64)
        w_agg = jnp.asarray(_w / _w.sum(), jnp.float32)

    def combine_fl(d, u=None):
        """Collapse a (fl, ...) stacked leaf: the server mean, the family's
        general weighted aggregation (sum_n w_n d_n), or — under client
        sampling — the round's Horvitz-Thompson sum ``sum_n u_n d_n``
        (``u`` already folds the cohort mask, the aggregation weights and
        the 1/pi_n reweighting, so it replaces both other branches)."""
        if u is not None:
            return jnp.tensordot(u.astype(jnp.float32), d, axes=1)
        if w_agg is None:
            return d.mean(axis=0)
        return jnp.tensordot(w_agg, d, axes=1)

    def worker_quantize(delta, key, s_w):
        leaves, treedef = jax.tree.flatten(delta)
        lvls, norms = [], []
        for i, leaf in enumerate(leaves):
            u = uniform_like(leaf, _seed_from(key, i))
            lvl, nrm = encode_tensor(leaf, None if sn_arr is None else s_w,
                                     u, bucket=bucket)
            lvls.append(lvl)
            norms.append(nrm)
        return (jax.tree.unflatten(treedef, lvls),
                jax.tree.unflatten(treedef, norms))

    # -- aggregation ---------------------------------------------------------
    def _decode_fl(levels_fl, norms_fl):
        """Per-worker dequantize of (fl, ...) stacked leaves — plain GSPMD
        ops on logical-global arrays (bucket boundaries index *global*
        coordinates, so bucketed decode must not run on shard-local blocks)."""
        ss = jnp.zeros(fed.n_workers) if sn_arr is None else sn_arr
        return jax.tree.map(
            lambda l, n: jax.vmap(
                lambda li, ni, si: decode_tensor(
                    li, ni, None if sn_arr is None else si, bucket=bucket))(
                l, n, ss),
            levels_fl, norms_fl)

    def agg_f32(levels_fl, norms_fl, u=None):
        """Paper-faithful: dequantize then mean over fl (f32 all-reduce);
        weighted families aggregate sum_n w_n Q(Δ_n) instead, sampled
        rounds the HT-weighted cohort sum."""
        return jax.tree.map(lambda d: combine_fl(d, u),
                            _decode_fl(levels_fl, norms_fl))

    def _replicated(x):
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P()))

    def _elias_roundtrip(levels_fl):
        """Reference elias transport: round-trip every worker's levels
        through the omega gap coder (lossless, so aggregation stays
        bit-identical to the f32 transport) and account the realized
        stream bits.  Each worker's *whole flattened delta* is one stream
        — exactly the d-dimensional message ``EdgeSystem.M_s`` prices, and
        one sequential decode per worker instead of one per tensor.  Runs
        on logical-global arrays outside shard_map — variable-length
        streams cannot ride SPMD collectives.  The stream and decoded
        levels are pinned fully replicated: left to itself the
        partitioner shards the decode scan's d-length outputs, turning
        every sequential step into cross-device traffic."""
        leaves, treedef = jax.tree.flatten(levels_fl)
        flat = _replicated(jnp.concatenate(
            [l.reshape(fed.n_workers, -1) for l in leaves],
            axis=1).astype(jnp.int8))
        words, nb = jax.vmap(elias.encode_levels)(flat)
        dec = _replicated(jax.vmap(
            lambda w: elias.decode_levels(w, flat.shape[1]))(
                _replicated(words)))
        out, off = [], 0
        for l in leaves:
            n = l.size // fed.n_workers
            out.append(dec[:, off:off + n].reshape(l.shape).astype(l.dtype))
            off += n
        return jax.tree.unflatten(treedef, out), jnp.sum(nb)

    def _agg_rs_ag_local(levels_loc, norms_loc):
        """Runs inside shard_map: dequantize locally (whole-tensor norms
        only — see :func:`_decode_fl` for why bucketed decode can't run on
        shard-local blocks), reduce-scatter the f32 mean over fl (each
        member owns a 1/fl shard), then all-gather — ~2x fewer wire bytes
        than a ring all-reduce of the same payload."""
        my_s = (None if sn_arr is None
                else sn_arr[jax.lax.axis_index("fl")])
        deq = jax.tree.map(
            lambda lvl, nrm: decode_tensor(lvl, nrm[0], my_s),
            levels_loc, norms_loc)
        return _mean_rs_ag_local(deq)

    def _mean_rs_ag_local(deq_loc):
        """Runs inside shard_map: mean (or weighted sum) of per-worker f32
        deltas over fl via reduce-scatter + all-gather.  ``deq_loc`` leaves
        are the local (1, ...) fl blocks of already-decoded deltas; each
        member pre-scales its own block (1/n, or its aggregation weight) so
        the reduction is a plain sum either way."""
        n = fed.n_workers

        def per_leaf(d):
            if w_agg is None:
                d = d[0] / n
            else:
                d = d[0] * w_agg[jax.lax.axis_index("fl")]
            if d.size % n:  # ragged leaf: fall back to psum
                return jax.lax.psum(d, "fl")
            own = jax.lax.psum_scatter(d.reshape(n, -1), "fl",
                                       scatter_dimension=0, tiled=False)
            return jax.lax.all_gather(own, "fl").reshape(d.shape)

        return jax.tree.map(per_leaf, deq_loc)

    def _agg_levels_local(levels_loc, norms_loc, pack_nibbles=False):
        """Runs inside shard_map: all-gather the level payload over fl,
        dequantize and average locally (whole-tensor norms only).  With
        ``pack_nibbles`` two levels travel per byte (half the int8 wire
        bytes); packing is lossless for s <= 7, so the result stays
        bit-identical to the f32 transport."""
        def per_leaf(lvl, nrm):
            # lvl: (1, ...) local block; gather -> (fl, ...)
            payload = pack_int4(lvl[0]) if pack_nibbles else lvl[0]
            g = jax.lax.all_gather(payload, "fl")         # int8 on the wire
            gn = jax.lax.all_gather(nrm[0], "fl")
            ss = (jnp.zeros(fed.n_workers) if sn_arr is None else sn_arr)

            def dec(pi, ni, si):
                li = (unpack_int4(pi, lvl[0].size).reshape(lvl[0].shape)
                      if pack_nibbles else pi)
                return decode_tensor(li, ni, None if sn_arr is None else si)

            return combine_fl(jax.vmap(dec)(g, gn, ss))
        return jax.tree.map(per_leaf, levels_loc, norms_loc)

    def _agg_int8_local(levels_loc, norms_loc):
        return _agg_levels_local(levels_loc, norms_loc)

    def _agg_int4_local(levels_loc, norms_loc):
        return _agg_levels_local(levels_loc, norms_loc, pack_nibbles=True)

    def _gather_levels_local(levels_loc, pack_nibbles=False):
        """Runs inside shard_map: move ONLY the compact level payload over fl
        (raw int8 or packed int4 on the wire) and return the gathered
        (fl, ...) levels.  Used by the bucketed transports, whose dequantize
        runs outside the shard_map (see :func:`_decode_fl`)."""
        def per_leaf(lvl):
            payload = pack_int4(lvl[0]) if pack_nibbles else lvl[0]
            g = jax.lax.all_gather(payload, "fl")         # int8 on the wire
            if pack_nibbles:
                g = jax.vmap(lambda pi: unpack_int4(pi, lvl[0].size)
                             .reshape(lvl[0].shape))(g)
            return g
        return jax.tree.map(per_leaf, levels_loc)

    def _pspecs(x_hat_example):
        return SH.param_specs(x_hat_example, mesh, fsdp_weights,
                              moe_tp_only=moe_tp_only)

    def make_agg_sm(x_hat_example, body):
        pspecs = _pspecs(x_hat_example)
        lv_specs = SH.with_fl(pspecs)
        nm_specs = jax.tree.map(lambda _: P("fl"), pspecs,
                                is_leaf=lambda x: isinstance(x, P))
        return shard_map(body, mesh=mesh,
                         in_specs=(lv_specs, nm_specs), out_specs=pspecs)

    def make_gather_sm(x_hat_example, pack_nibbles):
        pspecs = _pspecs(x_hat_example)
        out_specs = jax.tree.map(lambda s: P(None, *s), pspecs,
                                 is_leaf=lambda x: isinstance(x, P))
        return shard_map(
            functools.partial(_gather_levels_local,
                              pack_nibbles=pack_nibbles),
            mesh=mesh, in_specs=(SH.with_fl(pspecs),), out_specs=out_specs)

    def make_mean_sm(x_hat_example):
        pspecs = _pspecs(x_hat_example)
        return shard_map(_mean_rs_ag_local, mesh=mesh,
                         in_specs=(SH.with_fl(pspecs),), out_specs=pspecs)

    # -- the round ----------------------------------------------------------
    def genqsgd_round(x_hat, batch, key, gamma, u=None):
        keys = jax.random.split(key, fed.n_workers + 1)
        wkeys, skey = keys[:-1], keys[-1]

        params_w, losses = jax.vmap(
            local_train, in_axes=(None, 0, 0, None))(x_hat, batch, Kn, gamma)

        # (5): normalized per-worker deltas, quantized per tensor
        deltas = jax.tree.map(
            lambda pw, xh: (pw - xh[None]) / gamma, params_w, x_hat)
        s_dummy = (jnp.zeros(fed.n_workers) if sn_arr is None else sn_arr)
        levels_fl, norms_fl = jax.vmap(worker_quantize)(deltas, wkeys,
                                                        s_dummy)

        elias_bits = None
        if fed.wire == "f32":
            delta_hat = agg_f32(levels_fl, norms_fl, u)
        elif fed.wire == "elias":
            # exact workers (s=None) ride raw f32, exactly as priced
            if not fed.sn_exact:
                levels_fl, elias_bits = _elias_roundtrip(levels_fl)
            delta_hat = agg_f32(levels_fl, norms_fl, u)
        elif bucket is None:
            body = {"int8": _agg_int8_local, "int4": _agg_int4_local,
                    "rs_ag": _agg_rs_ag_local}[fed.wire]
            delta_hat = make_agg_sm(x_hat, body)(levels_fl, norms_fl)
        elif fed.wire in ("int8", "int4"):
            # bucketed level wires: compact payload moves inside shard_map,
            # dequantize outside on logical-global arrays (no further
            # fl-axis traffic — the gathered levels are fl-replicated).
            # Unlike the per-tensor paths, cross-wire agreement here is
            # ulp-level, not bitwise: the decode sits in a different fusion
            # context, so XLA's FMA choices can flip a few stochastic
            # roundings upstream.
            g = make_gather_sm(x_hat, fed.wire == "int4")(levels_fl)
            delta_hat = jax.tree.map(lambda d: combine_fl(d, u),
                                     _decode_fl(g, norms_fl))
        else:  # bucketed rs_ag: decode per worker, then rs+ag the f32 mean
            delta_hat = make_mean_sm(x_hat)(_decode_fl(levels_fl, norms_fl))

        # (3): server quantization of the averaged update, applied everywhere
        leaves, treedef = jax.tree.flatten(delta_hat)
        new_leaves = []
        lvls, nrms = [], []
        for i, leaf in enumerate(leaves):
            u = uniform_like(leaf, _seed_from(skey, 1000 + i))
            lvl, nrm = encode_tensor(leaf, fed.s0, u, bucket=bucket)
            lvls.append(lvl)
            nrms.append(nrm)
        if fed.wire == "elias" and fed.s0 is not None:
            # the server multicast rides the same coder: one stream over
            # the whole flattened update (lossless on levels)
            flat = _replicated(jnp.concatenate(
                [l.reshape(-1) for l in lvls]).astype(jnp.int8))
            words, nb = elias.encode_levels(flat)
            dec = _replicated(elias.decode_levels(_replicated(words),
                                                  flat.size))
            off = 0
            for i, l in enumerate(lvls):
                lvls[i] = (dec[off:off + l.size].reshape(l.shape)
                           .astype(l.dtype))
                off += l.size
            elias_bits = (nb if elias_bits is None else elias_bits + nb)
        for leaf_l, leaf_n, xh in zip(lvls, nrms, jax.tree.leaves(x_hat)):
            dq = decode_tensor(leaf_l, leaf_n, fed.s0, bucket=bucket)
            new_leaves.append((xh.astype(jnp.float32)
                               + gamma * dq).astype(xh.dtype))
        x_new = jax.tree.unflatten(treedef, new_leaves)
        metrics = {"loss": losses.mean(),
                   "delta_norm": jnp.sqrt(sum(
                       jnp.sum(jnp.square(l.astype(jnp.float32)))
                       for l in leaves))}
        if elias_bits is not None:
            metrics["elias_bits"] = elias_bits
        return x_new, metrics

    return genqsgd_round
