from .runtime import FedConfig, make_round_fn, quantize_tensor, dequantize_tensor
from . import sharding
