from .runtime import FedConfig, make_round_fn
from . import sharding
