from .checkpoint import save, load
from .trainer import GenQSGDTrainer, TrainState
