"""GenQSGD trainer: the driver that strings rounds together.

Uses the distributed round from :mod:`repro.fed.runtime` (works on 1 CPU
device or a full mesh alike) with a step-size sequence from
:mod:`repro.core.step_rules` and the offline-optimized (K, B, Γ) from
:mod:`repro.opt` when requested.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig
from ..core.step_rules import StepRule
from ..fed import sharding as SH
from ..fed.runtime import FedConfig, make_round_fn
from ..obs import REGISTRY as _METRICS
from ..obs.metrics import GLOBAL_SWITCH as _OBS_ON
from . import checkpoint as CKPT


def round_comm_bits(fed: FedConfig, dim: int, cohort=None) -> float:
    """Wire bits one round moves: worker uploads + the server multicast,
    priced by the same codec table the cost-layer optimizer uses.

    ``cohort`` (an index array, only under client sampling) restricts the
    upload sum to the workers that actually participated this round."""
    codecs = fed.codecs()
    idx = range(fed.n_workers) if cohort is None else cohort
    up = sum(codecs[int(i)].wire_bits(dim) for i in idx)
    return up + fed.server_codec().wire_bits(dim)

__all__ = ["TrainState", "GenQSGDTrainer", "round_comm_bits"]


@dataclasses.dataclass
class TrainState:
    params: object
    round: int
    history: list


class GenQSGDTrainer:
    def __init__(self, api, cfg: ArchConfig, fed: FedConfig, mesh,
                 step_rule: StepRule, checkpoint_dir: Optional[str] = None):
        self.api = api
        self.cfg = cfg
        self.fed = fed
        self.mesh = mesh
        self.rule = step_rule
        self.ckpt_dir = checkpoint_dir
        round_fn = make_round_fn(api, cfg, fed, mesh)
        self._round = jax.jit(round_fn)

    def init(self, key, dtype=jnp.float32) -> TrainState:
        params = self.api.init_params(key, self.cfg, dtype=dtype)
        if self.mesh.devices.size > 1:
            sh = SH.param_shardings(params, self.mesh)
            params = jax.device_put(params, sh)
        return TrainState(params=params, round=0, history=[])

    def run(self, state: TrainState, batches: Iterator, key, n_rounds: int,
            log_every: int = 10, eval_fn: Optional[Callable] = None,
            ckpt_every: int = 0) -> TrainState:
        gammas = self.rule.sequence(state.round + n_rounds)
        dim = sum(int(l.size) for l in jax.tree.leaves(state.params))
        comm_mbits = round_comm_bits(self.fed, dim) / 1e6
        fed = self.fed
        rng = (np.random.default_rng(fed.seed)
               if fed.sampling_S is not None else None)
        self.cohort_trace = []
        self.fault_trace = None
        fdrv = None
        if fed.faults is not None:
            # same driver + rng construction as the reference runtime, so a
            # (seed, model) pair produces the bit-identical FaultTrace on
            # either backend
            from ..faults import FaultDriver, fault_rng  # cycle
            fdrv = FaultDriver(fed.faults, fed.n_workers, fed.agg_weights)
            frng = fault_rng(fed.seed)
        # round metrics (repro.obs): reads only host-side values the loop
        # already computes; disabled runs pay one boolean check per round
        obs_on = _OBS_ON.on
        if obs_on:
            _round_h = _METRICS.histogram("run.round_s", backend="spmd")
            _htvar_h = _METRICS.histogram("run.ht_weight_var", backend="spmd")
            _bits_c = _METRICS.counter("run.wire_bits", backend="spmd",
                                       codec=fed.wire)
            _rounds_c = _METRICS.counter("run.rounds", backend="spmd")
        for r in range(state.round, state.round + n_rounds):
            key, rkey = jax.random.split(key)
            batch = next(batches)
            t0 = time.time()
            idx = pi = u = None
            if rng is not None:
                from ..sampling.base import cohort_weights, draw_cohort
                idx, pi = draw_cohort(rng, fed.n_workers, fed.sampling_S,
                                      fed.sampling_p)
                self.cohort_trace.append(idx)
            if fdrv is not None:
                u = fdrv.step(frng, r, idx, pi)
                # crashed workers never upload; timed-out/corrupt ones do
                # (the server just discards them), so they still pay bits
                rec = fdrv.last
                uploaded = [i for i in rec.cohort if i not in rec.crashed]
                comm_mbits = round_comm_bits(fed, dim, cohort=uploaded) / 1e6
            elif idx is not None:   # sampling only: the historical HT path
                u = cohort_weights(idx, pi, fed.n_workers, fed.agg_weights)
                comm_mbits = round_comm_bits(fed, dim, cohort=idx) / 1e6
            if u is not None:
                state.params, metrics = self._round(
                    state.params, batch, rkey, jnp.float32(gammas[r]),
                    jnp.asarray(u, jnp.float32))
            else:
                state.params, metrics = self._round(
                    state.params, batch, rkey, jnp.float32(gammas[r]))
            if obs_on:
                # async dispatch: host loop time per round, never an added
                # block_until_ready (observing must not serialize the mesh)
                _round_h.observe(time.time() - t0)
                _rounds_c.inc()
                _bits_c.inc(comm_mbits * 1e6)
                if u is not None:
                    # plain-python variance (see genqsgd.run): keeps the
                    # per-round observability cost off the ufunc path
                    _ul = u.tolist()
                    _mu = sum(_ul) / len(_ul)
                    _htvar_h.observe(
                        sum((v - _mu) ** 2 for v in _ul) / len(_ul))
            if r % log_every == 0 or r == state.round + n_rounds - 1:
                rec = {"round": r, "gamma": float(gammas[r]),
                       "loss": float(metrics["loss"]),
                       "delta_norm": float(metrics["delta_norm"]),
                       "comm_mbits": comm_mbits,
                       "dt": time.time() - t0}
                if eval_fn is not None:
                    rec.update(eval_fn(state.params))
                state.history.append(rec)
                print("  " + " ".join(f"{k}={v:.4g}" if isinstance(v, float)
                                      else f"{k}={v}" for k, v in rec.items()),
                      flush=True)
            if self.ckpt_dir and ckpt_every and (r + 1) % ckpt_every == 0:
                CKPT.save(f"{self.ckpt_dir}/round_{r+1:06d}.ckpt",
                          state.params, {"round": r + 1})
            state.round = r + 1
        if fdrv is not None:
            self.fault_trace = fdrv.trace()
        return state
