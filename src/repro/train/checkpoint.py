"""msgpack + zstd pytree checkpointing (no orbax dependency).

Arrays are stored as (dtype, shape, raw bytes); the pytree structure is
path-keyed so checkpoints are robust to ordering.  Sharded arrays are
gathered to host before writing (fine at the example scales this repo
actually executes; the dry-run never writes checkpoints).

``zstandard`` is an optional extra: without it, checkpoints fall back to
zlib.  A 4-byte magic prefix records the compressor, so either build reads
both formats (zstd-written checkpoints still need zstandard to load).
"""
from __future__ import annotations

import os
import zlib
from typing import Any

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

try:
    import zstandard
except ImportError:  # optional extra; zlib fallback below
    zstandard = None

__all__ = ["save", "load", "tree_paths"]

_MAGIC_ZSTD = b"RZS1"
_MAGIC_ZLIB = b"RZL1"


def _compress(raw: bytes) -> bytes:
    if zstandard is not None:
        return _MAGIC_ZSTD + zstandard.ZstdCompressor(level=3).compress(raw)
    return _MAGIC_ZLIB + zlib.compress(raw, level=3)


def _decompress(blob: bytes) -> bytes:
    magic, body = blob[:4], blob[4:]
    if magic == _MAGIC_ZSTD:
        if zstandard is None:
            raise RuntimeError("checkpoint was written with zstandard, "
                               "which is not installed")
        return zstandard.ZstdDecompressor().decompress(body)
    if magic == _MAGIC_ZLIB:
        return zlib.decompress(body)
    # pre-magic checkpoints were raw zstd frames
    if zstandard is None:
        raise RuntimeError("legacy zstd checkpoint needs zstandard installed")
    return zstandard.ZstdDecompressor().decompress(blob)


def tree_paths(tree) -> dict:
    flat = {}

    def visit(path, leaf):
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = leaf

    jax.tree_util.tree_map_with_path(visit, tree)
    return flat


def save(path: str, tree: Any, metadata: dict | None = None):
    flat = tree_paths(tree)
    payload = {"__meta__": metadata or {}}
    for k, v in flat.items():
        arr = np.asarray(jax.device_get(v))
        payload[k] = {"dtype": str(arr.dtype), "shape": list(arr.shape),
                      "data": arr.tobytes()}
    raw = msgpack.packb(payload, use_bin_type=True)
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "wb") as f:
        f.write(_compress(raw))


def load(path: str, like: Any | None = None):
    with open(path, "rb") as f:
        raw = _decompress(f.read())
    payload = msgpack.unpackb(raw, raw=False)
    meta = payload.pop("__meta__", {})
    arrays = {k: np.frombuffer(v["data"],
                               dtype=np.dtype(v["dtype"])
                               ).reshape(v["shape"])
              for k, v in payload.items()}
    if like is None:
        return arrays, meta
    flat_like = tree_paths(like)
    leaves = {k: jnp.asarray(arrays[k]) for k in flat_like}
    out = jax.tree_util.tree_map_with_path(
        lambda path, leaf: leaves["/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path)],
        like)
    return out, meta
