"""The shipped algorithm families.

``GenQSGDFamily`` covers the paper's four parameterizations (GenQSGD with
every variable free, plus the PM-SGD / FedAvg / PR-SGD baselines obtained by
pinning/tying variables through a ``VarMap``) — all hooks neutral, so the
optimizer and runtimes follow the exact historical code paths.

``GQFedWAvgFamily`` is the authors' follow-up family (arXiv 2306.07497)
adapted onto the Theorem-1 machinery this repo reproduces:

  * **general weighted aggregation** — the server update is
    ``x̂ += γ · Σ_n w_n Q(Δ_n)`` instead of the mean.  In the bound the
    weights enter through ``Σ_n ε_n K_n`` (effective local work,
    ``ε_n = N w_n``), the ε²-weighted quantization-variance block
    ``Σ_n q_n (ε_n K_n)²``, and the sample-variance factor
    ``N Σ_n w_n²`` on c3 — all coefficient-only changes, so the family
    batches and fuses through ``repro.opt.refresh`` / ``gia_jax``
    unchanged;
  * **normalized momentum local updates** — workers run
    ``v ← β v + (1-β) g;  x ← x − γ v/‖v‖``.  We fold the momentum drift
    amplification into the bound as ``c2 → c2 / (1-β)`` (the momentum
    buffer averages the last ~1/(1-β) drifting gradients); the
    normalization itself is a runtime property that does not change the
    bound's posynomial structure;
  * **rotation-preconditioned quantization** — deltas are preconditioned
    with a randomized Hadamard rotation before QSGD
    (:class:`repro.compress.RotatedQSGDCodec`); ``codec_kind="rotated"``
    makes :class:`repro.core.cost.EdgeSystem` price exactly the rotated
    wire format (padded-to-pow2 levels + the 32-bit rotation seed) the
    reference runtime sends.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Tuple

import numpy as np

from ..opt.problems import (VarMap, fa_varmap, identity_varmap, pm_varmap,
                            pr_varmap)
from .base import AlgorithmFamily, check_agg_weights

__all__ = ["GenQSGDFamily", "GQFedWAvgFamily", "BUILTIN_FAMILIES"]

#: varmap-factory spellings of the paper's Sec.-VII parameterizations;
#: factory(N, with_extra, samples_per_worker) -> VarMap
_VARMAPS = {
    "genqsgd": lambda N, we, spw: identity_varmap(N, with_extra=we),
    "pm": lambda N, we, spw: pm_varmap(N, with_extra=we),
    "fa": lambda N, we, spw: fa_varmap(N, [float(spw)] * N, with_extra=we),
    "pr": lambda N, we, spw: pr_varmap(N, with_extra=we),
}


@dataclasses.dataclass(frozen=True)
class GenQSGDFamily(AlgorithmFamily):
    """The paper's family: plain-SGD local updates, mean aggregation, QSGD.

    ``varmap_factory`` selects the decision-variable structure (free /
    PM / FA / PR); every other hook keeps the base class's neutral —
    bit-identical — behavior.
    """

    varmap_factory: Optional[Callable[..., VarMap]] = None

    def make_varmap(self, N: int, with_extra: bool,
                    samples_per_worker: float) -> VarMap:
        factory = self.varmap_factory or _VARMAPS["genqsgd"]
        return factory(N, with_extra, samples_per_worker)


@dataclasses.dataclass(frozen=True)
class GQFedWAvgFamily(AlgorithmFamily):
    """GQFedWAvg: weighted aggregation + normalized momentum + rotation.

    ``weights`` are the (unnormalized) aggregation weights ``w_n``; ``None``
    means uniform.  Register variants under their own keys to sweep weight
    schedules::

        from repro.families import GQFedWAvgFamily, register
        register(GQFedWAvgFamily(key="gqfedwavg-front",
                                 weights=(4.0, 2.0, 1.0, 1.0)))
    """

    key: str = "gqfedwavg"
    momentum: float = 0.5
    normalize: bool = True
    codec_kind: str = "rotated"
    weights: Optional[Tuple[float, ...]] = None

    def __post_init__(self):
        super().__post_init__()
        if self.weights is not None:
            object.__setattr__(self, "weights",
                               check_agg_weights(self.weights))

    def _w(self, N: int) -> Optional[np.ndarray]:
        if self.weights is None:
            return None
        if len(self.weights) != N:
            raise ValueError(f"family {self.key!r} has {len(self.weights)} "
                             f"aggregation weights for N={N} workers")
        w = np.asarray(self.weights, dtype=np.float64)
        return w / w.sum()

    # -- optimizer hooks -------------------------------------------------
    def agg_eps(self, N: int) -> Optional[np.ndarray]:
        w = self._w(N)
        return None if w is None else N * w

    def c_scales(self, N: int) -> Tuple[float, float]:
        c2s = 1.0 / (1.0 - self.momentum)
        w = self._w(N)
        c3s = 1.0 if w is None else float(N * np.sum(w * w))
        return c2s, c3s

    # -- runtime hooks ---------------------------------------------------
    def agg_weights(self, N: int) -> Optional[Tuple[float, ...]]:
        w = self._w(N)
        return None if w is None else tuple(float(x) for x in w)


#: day-one registry contents, in registration order
BUILTIN_FAMILIES = (
    GenQSGDFamily(key="genqsgd", varmap_factory=_VARMAPS["genqsgd"]),
    GenQSGDFamily(key="pm", varmap_factory=_VARMAPS["pm"]),
    GenQSGDFamily(key="fa", varmap_factory=_VARMAPS["fa"]),
    GenQSGDFamily(key="pr", varmap_factory=_VARMAPS["pr"]),
    GQFedWAvgFamily(),
)
