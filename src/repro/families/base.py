"""The AlgorithmFamily interface: everything one FL algorithm family owns.

The paper's GenQSGD is one point in a family space its authors later
generalized (GQFedWAvg, arXiv 2306.07497): general weighted aggregation,
normalized momentum local updates, and a preconditioned quantizer — all
optimized by the same CGP/GIA machinery.  An :class:`AlgorithmFamily`
bundles the four seams a family needs into one object, so a new family
plugs into the whole pipeline (``Scenario`` → batched/fused GIA → reference
and SPMD runtimes → bit accounting) without touching any of those layers:

  varmap hook        ``make_varmap(N, with_extra, samples_per_worker)`` —
                     the decision-variable structure the optimizer sees
                     (what the old ``repro.api.registries.FAMILIES``
                     factories provided);
  convergence hooks  ``agg_eps`` / ``c_scales`` — how the family's
                     convergence bound reweights Theorem 1's posynomial
                     blocks.  The *shape* of the convergence block (term
                     counts per constraint) is family-independent, which is
                     what lets every family batch and fuse through
                     ``repro.opt.refresh`` / ``repro.opt.gia_jax``
                     unchanged; only the coefficients move;
  runtime hooks      ``agg_weights`` (server aggregation rule), plus the
                     ``momentum`` / ``normalize`` local-update fields
                     consumed by :mod:`repro.core.genqsgd` and
                     :mod:`repro.fed.runtime`;
  codec hook         ``codec_kind`` — the :func:`repro.compress.make_codec`
                     preconditioner variant the family quantizes with
                     ("qsgd" or "rotated"), priced consistently by
                     :class:`repro.core.cost.EdgeSystem`.

The base class implements GenQSGD's neutral behavior for every hook: the
``None`` returns of ``agg_eps`` / ``agg_weights`` select the *exact*
pre-family code paths (unweighted sums, plain mean aggregation), so routing
GenQSGD through this interface is bit-identical to the historical pipeline
— asserted by ``tests/unit/test_families.py``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

from ..opt.problems import VarMap, identity_varmap

__all__ = ["AlgorithmFamily", "check_agg_weights", "check_momentum"]


def check_momentum(beta) -> float:
    """The ONE momentum-range validator (family + both runtime configs)."""
    beta = float(beta)
    if not (0.0 <= beta < 1.0):
        raise ValueError(f"momentum must be in [0, 1), got {beta}")
    return beta


def check_agg_weights(weights, n_workers: Optional[int] = None
                      ) -> Tuple[float, ...]:
    """The ONE validator for aggregation weights (family, Plan, and both
    runtime configs all accept them): coerces to a float tuple, requires
    strict positivity, and — when the worker count is known — the right
    length.  Keeping this shared stops the consumers' rules drifting."""
    w = tuple(float(x) for x in weights)
    if n_workers is not None and len(w) != n_workers:
        raise ValueError(f"{len(w)} aggregation weights for "
                         f"{n_workers} workers")
    if any(x <= 0 for x in w):
        raise ValueError(f"aggregation weights must be positive, got {w}")
    return w


@dataclasses.dataclass(frozen=True)
class AlgorithmFamily:
    """One FL algorithm family; frozen so instances key registries/caches.

    Fields are the runtime knobs every layer can read directly; behavioral
    variation goes through the overridable hook methods below.
    """

    key: str = "genqsgd"          # registry name == structure-signature key
    momentum: float = 0.0         # local-update momentum beta in [0, 1)
    normalize: bool = False       # normalized (unit-direction) local updates
    codec_kind: str = "qsgd"      # repro.compress.make_codec kind

    def __post_init__(self):
        check_momentum(self.momentum)

    # -- optimizer: decision variables ----------------------------------
    def make_varmap(self, N: int, with_extra: bool,
                    samples_per_worker: float) -> VarMap:
        """The family's decision-variable structure (paper Sec. VII)."""
        del samples_per_worker
        return identity_varmap(N, with_extra=with_extra)

    # -- optimizer: convergence-block reweighting -----------------------
    def agg_eps(self, N: int) -> Optional[np.ndarray]:
        """Effective participation weights ``eps_n = N * w_n`` entering the
        bound's ``sum_n eps_n K_n`` and ``sum_n q_n (eps_n K_n)^2`` blocks.

        ``None`` means uniform aggregation and selects the historical
        unweighted arithmetic verbatim (bit-identical, not merely equal).
        """
        del N
        return None

    def c_scales(self, N: int) -> Tuple[float, float]:
        """Multipliers ``(c2_scale, c3_scale)`` on Theorem 1's drift and
        sample-variance coefficients.

        ``c2_scale`` carries the momentum drift amplification
        ``1 / (1 - beta)`` of the normalized-momentum local update;
        ``c3_scale`` carries the weighted-aggregation variance factor
        ``N * sum_n w_n^2``  (== 1 for uniform weights).  Scales of exactly
        1.0 leave the coefficient objects untouched.
        """
        del N
        return 1.0, 1.0

    # -- runtime: server aggregation ------------------------------------
    def agg_weights(self, N: int) -> Optional[Tuple[float, ...]]:
        """Aggregation weights ``w_n`` (sum 1) for the server update, or
        ``None`` for the plain mean (the historical code path, bitwise)."""
        del N
        return None
