"""repro.families — pluggable FL-algorithm-family subsystem.

An :class:`AlgorithmFamily` owns everything the pipeline used to hardcode
for GenQSGD: the decision-variable map, the convergence-block reweighting
hooks the batched/fused GIA consumes, the runtime aggregation / local-update
hooks, and the codec preconditioner kind.  See :mod:`repro.families.base`
for the interface and :mod:`repro.families.builtin` for the shipped
families (``genqsgd`` / ``pm`` / ``fa`` / ``pr`` bit-identical to the
pre-family pipeline, plus ``gqfedwavg``).

    from repro.families import get_family, register
    fam = get_family("gqfedwavg")
    register(GQFedWAvgFamily(key="gqfedwavg-heavy", momentum=0.9))
"""
from .base import AlgorithmFamily, check_agg_weights, check_momentum
from .builtin import BUILTIN_FAMILIES, GenQSGDFamily, GQFedWAvgFamily
from .registry import family_names, get_family, register, resolve

__all__ = [
    "AlgorithmFamily", "GenQSGDFamily", "GQFedWAvgFamily",
    "register", "get_family", "family_names", "resolve",
    "BUILTIN_FAMILIES", "check_agg_weights", "check_momentum",
]

for _fam in BUILTIN_FAMILIES:
    register(_fam, overwrite=True)
del _fam
