"""The family registry: name -> :class:`AlgorithmFamily` instance.

This is the authoritative registry behind ``Scenario(family=...)`` and the
legacy ``repro.api.registries.FAMILIES`` mapping (now a thin back-compat
shim over this one).  Unknown names fail with a nearest-match suggestion.
"""
from __future__ import annotations

import difflib
from typing import Dict, Tuple, Union

from .base import AlgorithmFamily

__all__ = ["register", "get_family", "family_names", "resolve"]

_REGISTRY: Dict[str, AlgorithmFamily] = {}


def register(family: AlgorithmFamily, overwrite: bool = False) -> None:
    """Register a family under ``family.key``."""
    if not isinstance(family, AlgorithmFamily):
        raise TypeError(f"expected an AlgorithmFamily, got {type(family)}; "
                        f"legacy varmap factories go through "
                        f"repro.api.registries.register_family")
    if family.key in _REGISTRY and not overwrite:
        raise ValueError(f"family {family.key!r} is already registered; "
                         f"pass overwrite=True to replace it")
    _REGISTRY[str(family.key)] = family


def family_names() -> Tuple[str, ...]:
    return tuple(_REGISTRY)


def get_family(name: str) -> AlgorithmFamily:
    try:
        return _REGISTRY[name]
    except KeyError:
        close = difflib.get_close_matches(str(name), _REGISTRY, n=1)
        hint = f" — did you mean {close[0]!r}?" if close else ""
        raise ValueError(
            f"unknown family {name!r}{hint}; registered in repro.families: "
            f"{sorted(_REGISTRY)} (add one with repro.families.register, or "
            f"a legacy varmap factory with "
            f"repro.api.registries.register_family)") from None


def resolve(family: Union[str, AlgorithmFamily]) -> AlgorithmFamily:
    """Accept a registry key or an (unregistered) family instance."""
    if isinstance(family, AlgorithmFamily):
        return family
    return get_family(family)
