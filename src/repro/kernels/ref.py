"""Pure-jnp oracles for the Pallas kernels (the ground truth in tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["qsgd_quantize_ref", "qsgd_dequant_apply_ref", "sumsq_ref"]


def sumsq_ref(y: jax.Array) -> jax.Array:
    return jnp.sum(jnp.square(y.astype(jnp.float32)))


def qsgd_quantize_ref(y: jax.Array, u: jax.Array, s: int,
                      norm: jax.Array) -> jax.Array:
    """QSGD stochastic level assignment (per-tensor norm precomputed).

    levels = sign(y) * (floor(s|y|/norm) + Bernoulli(frac)), int8.
    """
    yf = y.astype(jnp.float32)
    safe = jnp.where(norm > 0, norm, 1.0)
    scaled = s * jnp.abs(yf) / safe
    base = jnp.floor(scaled)
    lvl = base + (u < (scaled - base)).astype(jnp.float32)
    return (jnp.sign(yf) * lvl).astype(jnp.int8)


def qsgd_dequant_apply_ref(x: jax.Array, lvl: jax.Array, norm: jax.Array,
                           s: int, gamma) -> jax.Array:
    """Fused model update: x + gamma * dequantize(lvl)  (Algorithm 1, (3))."""
    scale = norm / s
    return (x.astype(jnp.float32)
            + jnp.float32(gamma) * lvl.astype(jnp.float32) * scale
            ).astype(x.dtype)
