"""Flash-decode Pallas kernel: single-token GQA attention against a deep KV
cache, tiled over the cache length with an online-softmax accumulator held
in VMEM scratch.

The decode_32k / long_500k shapes are memory-bound on KV streaming; this
kernel reads each K/V tile exactly once (HBM -> VMEM), keeps the (G, dh)
running accumulator resident, and never materializes the (C,) score vector
in HBM.  Grid = (batch, kv_head, C/BLOCK_C); the innermost grid dim walks
the cache so scratch carries across iterations.

Tiles: BLOCK_C x dh = 512 x <=256 f32 <= 0.5 MiB per K and V tile — well
inside the ~16 MiB/core VMEM budget with double buffering.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_decode_call", "BLOCK_C"]

BLOCK_C = 512
NEG = -1e30


def _flash_decode_kernel(q_ref, k_ref, v_ref, valid_ref, o_ref,
                         m_scr, l_scr, acc_scr):
    """One (batch, kv_head, c_block) step of online-softmax decode.

    Block shapes: q (1,1,G,dh)  k/v (1,BLOCK_C,1,dh)  valid (1,BLOCK_C)
    out (1,1,G,dh); scratch: m/l (G,1), acc (G,dh) — carried across the
    innermost grid dim.
    """
    j = pl.program_id(2)
    nj = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)                # (G, dh)
    k = k_ref[0, :, 0].astype(jnp.float32)             # (C_b, dh)
    v = v_ref[0, :, 0].astype(jnp.float32)
    dh = q.shape[-1]
    s = jnp.dot(q, k.T) / np.sqrt(dh)                  # (G, C_b)
    s = jnp.where(valid_ref[...] > 0, s, NEG)          # (1, C_b) broadcasts

    m_prev = m_scr[...]                                # (G, 1)
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    corr = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)                             # (G, C_b)
    l_scr[...] = l_scr[...] * corr + p.sum(axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * corr + jnp.dot(p, v)
    m_scr[...] = m_new

    @pl.when(j == nj - 1)
    def _finish():
        o_ref[0, 0] = (acc_scr[...]
                       / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def flash_decode_call(q: jax.Array, k: jax.Array, v: jax.Array,
                      valid: jax.Array, *, interpret=None):
    """q: (B, KV, G, dh); k/v: (B, C, KV, dh); valid: (B, C) in {0,1}.

    Returns (B, KV, G, dh).  C must be a multiple of BLOCK_C.
    ``interpret=None`` auto-selects the interpreter only when no
    Pallas-capable backend is present (see :func:`qsgd.default_interpret`).
    """
    from .qsgd import default_interpret
    if interpret is None:
        interpret = default_interpret()
    B, KV, G, dh = q.shape
    C = k.shape[1]
    assert C % BLOCK_C == 0, (C, BLOCK_C)
    grid = (B, KV, C // BLOCK_C)
    return pl.pallas_call(
        _flash_decode_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, G, dh), lambda b, h, j: (b, h, 0, 0)),
            pl.BlockSpec((1, BLOCK_C, 1, dh), lambda b, h, j: (b, j, h, 0)),
            pl.BlockSpec((1, BLOCK_C, 1, dh), lambda b, h, j: (b, j, h, 0)),
            pl.BlockSpec((1, BLOCK_C), lambda b, h, j: (b, j)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, dh), lambda b, h, j: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KV, G, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, dh), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, valid)
