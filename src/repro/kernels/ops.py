"""Jitted public wrappers around the Pallas kernels.

Handle arbitrary-shaped tensors by flattening + padding to the kernel tile
grid; on CPU the kernels run under ``interpret=True`` (the TPU lowering is
the target, the interpreter validates semantics bit-for-bit against ref.py).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import qsgd as K
from . import ref

__all__ = ["qsgd_quantize", "qsgd_dequant_apply", "tensor_norm",
           "default_interpret"]


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _to_grid2d(flat: jax.Array) -> Tuple[jax.Array, int]:
    """Pad a 1-D array to a (R, BLOCK_COLS·k) grid; returns (2d, orig_len)."""
    n = flat.shape[0]
    cols = K.BLOCK_COLS
    rows = max(K.BLOCK_ROWS, -(-n // cols))
    rows = -(-rows // K.BLOCK_ROWS) * K.BLOCK_ROWS
    pad = rows * cols - n
    flat = jnp.pad(flat, (0, pad))
    return flat.reshape(rows, cols), n


@functools.partial(jax.jit, static_argnames=("s", "interpret"))
def qsgd_quantize(y: jax.Array, key: jax.Array, *, s: int,
                  interpret: Optional[bool] = None):
    """QSGD-quantize an arbitrary tensor -> (levels int8 like y, norm f32)."""
    itp = default_interpret() if interpret is None else interpret
    flat = y.reshape(-1).astype(jnp.float32)
    y2d, n = _to_grid2d(flat)
    norm = jnp.sqrt(K.sumsq_kernel_call(y2d, interpret=itp))
    safe = jnp.where(norm > 0, norm, 1.0)
    u = jax.random.uniform(key, y2d.shape, jnp.float32)
    lvl2d = K.quantize_kernel_call(y2d, u, jnp.float32(s) / safe,
                                   interpret=itp)
    return lvl2d.reshape(-1)[:n].reshape(y.shape), norm


@functools.partial(jax.jit, static_argnames=("s", "interpret"))
def qsgd_dequant_apply(x: jax.Array, lvl: jax.Array, norm: jax.Array,
                       gamma, *, s: int, interpret: Optional[bool] = None):
    """x + gamma * dequantize(lvl, norm, s) — the model-update apply (3)."""
    itp = default_interpret() if interpret is None else interpret
    x2d, n = _to_grid2d(x.reshape(-1))
    l2d, _ = _to_grid2d(lvl.reshape(-1).astype(jnp.float32))
    out = K.dequant_apply_kernel_call(
        x2d, l2d.astype(jnp.int8), (norm / s).astype(jnp.float32),
        jnp.float32(gamma), interpret=itp)
    return out.reshape(-1)[:n].reshape(x.shape).astype(x.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def tensor_norm(y: jax.Array, *, interpret: Optional[bool] = None):
    itp = default_interpret() if interpret is None else interpret
    y2d, _ = _to_grid2d(y.reshape(-1).astype(jnp.float32))
    return jnp.sqrt(K.sumsq_kernel_call(y2d, interpret=itp))
