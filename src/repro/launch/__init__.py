from .mesh import make_production_mesh, logical_mesh, mesh_axis_sizes
