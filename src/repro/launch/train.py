"""Training launcher: GenQSGD federated training for any registered arch.

On real hardware this runs under the production mesh; on CPU it simulates
the (fl, fsdp, tp) topology with host-platform devices (set --devices).

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b --smoke \\
      --rounds 20 --fl 2 --fsdp 2 --tp 2 --wire int8
"""
import argparse
import os


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config (CPU-friendly)")
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--fl", type=int, default=2)
    ap.add_argument("--fsdp", type=int, default=2)
    ap.add_argument("--tp", type=int, default=2)
    ap.add_argument("--k-local", type=int, default=2)
    ap.add_argument("--batch", type=int, default=8, help="per-worker batch")
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--gamma", type=float, default=0.01)
    ap.add_argument("--rule", default="C", choices=["C", "E", "D"])
    ap.add_argument("--rho", type=float, default=None)
    ap.add_argument("--s0", type=int, default=None,
                    help="server quantizer (default: 64, or 7 on int4)")
    ap.add_argument("--sn", type=int, default=None,
                    help="worker quantizer (default: 64, or 7 on int4)")
    # literal list (== compress.RUNTIME_WIRES): importing repro here would
    # pull in jax before XLA_FLAGS is set below; FedConfig re-validates
    ap.add_argument("--wire", default="f32",
                    choices=["f32", "int8", "int4", "rs_ag", "elias"])
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--devices", type=int, default=None,
                    help="host-platform device count (default fl*fsdp*tp)")
    args = ap.parse_args()

    n_dev = args.devices or args.fl * args.fsdp * args.tp
    os.environ.setdefault(
        "XLA_FLAGS", f"--xla_force_host_platform_device_count={n_dev}")

    import jax
    import numpy as np

    from repro.core.step_rules import make_rule
    from repro.data.federated import round_batches
    from repro.data.synthetic import token_batches
    from repro.fed.runtime import FedConfig
    from repro.models.registry import get_config, model_api
    from repro.train.trainer import GenQSGDTrainer

    cfg = get_config(args.arch, smoke=args.smoke)
    api = model_api(cfg)
    if cfg.encdec:
        raise SystemExit("enc-dec archs train via examples (frames input); "
                         "use a decoder-only arch here")
    from repro.compat import make_mesh
    devs = np.array(jax.devices()[:args.fl * args.fsdp * args.tp]).reshape(
        args.fl, args.fsdp, args.tp)
    mesh = make_mesh(devs, ("fl", "fsdp", "tp"))
    from repro.compress import wire_max_s
    s_default = min(64, wire_max_s(args.wire) or 64)
    s0 = args.s0 if args.s0 is not None else s_default
    sn = args.sn if args.sn is not None else s_default
    fed = FedConfig(n_workers=args.fl, Kn=(args.k_local,) * args.fl,
                    s0=s0, sn=sn, wire=args.wire)
    rule = make_rule(args.rule, args.gamma, args.rho)
    trainer = GenQSGDTrainer(api, cfg, fed, mesh, step_rule=rule,
                             checkpoint_dir=args.ckpt)
    state = trainer.init(jax.random.PRNGKey(0))
    n_params = sum(int(np.prod(l.shape))
                   for l in jax.tree.leaves(state.params))
    print(f"[train] {cfg.name}: {n_params/1e6:.1f}M params | mesh "
          f"fl={args.fl} fsdp={args.fsdp} tp={args.tp} | wire={args.wire} "
          f"rule={args.rule}")
    stream = token_batches(seed=0, batch=args.batch, seq=args.seq,
                           vocab=cfg.vocab)
    batches = round_batches(stream, args.fl, fed.K_max)
    state = trainer.run(state, batches, jax.random.PRNGKey(1),
                        n_rounds=args.rounds,
                        log_every=max(1, args.rounds // 10),
                        ckpt_every=(args.rounds // 2 if args.ckpt else 0))
    print(f"[train] done: loss {state.history[0]['loss']:.3f} -> "
          f"{state.history[-1]['loss']:.3f}")


if __name__ == "__main__":
    main()
