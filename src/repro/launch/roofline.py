import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")
"""Roofline extraction for every supported (arch × shape) on the single-pod
mesh (the §Roofline table) — plus optional multi-pod runs for the §Perf loop.

Methodology (DESIGN.md §5): XLA counts scan bodies once, so we compile two
cheap *unrolled* truncations of each model — 1 and 2 repeats of its layer
pattern — diff them for the per-repeat cost, and extrapolate to full depth:

    total = cost(1) + (R - 1) * (cost(2) - cost(1)),   R = n_layers / |pattern|

Training cases are lowered with k_local=1 and microbatch=1 so every scan in
the round has trip count 1 (the local-step count scales the compute term
analytically downstream).  Collective bytes come from the compiled per-device
HLO via the same diff.

Usage:
  python -m repro.launch.roofline --all [--out results/roofline]
  python -m repro.launch.roofline --arch llama3-405b --shape train_4k
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax

HW = {"peak_flops": 197e12, "hbm_bw": 819e9, "link_bw": 50e9}


def _truncate(cfg, reps: int):
    if cfg.encdec:
        return dataclasses.replace(cfg, n_layers=reps, enc_layers=reps)
    return dataclasses.replace(cfg, n_layers=reps * len(cfg.pattern))


def _compile_cost(arch, shape_name, cfg_t, multi_pod):
    from repro.launch.specs import build_case
    from repro.models import unroll
    from repro.roofline.analysis import collective_bytes, cost_summary

    case = build_case(arch, shape_name, multi_pod=multi_pod,
                      cfg_override=cfg_t, k_local=1, microbatch=1)
    jitted = jax.jit(case.fn, in_shardings=case.in_shardings)
    with unroll.unrolled(), case.activation_ctx():
        lowered = jitted.lower(*case.args)
    compiled = lowered.compile()
    cost = cost_summary(compiled.cost_analysis())
    coll = collective_bytes(compiled.as_text())
    flat = dict(cost)
    flat["collective_bytes"] = coll["total_bytes"]
    for op, b in coll["bytes"].items():
        flat[f"coll_{op}"] = b
    return flat, case


def roofline_case(arch: str, shape_name: str, multi_pod: bool = False) -> dict:
    from repro.configs.base import INPUT_SHAPES
    from repro.launch.specs import case_supported
    from repro.models.registry import get_config
    from repro.roofline.analysis import extrapolate, roofline_terms

    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    mesh_name = "multi(2,16,16)" if multi_pod else "single(16,16)"
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name}
    reason = case_supported(cfg, shape)
    if reason:
        rec.update(status="skipped", reason=reason)
        return rec
    t0 = time.time()
    c1, case = _compile_cost(arch, shape_name, _truncate(cfg, 1), multi_pod)
    c2, _ = _compile_cost(arch, shape_name, _truncate(cfg, 2), multi_pod)
    R = (cfg.n_layers if cfg.encdec
         else cfg.n_layers / len(cfg.pattern))
    full = extrapolate(c1, c2, R)

    chips = case.mesh.devices.size
    # tokens processed by one step execution (k_local=1 for train lowers)
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    n_active = cfg.param_count(active_only=True)
    mf_coef = 6.0 if shape.kind == "train" else 2.0
    model_flops = mf_coef * n_active * tokens
    hlo_flops_global = full["flops"] * chips

    terms = roofline_terms(full["flops"], full["bytes_accessed"],
                           full["collective_bytes"], chips=1,
                           )  # per-device values already divide by chips
    rec.update({
        "status": "ok",
        "dt": round(time.time() - t0, 1),
        "repeats": R,
        "chips": chips,
        "per_device": full,
        "terms": terms,
        "model_flops": model_flops,
        "hlo_flops_global": hlo_flops_global,
        "useful_flops_ratio": model_flops / max(hlo_flops_global, 1.0),
        "fl_axis": int(case.mesh.devices.shape[0]),
    })
    dom = terms["dominant"]
    hints = {
        "compute": "increase arithmetic efficiency (fuse/quantize compute or "
                   "reduce remat recompute)",
        "memory": "reduce bytes touched per step (bf16/int8 operands, fuse "
                  "elementwise chains, larger tiles)",
        "collective": "cut wire bytes (int8 QSGD wire, reduce-scatter "
                      "decomposition, rarer syncs / larger K_n)",
    }
    rec["hint"] = hints[dom]
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi", action="store_true")
    ap.add_argument("--out", default="results/roofline")
    args = ap.parse_args()

    from repro.configs.base import INPUT_SHAPES
    from repro.models.registry import ARCH_IDS

    archs = ARCH_IDS if (args.all or args.arch is None) else [args.arch]
    shapes = (list(INPUT_SHAPES) if (args.all or args.shape is None)
              else [args.shape])
    os.makedirs(args.out, exist_ok=True)
    results, failures = [], 0
    for arch in archs:
        for shape in shapes:
            print(f"[roofline] {arch} x {shape}", flush=True)
            try:
                rec = roofline_case(arch, shape, multi_pod=args.multi)
                if rec["status"] == "ok":
                    t = rec["terms"]
                    print(f"  compute={t['compute_s']*1e3:.2f}ms "
                          f"memory={t['memory_s']*1e3:.2f}ms "
                          f"collective={t['collective_s']*1e3:.2f}ms "
                          f"dominant={t['dominant']} "
                          f"useful={rec['useful_flops_ratio']:.2f}",
                          flush=True)
                else:
                    print(f"  skipped: {rec['reason']}", flush=True)
            except Exception as e:
                traceback.print_exc()
                rec = {"arch": arch, "shape": shape, "status": "FAILED",
                       "error": f"{type(e).__name__}: {e}"}
                failures += 1
            results.append(rec)
    suffix = "_multi" if args.multi else ""
    with open(os.path.join(args.out, f"summary{suffix}.json"), "w") as f:
        json.dump(results, f, indent=2)
    print(f"\n[roofline] done: {sum(r['status']=='ok' for r in results)} ok, "
          f"{failures} failed -> {args.out}/summary{suffix}.json")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
