import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")
"""§Perf hillclimb driver: re-extract roofline terms for named variants of
the three selected (arch × shape) pairs and append to results/perf/log.json.

Variants are config/flag switches (the code changes live in the library);
each entry records hypothesis → change → before → after.

Usage: python -m repro.launch.perf --pair llama3_train --variant int8_wire
       python -m repro.launch.perf --list
"""
import argparse
import dataclasses
import json
import time

import jax

PAIRS = {
    "llama3_train": ("llama3-405b", "train_4k"),
    "xlstm_prefill": ("xlstm-1.3b", "prefill_32k"),
    "phi35_train": ("phi3.5-moe-42b-a6.6b", "train_4k"),
}


def measure(arch, shape, *, wire="f32", cfg_mutation=None, multi_pod=False):
    from repro.launch.roofline import _compile_cost, _truncate
    from repro.models.registry import get_config
    from repro.roofline.analysis import extrapolate, roofline_terms

    cfg = get_config(arch)
    if cfg_mutation:
        cfg = dataclasses.replace(cfg, **cfg_mutation)
    from repro.launch import specs as SP

    def compile_at(reps):
        from repro.launch.specs import build_case
        from repro.models import unroll
        from repro.roofline.analysis import collective_bytes, cost_summary
        case = build_case(arch, shape, multi_pod=multi_pod,
                          cfg_override=_truncate(cfg, reps), k_local=1,
                          microbatch=1, wire=wire)
        jitted = jax.jit(case.fn, in_shardings=case.in_shardings)
        with unroll.unrolled(), case.activation_ctx():
            compiled = jitted.lower(*case.args).compile()
        cost = cost_summary(compiled.cost_analysis())
        coll = collective_bytes(compiled.as_text())
        cost["collective_bytes"] = coll["total_bytes"]
        for op, b in coll["bytes"].items():
            cost[f"coll_{op}"] = b
        return cost, case

    c1, case = compile_at(1)
    c2, _ = compile_at(2)
    R = cfg.n_layers if cfg.encdec else cfg.n_layers / len(cfg.pattern)
    full = extrapolate(c1, c2, R)
    terms = roofline_terms(full["flops"], full["bytes_accessed"],
                           full["collective_bytes"], chips=1)
    return {"per_device": full, "terms": terms}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--pair", choices=list(PAIRS), required=False)
    ap.add_argument("--wire", default="f32")
    ap.add_argument("--label", default="baseline")
    ap.add_argument("--capacity", type=float, default=None)
    ap.add_argument("--window", type=int, default=None)
    ap.add_argument("--multi", action="store_true")
    ap.add_argument("--out", default="results/perf")
    args = ap.parse_args()

    arch, shape = PAIRS[args.pair]
    mut = {}
    if args.capacity is not None:
        mut["capacity_factor"] = args.capacity
    if args.window is not None:
        mut["window"] = args.window
    t0 = time.time()
    rec = measure(arch, shape, wire=args.wire, cfg_mutation=mut or None,
                  multi_pod=args.multi)
    rec.update({"pair": args.pair, "label": args.label, "wire": args.wire,
                "mutation": mut, "multi_pod": args.multi,
                "dt": round(time.time() - t0, 1)})
    os.makedirs(args.out, exist_ok=True)
    log_path = os.path.join(args.out, "log.json")
    log = json.load(open(log_path)) if os.path.exists(log_path) else []
    log.append(rec)
    json.dump(log, open(log_path, "w"), indent=2)
    t = rec["terms"]
    print(f"[perf] {args.pair} / {args.label}: "
          f"compute={t['compute_s']:.3g}s memory={t['memory_s']:.3g}s "
          f"collective={t['collective_s']:.3g}s dominant={t['dominant']}")


if __name__ == "__main__":
    main()
