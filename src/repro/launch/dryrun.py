import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (arch × input-shape × mesh).

For each case this proves the sharding config is coherent at production
scale: ``jax.jit(step).lower(*abstract_inputs).compile()`` must succeed on
the single-pod (16, 16) mesh AND the 2-pod (2, 16, 16) mesh, and
``memory_analysis()`` must show per-device residency.  Results (bytes,
FLOPs, collective bytes parsed from the compiled HLO) are dumped as JSON
for EXPERIMENTS.md §Dry-run / §Roofline.

Usage:
  python -m repro.launch.dryrun --arch qwen3-1.7b --shape train_4k --mesh multi
  python -m repro.launch.dryrun --all [--mesh both] [--out results/dryrun]
"""
import argparse
import json
import time
import traceback

import jax


def run_case(arch: str, shape_name: str, multi_pod: bool, wire: str = "f32",
             verbose: bool = True) -> dict:
    from repro.configs.base import INPUT_SHAPES
    from repro.launch.specs import build_case, case_supported
    from repro.models.registry import get_config
    from repro.roofline.analysis import collective_bytes, cost_summary

    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    reason = case_supported(cfg, shape)
    mesh_name = "multi(2,16,16)" if multi_pod else "single(16,16)"
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name, "wire": wire}
    if reason:
        rec["status"] = "skipped"
        rec["reason"] = reason
        return rec
    t0 = time.time()
    case = build_case(arch, shape_name, multi_pod=multi_pod, wire=wire)
    jitted = jax.jit(case.fn, in_shardings=case.in_shardings)
    with case.activation_ctx():
        lowered = jitted.lower(*case.args)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis()
    rec.update({
        "status": "ok",
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "total_per_device_gib": round(
                (ma.argument_size_in_bytes + ma.output_size_in_bytes
                 + ma.temp_size_in_bytes) / 2**30, 3),
        },
        "cost": cost_summary(ca),
        "collectives": collective_bytes(compiled.as_text()),
        "fl_axis": int(case.mesh.devices.shape[0]),
        "param_count": cfg.param_count(),
        "param_count_active": cfg.param_count(active_only=True),
    })
    if verbose:
        m = rec["memory"]
        print(f"  lower {t_lower:.1f}s compile {t_compile:.1f}s | "
              f"per-device args {m['argument_bytes']/2**30:.2f} GiB "
              f"temp {m['temp_bytes']/2**30:.2f} GiB | "
              f"flops {rec['cost'].get('flops', 0):.3g} | "
              f"coll {rec['collectives']['total_bytes']/2**20:.1f} MiB",
              flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--wire", default="f32", choices=["f32", "int8"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()

    from repro.configs.base import INPUT_SHAPES
    from repro.models.registry import ARCH_IDS

    archs = ARCH_IDS if (args.all or args.arch is None) else [args.arch]
    shapes = (list(INPUT_SHAPES) if (args.all or args.shape is None)
              else [args.shape])
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    os.makedirs(args.out, exist_ok=True)
    results, failures = [], 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch} x {shape} x {'multi' if mp else 'single'}"
                print(f"[dryrun] {tag}", flush=True)
                try:
                    rec = run_case(arch, shape, mp, wire=args.wire)
                except Exception as e:  # a failure here is a bug in the system
                    traceback.print_exc()
                    rec = {"arch": arch, "shape": shape,
                           "mesh": "multi" if mp else "single",
                           "status": "FAILED", "error": f"{type(e).__name__}: {e}"}
                    failures += 1
                if rec["status"] == "skipped":
                    print(f"  skipped: {rec['reason']}", flush=True)
                results.append(rec)
                fname = (f"{arch.replace('/', '_')}_{shape}_"
                         f"{'multi' if mp else 'single'}.json")
                with open(os.path.join(args.out, fname), "w") as f:
                    json.dump(rec, f, indent=2)
    with open(os.path.join(args.out, "summary.json"), "w") as f:
        json.dump(results, f, indent=2)
    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skipped" for r in results)
    print(f"\n[dryrun] ok={n_ok} skipped={n_skip} failed={failures} "
          f"-> {args.out}/summary.json")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
