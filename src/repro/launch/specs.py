"""Abstract input construction for every (arch × input-shape × mesh) case.

``build_case`` returns the step function + ShapeDtypeStruct inputs +
shardings, without allocating anything — the dry-run lowers and compiles it.

Shape kinds:
  train   -> GenQSGD round (local-step scan + quantized fl aggregation)
  prefill -> full-sequence forward, returns last-token logits + KV caches
  decode  -> serve_step: ONE token against a seq_len-deep cache
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from ..configs.base import INPUT_SHAPES, ArchConfig, InputShape
from ..fed import sharding as SH
from ..fed.runtime import FedConfig, make_round_fn
from ..models.registry import get_config, model_api
from .mesh import logical_mesh, make_production_mesh

__all__ = ["build_case", "FL_SUB", "PARAM_DTYPE", "case_supported"]

# Per-arch mesh plan: training layout (fl_sub, tp) — fl workers carved per
# pod, tensor parallelism sized to d_model (tp=16 on a 2k-wide model would
# replicate activations 16x) — and serving tp (sized so KV heads divide).
# Giants keep fl_sub=1: their GenQSGD axis is the pod axis itself (multi-pod),
# exactly the paper's slow-link topology.
MESH_PLAN = {
    #                       train(fl_sub, tp)  serve_tp
    "qwen3-1.7b":            ((4, 4), 8),
    "mistral-large-123b":    ((1, 16), 8),
    "gemma3-4b":             ((4, 4), 4),
    "qwen2-vl-7b":           ((2, 8), 4),
    "olmoe-1b-7b":           ((4, 4), 16),
    "llama3-405b":           ((1, 16), 8),
    "xlstm-1.3b":            ((4, 4), 4),
    "zamba2-2.7b":           ((4, 4), 16),
    "whisper-tiny":          ((8, 1), 2),
    "phi3.5-moe-42b-a6.6b":  ((2, 8), 8),
}
FL_SUB = {a: p[0][0] for a, p in MESH_PLAN.items()}

# grad-accumulation microbatches per local step (activation memory / M)
MICROBATCH = {
    "llama3-405b": 8,
    "mistral-large-123b": 4,
    "phi3.5-moe-42b-a6.6b": 2,
    "qwen2-vl-7b": 2,
}

# archs whose expert weights shard over tp only (see §Perf phi3.5 iterations)
MOE_TP_ONLY = {"phi3.5-moe-42b-a6.6b"}

# param dtype for the *dry-run* master copy (f32 unless memory-bound)
PARAM_DTYPE = {
    "llama3-405b": jnp.bfloat16,
    "mistral-large-123b": jnp.bfloat16,
    "phi3.5-moe-42b-a6.6b": jnp.bfloat16,
}


def case_supported(cfg: ArchConfig, shape: InputShape) -> Optional[str]:
    """None if supported, else a human-readable skip reason."""
    if shape.name == "long_500k" and not cfg.supports_long_context():
        if cfg.encdec:
            return ("enc-dec audio family: 512k decoder context is not "
                    "meaningful (30 s audio, <=448 target positions)")
        return ("pure full-attention arch: 512k decode skipped per brief "
                "(no sliding-window/recurrent variant)")
    return None


def _sds(shape, dtype, sharding=None):
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sharding)


def _abstract_batch(cfg: ArchConfig, shape: InputShape, lead=()):
    """Token batch ShapeDtypeStructs with the given leading dims."""
    B = shape.global_batch
    S = shape.seq_len
    if lead:  # training: (fl, K) leading; per-worker batch slice
        B = B // lead[0]
    batch = {
        "tokens": _sds(lead + (B, S), jnp.int32),
        "labels": _sds(lead + (B, S), jnp.int32),
    }
    if cfg.family == "vlm":
        npatch = int(S * cfg.vision_patches_frac)
        batch["patch_embeds"] = _sds(lead + (B, npatch, cfg.d_model),
                                     jnp.bfloat16)
        if lead:
            batch["positions3"] = _sds(lead + (3, B, S), jnp.int32)
        else:
            batch["positions3"] = _sds((3, B, S), jnp.int32)
    if cfg.encdec:
        F = min(cfg.max_source_positions, S)
        batch["frames"] = _sds(lead + (B, F, cfg.d_model), jnp.bfloat16)
    return batch


@dataclasses.dataclass
class Case:
    arch: str
    shape: InputShape
    cfg: ArchConfig
    mesh: Mesh            # logical (fl, fsdp, tp)
    fn: Any               # function to jit
    args: tuple           # abstract example args
    in_shardings: tuple
    donate: tuple = ()
    fed: Optional[FedConfig] = None
    act_sharding: Any = None   # (boundary, interior) for the residual stream

    def activation_ctx(self):
        from ..models import shardctx
        b, i = self.act_sharding or (None, None)
        moe = None
        if self.cfg.n_experts:
            moe = NamedSharding(self.mesh, P("tp", "fsdp", None))
        return shardctx.activation_sharding(b, interior=i, moe=moe)


def _act_sharding(lmesh: Mesh, cfg: ArchConfig, batch_local: int,
                  seq: int, batch_axes) -> Optional[NamedSharding]:
    """Sequence-parallel residual sharding P(batch_axes, tp, None) when the
    dims divide; None otherwise (decode / tiny shapes)."""
    sizes = dict(zip(lmesh.axis_names, lmesh.devices.shape))
    tp = sizes.get("tp", 1)
    b_ok = batch_axes is not None
    s_ax = "tp" if (tp > 1 and seq % tp == 0) else None
    if not b_ok and s_ax is None:
        return None, None
    boundary = NamedSharding(lmesh, P(batch_axes if b_ok else None, s_ax, None))
    interior = NamedSharding(lmesh, P(batch_axes if b_ok else None, None, None))
    return boundary, interior


def build_case(arch: str, shape_name: str, *, multi_pod: bool = False,
               wire: str = "f32", k_local: int = 2,
               mesh: Optional[Mesh] = None, fl_sub: Optional[int] = None,
               param_dtype=None, smoke: bool = False,
               cfg_override: Optional[ArchConfig] = None,
               microbatch: Optional[int] = None) -> Case:
    cfg = cfg_override or get_config(arch, smoke=smoke)
    shape = INPUT_SHAPES[shape_name]
    reason = case_supported(cfg, shape)
    if reason:
        raise ValueError(f"{arch} x {shape_name} unsupported: {reason}")
    api = model_api(cfg)
    pdtype = param_dtype or PARAM_DTYPE.get(arch, jnp.float32)
    if mesh is None:
        pmesh = make_production_mesh(multi_pod=multi_pod)
    else:
        pmesh = mesh

    if shape.kind == "train":
        plan = MESH_PLAN.get(arch, ((4, 4), 8))
        fsub = fl_sub or plan[0][0]
        tp = plan[0][1] if fl_sub is None else None
        lmesh = (logical_mesh(pmesh, fl_sub=fsub, tp=tp)
                 if mesh is None else mesh)
        fl = lmesh.devices.shape[0]
        mb = microbatch if microbatch is not None else MICROBATCH.get(arch, 1)
        # heterogeneous per-worker K_n (alternating) when fl > 1 — exercises
        # the paper's virtual-local-update masking (eqs. (6)-(8)) in the
        # production lowering
        kn = (tuple((k_local + (i % 2)) for i in range(fl)) if fl > 1
              else (k_local,) * fl)
        fed = FedConfig(n_workers=fl, Kn=kn, s0=64, sn=64,
                        wire=wire, microbatch=mb)
        fsdp_w = True  # tp-only weights measured strictly worse (§Perf)
        mtp = arch in MOE_TP_ONLY
        params = api.abstract_params(cfg, dtype=pdtype)
        pspecs = SH.param_specs(params, lmesh, fsdp_weights=fsdp_w,
                                moe_tp_only=mtp)
        batch = _abstract_batch(cfg, shape, lead=(fl, fed.K_max))
        bspecs = SH.batch_specs(batch, lmesh, "fl_train")
        round_fn = make_round_fn(api, cfg, fed, lmesh, fsdp_weights=fsdp_w,
                                 moe_tp_only=mtp)
        args = (
            jax.tree.map(lambda s, sp: _sds(s.shape, s.dtype,
                                            NamedSharding(lmesh, sp)),
                         params, pspecs),
            jax.tree.map(lambda s, sp: _sds(s.shape, s.dtype,
                                            NamedSharding(lmesh, sp)),
                         batch, bspecs),
            _sds((2,), jnp.uint32),
            _sds((), jnp.float32),
        )
        in_sh = (SH.shardings(pspecs, lmesh), SH.shardings(bspecs, lmesh),
                 None, None)
        sizes = dict(zip(lmesh.axis_names, lmesh.devices.shape))
        b_loc = shape.global_batch // fl
        act = _act_sharding(
            lmesh, cfg, b_loc, shape.seq_len,
            "fsdp" if (sizes.get("fsdp", 1) > 1
                       and b_loc % sizes["fsdp"] == 0) else None)
        return Case(arch, shape, cfg, lmesh, round_fn, args, in_sh, fed=fed,
                    act_sharding=act)

    # ------- inference shapes: no fl grouping (fl folds into batch axes) ----
    serve_tp = MESH_PLAN.get(arch, ((4, 4), 8))[1]
    if shape.kind == "prefill":
        # prefill batch (32) must divide fl*fsdp or activations replicate
        # (measured: batch-replicated xlstm prefill, 53x compute) — tp=8
        # gives fsdp=32 on one pod.
        serve_tp = 8
    lmesh = (logical_mesh(pmesh, fl_sub=1, tp=serve_tp)
             if mesh is None else mesh)
    # tp-only experts is a TRAINING win (fsdp partial-k all-reduces on the
    # expert einsums); for inference it measured 3x WORSE — keep fsdp here.
    params = api.abstract_params(cfg, dtype=jnp.bfloat16)
    pspecs = SH.param_specs(params, lmesh)
    pshard = SH.shardings(pspecs, lmesh)
    p_sds = jax.tree.map(lambda s, sp: _sds(s.shape, s.dtype,
                                            NamedSharding(lmesh, sp)),
                         params, pspecs)

    if shape.kind == "prefill":
        batch = _abstract_batch(cfg, shape)
        bspecs = SH.batch_specs(batch, lmesh, "serve")
        b_sds = jax.tree.map(lambda s, sp: _sds(s.shape, s.dtype,
                                                NamedSharding(lmesh, sp)),
                             batch, bspecs)

        def prefill_fn(p, b):
            return api.prefill(p, cfg, b, cache_len=shape.seq_len)

        act = _act_sharding(lmesh, cfg, shape.global_batch, shape.seq_len,
                            SH._batch_axes(
                                dict(zip(lmesh.axis_names,
                                         lmesh.devices.shape)),
                                shape.global_batch))
        return Case(arch, shape, cfg, lmesh, prefill_fn, (p_sds, b_sds),
                    (pshard, SH.shardings(bspecs, lmesh)), act_sharding=act)

    # decode
    B = shape.global_batch
    caches = jax.eval_shape(
        lambda: api.init_caches(cfg, B, shape.seq_len, dtype=jnp.bfloat16))
    cspecs = SH.cache_specs(caches, lmesh, cfg, B)
    c_sds = jax.tree.map(lambda s, sp: _sds(s.shape, s.dtype,
                                            NamedSharding(lmesh, sp)),
                         caches, cspecs)
    tok_spec = SH.batch_specs({"tokens": _sds((B, 1), jnp.int32)}, lmesh,
                              "serve")["tokens"]
    tok = _sds((B, 1), jnp.int32, NamedSharding(lmesh, tok_spec))
    pos = _sds((B, 1), jnp.int32, NamedSharding(lmesh, tok_spec))

    def serve_step(p, t, c, po):
        return api.decode_step(p, cfg, t, c, po)

    return Case(arch, shape, cfg, lmesh, serve_step,
                (p_sds, tok, c_sds, pos),
                (pshard, NamedSharding(lmesh, tok_spec),
                 SH.shardings(cspecs, lmesh), NamedSharding(lmesh, tok_spec)))
