"""Production mesh construction + logical (fl, fsdp, tp) view.

``make_production_mesh`` builds the physical mesh the brief specifies:
(16, 16) = ("data", "model") for one pod, (2, 16, 16) = ("pod", "data",
"model") for two pods.  ``logical_mesh`` folds it into the axes the GenQSGD
runtime actually shards over:

  fl   — federated-worker axis (pods × fl_sub replica groups).  GenQSGD's
         quantized aggregation is the ONLY communication on this axis.
  fsdp — parameter/batch sharding inside one worker group.
  tp   — tensor parallelism.

Everything is a function (module import never touches jax device state).
"""
from __future__ import annotations

from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh

from ..compat import make_mesh, make_mesh_by_shape

__all__ = ["make_production_mesh", "logical_mesh", "mesh_axis_sizes"]


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_by_shape(shape, axes)


def logical_mesh(mesh: Mesh, fl_sub: int = 1, tp: Optional[int] = None) -> Mesh:
    """Reshape a production mesh's devices into (fl, fsdp, tp).

    The pod axis (if present) folds entirely into ``fl``; ``fl_sub`` worker
    groups are additionally carved out of each pod, so fl = pods * fl_sub and
    fsdp = chips_per_pod / (fl_sub * tp).  Cross-pod links only ever carry
    fl-axis (GenQSGD aggregation) traffic — the paper's edge topology.

    ``tp`` defaults to the physical model-axis size (16); small-d_model archs
    shrink it (tp=16 on a 2048-wide model would replicate activations 16x)
    — the extra factor folds into fsdp.
    """
    devs = np.asarray(mesh.devices)
    if devs.ndim == 3:
        pods, data, model = devs.shape
    else:
        data, model = devs.shape
        pods = 1
    if tp is None:
        tp = model
    per_pod = data * model
    if per_pod % (fl_sub * tp):
        raise ValueError(f"fl_sub={fl_sub} * tp={tp} must divide the pod size"
                         f" ({per_pod})")
    fsdp = per_pod // (fl_sub * tp)
    new = devs.reshape(pods * fl_sub, fsdp, tp)
    return make_mesh(new, ("fl", "fsdp", "tp"))


def mesh_axis_sizes(mesh: Mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))
