"""The ONE writer every ``BENCH_*.json`` goes through.

Before this module each benchmark invented its own schema ("schema": 1 vs 2
vs "bench"/"mode" keys, some with machine info, some without).  Now every
artifact shares a uniform envelope:

```json
{
  "bench": "serve",            // which benchmark wrote it
  "bench_schema": 2,           // envelope version (bump on shape changes)
  "smoke": false,              // CI smoke mode vs full mode
  "created_unix": 1754650000,  // write time (int seconds)
  "git_sha": "abc123...",      // repo HEAD at write time (null if unknown)
  "machine": {"platform": ..., "python": ..., "cpus": ...,
              "jax": ..., "jax_backend": ..., "jax_devices": ...},
  ...                          // benchmark-specific payload, flattened
}
```

Payload keys must not collide with the envelope; ``write_bench`` raises if
they do, so a benchmark can never silently shadow provenance fields.
"""
from __future__ import annotations

import json
import os
import platform
import subprocess
import time
from typing import Dict, Optional

__all__ = ["BENCH_SCHEMA", "machine_info", "git_sha", "write_bench",
           "ENVELOPE_KEYS"]

#: version of the shared envelope (not of any benchmark's payload)
BENCH_SCHEMA = 2

ENVELOPE_KEYS = ("bench", "bench_schema", "smoke", "created_unix",
                 "git_sha", "machine")


def machine_info() -> Dict[str, object]:
    """Host + accelerator identity, best effort (never raises)."""
    info: Dict[str, object] = {
        "platform": platform.platform(),
        "python": platform.python_version(),
        "cpus": os.cpu_count(),
    }
    try:
        import jax
        info["jax"] = jax.__version__
        info["jax_backend"] = jax.default_backend()
        info["jax_devices"] = jax.device_count()
    except Exception:  # pragma: no cover - jax is a hard dep in this repo
        pass
    return info


def git_sha(cwd: Optional[str] = None) -> Optional[str]:
    """HEAD commit of the repo the benchmark ran from (None if unknown)."""
    env_sha = os.environ.get("GITHUB_SHA")
    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"],
            cwd=cwd or os.path.dirname(os.path.abspath(__file__)),
            capture_output=True, text=True, timeout=10)
        if out.returncode == 0:
            return out.stdout.strip()
    except Exception:
        pass
    return env_sha


def write_bench(path: str, name: str, payload: Dict[str, object],
                smoke: bool = False) -> Dict[str, object]:
    """Write ``path`` as a uniform-schema bench artifact; return the doc."""
    clash = set(payload) & set(ENVELOPE_KEYS)
    if clash:
        raise ValueError(f"payload keys shadow the bench envelope: "
                         f"{sorted(clash)}")
    doc: Dict[str, object] = {
        "bench": str(name),
        "bench_schema": BENCH_SCHEMA,
        "smoke": bool(smoke),
        "created_unix": int(time.time()),
        "git_sha": git_sha(),
        "machine": machine_info(),
    }
    doc.update(payload)
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=False, default=float)
        f.write("\n")
    return doc
