"""Span tracer with Chrome-trace / Perfetto JSON export.

Spans are recorded only at natural host boundaries (function entry/exit on the
Python side of a dispatch, queue hand-offs, resolution callbacks) — never from
inside traced JAX code — so enabling tracing adds **zero extra compiles and
zero host syncs** to jitted ``lax.while_loop`` paths.

The tracer buffers events in a bounded deque under a lock; when the shared
:class:`~repro.obs.metrics.Switch` is off, ``span()`` hands back a shared no-op
context manager and nothing is buffered.

Export target is the Chrome trace-event JSON format, which Perfetto
(https://ui.perfetto.dev) opens directly:

* ``span()`` / ``add_span()`` emit complete events (``"ph": "X"``) with
  microsecond ``ts``/``dur`` relative to the tracer epoch.
* ``async_span()`` emits ``"b"``/``"e"`` async pairs so overlapping
  per-request lifetimes (e.g. PlanServer queue→solve) each render on their
  own track instead of stacking incorrectly on one thread lane.
"""
from __future__ import annotations

import collections
import json
import os
import threading
import time
from typing import Dict, List, Optional

from .metrics import GLOBAL_SWITCH, Switch


class _NoopSpan:
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NOOP = _NoopSpan()


class _Span:
    __slots__ = ("_tracer", "name", "args", "_t0")

    def __init__(self, tracer: "Tracer", name: str, args: Dict[str, object]):
        self._tracer = tracer
        self.name = name
        self.args = args
        self._t0 = 0.0

    def __enter__(self):
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self._tracer.add_span(self.name, self._t0, time.perf_counter(), **self.args)
        return False


class Tracer:
    """Thread-safe, bounded buffer of Chrome trace events."""

    def __init__(self, switch: Optional[Switch] = None, maxlen: int = 200_000):
        self.switch = switch if switch is not None else Switch(True)
        self._lock = threading.Lock()
        self._events = collections.deque(maxlen=maxlen)
        self._epoch = time.perf_counter()
        self._pid = os.getpid()

    # -- recording ---------------------------------------------------------
    def span(self, name: str, **args: object):
        """Context manager timing a host-side region; no-op when disabled."""
        if not self.switch.on:
            return _NOOP
        return _Span(self, name, args)

    def add_span(self, name: str, t_start: float, t_end: float, **args: object) -> None:
        """Record a completed span from ``time.perf_counter()`` endpoints.

        Lets callers stamp timestamps as events happen but defer buffering to
        a natural host point (PlanServer records queue spans at resolution).
        """
        if not self.switch.on:
            return
        ev = {
            "name": name,
            "ph": "X",
            "ts": (t_start - self._epoch) * 1e6,
            "dur": max(0.0, (t_end - t_start) * 1e6),
            "pid": self._pid,
            "tid": threading.get_ident() % 2**31,
        }
        if args:
            ev["args"] = {k: _jsonable(v) for k, v in args.items()}
        with self._lock:
            self._events.append(ev)

    def async_span(self, name: str, span_id: int, t_start: float, t_end: float,
                   cat: str = "async", **args: object) -> None:
        """Record a begin/end async pair (own track per ``span_id`` in Perfetto)."""
        if not self.switch.on:
            return
        common = {"name": name, "cat": cat, "id": int(span_id) % 2**31,
                  "pid": self._pid, "tid": threading.get_ident() % 2**31}
        b = dict(common, ph="b", ts=(t_start - self._epoch) * 1e6)
        e = dict(common, ph="e", ts=(t_end - self._epoch) * 1e6)
        if args:
            b["args"] = {k: _jsonable(v) for k, v in args.items()}
        with self._lock:
            self._events.append(b)
            self._events.append(e)

    def instant(self, name: str, **args: object) -> None:
        if not self.switch.on:
            return
        ev = {
            "name": name,
            "ph": "i",
            "s": "t",
            "ts": (time.perf_counter() - self._epoch) * 1e6,
            "pid": self._pid,
            "tid": threading.get_ident() % 2**31,
        }
        if args:
            ev["args"] = {k: _jsonable(v) for k, v in args.items()}
        with self._lock:
            self._events.append(ev)

    # -- inspection / export ----------------------------------------------
    def events(self) -> List[dict]:
        with self._lock:
            return list(self._events)

    def __len__(self) -> int:
        with self._lock:
            return len(self._events)

    def clear(self) -> None:
        with self._lock:
            self._events.clear()

    def to_chrome(self) -> dict:
        """Chrome trace-event document; open at https://ui.perfetto.dev."""
        return {"traceEvents": self.events(), "displayTimeUnit": "ms"}

    def save(self, path: str) -> str:
        doc = self.to_chrome()
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(path, "w") as f:
            json.dump(doc, f)
        return path


def _jsonable(v: object) -> object:
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    return str(v)


#: Global tracer, gated on the process-wide switch (off by default).
TRACER = Tracer(GLOBAL_SWITCH)


def span(name: str, **args: object):
    """``with trace.span("gia.solve", sig=...):`` on the global tracer."""
    return TRACER.span(name, **args)


def add_span(name: str, t_start: float, t_end: float, **args: object) -> None:
    TRACER.add_span(name, t_start, t_end, **args)


def async_span(name: str, span_id: int, t_start: float, t_end: float, **args: object) -> None:
    TRACER.async_span(name, span_id, t_start, t_end, **args)


def instant(name: str, **args: object) -> None:
    TRACER.instant(name, **args)


def save(path: str) -> str:
    return TRACER.save(path)
