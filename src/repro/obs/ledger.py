"""RunLedger: the per-round predicted-vs-measured drift timeline.

The paper's premise is that energy/time/comm-bits are *predictable* enough to
optimize over; :class:`~repro.api.plan.RunReport` already closes that loop at
end-of-run aggregates.  The ledger refines it to a per-round timeline: for
every executed round, what the Plan budgeted (``predicted_T / K0``,
``expected_round_bits()``, ``predicted_E / K0``) next to what the run
realized (the FaultTrace's deadline-cut round times, the sampled cohort's
wire bits, the cost model at the executed rounds), plus running cumulative
drift ratios.

A ledger is a **pure function of the frozen RunReport** — it reads no clocks
and no global state — so ``RunReport.drift()`` returns the identical object
whether observability is enabled or not (the observer-effect suite asserts
this).  Wall-clock timings live in spans and metrics, never here.
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
from typing import Dict, List, Tuple

__all__ = ["LedgerRow", "RunLedger"]


def _ratio(measured: float, predicted: float) -> float:
    """Relative drift (measured/predicted - 1); NaN when undefined."""
    if not math.isfinite(predicted) or predicted == 0.0:
        return math.nan
    return measured / predicted - 1.0


@dataclasses.dataclass(frozen=True)
class LedgerRow:
    """One round's predicted-vs-measured entry (all per-round quantities)."""

    round: int
    predicted_time_s: float
    measured_time_s: float
    predicted_bits: float
    measured_bits: float
    predicted_energy_j: float
    measured_energy_j: float
    # running totals through this round, and their relative drift
    cum_predicted_time_s: float
    cum_measured_time_s: float
    cum_predicted_bits: float
    cum_measured_bits: float
    cum_predicted_energy_j: float
    cum_measured_energy_j: float
    drift_time: float
    drift_bits: float
    drift_energy: float

    def to_json(self) -> Dict[str, object]:
        # not dataclasses.asdict: that deep-copies every leaf, and the
        # ledger write sits on Scenario.run's obs-enabled exit path
        return {name: getattr(self, name) for name in _ROW_FIELDS}


_ROW_FIELDS = tuple(f.name for f in dataclasses.fields(LedgerRow))

# rows are a fixed all-number schema, so to_jsonl renders them through a
# %-template instead of per-row json.dumps (~5x cheaper; the write sits on
# Scenario.run's obs-enabled exit path).  repr(float) round-trips exactly,
# so load_jsonl reconstructs bit-identical rows.
_ROW_TEMPLATE = ("{" + ", ".join(f'"{n}": %s' for n in _ROW_FIELDS) + "}")


def _jnum(v) -> str:
    """JSON number token for ``v`` (json.loads-compatible, incl. NaN/inf)."""
    v = float(v)
    if math.isnan(v):
        return "NaN"
    if math.isinf(v):
        return "Infinity" if v > 0 else "-Infinity"
    return repr(v)


def _row_line(row: LedgerRow) -> str:
    return _ROW_TEMPLATE % ((row.round,) + tuple(
        _jnum(getattr(row, n)) for n in _ROW_FIELDS[1:]))


@dataclasses.dataclass(frozen=True)
class RunLedger:
    """Per-round drift ledger of one run; built by ``RunReport.drift()``."""

    rows: Tuple[LedgerRow, ...] = ()
    backend: str = ""
    family: str = ""

    @classmethod
    def from_report(cls, report) -> "RunLedger":
        """Build the timeline from a frozen RunReport.

        Per-round predictions are the Plan's totals amortized over its
        planned ``K0`` (the cost models are linear in the round count, so
        this is exact, not an approximation).  Per-round measurements use
        the finest trace the report carries: realized round times from the
        FaultTrace when faults ran, realized cohort bits from
        ``round_bits_trace`` when sampling ran — falling back to the
        uniform per-round share of the measured totals, which is exact for
        deterministic full-participation runs.
        """
        plan = report.plan
        R = int(report.rounds)
        pred_t = plan.predicted_T / plan.K0
        pred_e = plan.predicted_E / plan.K0
        pred_b = plan.expected_round_bits()

        ft = report.fault_trace
        fault_t = None
        if ft is not None and len(ft) >= R:
            fault_t = [r.t_round for r in ft.records[:R]]
        bits_tr = report.round_bits_trace
        have_bits = len(bits_tr) >= R

        meas_e = report.measured_E / R if R else math.nan
        rows: List[LedgerRow] = []
        cpt = cpe = cpb = 0.0
        cmt = cme = cmb = 0.0
        for r in range(R):
            mt = fault_t[r] if fault_t is not None else (
                report.measured_T / R)
            mb = float(bits_tr[r]) if have_bits else (report.comm_bits / R)
            cpt += pred_t
            cpe += pred_e
            cpb += pred_b
            cmt += mt
            cme += meas_e
            cmb += mb
            rows.append(LedgerRow(
                round=r,
                predicted_time_s=pred_t, measured_time_s=mt,
                predicted_bits=pred_b, measured_bits=mb,
                predicted_energy_j=pred_e, measured_energy_j=meas_e,
                cum_predicted_time_s=cpt, cum_measured_time_s=cmt,
                cum_predicted_bits=cpb, cum_measured_bits=cmb,
                cum_predicted_energy_j=cpe, cum_measured_energy_j=cme,
                drift_time=_ratio(cmt, cpt),
                drift_bits=_ratio(cmb, cpb),
                drift_energy=_ratio(cme, cpe)))
        return cls(rows=tuple(rows), backend=report.backend,
                   family=plan.family)

    # ------------------------------------------------------------------
    def __len__(self) -> int:
        return len(self.rows)

    def cumulative(self) -> Dict[str, float]:
        """Final cumulative drift ratios (empty run: all NaN)."""
        if not self.rows:
            return {"drift_time": math.nan, "drift_bits": math.nan,
                    "drift_energy": math.nan}
        last = self.rows[-1]
        return {"drift_time": last.drift_time,
                "drift_bits": last.drift_bits,
                "drift_energy": last.drift_energy}

    def to_jsonl(self, path: str) -> str:
        """One JSON object per round, plus a trailing summary line."""
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        lines = [_row_line(row) for row in self.rows]
        lines.append(json.dumps({"summary": True, "backend": self.backend,
                                 "family": self.family,
                                 "rounds": len(self.rows),
                                 **self.cumulative()}))
        with open(path, "w") as f:
            f.write("\n".join(lines) + "\n")
        return path

    @classmethod
    def load_jsonl(cls, path: str) -> "RunLedger":
        rows = []
        backend = family = ""
        with open(path) as f:
            for line in f:
                doc = json.loads(line)
                if doc.get("summary"):
                    backend = doc.get("backend", "")
                    family = doc.get("family", "")
                    continue
                rows.append(LedgerRow(**doc))
        return cls(rows=tuple(rows), backend=backend, family=family)

    def summary(self) -> str:
        c = self.cumulative()
        return (f"RunLedger[{self.backend}/{self.family}] "
                f"{len(self.rows)} rounds | cumulative drift: "
                f"time {c['drift_time']:+.3%} "
                f"bits {c['drift_bits']:+.3%} "
                f"energy {c['drift_energy']:+.3%}")
