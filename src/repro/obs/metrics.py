"""Low-overhead metrics primitives: counters, gauges, histograms.

Design constraints (see ISSUE 10):

* **Off by default, provably inert.**  Every instrument holds a reference to a
  :class:`Switch`; when the switch is off, ``inc``/``set``/``observe`` return
  after a single attribute check and no state mutates.  The global registry
  (:data:`REGISTRY`) is gated on the process-wide switch flipped by
  ``repro.obs.enable()``.  Components that must *always* measure (PlanServer's
  ``stats()`` is a public API, not an opt-in) construct their own registry with
  an always-on switch.
* **No device interaction.**  Instruments only touch host Python state, so they
  can be called from jitted-function *host* call sites without adding compiles
  or syncs.
* **Thread-safe.**  Each instrument carries its own lock; the registry guards
  get-or-create with another.  Locks are only taken when the switch is on.

Histograms keep raw samples (bounded reservoir) so they can serve exact
p50/p95/p99 for the sample sizes this repo sees (1e2..1e5 observations).
"""
from __future__ import annotations

import math
import random
import threading
from typing import Dict, List, Optional, Tuple


class Switch:
    """A shared boolean flag instruments check before recording."""

    __slots__ = ("on",)

    def __init__(self, on: bool = True):
        self.on = bool(on)


#: Process-wide switch controlled by ``repro.obs.enable()`` / ``disable()``.
GLOBAL_SWITCH = Switch(False)


def _label_key(labels: Dict[str, str]) -> Tuple[Tuple[str, str], ...]:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def _format_labels(labels: Tuple[Tuple[str, str], ...]) -> str:
    if not labels:
        return ""
    inner = ",".join('%s="%s"' % (k, v) for k, v in labels)
    return "{%s}" % inner


class _Instrument:
    __slots__ = ("name", "labels", "_switch", "_lock")

    def __init__(self, name: str, labels: Tuple[Tuple[str, str], ...], switch: Switch):
        self.name = name
        self.labels = labels
        self._switch = switch
        self._lock = threading.Lock()

    @property
    def full_name(self) -> str:
        return self.name + _format_labels(self.labels)


class Counter(_Instrument):
    """Monotonically increasing counter."""

    __slots__ = ("value",)

    def __init__(self, name, labels, switch):
        super().__init__(name, labels, switch)
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        if not self._switch.on:
            return
        with self._lock:
            self.value += n


class Gauge(_Instrument):
    """Last-write-wins instantaneous value."""

    __slots__ = ("value",)

    def __init__(self, name, labels, switch):
        super().__init__(name, labels, switch)
        self.value = 0.0

    def set(self, v: float) -> None:
        if not self._switch.on:
            return
        with self._lock:
            self.value = float(v)

    def add(self, dv: float) -> None:
        if not self._switch.on:
            return
        with self._lock:
            self.value += dv


class Histogram(_Instrument):
    """Sample histogram with exact percentiles over a bounded reservoir.

    Keeps up to ``maxlen`` raw samples; beyond that, new samples overwrite a
    pseudo-random slot (seeded RNG, so runs are reproducible).  ``count``,
    ``total``, ``min`` and ``max`` always reflect every observation.
    """

    __slots__ = ("_samples", "count", "total", "vmin", "vmax", "_maxlen", "_rng")

    def __init__(self, name, labels, switch, maxlen: int = 100_000):
        super().__init__(name, labels, switch)
        self._samples: List[float] = []
        self.count = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf
        self._maxlen = int(maxlen)
        self._rng = random.Random(0)

    def observe(self, v: float) -> None:
        if not self._switch.on:
            return
        v = float(v)
        with self._lock:
            self.count += 1
            self.total += v
            if v < self.vmin:
                self.vmin = v
            if v > self.vmax:
                self.vmax = v
            if len(self._samples) < self._maxlen:
                self._samples.append(v)
            else:  # reservoir replacement keeps percentiles representative
                self._samples[self._rng.randrange(self._maxlen)] = v

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else math.nan

    def percentile(self, q: float) -> float:
        """Exact percentile (linear interpolation) over retained samples."""
        with self._lock:
            xs = sorted(self._samples)
        if not xs:
            return math.nan
        if len(xs) == 1:
            return xs[0]
        pos = (q / 100.0) * (len(xs) - 1)
        lo = int(math.floor(pos))
        hi = min(lo + 1, len(xs) - 1)
        frac = pos - lo
        return xs[lo] * (1.0 - frac) + xs[hi] * frac

    def summary(self) -> Dict[str, float]:
        with self._lock:
            n = self.count
        if n == 0:
            return {"count": 0}
        return {
            "count": n,
            "mean": self.mean,
            "min": self.vmin,
            "max": self.vmax,
            "p50": self.percentile(50),
            "p95": self.percentile(95),
            "p99": self.percentile(99),
        }


class MetricsRegistry:
    """Get-or-create registry of instruments keyed by (name, labels)."""

    def __init__(self, switch: Optional[Switch] = None):
        self.switch = switch if switch is not None else Switch(True)
        self._lock = threading.Lock()
        self._metrics: Dict[Tuple[str, str, Tuple[Tuple[str, str], ...]], _Instrument] = {}

    def _get(self, kind: str, cls, name: str, labels: Dict[str, str], **kw):
        key = (kind, name, _label_key(labels))
        with self._lock:
            m = self._metrics.get(key)
            if m is None:
                m = cls(name, key[2], self.switch, **kw)
                self._metrics[key] = m
            return m

    def counter(self, name: str, **labels: str) -> Counter:
        return self._get("counter", Counter, name, labels)

    def gauge(self, name: str, **labels: str) -> Gauge:
        return self._get("gauge", Gauge, name, labels)

    def histogram(self, name: str, maxlen: int = 100_000, **labels: str) -> Histogram:
        return self._get("histogram", Histogram, name, labels, maxlen=maxlen)

    def instruments(self) -> List[_Instrument]:
        with self._lock:
            return list(self._metrics.values())

    def __len__(self) -> int:
        with self._lock:
            return len(self._metrics)

    def reset(self) -> None:
        with self._lock:
            self._metrics.clear()

    def snapshot(self) -> Dict[str, object]:
        """Plain-dict view: counters/gauges -> value, histograms -> summary."""
        out: Dict[str, object] = {}
        for m in self.instruments():
            if isinstance(m, Histogram):
                out[m.full_name] = m.summary()
            else:
                out[m.full_name] = m.value
        return out

    def to_prometheus(self) -> str:
        """Prometheus text exposition format (counters, gauges, summaries)."""
        lines: List[str] = []
        seen_types = set()
        for m in sorted(self.instruments(), key=lambda m: m.full_name):
            pname = m.name.replace(".", "_").replace("-", "_")
            lbl = _format_labels(m.labels)
            if isinstance(m, Counter):
                if pname not in seen_types:
                    lines.append("# TYPE %s counter" % pname)
                    seen_types.add(pname)
                lines.append("%s%s %g" % (pname, lbl, m.value))
            elif isinstance(m, Gauge):
                if pname not in seen_types:
                    lines.append("# TYPE %s gauge" % pname)
                    seen_types.add(pname)
                lines.append("%s%s %g" % (pname, lbl, m.value))
            elif isinstance(m, Histogram):
                if pname not in seen_types:
                    lines.append("# TYPE %s summary" % pname)
                    seen_types.add(pname)
                s = m.summary()
                base = list(m.labels)
                for q in (50, 95, 99):
                    qlbl = _format_labels(tuple(base + [("quantile", "0.%02d" % q)]))
                    lines.append("%s%s %g" % (pname, qlbl, s.get("p%d" % q, math.nan)))
                lines.append("%s_sum%s %g" % (pname, lbl, m.total))
                lines.append("%s_count%s %d" % (pname, lbl, m.count))
        return "\n".join(lines) + ("\n" if lines else "")


#: Global registry, gated on :data:`GLOBAL_SWITCH` (off by default).
REGISTRY = MetricsRegistry(GLOBAL_SWITCH)
