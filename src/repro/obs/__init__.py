"""repro.obs — spans, metrics, and the predicted-vs-measured drift ledger.

Off by default and provably inert: until :func:`enable` flips the global
switch, every ``trace.span`` returns a shared no-op context manager, every
global-registry instrument drops its sample after one attribute check, and
enabling it leaves Plan / RunReport / FaultTrace **bit-identical** (asserted
by ``tests/unit/test_obs.py`` and hard-gated, with <2% overhead, by
``benchmarks/obs_bench.py``).

Quickstart::

    from repro import obs
    obs.enable()
    with obs.trace.span("my.block", note="warm"):
        report = scenario.run(plan, backend="reference", seed=0)
    print(report.drift().summary())          # per-round drift ledger
    print(obs.REGISTRY.to_prometheus())      # metrics text dump
    obs.trace.save("results/obs/trace.json") # open at ui.perfetto.dev

Instrumented call sites record only at natural host boundaries (dispatch
wrappers, queue hand-offs, resolution callbacks) — never inside traced JAX
code — so jitted ``lax.while_loop`` paths gain zero extra compiles and zero
host syncs (asserted via the ``TRACE_COUNTS`` hook in ``repro.opt.gia_jax``).
"""
from __future__ import annotations

import os

from . import bench, trace
from .bench import write_bench
from .ledger import LedgerRow, RunLedger
from .metrics import (GLOBAL_SWITCH, REGISTRY, Counter, Gauge, Histogram,
                      MetricsRegistry, Switch)
from .trace import TRACER, Tracer, span

__all__ = [
    "enable", "disable", "enabled", "artifact_dir", "artifact_path",
    "trace", "span", "TRACER", "Tracer",
    "REGISTRY", "MetricsRegistry", "Counter", "Gauge", "Histogram", "Switch",
    "RunLedger", "LedgerRow",
    "bench", "write_bench",
]


def enable(reset: bool = False) -> None:
    """Turn on the global tracer + metrics registry (off by default)."""
    if reset:
        TRACER.clear()
        REGISTRY.reset()
    GLOBAL_SWITCH.on = True


def disable() -> None:
    """Turn observability back off (buffers are kept until ``enable(reset=True)``)."""
    GLOBAL_SWITCH.on = False


def enabled() -> bool:
    return GLOBAL_SWITCH.on


def artifact_dir() -> str:
    """Where run artifacts (ledgers, traces) land; override with REPRO_OBS_DIR."""
    return os.environ.get("REPRO_OBS_DIR", os.path.join("results", "obs"))


def artifact_path(name: str) -> str:
    d = artifact_dir()
    os.makedirs(d, exist_ok=True)
    return os.path.join(d, name)
