"""Mixture-of-Experts MLP: top-k routing with capacity + scatter dispatch.

Dispatch uses an (E, C, D) expert buffer filled by scatter-add — no
(T, E, C) one-hot dispatch tensor is ever materialized, so 32k-sequence
shapes stay lowerable.  With the expert axis sharded over ``tp`` and tokens
sharded over the batch axes, GSPMD inserts the all-to-all exchange.

Token-dropping semantics: assignments beyond an expert's capacity
``C = ceil(T * top_k / E * capacity_factor)`` are dropped (standard
Switch/GShard behaviour); dropped slots contribute zero and the residual
stream passes through.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig
from . import shardctx
from .blocks import dense_init

__all__ = ["moe_init", "moe_apply", "router_aux_loss"]


def moe_init(key, cfg: ArchConfig, dtype=jnp.float32):
    D, E, F = cfg.d_model, cfg.n_experts, cfg.d_expert
    ks = jax.random.split(key, 4)
    scale = 1.0 / np.sqrt(D)
    return {
        "router": dense_init(ks[0], D, E, dtype),
        "w_gate": (jax.random.normal(ks[1], (E, D, F), jnp.float32) * scale).astype(dtype),
        "w_up": (jax.random.normal(ks[2], (E, D, F), jnp.float32) * scale).astype(dtype),
        "w_down": (jax.random.normal(ks[3], (E, F, D), jnp.float32)
                   / np.sqrt(F)).astype(dtype),
    }


def moe_apply(p, x, cfg: ArchConfig):
    """x: (B, S, D) -> (y, router_probs) with y: (B, S, D)."""
    B, S, D = x.shape
    E, k = cfg.n_experts, cfg.top_k
    T = B * S
    xf = x.reshape(T, D)

    logits = (xf @ p["router"]).astype(jnp.float32)      # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, k)               # (T, k)
    top_p = top_p / jnp.clip(top_p.sum(-1, keepdims=True), 1e-9)

    C = int(np.ceil(T * k / E * cfg.capacity_factor))
    flat_e = top_i.reshape(-1)                            # (T*k,)
    # position of each assignment within its expert (token order)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)   # (T*k, E)
    pos = (jnp.cumsum(onehot, axis=0) - 1)
    pos = jnp.take_along_axis(pos, flat_e[:, None], axis=1)[:, 0]  # (T*k,)
    keep = pos < C
    slot = jnp.where(keep, pos, C - 1)

    tok = jnp.repeat(jnp.arange(T), k)                    # (T*k,)
    buf = jnp.zeros((E, C, D), x.dtype)
    contrib = xf[tok] * keep[:, None].astype(x.dtype)
    buf = buf.at[flat_e, slot].add(contrib, mode="drop")

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])) \
        * jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
    y_buf = jnp.einsum("ecf,efd->ecd", h, p["w_down"])    # (E, C, D)

    gathered = y_buf[flat_e, slot]                        # (T*k, D)
    w = (top_p.reshape(-1) * keep.astype(jnp.float32)).astype(x.dtype)
    out = (gathered * w[:, None]).reshape(T, k, D).sum(axis=1)
    return out.reshape(B, S, D), probs


def router_aux_loss(probs: jax.Array, top_i: jax.Array | None = None) -> jax.Array:
    """Switch-style load-balance auxiliary loss: E * sum_e f_e * P_e,
    with f_e the fraction of tokens whose argmax is e and P_e the mean router
    probability."""
    E = probs.shape[-1]
    hard = jax.nn.one_hot(jnp.argmax(probs, axis=-1), E, dtype=jnp.float32)
    f = hard.mean(axis=0)
    P = probs.mean(axis=0)
    return E * jnp.sum(f * P)
