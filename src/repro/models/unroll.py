"""Trace-time unroll switch for roofline extraction.

XLA's ``cost_analysis()`` counts a ``while`` (scan) body ONCE regardless of
trip count (verified empirically — see DESIGN.md §5), so roofline term
extraction compiles small *unrolled* model variants (1 and 2 layer-pattern
repeats) and extrapolates.  Inside ``unrolled()``, every structural scan
(layer stacks, attention query chunks, vocab-loss chunks, GenQSGD local
steps) traces as a Python loop instead.
"""
from __future__ import annotations

import contextlib

_UNROLL = False


def enabled() -> bool:
    return _UNROLL


@contextlib.contextmanager
def unrolled():
    global _UNROLL
    prev = _UNROLL
    _UNROLL = True
    try:
        yield
    finally:
        _UNROLL = prev
