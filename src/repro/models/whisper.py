"""Whisper-style encoder-decoder backbone (arXiv:2212.04356).

The mel-spectrogram + conv feature extractor is a STUB per the brief:
``input_specs`` provides precomputed frame embeddings (B, frames, D) — we
implement the transformer encoder (bidirectional) and decoder (causal self-
attention + cross-attention) that consume them.  Frames are capped at
``cfg.max_source_positions`` (1500 = 30 s audio).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig
from . import blocks as B
from . import unroll

__all__ = ["init_params", "abstract_params", "loss_train", "prefill",
           "decode_step", "init_caches"]


def _enc_block_init(key, cfg, dtype):
    D = cfg.d_model
    ks = jax.random.split(key, 2)
    return {"ln1": jnp.ones((D,), dtype),
            "attn": B.attn_init(ks[0], cfg, dtype),
            "ln2": jnp.ones((D,), dtype),
            "mlp": B.mlp_init(ks[1], cfg, dtype=dtype)}


def _dec_block_init(key, cfg, dtype):
    D = cfg.d_model
    ks = jax.random.split(key, 3)
    return {"ln1": jnp.ones((D,), dtype),
            "self_attn": B.attn_init(ks[0], cfg, dtype),
            "ln_x": jnp.ones((D,), dtype),
            "cross_attn": B.attn_init(ks[1], cfg, dtype),
            "ln2": jnp.ones((D,), dtype),
            "mlp": B.mlp_init(ks[2], cfg, dtype=dtype)}


def init_params(key, cfg: ArchConfig, dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    enc_keys = jax.random.split(ks[0], cfg.enc_layers)
    dec_keys = jax.random.split(ks[1], cfg.n_layers)
    return {
        "embed": (jax.random.normal(ks[2], (cfg.vocab, cfg.d_model),
                                    jnp.float32)
                  / np.sqrt(cfg.d_model)).astype(dtype),
        "enc": jax.vmap(lambda k: _enc_block_init(k, cfg, dtype))(enc_keys),
        "enc_norm": jnp.ones((cfg.d_model,), dtype),
        "dec": jax.vmap(lambda k: _dec_block_init(k, cfg, dtype))(dec_keys),
        "final_norm": jnp.ones((cfg.d_model,), dtype),
    }


def abstract_params(cfg: ArchConfig, dtype=jnp.bfloat16):
    return jax.eval_shape(lambda k: init_params(k, cfg, dtype),
                          jax.random.PRNGKey(0))


def encode(params, cfg: ArchConfig, frames):
    """frames: (B, F, D) stub conv-frontend output -> encoder states."""
    Bt, F, D = frames.shape
    pos = jnp.broadcast_to(jnp.arange(F, dtype=jnp.int32), (Bt, F))
    h = frames

    @jax.checkpoint
    def _enc_block(h, pl):
        a, _ = B.attn_apply(pl["attn"], B.rmsnorm(h, pl["ln1"], cfg.norm_eps),
                            cfg, pos, causal=False)
        h = h + a
        h = h + B.mlp_apply(pl["mlp"], B.rmsnorm(h, pl["ln2"], cfg.norm_eps))
        return h

    def body(h, pl):
        return _enc_block(h, pl), None

    if unroll.enabled():
        for j in range(cfg.enc_layers):
            h, _ = body(h, jax.tree.map(lambda a: a[j], params["enc"]))
    else:
        h, _ = jax.lax.scan(body, h, params["enc"])
    return B.rmsnorm(h, params["enc_norm"], cfg.norm_eps)


def _cross_attend(p, x, enc, cfg: ArchConfig):
    """Cross attention: queries from decoder x, K/V from encoder states."""
    Bt, S, D = x.shape
    F = enc.shape[1]
    H, KV, dh = cfg.n_heads, cfg.n_kv, cfg.d_head
    q = (x @ p["wq"]).reshape(Bt, S, H, dh)
    k = (enc @ p["wk"]).reshape(Bt, F, KV, dh)
    v = (enc @ p["wv"]).reshape(Bt, F, KV, dh)
    G = H // KV
    qpos = jnp.zeros((Bt, S), jnp.int32)
    kpos = jnp.zeros((Bt, F), jnp.int32)
    out = B._sdpa_chunk(q.reshape(Bt, S, KV, G, dh), k, v, qpos, kpos,
                        None, causal=False)
    return out.reshape(Bt, S, H * dh) @ p["wo"]


def _decode_stack(params, cfg: ArchConfig, h, pos, enc):
    @jax.checkpoint
    def _dec_block(h, pl):
        a, _ = B.attn_apply(pl["self_attn"],
                            B.rmsnorm(h, pl["ln1"], cfg.norm_eps), cfg, pos)
        h = h + a
        h = h + _cross_attend(pl["cross_attn"],
                              B.rmsnorm(h, pl["ln_x"], cfg.norm_eps), enc, cfg)
        h = h + B.mlp_apply(pl["mlp"], B.rmsnorm(h, pl["ln2"], cfg.norm_eps))
        return h

    def body(h, pl):
        return _dec_block(h, pl), None

    if unroll.enabled():
        for j in range(cfg.n_layers):
            h, _ = body(h, jax.tree.map(lambda a: a[j], params["dec"]))
    else:
        h, _ = jax.lax.scan(body, h, params["dec"])
    return B.rmsnorm(h, params["final_norm"], cfg.norm_eps)


def loss_train(params, cfg: ArchConfig, batch, aux_weight: float = 0.0):
    """batch: frames (B,F,D), tokens (B,S), labels (B,S)."""
    enc = encode(params, cfg, batch["frames"])
    tokens, labels = batch["tokens"], batch["labels"]
    Bt, S = tokens.shape
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (Bt, S))
    h = params["embed"][tokens]
    h = _decode_stack(params, cfg, h, pos, enc)

    @jax.checkpoint
    def _chunk_ce(hh, ll):
        logits = (hh @ params["embed"].T).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, ll[..., None], axis=-1)[..., 0]
        return (logz - gold).sum()

    CH = 512
    if S % CH == 0 and S > CH:
        hc = jnp.moveaxis(h.reshape(Bt, S // CH, CH, -1), 1, 0)
        lc = jnp.moveaxis(labels.reshape(Bt, S // CH, CH), 1, 0)
        total, _ = jax.lax.scan(
            lambda acc, args: (acc + _chunk_ce(*args), None),
            jnp.zeros((), jnp.float32), (hc, lc))
    else:
        total = _chunk_ce(h, labels)
    return total / (Bt * S)


def init_caches(cfg: ArchConfig, batch: int, length: int, dtype=jnp.bfloat16):
    """Self-attn KV caches (stacked over decoder layers) + encoder states."""
    one = B.make_cache(cfg, batch, length, dtype=dtype)
    self_caches = jax.tree.map(
        lambda a: jnp.broadcast_to(a, (cfg.n_layers,) + a.shape), one)
    enc_states = jnp.zeros((batch, min(cfg.max_source_positions, length),
                            cfg.d_model), dtype)
    return {"self": self_caches, "enc": enc_states}


def prefill(params, cfg: ArchConfig, batch, cache_len: Optional[int] = None,
            cache_dtype=jnp.bfloat16):
    """Encode audio + run decoder over the prompt, building caches."""
    enc = encode(params, cfg, batch["frames"])
    tokens = batch["tokens"]
    Bt, S = tokens.shape
    cache_len = cache_len or S
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (Bt, S))
    h = params["embed"][tokens]

    def body(h, pl):
        x1 = B.rmsnorm(h, pl["ln1"], cfg.norm_eps)
        a, (k, v) = B.attn_apply(pl["self_attn"], x1, cfg, pos)
        h = h + a
        h = h + _cross_attend(pl["cross_attn"],
                              B.rmsnorm(h, pl["ln_x"], cfg.norm_eps), enc, cfg)
        h = h + B.mlp_apply(pl["mlp"], B.rmsnorm(h, pl["ln2"], cfg.norm_eps))
        C = cache_len
        if S >= C:
            ck, cv, cp = k[:, S - C:], v[:, S - C:], pos[:, S - C:]
        else:
            pad = jnp.zeros((Bt, C - S) + k.shape[2:], k.dtype)
            ck = jnp.concatenate([k, pad], 1)
            cv = jnp.concatenate([v, pad], 1)
            cp = jnp.concatenate([pos, jnp.full((Bt, C - S), -1, jnp.int32)], 1)
        cache = {"k": ck.astype(cache_dtype), "v": cv.astype(cache_dtype),
                 "pos": cp.astype(jnp.int32),
                 "idx": jnp.full((Bt,), S, jnp.int32)}
        return h, cache

    if unroll.enabled():
        outs = []
        for j in range(cfg.n_layers):
            h, c = body(h, jax.tree.map(lambda a: a[j], params["dec"]))
            outs.append(c)
        self_caches = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
    else:
        h, self_caches = jax.lax.scan(body, h, params["dec"])
    h = B.rmsnorm(h, params["final_norm"], cfg.norm_eps)
    logits = (h[:, -1] @ params["embed"].T)
    return logits, {"self": self_caches, "enc": enc.astype(cache_dtype)}


def decode_step(params, cfg: ArchConfig, token, caches, pos):
    """token: (B,1); pos: (B,1).  Returns (logits (B,V), new caches)."""
    h = params["embed"][token]
    enc = caches["enc"]

    def body(h, xs):
        pl, cache = xs
        x1 = B.rmsnorm(h, pl["ln1"], cfg.norm_eps)
        a, cache = B.attn_decode(pl["self_attn"], x1, cfg, pos, cache)
        h = h + a
        h = h + _cross_attend(pl["cross_attn"],
                              B.rmsnorm(h, pl["ln_x"], cfg.norm_eps), enc, cfg)
        h = h + B.mlp_apply(pl["mlp"], B.rmsnorm(h, pl["ln2"], cfg.norm_eps))
        return h, cache

    if unroll.enabled():
        outs = []
        for j in range(cfg.n_layers):
            h, c = body(h, (jax.tree.map(lambda a: a[j], params["dec"]),
                            jax.tree.map(lambda a: a[j], caches["self"])))
            outs.append(c)
        self_caches = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
    else:
        h, self_caches = jax.lax.scan(body, h,
                                      (params["dec"], caches["self"]))
    h = B.rmsnorm(h, params["final_norm"], cfg.norm_eps)
    logits = h[:, 0] @ params["embed"].T
    return logits, {"self": self_caches, "enc": enc}
