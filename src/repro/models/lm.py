"""Unified decoder-only LM over heterogeneous block stacks.

The layer list (``cfg.layer_types``) is segmented into maximal runs of equal
block type; each segment's per-layer params are stacked on a leading axis and
applied with ``lax.scan`` — full-size HLO stays small (one body per segment)
and 100B+ configs lower abstractly.

Supported block types:
  attn         full-attention + dense MLP           (qwen3 / mistral / llama3 / qwen2-vl)
  local        sliding-window attention + dense MLP (gemma3)
  global       full-attention + dense MLP           (gemma3's 1-in-6 layers)
  attn_moe     full-attention + MoE MLP             (olmoe / phi3.5-moe)
  mamba2       Mamba2 SSD mixer                     (zamba2)
  shared_attn  zamba2's weight-shared attention+MLP block (one param set,
               per-invocation input norm)
  mlstm/slstm  xLSTM blocks
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig
from . import blocks as B
from . import shardctx
from . import unroll
from . import mamba2 as M2
from . import moe as MOE
from . import xlstm as XL

__all__ = ["init_params", "abstract_params", "loss_train", "prefill",
           "decode_step", "init_caches", "forward_hidden"]

LOSS_CHUNK = 512  # sequence chunk for the vocab-projection loss

ATTN_TYPES = ("attn", "local", "global", "attn_moe", "shared_attn")


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def _block_init(key, btype: str, cfg: ArchConfig, dtype):
    D = cfg.d_model
    ks = jax.random.split(key, 3)
    if btype in ("attn", "local", "global"):
        return {"ln1": jnp.ones((D,), dtype),
                "attn": B.attn_init(ks[0], cfg, dtype),
                "ln2": jnp.ones((D,), dtype),
                "mlp": B.mlp_init(ks[1], cfg, dtype=dtype)}
    if btype == "attn_moe":
        return {"ln1": jnp.ones((D,), dtype),
                "attn": B.attn_init(ks[0], cfg, dtype),
                "ln2": jnp.ones((D,), dtype),
                "moe": MOE.moe_init(ks[1], cfg, dtype)}
    if btype == "mamba2":
        return {"ln1": jnp.ones((D,), dtype),
                "mamba": M2.mamba2_init(ks[0], cfg, dtype)}
    if btype == "shared_attn":
        # per-invocation params only; the weight-shared body lives in
        # params["shared"]
        return {"ln1": jnp.ones((D,), dtype), "ln2": jnp.ones((D,), dtype)}
    if btype == "mlstm":
        return {"ln1": jnp.ones((D,), dtype),
                "mlstm": XL.mlstm_init(ks[0], cfg, dtype)}
    if btype == "slstm":
        return {"ln1": jnp.ones((D,), dtype),
                "slstm": XL.slstm_init(ks[0], cfg, dtype)}
    raise ValueError(f"unknown block type {btype!r}")


def init_params(key, cfg: ArchConfig, dtype=jnp.float32):
    ks = jax.random.split(key, len(cfg.segments) + 3)
    params = {
        "embed": (jax.random.normal(ks[0], (cfg.vocab, cfg.d_model),
                                    jnp.float32)
                  / np.sqrt(cfg.d_model)).astype(dtype),
        "final_norm": jnp.ones((cfg.d_model,), dtype),
        "segments": [],
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = B.dense_init(ks[1], cfg.d_model, cfg.vocab, dtype)
    if any(t == "shared_attn" for t, _ in cfg.segments):
        params["shared"] = {
            "attn": B.attn_init(jax.random.fold_in(ks[2], 1), cfg, dtype),
            "mlp": B.mlp_init(jax.random.fold_in(ks[2], 2), cfg, dtype=dtype),
        }
    for i, (btype, count) in enumerate(cfg.segments):
        seg_keys = jax.random.split(ks[3 + i] if 3 + i < len(ks)
                                    else jax.random.fold_in(key, 1000 + i),
                                    count)
        params["segments"].append(
            jax.vmap(lambda k: _block_init(k, btype, cfg, dtype))(seg_keys))
    return params


def abstract_params(cfg: ArchConfig, dtype=jnp.bfloat16):
    return jax.eval_shape(
        lambda k: init_params(k, cfg, dtype), jax.random.PRNGKey(0))


# ---------------------------------------------------------------------------
# block application (train / prefill path)
# ---------------------------------------------------------------------------
def _block_apply(p, h, btype, cfg: ArchConfig, positions, shared, aux):
    _in = shardctx.constrain_interior
    if btype in ("attn", "local", "global", "attn_moe"):
        window = cfg.window if btype == "local" else None
        a, _ = B.attn_apply({**p["attn"]},
                            _in(B.rmsnorm(h, p["ln1"], cfg.norm_eps)),
                            cfg, positions, window=window)
        h = h + a
        x2 = shardctx.constrain_interior_mlp(
            B.rmsnorm(h, p["ln2"], cfg.norm_eps))
        if btype == "attn_moe":
            y, probs = MOE.moe_apply(p["moe"], x2, cfg)
            aux = aux + MOE.router_aux_loss(probs)
        else:
            y = B.mlp_apply(p["mlp"], x2)
        return h + y, aux
    if btype == "mamba2":
        return h + M2.mamba2_apply(p["mamba"],
                                   _in(B.rmsnorm(h, p["ln1"], cfg.norm_eps)),
                                   cfg), aux
    if btype == "shared_attn":
        a, _ = B.attn_apply(shared["attn"],
                            _in(B.rmsnorm(h, p["ln1"], cfg.norm_eps)),
                            cfg, positions)
        h = h + a
        y = B.mlp_apply(shared["mlp"],
                        _in(B.rmsnorm(h, p["ln2"], cfg.norm_eps)))
        return h + y, aux
    if btype == "mlstm":
        return h + XL.mlstm_apply(p["mlstm"],
                                  _in(B.rmsnorm(h, p["ln1"], cfg.norm_eps)),
                                  cfg), aux
    if btype == "slstm":
        return h + XL.slstm_apply(p["slstm"],
                                  _in(B.rmsnorm(h, p["ln1"], cfg.norm_eps)),
                                  cfg), aux
    raise ValueError(btype)


def _group_factor(count: int) -> int:
    """Divisor of ``count`` nearest sqrt(count) (2-level remat split)."""
    best, target = 1, count ** 0.5
    for g in range(1, count + 1):
        if count % g == 0 and abs(g - target) < abs(best - target):
            best = g
    return best


def forward_hidden(params, cfg: ArchConfig, h, positions,
                   unroll_segments: bool = False):
    """Run the block stack on embeddings h: (B, S, D) -> (h, aux_loss)."""
    shared = params.get("shared")
    aux = jnp.zeros((), jnp.float32)
    h = shardctx.constrain(h)
    for (btype, count), seg_p in zip(cfg.segments, params["segments"]):
        if count == 1 or unroll_segments or unroll.enabled():
            for j in range(count):
                pj = jax.tree.map(lambda a: a[j], seg_p)
                h, aux = _block_apply(pj, h, btype, cfg, positions, shared, aux)
                h = shardctx.constrain(h)
        else:
            # remat the block body: the backward pass recomputes per-layer
            # intermediates instead of saving them across the layer scan.
            ck = jax.checkpoint(
                lambda pl, hh, ax, pos, sh: _block_apply(
                    pl, hh, btype, cfg, pos, sh, ax),
                static_argnums=())

            def body(carry, pl, btype=btype, ck=ck):
                hh, ax = carry
                hh, ax = ck(pl, hh, ax, positions, shared)
                hh = shardctx.constrain(hh)
                return (hh, ax), None

            if count >= 16:
                # two-level (sqrt-L) remat: scan groups of layers, each group
                # itself checkpointed — peak saved carries ~ G + count/G.
                G = _group_factor(count)
                seg2 = jax.tree.map(
                    lambda a: a.reshape((G, count // G) + a.shape[1:]), seg_p)
                group = jax.checkpoint(
                    lambda carry, grp: jax.lax.scan(body, carry, grp)[0])

                def outer(carry, grp):
                    return group(carry, grp), None

                (h, aux), _ = jax.lax.scan(outer, (h, aux), seg2)
            else:
                (h, aux), _ = jax.lax.scan(body, (h, aux), seg_p)
    h = B.rmsnorm(h, params["final_norm"], cfg.norm_eps)
    return h, aux


def _embed(params, cfg: ArchConfig, batch):
    tokens = batch["tokens"]
    h = params["embed"][tokens]
    if "patch_embeds" in batch:   # VLM: overwrite the image-token span
        n_patch = batch["patch_embeds"].shape[1]
        h = jnp.concatenate(
            [batch["patch_embeds"].astype(h.dtype), h[:, n_patch:]], axis=1)
    return h


def _positions(cfg: ArchConfig, batch):
    if cfg.mrope:
        return batch["positions3"]
    tokens = batch["tokens"]
    Bt, S = tokens.shape
    return jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (Bt, S))


def _logits(params, cfg: ArchConfig, h):
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return h @ head


def loss_train(params, cfg: ArchConfig, batch, aux_weight: float = 0.01):
    """Causal-LM cross entropy, sequence-chunked vocab projection."""
    h = _embed(params, cfg, batch)
    pos = _positions(cfg, batch)
    h, aux = forward_hidden(params, cfg, h, pos)
    labels = batch["labels"]
    Bt, S, D = h.shape
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]

    n_chunks = max(1, S // LOSS_CHUNK)
    if S % LOSS_CHUNK == 0 and n_chunks > 1:
        hc = jnp.moveaxis(h.reshape(Bt, n_chunks, LOSS_CHUNK, D), 1, 0)
        lc = jnp.moveaxis(labels.reshape(Bt, n_chunks, LOSS_CHUNK), 1, 0)

        @jax.checkpoint
        def _chunk_ce(hh, ll):
            logits = (hh @ head).astype(jnp.float32)
            logz = jax.nn.logsumexp(logits, axis=-1)
            gold = jnp.take_along_axis(logits, ll[..., None], axis=-1)[..., 0]
            return (logz - gold).sum()

        def body(acc, args):
            hh, ll = args
            return acc + _chunk_ce(hh, ll), None

        if unroll.enabled():
            total = jnp.zeros((), jnp.float32)
            for i in range(n_chunks):
                total, _ = body(total, (hc[i], lc[i]))
        else:
            total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32),
                                    (hc, lc))
    else:
        logits = (h @ head).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
        total = (logz - gold).sum()
    return total / (Bt * S) + aux_weight * aux


# ---------------------------------------------------------------------------
# caches / decode
# ---------------------------------------------------------------------------
def _block_cache(btype: str, cfg: ArchConfig, batch: int, length: int, dtype):
    if btype in ("attn", "global", "attn_moe", "shared_attn"):
        return B.make_cache(cfg, batch, length, dtype=dtype)
    if btype == "local":
        return B.make_cache(cfg, batch, min(cfg.window, length), dtype=dtype)
    if btype == "mamba2":
        return M2.make_ssm_state(cfg, batch, dtype)
    if btype == "mlstm":
        return XL.make_mlstm_state(cfg, batch)
    if btype == "slstm":
        return XL.make_slstm_state(cfg, batch)
    raise ValueError(btype)


def init_caches(cfg: ArchConfig, batch: int, length: int, dtype=jnp.bfloat16):
    """One stacked cache pytree per segment."""
    caches = []
    for btype, count in cfg.segments:
        one = _block_cache(btype, cfg, batch, length, dtype)
        caches.append(jax.tree.map(
            lambda a: jnp.broadcast_to(a, (count,) + a.shape), one))
    return caches


def _block_decode(p, h, cache, btype, cfg: ArchConfig, positions, shared):
    if btype in ("attn", "local", "global", "attn_moe"):
        window = cfg.window if btype == "local" else None
        a, cache = B.attn_decode(p["attn"],
                                 B.rmsnorm(h, p["ln1"], cfg.norm_eps),
                                 cfg, positions, cache, window=window)
        h = h + a
        x2 = B.rmsnorm(h, p["ln2"], cfg.norm_eps)
        if btype == "attn_moe":
            y, _ = MOE.moe_apply(p["moe"], x2, cfg)
        else:
            y = B.mlp_apply(p["mlp"], x2)
        return h + y, cache
    if btype == "shared_attn":
        a, cache = B.attn_decode(shared["attn"],
                                 B.rmsnorm(h, p["ln1"], cfg.norm_eps),
                                 cfg, positions, cache)
        h = h + a
        y = B.mlp_apply(shared["mlp"], B.rmsnorm(h, p["ln2"], cfg.norm_eps))
        return h + y, cache
    if btype == "mamba2":
        y, cache = M2.mamba2_decode(p["mamba"],
                                    B.rmsnorm(h, p["ln1"], cfg.norm_eps),
                                    cfg, cache)
        return h + y, cache
    if btype == "mlstm":
        y, cache = XL.mlstm_decode(p["mlstm"],
                                   B.rmsnorm(h, p["ln1"], cfg.norm_eps),
                                   cfg, cache)
        return h + y, cache
    if btype == "slstm":
        y, cache = XL.slstm_decode(p["slstm"],
                                   B.rmsnorm(h, p["ln1"], cfg.norm_eps),
                                   cfg, cache)
        return h + y, cache
    raise ValueError(btype)


def decode_step(params, cfg: ArchConfig, token, caches, pos):
    """One decode step.  token: (B, 1) int32; pos: (B, 1) int32 positions.

    Returns (logits (B, vocab), new_caches).
    """
    h = params["embed"][token]
    positions = (jnp.broadcast_to(pos[None], (3,) + pos.shape)
                 if cfg.mrope else pos)
    shared = params.get("shared")
    new_caches = []
    for (btype, count), seg_p, cache in zip(cfg.segments, params["segments"],
                                            caches):
        if count == 1:
            p0 = jax.tree.map(lambda a: a[0], seg_p)
            c0 = jax.tree.map(lambda a: a[0], cache)
            h, c0 = _block_decode(p0, h, c0, btype, cfg, positions, shared)
            new_caches.append(jax.tree.map(lambda a: a[None], c0))
        else:
            def body(hh, xs, btype=btype):
                pl, cl = xs
                hh, cl = _block_decode(pl, hh, cl, btype, cfg, positions,
                                       shared)
                return hh, cl
            if unroll.enabled():
                outs = []
                for j in range(count):
                    h, cj = body(h, (jax.tree.map(lambda a: a[j], seg_p),
                                     jax.tree.map(lambda a: a[j], cache)))
                    outs.append(cj)
                cache = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
            else:
                h, cache = jax.lax.scan(body, h, (seg_p, cache))
            new_caches.append(cache)
    h = B.rmsnorm(h, params["final_norm"], cfg.norm_eps)
    logits = _logits(params, cfg, h)[:, 0]
    return logits, new_caches


def prefill(params, cfg: ArchConfig, batch, cache_len: Optional[int] = None,
            cache_dtype=jnp.bfloat16):
    """Full-sequence forward returning last-token logits + populated caches.

    For lowering-oriented use the caches are built by re-running attention
    blocks' K/V (structurally identical to incremental fill).
    """
    h = _embed(params, cfg, batch)
    pos = _positions(cfg, batch)
    Bt, S, _ = h.shape
    cache_len = cache_len or S
    shared = params.get("shared")
    caches = []
    aux = jnp.zeros((), jnp.float32)
    tok_pos = pos[0] if cfg.mrope else pos
    for (btype, count), seg_p in zip(cfg.segments, params["segments"]):
        def body(carry, pl, btype=btype):
            hh, ax = carry
            hh, ax, cache = _block_apply_with_cache(
                pl, hh, btype, cfg, pos, tok_pos, shared, ax, cache_len,
                cache_dtype)
            hh = shardctx.constrain(hh)
            return (hh, ax), cache
        if unroll.enabled():
            per_layer = []
            for j in range(count):
                (h, aux), c = body((h, aux),
                                   jax.tree.map(lambda a: a[j], seg_p))
                per_layer.append(c)
            cache = jax.tree.map(lambda *xs: jnp.stack(xs), *per_layer)
        else:
            (h, aux), cache = jax.lax.scan(body, (h, aux), seg_p)
        caches.append(cache)
    h = B.rmsnorm(h, params["final_norm"], cfg.norm_eps)
    logits = _logits(params, cfg, h[:, -1:, :])[:, 0]
    return logits, caches


def _block_apply_with_cache(p, h, btype, cfg, positions, tok_pos, shared, aux,
                            cache_len, cache_dtype):
    _in = shardctx.constrain_interior
    if btype in ATTN_TYPES:
        window = cfg.window if btype == "local" else None
        attn_p = shared["attn"] if btype == "shared_attn" else p["attn"]
        a, (k, v) = B.attn_apply(attn_p,
                                 _in(B.rmsnorm(h, p["ln1"], cfg.norm_eps)),
                                 cfg, positions, window=window)
        h = h + a
        x2 = _in(B.rmsnorm(h, p["ln2"], cfg.norm_eps))
        if btype == "attn_moe":
            y, probs = MOE.moe_apply(p["moe"], x2, cfg)
            aux = aux + MOE.router_aux_loss(probs)
        elif btype == "shared_attn":
            y = B.mlp_apply(shared["mlp"], x2)
        else:
            y = B.mlp_apply(p["mlp"], x2)
        h = h + y
        S = k.shape[1]
        C = min(cache_len, window) if window else cache_len
        if S >= C:  # keep the last C entries
            ck, cv, cp = k[:, S - C:], v[:, S - C:], tok_pos[:, S - C:]
        else:
            padk = jnp.zeros((k.shape[0], C - S) + k.shape[2:], k.dtype)
            ck = jnp.concatenate([k, padk], 1)
            cv = jnp.concatenate([v, padk], 1)
            cp = jnp.concatenate(
                [tok_pos, jnp.full((k.shape[0], C - S), -1, tok_pos.dtype)], 1)
        cache = {"k": ck.astype(cache_dtype), "v": cv.astype(cache_dtype),
                 "pos": cp.astype(jnp.int32),
                 "idx": jnp.full((h.shape[0],), S % C if window else S,
                                 jnp.int32)}
        return h, aux, cache
    if btype == "mamba2":
        y, st = M2.mamba2_apply(p["mamba"],
                                B.rmsnorm(h, p["ln1"], cfg.norm_eps),
                                cfg, return_state=True)
        return h + y, aux, st
    if btype == "mlstm":
        y, st = XL.mlstm_apply(p["mlstm"],
                               B.rmsnorm(h, p["ln1"], cfg.norm_eps),
                               cfg, return_state=True)
        return h + y, aux, st
    if btype == "slstm":
        y, st = XL.slstm_apply(p["slstm"],
                               B.rmsnorm(h, p["ln1"], cfg.norm_eps),
                               cfg, return_state=True)
        return h + y, aux, st
    raise ValueError(btype)
