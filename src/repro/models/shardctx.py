"""Activation-sharding context: models call ``constrain(h)`` on the residual
stream; the launcher installs the appropriate sharding for the case being
lowered (sequence-parallel over tp for train/prefill, nothing for decode).

Under ``vmap`` (the GenQSGD fl axis) JAX prepends the mapped dim and keeps
its sharding — verified on jax 0.8: a (B, S, D) -> P(fsdp, tp, None)
constraint inside vmap yields P(fl, fsdp, tp) on the batched value.
"""
from __future__ import annotations

import contextlib
from typing import Optional

import jax

_ACT_SHARDING = None       # boundary: (B, S, D) residual carries (seq over tp)
_INTERIOR_SHARDING = None  # interior: block inputs after norm (seq gathered)
_MOE_SHARDING = None       # (E, C, D) expert dispatch buffers


@contextlib.contextmanager
def activation_sharding(ns, interior=None, moe=None):
    """ns: boundary sharding for residual carries (sequence-parallel, seq
    over tp — shrinks remat-saved carries).  interior: sharding for block
    inputs right after the pre-norms (seq *gathered*, batch still sharded) —
    without it the partitioner may satisfy the attention/MLP dots by
    all-gathering FULL weights instead of the activation (measured at 405B:
    7 concurrent full-weight buffers)."""
    global _ACT_SHARDING, _INTERIOR_SHARDING, _MOE_SHARDING
    prev = (_ACT_SHARDING, _INTERIOR_SHARDING, _MOE_SHARDING)
    _ACT_SHARDING = ns
    _INTERIOR_SHARDING = interior
    _MOE_SHARDING = moe
    try:
        yield
    finally:
        _ACT_SHARDING, _INTERIOR_SHARDING, _MOE_SHARDING = prev


def _apply(h, ns):
    if ns is None or h.ndim != 3:
        return h
    try:
        return jax.lax.with_sharding_constraint(h, ns)
    except Exception:
        return h


def constrain(h):
    return _apply(h, _ACT_SHARDING)


MLP_INTERIOR_GATHERED = True  # §Perf: sharded-MLP variant measured
                              # neutral (AR up as AG down); keep gathered


def constrain_interior(h):
    return _apply(h, _INTERIOR_SHARDING)


def constrain_interior_mlp(h):
    if MLP_INTERIOR_GATHERED:
        return _apply(h, _INTERIOR_SHARDING)
    return _apply(h, _ACT_SHARDING)


def constrain_moe(buf):
    """Expert dispatch buffers (E, C, D): experts over tp, capacity over
    fsdp — expert compute stays token-sharded without fsdp partial-k
    all-reduces on the expert weights."""
    if _MOE_SHARDING is None or buf.ndim != 3:
        return buf
    try:
        return jax.lax.with_sharding_constraint(buf, _MOE_SHARDING)
    except Exception:
        return buf
