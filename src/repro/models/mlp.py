"""The paper's Sec.-VII model: a 784-128-10 two-layer neural network with
sigmoid hidden activation, softmax output and cross-entropy loss
(D = 784*128 + 128 + 128*10 + 10 = 101,770 parameters).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["init_params", "loss", "accuracy", "predict", "PARAM_DIM",
           "estimate_constants"]

PARAM_DIM = 784 * 128 + 128 + 128 * 10 + 10


def init_params(key):
    k1, k2 = jax.random.split(key)
    return {
        "w1": jax.random.normal(k1, (784, 128)) / np.sqrt(784),
        "b1": jnp.zeros(128),
        "w2": jax.random.normal(k2, (128, 10)) / np.sqrt(128),
        "b2": jnp.zeros(10),
    }


def predict(params, X):
    h = jax.nn.sigmoid(X @ params["w1"] + params["b1"])
    return h @ params["w2"] + params["b2"]


def loss(params, batch):
    X, y = batch
    logits = predict(params, X)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, y[:, None].astype(jnp.int32),
                               axis=-1)[:, 0]
    return (logz - gold).mean()


def accuracy(params, X, y):
    return float((jnp.argmax(predict(params, X), -1) == y).mean())


def estimate_constants(X, y, key, n_iters: int = 300, batch: int = 256,
                       lr: float = 0.5, n_probe: int = 20):
    """Pre-training estimates of (L, sigma, G, f_gap) — Sec. IV-A.

    L: max ||∇f(x)-∇f(y)|| / ||x-y|| over probe pairs along the SGD path;
    sigma: per-sample gradient deviation bound (Assumption 4);
    G: per-sample gradient second-moment bound (Assumption 5);
    f_gap: f(x^(1)) - f(x_pretrained)  (upper bound on f(x1) - f*).
    """
    from ..core.genqsgd import flatten_like

    params = init_params(key)
    f0 = float(loss(params, (X[:4096], y[:4096])))
    grad_fn = jax.jit(jax.grad(loss))
    full_grad = jax.jit(jax.grad(loss))

    snapshots = []
    p = params
    for it in range(n_iters):
        key, k = jax.random.split(key)
        idx = jax.random.randint(k, (batch,), 0, X.shape[0])
        g = grad_fn(p, (X[idx], y[idx]))
        p = jax.tree.map(lambda w, gg: w - lr * gg, p, g)
        if it % (n_iters // n_probe) == 0:
            snapshots.append(p)
    f_star = float(loss(p, (X[:8192], y[:8192])))

    # Lipschitz probe over snapshot pairs
    Xp, yp = X[:4096], y[:4096]
    L = 0.0
    gs = [flatten_like(full_grad(s, (Xp, yp))) for s in snapshots]
    xs = [flatten_like(s) for s in snapshots]
    for i in range(len(snapshots) - 1):
        num = float(jnp.linalg.norm(gs[i + 1] - gs[i]))
        den = float(jnp.linalg.norm(xs[i + 1] - xs[i]))
        if den > 1e-9:
            L = max(L, num / den)

    # sigma, G from per-sample grads at a few snapshots
    per_sample = jax.jit(jax.vmap(
        lambda p_, x_, y_: flatten_like(
            jax.grad(loss)(p_, (x_[None], y_[None]))),
        in_axes=(None, 0, 0)))
    sig2, G2 = 0.0, 0.0
    for s in snapshots[:: max(1, len(snapshots) // 4)]:
        sample = per_sample(s, X[:512], y[:512])
        mean_g = sample.mean(axis=0)
        sig2 = max(sig2, float(jnp.mean(jnp.sum((sample - mean_g) ** 2, -1))))
        G2 = max(G2, float(jnp.max(jnp.sum(sample**2, -1))))
    return {
        "L": L,
        "sigma": float(np.sqrt(sig2)),
        "G": float(np.sqrt(G2)),
        "f_gap": max(f0 - f_star, 1e-3),
        "f0": f0,
        "f_star": f_star,
    }
