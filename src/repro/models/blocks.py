"""Transformer building blocks: norms, RoPE / M-RoPE, GQA attention, MLP.

Functional style: ``*_init(key, cfg) -> params`` builds ONE layer's params;
stacking for `lax.scan` happens in :mod:`repro.models.lm` via vmapped inits.

Attention is query-chunked (no S×S mask materialization) so 32k-sequence
shapes lower with bounded temporaries; decode takes a dense or ring-buffer
(sliding-window) KV cache.  With a sequence-sharded cache the softmax
reduction over S is partitioned by GSPMD (collectives inserted by XLA).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig
from . import unroll

Q_CHUNK = 1024  # query chunk for attention score tiles


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------
def dense_init(key, d_in: int, d_out: int, dtype=jnp.float32) -> jax.Array:
    scale = 1.0 / np.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def rmsnorm(x, w, eps: float = 1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)).astype(x.dtype) * w


def layernorm(x, w, b, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = xf.mean(-1, keepdims=True)
    var = xf.var(-1, keepdims=True)
    return (((xf - mu) * jax.lax.rsqrt(var + eps)) * w + b).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE and M-RoPE
# ---------------------------------------------------------------------------
def rope_freqs(d_head: int, theta: float):
    return 1.0 / theta ** (jnp.arange(0, d_head // 2, dtype=jnp.float32)
                           / (d_head // 2))


def apply_rope(x, positions, theta: float):
    """x: (B, S, H, dh); positions: (B, S) int."""
    dh = x.shape[-1]
    inv = rope_freqs(dh, theta)                       # (dh/2,)
    ang = positions[..., None].astype(jnp.float32) * inv  # (B,S,dh/2)
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x, 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x, positions3, theta: float, sections):
    """Multimodal RoPE (qwen2-vl): positions3 (3, B, S) = (t, h, w) streams;
    the dh/2 frequency bands are split into ``sections`` consuming different
    position streams."""
    dh = x.shape[-1]
    inv = rope_freqs(dh, theta)                       # (dh/2,)
    secs = np.asarray(sections)
    assert secs.sum() == dh // 2, (sections, dh)
    # stream id per frequency band
    sid = np.repeat(np.arange(3), secs)               # (dh/2,)
    pos = positions3[sid]                             # (dh/2, B, S) gathered
    ang = jnp.moveaxis(pos, 0, -1).astype(jnp.float32) * inv  # (B,S,dh/2)
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x, 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# GQA attention
# ---------------------------------------------------------------------------
def attn_init(key, cfg: ArchConfig, dtype=jnp.float32):
    D, H, KV, dh = cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.d_head
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], D, H * dh, dtype),
        "wk": dense_init(ks[1], D, KV * dh, dtype),
        "wv": dense_init(ks[2], D, KV * dh, dtype),
        "wo": dense_init(ks[3], H * dh, D, dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((dh,), dtype)
        p["k_norm"] = jnp.ones((dh,), dtype)
    return p


def _qkv(p, x, cfg: ArchConfig, positions):
    B, S, D = x.shape
    H, KV, dh = cfg.n_heads, cfg.n_kv, cfg.d_head
    q = (x @ p["wq"]).reshape(B, S, H, dh)
    k = (x @ p["wk"]).reshape(B, S, KV, dh)
    v = (x @ p["wv"]).reshape(B, S, KV, dh)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
    if cfg.mrope:
        q = apply_mrope(q, positions, cfg.rope_theta, cfg.mrope_sections)
        k = apply_mrope(k, positions, cfg.rope_theta, cfg.mrope_sections)
    else:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _sdpa_chunk(q, k, v, q_pos, k_pos, window: Optional[int], causal: bool,
                k_valid=None):
    """q: (B,Q,KV,G,dh)  k/v: (B,S,KV,dh) -> (B,Q,KV,G,dh).

    Bias is built from position vectors (no S×S global mask).
    """
    dh = q.shape[-1]
    scores = jnp.einsum("bqkgd,bskd->bkgqs", q, k,
                        preferred_element_type=jnp.float32)
    scores = scores / np.sqrt(dh)
    if causal:
        m = k_pos[:, None, :] <= q_pos[:, :, None]          # (B,Q,S)
        if window is not None:
            m &= k_pos[:, None, :] > q_pos[:, :, None] - window
    else:
        m = jnp.ones((q_pos.shape[0], q_pos.shape[1], k_pos.shape[1]),
                     dtype=bool)
    if k_valid is not None:
        m &= k_valid[:, None, :]
    scores = jnp.where(m[:, None, None, :, :], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", w.astype(v.dtype), v)
    return out


def attn_apply(p, x, cfg: ArchConfig, positions, *, window=None,
               causal: bool = True):
    """Full-sequence attention (train / prefill), query-chunked.

    Returns (y, (k, v)) — k/v handed to the cache builder in prefill.
    """
    B, S, D = x.shape
    H, KV, dh = cfg.n_heads, cfg.n_kv, cfg.d_head
    G = H // KV
    rope_pos = positions
    q, k, v = _qkv(p, x, cfg, rope_pos)
    q = q.reshape(B, S, KV, G, dh)
    tok_pos = positions[0] if cfg.mrope else positions   # (B,S) temporal order
    n_chunks = max(1, S // Q_CHUNK)
    if S % Q_CHUNK == 0 and n_chunks > 1:
        qc = q.reshape(B, n_chunks, Q_CHUNK, KV, G, dh)
        pc = tok_pos.reshape(B, n_chunks, Q_CHUNK)

        if unroll.enabled():
            outs = [_sdpa_chunk(qc[:, i], k, v, pc[:, i], tok_pos, window,
                                causal) for i in range(n_chunks)]
            out = jnp.stack(outs, axis=1).reshape(B, S, H * dh)
        else:
            # checkpoint each chunk: backward recomputes that chunk's scores
            # instead of keeping all chunks' f32 score tiles live (flash-
            # attention-style memory behaviour from plain XLA).
            ck_chunk = jax.checkpoint(
                lambda qq, pp, kk, vv: _sdpa_chunk(qq, kk, vv, pp, tok_pos,
                                                   window, causal))

            def body(_, args):
                qq, pp = args
                return None, ck_chunk(qq, pp, k, v)

            _, out = jax.lax.scan(
                body, None,
                (jnp.moveaxis(qc, 1, 0), jnp.moveaxis(pc, 1, 0)))
            out = jnp.moveaxis(out, 0, 1).reshape(B, S, H * dh)
    else:
        out = _sdpa_chunk(q, k, v, tok_pos, tok_pos, window, causal)
        out = out.reshape(B, S, H * dh)
    y = out @ p["wo"]
    return y, (k, v)


def attn_decode(p, x, cfg: ArchConfig, positions, cache, *, window=None):
    """Single-token decode against a dense or ring-buffer KV cache.

    cache: {"k": (B, C, KV, dh), "v": ..., "pos": (B, C) int32 positions of
    cached entries (-1 = empty), "idx": (B,) per-row write cursors (per-row
    so batched serving slots at different depths stay correct)}.
    For a sliding-window cache C == window and writes wrap around.
    """
    B, S, D = x.shape
    assert S == 1
    H, KV, dh = cfg.n_heads, cfg.n_kv, cfg.d_head
    G = H // KV
    q, k, v = _qkv(p, x, cfg, positions)
    C = cache["k"].shape[1]
    rows = jnp.arange(B)
    slot = cache["idx"] % C                                   # (B,)
    ck = cache["k"].at[rows, slot].set(k[:, 0].astype(cache["k"].dtype))
    cv = cache["v"].at[rows, slot].set(v[:, 0].astype(cache["v"].dtype))
    tok_pos = positions[0] if cfg.mrope else positions
    cpos = cache["pos"].at[rows, slot].set(tok_pos[:, 0].astype(jnp.int32))
    valid = cpos >= 0
    out = _sdpa_chunk(q.reshape(B, 1, KV, G, dh), ck, cv, tok_pos, cpos,
                      window, causal=True, k_valid=valid)
    y = out.reshape(B, 1, H * dh) @ p["wo"]
    new_cache = {"k": ck, "v": cv, "pos": cpos, "idx": cache["idx"] + 1}
    return y, new_cache


def make_cache(cfg: ArchConfig, batch: int, length: int, kv_heads=None,
               dtype=jnp.bfloat16):
    KV = kv_heads or cfg.n_kv
    return {
        "k": jnp.zeros((batch, length, KV, cfg.d_head), dtype),
        "v": jnp.zeros((batch, length, KV, cfg.d_head), dtype),
        "pos": jnp.full((batch, length), -1, jnp.int32),
        "idx": jnp.zeros((batch,), jnp.int32),
    }


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------
def mlp_init(key, cfg: ArchConfig, d_ff=None, dtype=jnp.float32):
    D = cfg.d_model
    F = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(ks[0], D, F, dtype),
        "w_up": dense_init(ks[1], D, F, dtype),
        "w_down": dense_init(ks[2], F, D, dtype),
    }


def mlp_apply(p, x):
    h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    return h @ p["w_down"]
