"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory, parallelizable)
and sLSTM (scalar memory with true recurrence).

mLSTM training uses the stabilized parallel (quadratic) form of the paper's
Eq. (?)-style formulation; decode is the O(1) recurrent update with matrix
state C (dh x dh per head), normalizer n and stabilizer m.  sLSTM is a real
recurrence (hidden-to-hidden block-diagonal R), so training scans over time.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig
from . import shardctx
from .blocks import dense_init, rmsnorm

__all__ = ["mlstm_init", "mlstm_apply", "mlstm_decode", "make_mlstm_state",
           "slstm_init", "slstm_apply", "slstm_decode", "make_slstm_state"]


# ===========================================================================
# mLSTM
# ===========================================================================
def _mlstm_dims(cfg: ArchConfig):
    di = 2 * cfg.d_model          # proj factor 2
    H = cfg.n_heads
    dh = di // H
    return di, H, dh


def mlstm_init(key, cfg: ArchConfig, dtype=jnp.float32):
    D = cfg.d_model
    di, H, dh = _mlstm_dims(cfg)
    ks = jax.random.split(key, 7)
    return {
        "w_up": dense_init(ks[0], D, 2 * di, dtype),    # [path, gate]
        "wq": dense_init(ks[1], di, di, dtype),
        "wk": dense_init(ks[2], di, di, dtype),
        "wv": dense_init(ks[3], di, di, dtype),
        "w_if": dense_init(ks[4], di, 2 * H, dtype),    # input/forget preacts
        "out_norm": jnp.ones((di,), dtype),
        "w_down": dense_init(ks[5], di, D, dtype),
    }


def _mlstm_qkvif(p, x, cfg):
    B, S, D = x.shape
    di, H, dh = _mlstm_dims(cfg)
    up = x @ p["w_up"]
    a, g = up[..., :di], up[..., di:]
    q = (a @ p["wq"]).reshape(B, S, H, dh)
    k = (a @ p["wk"]).reshape(B, S, H, dh) / np.sqrt(dh)
    v = (a @ p["wv"]).reshape(B, S, H, dh)
    i_f = (a @ p["w_if"]).astype(jnp.float32)
    i_pre, f_pre = i_f[..., :H], i_f[..., H:]
    return q, k, v, i_pre, f_pre, g


MLSTM_CHUNK = 256


def mlstm_apply(p, x, cfg: ArchConfig, return_state: bool = False):
    """mLSTM forward: chunkwise-parallel when the sequence is long (O(S·C)
    score work instead of O(S^2) — the §Perf fix for prefill_32k), quadratic
    stabilized form otherwise."""
    S = x.shape[1]
    if S % MLSTM_CHUNK == 0 and S > MLSTM_CHUNK:
        return _mlstm_chunked(p, x, cfg, return_state)
    return _mlstm_quadratic(p, x, cfg, return_state)


def _mlstm_quadratic(p, x, cfg: ArchConfig, return_state: bool = False):
    """Parallel (stabilized quadratic) form.  x: (B, S, D)."""
    B, S, D = x.shape
    di, H, dh = _mlstm_dims(cfg)
    q, k, v, i_pre, f_pre, g = _mlstm_qkvif(p, x, cfg)
    logf = jax.nn.log_sigmoid(f_pre)                       # (B,S,H)
    F_cum = jnp.cumsum(logf, axis=1)                       # (B,S,H)
    # D_ij = exp(F_i - F_j + i_j) stabilized per row
    dlog = (F_cum[:, :, None, :] - F_cum[:, None, :, :]
            + i_pre[:, None, :, :])                        # (B,Sq,Sk,H)
    mask = jnp.tril(jnp.ones((S, S), bool))
    dlog = jnp.where(mask[None, :, :, None], dlog, -jnp.inf)
    m = jnp.max(dlog, axis=2, keepdims=True)               # (B,Sq,1,H)
    Dmat = jnp.exp(dlog - m)                               # (B,Sq,Sk,H)
    scores = jnp.einsum("bqhd,bkhd->bqkh", q.astype(jnp.float32),
                        k.astype(jnp.float32))
    C = scores * Dmat
    norm = jnp.maximum(jnp.abs(C.sum(axis=2)), jnp.exp(-m[:, :, 0, :]))
    y = jnp.einsum("bqkh,bkhd->bqhd", C, v.astype(jnp.float32))
    y = (y / (norm[..., None] + 1e-6)).reshape(B, S, di).astype(x.dtype)
    y = rmsnorm(y, p["out_norm"], cfg.norm_eps)
    out = (y * jax.nn.silu(g)) @ p["w_down"]
    if not return_state:
        return out
    # final recurrent state (for prefill -> decode handoff)
    state = make_mlstm_state(cfg, B)
    # run the recurrence once over the sequence to produce the exact state
    def step(st, inp):
        return _mlstm_recurrent_update(st, *inp), None
    seq = (jnp.moveaxis(q, 1, 0), jnp.moveaxis(k, 1, 0),
           jnp.moveaxis(v, 1, 0), jnp.moveaxis(i_pre, 1, 0),
           jnp.moveaxis(logf, 1, 0))
    state, _ = jax.lax.scan(step, state, seq)
    return out, state


def _mlstm_chunked(p, x, cfg: ArchConfig, return_state: bool = False):
    """Chunkwise-parallel mLSTM: intra-chunk stabilized quadratic + an
    inter-chunk recurrent (C, n, m) state carry — identical semantics to the
    per-token recurrence (unit-tested against it)."""
    B, S, D = x.shape
    di, H, dh = _mlstm_dims(cfg)
    Q = MLSTM_CHUNK
    Nc = S // Q
    q, k, v, i_pre, f_pre, g = _mlstm_qkvif(p, x, cfg)
    qf = q.astype(jnp.float32).reshape(B, Nc, Q, H, dh)
    kf = k.astype(jnp.float32).reshape(B, Nc, Q, H, dh)
    vf = v.astype(jnp.float32).reshape(B, Nc, Q, H, dh)
    i_c = i_pre.reshape(B, Nc, Q, H)
    logf = jax.nn.log_sigmoid(f_pre).reshape(B, Nc, Q, H)
    F_cum = jnp.cumsum(logf, axis=2)                       # within-chunk
    # intra-chunk decay D_tj = exp(F_t - F_j + i_j), j <= t
    dlog = (F_cum[:, :, :, None, :] - F_cum[:, :, None, :, :]
            + i_c[:, :, None, :, :])                       # (B,Nc,Q,K,H)
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    dlog = jnp.where(mask[None, None, :, :, None], dlog, -1e30)
    m_intra = jnp.max(dlog, axis=3)                        # (B,Nc,Q,H)

    def chunk_step(st, inp):
        qc, kc, vc, ic, fc, Fc, dl, mi = inp               # per chunk
        C0, n0, m0 = st["C"], st["n"], st["m"]             # (B,H,dh,dh) ...
        m_inter = Fc + m0[:, None, :]                      # (B,Q,H)
        m_t = jnp.maximum(mi, m_inter)
        Dm = jnp.exp(dl - m_t[:, :, None, :])              # (B,Q,K,H)
        scores = jnp.einsum("bqhd,bkhd->bqkh", qc, kc)
        Cmat = scores * Dm
        num_intra = jnp.einsum("bqkh,bkhd->bqhd", Cmat, vc)
        den_intra = Cmat.sum(axis=2)                       # (B,Q,H)
        w_inter = jnp.exp(m_inter - m_t)                   # (B,Q,H)
        num_inter = jnp.einsum("bqhd,bhde->bqhe", qc, C0) \
            * w_inter[..., None]
        den_inter = jnp.einsum("bqhd,bhd->bqh", qc, n0) * w_inter
        num = num_intra + num_inter
        den = jnp.maximum(jnp.abs(den_intra + den_inter), jnp.exp(-m_t))
        y = num / (den[..., None] + 1e-6)                  # (B,Q,H,dh)
        # end-of-chunk state update
        F_end = Fc[:, -1, :]                               # (B,H)
        w_j = jnp.exp(F_end[:, None, :] - Fc + ic)         # (B,Q,H) decay of
        m_new = jnp.maximum(F_end + m0, jnp.max(
            F_end[:, None, :] - Fc + ic, axis=1))
        carry_w = jnp.exp(F_end + m0 - m_new)              # (B,H)
        upd_w = jnp.exp(F_end[:, None, :] - Fc + ic
                        - m_new[:, None, :])               # (B,Q,H)
        C_new = carry_w[..., None, None] * C0 \
            + jnp.einsum("bqh,bqhd,bqhe->bhde", upd_w, kc, vc)
        n_new = carry_w[..., None] * n0 \
            + jnp.einsum("bqh,bqhd->bhd", upd_w, kc)
        return {"C": C_new, "n": n_new, "m": m_new}, y

    st0 = make_mlstm_state(cfg, B)
    xs = (jnp.moveaxis(qf, 1, 0), jnp.moveaxis(kf, 1, 0),
          jnp.moveaxis(vf, 1, 0), jnp.moveaxis(i_c, 1, 0),
          jnp.moveaxis(logf, 1, 0), jnp.moveaxis(F_cum, 1, 0),
          jnp.moveaxis(dlog, 1, 0), jnp.moveaxis(m_intra, 1, 0))
    st, ys = jax.lax.scan(chunk_step, st0, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, di).astype(x.dtype)
    y = rmsnorm(y, p["out_norm"], cfg.norm_eps)
    out = (y * jax.nn.silu(g)) @ p["w_down"]
    if return_state:
        return out, st
    return out


def make_mlstm_state(cfg: ArchConfig, batch: int):
    di, H, dh = _mlstm_dims(cfg)
    return {
        "C": jnp.zeros((batch, H, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, H, dh), jnp.float32),
        "m": jnp.full((batch, H), -1e30, jnp.float32),
    }


def _mlstm_recurrent_update(st, q_t, k_t, v_t, i_t, logf_t):
    """One step of the stabilized mLSTM recurrence (all per (B,H))."""
    m_new = jnp.maximum(logf_t + st["m"], i_t)
    f_eff = jnp.exp(logf_t + st["m"] - m_new)[..., None]
    i_eff = jnp.exp(i_t - m_new)[..., None]
    C = f_eff[..., None] * st["C"] \
        + i_eff[..., None] * jnp.einsum("bhd,bhe->bhde",
                                        k_t.astype(jnp.float32),
                                        v_t.astype(jnp.float32))
    n = f_eff * st["n"] + i_eff * k_t.astype(jnp.float32)
    return {"C": C, "n": n, "m": m_new}


def mlstm_decode(p, x, cfg: ArchConfig, state):
    B, S, D = x.shape
    assert S == 1
    di, H, dh = _mlstm_dims(cfg)
    q, k, v, i_pre, f_pre, g = _mlstm_qkvif(p, x, cfg)
    q_t, k_t, v_t = q[:, 0], k[:, 0], v[:, 0]
    logf_t = jax.nn.log_sigmoid(f_pre[:, 0])
    st = _mlstm_recurrent_update(state, q_t, k_t, v_t, i_pre[:, 0], logf_t)
    qf = q_t.astype(jnp.float32)
    h_num = jnp.einsum("bhde,bhd->bhe", st["C"], qf)
    h_den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", st["n"], qf)),
                        jnp.exp(-st["m"]))
    y = (h_num / (h_den[..., None] + 1e-6)).reshape(B, 1, di).astype(x.dtype)
    y = rmsnorm(y, p["out_norm"], cfg.norm_eps)
    out = (y * jax.nn.silu(g)) @ p["w_down"]
    return out, st


# ===========================================================================
# sLSTM
# ===========================================================================
def _slstm_dims(cfg: ArchConfig):
    di = cfg.d_model
    H = cfg.n_heads
    dh = di // H
    return di, H, dh


def slstm_init(key, cfg: ArchConfig, dtype=jnp.float32):
    D = cfg.d_model
    di, H, dh = _slstm_dims(cfg)
    ks = jax.random.split(key, 4)
    f_ff = max(1, int(4 * D / 3) // 8 * 8)
    return {
        "w_gates": dense_init(ks[0], D, 4 * di, dtype),   # z, i, f, o preacts
        "r_gates": (jax.random.normal(ks[1], (H, dh, 4 * dh), jnp.float32)
                    / np.sqrt(dh)).astype(dtype),         # block-diag recurrence
        "b_gates": jnp.zeros((4 * di,), dtype),
        "out_norm": jnp.ones((di,), dtype),
        "ff_up": dense_init(ks[2], di, f_ff, dtype),
        "ff_down": dense_init(ks[3], f_ff, D, dtype),
    }


def make_slstm_state(cfg: ArchConfig, batch: int):
    di, H, dh = _slstm_dims(cfg)
    return {
        "c": jnp.zeros((batch, H, dh), jnp.float32),
        "n": jnp.full((batch, H, dh), 1e-6, jnp.float32),
        "h": jnp.zeros((batch, H, dh), jnp.float32),
        "m": jnp.full((batch, H, dh), -1e30, jnp.float32),
    }


def _slstm_step(p, cfg, st, wx_t):
    """wx_t: (B, 4*di) pre-computed input contribution at time t."""
    di, H, dh = _slstm_dims(cfg)
    B = wx_t.shape[0]
    rec = jnp.einsum("bhd,hdk->bhk", st["h"],
                     p["r_gates"].astype(jnp.float32))     # (B,H,4*dh)
    pre = wx_t.reshape(B, 4, H, dh).astype(jnp.float32) \
        + jnp.moveaxis(rec.reshape(B, H, 4, dh), 2, 1)
    z = jnp.tanh(pre[:, 0])
    i_pre, f_pre, o_pre = pre[:, 1], pre[:, 2], pre[:, 3]
    o = jax.nn.sigmoid(o_pre)
    m_new = jnp.maximum(f_pre + st["m"], i_pre)
    i_eff = jnp.exp(i_pre - m_new)
    f_eff = jnp.exp(f_pre + st["m"] - m_new)
    c = f_eff * st["c"] + i_eff * z
    n = f_eff * st["n"] + i_eff
    h = o * c / jnp.maximum(n, 1e-6)
    return {"c": c, "n": n, "h": h, "m": m_new}


def slstm_apply(p, x, cfg: ArchConfig, return_state: bool = False):
    """True recurrence: lax.scan over time.  x: (B, S, D)."""
    B, S, D = x.shape
    di, H, dh = _slstm_dims(cfg)
    # keep the scan input batch-sharded (otherwise the per-token scan forces
    # a full all-gather of wx — measured 32 GiB/device at prefill_32k)
    wx = shardctx.constrain_interior(x @ p["w_gates"] + p["b_gates"])

    def step(st, wx_t):
        st = _slstm_step(p, cfg, st, wx_t)
        return st, st["h"]

    st0 = make_slstm_state(cfg, B)
    st, hs = jax.lax.scan(step, st0, jnp.moveaxis(wx, 1, 0))
    y = jnp.moveaxis(hs, 0, 1).reshape(B, S, di).astype(x.dtype)
    y = rmsnorm(y, p["out_norm"], cfg.norm_eps)
    out = jax.nn.gelu(y @ p["ff_up"]) @ p["ff_down"]
    if return_state:
        return out, st
    return out


def slstm_decode(p, x, cfg: ArchConfig, state):
    B, S, D = x.shape
    assert S == 1
    di, H, dh = _slstm_dims(cfg)
    wx = (x @ p["w_gates"] + p["b_gates"])[:, 0]
    st = _slstm_step(p, cfg, state, wx)
    y = st["h"].reshape(B, 1, di).astype(x.dtype)
    y = rmsnorm(y, p["out_norm"], cfg.norm_eps)
    out = jax.nn.gelu(y @ p["ff_up"]) @ p["ff_down"]
    return out, st
