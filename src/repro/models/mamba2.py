"""Mamba2 (SSD — state-space duality) mixer: chunked parallel scan for
training/prefill and an O(1) recurrent state update for decode.

Follows the "minimal mamba2" formulation with a single B/C group:
  h_t = exp(dt_t * A_h) * h_{t-1} + dt_t * B_t x_t^T      (per head h)
  y_t = C_t . h_t + D_h * x_t
with x projected to (H, P) heads, A scalar per head, B/C of size N=ssm_state.
Training computes the same recurrence chunk-parallel: intra-chunk "attention"
term + inter-chunk state carry (lax.scan over chunks).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig
from .blocks import dense_init, rmsnorm

__all__ = ["mamba2_init", "mamba2_apply", "mamba2_decode", "make_ssm_state",
           "mamba2_dims"]

CHUNK = 128


def mamba2_dims(cfg: ArchConfig):
    d_inner = cfg.ssm_expand * cfg.d_model
    n_heads = d_inner // cfg.ssm_head_dim
    return d_inner, n_heads, cfg.ssm_head_dim, cfg.ssm_state


def mamba2_init(key, cfg: ArchConfig, dtype=jnp.float32):
    D = cfg.d_model
    di, H, P, N = mamba2_dims(cfg)
    ks = jax.random.split(key, 5)
    conv_ch = di + 2 * N
    return {
        # projections for z (gate), x, B, C, dt
        "in_proj": dense_init(ks[0], D, 2 * di + 2 * N + H, dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv, conv_ch), jnp.float32)
                   / np.sqrt(cfg.ssm_conv)).astype(dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "A_log": jnp.log(jnp.arange(1, H + 1, dtype=jnp.float32)
                         .clip(1.0, 16.0)),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "out_norm": jnp.ones((di,), dtype),
        "out_proj": dense_init(ks[2], di, D, dtype),
    }


def _split_proj(p, x, cfg):
    di, H, P, N = mamba2_dims(cfg)
    zxbcdt = x @ p["in_proj"]
    z = zxbcdt[..., :di]
    xin = zxbcdt[..., di:2 * di]
    Bc = zxbcdt[..., 2 * di:2 * di + N]
    Cc = zxbcdt[..., 2 * di + N:2 * di + 2 * N]
    dt = zxbcdt[..., 2 * di + 2 * N:]
    return z, xin, Bc, Cc, dt


def _causal_conv(seq, w, b):
    """Depthwise causal conv: seq (B,S,C), w (W,C)."""
    W = w.shape[0]
    pad = jnp.pad(seq, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + seq.shape[1], :] * w[i] for i in range(W))
    return out + b


def _segsum_exp(dA_cum):
    """L[.., i, j] = exp(dA_cum[.., i] - dA_cum[.., j]) for i >= j else 0.

    dA_cum: (..., Q); returns (..., Q, Q).
    """
    Q = dA_cum.shape[-1]
    diff = dA_cum[..., :, None] - dA_cum[..., None, :]
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    # mask BEFORE exp: masked (upper-triangle) diffs are positive and large,
    # and exp-overflow would leak NaN through the where() backward pass.
    return jnp.exp(jnp.where(mask, diff, -1e30))


def mamba2_apply(p, x, cfg: ArchConfig, return_state: bool = False):
    """x: (B, S, D) -> y: (B, S, D).  S must be a multiple of CHUNK or < CHUNK."""
    B, S, D = x.shape
    di, H, P, N = mamba2_dims(cfg)
    z, xin, Bc, Cc, dt = _split_proj(p, x, cfg)
    cin = jnp.concatenate([xin, Bc, Cc], -1)
    conv = jax.nn.silu(_causal_conv(cin, p["conv_w"], p["conv_b"]))
    xin, Bc, Cc = conv[..., :di], conv[..., di:di + N], conv[..., di + N:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # (B,S,H)
    A = -jnp.exp(p["A_log"])                                      # (H,)
    xh = xin.reshape(B, S, H, P)

    Q = CHUNK if S % CHUNK == 0 else S
    Nc = S // Q
    xq = xh.reshape(B, Nc, Q, H, P)
    dtq = dt.reshape(B, Nc, Q, H)
    Bq = Bc.reshape(B, Nc, Q, N).astype(jnp.float32)
    Cq = Cc.reshape(B, Nc, Q, N).astype(jnp.float32)
    dA = dtq * A                                                  # (B,Nc,Q,H)
    dA_cum = jnp.cumsum(dA, axis=2)

    # --- intra-chunk (diagonal blocks) --------------------------------------
    L = _segsum_exp(jnp.moveaxis(dA_cum, -1, 2))                  # (B,Nc,H,Q,Q)
    att = jnp.einsum("bcqn,bckn->bcqk", Cq, Bq)                   # (B,Nc,Q,Q)
    xdt = xq * dtq[..., None]                                     # (B,Nc,Q,H,P)
    y_diag = jnp.einsum("bchqk,bcqk,bckhp->bcqhp",
                        L, att, xdt.astype(jnp.float32))

    # --- inter-chunk state carry ---------------------------------------------
    decay_to_end = jnp.exp(dA_cum[:, :, -1:, :] - dA_cum)         # (B,Nc,Q,H)
    chunk_states = jnp.einsum("bckn,bckh,bckhp->bcnhp",
                              Bq, decay_to_end, xdt.astype(jnp.float32))
    chunk_decay = jnp.exp(dA_cum[:, :, -1, :])                    # (B,Nc,H)

    def carry_fn(h, inp):
        cs, cd = inp                                              # per chunk
        h_new = h * cd[:, None, :, None] + cs
        return h_new, h                                           # emit state *before* chunk

    init = jnp.zeros((B, N, H, P), jnp.float32)
    final_state, prev_states = jax.lax.scan(
        carry_fn, init,
        (jnp.moveaxis(chunk_states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)                 # (B,Nc,N,H,P)

    decay_from_start = jnp.exp(dA_cum)                            # (B,Nc,Q,H)
    y_off = jnp.einsum("bcqn,bcqh,bcnhp->bcqhp",
                       Cq, decay_from_start, prev_states)

    y = (y_diag + y_off).reshape(B, S, H, P)
    y = y + p["D"][:, None] * xh.astype(jnp.float32)
    y = y.reshape(B, S, di).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = rmsnorm(y, p["out_norm"], cfg.norm_eps)
    out = y @ p["out_proj"]
    if return_state:
        # conv tail: last ssm_conv-1 pre-activation channel inputs
        tail = cin[:, S - (cfg.ssm_conv - 1):]
        return out, {"h": final_state, "conv": tail}
    return out


def make_ssm_state(cfg: ArchConfig, batch: int, dtype=jnp.float32):
    di, H, P, N = mamba2_dims(cfg)
    return {
        "h": jnp.zeros((batch, N, H, P), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, di + 2 * N), dtype),
    }


def mamba2_decode(p, x, cfg: ArchConfig, state):
    """Single-token step.  x: (B, 1, D); state from make_ssm_state."""
    B, S, D = x.shape
    assert S == 1
    di, H, P, N = mamba2_dims(cfg)
    z, xin, Bc, Cc, dt = _split_proj(p, x, cfg)
    cin = jnp.concatenate([xin, Bc, Cc], -1)                      # (B,1,C)
    window = jnp.concatenate([state["conv"], cin], axis=1)        # (B,W,C)
    conv = jax.nn.silu((window * p["conv_w"]).sum(axis=1) + p["conv_b"])
    xin, Bc, Cc = (conv[..., :di], conv[..., di:di + N],
                   conv[..., di + N:])
    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B,H)
    A = -jnp.exp(p["A_log"])
    xh = xin.reshape(B, H, P).astype(jnp.float32)
    dA = jnp.exp(dt * A)                                          # (B,H)
    h = state["h"] * dA[:, None, :, None] \
        + jnp.einsum("bn,bh,bhp->bnhp", Bc.astype(jnp.float32), dt, xh)
    y = jnp.einsum("bn,bnhp->bhp", Cc.astype(jnp.float32), h)
    y = y + p["D"][:, None] * xh
    y = y.reshape(B, 1, di).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = rmsnorm(y, p["out_norm"], cfg.norm_eps)
    out = y @ p["out_proj"]
    return out, {"h": h, "conv": window[:, 1:]}
