"""Architecture registry: ``--arch <id>`` -> (config, smoke config, model API).

``model_api(cfg)`` returns the module implementing the uniform interface
(init_params / abstract_params / loss_train / prefill / decode_step /
init_caches) — decoder-only LMs use :mod:`repro.models.lm`, enc-dec uses
:mod:`repro.models.whisper`.
"""
from __future__ import annotations

import importlib
from types import ModuleType
from typing import Dict, Tuple

from ..configs.base import ArchConfig

_CONFIG_MODULES = {
    "qwen3-1.7b": "qwen3_1_7b",
    "mistral-large-123b": "mistral_large_123b",
    "gemma3-4b": "gemma3_4b",
    "qwen2-vl-7b": "qwen2_vl_7b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "llama3-405b": "llama3_405b",
    "xlstm-1.3b": "xlstm_1_3b",
    "zamba2-2.7b": "zamba2_2_7b",
    "whisper-tiny": "whisper_tiny",
    "phi3.5-moe-42b-a6.6b": "phi35_moe",
}

ARCH_IDS = tuple(_CONFIG_MODULES)


def get_config(arch: str, smoke: bool = False) -> ArchConfig:
    if arch not in _CONFIG_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ARCH_IDS)}")
    mod = importlib.import_module(f"repro.configs.{_CONFIG_MODULES[arch]}")
    return mod.SMOKE if smoke else mod.CONFIG


def model_api(cfg: ArchConfig) -> ModuleType:
    if cfg.encdec:
        from . import whisper
        return whisper
    from . import lm
    return lm


def all_configs(smoke: bool = False) -> Dict[str, ArchConfig]:
    return {a: get_config(a, smoke) for a in ARCH_IDS}
