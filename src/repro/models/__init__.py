from .registry import ARCH_IDS, get_config, model_api, all_configs
