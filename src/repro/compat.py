"""Version shims for the moving parts of the jax API surface.

The repo targets current jax (``jax.shard_map``, ``Mesh(axis_types=...)``),
but clean environments may carry 0.4.x where shard_map still lives in
``jax.experimental`` (``check_rep`` instead of ``check_vma``) and meshes have
no axis types.  Routing the three call sites through here keeps every
transport runnable on both.
"""
from __future__ import annotations

import jax

__all__ = ["shard_map", "make_mesh", "make_mesh_by_shape"]


def shard_map(f, mesh, in_specs, out_specs):
    """jax.shard_map with replication checking off, on any jax version."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=False)


def make_mesh(devices, axis_names) -> jax.sharding.Mesh:
    """Mesh with Auto axis types where the installed jax supports them."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.sharding.Mesh(devices, axis_names)
    return jax.sharding.Mesh(
        devices, axis_names, axis_types=(axis_type.Auto,) * len(axis_names))


def make_mesh_by_shape(shape, axis_names) -> jax.sharding.Mesh:
    """jax.make_mesh (topology-aware device ordering on real fleets) with
    Auto axis types when supported; enumeration-order fallback otherwise."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if hasattr(jax, "make_mesh"):
        if axis_type is not None:
            try:
                return jax.make_mesh(
                    shape, axis_names,
                    axis_types=(axis_type.Auto,) * len(axis_names))
            except TypeError:  # make_mesh predates axis_types
                pass
        return jax.make_mesh(shape, axis_names)
    import numpy as np
    n = int(np.prod(shape))
    return make_mesh(np.asarray(jax.devices()[:n]).reshape(shape), axis_names)
