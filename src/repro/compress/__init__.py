"""repro.compress — the codec subsystem: one quantizer, many consumers.

The paper reduces every algorithm in its family to one abstract object, the
random quantizer ``Q(·; s)`` characterized by its variance constant ``q_s``
(Assumption 1) and message size ``M_s``.  This package is that object's single
concrete home.  It splits the concern into three orthogonal axes:

  codec     (*what* is sent)   — :class:`QSGDCodec` (Assumption-1 stochastic
            levels, optional per-bucket norms), :class:`RotatedQSGDCodec`
            (randomized-Hadamard preconditioning, GQFedWAvg's quantizer),
            :class:`IdentityCodec` (s = ∞, recovering PM-SGD / FedAvg /
            PR-SGD), and the stateful :class:`ErrorFeedbackCodec` wrapper
            (memory-compensated encode; runtime-only — see its legality
            note);
  backend   (*how* it is computed) — reference ``jnp`` math or the Pallas TPU
            kernels from :mod:`repro.kernels.qsgd`, interchangeable per call
            and verified bit-identical;
  wire      (*how* it travels / what it costs) — "packed" | "f32" | "int8" |
            "int4" | "rs_ag" | "elias" formats with the bit accounting in
            :mod:`repro.compress.wire` (the Elias-omega gap coder itself
            lives in :mod:`repro.compress.elias`).

The encode side is a *one-pass pipeline*: ``Codec.encode_payload`` goes
straight from gradient to wire payload — fused norm+quantize+pack Pallas
kernel for "int4" (``encode_fused``, with a rotate-fused variant for the
Hadamard-preconditioned codec), omega-coded words for "elias" — instead
of separate norm / quantize / pack sweeps over HBM.

Consumers:
  * :mod:`repro.core.genqsgd` — Algorithm 1 reference, via ``make_codec``;
  * :mod:`repro.fed.runtime` — per-tensor encode + aggregation transports,
    via the traced-``s``-capable ``encode_tensor`` / ``decode_tensor``;
  * :mod:`repro.core.cost` — ``M_s`` / ``q_s`` via ``codec.wire_bits`` /
    ``codec.variance_bound``, so the GIA/CGP optimizer prices exactly the
    bytes the runtime sends;
  * :mod:`repro.train.trainer` and ``benchmarks/kernel_bench.py``.
"""
from . import elias
from .backends import (default_interpret, decode_tensor, encode_fused,
                       encode_fused_jnp, encode_rotated_fused, encode_tensor,
                       level_dtype, qsgd_levels)
from .codec import (CODEC_KINDS, Codec, ErrorFeedbackCodec, IdentityCodec,
                    QSGDCodec, RotatedQSGDCodec, bits_per_message,
                    make_codec, q_pair, variance_bound)
from .rotation import fwht, next_pow2, rotate, unrotate
from .wire import (RUNTIME_WIRES, WIRE_FORMATS, level_bits, pack_int4,
                   unpack_int4, wire_bits, wire_max_s)

__all__ = [
    "Codec", "QSGDCodec", "IdentityCodec", "RotatedQSGDCodec",
    "ErrorFeedbackCodec", "CODEC_KINDS", "make_codec",
    "encode_tensor", "decode_tensor", "qsgd_levels", "level_dtype",
    "encode_fused", "encode_fused_jnp", "encode_rotated_fused",
    "variance_bound", "bits_per_message", "q_pair",
    "WIRE_FORMATS", "RUNTIME_WIRES", "wire_bits", "level_bits",
    "wire_max_s", "pack_int4", "unpack_int4", "default_interpret",
    "rotate", "unrotate", "fwht", "next_pow2", "elias",
]
