"""The two interchangeable execution backends of the codec subsystem.

This module owns the ONE implementation of the QSGD stochastic level
assignment in the repository:

    xi_i = floor(s * |y_i| / ||y||) + Bernoulli(frac)          (Assumption 1)

``qsgd_levels`` is the reference ``jnp`` form; the Pallas backend reaches the
same math through the tiled TPU kernels in :mod:`repro.kernels.qsgd` (whose
kernel body is the lowered twin of this formula) and is verified bit-identical
against the reference in ``tests/kernels/test_qsgd_kernels.py``.

Every former copy of this computation — ``core/quantizer._levels``,
``fed/runtime.quantize_tensor``, ``kernels/ref.qsgd_quantize_ref`` — was
deleted in favour of this module; consumers go through
:mod:`repro.compress.codec` or the functional ``encode_tensor`` /
``decode_tensor`` pair below.

Randomness is externally supplied as a uniform(0,1) tensor shaped like the
input (callers choose ``jax.random`` or the runtime's partitionable
counter-RNG), so both backends are deterministic functions of their inputs
and can be cross-checked exactly.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..kernels import qsgd as _K
from ..kernels.qsgd import default_interpret
from . import rotation as R

__all__ = [
    "qsgd_levels", "encode_jnp", "decode_jnp", "encode_pallas",
    "decode_apply_pallas", "encode_tensor", "decode_tensor",
    "encode_bucketed", "decode_bucketed", "to_buckets",
    "tensor_norm_pallas", "default_interpret", "level_dtype",
    "encode_fused", "encode_fused_jnp", "encode_rotated_fused",
]


def level_dtype(s: int):
    """Narrowest signed container for levels in [-s, s]."""
    return jnp.int8 if s <= 127 else jnp.int32


# ---------------------------------------------------------------------------
# reference jnp backend
# ---------------------------------------------------------------------------
def qsgd_levels(y: jax.Array, u: jax.Array, s, norm: jax.Array) -> jax.Array:
    """Signed stochastic levels sign(y) * xi as f32 (caller picks container).

    ``s`` may be a Python int or a traced scalar (heterogeneous per-worker
    quantizers vectorize through vmap); ``u`` is uniform(0,1) noise like y.
    """
    yf = y.astype(jnp.float32)
    safe = jnp.where(norm > 0, norm, 1.0)
    scaled = jnp.asarray(s, jnp.float32) * jnp.abs(yf) / safe
    base = jnp.floor(scaled)
    xi = base + (u < (scaled - base)).astype(jnp.float32)
    return jnp.sign(yf) * xi


def encode_jnp(y: jax.Array, s, u: jax.Array):
    """-> (levels f32, norm f32 scalar) with the per-tensor L2 norm."""
    yf = y.astype(jnp.float32)
    norm = jnp.sqrt(jnp.sum(yf * yf))
    return qsgd_levels(y, u, s, norm), norm


def decode_jnp(levels: jax.Array, norm: jax.Array, s,
               dtype=jnp.float32) -> jax.Array:
    """Q(y; s) value from (levels, norm): levels * norm / s."""
    s_f = jnp.asarray(s, jnp.float32)
    return (levels.astype(jnp.float32) * (norm / s_f)).astype(dtype)


# ---------------------------------------------------------------------------
# Pallas kernel backend (pads to the kernel tile grid, delegates to
# repro.kernels.qsgd; int8 container, so s <= 127)
# ---------------------------------------------------------------------------
def _to_grid2d(flat: jax.Array):
    """Pad a 1-D array to a (R, BLOCK_COLS) grid; returns (2d, orig_len)."""
    n = flat.shape[0]
    cols = _K.BLOCK_COLS
    rows = max(_K.BLOCK_ROWS, -(-n // cols))
    rows = -(-rows // _K.BLOCK_ROWS) * _K.BLOCK_ROWS
    pad = rows * cols - n
    flat = jnp.pad(flat, (0, pad))
    return flat.reshape(rows, cols), n


def encode_pallas(y: jax.Array, s: int, u: jax.Array,
                  interpret: Optional[bool] = None):
    """Kernel-backed encode: -> (levels int8 shaped like y, norm f32)."""
    if s > 127:
        raise ValueError(f"the Pallas backend stores levels as int8 "
                         f"(s <= 127), got {s}")
    itp = default_interpret() if interpret is None else interpret
    y2d, n = _to_grid2d(y.reshape(-1).astype(jnp.float32))
    # zero-padded noise is safe: padded y is 0 => frac 0 => u < 0 never fires
    u2d, _ = _to_grid2d(u.reshape(-1).astype(jnp.float32))
    norm = jnp.sqrt(_K.sumsq_kernel_call(y2d, interpret=itp))
    safe = jnp.where(norm > 0, norm, 1.0)
    lvl2d = _K.quantize_kernel_call(y2d, u2d, jnp.float32(s) / safe,
                                    interpret=itp)
    return lvl2d.reshape(-1)[:n].reshape(y.shape), norm


def decode_apply_pallas(x: jax.Array, levels: jax.Array, norm: jax.Array,
                        s: int, gamma, interpret: Optional[bool] = None):
    """Fused x + gamma * decode(levels) — the model-update apply (3)."""
    itp = default_interpret() if interpret is None else interpret
    x2d, n = _to_grid2d(x.reshape(-1))
    l2d, _ = _to_grid2d(levels.reshape(-1).astype(jnp.float32))
    out = _K.dequant_apply_kernel_call(
        x2d, l2d.astype(jnp.int8), (norm / s).astype(jnp.float32),
        jnp.float32(gamma), interpret=itp)
    return out.reshape(-1)[:n].reshape(x.shape).astype(x.dtype)


def tensor_norm_pallas(y: jax.Array, interpret: Optional[bool] = None):
    itp = default_interpret() if interpret is None else interpret
    y2d, _ = _to_grid2d(y.reshape(-1).astype(jnp.float32))
    return jnp.sqrt(_K.sumsq_kernel_call(y2d, interpret=itp))


# ---------------------------------------------------------------------------
# one-pass fused encode (the encode pipeline's kernel entry points)
# ---------------------------------------------------------------------------
def _check_fused_s(s: int, pack: bool):
    if s > 127:
        raise ValueError(f"the fused encode stores levels as int8 "
                         f"(s <= 127), got {s}")
    if pack and s > 7:
        raise ValueError(f"int4 nibble packing carries s <= 7, got {s}")


def encode_fused(y: jax.Array, s: int, u: jax.Array, *, pack: bool = False,
                 interpret: Optional[bool] = None):
    """One-pass kernel encode: norm + quantize (+ int4 pack) in a single
    pallas_call — bit-identical to ``encode_pallas`` followed by
    ``wire.pack_int4`` but without the int8 level round-trip through HBM.

    -> ``(payload, norm)``: packed int4 bytes of length ceil(n/2) when
    ``pack`` (the padded tail quantizes to level 0, so slicing the packed
    grid reproduces ``pack_int4`` exactly, odd lengths included), else int8
    levels shaped like ``y``.
    """
    _check_fused_s(int(s), pack)
    itp = default_interpret() if interpret is None else interpret
    y2d, n = _to_grid2d(y.reshape(-1).astype(jnp.float32))
    u2d, _ = _to_grid2d(u.reshape(-1).astype(jnp.float32))
    out2d, norm = _K.fused_encode_call(y2d, u2d, s, pack=pack, interpret=itp)
    if pack:
        return out2d.reshape(-1)[:(n + 1) // 2], norm
    return out2d.reshape(-1)[:n].reshape(y.shape), norm


def encode_fused_jnp(y: jax.Array, s, u: jax.Array, *, pack: bool = False):
    """The reference backend's one-pass pipeline: ``encode_jnp`` + nibble
    pack as ONE jittable expression (XLA fuses the quantize and pack,
    skipping the int8 materialization the staged path pays).  Same payload
    contract as :func:`encode_fused`; ``s`` may be traced (pack needs
    static s <= 7, which the codec layer validates)."""
    from .wire import pack_int4
    lvl, norm = encode_jnp(y, s, u)
    if pack:
        n = y.size
        return pack_int4(lvl.astype(jnp.int8))[:(n + 1) // 2], norm
    return lvl.astype(jnp.int8), norm


def encode_rotated_fused(y: jax.Array, s: int, u: jax.Array, seed: int,
                         *, pack: bool = False,
                         interpret: Optional[bool] = None):
    """One-pass rotated encode: randomized-Hadamard rotation + norm +
    quantize (+ pack) without a separate rotation pass.  Messages whose
    pow2-padded dimension fits one VMEM block run entirely in-kernel
    (:func:`repro.kernels.qsgd.fused_rotate_encode_call`); larger ones
    rotate via the jnp FWHT and fuse the remaining norm+quantize+pack.

    ``u`` must have the padded length ``next_pow2(y.size)`` (the rotated
    message's length — same contract as ``RotatedQSGDCodec.encode``).
    -> ``(payload, norm)`` with payload of the *padded* length d (levels)
    or d/2 (packed bytes): the padded message IS what travels.
    """
    _check_fused_s(int(s), pack)
    itp = default_interpret() if interpret is None else interpret
    n = y.size
    d = R.next_pow2(n)
    if d <= _K.FUSED_ROTATE_MAX_DIM:
        ypad = jnp.pad(y.reshape(-1).astype(jnp.float32), (0, d - n))
        return _K.fused_rotate_encode_call(ypad, u, s, seed, pack=pack,
                                           interpret=itp)
    r = R.rotate(y, seed)
    out, norm = encode_fused(r, s, u, pack=pack, interpret=itp)
    return out.reshape(-1)[:(d // 2 if pack else d)], norm


# ---------------------------------------------------------------------------
# functional per-tensor entry points (traced-s capable; None = identity)
# ---------------------------------------------------------------------------
def to_buckets(flat: jax.Array, bucket: int) -> jax.Array:
    """Zero-pad a 1-D array to a whole number of buckets -> (n_buckets, bucket)."""
    nb = -(-flat.shape[0] // bucket)
    pad = nb * bucket - flat.shape[0]
    return jnp.pad(flat, (0, pad)).reshape(nb, bucket)


def encode_bucketed(y: jax.Array, s, u: jax.Array, bucket: int):
    """Per-bucket-norm encode (QSGD bucketing): -> (levels f32 shaped like y,
    norms (n_buckets,)).  The ONE bucketed implementation — QSGDCodec and the
    runtime-facing ``encode_tensor`` both delegate here; ``s`` may be traced.
    """
    y2 = to_buckets(y.reshape(-1).astype(jnp.float32), bucket)
    u2 = to_buckets(u.reshape(-1).astype(jnp.float32), bucket)
    lvl2, norms = jax.vmap(lambda yy, uu: encode_jnp(yy, s, uu))(y2, u2)
    return lvl2.reshape(-1)[:y.size].reshape(y.shape), norms


def decode_bucketed(levels: jax.Array, norm: jax.Array, s,
                    dtype=jnp.float32, bucket: int = 1) -> jax.Array:
    l2 = to_buckets(levels.reshape(-1).astype(jnp.float32), bucket)
    v2 = jax.vmap(lambda ll, nn: decode_jnp(ll, nn, s))(l2, norm.reshape(-1))
    return (v2.reshape(-1)[:levels.size].reshape(levels.shape).astype(dtype))


def encode_tensor(y: jax.Array, s, u: jax.Array, backend: str = "jnp",
                  bucket: Optional[int] = None):
    """-> (levels int8, norm); passthrough (y, 1.0) for s=None.

    ``norm`` is an f32 scalar, or (n_buckets,) when ``bucket`` is set
    (per-bucket-norm quantization — the same bucketing
    :class:`~repro.compress.codec.QSGDCodec` implements and
    ``EdgeSystem(q_dim=...)`` prices).  The int8 container bounds ``s`` at
    127 — exactly the runtime's wire constraint; use a
    :class:`~repro.compress.codec.QSGDCodec` for wider static quantizers.
    """
    if s is None:
        return y, jnp.float32(1.0)
    if isinstance(s, int) and s > 127:
        raise ValueError(f"encode_tensor's int8 container carries s <= 127, "
                         f"got {s}; use QSGDCodec for wider quantizers")
    if bucket is not None:
        if backend == "pallas":
            raise ValueError("the Pallas backend computes whole-tensor norms")
        lvl, norms = encode_bucketed(y, s, u, bucket)
        return lvl.astype(jnp.int8), norms
    if backend == "pallas":
        return encode_pallas(y, int(s), u)
    lvl, norm = encode_jnp(y, s, u)
    return lvl.astype(jnp.int8), norm


def decode_tensor(levels: jax.Array, norm: jax.Array, s,
                  dtype=jnp.float32, bucket: Optional[int] = None) -> jax.Array:
    if s is None:
        return levels.astype(dtype)
    if bucket is not None:
        return decode_bucketed(levels, norm, s, dtype, bucket)
    return decode_jnp(levels, norm, s, dtype)
