"""Elias-omega entropy coding for QSGD levels — the "elias" wire format.

QSGD's communication theorem (arXiv 1610.02132, Thm 3.2) prices messages
with a *universal* integer code over the levels, not a fixed-width
container: the expected message length is O(s(s + sqrt(d)) log(..)) bits,
far below 32d for sparse low-s messages — the bound GenQSGD
(arXiv 2110.12987) and GQFedWAvg (arXiv 2306.07497) both assume for
their convergence-vs-cost trade-offs.  The bound is only reachable with
*positional* (gap) coding — most levels are zero, and spending even one
bit per zero coordinate already costs d bits — so this module implements
QSGD's actual scheme end to end:

  stream := [ omega(gap) omega(|level|) sign ]*  omega(terminal-gap)

one triple per **nonzero** level, where ``gap`` is the distance to the
previous nonzero coordinate (>= 1) and the terminal gap points one past
the end of the vector, which makes the stream self-delimiting given d.
Everything is Elias-omega coded; zeros cost no codewords of their own.

Pricing (used by ``wire.wire_bits(..., wire="elias")``):
  * :func:`expected_code_bits` — Thm 3.2's closed-form expected payload;
  * :func:`omega_max_bits` — worst-case bits per coordinate (unit gap +
    largest magnitude codeword + sign), monotone in s;
  * :func:`payload_bits` — min of the two total bounds.  The realized
    stream provably fits the worst-case bound; the expected bound holds
    in expectation (tests pin both).

Bit layout: the stream is a little-endian bit sequence — transmitted bit
``j`` of a codeword lands at stream bit ``offset + j`` (omega groups
MSB-first within the codeword), stream bit ``b`` lives in
``words[b >> 5]`` at bit ``b & 31``.  The payload is a plain jnp
``uint32`` vector, so it is identical no matter which codec *backend*
(jnp or Pallas) produced the levels: the backends are level-bit-identical
and the coder below is shared — asserted in ``tests/unit/test_elias.py``.
All arithmetic is pure uint32 (x64 is off by default, so uint64 would
silently downcast).

The encoder is fully vectorized (cummax gaps + cumsum offsets + three
scatter-adds); the decoder is a ``lax.scan`` over nonzero slots with an
unrolled omega-group walk per codeword — fine for the reference
transport and tests; a lane-parallel Pallas decode is future work
(variable-length codes do not block-decompose).
"""
from __future__ import annotations

import functools
import math
from typing import Tuple

__all__ = [
    "MAX_COORD_BITS", "MAX_RUNTIME_S", "omega_length", "omega_max_bits",
    "expected_code_bits", "payload_bits", "encode_levels", "decode_levels",
    "stream_bits", "word_capacity",
]

#: worst-case stream bits one coordinate can cost at the runtime cap
#: (unit gap = 1 bit, |level| <= 127 -> <= 13-bit magnitude, sign = 1)
MAX_COORD_BITS = 15
#: the runtime coder reads levels from an int8 container, like every other
#: level transport (pricing via :func:`payload_bits` is unbounded in s)
MAX_RUNTIME_S = 127
#: any terminal-gap codeword for vectors below 2^24 coordinates fits this
_TERM_BITS = 36


# ---------------------------------------------------------------------------
# pricing (pure python; the jnp coder below realizes these bounds)
# ---------------------------------------------------------------------------
def _omega_bits(n: int):
    """Elias-omega codeword of n >= 1, in transmission order."""
    if n < 1:
        raise ValueError(f"omega codes positive integers, got {n}")
    bits = [0]
    while n > 1:
        group = [int(c) for c in bin(n)[2:]]
        bits = group + bits
        n = len(group) - 1
    return bits


def omega_length(n: int) -> int:
    """Codeword length (bits) of the Elias-omega code of n >= 1."""
    return len(_omega_bits(n))


@functools.lru_cache(maxsize=None)
def omega_max_bits(s: int) -> int:
    """Worst-case stream bits one coordinate costs at quantizer s: a unit
    gap (1 bit) + the largest magnitude codeword over |level| in [1, s]
    (omega length is not monotone — powers of two jump — so take the max)
    + the sign bit.  Monotone in s, like every fixed-length wire's
    bits/coordinate."""
    if s <= 0:
        raise ValueError(f"quantization parameter s must be positive, got {s}")
    return 2 + max(omega_length(m) for m in range(1, s + 1))


def expected_code_bits(s: int, d: int) -> float:
    """QSGD Thm 3.2's closed-form expected payload (bits, excluding the norm
    word): at most s(s + sqrt(d)) nonzero levels travel, each costing
    O(log(d / #nonzeros)) positional+magnitude bits under a universal code:

        s(s + sqrt(d)) * (3 + 1.5 * log2(2(s^2 + d) / (s(s + sqrt(d)))))
    """
    if s <= 0:
        raise ValueError(f"quantization parameter s must be positive, got {s}")
    nz = s * (s + math.sqrt(d))
    return nz * (3.0 + 1.5 * math.log2(2.0 * (s * s + d) / nz))


def payload_bits(s: int, d: int) -> float:
    """min(worst-case, expected-sparse) total level bits for d coordinates —
    both are valid message-size bounds, so the cost model prices the tighter
    one (dense high-s messages take d * omega_max_bits; sparse low-s
    messages the Thm-3.2 term)."""
    return min(float(d) * omega_max_bits(s) + _TERM_BITS,
               expected_code_bits(s, d))


def word_capacity(d: int) -> int:
    """Static uint32 word count that always holds d coded levels (the
    realized stream fits ``MAX_COORD_BITS * d + _TERM_BITS``; +2 words of
    slack so the 3-word scatter / 2-word gather never run off the end)."""
    return (MAX_COORD_BITS * d + _TERM_BITS + 31) // 32 + 2


# ---------------------------------------------------------------------------
# vectorized bit plumbing (everything uint32)
# ---------------------------------------------------------------------------
def _bitlen(v):
    """Bit length of uint32 v >= 1 (branch-free)."""
    import jax.numpy as jnp
    ln = jnp.zeros_like(v)
    x = v
    for k in (16, 8, 4, 2, 1):
        t = x >> jnp.uint32(k)
        big = t > 0
        ln = ln + jnp.where(big, jnp.uint32(k), jnp.uint32(0))
        x = jnp.where(big, t, x)
    return ln + jnp.uint32(1)


def _rev32(x):
    """Bit-reversal of uint32 (group value <-> MSB-first transmission)."""
    import jax.numpy as jnp
    u = jnp.uint32
    x = ((x & u(0x55555555)) << u(1)) | ((x >> u(1)) & u(0x55555555))
    x = ((x & u(0x33333333)) << u(2)) | ((x >> u(2)) & u(0x33333333))
    x = ((x & u(0x0F0F0F0F)) << u(4)) | ((x >> u(4)) & u(0x0F0F0F0F))
    x = ((x & u(0x00FF00FF)) << u(8)) | ((x >> u(8)) & u(0x00FF00FF))
    return (x << u(16)) | (x >> u(16))


def _or_at(lo, hi, off, g):
    """OR a <=25-bit group ``g`` into the 64-bit register (lo, hi) at bit
    ``off`` (total register use stays < 64 bits by construction)."""
    import jax.numpy as jnp
    u = jnp.uint32
    sh = off & u(31)
    spill = jnp.where(sh > 0, g >> ((u(32) - sh) & u(31)), u(0))
    in_lo = off < u(32)
    lo = lo | jnp.where(in_lo, g << sh, u(0))
    hi = hi | jnp.where(in_lo, spill, g << sh)
    return lo, hi


def _omega_parts(v):
    """Vectorized Elias-omega codeword of uint32 v in [1, 2^25):
    -> (lo, hi, nbits) with transmitted bit j at register bit j."""
    import jax.numpy as jnp
    u = jnp.uint32
    v = v.astype(jnp.uint32)
    chain = [v]
    for _ in range(4):  # values < 2^25 terminate in <= 4 length steps
        p = chain[-1]
        chain.append(jnp.where(p > 1, _bitlen(p) - u(1), u(1)))
    lo = jnp.zeros_like(v)
    hi = jnp.zeros_like(v)
    off = jnp.zeros_like(v)
    for grp_val in reversed(chain):  # outermost length group transmits first
        valid = grp_val > u(1)
        ln = jnp.where(valid, _bitlen(grp_val), u(0))
        grp = jnp.where(valid,
                        _rev32(grp_val) >> ((u(32) - ln) & u(31)), u(0))
        lo, hi = _or_at(lo, hi, off, grp)
        off = off + ln
    return lo, hi, off + u(1)  # terminal zero bit (value 0: no data change)


def _gaps(flat):
    """-> (nz mask, per-coordinate gap to the previous nonzero, terminal
    gap) for int32 levels; gaps are uint32 >= 1."""
    import jax
    import jax.numpy as jnp
    d = flat.shape[0]
    nz = flat != 0
    pos = jnp.arange(d, dtype=jnp.int32)
    tagged = jnp.where(nz, pos, -1)
    run = jax.lax.associative_scan(jnp.maximum, tagged)
    prev = jnp.concatenate([jnp.full((1,), -1, jnp.int32), run[:-1]])
    gap = (pos - prev).astype(jnp.uint32)
    tgap = (jnp.int32(d) - run[-1]).astype(jnp.uint32)
    return nz, gap, tgap


# ---------------------------------------------------------------------------
# the runtime coder
# ---------------------------------------------------------------------------
def encode_levels(levels) -> Tuple["object", "object"]:
    """levels (any shape, int in [-127, 127]) -> (words, nbits).

    ``words`` is a ``uint32`` vector of the *static* capacity
    :func:`word_capacity` (jit-friendly); ``nbits`` the realized stream
    length in bits (traced int32 scalar) — the payload on the wire is the
    first ceil(nbits/32) words.  Fully vectorized: per-nonzero codewords
    assembled in 64-bit registers, cumsum offsets, three scatter-adds.
    """
    import jax.numpy as jnp
    u = jnp.uint32
    flat = levels.reshape(-1).astype(jnp.int32)
    d = flat.shape[0]
    if d >= (1 << 24):
        raise ValueError(f"elias runtime coder handles < 2^24 coords, "
                         f"got {d}")
    if d == 0:
        # just the terminal gap omega(1) = a single 0 bit
        return jnp.zeros(word_capacity(0), jnp.uint32), jnp.int32(1)
    nz, gap, tgap = _gaps(flat)
    glo, ghi, gn = _omega_parts(gap)
    mlo, _, mn = _omega_parts(jnp.maximum(jnp.abs(flat), 1).astype(u))
    lo, hi = _or_at(glo, ghi, gn, mlo)   # magnitude <= 127: <= 13 bits
    nb = gn + mn
    lo, hi = _or_at(lo, hi, nb, (flat < 0).astype(u))
    nb = nb + u(1)
    lo = jnp.where(nz, lo, u(0))
    hi = jnp.where(nz, hi, u(0))
    nb = jnp.where(nz, nb, u(0))
    ends = jnp.cumsum(nb)
    tlo, thi, tn = _omega_parts(tgap[None])
    lo = jnp.concatenate([lo, tlo])
    hi = jnp.concatenate([hi, thi])
    offs = jnp.concatenate([ends - nb, ends[-1:]])
    total = ends[-1] + tn[0]
    # each 64-bit register spans at most three 32-bit words; pure u32
    widx = (offs >> u(5)).astype(jnp.int32)
    sh = offs & u(31)
    carry = (u(32) - sh) & u(31)
    w0 = lo << sh
    w1 = jnp.where(sh > 0, lo >> carry, u(0)) | (hi << sh)
    w2 = jnp.where(sh > 0, hi >> carry, u(0))
    words = jnp.zeros(word_capacity(d), jnp.uint32)
    words = words.at[widx].add(w0).at[widx + 1].add(w1).at[widx + 2].add(w2)
    return words, total.astype(jnp.int32)


def decode_levels(words, d: int):
    """Inverse of :func:`encode_levels`: -> int8 levels of length ``d``
    (sequential prefix-code walk; ``d`` must be static)."""
    import jax
    import jax.numpy as jnp
    u = jnp.uint32
    if d == 0:
        return jnp.zeros(0, jnp.int8)
    wpad = jnp.concatenate([words.astype(jnp.uint32), jnp.zeros(2, u)])

    def window(p):
        """32 stream bits at bit position p, little-endian."""
        wi = (p >> u(5)).astype(jnp.int32)
        b = p & u(31)
        hi = jnp.where(b > 0, wpad[wi + 1] << ((u(32) - b) & u(31)), u(0))
        return (wpad[wi] >> b) | hi

    def omega_decode(p):
        n = u(1)
        done = jnp.bool_(False)
        for _ in range(6):  # covers values < 2^25 (4 groups + stop + slack)
            win = window(p)
            stop = jnp.logical_and(~done, (win & u(1)) == 0)
            go = jnp.logical_and(~done, (win & u(1)) == 1)
            ln = jnp.minimum(n + u(1), u(25))
            grp = win & ((u(1) << ln) - u(1))
            val = _rev32(grp) >> ((u(32) - ln) & u(31))
            p = jnp.where(stop, p + u(1), jnp.where(go, p + ln, p))
            n = jnp.where(go, val, n)
            done = jnp.logical_or(done, stop)
        return n, p

    def step(carry, _):
        # carry stays scalar-only: emitting (index, value) pairs as scan
        # outputs instead of scattering into a d-sized carry keeps the
        # per-step state tiny (an in-carry scatter degrades to a full
        # buffer copy per step under the SPMD partitioner — O(d^2)).
        p, pos, done = carry
        g, p1 = omega_decode(p)
        npos = pos + g.astype(jnp.int32)
        fin = npos >= d
        m, p2 = omega_decode(p1)     # junk when fin/done: gated below
        neg = (window(p2) & u(1)) == 1
        val = jnp.where(neg, -m.astype(jnp.int32), m.astype(jnp.int32))
        live = jnp.logical_and(~done, ~fin)
        p = jnp.where(done, p, jnp.where(fin, p1, p2 + u(1)))
        pos = jnp.where(live, npos, pos)
        done = jnp.logical_or(done, fin)
        return (p, pos, done), (jnp.where(live, npos, jnp.int32(d)),
                                jnp.where(live, val, jnp.int32(0)))

    carry = (u(0), jnp.int32(-1), jnp.bool_(False))
    _, (idxs, vals) = jax.lax.scan(step, carry, None, length=d)
    out = jnp.zeros(d + 1, jnp.int32).at[idxs].set(vals)  # slot d: dead 0s
    return out[:d].astype(jnp.int8)


def stream_bits(levels):
    """Realized stream length (bits, traced int32) without materializing
    the words — the runtime's per-round payload metric."""
    import jax.numpy as jnp
    flat = levels.reshape(-1).astype(jnp.int32)
    if flat.shape[0] == 0:
        return jnp.int32(1)
    nz, gap, tgap = _gaps(flat)
    _, _, gn = _omega_parts(gap)
    _, _, mn = _omega_parts(jnp.maximum(jnp.abs(flat), 1)
                            .astype(jnp.uint32))
    nb = jnp.where(nz, gn + mn + jnp.uint32(1), jnp.uint32(0))
    _, _, tn = _omega_parts(tgap[None])
    return (jnp.sum(nb) + tn[0]).astype(jnp.int32)
