"""Wire formats and cost-model-consistent bit accounting.

A *codec* decides how a tensor becomes (levels, norm); a *wire format*
decides how those travel and therefore how many bits one message costs.
The same table serves both sides of the system:

  * the :mod:`repro.fed.runtime` aggregation transports validate their
    quantizers against :func:`wire_max_s` and move exactly the payloads
    priced here;
  * :class:`repro.core.cost.EdgeSystem` derives ``M_s`` from
    :func:`wire_bits` via the codec, so the GIA/CGP optimizer provably
    prices the same bytes the runtime sends.

Formats:
  "packed" — fixed-length code: 32-bit norm per bucket plus, per coordinate,
             a sign bit and ceil(log2(s+1)) level bits.  The paper's
             monotone-in-s cost model (arbitrary s); not a runtime transport.
  "f32"    — dequantized values as f32 (paper-faithful math on the wire).
  "rs_ag"  — same f32 payload moved as reduce-scatter + all-gather.
  "int8"   — raw int8 levels + f32 norms; s <= 127.
  "int4"   — two levels packed per byte + f32 norms; s <= 7 (the paper's
             low-s regime), 2x fewer aggregation bytes than int8.
  "elias"  — Elias-omega gap-coded levels + f32 norms
             (:mod:`repro.compress.elias`): one omega(gap) + omega(|level|)
             + sign triple per *nonzero* level, so the message costs
             min(d * omega_max_bits(s) + term, QSGD-Thm-3.2 expected bits)
             — the paper's tighter M_s bound.  Unbounded s for *pricing*
             (worst-case cost grows with log s, e.g. 24 bits/coordinate at
             s = 2^14); the *runtime* coder reads levels from an int8
             container, so the fed transport carries s <= 127 (validated
             by FedConfig, not here).  An exact (s = None) message rides
             raw f32, like every non-packing wire.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from . import elias as E

__all__ = [
    "WIRE_FORMATS", "RUNTIME_WIRES", "wire_max_s", "level_bits",
    "wire_bits", "pack_int4", "unpack_int4",
]

#: every format the bit model prices
WIRE_FORMATS = ("packed", "f32", "int8", "int4", "rs_ag", "elias")
#: the subset the fed runtime accepts as aggregation transports
RUNTIME_WIRES = ("f32", "int8", "int4", "rs_ag", "elias")

#: largest s each format can carry (None = unbounded)
_WIRE_MAX_S = {"packed": None, "f32": 127, "rs_ag": 127,
               "int8": 127, "int4": 7, "elias": None}


def wire_max_s(wire: str) -> Optional[int]:
    """Largest quantization parameter the format's container can hold.

    f32/rs_ag move f32 *values*, but the runtime still materializes levels
    in an int8 container first, hence the shared 127 cap there.
    """
    if wire not in _WIRE_MAX_S:
        raise ValueError(f"unknown wire format {wire!r}; "
                         f"expected one of {WIRE_FORMATS}")
    return _WIRE_MAX_S[wire]


def level_bits(s: Optional[int], wire: str) -> float:
    """Bits one coordinate occupies on the wire.  For the variable-length
    "elias" format this is the *worst-case* per-coordinate cost (unit gap
    + largest magnitude codeword + sign); :func:`wire_bits` prices the
    tighter min(worst-case, expected) total."""
    if s is None or wire in ("f32", "rs_ag"):
        return 32.0
    if wire == "packed":
        return 1.0 + math.ceil(math.log2(s + 1))
    if wire == "int8":
        return 8.0
    if wire == "int4":
        return 4.0
    if wire == "elias":
        return float(E.omega_max_bits(s))
    raise ValueError(f"unknown wire format {wire!r}")


def wire_bits(s: Optional[int], dim: int, wire: str = "packed",
              bucket: Optional[int] = None) -> float:
    """M_s: bits to represent one D-dimensional message on this wire.

    ``bucket`` = per-bucket-norm quantization (QSGD bucketing): each bucket
    contributes its own 32-bit norm word.  Raises for (s, wire) pairs the
    transport cannot carry, so the cost layer can never price a message the
    runtime would reject.
    """
    cap = wire_max_s(wire)
    if s is not None and s <= 0:
        raise ValueError(f"quantization parameter s must be positive, got {s}")
    if s is not None and cap is not None and s > cap:
        raise ValueError(f"wire format {wire!r} carries s <= {cap}, got {s}")
    if s is None:
        if wire == "int4":
            # mirror the runtime: the packing wire cannot carry an exact
            # (s = infinity) f32 passthrough, so refuse to price one
            raise ValueError("wire format 'int4' packs quantized levels and "
                             "cannot carry exact (s=None) messages")
        return 32.0 * (dim + 1)  # raw f32 vector + norm word
    if wire in ("f32", "rs_ag"):
        return 32.0 * dim        # values on the wire; norm already folded in
    n_buckets = 1 if bucket is None else -(-dim // bucket)
    if wire == "elias":
        # gap-coded levels: min(worst-case, QSGD-Thm-3.2 expected) — with
        # bucketing the expectation applies per bucket (each bucket is
        # normalized by its own norm), the stream itself stays one run
        if bucket is None:
            lvl_bits = E.payload_bits(s, dim)
        else:
            lvl_bits = min(float(dim) * E.omega_max_bits(s) + E._TERM_BITS,
                           n_buckets * E.expected_code_bits(s, bucket))
        return 32.0 * n_buckets + lvl_bits
    return 32.0 * n_buckets + dim * level_bits(s, wire)


# ---------------------------------------------------------------------------
# int4 packing: two signed nibbles per int8 byte (levels in [-7, 7])
# ---------------------------------------------------------------------------
def pack_int4(levels: jax.Array) -> jax.Array:
    """Pack int levels in [-7, 7] into ceil(n/2) bytes (lo nibble first)."""
    flat = levels.reshape(-1).astype(jnp.uint8)
    if flat.shape[0] % 2:
        flat = jnp.pad(flat, (0, 1))
    lo = flat[0::2] & jnp.uint8(0x0F)
    hi = (flat[1::2] & jnp.uint8(0x0F)) << jnp.uint8(4)
    return (lo | hi).astype(jnp.int8)


def unpack_int4(packed: jax.Array, n: int) -> jax.Array:
    """Inverse of :func:`pack_int4`: -> flat int8 levels of length ``n``."""
    p = packed.reshape(-1).astype(jnp.uint8)
    lo = p & jnp.uint8(0x0F)
    hi = (p >> jnp.uint8(4)) & jnp.uint8(0x0F)
    nib = jnp.stack([lo, hi], axis=-1).reshape(-1)[:n].astype(jnp.int32)
    return jnp.where(nib > 7, nib - 16, nib).astype(jnp.int8)
