"""Codec objects: the paper's abstract quantizer (q_s, M_s) made concrete.

A codec bundles the four things every layer of the system needs from the
quantizer ``Q(·; s)``:

  encode(tensor, noise) -> (levels, norm)   stochastic quantization
  decode(levels, norm)  -> tensor           dequantization
  wire_bits(dim)        -> M_s              bits per message (cost layer)
  variance_bound(dim)   -> q_s              Assumption-1 variance constant

Instances:
  :class:`QSGDCodec`     — the paper's Assumption-1 quantizer; optional
                           per-bucket norms (QSGD bucketing, matching the
                           cost layer's ``q_dim``); backend "jnp" or
                           "pallas" (bit-identical, kernel-tiled).
  :class:`IdentityCodec` — s = ∞: exact passthrough, q_s = 0, recovering
                           PM-SGD / FedAvg / PR-SGD as special cases.

``make_codec`` is the single constructor the rest of the repo uses.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp

from . import backends as B
from . import elias as E
from . import rotation as R
from . import wire as W

__all__ = [
    "Codec", "QSGDCodec", "IdentityCodec", "RotatedQSGDCodec",
    "ErrorFeedbackCodec", "CODEC_KINDS", "make_codec",
    "variance_bound", "bits_per_message", "q_pair",
]

#: make_codec preconditioner variants ("kind" axis, orthogonal to backend/wire)
CODEC_KINDS = ("qsgd", "rotated")


def variance_bound(s: Optional[int], dim: int) -> float:
    """q_s of Assumption 1 for the QSGD quantizer: min(D/s^2, sqrt(D)/s)."""
    if s is None:
        return 0.0
    if s <= 0:
        raise ValueError(f"quantization parameter s must be positive, got {s}")
    return min(dim / s**2, math.sqrt(dim) / s)


def bits_per_message(s: Optional[int], dim: int) -> float:
    """M_s under the fixed-length "packed" wire model (monotone in s)."""
    return W.wire_bits(s, dim, wire="packed")


def q_pair(q_s0: float, q_sn: float) -> float:
    """q_{s0,sn} = q_{s0} + q_{sn} + q_{s0} q_{sn} (Theorem 1)."""
    return q_s0 + q_sn + q_s0 * q_sn


@dataclasses.dataclass(frozen=True)
class Codec:
    """Interface + shared conveniences.  ``wire`` only affects bit pricing
    and transport validation — encode/decode math is wire-independent."""

    wire: str = "packed"

    @property
    def s(self) -> Optional[int]:
        raise NotImplementedError

    def encode(self, y: jax.Array, u: jax.Array):
        raise NotImplementedError

    def decode(self, levels: jax.Array, norm: jax.Array, dtype=jnp.float32):
        raise NotImplementedError

    def wire_bits(self, dim: int) -> float:
        raise NotImplementedError

    def variance_bound(self, dim: int) -> float:
        raise NotImplementedError

    @property
    def is_identity(self) -> bool:
        return self.s is None

    def quantize_dequantize(self, y: jax.Array, key: jax.Array) -> jax.Array:
        """Q(y; s) as a value (the paper's math; jax.random noise)."""
        u = jax.random.uniform(key, y.shape, jnp.float32)
        lvl, norm = self.encode(y, u)
        return self.decode(lvl, norm, dtype=y.dtype)

    # -- the one-pass encode pipeline -----------------------------------
    def encode_payload(self, y: jax.Array, u: jax.Array):
        """Encode straight to the *wire payload* of ``self.wire`` in one
        pass: -> (payload, norm, nbits).

          wire "int4"  — packed nibble bytes (fused Pallas kernel on the
                         pallas backend; single-jit-fusable jnp otherwise);
          wire "elias" — Elias-omega coded ``uint32`` words (payload is
                         backend-independent: levels are bit-identical
                         across backends and the coder is shared), nbits =
                         the realized stream length (traced);
          otherwise    — the levels themselves in their wire container.

        ``nbits`` is the payload's realized size on the wire (container
        bits; excludes the 32-bit norm words).  ``decode_payload`` is the
        exact inverse back to the dequantized tensor.
        """
        raise NotImplementedError

    def decode_payload(self, payload: jax.Array, norm: jax.Array, n: int,
                       dtype=jnp.float32):
        """Inverse of :meth:`encode_payload`: payload -> dequantized tensor
        of ``n`` flat coordinates."""
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class IdentityCodec(Codec):
    """s = ∞: exact communication, q_s = 0, raw f32 on the wire."""

    @property
    def s(self) -> Optional[int]:
        return None

    def encode(self, y, u):
        return y, jnp.float32(1.0)

    def decode(self, levels, norm, dtype=jnp.float32):
        return levels.astype(dtype)

    def wire_bits(self, dim: int) -> float:
        return W.wire_bits(None, dim, wire=self.wire)

    def variance_bound(self, dim: int) -> float:
        return 0.0

    def quantize_dequantize(self, y, key):
        return y

    def encode_payload(self, y, u):
        return y, jnp.float32(1.0), 32 * y.size

    def decode_payload(self, payload, norm, n, dtype=jnp.float32):
        return payload.reshape(-1)[:n].astype(dtype)


@dataclasses.dataclass(frozen=True)
class QSGDCodec(Codec):
    """The Assumption-1 QSGD quantizer with s levels.

    Attributes:
      s_levels: quantization parameter s (>= 1).
      wire: pricing/transport format (see :mod:`repro.compress.wire`).
      bucket: per-bucket-norm quantization — the flattened input is split
        into buckets of this many coordinates, each normalized by its own
        L2 norm (Assumption 1 then holds per bucket with D = bucket).
        ``None`` = one norm for the whole tensor.
      backend: "jnp" reference math or "pallas" TPU kernels (s <= 127,
        whole-tensor norm); verified bit-identical.
    """

    s_levels: int = 1
    bucket: Optional[int] = None
    backend: str = "jnp"
    interpret: Optional[bool] = None  # Pallas interpreter override

    def __post_init__(self):
        if self.s_levels <= 0:
            raise ValueError(f"s must be positive, got {self.s_levels}")
        cap = W.wire_max_s(self.wire)
        if cap is not None and self.s_levels > cap:
            raise ValueError(f"wire {self.wire!r} carries s <= {cap}, "
                             f"got {self.s_levels}")
        if self.backend not in ("jnp", "pallas"):
            raise ValueError(f"unknown backend {self.backend!r}")
        if self.backend == "pallas" and self.bucket is not None:
            raise ValueError("the Pallas backend computes whole-tensor norms")
        if self.backend == "pallas" and self.s_levels > 127:
            raise ValueError("the Pallas backend stores levels as int8 "
                             f"(s <= 127), got {self.s_levels}")

    @property
    def s(self) -> int:
        return self.s_levels

    @property
    def level_dtype(self):
        return B.level_dtype(self.s_levels)

    # -- encode / decode -------------------------------------------------
    def encode(self, y: jax.Array, u: jax.Array):
        """-> (levels shaped like y, norm) — norm is a scalar, or (n_buckets,)
        when ``bucket`` is set."""
        if self.backend == "pallas":
            return B.encode_pallas(y, self.s_levels, u, self.interpret)
        if self.bucket is not None:
            lvl, norms = B.encode_bucketed(y, self.s_levels, u, self.bucket)
            return lvl.astype(self.level_dtype), norms
        lvl, norm = B.encode_jnp(y, self.s_levels, u)
        return lvl.astype(self.level_dtype), norm

    def decode(self, levels: jax.Array, norm: jax.Array, dtype=jnp.float32):
        if self.bucket is not None and norm.ndim == 1:
            return B.decode_bucketed(levels, norm, self.s_levels, dtype,
                                     self.bucket)
        return B.decode_jnp(levels, norm, self.s_levels, dtype)

    def decode_apply(self, x: jax.Array, levels: jax.Array, norm: jax.Array,
                     gamma) -> jax.Array:
        """x + gamma * decode(levels) — kernel-fused on the Pallas backend."""
        if self.backend == "pallas":
            return B.decode_apply_pallas(x, levels, norm, self.s_levels,
                                         gamma, self.interpret)
        upd = gamma * self.decode(levels, norm)
        return (x.astype(jnp.float32) + upd).astype(x.dtype)

    # -- the one-pass encode pipeline ------------------------------------
    def encode_payload(self, y: jax.Array, u: jax.Array):
        if self.wire == "int4":
            if self.backend == "pallas":
                packed, norm = B.encode_fused(y, self.s_levels, u, pack=True,
                                              interpret=self.interpret)
            elif self.bucket is not None:
                lvl, norm = B.encode_bucketed(y, self.s_levels, u,
                                              self.bucket)
                packed = W.pack_int4(lvl.astype(jnp.int8))[:(y.size + 1) // 2]
            else:
                packed, norm = B.encode_fused_jnp(y, self.s_levels, u,
                                                  pack=True)
            return packed, norm, 8 * packed.size
        lvl, norm = self.encode(y, u)
        if self.wire == "elias":
            words, nbits = E.encode_levels(lvl.astype(jnp.int8))
            return words, norm, nbits
        return lvl, norm, int(W.level_bits(self.s_levels, self.wire)
                              * lvl.size)

    def decode_payload(self, payload: jax.Array, norm: jax.Array, n: int,
                       dtype=jnp.float32):
        if self.wire == "int4":
            lvl = W.unpack_int4(payload, n)
        elif self.wire == "elias":
            lvl = E.decode_levels(payload, n)
        else:
            lvl = payload.reshape(-1)[:n]
        if self.bucket is not None and norm.ndim == 1:
            return B.decode_bucketed(lvl, norm, self.s_levels, dtype,
                                     self.bucket)
        return B.decode_jnp(lvl, norm, self.s_levels, dtype)

    # -- cost-layer views ------------------------------------------------
    def wire_bits(self, dim: int) -> float:
        return W.wire_bits(self.s_levels, dim, wire=self.wire,
                           bucket=self.bucket)

    def variance_bound(self, dim: int) -> float:
        eff = dim if self.bucket is None else min(self.bucket, dim)
        return variance_bound(self.s_levels, eff)


@dataclasses.dataclass(frozen=True)
class RotatedQSGDCodec(QSGDCodec):
    """Rotation-preconditioned QSGD (GQFedWAvg's quantizer).

    Encodes ``R y`` with ``R = (1/sqrt(d)) H_d D_sigma`` the randomized
    Hadamard rotation (:mod:`repro.compress.rotation`), decodes with the
    exact inverse ``R^T``.  ``R`` is orthonormal, so Assumption 1 holds for
    the rotated message verbatim; the preconditioner makes the quantizer's
    input near-isotropic (no coordinate can dominate the post-rotation
    norm), so realized error is input-structure-independent and the
    dynamic range collapses to ~sqrt(2 log d / d) of the norm.

    Shape contract: the rotation pads to ``d' = next_pow2(dim)``, so
    ``encode`` returns levels of length ``d'`` (that is the message — the
    wire moves the padded levels plus the 32-bit rotation seed, and
    ``wire_bits`` prices exactly that) and ``decode`` returns the
    unrotated padded vector; ``quantize_dequantize`` round-trips the
    caller's exact shape.  Per-bucket norms are not supported (the rotation
    already isotropizes the message).

    Backends share the rotation code verbatim and differ only in the QSGD
    level assignment ("jnp" reference vs the Pallas kernels) — verified
    bit-identical in ``tests/unit/test_rotation_codec.py``.
    """

    seed: int = 0

    def __post_init__(self):
        super().__post_init__()
        if self.bucket is not None:
            raise ValueError("rotation preconditioning and per-bucket norms "
                             "are mutually exclusive (the rotation already "
                             "isotropizes the message)")

    def padded_dim(self, dim: int) -> int:
        return R.next_pow2(dim)

    # -- encode / decode -------------------------------------------------
    def encode(self, y: jax.Array, u: jax.Array):
        """``u`` must be uniform noise of shape ``(padded_dim(y.size),)``
        — the rotated message's length (``quantize_dequantize`` handles
        this; direct callers padding by hand get a shape error from the
        level assignment otherwise)."""
        r = R.rotate(y, self.seed)
        if self.backend == "pallas":
            return B.encode_pallas(r, self.s_levels, u, self.interpret)
        lvl, norm = B.encode_jnp(r, self.s_levels, u)
        return lvl.astype(self.level_dtype), norm

    def decode(self, levels: jax.Array, norm: jax.Array, dtype=jnp.float32):
        dq = B.decode_jnp(levels, norm, self.s_levels, jnp.float32)
        return R.unrotate(dq, self.seed, dq.shape[0]).astype(dtype)

    def decode_apply(self, x: jax.Array, levels: jax.Array, norm: jax.Array,
                     gamma) -> jax.Array:
        upd = gamma * self.decode(levels, norm)[:x.size].reshape(x.shape)
        return (x.astype(jnp.float32) + upd).astype(x.dtype)

    def quantize_dequantize(self, y: jax.Array, key: jax.Array) -> jax.Array:
        u = jax.random.uniform(key, (self.padded_dim(y.size),), jnp.float32)
        lvl, norm = self.encode(y, u)
        out = self.decode(lvl, norm)
        return out[:y.size].reshape(y.shape).astype(y.dtype)

    # -- the one-pass encode pipeline ------------------------------------
    def encode_payload(self, y: jax.Array, u: jax.Array):
        """Same contract as the base, on the *rotated padded* message: the
        fused rotate+encode kernel folds the Hadamard preconditioner into
        the quantize pass, so the rotation costs no extra memory sweep."""
        if self.wire == "int4":
            if self.backend == "pallas":
                packed, norm = B.encode_rotated_fused(
                    y, self.s_levels, u, self.seed, pack=True,
                    interpret=self.interpret)
            else:
                packed, norm = B.encode_fused_jnp(
                    R.rotate(y, self.seed), self.s_levels, u, pack=True)
            return packed, norm, 8 * packed.size
        lvl, norm = self.encode(y, u)
        if self.wire == "elias":
            words, nbits = E.encode_levels(lvl.astype(jnp.int8))
            return words, norm, nbits
        return lvl, norm, int(W.level_bits(self.s_levels, self.wire)
                              * lvl.size)

    def decode_payload(self, payload: jax.Array, norm: jax.Array, n: int,
                       dtype=jnp.float32):
        """``n`` is the rotated message length (``padded_dim`` of the
        original); returns the unrotated padded vector like :meth:`decode`."""
        if self.wire == "int4":
            lvl = W.unpack_int4(payload, n)
        elif self.wire == "elias":
            lvl = E.decode_levels(payload, n)
        else:
            lvl = payload.reshape(-1)[:n]
        return self.decode(lvl, norm, dtype)

    # -- cost-layer views ------------------------------------------------
    def wire_bits(self, dim: int) -> float:
        """The padded levels plus the 32-bit rotation seed — what actually
        travels, so ``EdgeSystem.M_s`` and the runtime agree."""
        return W.wire_bits(self.s_levels, self.padded_dim(dim),
                           wire=self.wire) + 32.0

    def variance_bound(self, dim: int) -> float:
        """Assumption 1 at the rotated message's dimension (the rotation is
        orthonormal, so the bound applies to the padded vector as-is)."""
        return variance_bound(self.s_levels, self.padded_dim(dim))


@dataclasses.dataclass(frozen=True)
class ErrorFeedbackCodec:
    """Memory-compensated (EF-)quantization around any inner codec.

    Encodes the *error-compensated* message ``y + e`` and carries the new
    residual ``e' = (y + e) - decode(encode(y + e))`` as explicit state —
    codecs stay frozen/stateless, so the caller threads ``state`` through
    (``init_state`` → ``encode``/``quantize_dequantize`` → next round).  The
    telescoping identity ``sum_t decode_t = sum_t y_t + e_0 - e_T`` makes
    the *cumulative* applied update track the true sum to within one
    residual — the contract ``tests/unit/test_rotation_codec.py`` asserts.

    Legality note: EF-compensated quantization is **biased** per message —
    Assumption 1's unbiasedness fails, so Theorem 1 (and therefore every
    shipped family's convergence block: ``genqsgd``/``pm``/``fa``/``pr``
    and ``gqfedwavg``) does not cover it.  ``variance_bound`` raises to
    keep the optimizer from ever pricing ``q_s`` for an EF codec; use it
    for runtime experimentation, not inside ``Scenario.optimize``.
    """

    inner: Codec

    @property
    def s(self) -> Optional[int]:
        return self.inner.s

    @property
    def wire(self) -> str:
        return self.inner.wire

    def init_state(self, dim: int) -> jax.Array:
        """The zero residual memory (f32 vector of the message dimension)."""
        return jnp.zeros(int(dim), jnp.float32)

    def encode(self, y: jax.Array, u: jax.Array, state: jax.Array):
        """-> (levels, norm, new_state); ``state`` shaped like the flat y."""
        comp = y.astype(jnp.float32) + state.reshape(y.shape)
        lvl, norm = self.inner.encode(comp, u)
        # rotated inners decode to the padded flat message; slice flat
        sent = self.inner.decode(lvl, norm).reshape(-1)[:y.size] \
            .reshape(y.shape)
        return lvl, norm, (comp - sent).reshape(-1)

    def decode(self, levels: jax.Array, norm: jax.Array, dtype=jnp.float32):
        return self.inner.decode(levels, norm, dtype)

    def quantize_dequantize(self, y: jax.Array, key: jax.Array,
                            state: jax.Array):
        """-> (value, new_state): the stateful twin of the codec method."""
        comp = y.astype(jnp.float32) + state.reshape(y.shape)
        sent = self.inner.quantize_dequantize(comp, key)
        return sent, (comp - sent).reshape(-1)

    def wire_bits(self, dim: int) -> float:
        return self.inner.wire_bits(dim)

    def variance_bound(self, dim: int) -> float:
        raise TypeError(
            "error-feedback quantization is biased: Assumption 1's q_s does "
            "not exist, so no shipped family's convergence block may price "
            "it — run it in the runtime, keep the optimizer on the inner "
            "codec")


@functools.lru_cache(maxsize=1024)
def _make_codec_cached(s: Optional[int], wire: str, bucket: Optional[int],
                       backend: str, interpret: Optional[bool], kind: str,
                       seed: int) -> Codec:
    if kind not in CODEC_KINDS:
        raise ValueError(f"unknown codec kind {kind!r}; "
                         f"expected one of {CODEC_KINDS}")
    if s is None:
        # exact communication needs no preconditioner either way
        return IdentityCodec(wire=wire)
    if kind == "rotated":
        return RotatedQSGDCodec(wire=wire, s_levels=int(s), bucket=bucket,
                                backend=backend, interpret=interpret,
                                seed=int(seed))
    return QSGDCodec(wire=wire, s_levels=int(s), bucket=bucket,
                     backend=backend, interpret=interpret)


def make_codec(s: Optional[int], wire: str = "packed",
               bucket: Optional[int] = None, backend: str = "jnp",
               interpret: Optional[bool] = None, kind: str = "qsgd",
               seed: int = 0) -> Codec:
    """The one constructor: s=None -> IdentityCodec, else QSGDCodec (or the
    rotation-preconditioned variant for ``kind="rotated"``).

    Codecs are frozen/stateless, so instances are memoized — the cost layer
    reconstructs them inside the GIA inner loop and must not pay object
    churn there.
    """
    try:
        hash((s, wire, bucket, backend, interpret, kind, seed))
    except TypeError:  # unhashable argument: build fresh, uncached
        return _make_codec_cached.__wrapped__(s, wire, bucket, backend,
                                              interpret, kind, seed)
    return _make_codec_cached(s, wire, bucket, backend, interpret, kind, seed)
