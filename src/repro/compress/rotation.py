"""Randomized-Hadamard rotation preconditioner (GQFedWAvg's quantizer).

Pre-rotating with a randomized Hadamard transform ``R = (1/sqrt(d)) H_d
D_sigma`` (``H_d`` the Walsh-Hadamard matrix, ``D_sigma`` random signs)
spreads every input's energy evenly across coordinates before quantization
and is undone exactly after dequantization.  ``R`` is orthonormal, so norms
(and therefore Assumption 1's per-message analysis) are preserved; what the
preconditioner buys is *input-independence*: the quantizer always sees a
near-isotropic message (max coordinate ~ sqrt(2 log d / d) of the norm
w.h.p.), so realized error concentrates at the dense-case level regardless
of input structure and the dynamic range that fixed-grid wire formats pay
for collapses by ~sqrt(d / log d).

Implementation notes:

  * ``fwht`` is the standard O(d log d) butterfly on a power-of-2 length;
    inputs are zero-padded to ``next_pow2(dim)`` (padding is part of the
    wire format — the cost layer prices the padded message, see
    ``RotatedQSGDCodec.wire_bits``).
  * The sign vector derives from a 32-bit seed through the same murmur3
    finalizer the SPMD runtime uses for quantization noise — a pure
    elementwise index hash, so encode and decode regenerate identical signs
    from the seed alone (the seed is the only rotation state on the wire:
    32 bits).
  * Both codec backends ("jnp" reference and "pallas" kernels) share this
    exact rotation code and differ only in the QSGD level assignment they
    delegate to, which keeps them bit-identical end to end.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["next_pow2", "rademacher", "fwht", "rotate", "unrotate"]


def next_pow2(n: int) -> int:
    """Smallest power of two >= n (>= 1)."""
    if n <= 1:
        return 1
    return 1 << (int(n) - 1).bit_length()


def _mix32(z: jax.Array) -> jax.Array:
    z = (z ^ (z >> 16)) * jnp.uint32(0x85EBCA6B)
    z = (z ^ (z >> 13)) * jnp.uint32(0xC2B2AE35)
    return z ^ (z >> 16)


def rademacher(n: int, seed: int) -> jax.Array:
    """Deterministic ±1 f32 signs of length n from a 32-bit seed."""
    idx = jnp.arange(n, dtype=jnp.uint32)
    z = _mix32(idx * jnp.uint32(0x9E3779B9) + jnp.uint32(seed & 0xFFFFFFFF))
    return jnp.where((z & jnp.uint32(1)) == 0, jnp.float32(1.0),
                     jnp.float32(-1.0))


def fwht(x: jax.Array) -> jax.Array:
    """Unnormalized Walsh-Hadamard transform of a 1-D power-of-2 vector."""
    d = x.shape[0]
    h = 1
    while h < d:
        x = x.reshape(d // (2 * h), 2, h)
        a, b = x[:, 0, :], x[:, 1, :]
        x = jnp.stack([a + b, a - b], axis=1).reshape(d)
        h *= 2
    return x


def rotate(y: jax.Array, seed: int) -> jax.Array:
    """R y for the flattened input: pad to pow2, sign-flip, orthonormal WHT.

    Returns the rotated vector of length ``next_pow2(y.size)``.
    """
    flat = y.reshape(-1).astype(jnp.float32)
    d = next_pow2(flat.shape[0])
    flat = jnp.pad(flat, (0, d - flat.shape[0]))
    return fwht(flat * rademacher(d, seed)) * jnp.float32(d ** -0.5)


def unrotate(v: jax.Array, seed: int, n: int) -> jax.Array:
    """R^T v: the exact inverse of :func:`rotate`, sliced back to length n."""
    d = v.shape[0]
    out = fwht(v.astype(jnp.float32)) * jnp.float32(d ** -0.5)
    return (out * rademacher(d, seed))[:n]
