"""Convergence-error expressions of GenQSGD (Theorem 1 + Lemmas 1-3).

All functions are NumPy-float implementations (they feed the offline GP-based
parameter optimizer, not the device-side training step) and accept vectorized
``K_n``.

Notation:
  K0        : number of global iterations
  Kn        : array (N,) of per-worker local iteration counts
  B         : mini-batch size
  gammas    : step-size sequence (K0,)
  c = (c1, c2, c3, c4) with
      c1 = 2 N (f(x^(1)) - f*),  c2 = 4 G^2 L^2,  c3 = L sigma^2 / N,
      c4 = 2 L G^2                                    (Theorem 1)
  q_pairs   : array (N,) of q_{s0,sn} = q_{s0} + q_{sn} + q_{s0} q_{sn}
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["MLProblemConstants", "coefficients", "c_arbitrary", "c_constant",
           "c_exponential", "c_diminishing", "c_m"]


def _weighted_blocks(Kn: np.ndarray, q_pairs: np.ndarray, eps):
    """``(sum_n eps_n K_n, sum_n q_n (eps_n K_n)^2)`` — the two aggregation
    blocks of the bound.  ``eps=None`` (uniform weights) takes the exact
    historical arithmetic, so GenQSGD results stay bitwise unchanged;
    weighted families (``eps_n = N w_n``, :mod:`repro.families`) reweight
    the effective local work and the quantization variance per worker."""
    if eps is None:
        return Kn.sum(), (q_pairs * Kn**2).sum()
    e = np.asarray(eps, dtype=np.float64)
    eK = e * Kn
    return eK.sum(), (q_pairs * eK**2).sum()


@dataclasses.dataclass(frozen=True)
class MLProblemConstants:
    """Pre-training estimates describing the ML problem (Sec. IV-A)."""
    L: float            # gradient Lipschitz constant (Assumption 3)
    sigma: float        # stochastic-gradient std bound (Assumption 4)
    G: float            # second-moment bound (Assumption 5)
    f_gap: float        # f(x^(1)) - lower bound on f*
    N: int              # number of workers

    @property
    def c(self):
        return coefficients(self.L, self.sigma, self.G, self.f_gap, self.N)


def coefficients(L: float, sigma: float, G: float, f_gap: float, N: int):
    c1 = 2.0 * N * f_gap
    c2 = 4.0 * G**2 * L**2
    c3 = L * sigma**2 / N
    c4 = 2.0 * L * G**2
    return c1, c2, c3, c4


def c_arbitrary(K0, Kn, B, gammas, c, q_pairs, eps=None) -> float:
    """C_A(K, B, Gamma) — eq. (9), arbitrary step-size sequence."""
    c1, c2, c3, c4 = c
    Kn = np.asarray(Kn, dtype=np.float64)
    g = np.asarray(gammas, dtype=np.float64)
    assert g.shape[0] == int(round(K0)), (g.shape, K0)
    q_pairs = np.asarray(q_pairs, dtype=np.float64)
    sum_g = g.sum()
    sum_g2 = (g**2).sum()
    sum_g3 = (g**3).sum()
    sum_K, qK2 = _weighted_blocks(Kn, q_pairs, eps)
    kmax = Kn.max()
    t1 = c1 / (sum_K * sum_g)
    t2 = c2 * kmax**2 * sum_g3 / sum_g
    t3 = c3 * sum_g2 / (B * sum_g)
    t4 = c4 * qK2 * sum_g2 / (sum_K * sum_g)
    return float(t1 + t2 + t3 + t4)


def c_constant(K0, Kn, B, gamma_c, c, q_pairs, eps=None):
    """C_C — eq. (11).  Broadcasts over an ndarray ``K0`` (the feasibility
    grid search evaluates whole K0 ladders at once); scalar in, float out."""
    c1, c2, c3, c4 = c
    Kn = np.asarray(Kn, dtype=np.float64)
    q_pairs = np.asarray(q_pairs, dtype=np.float64)
    sum_K, qK2 = _weighted_blocks(Kn, q_pairs, eps)
    out = (
        c1 / (gamma_c * K0 * sum_K)
        + c2 * gamma_c**2 * Kn.max() ** 2
        + c3 * gamma_c / B
        + c4 * gamma_c * qK2 / sum_K
    )
    return out if np.ndim(K0) else float(out)


def c_exponential(K0, Kn, B, gamma_e, rho_e, c, q_pairs, eps=None):
    """C_E — eq. (13).  Broadcasts over an ndarray ``K0``."""
    c1, c2, c3, c4 = c
    Kn = np.asarray(Kn, dtype=np.float64)
    q_pairs = np.asarray(q_pairs, dtype=np.float64)
    a1 = (1.0 - rho_e) / gamma_e
    a2 = gamma_e**2 / (1.0 + rho_e + rho_e**2)
    a3 = gamma_e / (1.0 + rho_e)
    r1 = rho_e**K0
    sum_K, qK2 = _weighted_blocks(Kn, q_pairs, eps)
    out = (
        a1 * c1 / ((1.0 - r1) * sum_K)
        + a2 * c2 * (1.0 - rho_e ** (3 * K0)) / (1.0 - r1) * Kn.max() ** 2
        + a3 * (1.0 - rho_e ** (2 * K0)) / (1.0 - r1)
        * (c3 / B + c4 * qK2 / sum_K)
    )
    return out if np.ndim(K0) else float(out)


def c_diminishing(K0, Kn, B, gamma_d, rho_d, c, q_pairs, eps=None):
    """C_D — eq. (16) (upper bound used for optimization).  Broadcasts over
    an ndarray ``K0``."""
    c1, c2, c3, c4 = c
    Kn = np.asarray(Kn, dtype=np.float64)
    q_pairs = np.asarray(q_pairs, dtype=np.float64)
    b1 = 1.0 / (rho_d * gamma_d)
    b2 = (rho_d**2 * gamma_d**2) / (rho_d + 1.0) ** 3 \
        + (rho_d**2 * gamma_d**2) / (2.0 * (rho_d + 1.0) ** 2)
    b3 = rho_d * gamma_d / (rho_d + 1.0) ** 2 + rho_d * gamma_d / (rho_d + 1.0)
    logt = np.log((K0 + rho_d + 1.0) / (rho_d + 1.0))
    sum_K, qK2 = _weighted_blocks(Kn, q_pairs, eps)
    out = (
        b1 * c1 / (logt * sum_K)
        + b2 * c2 * Kn.max() ** 2 / logt
        + b3 * c3 / (B * logt)
        + b3 * c4 * qK2 / (logt * sum_K)
    )
    return out if np.ndim(K0) else float(out)


def c_m(m: str, K0, Kn, B, rule, c, q_pairs, eps=None) -> float:
    """Dispatch on the paper's m in {A, C, E, D}."""
    if m == "C":
        return c_constant(K0, Kn, B, rule.gamma, c, q_pairs, eps)
    if m == "E":
        return c_exponential(K0, Kn, B, rule.gamma, rule.rho, c, q_pairs, eps)
    if m == "D":
        return c_diminishing(K0, Kn, B, rule.gamma, rule.rho, c, q_pairs, eps)
    if m == "A":
        return c_arbitrary(K0, Kn, B, rule.sequence(int(round(K0))), c,
                           q_pairs, eps)
    raise ValueError(f"unknown convergence measure m={m!r}")
