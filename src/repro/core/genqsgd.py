"""GenQSGD (Algorithm 1) as a composable JAX module.

This is the single-process reference implementation (the paper's algorithm,
exactly): the N workers are carried as a leading ``vmap`` axis and the server
aggregation is a mean across it (or, for weighted families like GQFedWAvg, a
general weighted sum — see :mod:`repro.families`).  The multi-device SPMD
version that maps workers onto the ``fl`` mesh axis lives in
:mod:`repro.fed.runtime` and is tested for equivalence against this one.

Heterogeneous local iteration counts ``K_n`` are handled the way the paper's
analysis does (eqs. (6)-(8)): every worker scans ``K_max = max_n K_n`` local
steps and workers whose ``K_n`` is exhausted perform *virtual* (masked, no-op)
updates.

Quantization follows Algorithm 1 lines 3-10:
  * worker n sends  Q((x_n^{(k0,K_n)} - x̂^{(k0)}) / γ^{(k0)}; s_n)     (5)
  * the server averages these into Δx̂^{(k0)} and multicasts Q(Δx̂; s_0)
  * every node recovers x̂^{(k0+1)} = x̂^{(k0)} + γ^{(k0)} Q(Δx̂; s_0)   (3)

Both quantizers act on the *flattened* D-dimensional model delta (the paper's
vectors live in R^D).
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..compress import make_codec
from ..obs import REGISTRY as _METRICS
from ..obs.metrics import GLOBAL_SWITCH as _OBS_ON
from .step_rules import StepRule

__all__ = ["GenQSGDConfig", "GenQSGD", "flatten_like", "unflatten_like"]

Params = object  # pytree
LossFn = Callable[[Params, object], jax.Array]  # (params, batch) -> scalar


def flatten_like(tree):
    """Ravel a pytree of arrays into a single f32 vector + static unravel info."""
    leaves = jax.tree.leaves(tree)
    flat = jnp.concatenate([l.reshape(-1).astype(jnp.float32) for l in leaves])
    return flat


def unflatten_like(flat, tree):
    leaves, treedef = jax.tree.flatten(tree)
    out, off = [], 0
    for l in leaves:
        n = int(np.prod(l.shape)) if l.shape else 1
        out.append(flat[off:off + n].reshape(l.shape).astype(l.dtype))
        off += n
    return jax.tree.unflatten(treedef, out)


@dataclasses.dataclass(frozen=True)
class GenQSGDConfig:
    """Algorithm parameters (K, B, Γ) + quantizer parameters (s_0, s_n).

    The family hooks (:mod:`repro.families`) ride along as plain fields:
    ``agg_weights`` turns the server mean into a general weighted
    aggregation, ``momentum``/``normalize`` select GQFedWAvg's normalized
    momentum local update, ``codec_kind`` the quantizer preconditioner.
    The defaults reproduce GenQSGD (Algorithm 1) exactly.
    """
    K0: int                      # global iterations
    Kn: Tuple[int, ...]          # per-worker local iterations (len N)
    B: int                       # mini-batch size
    step_rule: StepRule          # Γ generator
    s0: Optional[int] = None     # server quantizer (None = s = ∞)
    sn: Optional[Sequence[Optional[int]]] = None  # per-worker quantizers
    bucket: Optional[int] = None  # per-bucket-norm quantization (q_dim)
    agg_weights: Optional[Tuple[float, ...]] = None  # w_n (None = mean)
    momentum: float = 0.0        # local-update momentum beta
    normalize: bool = False      # normalized (unit-direction) local updates
    codec_kind: str = "qsgd"     # repro.compress.make_codec kind
    sampling_S: Optional[int] = None  # per-round cohort size (None = full)
    sampling_p: Optional[Tuple[float, ...]] = None  # base probs (None = unif)
    seed: Optional[int] = None   # cohort/fault rng seed (None = OS entropy)
    faults: Optional[object] = None  # repro.faults.FaultSpec (None = no
                                     # faults — the historical path, bitwise)

    def __post_init__(self):
        from ..families import check_agg_weights, check_momentum  # cycle
        if self.agg_weights is not None:
            object.__setattr__(self, "agg_weights",
                               check_agg_weights(self.agg_weights,
                                                 len(self.Kn)))
        check_momentum(self.momentum)
        if self.sampling_p is not None and self.sampling_S is None:
            raise ValueError("sampling_p given without sampling_S")
        if self.sampling_S is not None:
            from ..sampling.base import check_probs  # cycle
            S = int(self.sampling_S)
            if not 1 <= S <= self.N:
                raise ValueError(f"sampling_S={S} outside [1, N={self.N}]")
            object.__setattr__(self, "sampling_S", S)
            if self.sampling_p is not None:
                p = check_probs(self.sampling_p, self.N)
                if S * max(p) > 1.0 + 1e-9:
                    raise ValueError(
                        f"inclusion probability S*max(p)={S * max(p):.4g} "
                        f"exceeds 1")
                object.__setattr__(self, "sampling_p", p)
        if self.faults is not None:
            from ..faults import FaultSpec  # cycle
            if not isinstance(self.faults, FaultSpec):
                raise TypeError(f"faults must be a repro.faults.FaultSpec, "
                                f"got {type(self.faults)}")
            if self.faults.N != self.N:
                raise ValueError(f"FaultSpec describes {self.faults.N} "
                                 f"workers, config has {self.N}")

    @property
    def N(self) -> int:
        return len(self.Kn)

    @property
    def K_max(self) -> int:
        return int(max(self.Kn))

    def worker_s(self) -> Sequence[Optional[int]]:
        return self.sn if self.sn is not None else [None] * self.N

    def homogeneous_sn(self) -> Optional[int]:
        ss = set(self.worker_s())
        if len(ss) != 1:
            raise ValueError("workers have heterogeneous quantizers")
        return next(iter(ss))


class GenQSGD:
    """Bundles the jitted round function and the driver loop.

    Args:
      loss_fn: ``loss_fn(params, batch) -> scalar``; ``batch`` is whatever the
        sampler yields.
      sample_fn: ``sample_fn(worker_data, key, B) -> batch`` — draws one
        mini-batch from a *single worker's* local dataset (Assumption 2 IID).
      config: the (K, B, Γ, s) parameterization.
    """

    def __init__(self, loss_fn: LossFn, sample_fn, config: GenQSGDConfig):
        self.loss_fn = loss_fn
        self.sample_fn = sample_fn
        self.cfg = config
        self._round = jax.jit(self._round_impl)

    # ------------------------------------------------------------------
    def _local_train(self, x_hat, worker_data, key, gamma, k_n):
        """K_max masked local steps for ONE worker.

        Plain mini-batch SGD by default; with ``momentum``/``normalize``
        set (GQFedWAvg) each active step updates a momentum buffer
        ``v ← β v + (1-β) g`` and moves along ``v`` (unit-normalized over
        the whole model when ``normalize``).  Virtual (masked) steps leave
        both ``x`` and ``v`` untouched, as eqs. (6)-(8) require.
        """
        cfg = self.cfg
        grad_fn = jax.grad(self.loss_fn)

        if cfg.momentum == 0.0 and not cfg.normalize:
            def body(carry, k):
                x, key = carry
                key, bkey = jax.random.split(key)
                batch = self.sample_fn(worker_data, bkey, cfg.B)
                g = grad_fn(x, batch)
                active = (k < k_n).astype(jnp.float32)
                x = jax.tree.map(
                    lambda p, gg: p - (gamma * active) * gg.astype(p.dtype),
                    x, g)
                return (x, key), None

            (x, _), _ = jax.lax.scan(body, (x_hat, key),
                                     jnp.arange(cfg.K_max))
            return x

        beta = jnp.float32(cfg.momentum)

        def body_m(carry, k):
            x, v, key = carry
            key, bkey = jax.random.split(key)
            batch = self.sample_fn(worker_data, bkey, cfg.B)
            g = grad_fn(x, batch)
            active = (k < k_n).astype(jnp.float32)
            v = jax.tree.map(
                lambda vv, gg: vv + active * (beta * vv + (1.0 - beta)
                                              * gg.astype(jnp.float32) - vv),
                v, g)
            if cfg.normalize:
                vn = jnp.sqrt(sum(jnp.sum(jnp.square(l))
                                  for l in jax.tree.leaves(v)))
                scale = (gamma * active) / jnp.maximum(vn, 1e-12)
            else:
                scale = gamma * active
            x = jax.tree.map(
                lambda p, vv: p - scale * vv.astype(p.dtype), x, v)
            return (x, v, key), None

        v0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), x_hat)
        (x, _, _), _ = jax.lax.scan(body_m, (x_hat, v0, key),
                                    jnp.arange(cfg.K_max))
        return x

    def _round_impl(self, x_hat, data, key, gamma, u=None):
        """One global iteration (Algorithm 1, lines 3-10).

        ``data`` is a pytree whose leaves have leading axis N (per-worker
        shards).  ``u`` (length-N, only under client sampling) replaces the
        server aggregation with the Horvitz-Thompson weighted sum
        ``sum_n u_n d_n`` — ``u_n = mask_n w_n / pi_n`` zeroes workers
        outside the round's cohort and reweights the rest so the sampled
        round is an unbiased estimate of the full one.
        """
        cfg = self.cfg
        keys = jax.random.split(key, cfg.N + 1)
        wkeys, skey = keys[:-1], keys[-1]
        k_n = jnp.asarray(cfg.Kn)

        local = jax.vmap(self._local_train, in_axes=(None, 0, 0, None, 0))
        x_workers = local(x_hat, data, wkeys, gamma, k_n)

        # (5): per-worker quantized normalized deltas, then the server mean.
        flat_hat = flatten_like(x_hat)

        def worker_delta(xw, wkey, codec):
            d = (flatten_like(xw) - flat_hat) / gamma
            return codec.quantize_dequantize(d, wkey)

        codecs = [make_codec(s, bucket=cfg.bucket, kind=cfg.codec_kind)
                  for s in cfg.worker_s()]
        if len(set(codecs)) == 1:
            deltas = jax.vmap(
                lambda xw, wk: worker_delta(xw, wk, codecs[0]))(
                x_workers, wkeys)
        else:  # heterogeneous codecs: unrolled per worker
            deltas = jnp.stack([
                worker_delta(jax.tree.map(lambda l: l[i], x_workers),
                             wkeys[i], codecs[i]) for i in range(cfg.N)])
        if u is not None:  # sampled round: unbiased reweighted cohort sum
            delta_hat = jnp.tensordot(u.astype(jnp.float32), deltas, axes=1)
        elif cfg.agg_weights is None:
            delta_hat = deltas.mean(axis=0)
        else:  # general weighted aggregation (GQFedWAvg)
            w = jnp.asarray(cfg.agg_weights, jnp.float32)
            delta_hat = jnp.tensordot(w / w.sum(), deltas, axes=1)

        # (3): server quantizes the averaged update and everyone applies it.
        delta_q = make_codec(cfg.s0, bucket=cfg.bucket, kind=cfg.codec_kind) \
            .quantize_dequantize(delta_hat, skey)
        new_flat = flat_hat + gamma * delta_q
        x_new = unflatten_like(new_flat, x_hat)
        metrics = {
            "delta_norm": jnp.linalg.norm(delta_hat),
            "update_norm": gamma * jnp.linalg.norm(delta_q),
        }
        return x_new, metrics

    # ------------------------------------------------------------------
    def run(self, x0, data, key, eval_fn=None, eval_every: int = 10):
        """Full K0-round driver.  Returns (x*, history).

        Under client sampling (``cfg.sampling_S``) each round draws a
        seeded cohort (``cfg.seed``) and aggregates it with unbiased
        Horvitz-Thompson weights; ``self.cohort_trace`` records the drawn
        cohort indices per round.  Under fault injection (``cfg.faults``)
        each round additionally draws seeded faults from a *separate* rng
        stream (so the cohort sequence is unchanged by the fault model),
        excludes crashed / timed-out / corrupted workers, and divides the
        survivors' weights by their delivery probabilities — deadline-HT
        aggregation; ``self.fault_trace`` is the per-round
        :class:`~repro.faults.FaultTrace`.  Unsampled, unfaulted configs
        take the historical path verbatim.
        """
        cfg = self.cfg
        gammas = cfg.step_rule.sequence(cfg.K0)
        x = x0
        history = []
        self.cohort_trace = []
        self.fault_trace = None
        rng = (np.random.default_rng(cfg.seed)
               if cfg.sampling_S is not None else None)
        fdrv = None
        if cfg.faults is not None:
            from ..faults import FaultDriver, fault_rng  # cycle
            fdrv = FaultDriver(cfg.faults, cfg.N, cfg.agg_weights)
            frng = fault_rng(cfg.seed)
        # round metrics (repro.obs): priced from static config + host-side
        # fault/cohort records only, so the jitted round is untouched and
        # disabled runs pay one boolean check per round
        obs_on = _OBS_ON.on
        if obs_on:
            _dim = int(sum(int(np.prod(l.shape)) if l.shape else 1
                           for l in jax.tree.leaves(x0)))
            _up_bits = [make_codec(s, bucket=cfg.bucket,
                                   kind=cfg.codec_kind).wire_bits(_dim)
                        for s in cfg.worker_s()]
            _down_bits = make_codec(cfg.s0, bucket=cfg.bucket,
                                    kind=cfg.codec_kind).wire_bits(_dim)
            _round_h = _METRICS.histogram("run.round_s", backend="reference")
            _htvar_h = _METRICS.histogram("run.ht_weight_var",
                                          backend="reference")
            _bits_c = _METRICS.counter("run.wire_bits", backend="reference",
                                       codec=cfg.codec_kind)
            _rounds_c = _METRICS.counter("run.rounds", backend="reference")
        for k0 in range(cfg.K0):
            _t0 = time.perf_counter() if obs_on else 0.0
            key, rkey = jax.random.split(key)
            idx = pi = u = None
            if rng is not None:
                from ..sampling.base import cohort_weights, draw_cohort
                idx, pi = draw_cohort(rng, cfg.N, cfg.sampling_S,
                                      cfg.sampling_p)
                self.cohort_trace.append(idx)
            if fdrv is not None:
                u = fdrv.step(frng, k0, idx, pi)
            elif idx is not None:   # sampling only: the historical HT path
                u = cohort_weights(idx, pi, cfg.N, cfg.agg_weights)
            if u is not None:
                x, m = self._round(x, data, rkey, jnp.float32(gammas[k0]),
                                   jnp.asarray(u, jnp.float32))
            else:
                x, m = self._round(x, data, rkey, jnp.float32(gammas[k0]))
            if obs_on:
                # dispatch is async: this is the host loop time per round
                # (exact where eval or metric reads force a sync), never an
                # added block_until_ready — observing must not serialize
                _round_h.observe(time.perf_counter() - _t0)
                _rounds_c.inc()
                if u is not None:
                    # plain-python variance: np.var costs ~15us of ufunc
                    # dispatch for a length-N vector, which at edge-scale N
                    # would be most of the round's observability budget
                    _ul = u.tolist()
                    _mu = sum(_ul) / len(_ul)
                    _htvar_h.observe(
                        sum((v - _mu) ** 2 for v in _ul) / len(_ul))
                if fdrv is not None:
                    rec = fdrv.last   # crashed workers never reach the wire
                    senders = (rec.cohort if not rec.crashed
                               else set(rec.cohort) - set(rec.crashed))
                elif idx is not None:
                    senders = set(int(i) for i in idx)
                else:
                    senders = range(cfg.N)
                _bits_c.inc(sum(_up_bits[i] for i in senders) + _down_bits)
            if eval_fn is not None and (k0 % eval_every == 0 or k0 == cfg.K0 - 1):
                e = eval_fn(x)
                e.update({k: float(v) for k, v in m.items()})
                e["k0"] = k0
                history.append(e)
        if fdrv is not None:
            self.fault_trace = fdrv.trace()
        return x, history
