"""Time and energy cost models (Sec. IV-A, eqs. (17), (18)) for a
heterogeneous edge (or TPU-fleet) system.

    T(K, B) = K0 * ( B * max_n (C_n / F_n) * K_n
                     + C_0 / F_0
                     + max_n (M_{s_n} / r_n)
                     + M_{s_0} / r_0 )

    E(K, B) = K0 * ( B * sum_n alpha_n C_n F_n^2 K_n
                     + alpha_0 C_0 F_0^2
                     + sum_{n in N̄} p_n M_{s_n} / r_n )

The same closed forms serve two roles:
  * paper-faithful reproduction with Sec.-VII edge constants;
  * the TPU auto-tuner, re-parameterized with v5e constants via
    :func:`EdgeSystem.tpu_v5e_fleet`.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Sequence

import numpy as np

from ..compress import make_codec, q_pair

__all__ = ["EdgeSystem", "time_cost", "energy_cost"]


@dataclasses.dataclass(frozen=True)
class EdgeSystem:
    """System parameters for server (index 0) and N workers (Remark 1)."""
    # server
    F0: float          # CPU frequency (cycles/s) or FLOP/s-equivalent
    C0: float          # cycles per global model update
    p0: float          # transmit power (W)
    r0: float          # multicast rate (b/s)
    s0: Optional[int]  # server quantization parameter (None = no quantization)
    alpha0: float      # switched-capacitance factor
    # workers (arrays of length N)
    Fn: np.ndarray
    Cn: np.ndarray
    pn: np.ndarray
    rn: np.ndarray
    sn: Sequence[Optional[int]]
    alphan: np.ndarray
    # model dimension (for M_s)
    dim: int
    # quantization-bucket dimension for q_s (QSGD bucketing: per-bucket norms;
    # Assumption 1 holds per bucket exactly as per tensor).  None = whole-dim.
    q_dim: Optional[int] = None
    # wire format priced by M_s ("packed" = fixed-length code, arbitrary s;
    # "f32"/"int8"/"int4"/"rs_ag"/"elias" = the runtime's aggregation
    # transports — "elias" prices the paper's tighter Elias-coded bound,
    # min(worst-case, QSGD-Thm-3.2 expected), unbounded in s).
    wire: str = "packed"
    # codec preconditioner kind priced by M_s / q_s: "qsgd" (the paper's
    # quantizer) or "rotated" (randomized-Hadamard preconditioning —
    # GQFedWAvg's family; pow2-padded levels + 32-bit seed on the wire).
    # Scenario derives this from the algorithm family so the optimizer
    # provably prices the codec the runtime runs.
    codec_kind: str = "qsgd"
    # per-worker availability a_n in (0, 1]: the probability an attempted
    # update is usable (crash / corruption survival).  Inflates the
    # convergence variance blocks exactly like client sampling with
    # pi_n -> a_n pi_n.  None = the historical always-available arithmetic.
    # Scenario stamps this from the fault model (repro.faults).
    an: Optional[np.ndarray] = None
    # worst-case uncertainty margins: the time constraint prices
    # F_n (1 - freq_margin) and r_n (1 - rate_margin) — worst case over
    # the capability box (still posynomial: T is monotone in F_n, r_n).
    freq_margin: float = 0.0
    rate_margin: float = 0.0

    def __post_init__(self):
        for name in ("Fn", "Cn", "pn", "rn", "alphan"):
            object.__setattr__(self, name, np.asarray(getattr(self, name), dtype=np.float64))
        n = self.Fn.shape[0]
        assert all(getattr(self, k).shape == (n,) for k in ("Cn", "pn", "rn", "alphan"))
        assert len(self.sn) == n
        if self.an is not None:
            object.__setattr__(self, "an", np.asarray(self.an, np.float64))
            assert self.an.shape == (n,)
            assert np.all((self.an > 0.0) & (self.an <= 1.0)), self.an
        assert 0.0 <= self.freq_margin < 1.0, self.freq_margin
        assert 0.0 <= self.rate_margin < 1.0, self.rate_margin

    @property
    def N(self) -> int:
        return int(self.Fn.shape[0])

    # --- quantization-derived quantities (delegated to repro.compress so
    # the optimizer provably prices the same bytes the runtime sends).
    # All derived quantities are memoized (functools.cached_property writes
    # straight into __dict__, which frozen dataclasses permit): the GIA inner
    # loop reads q_pairs / comm_time on every surrogate build, and rebuilding
    # codec objects there is pure overhead.
    def codec(self, s: Optional[int]):
        return make_codec(s, wire=self.wire, bucket=self.q_dim,
                          kind=self.codec_kind)

    @functools.cached_property
    def M_s0(self) -> float:
        return self.codec(self.s0).wire_bits(self.dim)

    @functools.cached_property
    def M_sn(self) -> np.ndarray:
        return np.array([self.codec(s).wire_bits(self.dim) for s in self.sn])

    @functools.cached_property
    def q_s0(self) -> float:
        return self.codec(self.s0).variance_bound(self.dim)

    @functools.cached_property
    def q_sn(self) -> np.ndarray:
        return np.array([self.codec(s).variance_bound(self.dim)
                         for s in self.sn])

    @functools.cached_property
    def q_pairs(self) -> np.ndarray:
        """q_{s0,sn} per worker (Theorem 1)."""
        return np.array([q_pair(self.q_s0, q) for q in self.q_sn])

    # --- per-global-iteration cost pieces -------------------------------
    @functools.cached_property
    def comp_time_coeff(self) -> np.ndarray:
        """C_n / F_n — per-sample-per-local-iteration compute time."""
        return self.Cn / self.Fn

    @functools.cached_property
    def comm_time(self) -> float:
        """max_n M_{s_n}/r_n + M_{s_0}/r_0 + C_0/F_0 (the K/B-independent part)."""
        return float(np.max(self.M_sn / self.rn) + self.M_s0 / self.r0
                     + self.C0 / self.F0)

    @functools.cached_property
    def comp_time_coeff_wc(self) -> np.ndarray:
        """Worst-case ``C_n / (F_n (1 - freq_margin))``.  At zero margin
        this IS ``comp_time_coeff`` (same object — zero-margin problems
        stay bitwise identical to the historical arithmetic)."""
        if self.freq_margin == 0.0:
            return self.comp_time_coeff
        return self.Cn / (self.Fn * (1.0 - self.freq_margin))

    @functools.cached_property
    def comm_time_wc(self) -> float:
        """Worst-case ``comm_time`` with worker uplink rates derated by
        ``rate_margin`` (server multicast/compute terms stay nominal —
        the uncertainty box covers worker capabilities)."""
        if self.rate_margin == 0.0:
            return self.comm_time
        return float(np.max(self.M_sn / (self.rn * (1.0 - self.rate_margin)))
                     + self.M_s0 / self.r0 + self.C0 / self.F0)

    @functools.cached_property
    def comp_energy_coeff(self) -> np.ndarray:
        """alpha_n C_n F_n^2 — per-sample-per-local-iteration compute energy."""
        return self.alphan * self.Cn * self.Fn**2

    @functools.cached_property
    def const_energy(self) -> float:
        """alpha_0 C_0 F_0^2 + sum_{n in N̄} p_n M_{s_n}/r_n."""
        return float(self.alpha0 * self.C0 * self.F0**2
                     + self.p0 * self.M_s0 / self.r0
                     + np.sum(self.pn * self.M_sn / self.rn))

    @functools.cached_property
    def server_energy(self) -> float:
        """The worker-independent slice of ``const_energy`` — server
        compute + multicast (paid every round regardless of the cohort)."""
        return float(self.alpha0 * self.C0 * self.F0**2
                     + self.p0 * self.M_s0 / self.r0)

    @functools.cached_property
    def comm_energy_coeff(self) -> np.ndarray:
        """p_n M_{s_n} / r_n — per-worker upload energy per round (paid by
        a worker only in rounds it participates)."""
        return self.pn * self.M_sn / self.rn

    def resized(self, N: int) -> "EdgeSystem":
        """This system with ``N`` workers: per-worker arrays tiled (or
        truncated) cyclically, server parameters untouched — the knob
        ``Scenario.sweep(over={"N": ...})`` turns."""
        N = int(N)
        reps = -(-N // self.N)             # ceil(N / current N)
        return dataclasses.replace(
            self,
            Fn=np.tile(self.Fn, reps)[:N], Cn=np.tile(self.Cn, reps)[:N],
            pn=np.tile(self.pn, reps)[:N], rn=np.tile(self.rn, reps)[:N],
            sn=(list(self.sn) * reps)[:N],
            alphan=np.tile(self.alphan, reps)[:N],
            an=None if self.an is None else np.tile(self.an, reps)[:N])

    # --- canonical instantiations ---------------------------------------
    @staticmethod
    def paper_sec_vii(dim: int = 784 * 128 + 128 + 128 * 10 + 10,
                      F_ratio: float = 10.0, s_ratio: float = 1.0,
                      s0: int = 2**14, N: int = 10) -> "EdgeSystem":
        """The exact Sec.-VII system: two worker classes of 5 workers each.

        F^(1)+F^(2) = 2e9 with F^(1)/F^(2) = F_ratio;
        s^(1)+s^(2) = 2*2^14 with s^(1)/s^(2) = s_ratio.
        """
        assert N % 2 == 0
        F2 = 2e9 / (1.0 + F_ratio)
        F1 = F_ratio * F2
        sbar = 2.0**14
        s2 = 2 * sbar / (1.0 + s_ratio)
        s1 = s_ratio * s2
        half = N // 2
        Fn = np.array([F1] * half + [F2] * half)
        sn = [max(1, int(round(s1)))] * half + [max(1, int(round(s2)))] * half
        return EdgeSystem(
            F0=3e9, C0=100.0, p0=20.0, r0=7.5e7, s0=s0, alpha0=2e-28,
            Fn=Fn, Cn=np.full(N, 1e8), pn=np.full(N, 1.5),
            rn=np.full(N, 1e6), sn=sn, alphan=np.full(N, 2e-28), dim=dim)

    @staticmethod
    def tpu_v5e_fleet(dim: int, n_groups: int, chips_per_group: int,
                      s0: Optional[int] = 2**7, sn: Optional[int] = 2**7,
                      link_bw: float = 50e9 * 8, peak_flops: float = 197e12,
                      watts_per_chip: float = 200.0,
                      flops_per_sample_step: float = 1.0) -> "EdgeSystem":
        """Re-parameterize the cost models with TPU v5e fleet constants.

        Each FL "worker" is a replica group of ``chips_per_group`` chips; the
        "server" is the reduction over the slow inter-group links.  ``C_n`` is
        expressed in FLOPs (so ``F_n`` is FLOP/s) — the ratio C/F is all that
        matters to the model.
        """
        N = n_groups
        group_flops = peak_flops * chips_per_group * 0.4  # 40% MFU assumption
        return EdgeSystem(
            F0=group_flops, C0=float(2 * dim), p0=watts_per_chip * chips_per_group,
            r0=link_bw, s0=s0,
            alpha0=watts_per_chip * chips_per_group / group_flops**3,
            Fn=np.full(N, group_flops),
            Cn=np.full(N, flops_per_sample_step),
            pn=np.full(N, watts_per_chip * chips_per_group),
            rn=np.full(N, link_bw),
            sn=[sn] * N,
            alphan=np.full(N, watts_per_chip * chips_per_group / group_flops**3),
            dim=dim, q_dim=4096)


def time_cost(sys: EdgeSystem, K0, Kn, B, worst_case: bool = False):
    """T(K, B) — eq. (17).  Broadcasts over an ndarray ``K0``.

    ``worst_case=True`` prices the derated worker capabilities
    ``F_n (1 - freq_margin)`` / ``r_n (1 - rate_margin)`` — identical to
    the nominal arithmetic when the system carries zero margins.
    """
    Kn = np.asarray(Kn, dtype=np.float64)
    ct = sys.comp_time_coeff_wc if worst_case else sys.comp_time_coeff
    tau = sys.comm_time_wc if worst_case else sys.comm_time
    out = K0 * (B * np.max(ct * Kn) + tau)
    return out if np.ndim(K0) else float(out)


def energy_cost(sys: EdgeSystem, K0, Kn, B, pi=None):
    """E(K, B) — eq. (18).  Broadcasts over an ndarray ``K0``.

    ``pi`` (per-worker inclusion probabilities under client sampling)
    turns this into the *expected* energy over cohort draws: each worker's
    compute and upload terms scale by ``pi_n``.  ``pi=None`` is the
    historical full-participation arithmetic, verbatim.
    """
    Kn = np.asarray(Kn, dtype=np.float64)
    if pi is None:
        out = K0 * (B * np.sum(sys.comp_energy_coeff * Kn)
                    + sys.const_energy)
    else:
        pi = np.asarray(pi, dtype=np.float64)
        out = K0 * (B * np.sum(sys.comp_energy_coeff * pi * Kn)
                    + sys.server_energy
                    + np.sum(sys.comm_energy_coeff * pi))
    return out if np.ndim(K0) else float(out)
