"""The paper's primary contribution: GenQSGD + its convergence/cost models.

Layers:
  step_rules   — constant / exponential / diminishing Γ generators
  convergence  — C_A / C_C / C_E / C_D closed forms (Theorem 1, Lemmas 1-3)
  cost         — T(K,B), E(K,B) heterogeneous-system cost models
  genqsgd      — Algorithm 1 (single-process reference; SPMD twin in repro.fed)

The quantizer itself lives in :mod:`repro.compress` (codecs + backends +
wire formats); the (q_s, M_s) helpers are re-exported here for convenience.
"""
from ..compress import (bits_per_message, make_codec, q_pair, variance_bound)
from .step_rules import (ConstantRule, ExponentialRule, DiminishingRule,
                         StepRule, make_rule)
from .convergence import (MLProblemConstants, coefficients, c_arbitrary,
                          c_constant, c_exponential, c_diminishing, c_m)
from .cost import EdgeSystem, time_cost, energy_cost
from .genqsgd import GenQSGD, GenQSGDConfig, flatten_like, unflatten_like
