"""Step-size rules (Sec. III-B): constant (10), exponential (12), diminishing (15)."""
from __future__ import annotations

import dataclasses
from typing import Union

import numpy as np

__all__ = ["ConstantRule", "ExponentialRule", "DiminishingRule", "StepRule",
           "make_rule"]


@dataclasses.dataclass(frozen=True)
class ConstantRule:
    """gamma^(k0) = gamma_c  (eq. 10)."""
    gamma: float
    name = "C"

    def __call__(self, k0: np.ndarray | int):
        return np.broadcast_to(np.float64(self.gamma), np.shape(k0)) if np.ndim(k0) else float(self.gamma)

    def sequence(self, k0_count: int) -> np.ndarray:
        return np.full(k0_count, self.gamma, dtype=np.float64)


@dataclasses.dataclass(frozen=True)
class ExponentialRule:
    """gamma^(k0) = rho^(k0-1) * gamma_e, rho in (0,1)  (eq. 12)."""
    gamma: float
    rho: float
    name = "E"

    def __post_init__(self):
        if not (0.0 < self.rho < 1.0):
            raise ValueError("exponential rule requires rho in (0, 1)")

    def sequence(self, k0_count: int) -> np.ndarray:
        k = np.arange(1, k0_count + 1, dtype=np.float64)
        return self.gamma * self.rho ** (k - 1.0)


@dataclasses.dataclass(frozen=True)
class DiminishingRule:
    """gamma^(k0) = rho_d * gamma_d / (k0 + rho_d)  (eq. 15)."""
    gamma: float
    rho: float
    name = "D"

    def __post_init__(self):
        if self.rho <= 0:
            raise ValueError("diminishing rule requires rho > 0")

    def sequence(self, k0_count: int) -> np.ndarray:
        k = np.arange(1, k0_count + 1, dtype=np.float64)
        return self.rho * self.gamma / (k + self.rho)


StepRule = Union[ConstantRule, ExponentialRule, DiminishingRule]


def make_rule(name: str, gamma: float, rho: float | None = None) -> StepRule:
    name = name.upper()
    if name == "C":
        return ConstantRule(gamma)
    if name == "E":
        assert rho is not None
        return ExponentialRule(gamma, rho)
    if name == "D":
        assert rho is not None
        return DiminishingRule(gamma, rho)
    raise ValueError(f"unknown step rule {name!r}")
