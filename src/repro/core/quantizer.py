"""QSGD-style random quantizer satisfying Assumption 1 of the paper.

For an input vector ``y`` and quantization parameter ``s`` (number of
quantization levels per unit of the normalized magnitude), the quantizer is

    Q(y; s)_i = ||y||_2 * sign(y_i) * xi_i / s

where ``xi_i`` is the stochastic level: with ``u_i = s * |y_i| / ||y||_2``,
``xi_i = floor(u_i) + Bernoulli(u_i - floor(u_i))``.

Properties (Lemma 3.1 of QSGD, restated as the paper's Assumption 1):
  (i)  E[Q(y; s)] = y                               (unbiased)
  (ii) E||Q(y; s) - y||^2 <= q_s ||y||^2  with  q_s = min(D / s^2, sqrt(D) / s)

The paper treats the quantizer abstractly through ``(q_s, M_s)``; we provide
the concrete QSGD instance plus the bit model ``M_s`` used by the cost layer.

``s == None`` (or ``jnp.inf``) encodes the paper's ``s = ∞`` — no quantization
(``q_s = 0``) — used to recover PM-SGD / FedAvg / PR-SGD as special cases.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp

__all__ = [
    "QuantizerSpec",
    "variance_bound",
    "bits_per_message",
    "quantize",
    "dequantize",
    "quantize_dequantize",
    "q_pair",
]


@dataclasses.dataclass(frozen=True)
class QuantizerSpec:
    """Static description of one node's quantizer.

    Attributes:
      s: number of quantization levels (``None`` == no quantization, s = ∞).
      wire_dtype: dtype used on the wire by the *optimized* transport
        ("f32" faithful math, "int8"/"int4" packed levels).
    """

    s: Optional[int]
    wire_dtype: str = "f32"

    @property
    def is_identity(self) -> bool:
        return self.s is None

    def q(self, dim: int) -> float:
        return variance_bound(self.s, dim)

    def bits(self, dim: int) -> float:
        return bits_per_message(self.s, dim)


def variance_bound(s: Optional[int], dim: int) -> float:
    """q_s of Assumption 1 for the QSGD quantizer: min(D/s^2, sqrt(D)/s)."""
    if s is None:
        return 0.0
    if s <= 0:
        raise ValueError(f"quantization parameter s must be positive, got {s}")
    return min(dim / s**2, math.sqrt(dim) / s)


def bits_per_message(s: Optional[int], dim: int) -> float:
    """M_s: bits to represent Q(y; s) for a D-dimensional y.

    Simple fixed-length code: a 32-bit norm plus, per coordinate, a sign bit
    and ceil(log2(s+1)) bits of level index.  (QSGD's Elias coding achieves
    fewer bits; fixed-length is what a TPU wire format would use and is the
    monotone-in-s model the paper's cost layer expects.)
    """
    if s is None:
        return 32.0 * (dim + 1)  # raw f32 vector
    return 32.0 + dim * (1.0 + math.ceil(math.log2(s + 1)))


def q_pair(q_s0: float, q_sn: float) -> float:
    """q_{s0,sn} = q_{s0} + q_{sn} + q_{s0} q_{sn} (Theorem 1)."""
    return q_s0 + q_sn + q_s0 * q_sn


def _levels(y: jax.Array, s: int, key: jax.Array):
    """Stochastic level assignment.  Returns (levels int32, norm f32).

    levels are signed: sign(y) * xi in [-s, s].
    """
    norm = jnp.linalg.norm(y.astype(jnp.float32).ravel())
    # Avoid 0/0 for the zero vector; levels are 0 there anyway.
    safe = jnp.where(norm > 0, norm, 1.0)
    u = s * jnp.abs(y.astype(jnp.float32)) / safe
    lo = jnp.floor(u)
    frac = u - lo
    bern = jax.random.uniform(key, y.shape, jnp.float32) < frac
    xi = lo + bern.astype(jnp.float32)
    lvl = jnp.sign(y) * xi
    return lvl.astype(jnp.int32), norm


def quantize(y: jax.Array, s: Optional[int], key: jax.Array):
    """Quantize ``y`` -> (levels, norm).  Identity passthrough for s=None."""
    if s is None:
        return y, jnp.float32(1.0)
    return _levels(y, s, key)


def dequantize(levels: jax.Array, norm: jax.Array, s: Optional[int],
               dtype=jnp.float32) -> jax.Array:
    if s is None:
        return levels.astype(dtype)
    return (levels.astype(jnp.float32) * (norm / s)).astype(dtype)


def quantize_dequantize(y: jax.Array, s: Optional[int], key: jax.Array) -> jax.Array:
    """Q(y; s) as a value (the paper's math; f32 on the wire)."""
    lvl, norm = quantize(y, s, key)
    return dequantize(lvl, norm, s, dtype=y.dtype)
