from .synthetic import token_batches, mnist_like, lm_batch
from .federated import partition_iid, round_batches, sample_minibatch
