"""Federated data plumbing: per-worker partitioning (Assumption 2: IID) and
round-batch assembly for the distributed runtime.

The runtime consumes batches with leading (fl, K_max, B_local) dims — one
mini-batch per local step per worker.
"""
from __future__ import annotations

from typing import Dict, Iterator, Sequence

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["partition_iid", "round_batches", "sample_minibatch"]


def partition_iid(X: np.ndarray, y: np.ndarray, n_workers: int, seed: int = 0):
    """Shuffle + equal split (the paper's IID assumption)."""
    rng = np.random.default_rng(seed)
    perm = rng.permutation(len(X))
    Xs, ys = X[perm], y[perm]
    per = len(X) // n_workers
    return ([Xs[i * per:(i + 1) * per] for i in range(n_workers)],
            [ys[i * per:(i + 1) * per] for i in range(n_workers)])


def sample_minibatch(worker_data, key, B: int):
    """Uniform with-replacement mini-batch from one worker's shard
    (the sample_fn contract of repro.core.GenQSGD)."""
    X, y = worker_data
    idx = jax.random.randint(key, (B,), 0, X.shape[0])
    return X[idx], y[idx]


def round_batches(stream, n_workers: int, k_max: int) -> Iterator[Dict]:
    """Stack per-worker, per-local-step LM batches into the runtime layout.

    ``stream`` is an iterator yielding dicts of arrays with a leading batch
    dim.
    """
    while True:
        steps = [[next(stream) for _ in range(k_max)]
                 for _ in range(n_workers)]
        out = {}
        for k in steps[0][0]:
            out[k] = jnp.stack([jnp.stack([steps[w][s][k]
                                           for s in range(k_max)])
                                for w in range(n_workers)])
        yield out
