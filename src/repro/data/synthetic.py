"""Synthetic data generators (fully offline, deterministic).

* ``token_stream`` — procedural LM token sequences with local statistical
  structure (a random Markov backbone + noise) so cross-entropy actually
  decreases during the example runs.
* ``mnist_like`` — the paper-repro dataset: a 10-class, 784-dim image-like
  Gaussian-mixture (class templates are smoothed random blobs), 60k samples,
  matching Sec. VII's MNIST setup in shape and difficulty class.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["token_batches", "mnist_like", "lm_batch"]


def _markov_matrix(vocab: int, seed: int, branching: int = 8) -> np.ndarray:
    rng = np.random.default_rng(seed)
    T = np.full((vocab, vocab), 1e-3)
    for v in range(vocab):
        nxt = rng.choice(vocab, size=branching, replace=False)
        T[v, nxt] += rng.dirichlet(np.ones(branching)) * branching
    return T / T.sum(axis=1, keepdims=True)


def lm_batch(key, batch: int, seq: int, vocab: int, trans: np.ndarray):
    """One (tokens, labels) batch from the Markov backbone."""
    rng = np.random.default_rng(int(jax.random.randint(key, (), 0, 2**31 - 1)))
    toks = np.empty((batch, seq + 1), np.int32)
    toks[:, 0] = rng.integers(0, vocab, batch)
    cum = np.cumsum(trans, axis=1)
    for t in range(seq):
        u = rng.random(batch)
        toks[:, t + 1] = (cum[toks[:, t]] > u[:, None]).argmax(axis=1)
    return {"tokens": jnp.asarray(toks[:, :-1]),
            "labels": jnp.asarray(toks[:, 1:])}


def token_batches(seed: int, batch: int, seq: int, vocab: int
                  ) -> Iterator[dict]:
    trans = _markov_matrix(vocab, seed)
    key = jax.random.PRNGKey(seed)
    while True:
        key, sub = jax.random.split(key)
        yield lm_batch(sub, batch, seq, vocab, trans)


def mnist_like(n: int = 60_000, n_classes: int = 10, dim: int = 784,
               seed: int = 0, noise: float = 0.35):
    """(X (n, 784) f32 in [0,1]-ish, y (n,) int32).  Deterministic."""
    rng = np.random.default_rng(seed)
    side = int(np.sqrt(dim))
    # class templates: superpositions of smooth random blobs
    templates = np.zeros((n_classes, side, side), np.float32)
    yy, xx = np.mgrid[0:side, 0:side]
    for c in range(n_classes):
        for _ in range(4):
            cy, cx = rng.uniform(4, side - 4, 2)
            sig = rng.uniform(2.0, 5.0)
            amp = rng.uniform(0.6, 1.0)
            templates[c] += amp * np.exp(-((yy - cy) ** 2 + (xx - cx) ** 2)
                                         / (2 * sig**2))
    templates = templates.reshape(n_classes, dim)
    templates /= templates.max(axis=1, keepdims=True)
    y = rng.integers(0, n_classes, n).astype(np.int32)
    X = templates[y] + noise * rng.standard_normal((n, dim)).astype(np.float32)
    return np.clip(X, 0.0, 1.3).astype(np.float32), y
