"""The sampling registry: name -> :class:`SamplingModel` instance.

The authoritative registry behind ``Scenario(sampling=...)``.  Unknown
names fail with a nearest-match suggestion, mirroring
:mod:`repro.families.registry`.
"""
from __future__ import annotations

import difflib
from typing import Dict, Tuple, Union

from .base import SamplingModel

__all__ = ["register", "get_sampling", "sampling_names", "resolve"]

_REGISTRY: Dict[str, SamplingModel] = {}


def register(model: SamplingModel, overwrite: bool = False) -> None:
    """Register a sampling model under ``model.key``."""
    if not isinstance(model, SamplingModel):
        raise TypeError(f"expected a SamplingModel, got {type(model)}")
    if model.key in _REGISTRY and not overwrite:
        raise ValueError(f"sampling model {model.key!r} is already "
                         f"registered; pass overwrite=True to replace it")
    _REGISTRY[str(model.key)] = model


def sampling_names() -> Tuple[str, ...]:
    return tuple(_REGISTRY)


def get_sampling(name: str) -> SamplingModel:
    try:
        return _REGISTRY[name]
    except KeyError:
        close = difflib.get_close_matches(str(name), _REGISTRY, n=1)
        hint = f" — did you mean {close[0]!r}?" if close else ""
        raise ValueError(
            f"unknown sampling model {name!r}{hint}; registered in "
            f"repro.sampling: {sorted(_REGISTRY)} (add one with "
            f"repro.sampling.register, or pass a SamplingModel instance — "
            f"e.g. repro.sampling.uniform(S=...) / "
            f"repro.sampling.importance(p, S=...))") from None


def resolve(model: Union[str, SamplingModel]) -> SamplingModel:
    """Accept a registry key or an (unregistered) model instance."""
    if isinstance(model, SamplingModel):
        return model
    return get_sampling(model)
