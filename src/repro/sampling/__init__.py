"""repro.sampling: client sampling / partial participation, end-to-end.

Pluggable participation models (mirroring :mod:`repro.families`): each
model contributes GP decision variables + expected-cost / inflated
convergence-bound coefficients to the optimizer, and a seeded cohort draw
+ unbiased Horvitz-Thompson reweighting to the runtimes.

    from repro.api import Scenario
    from repro.sampling import uniform

    plan = Scenario(..., sampling="uniform").optimize()   # S chosen by GP
    plan = Scenario(..., sampling=uniform(S=4)).optimize()  # pinned cohort
"""
from .base import (SamplingModel, check_probs, cohort_weights, draw_cohort,
                   draw_cohort_weights, widen_varmap)
from .builtin import (FullParticipation, ImportanceSampling, UniformSampling,
                      importance, uniform)
from .registry import get_sampling, register, resolve, sampling_names

__all__ = [
    "SamplingModel", "FullParticipation", "UniformSampling",
    "ImportanceSampling", "uniform", "importance",
    "register", "get_sampling", "sampling_names", "resolve",
    "draw_cohort", "cohort_weights", "draw_cohort_weights",
    "widen_varmap", "check_probs",
]

#: the named models: "full" (the neutral default) and "uniform" (free S)
BUILTIN_SAMPLING = (FullParticipation(), UniformSampling())
for _s in BUILTIN_SAMPLING:
    register(_s, overwrite=True)
del _s
