"""Built-in participation models: full, uniform cohorts, importance.

``FullParticipation`` is the neutral base class re-exported under its
registry name.  ``UniformSampling`` draws a fixed-size cohort uniformly
without replacement — with ``S=None`` the cohort size is a GP decision
variable, with an integer ``S`` it is pinned (``S=N`` reduces bitwise to
full participation).  ``ImportanceSampling`` carries per-worker base
probabilities ``p_n`` (systematic PPS draw at runtime; inclusion
probability ``pi_n = S * p_n``).

Bound honesty (see the module docstring of :mod:`repro.sampling.base`):
both pinned and free-``S`` models keep the *exact* inflation factors
``(q_n + 1 - pi_n)/pi_n`` and ``(1/N) sum 1/pi_n`` — free-``S`` problems
carry them in ratio form (positive part in the numerator, the ``-1`` part
AM-GM-condensed into the denominator), the standard GIA condensation with
zero slack at convergence.  The time constraints stay worst-case over all
N workers in both cases.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

from .base import SamplingModel, check_probs, widen_varmap

__all__ = ["FullParticipation", "UniformSampling", "ImportanceSampling",
           "uniform", "importance"]


@dataclasses.dataclass(frozen=True)
class FullParticipation(SamplingModel):
    """Every worker in every round — the historical pipeline, verbatim."""

    key: str = "full"


@dataclasses.dataclass(frozen=True)
class UniformSampling(SamplingModel):
    """Fixed-size cohort drawn uniformly without replacement.

    ``S=None`` exposes the cohort size as a GP variable (box ``[1, N]``);
    an integer ``S`` pins it.  ``pi_n = S/N`` for every worker, so the
    sample-variance coefficient scales by exactly ``N/S`` regardless of
    the family's aggregation weights.
    """

    key: str = "uniform"
    S: Optional[int] = None       # cohort size; None = optimized by the GP

    def validate(self, N: int) -> None:
        if self.S is not None and not 1 <= int(self.S) <= N:
            raise ValueError(f"cohort size S={self.S} outside [1, N={N}]")

    def is_neutral(self, N: int) -> bool:
        return self.S is not None and int(self.S) == int(N)

    def signature(self, N: int) -> tuple:
        if self.is_neutral(N):
            return ("full",)
        return ("uniform", None if self.S is None else int(self.S))

    @property
    def free_S(self) -> bool:
        return self.S is None

    def pinned_S(self, N: int) -> Optional[int]:
        return None if (self.S is None or self.is_neutral(N)) else int(self.S)

    def extend_varmap(self, vmap, N: int):
        if not self.free_S:
            return vmap
        return widen_varmap(vmap, "S", 1.0, self.s_cap(N))

    def pi(self, N: int) -> Optional[np.ndarray]:
        if self.free_S or self.is_neutral(N):
            return None
        return np.full(N, float(self.S) / N)

    def base_p(self, N: int) -> Optional[np.ndarray]:
        return np.full(N, 1.0 / N) if self.free_S else None

    def q_coeffs(self, q_pairs, N: int) -> Optional[np.ndarray]:
        if self.is_neutral(N):
            return None
        if self.free_S:                    # numerator part (q+1)/p_n; caller / S
            return (np.asarray(q_pairs, np.float64) + 1.0) * float(N)
        pi = float(self.S) / N             # exact (q + 1 - pi)/pi
        return (np.asarray(q_pairs, np.float64) + 1.0 - pi) / pi

    def c3_scale(self, N: int) -> float:
        if self.is_neutral(N):
            return 1.0
        if self.free_S:                    # (1/N) sum 1/p_n = N; caller / S
            return float(N)
        return float(N) / float(self.S)    # exact N/S


@dataclasses.dataclass(frozen=True)
class ImportanceSampling(SamplingModel):
    """Weighted cohort sampling with per-worker base probabilities ``p_n``.

    The runtime draw is systematic PPS sampling — exactly ``S`` distinct
    workers with inclusion probability exactly ``pi_n = S * p_n`` as long
    as every ``pi_n <= 1``, which the cohort cap ``s_cap = min(N,
    1/max p_n)`` guarantees.  The sample-variance scale ``(1/N) sum 1/pi_n``
    is exact for uniform aggregation weights; under family-weighted
    aggregation it is the factorized surrogate of the coupled bound.
    """

    key: str = "importance"
    p: Tuple[float, ...] = ()
    S: Optional[int] = None

    def __post_init__(self):
        object.__setattr__(self, "p", check_probs(self.p))

    def validate(self, N: int) -> None:
        check_probs(self.p, n_workers=N)
        if self.S is not None:
            if not 1 <= int(self.S) <= N:
                raise ValueError(f"cohort size S={self.S} outside "
                                 f"[1, N={N}]")
            if int(self.S) * max(self.p) > 1.0 + 1e-12:
                raise ValueError(
                    f"S={self.S} pushes max inclusion probability "
                    f"{int(self.S) * max(self.p):.4f} above 1; cohort cap "
                    f"is {self.s_cap(N):.2f}")

    def is_neutral(self, N: int) -> bool:
        # pi_n == 1 for every worker — full participation in disguise
        return self.S is not None and \
            all(int(self.S) * pn == 1.0 for pn in self.p)

    def signature(self, N: int) -> tuple:
        if self.is_neutral(N):
            return ("full",)
        return ("importance", None if self.S is None else int(self.S),
                tuple(round(pn, 12) for pn in self.p))

    @property
    def free_S(self) -> bool:
        return self.S is None

    def s_cap(self, N: int) -> float:
        return float(min(float(N), 1.0 / max(self.p)))

    def pinned_S(self, N: int) -> Optional[int]:
        return None if (self.S is None or self.is_neutral(N)) else int(self.S)

    def extend_varmap(self, vmap, N: int):
        if not self.free_S:
            return vmap
        return widen_varmap(vmap, "S", 1.0, self.s_cap(N))

    def pi(self, N: int) -> Optional[np.ndarray]:
        if self.free_S or self.is_neutral(N):
            return None
        return float(self.S) * np.asarray(self.p, np.float64)

    def base_p(self, N: int) -> Optional[np.ndarray]:
        return np.asarray(self.p, np.float64) if self.free_S else None

    def q_coeffs(self, q_pairs, N: int) -> Optional[np.ndarray]:
        if self.is_neutral(N):
            return None
        qp = np.asarray(q_pairs, np.float64)
        pa = np.asarray(self.p, np.float64)
        if self.free_S:                    # numerator part (q+1)/p_n; caller / S
            return (qp + 1.0) / pa
        pi = float(self.S) * pa            # exact (q + 1 - pi)/pi
        return (qp + 1.0 - pi) / pi

    def c3_scale(self, N: int) -> float:
        if self.is_neutral(N):
            return 1.0
        inv = float(np.sum(1.0 / np.asarray(self.p, np.float64)))
        if self.free_S:                    # S-independent part; caller / S
            return inv / N
        return inv / (float(self.S) * N)

    def plan_p(self, N: int) -> Optional[Tuple[float, ...]]:
        del N
        return tuple(float(x) for x in self.p)


def uniform(S: Optional[int] = None) -> UniformSampling:
    """Uniform cohort sampling; ``S=None`` lets the optimizer choose."""
    return UniformSampling(S=None if S is None else int(S))


def importance(p, S: Optional[int] = None) -> ImportanceSampling:
    """Importance sampling with base probabilities ``p`` (sum 1)."""
    return ImportanceSampling(p=tuple(p), S=None if S is None else int(S))
