"""The SamplingModel interface: client sampling / partial participation
as a first-class, pluggable piece of the optimization problem.

The paper assumes all N workers participate in every round, but production
cross-device FL samples a small cohort per round — the regime both
"Cost-Effective Federated Learning" papers (arXiv 2109.05411, 2012.08336)
show must be co-optimized with convergence.  A :class:`SamplingModel`
bundles the seams a participation model needs, mirroring how
:class:`repro.families.AlgorithmFamily` wraps the algorithm:

  varmap hook        ``extend_varmap`` — free-cohort models append a new GP
                     decision variable ``S`` (cohort size) to the family's
                     varmap; expected costs and the inflated convergence
                     block stay posynomial in (S, Kn, B), so sampled
                     problems batch and fuse through ``repro.opt.refresh``
                     / ``repro.opt.gia_jax`` unchanged;
  convergence hooks  ``q_coeffs`` / ``c3_scale`` — partial participation
                     inflates Theorem 1's variance blocks: with inclusion
                     probability ``pi_n`` the per-worker quantization block
                     coefficient ``q_n`` becomes ``(q_n + 1 - pi_n)/pi_n``
                     (quantization noise divided by ``pi_n`` plus the
                     participation-noise term ``(1-pi_n)/pi_n``; exactly
                     ``q_n`` at ``pi_n = 1``) and the sample-variance
                     coefficient ``c3`` picks up ``(1/N) sum_n 1/pi_n``
                     (``N/S`` for uniform cohorts; exactly 1 at S=N);
  cost hooks         ``pi`` / ``base_p`` / ``pi_at`` — the inclusion
                     probabilities that turn the energy objective into an
                     *expected* energy (each worker's compute and upload
                     terms scale by ``pi_n``); the time constraints stay
                     worst-case over all N workers (E[max over a random
                     cohort] is not posynomial — a deliberately
                     conservative modeling choice, noted in ROADMAP.md);
  runtime hooks      the module-level :func:`draw_cohort` /
                     :func:`cohort_weights` helpers — a seeded per-round
                     cohort draw plus the Horvitz-Thompson reweighting
                     ``u_n = mask_n * w_n / pi_n`` that keeps the server
                     aggregation unbiased (``E[sum_n u_n d_n] = sum_n w_n
                     d_n`` for any aggregation weights ``w``), consumed by
                     :mod:`repro.core.genqsgd` and
                     :mod:`repro.train.trainer`.

For free-``S`` models the GP constraint must be posynomial in ``S``.  The
exact per-worker factor ``(q_n + 1 - pi_n)/pi_n`` is not (the ``-pi_n``
makes it a signomial), but no relaxation is paid: the convergence
constraint is kept *exact* in ratio form — the positive part
``[(q_n+1)/p_n] * S^{-1}`` stays in the numerator and the ``-1`` part
moves to the denominator, which ``repro.opt.condense.ratio_to_posy``
AM-GM-condenses around the previous iterate (conservative inner
approximation, tight at the expansion point — the standard GIA
condensation contract, zero slack at convergence).  Pinned-``S`` models
keep the exact factor directly (a pure coefficient change, so a pinned
sampled problem shares the *compiled program* of the unsampled one while
keying its own cache pool).

The base class implements full participation for every hook: the ``None``
/ ``1.0`` returns select the exact pre-sampling code paths, so routing an
unsampled (or ``uniform(S=N)``) scenario through this interface is
bit-identical to the historical pipeline — asserted by
``tests/unit/test_sampling.py``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

from ..opt.posy import Posy
from ..opt.problems import VarMap

__all__ = ["SamplingModel", "widen_varmap", "draw_cohort", "cohort_weights",
           "draw_cohort_weights", "check_probs"]


def check_probs(p, n_workers: Optional[int] = None) -> Tuple[float, ...]:
    """The ONE validator for per-worker sampling probabilities ``p_n``:
    coerces to a float tuple, requires strict positivity and sum 1, and —
    when the worker count is known — the right length."""
    w = tuple(float(x) for x in p)
    if n_workers is not None and len(w) != n_workers:
        raise ValueError(f"{len(w)} sampling probabilities for "
                         f"{n_workers} workers")
    if any(x <= 0 for x in w):
        raise ValueError(f"sampling probabilities must be positive, got {w}")
    if abs(sum(w) - 1.0) > 1e-9:
        raise ValueError(f"sampling probabilities must sum to 1, "
                         f"got sum {sum(w)}")
    return w


def _widen(p: Optional[Posy], n_new: int) -> Optional[Posy]:
    """The posynomial re-expressed over ``n_new`` variables (zero exponents
    on the appended ones) — coefficients untouched."""
    if p is None:
        return None
    pad = np.zeros((p.A.shape[0], n_new - p.A.shape[1]))
    return Posy(p.c.copy(), np.concatenate([p.A, pad], axis=1))


def widen_varmap(vmap: VarMap, name: str, lower: float, upper: float
                 ) -> VarMap:
    """``vmap`` with one new boxed variable appended (after every existing
    one, ``extra`` included, so positional assumptions elsewhere —
    ``names.index("extra")``, the z_init coordinate fills — stay valid)."""
    n = vmap.n + 1
    lo = np.full(n, 1e-12)
    up = np.full(n, 1e12)
    if vmap.lower is not None:
        lo[:n - 1] = vmap.lower
    if vmap.upper is not None:
        up[:n - 1] = vmap.upper
    lo[n - 1] = float(lower)
    up[n - 1] = float(upper)
    return VarMap(n=n, names=list(vmap.names) + [str(name)],
                  K0=_widen(vmap.K0, n),
                  Kn=[_widen(k, n) for k in vmap.Kn],
                  B=_widen(vmap.B, n), T1=_widen(vmap.T1, n),
                  T2=_widen(vmap.T2, n), extra=_widen(vmap.extra, n),
                  lower=lo, upper=up)


@dataclasses.dataclass(frozen=True)
class SamplingModel:
    """One participation model; frozen so instances key registries/caches.

    The base class *is* full participation: every hook returns the neutral
    value selecting the historical code path bitwise.
    """

    key: str = "full"             # registry name == structure-signature key

    # -- identity --------------------------------------------------------
    def validate(self, N: int) -> None:
        """Fail loudly on an N-mismatched model (length of p, S > N)."""
        del N

    def is_neutral(self, N: int) -> bool:
        """True when the model is full participation in disguise — every
        hook must then return its neutral value so the pipeline is
        bit-identical to the unsampled one."""
        del N
        return True

    def signature(self, N: int) -> tuple:
        """The structure-signature element.  Neutral models report
        ``("full",)`` so they share the default problems' compile/cache
        pools; genuinely sampled models must differ from it (and from each
        other when their conv-block coefficients differ)."""
        del N
        return ("full",)

    # -- optimizer: decision variables -----------------------------------
    @property
    def free_S(self) -> bool:
        """Whether the cohort size is a GP decision variable ``S``."""
        return False

    def s_cap(self, N: int) -> float:
        """Upper bound on the cohort size (keeps every ``pi_n <= 1``)."""
        return float(N)

    def pinned_S(self, N: int) -> Optional[int]:
        """The fixed cohort size of a pinned model (None = full or free)."""
        del N
        return None

    def extend_varmap(self, vmap: VarMap, N: int) -> VarMap:
        """Append the model's decision variables (free-``S`` models append
        ``"S"`` with box ``[1, s_cap]``); pinned/full models are a no-op."""
        del N
        return vmap

    # -- optimizer: expected-cost / convergence coefficients --------------
    def pi(self, N: int) -> Optional[np.ndarray]:
        """Pinned per-worker inclusion probabilities ``pi_n`` (None = full
        participation or free-``S`` — use :meth:`pi_at` for the latter)."""
        del N
        return None

    def base_p(self, N: int) -> Optional[np.ndarray]:
        """Free-``S`` base probabilities ``p_n`` with ``pi_n = p_n * S``
        (None for pinned/full models)."""
        del N
        return None

    def pi_at(self, N: int, S: Optional[float] = None
              ) -> Optional[np.ndarray]:
        """Inclusion probabilities at a concrete cohort size (None = the
        historical full-participation costs, verbatim)."""
        if self.free_S:
            if S is None:
                raise ValueError(f"sampling model {self.key!r} optimizes S; "
                                 f"pass the cohort size")
            return float(S) * self.base_p(N)
        return self.pi(N)

    def q_coeffs(self, q_pairs: np.ndarray, N: int) -> Optional[np.ndarray]:
        """The quantization-block coefficients with the participation
        inflation folded in (None = historical ``q_pairs``, bitwise).

        Pinned models return the exact ``(q_n + 1 - pi_n)/pi_n``; free-``S``
        models return the ``S``-independent *numerator* part of the exact
        ratio form, ``(q_n + 1)/p_n`` — the caller multiplies by ``S^{-1}``
        and moves the ``-1`` part into the condensed denominator, so the
        constraint stays exact.  Concrete-``S`` evaluation goes through
        :meth:`q_coeffs_at`.
        """
        del q_pairs, N
        return None

    def q_coeffs_at(self, q_pairs: np.ndarray, N: int,
                    S: Optional[float] = None) -> Optional[np.ndarray]:
        """The *exact* inflated coefficients ``(q_n + 1 - pi_n)/pi_n`` at a
        concrete cohort size (None = historical ``q_pairs``, bitwise).

        This is what ``evaluate`` / integer recovery / the feasibility flag
        use — the same surrogate-vs-validation split m=E's Taylor
        constraints already follow, so the reported bound is always the
        exact one.  Positive whenever every ``pi_n <= 1`` — guaranteed by
        ``s_cap``.
        """
        if not self.free_S:
            return self.q_coeffs(q_pairs, N)
        pi = self.pi_at(N, S)
        return (np.asarray(q_pairs, np.float64) + 1.0 - pi) / pi

    def c3_scale(self, N: int) -> float:
        """Multiplier on Theorem 1's sample-variance coefficient ``c3``:
        ``(1/N) sum_n 1/pi_n`` (free-``S``: its ``S``-independent part
        ``(1/N) sum_n 1/p_n``; the caller multiplies by ``S^{-1}``).
        Exactly 1.0 leaves the coefficient bitwise untouched."""
        del N
        return 1.0

    def plan_p(self, N: int) -> Optional[Tuple[float, ...]]:
        """The probabilities a frozen Plan must carry to reproduce the
        runtime draw (None = uniform / full)."""
        del N
        return None


# ---------------------------------------------------------------------------
# runtime: seeded cohort draws + unbiased reweighting
# ---------------------------------------------------------------------------
def draw_cohort(rng: np.random.Generator, N: int, S: int, p=None):
    """One per-round cohort: exactly ``S`` distinct workers of ``N``.

    ``p=None`` draws uniformly without replacement (inclusion probability
    ``S/N`` each).  Otherwise ``p`` are per-worker base probabilities and
    the draw is systematic PPS sampling — cumulate ``pi = S*p``, place
    ``S`` equispaced points at a common uniform offset — which yields a
    fixed-size cohort with inclusion probabilities *exactly* ``pi_n``
    whenever every ``pi_n <= 1`` (guaranteed by the model's ``s_cap``).

    Returns ``(idx, pi)`` — sorted cohort indices and the length-N
    inclusion-probability vector.
    """
    S = int(S)
    if p is None:
        idx = np.sort(rng.choice(N, size=S, replace=False))
        pi = np.full(N, float(S) / N)
    else:
        pi = float(S) * np.asarray(p, dtype=np.float64)
        points = rng.uniform(0.0, 1.0) + np.arange(S)
        idx = np.searchsorted(np.cumsum(pi), points, side="right")
        idx = np.minimum(idx, N - 1)       # fp guard at the last cum point
    return idx, pi


def cohort_weights(idx: np.ndarray, pi: np.ndarray, N: int,
                   agg_weights=None) -> np.ndarray:
    """The Horvitz-Thompson aggregation vector ``u_n = mask_n * w_n / pi_n``.

    ``w`` are the (normalized) server aggregation weights — the plain mean
    ``w_n = 1/N`` when ``agg_weights`` is None.  ``E[sum_n u_n d_n] =
    sum_n w_n d_n`` over cohort draws, so the sampled round is an unbiased
    estimate of the full-participation round for any family weighting.
    """
    w = (np.full(N, 1.0 / N) if agg_weights is None
         else np.asarray(agg_weights, dtype=np.float64)
         / float(np.sum(agg_weights)))
    u = np.zeros(N)
    u[idx] = w[idx] / pi[idx]
    return u


def draw_cohort_weights(rng: np.random.Generator, N: int, S: int, p=None,
                        agg_weights=None):
    """One round's ``(idx, u)``: seeded cohort draw + unbiased weights."""
    idx, pi = draw_cohort(rng, N, S, p)
    return idx, cohort_weights(idx, pi, N, agg_weights)
