"""repro.serve — serving layers: token decoding and plan solving.

Two engines share the continuous-batching idea:

  * :class:`ServeEngine` (:mod:`repro.serve.engine`) — slot-based token
    serving over the LM decode step;
  * :class:`PlanServer` (:mod:`repro.serve.planserver`) — multi-tenant
    ``Scenario.optimize`` serving: signature micro-batching into the fused
    GIA solver plus a warm-start plan cache.

Imports are lazy: ``PlanServer`` consumers never pull the LM model stack
and ``ServeEngine`` consumers never pull the optimizer.
"""
_ENGINE = ("Request", "ServeEngine")
_PLAN = ("PlanServer", "PlanHandle", "PlanCache", "fingerprint",
         "fingerprint_distance")

__all__ = list(_ENGINE + _PLAN)


def __getattr__(name):
    if name in _ENGINE:
        from . import engine
        return getattr(engine, name)
    if name in _PLAN:
        from . import planserver
        return getattr(planserver, name)
    raise AttributeError(f"module 'repro.serve' has no attribute {name!r}")
