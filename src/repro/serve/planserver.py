"""PlanServer: multi-tenant plan serving over the fused GIA engine.

The optimizer as a service: every device cohort (its own
:class:`~repro.api.Scenario` — system, family, budgets) asks for its own
operating point, concurrently.  The fused solver already turns 1e3+-point
same-signature batches into one compiled device call; this module exploits
that for an *open-loop stream* of heterogeneous requests:

  * **signature micro-batching** — ``submit()`` enqueues the request under
    its optimizer structure signature ``(m, family varmap, N)``; a
    dispatcher thread groups same-signature requests into micro-batches
    (admission ``window_s`` / ``max_batch`` knobs, modeled on the slot-based
    continuous batching in :mod:`repro.serve.engine`) and dispatches each
    batch to ``backend="jnp-fused"`` — padded to a fixed ``max_batch`` row
    count, so the whole trace pays **one trace/compile per distinct
    signature** (process-level LRU of traced refresh plans + executables in
    :mod:`repro.opt.gia_jax`, asserted via its ``TRACE_COUNTS`` hook);

  * **warm-start plan cache** — solved scenarios are cached under a
    quantized *fingerprint* of the problem's coefficient tensors.  An exact
    fingerprint match returns the frozen Plan without solving; a near match
    (same signature, relative distance ≤ ``warm_radius``) seeds the new
    row's GIA at the cached solution's expansion point, so warm rows
    re-converge in 1-3 GIA iterations instead of cold phase-I — warm and
    cold rows mix freely inside one micro-batch (per-row ``z0s`` in
    :func:`repro.opt.gia.solve_param_opt_batched`).

Requests return :class:`PlanHandle`\\ s; ``handle.result()`` blocks until
the frozen :class:`~repro.api.plan.Plan` is ready.  ``Scenario.optimize(
server=...)`` routes through a server transparently.

Failure isolation: a *poison* request (corrupt warm seed, pathological
coefficients) that makes the fused solver raise no longer takes its
micro-batch peers down — the dispatcher bisects the failing batch so every
healthy row re-converges, quarantines the poison row for solo retries with
capped exponential backoff, and only then errors its handle
(``stats()["bisections"/"quarantined"/"poisoned"]``).  Queued requests can
be withdrawn with ``PlanHandle.cancel()``.

    with PlanServer(max_batch=16, window_s=0.02) as srv:
        handles = [srv.submit(s) for s in scenarios]   # open-loop stream
        plans = [h.result() for h in handles]
        srv.stats()                                    # hit-rate, compiles
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..obs import trace as _otrace
from ..obs.metrics import GLOBAL_SWITCH as _OBS_ON
from ..obs.metrics import MetricsRegistry
from ..opt.gia import GIAResult, solve_param_opt_batched
from ..opt.problems import Objective
from ..opt.refresh import RefreshPlan
from ..opt.structure import structure_signature

__all__ = ["PlanServer", "PlanHandle", "PlanCache", "fingerprint",
           "fingerprint_distance"]


# ---------------------------------------------------------------------------
# scenario fingerprints
# ---------------------------------------------------------------------------
def fingerprint(problem) -> np.ndarray:
    """The problem instance as a flat coefficient vector.

    Concatenates the objective / packed-skeleton log-coefficients and the
    refresh plan's per-instance coefficient arrays (exponent rows are
    signature-determined, so they are skipped): two problems of one
    signature agree on this vector iff they are numerically the same
    instance — budgets, step-size parameters, Theorem-1 constants, and
    every cost-model coefficient all live in these tensors, so nothing a
    Scenario can vary escapes the fingerprint.
    """
    plan = RefreshPlan.build([problem])
    parts = [plan.obj_logc[0].ravel(), plan.skel_logc[0].ravel()]
    for k in sorted(plan.arrays):
        if k.endswith("_A"):
            continue
        parts.append(np.asarray(plan.arrays[k][0], np.float64).ravel())
    return np.concatenate(parts)


def fingerprint_distance(a: np.ndarray, b: np.ndarray) -> float:
    """Scale-free nearness: max relative coordinate deviation."""
    return float(np.max(np.abs(a - b) / (1.0 + np.abs(b))))


def _quantize(vec: np.ndarray) -> bytes:
    # float32 keeps ~7 significant digits per coordinate — repeats of the
    # same Scenario collide exactly, genuinely different budgets never do
    return vec.astype(np.float32).tobytes()


# ---------------------------------------------------------------------------
# warm-start plan cache
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class _CacheEntry:
    vec: np.ndarray
    result: GIAResult          # converged GIA result (z = expansion point)


class PlanCache:
    """LRU of converged solves keyed by (signature, quantized fingerprint).

    Two lookups: :meth:`get` (exact quantized match — serve the cached
    solution without solving) and :meth:`nearest` (closest cached neighbor
    of one signature — its continuous solution seeds a warm GIA row).
    Only *converged* results are cached: a stalled/infeasible point is not
    an expansion point anyone should warm-start from.
    """

    def __init__(self, maxsize: int = 4096):
        self.maxsize = int(maxsize)
        self._lock = threading.Lock()
        self._entries: "collections.OrderedDict[tuple, _CacheEntry]" = \
            collections.OrderedDict()          # (sig, fp) -> entry
        self._by_sig: Dict[tuple, Dict[bytes, _CacheEntry]] = {}

    def __len__(self) -> int:
        return len(self._entries)

    def get(self, sig: tuple, fp: bytes) -> Optional[_CacheEntry]:
        with self._lock:
            e = self._entries.get((sig, fp))
            if e is not None:
                self._entries.move_to_end((sig, fp))
            return e

    def nearest(self, sig: tuple, vec: np.ndarray
                ) -> Tuple[Optional[_CacheEntry], float]:
        with self._lock:
            pool = self._by_sig.get(sig)
            if not pool:
                return None, float("inf")
            best, best_d = None, float("inf")
            for e in pool.values():
                d = fingerprint_distance(vec, e.vec)
                if d < best_d:
                    best, best_d = e, d
            return best, best_d

    def put(self, sig: tuple, fp: bytes, entry: _CacheEntry):
        with self._lock:
            key = (sig, fp)
            self._entries[key] = entry
            self._entries.move_to_end(key)
            self._by_sig.setdefault(sig, {})[fp] = entry
            while len(self._entries) > self.maxsize:
                (osig, ofp), _ = self._entries.popitem(last=False)
                self._by_sig[osig].pop(ofp, None)


# ---------------------------------------------------------------------------
# request handle
# ---------------------------------------------------------------------------
class PlanHandle:
    """One submitted ``Scenario.optimize`` request.

    ``source`` records how it was served: ``"hit"`` (exact fingerprint —
    cached solution, no solve), ``"warm"`` (solved, seeded from the nearest
    cached neighbor), or ``"cold"`` (solved from ``z_init``).  After
    resolution ``converged`` mirrors the GIA verdict (exact hits are
    converged by construction — only converged results are cached).
    """

    def __init__(self, scenario, m, problem, sig, vec, fp):
        self.scenario = scenario
        self.m = m
        self.problem = problem
        self.sig = sig
        self.vec = vec
        self.fp = fp
        self.plan = None
        self.error: Optional[str] = None
        self.source: Optional[str] = None
        self.warm_dist: Optional[float] = None
        self.batch_size: Optional[int] = None
        self.converged: Optional[bool] = None
        self.cancelled = False
        self.t_submit = time.perf_counter()
        self.t_taken: Optional[float] = None   # popped into a micro-batch
        self.t_done: Optional[float] = None
        self.z0: Optional[np.ndarray] = None
        self._event = threading.Event()

    def done(self) -> bool:
        return self._event.is_set()

    @property
    def latency_s(self) -> Optional[float]:
        return None if self.t_done is None else self.t_done - self.t_submit

    def result(self, timeout: Optional[float] = None):
        if not self._event.wait(timeout):
            raise TimeoutError("plan request still pending")
        if self.error is not None:
            raise RuntimeError(self.error)
        return self.plan

    def cancel(self) -> bool:
        """Withdraw a still-pending request.

        Returns True if the request was cancelled before solving began —
        the dispatcher then drops it while popping its batch and never
        spends solver time on it.  Returns False if the handle is already
        resolved (best-effort: a row that was mid-solve keeps its plan).
        A cancelled handle's ``result()`` raises ``RuntimeError``.
        """
        if self._event.is_set():
            return False
        self.cancelled = True
        self.error = "cancelled"
        self._resolve()
        return True

    def _resolve(self):
        self.t_done = time.perf_counter()
        self._event.set()


# ---------------------------------------------------------------------------
# the server
# ---------------------------------------------------------------------------
class PlanServer:
    """Multi-tenant plan serving: signature micro-batching + warm-start
    cache over the fused GIA backend.

    Knobs: ``max_batch`` (batch capacity *and* the fixed padded device
    shape — every dispatch of a signature reuses one compiled executable),
    ``window_s`` (admission window: a batch launches when full or when its
    oldest request has waited this long), ``warm_radius`` (max relative
    fingerprint distance for warm-start seeding), ``cache_size`` (LRU
    entries), ``quarantine_retries``/``retry_base_s``/``retry_cap_s``
    (solo-retry budget and backoff for quarantined poison rows).
    ``tol``/``max_iter`` are server-wide so every micro-batch of a
    signature shares one compiled program.

    m=J batches whose rows are *all* warm skip the Gen-C-seeded joint
    restart (``restart_warm_joint=True`` re-enables it): each warm seed is
    itself a post-restart best KKT point, so re-running the companion
    solves can only reproduce it.
    """

    def __init__(self, max_batch: int = 16, window_s: float = 0.02,
                 backend: str = "jnp-fused", tol: float = 1e-4,
                 max_iter: int = 60, cache_size: int = 4096,
                 warm_radius: float = 0.05, restart_warm_joint: bool = False,
                 quarantine_retries: int = 2, retry_base_s: float = 0.05,
                 retry_cap_s: float = 1.0, start: bool = True):
        self.max_batch = int(max_batch)
        self.window_s = float(window_s)
        self.backend = backend
        self.tol = float(tol)
        self.max_iter = int(max_iter)
        self.warm_radius = float(warm_radius)
        self.restart_warm_joint = bool(restart_warm_joint)
        self.quarantine_retries = int(quarantine_retries)
        self.retry_base_s = float(retry_base_s)
        self.retry_cap_s = float(retry_cap_s)
        self.cache = PlanCache(maxsize=cache_size)
        self._cond = threading.Condition()
        self._queues: Dict[tuple, "collections.deque[PlanHandle]"] = {}
        self._closing = False
        # the server's own always-on registry: stats() is a public API, so
        # its instruments record regardless of the global repro.obs switch
        self.metrics = MetricsRegistry()
        self._queue_depth = 0            # queued handles (under _cond)
        self._inflight = 0               # taken but unresolved (under _cond)
        self._trace_base: Dict[tuple, Tuple[tuple, int]] = {}
        self._thread: Optional[threading.Thread] = None
        if start:
            self.start()

    # -- metric shorthands (get-or-create is cheap: one dict lookup) ----
    def _count(self, name: str, n: float = 1, **labels):
        self.metrics.counter("planserver." + name, **labels).inc(n)

    def _observe(self, name: str, v: float, **labels):
        self.metrics.histogram("planserver." + name, **labels).observe(v)

    def _set_gauges(self):
        self.metrics.gauge("planserver.queue_depth").set(self._queue_depth)
        self.metrics.gauge("planserver.inflight").set(self._inflight)

    def _request_done(self, h: PlanHandle, latency: bool = True):
        """Per-request bookkeeping after a taken handle resolves (or is
        dropped): inflight gauge, per-source latency histogram, and — when
        global tracing is on — the request's queue→solve async spans."""
        with self._cond:
            self._inflight -= 1
            self._set_gauges()
        if latency and h.t_done is not None and h.source is not None:
            self._observe("latency_s", h.latency_s, source=h.source)
            self._observe("latency_s", h.latency_s, source="all")
        if _OBS_ON.on and h.t_taken is not None and h.t_done is not None:
            rid = id(h)
            _otrace.async_span("planserver.queue", rid, h.t_submit,
                               h.t_taken, cat="planserver",
                               source=h.source or "?")
            _otrace.async_span("planserver.solve", rid, h.t_taken, h.t_done,
                               cat="planserver", source=h.source or "?",
                               error=h.error)

    # -- lifecycle -----------------------------------------------------
    def start(self):
        if self._thread is not None:
            return self
        self._thread = threading.Thread(target=self._dispatch_loop,
                                        name="planserver", daemon=True)
        self._thread.start()
        return self

    def close(self):
        """Drain every pending request, then stop the dispatcher."""
        with self._cond:
            self._closing = True
            self._cond.notify_all()
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def __enter__(self):
        return self.start()

    def __exit__(self, *exc):
        self.close()

    # -- submission ----------------------------------------------------
    def submit(self, scenario, m=None) -> PlanHandle:
        """Admit one ``Scenario.optimize`` request; returns immediately."""
        m = scenario._resolve(m)
        problem = scenario.problem(m)
        sig = structure_signature(problem)
        vec = fingerprint(problem)
        fp = _quantize(vec)
        h = PlanHandle(scenario, m, problem, sig, vec, fp)
        hit = self.cache.get(sig, fp)
        if hit is not None:
            h.source = "hit"
            h.converged = True          # only converged results are cached
            h.plan = scenario._plan_from_result(m, hit.result)
            self._count("submitted")
            self._count("requests", source="hit")
            h._resolve()
            self._observe("latency_s", h.latency_s, source="hit")
            self._observe("latency_s", h.latency_s, source="all")
            if _OBS_ON.on:
                _otrace.instant("planserver.hit")
            return h
        near, dist = self.cache.nearest(sig, vec)
        if near is not None and dist <= self.warm_radius:
            h.source, h.warm_dist, h.z0 = "warm", dist, near.result.z
        else:
            h.source = "cold"
        with self._cond:
            if self._closing:
                raise RuntimeError("PlanServer is closed")
            self._queues.setdefault(sig, collections.deque()).append(h)
            self._queue_depth += 1
            self._set_gauges()
            self._cond.notify_all()
        self._count("submitted")
        self._count("requests", source=h.source)
        return h

    def solve(self, scenario, m=None, timeout: Optional[float] = None):
        """Blocking convenience: ``submit(...).result(...)``."""
        return self.submit(scenario, m=m).result(timeout)

    def solve_many(self, scenarios: Sequence, timeout: Optional[float] = None
                   ) -> List:
        handles = [self.submit(s) for s in scenarios]
        return [h.result(timeout) for h in handles]

    # -- dispatcher ----------------------------------------------------
    def _take_batch(self) -> Optional[List[PlanHandle]]:
        """Under the lock: pop the most overdue ready batch, or None."""
        now = time.perf_counter()
        ready_sig, oldest = None, None
        for sig, q in self._queues.items():
            if not q:
                continue
            t0 = q[0].t_submit
            if (len(q) >= self.max_batch or self._closing
                    or now - t0 >= self.window_s):
                if oldest is None or t0 < oldest:
                    ready_sig, oldest = sig, t0
        if ready_sig is None:
            return None
        q = self._queues[ready_sig]
        batch: List[PlanHandle] = []
        while q and len(batch) < self.max_batch:
            h = q.popleft()
            self._queue_depth -= 1
            if h.cancelled:             # withdrawn while queued: free slot
                self._count("cancelled")
                continue
            h.t_taken = now
            self._observe("queue_wait_s", now - h.t_submit)
            batch.append(h)
        self._inflight += len(batch)
        self._set_gauges()
        return batch or None

    def _next_deadline(self) -> Optional[float]:
        ts = [q[0].t_submit + self.window_s
              for q in self._queues.values() if q]
        return min(ts) if ts else None

    def _dispatch_loop(self):
        while True:
            with self._cond:
                batch = self._take_batch()
                while batch is None:
                    if self._closing and not any(self._queues.values()):
                        return
                    dl = self._next_deadline()
                    self._cond.wait(
                        None if dl is None
                        else max(1e-4, dl - time.perf_counter()))
                    batch = self._take_batch()
            self._solve_batch(batch)

    def _solve_batch(self, batch: List[PlanHandle]):
        sig = batch[0].sig
        if sig not in self._trace_base:
            from ..opt import gia_jax
            key = RefreshPlan.build([batch[0].problem]).signature_key
            self._trace_base[sig] = (key, gia_jax.trace_count(key))
        self._observe("batch_rows", len(batch))
        with _otrace.span("planserver.batch", rows=len(batch),
                          sig="/".join(map(str, sig))[:120]):
            self._solve_rows(batch)

    def _solve_rows(self, rows: List[PlanHandle]):
        """Solve ``rows`` as one fused dispatch, bisecting on failure.

        One poison row (corrupt warm seed, NaN coefficients, ...) must not
        take its batch peers down with it: on a solver exception the rows
        are split in half and retried, so every healthy row re-converges in
        O(log n) re-dispatches while the poison row is isolated down to a
        singleton and handed to :meth:`_solve_quarantined`.  Every
        re-dispatch pads to the same ``max_batch`` device shape, so the
        splitting never costs an extra compile.
        """
        joint = rows[0].problem.m is Objective.JOINT
        all_warm = all(h.source == "warm" for h in rows)
        restart = not (joint and all_warm and not self.restart_warm_joint)
        pad = self.max_batch if self.backend == "jnp-fused" else 0
        try:
            results = solve_param_opt_batched(
                [h.problem for h in rows], z0s=[h.z0 for h in rows],
                tol=self.tol, max_iter=self.max_iter, backend=self.backend,
                joint_restart=restart, pad_to=pad)
        except Exception:                           # noqa: BLE001
            if len(rows) == 1:
                self._solve_quarantined(rows[0])
                return
            self._count("bisections")
            mid = len(rows) // 2
            self._solve_rows(rows[:mid])
            self._solve_rows(rows[mid:])
            return
        for h, r in zip(rows, results):
            self._finish(h, r, len(rows))

    def _solve_quarantined(self, h: PlanHandle):
        """Last resort for an isolated failing row: retry it solo with
        capped exponential backoff — transient failures (allocator
        pressure under concurrent compiles, cache races) usually clear,
        and the row keeps its own warm seed — then error the handle."""
        self._count("quarantined")
        joint = h.problem.m is Objective.JOINT
        restart = not (joint and h.source == "warm"
                       and not self.restart_warm_joint)
        pad = self.max_batch if self.backend == "jnp-fused" else 0
        delay, err = self.retry_base_s, None
        for attempt in range(self.quarantine_retries + 1):
            if attempt:
                time.sleep(delay)
                delay = min(delay * 2.0, self.retry_cap_s)
            try:
                r = solve_param_opt_batched(
                    [h.problem], z0s=[h.z0], tol=self.tol,
                    max_iter=self.max_iter, backend=self.backend,
                    joint_restart=restart, pad_to=pad)[0]
            except Exception as e:                  # noqa: BLE001
                err = e
                continue
            self._finish(h, r, 1)
            return
        self._count("poisoned")
        h.error = f"{type(err).__name__}: {err}"
        h._resolve()
        self._request_done(h)

    def _finish(self, h: PlanHandle, r: GIAResult, batch_size: int):
        """Resolve one solved row: freeze its Plan, record convergence,
        cache the converged result.  A row cancelled mid-solve is already
        resolved with ``error="cancelled"`` — leave it alone."""
        if h.cancelled:
            self._request_done(h, latency=False)
            return
        try:
            h.plan = h.scenario._plan_from_result(h.m, r)
        except Exception as e:                      # noqa: BLE001
            # a row whose *plan construction* blows up is as poisonous as
            # one that kills the solver — contain it, don't unwind the
            # dispatcher with sibling rows still unresolved
            self._count("poisoned")
            h.error = f"{type(e).__name__}: {e}"
            h._resolve()
            self._request_done(h)
            return
        h.batch_size = batch_size
        h.converged = bool(r.converged)
        if r.converged:
            self.cache.put(h.sig, h.fp, _CacheEntry(h.vec, r))
        else:
            self._count("non_converged")
        h._resolve()
        self._request_done(h)

    # -- introspection -------------------------------------------------
    def compile_counts(self) -> Dict[tuple, int]:
        """Fused-program traces attributed to this server, per signature —
        the "one compile per distinct signature" assertion reads this."""
        from ..opt import gia_jax
        return {sig: gia_jax.trace_count(key) - base
                for sig, (key, base) in self._trace_base.items()}

    def stats(self) -> dict:
        """A view over the server's always-on metrics registry.

        Counter/batch keys are unchanged from the Counter-based
        implementation; ``queue_depth``/``inflight`` expose the live
        dispatcher state, and ``queue_wait_s``/``latency_s`` serve the
        percentile summaries ``benchmarks/serve_bench.py`` used to compute
        by hand from resolved handles (``latency_s`` is keyed by request
        source, plus an ``"all"`` aggregate)."""
        def count(name, **labels):
            return int(self.metrics.counter("planserver." + name,
                                            **labels).value)

        submitted = count("submitted")
        hits = count("requests", source="hit")
        batch_h = self.metrics.histogram("planserver.batch_rows")
        lat = {}
        for src in ("hit", "warm", "cold", "all"):
            s = self.metrics.histogram("planserver.latency_s",
                                       source=src).summary()
            if s["count"]:
                lat[src] = s
        return {
            "submitted": submitted,
            "hits": hits,
            "warm": count("requests", source="warm"),
            "cold": count("requests", source="cold"),
            "hit_rate": hits / submitted if submitted else 0.0,
            "batches": batch_h.count,
            "mean_batch": batch_h.mean if batch_h.count else 0.0,
            "cancelled": count("cancelled"),
            "bisections": count("bisections"),
            "quarantined": count("quarantined"),
            "poisoned": count("poisoned"),
            "non_converged": count("non_converged"),
            "signatures": len(self._trace_base),
            "cache_entries": len(self.cache),
            "compiles": {"/".join(map(str, sig)): c
                         for sig, c in self.compile_counts().items()},
            "queue_depth": int(self.metrics.gauge(
                "planserver.queue_depth").value),
            "inflight": int(self.metrics.gauge(
                "planserver.inflight").value),
            "queue_wait_s": self.metrics.histogram(
                "planserver.queue_wait_s").summary(),
            "latency_s": lat,
        }
