"""Batched serving engine: slot-based continuous batching over the
decode_step the dry-run shapes lower.

A fixed pool of ``slots`` shares one KV cache; requests join free slots,
prefill as a batch-of-one (cache splice), then decode together.  Greedy
sampling; completion on EOS or max_new_tokens.  This is the minimal real
engine shape (vLLM-lite without paging) — enough to serve the smoke models
on CPU and to lower at production shapes.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig
from ..models.registry import model_api

__all__ = ["Request", "ServeEngine"]


@dataclasses.dataclass
class Request:
    prompt: np.ndarray                 # (P,) int32 token ids
    max_new_tokens: int = 32
    eos_id: Optional[int] = None
    # filled by the engine
    output: Optional[List[int]] = None
    slot: int = -1
    done: bool = False
    failed: bool = False               # rejected at admission (no slot used)
    error: Optional[str] = None


class ServeEngine:
    def __init__(self, params, cfg: ArchConfig, slots: int = 4,
                 max_len: int = 256, cache_dtype=jnp.bfloat16):
        self.params = params
        self.cfg = cfg
        self.api = model_api(cfg)
        self.slots = slots
        self.max_len = max_len
        self.caches = self.api.init_caches(cfg, slots, max_len,
                                           dtype=cache_dtype)
        self.pos = np.zeros(slots, np.int32)        # next position per slot
        self.active: List[Optional[Request]] = [None] * slots
        self.last_tok = np.zeros((slots, 1), np.int32)
        self._decode = jax.jit(
            lambda p, t, c, po: self.api.decode_step(p, cfg, t, c, po))
        self._prefill_one = jax.jit(
            lambda p, b: self.api.prefill(p, cfg, b, cache_len=max_len,
                                          cache_dtype=cache_dtype))

    # ------------------------------------------------------------------
    def _free_slot(self) -> Optional[int]:
        for i, r in enumerate(self.active):
            if r is None:
                return i
        return None

    def _splice_cache(self, slot: int, new_caches):
        """Copy a freshly prefilled batch-of-one cache into slot ``slot``."""
        def splice(full, one):
            # leaves are (count, B, ...) or (B, ...) or scalars per segment
            if full.ndim >= 2 and full.shape[1] == self.slots \
                    and one.ndim == full.ndim and one.shape[1] == 1:
                return full.at[:, slot:slot + 1].set(one.astype(full.dtype))
            if full.ndim >= 1 and full.shape[0] == self.slots \
                    and one.ndim == full.ndim and one.shape[0] == 1:
                return full.at[slot:slot + 1].set(one.astype(full.dtype))
            return one  # shared scalars (e.g. write cursors)
        self.caches = jax.tree.map(splice, self.caches, new_caches)

    def submit(self, req: Request) -> bool:
        """Admit a request if a slot is free.  Prefills immediately.

        Returns True when the request was *consumed* — admitted to a slot,
        or rejected (``req.failed`` set) because it can never fit the KV
        cache.  A rejection must not take the whole engine down (one
        oversized request in a stream used to assert-crash every other
        in-flight request); it also must not occupy a slot.  False means
        "no free slot, try again later".
        """
        P = len(req.prompt)
        if P + req.max_new_tokens > self.max_len:
            req.done = True
            req.failed = True
            req.output = []
            req.error = (f"prompt ({P}) + max_new_tokens "
                         f"({req.max_new_tokens}) exceeds the engine's "
                         f"max_len ({self.max_len})")
            return True
        slot = self._free_slot()
        if slot is None:
            return False
        batch = {"tokens": jnp.asarray(req.prompt, jnp.int32)[None],
                 "labels": jnp.zeros((1, P), jnp.int32)}
        if self.cfg.family == "vlm":
            npatch = max(1, int(P * self.cfg.vision_patches_frac))
            batch["patch_embeds"] = jnp.zeros((1, npatch, self.cfg.d_model))
            pos = jnp.arange(P)[None]
            batch["positions3"] = jnp.stack([pos, pos, pos])
        if self.cfg.encdec:
            batch["frames"] = jnp.zeros(
                (1, self.cfg.max_source_positions, self.cfg.d_model))
        logits, one_caches = self._prefill_one(self.params, batch)
        self._splice_cache(slot, one_caches)
        req.slot = slot
        req.output = [int(jnp.argmax(logits[0]))]
        self.pos[slot] = P
        self.last_tok[slot, 0] = req.output[-1]
        self.active[slot] = req
        return True

    def step(self) -> int:
        """One decode step for every active slot.  Returns #active."""
        if not any(r is not None for r in self.active):
            return 0
        toks = jnp.asarray(self.last_tok)
        pos = jnp.asarray(self.pos[:, None])
        logits, self.caches = self._decode(self.params, toks, self.caches,
                                           pos)
        nxt = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        n_active = 0
        for i, r in enumerate(self.active):
            if r is None:
                continue
            r.output.append(int(nxt[i]))
            self.pos[i] += 1
            self.last_tok[i, 0] = nxt[i]
            if (len(r.output) >= r.max_new_tokens
                    or (r.eos_id is not None and nxt[i] == r.eos_id)):
                r.done = True
                self.active[i] = None
            else:
                n_active += 1
        return n_active

    def run(self, requests: List[Request]) -> List[Request]:
        """Serve a list of requests to completion (simple FCFS queue)."""
        queue = list(requests)
        while queue or any(r is not None for r in self.active):
            while queue and self.submit(queue[0]):
                queue.pop(0)
            self.step()
        return requests
