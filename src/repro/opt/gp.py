"""Geometric-program solver: log-space primal barrier Newton method.

Standard-form GP:  min f0(x)  s.t.  f_i(x) <= 1,  x > 0,
with f_i posynomials.  In z = log x the problem is convex:
    min LSE_0(z)  s.t.  g_i(z) = LSE_i(z) <= 0.

Textbook log-barrier interior-point, pure NumPy float64.  All constraints are
evaluated *batched*: their (log c, A) rows are concatenated once and per-
constraint log-sum-exps / gradients / Hessian pieces come from segment
reductions — the Newton iteration is a handful of small matmuls.
Strict feasibility comes from a phase-I GP (min S s.t. f_i/S <= 1), itself a
GP with a trivially feasible start.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

from .posy import Posy

__all__ = ["GP", "solve_gp", "GPResult", "BatchedGPResult", "GP_BACKENDS",
           "register_gp_backend", "solve_gp_batch"]


@dataclasses.dataclass
class GP:
    objective: Posy
    constraints: List[Posy]  # each <= 1

    @property
    def n(self) -> int:
        return self.objective.n


@dataclasses.dataclass
class GPResult:
    z: np.ndarray          # log-space optimum
    x: np.ndarray          # exp(z)
    obj: float
    feasible: bool
    max_violation: float   # max_i log f_i (<= 0 when feasible)
    newton_iters: int


class _Batched:
    """Concatenated constraint system with segment reductions."""

    def __init__(self, gp: GP):
        self.n = gp.n
        self.obj_logc = np.log(gp.objective.c)
        self.obj_A = gp.objective.A
        if gp.constraints:
            self.logc = np.concatenate([np.log(c.c) for c in gp.constraints])
            self.A = np.concatenate([c.A for c in gp.constraints], axis=0)
            sizes = np.array([c.n_terms for c in gp.constraints])
            self.starts = np.concatenate([[0], np.cumsum(sizes)[:-1]])
            self.seg = np.repeat(np.arange(len(sizes)), sizes)
            self.m = len(sizes)
        else:
            self.m = 0

    # -- constraint log-values g_i(z) ------------------------------------
    def g(self, z):
        t = self.logc + self.A @ z
        mx = np.maximum.reduceat(t, self.starts)
        s = np.add.reduceat(np.exp(t - mx[self.seg]), self.starts)
        return mx + np.log(s)

    def f0(self, z):
        t = self.obj_logc + self.obj_A @ z
        mx = t.max()
        return float(mx + np.log(np.exp(t - mx).sum()))

    def barrier(self, z, t_scale):
        """(phi, grad, hess) of t*f0 - sum log(-g_i); phi=inf off-domain."""
        # objective part
        t0 = self.obj_logc + self.obj_A @ z
        mx0 = t0.max()
        e0 = np.exp(t0 - mx0)
        s0 = e0.sum()
        w0 = e0 / s0
        f0 = mx0 + np.log(s0)
        q0 = self.obj_A.T @ w0
        H = t_scale * ((self.obj_A.T * w0) @ self.obj_A - np.outer(q0, q0))
        grad = t_scale * q0
        phi = t_scale * f0
        if self.m:
            t = self.logc + self.A @ z
            mx = np.maximum.reduceat(t, self.starts)
            e = np.exp(t - mx[self.seg])
            s = np.add.reduceat(e, self.starts)
            g = mx + np.log(s)
            if np.any(g >= 0.0):
                return np.inf, None, None
            w = e / s[self.seg]
            c = 1.0 / (-g)                        # (m,), > 0
            phi += float(-np.log(-g).sum())
            wc = w * c[self.seg]
            # q_i = A^T w_i  (per constraint), via segment sums
            Q = np.zeros((self.m, self.n))
            np.add.at(Q, self.seg, w[:, None] * self.A)
            grad = grad + Q.T @ c
            H = H + (self.A.T * wc) @ self.A + (Q.T * (c**2 - c)) @ Q
        return phi, grad, H

    def value(self, z, t_scale):
        t0 = self.obj_logc + self.obj_A @ z
        mx0 = t0.max()
        phi = t_scale * float(mx0 + np.log(np.exp(t0 - mx0).sum()))
        if self.m:
            g = self.g(z)
            if np.any(g >= 0.0):
                return np.inf
            phi += float(-np.log(-g).sum())
        return phi


def _newton(bat: _Batched, z: np.ndarray, t: float, tol: float = 1e-9,
            max_iter: int = 200):
    iters = 0
    eye = np.eye(bat.n)
    for _ in range(max_iter):
        phi, grad, hess = bat.barrier(z, t)
        assert np.isfinite(phi), "Newton started outside barrier domain"
        lam = 1e-12
        while True:
            try:
                Lc = np.linalg.cholesky(hess + lam * eye)
                break
            except np.linalg.LinAlgError:
                lam = max(lam * 10.0, 1e-10)
        step = -np.linalg.solve(Lc.T, np.linalg.solve(Lc, grad))
        dec = -grad @ step
        if dec / 2.0 <= tol:
            return z, iters
        alpha, beta, a = 0.25, 0.5, 1.0
        gs = grad @ step
        for _ in range(60):
            phin = bat.value(z + a * step, t)
            if np.isfinite(phin) and phin <= phi + alpha * a * gs:
                break
            a *= beta
        else:
            return z, iters  # stalled
        z = z + a * step
        iters += 1
    return z, iters


def _phase_one(gp: GP, z0: np.ndarray, target_margin: float = 1e-3):
    """Strictly feasible z via the auxiliary GP  min S, f_i/S <= 1."""
    n = gp.n
    aug_cons = [Posy(c.c, np.concatenate([c.A, -np.ones((c.n_terms, 1))],
                                         axis=1))
                for c in gp.constraints]
    A_obj = np.zeros((1, n + 1))
    A_obj[0, -1] = 1.0
    aug = GP(Posy(np.array([1.0]), A_obj), aug_cons)
    bat_orig = _Batched(gp)
    bat = _Batched(aug)
    s0 = float(bat_orig.g(z0).max()) + 1.0
    za = np.concatenate([z0, [s0]])
    t = 1.0
    total = 0
    for _ in range(40):
        za, it = _newton(bat, za, t)
        total += it
        if za[-1] < -target_margin \
                and float(bat_orig.g(za[:n]).max()) < -target_margin:
            return za[:n], True, total
        if len(aug_cons) / t < 1e-9:
            break
        t *= 20.0
    z = za[:n]
    return z, bool(bat_orig.g(z).max() < 0.0), total


def solve_gp(gp: GP, z0: Optional[np.ndarray] = None, tol_gap: float = 1e-8,
             t0: float = 1.0, mu: float = 20.0) -> GPResult:
    n = gp.n
    z = np.zeros(n) if z0 is None else np.asarray(z0, dtype=np.float64).copy()
    bat = _Batched(gp)
    total_iters = 0
    if bat.m and float(bat.g(z).max()) >= 0.0:
        z, ok, it = _phase_one(gp, z)
        total_iters += it
        if not ok:
            viol = float(bat.g(z).max())
            return GPResult(z, np.exp(z), gp.objective.value(z), False, viol,
                            total_iters)
    if not bat.m:
        z, it = _newton(bat, z, 1.0)
        return GPResult(z, np.exp(z), gp.objective.value(z), True, -np.inf, it)
    t = t0
    while True:
        z, it = _newton(bat, z, t)
        total_iters += it
        if bat.m / t < tol_gap:
            break
        t *= mu
    viol = float(bat.g(z).max())
    return GPResult(z, np.exp(z), gp.objective.value(z), viol <= 1e-7, viol,
                    total_iters)


# ---------------------------------------------------------------------------
# batched solving: pluggable backends (mirroring repro.compress.backends)
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class BatchedGPResult:
    """Per-instance results of one batched solve (leading axis = batch)."""
    z: np.ndarray              # (B, n) log-space optima
    obj: np.ndarray            # (B,)
    feasible: np.ndarray       # (B,) bool
    max_violation: np.ndarray  # (B,)
    newton_iters: np.ndarray   # (B,) int


def _solve_batch_numpy(pack) -> BatchedGPResult:
    """Reference backend: the scalar interior point looped over the batch.

    Operates on the unpadded per-instance GPs, so each active row is
    bit-identical to a standalone :func:`solve_gp` call — the parity anchor
    for every other backend.  Inactive rows return placeholders (z0
    passthrough, infeasible) that callers must not read.
    """
    rs = [solve_gp(gp, pack.z0[i]) if pack.active[i] else
          GPResult(pack.z0[i], np.exp(pack.z0[i]), np.nan, False, np.inf, 0)
          for i, gp in enumerate(pack.gps)]
    return BatchedGPResult(
        z=np.stack([r.z for r in rs]),
        obj=np.array([r.obj for r in rs]),
        feasible=np.array([r.feasible for r in rs], dtype=bool),
        max_violation=np.array([r.max_violation for r in rs]),
        newton_iters=np.array([r.newton_iters for r in rs], dtype=np.int64))


GP_BACKENDS = {"numpy": _solve_batch_numpy}


def register_gp_backend(name: str, solve_batch) -> None:
    """Register ``solve_batch(pack: PackedBatch) -> BatchedGPResult``."""
    GP_BACKENDS[str(name)] = solve_batch


def solve_gp_batch(pack, backend: str = "numpy") -> BatchedGPResult:
    """Solve every instance of a :class:`~repro.opt.structure.PackedBatch`.

    ``backend="numpy"`` loops the reference scalar solver; ``backend="jnp"``
    dispatches the whole batch to one jitted+vmapped interior point
    (:mod:`repro.opt.gp_jax`), compiled once per padded structure shape.
    (The GIA-level ``backend="jnp-fused"`` never reaches this function — it
    fuses the whole outer loop in :mod:`repro.opt.gia_jax`.)
    """
    if backend == "jnp" and backend not in GP_BACKENDS:
        from . import gp_jax  # noqa: F401  (registers itself on import)
    try:
        fn = GP_BACKENDS[backend]
    except KeyError:
        raise ValueError(f"unknown GP backend {backend!r}; registered: "
                         f"{sorted(GP_BACKENDS)}") from None
    return fn(pack)
