"""Device-side CGP condensation: the GIA coefficient refresh as tensor updates.

Since PR 3 the inner GP solve is one jitted, vmapped interior point, but every
GIA outer iteration still round-tripped to the host to rebuild surrogate
coefficients in Python (``condense.amgm_monomial`` / Taylor bounds →
``problems.conv_block`` → re-pack).  This module closes that gap: a
:class:`RefreshPlan` is traced **once per structure signature** from a
problem's skeleton — which coefficient slots of the packed ``(log c, A,
segment-id)`` tensors depend on the expansion point z, and how — and
:func:`make_refresh` emits the matching jnp update, so the whole refresh is a
handful of vectorized ops inside the fused solver loop
(:mod:`repro.opt.gia_jax`) with zero host syncs.

The device arithmetic mirrors the NumPy surrogate constructors operation for
operation (same products, same reciprocals, same max-shifted softmax weights
in the AM-GM condensation), so the refreshed coefficients agree with
``conv_block`` to ulp level in log-space — asserted across the full
(m, family, step-rule) grid by the parity suite.

Plan layout per objective m (term counts are z-independent, so every slot is
static; only the m=E surrogate (32) flips between 2 and 1 live terms, which
the plan handles with one padded slot):

  C:  [ head/M | mid | tail/M ]                       M = AM-GM(sum_n K_n)
  J:  [ head/M | mid | tail/M ] [ gamma_cap ]
  D:  [ (head/M | mid | tail/M | b·C_max) / (C_max·a·K0) ]   a,b Taylor(K0)
  E:  [ num/M_den ] [ (32) 2-slot branch ] [ (33) ] [ x0_cap ]

Free-cohort sampling (``sampled=True``, repro.sampling models with the "S"
variable) replaces the C/J/D layouts with the exact ratio form built by
``problems._conv_static`` — the whole constraint multiplied through by
sum_n eps_n K_n, the participation penalty's negative part in the
denominator:

  C:  [ fs_num / AM-GM(fs_den) ]
  J:  [ fs_num / AM-GM(fs_den) ] [ gamma_cap ]
  D:  [ (fs_num | b·fs_numB) / AM-GM(a·fs_denK | fs_denQ) ]  a,b Taylor(K0)

m=E is untouched: its num/den ratio absorbs the extra static terms and the
existing refresh is term-count-agnostic.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

import numpy as np

from .problems import Objective, ParamOptProblem
from .structure import PAD_LOGC, structure_signature

__all__ = ["RefreshPlan", "make_refresh", "make_project"]

#: the (32)/(33) interior margin of problems._conv_constraint, bit-identical
_DELTA = float(np.exp(-3e-3))


def _row(posys) -> Tuple[np.ndarray, np.ndarray]:
    """Stack 1-term posynomials into ((B,) coeffs, (B, n) exponent rows)."""
    return (np.stack([p.c[0] for p in posys]),
            np.stack([p.A[0] for p in posys]))


def _terms(posys) -> Tuple[np.ndarray, np.ndarray]:
    """Stack same-shape posynomials into ((B, K) coeffs, (B, K, n))."""
    return np.stack([p.c for p in posys]), np.stack([p.A for p in posys])


@dataclasses.dataclass
class RefreshPlan:
    """One structure signature's fused-solver inputs.

    Static layout (``caps``, ``seg``, objective kind) keys the compiled
    program; the per-instance tensors (objective, packed skeleton, and the
    m-specific surrogate coefficients in ``arrays``) are its runtime
    arguments.  Built once per batch — the GIA loop never re-packs.
    """

    m: Objective
    n: int                      # number of optimization variables
    m_cons: int                 # constraint count incl. conv block
    caps: Tuple[int, ...]       # per-conv-constraint term capacities
    seg: np.ndarray             # (T,) int32 constraint id per packed term
    i_x0: int                   # index of the X0 variable (m=E), else -1
    obj_logc: np.ndarray        # (B, K_obj)
    obj_A: np.ndarray           # (B, K_obj, n)
    skel_logc: np.ndarray       # (B, T_common) z-independent constraints
    skel_A: np.ndarray          # (B, T_common, n)
    arrays: Dict[str, np.ndarray]   # m-specific refresh coefficients
    sampled: bool = False       # free-cohort (ratio-form) C/J/D conv block

    @property
    def batch(self) -> int:
        return self.obj_logc.shape[0]

    @property
    def signature_key(self) -> tuple:
        """Hashable static layout — one compiled fused program per value."""
        return (self.m.value, self.n, self.m_cons, self.caps,
                self.seg.tobytes(), self.i_x0, self.sampled)

    # ------------------------------------------------------------------
    @classmethod
    def build(cls, problems: Sequence[ParamOptProblem]) -> "RefreshPlan":
        problems = list(problems)
        sig = structure_signature(problems[0])
        for p in problems[1:]:
            if structure_signature(p) != sig:
                raise ValueError(
                    f"refresh plan needs one structure signature, got both "
                    f"{sig} and {structure_signature(p)}")
        p0 = problems[0]
        m, v = p0.m, p0.vmap
        sts = [p._conv_static for p in problems]
        st0 = sts[0]

        objs = [p.skeleton[0] for p in problems]
        obj_c, obj_A = _terms(objs)
        skels = [p.packed_skeleton for p in problems]
        skel_logc = np.stack([s[0] for s in skels])
        skel_A = np.stack([s[1] for s in skels])
        common_sizes = [c.n_terms for c in p0.skeleton[1]]

        a: Dict[str, np.ndarray] = {}
        sampled = "fs_num" in st0
        if sampled:
            a["fsnum_c"], a["fsnum_A"] = _terms([st["fs_num"] for st in sts])
            if m is Objective.DIMINISHING:
                a["fsnumB_c"], a["fsnumB_A"] = _terms(
                    [st["fs_numB"] for st in sts])
                a["fsdenK_c"], a["fsdenK_A"] = _terms(
                    [st["fs_denK"] for st in sts])
                a["fsdenQ_c"], a["fsdenQ_A"] = _terms(
                    [st["fs_denQ"] for st in sts])
                a["rho"] = np.array([p.rho for p in problems],
                                    dtype=np.float64)
                a["K0_c"], a["K0_A"] = _row([p.vmap.K0 for p in problems])
                caps = (st0["fs_num"].n_terms + st0["fs_numB"].n_terms,)
            else:
                fsden_c, a["fsden_A"] = _terms([st["fs_den"] for st in sts])
                a["fsden_logc"] = np.log(fsden_c)
                if m is Objective.JOINT:
                    gcap_c, a["gcap_A"] = _terms(
                        [st["gamma_cap"] for st in sts])
                    a["gcap_logc"] = np.log(gcap_c)
                    caps = (st0["fs_num"].n_terms, 1)
                else:
                    caps = (st0["fs_num"].n_terms,)
            sizes = np.asarray(common_sizes + list(caps), dtype=np.int64)
            seg = np.repeat(np.arange(sizes.size, dtype=np.int32), sizes)
            return cls(m=m, n=v.n, m_cons=int(sizes.size), caps=caps,
                       seg=seg, i_x0=-1, obj_logc=np.log(obj_c), obj_A=obj_A,
                       skel_logc=skel_logc, skel_A=skel_A, arrays=a,
                       sampled=True)
        if m is Objective.EXPONENTIAL:
            a["num_c"], a["num_A"] = _terms([st["num"] for st in sts])
            den_c, a["den_A"] = _terms([st["den"] for st in sts])
            a["den_logc"] = np.log(den_c)
            a["lamX0K0_c"], a["lamX0K0_A"] = _row(
                [st["lam_X0K0"] for st in sts])
            a["lamX0K0_logc"] = np.log(a["lamX0K0_c"])
            a["lamK0_c"], a["lamK0_A"] = _row([st["lam_K0"] for st in sts])
            x0cap_c, a["x0cap_A"] = _terms([st["x0_cap"] for st in sts])
            a["x0cap_logc"] = np.log(x0cap_c)
            a["X0_c"], a["X0_A"] = _row([p.vmap.extra for p in problems])
            a["K0_c"], a["K0_A"] = _row([p.vmap.K0 for p in problems])
            a["K0_logc"] = np.log(a["K0_c"])
            a["log_rho"] = np.log(np.array([p.rho for p in problems],
                                           dtype=np.float64))
            caps = (st0["num"].n_terms, 2, 2, 1)
            i_x0 = v.names.index("extra")
        else:
            sumK_c, a["sumK_A"] = _terms([st["sumK"] for st in sts])
            a["sumK_logc"] = np.log(sumK_c)
            a["head_c"], a["head_A"] = _terms(
                [st["overM_head"] for st in sts])
            mid_c, a["mid_A"] = _terms([st["mid"] for st in sts])
            a["mid_c"], a["mid_logc"] = mid_c, np.log(mid_c)
            a["tail_c"], a["tail_A"] = _terms(
                [st["overM_tail"] for st in sts])
            base = (st0["overM_head"].n_terms + st0["mid"].n_terms
                    + st0["overM_tail"].n_terms)
            if m is Objective.JOINT:
                gcap_c, a["gcap_A"] = _terms([st["gamma_cap"] for st in sts])
                a["gcap_logc"] = np.log(gcap_c)
                caps = (base, 1)
            elif m is Objective.DIMINISHING:
                a["rho"] = np.array([p.rho for p in problems],
                                    dtype=np.float64)
                a["Cmax"] = np.array([p.C_max for p in problems],
                                     dtype=np.float64)
                a["K0_c"], a["K0_A"] = _row([p.vmap.K0 for p in problems])
                caps = (base + 1,)
            else:
                caps = (base,)
            i_x0 = -1

        sizes = np.asarray(common_sizes + list(caps), dtype=np.int64)
        seg = np.repeat(np.arange(sizes.size, dtype=np.int32), sizes)
        return cls(m=m, n=v.n, m_cons=int(sizes.size), caps=caps, seg=seg,
                   i_x0=i_x0, obj_logc=np.log(obj_c), obj_A=obj_A,
                   skel_logc=skel_logc, skel_A=skel_A, arrays=a)


# ---------------------------------------------------------------------------
# the jnp refresh — mirrors condense.py / problems._conv_constraint exactly
# ---------------------------------------------------------------------------
def _amgm_jnp(logc, A, z):
    """jnp mirror of :func:`repro.opt.condense.amgm_monomial` (same shifted
    softmax, same 0·log0 masking) on precomputed term logs."""
    import jax.numpy as jnp

    t = logc + A @ z
    mx = jnp.max(t)
    e = jnp.exp(t - mx)
    beta = e / jnp.sum(e)
    keep = beta > 0.0
    logc_m = jnp.sum(jnp.where(
        keep, beta * (logc - jnp.log(jnp.where(keep, beta, 1.0))), 0.0))
    A_m = jnp.sum(beta[:, None] * A, axis=0)
    return logc_m, A_m


def make_refresh(m: Objective, n: int, caps: Tuple[int, ...],
                 sampled: bool = False):
    """The per-instance coefficient refresh ``(z, arrays) -> (logc, A)`` for
    one conv block, as pure jnp (vmapped/jitted by the fused solver).

    Output shapes are ``(sum(caps),)`` / ``(sum(caps), n)`` — the conv
    segment of the packed constraint tensors; unused slots carry
    :data:`~repro.opt.structure.PAD_LOGC`.  ``sampled`` selects the
    free-cohort ratio-form C/J/D refresh (m=E needs no variant).
    """
    import jax.numpy as jnp

    if sampled and m in (Objective.CONSTANT, Objective.JOINT):

        def refresh(z, a):
            # mirror of ratio_to_posy(fs_num, fs_den, z): num coefficients
            # divided by the AM-GM-condensed denominator monomial
            logc_d, A_d = _amgm_jnp(a["fsden_logc"], a["fsden_A"], z)
            logc = jnp.log(a["fsnum_c"] * (1.0 / jnp.exp(logc_d)))
            A = a["fsnum_A"] - A_d
            if m is Objective.JOINT:
                logc = jnp.concatenate([logc, a["gcap_logc"]])
                A = jnp.concatenate([A, a["gcap_A"]])
            return logc, A

        return refresh

    if sampled and m is Objective.DIMINISHING:

        def refresh(z, a):
            rho = a["rho"]
            k0 = jnp.exp(z @ a["K0_A"]) * a["K0_c"]
            # same Taylor lower bound of phi(K0) as the pinned branch
            at = (jnp.log((k0 + rho + 1.0) / (rho + 1.0))
                  + k0 / (k0 + rho + 1.0))
            bt = k0 ** 2 / (k0 + rho + 1.0)
            den_logc = jnp.log(jnp.concatenate(
                [a["fsdenK_c"] * at, a["fsdenQ_c"]]))
            den_A = jnp.concatenate([a["fsdenK_A"], a["fsdenQ_A"]])
            logc_d, A_d = _amgm_jnp(den_logc, den_A, z)
            num_c = jnp.concatenate([a["fsnum_c"], a["fsnumB_c"] * bt])
            num_A = jnp.concatenate([a["fsnum_A"], a["fsnumB_A"]])
            return (jnp.log(num_c * (1.0 / jnp.exp(logc_d))), num_A - A_d)

        return refresh

    if m in (Objective.CONSTANT, Objective.JOINT):

        def refresh(z, a):
            logc_m, A_m = _amgm_jnp(a["sumK_logc"], a["sumK_A"], z)
            inv = 1.0 / jnp.exp(logc_m)
            logc = jnp.concatenate([jnp.log(a["head_c"] * inv),
                                    a["mid_logc"],
                                    jnp.log(a["tail_c"] * inv)])
            A = jnp.concatenate([a["head_A"] - A_m, a["mid_A"],
                                 a["tail_A"] - A_m])
            if m is Objective.JOINT:
                logc = jnp.concatenate([logc, a["gcap_logc"]])
                A = jnp.concatenate([A, a["gcap_A"]])
            return logc, A

        return refresh

    if m is Objective.DIMINISHING:

        def refresh(z, a):
            logc_m, A_m = _amgm_jnp(a["sumK_logc"], a["sumK_A"], z)
            rho, cmax = a["rho"], a["Cmax"]
            k0 = jnp.exp(z @ a["K0_A"]) * a["K0_c"]
            # Taylor lower bound of phi(K0) = K0 log((K0+rho+1)/(rho+1))
            at = (jnp.log((k0 + rho + 1.0) / (rho + 1.0))
                  + k0 / (k0 + rho + 1.0))
            bt = k0 ** 2 / (k0 + rho + 1.0)
            inv = 1.0 / jnp.exp(logc_m)
            lhs_c = jnp.concatenate([a["head_c"] * inv, a["mid_c"],
                                     a["tail_c"] * inv, (bt * cmax)[None]])
            lhs_A = jnp.concatenate([a["head_A"] - A_m, a["mid_A"],
                                     a["tail_A"] - A_m, jnp.zeros((1, n))])
            den_c = a["K0_c"] * (cmax * at)
            return (jnp.log(lhs_c * (1.0 / den_c)),
                    lhs_A - a["K0_A"][None, :])

        return refresh

    if m is Objective.EXPONENTIAL:

        def refresh(z, a):
            # (31): num / AM-GM(den)
            logc_md, A_md = _amgm_jnp(a["den_logc"], a["den_A"], z)
            c1_logc = jnp.log(a["num_c"] * (1.0 / jnp.exp(logc_md)))
            c1_A = a["num_A"] - A_md
            x0 = jnp.exp(z @ a["X0_A"]) * a["X0_c"]
            # (32): X0 log(1/X0) <= X0 K0 log(1/rho), Taylor at X0_prev;
            # a negative slope moves across the inequality (2-term branch
            # collapses to 1 live term + one padded slot)
            at = jnp.log(1.0 / x0) - 1.0
            bt = x0
            pos_logc = jnp.log(jnp.stack([a["X0_c"] * at, bt])
                               * (1.0 / a["lamX0K0_c"]) * _DELTA)
            pos_A = (jnp.stack([a["X0_A"], jnp.zeros(n)])
                     - a["lamX0K0_A"][None, :])
            d32_logc = jnp.stack([a["lamX0K0_logc"],
                                  jnp.log(a["X0_c"] * (-at))])
            d32_A = jnp.stack([a["lamX0K0_A"], a["X0_A"]])
            logc_m32, A_m32 = _amgm_jnp(d32_logc, d32_A, z)
            neg_logc = jnp.stack(
                [jnp.log(bt * (1.0 / jnp.exp(logc_m32)) * _DELTA),
                 jnp.full((), PAD_LOGC)])
            neg_A = jnp.stack([-A_m32, jnp.zeros(n)])
            c2_logc = jnp.where(at >= 0, pos_logc, neg_logc)
            c2_A = jnp.where(at >= 0, pos_A, neg_A)
            # (33): K0 log(1/rho) + aX X0 <= -bX, affine bound of log X0
            ax = 1.0 / x0
            rhs = -(jnp.log(x0) - 1.0)
            c3_logc = jnp.log(jnp.stack([a["lamK0_c"], a["X0_c"] * ax])
                              * (1.0 / rhs) * _DELTA)
            c3_A = jnp.stack([a["lamK0_A"], a["X0_A"]])
            return (jnp.concatenate([c1_logc, c2_logc, c3_logc,
                                     a["x0cap_logc"]]),
                    jnp.concatenate([c1_A, c2_A, c3_A, a["x0cap_A"]]))

        return refresh

    raise ValueError(m)


def make_project(m: Objective, i_x0: int):
    """jnp mirror of :meth:`ParamOptProblem.project_expansion` — re-imposes
    X0 = rho^{K0} exactly before every m=E refresh; identity otherwise."""
    import jax.numpy as jnp

    if m is not Objective.EXPONENTIAL:
        return lambda z, a: z

    def project(z, a):
        k0 = jnp.exp(a["K0_logc"] + a["K0_A"] @ z)
        return z.at[i_x0].set(k0 * a["log_rho"])

    return project
