"""GIA outer loops — Algorithms 2, 3, 4, 5 — plus integer recovery.

``solve_param_opt`` runs the successive-GP refinement of a
:class:`~repro.opt.problems.ParamOptProblem` to a KKT point of the continuous
relaxation and then constructs a nearly-optimal integer point (the paper
relaxes K, B to reals and notes integer recovery is straightforward).

``solve_param_opt_batched`` is the same algorithm in lockstep over a batch of
instances sharing one structure signature (same objective m, family varmap,
worker count — e.g. one Fig.-5 sweep line): every outer iteration refreshes
all expansion-point coefficients and performs the whole batch's GP solves in
one :func:`~repro.opt.gp.solve_gp_batch` call, with per-instance
convergence / stall masks freezing finished instances.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence

import numpy as np

from .gp import GPResult, solve_gp, solve_gp_batch
from .problems import Objective, ParamOptProblem
from .structure import GPStructure, structure_signature

__all__ = ["GIAResult", "solve_param_opt", "solve_param_opt_batched",
           "min_feasible_K0"]


@dataclasses.dataclass
class GIAResult:
    converged: bool
    feasible: bool
    iterations: int
    z: np.ndarray                  # final log-space point (continuous)
    x: Dict[str, float]            # named continuous solution
    K0: int
    Kn: np.ndarray                 # integer per-worker local iterations
    B: int
    gamma: Optional[float]         # optimized step size (m="J" only)
    E: float                       # true energy cost at the integer point
    T: float
    C: float
    history: List[float]           # objective per GIA iteration


def _extract(problem: ParamOptProblem, z: np.ndarray):
    v = problem.vmap
    K0 = float(np.exp(v.K0.logvalue(z)))
    Kn = np.array([float(np.exp(k.logvalue(z))) for k in v.Kn])
    B = float(np.exp(v.B.logvalue(z)))
    extra = float(np.exp(v.extra.logvalue(z))) if v.extra is not None else None
    return K0, Kn, B, extra


def solve_param_opt(problem: ParamOptProblem,
                    z0: Optional[np.ndarray] = None,
                    tol: float = 1e-4, max_iter: int = 60,
                    verbose: bool = False) -> GIAResult:
    z = problem.z_init() if z0 is None else np.asarray(z0, dtype=np.float64)
    history: List[float] = []
    converged = False
    res: Optional[GPResult] = None
    stall = 0
    for it in range(max_iter):
        z = problem.project_expansion(z)
        gp = problem.build(z)
        res = solve_gp(gp, z)
        if not res.feasible:
            # The *approximate* problem can be infeasible away from a good
            # expansion point; the phase-I minimizer inside solve_gp is the
            # min-slack point — rebuild the surrogates there and retry.
            z = res.z
            stall += 1
            if stall > 8:
                break
            continue
        stall = 0
        step = float(np.max(np.abs(res.z - z)))
        z = res.z
        history.append(res.obj)
        if verbose:
            print(f"  GIA iter {it}: E={res.obj:.6g} step={step:.3g}")
        if step < tol:
            converged = True
            break
    return _finalize(problem, z, history, converged)


def solve_param_opt_batched(problems: Sequence[ParamOptProblem],
                            z0s: Optional[Sequence[np.ndarray]] = None,
                            tol: float = 1e-4, max_iter: int = 60,
                            backend: str = "jnp",
                            verbose: bool = False) -> List[GIAResult]:
    """Lockstep-batched ``solve_param_opt`` over same-structure instances.

    Per-instance semantics match the scalar loop exactly: each instance sees
    the same sequence of expansion points, phase-I retries, and stall exits
    it would see standalone (the ``backend="numpy"`` path is bit-identical
    row-for-row); ``backend="jnp"`` performs each iteration's GP solves in
    one jitted, vmapped interior-point call.
    """
    problems = list(problems)
    if not problems:
        return []
    sig = structure_signature(problems[0])
    for p in problems[1:]:
        if structure_signature(p) != sig:
            raise ValueError(
                f"batched GIA needs one structure signature, got both {sig} "
                f"and {structure_signature(p)}; group instances by "
                f"(m, family, N) first")
    B = len(problems)
    if z0s is None:
        zs = [p.z_init() for p in problems]
    else:
        zs = [np.asarray(z, dtype=np.float64).copy() for z in z0s]
    structure = GPStructure(problems[0])
    history: List[List[float]] = [[] for _ in range(B)]
    converged = [False] * B
    active = [True] * B
    stall = [0] * B
    for it in range(max_iter):
        if not any(active):
            break
        pack = structure.pack_batch(problems, zs, active=active)
        # projected expansion points (inactive rows keep their final z —
        # their pack rows are stale placeholders the backends skip)
        zs = [pack.z0[i] if active[i] else zs[i] for i in range(B)]
        res = solve_gp_batch(pack, backend=backend)
        for i in range(B):
            if not active[i]:
                continue
            if not res.feasible[i]:
                zs[i] = res.z[i]                # retry from min-slack point
                stall[i] += 1
                if stall[i] > 8:
                    active[i] = False
                continue
            stall[i] = 0
            step = float(np.max(np.abs(res.z[i] - zs[i])))
            zs[i] = res.z[i]
            history[i].append(float(res.obj[i]))
            if verbose:
                print(f"  GIA[{i}] iter {it}: E={res.obj[i]:.6g} "
                      f"step={step:.3g}")
            if step < tol:
                converged[i] = True
                active[i] = False
    return [_finalize(p, np.asarray(zs[i], dtype=np.float64), history[i],
                      converged[i])
            for i, p in enumerate(problems)]


def _finalize(problem: ParamOptProblem, z: np.ndarray,
              history: List[float], converged: bool) -> GIAResult:
    """Integer recovery + true-constraint evaluation at the continuous point."""
    _, _, _, extra = _extract(problem, z)
    K0i, Kni, Bi, _ = _round_integer(problem, z, extra)
    ev = problem.evaluate(K0i, Kni, Bi, extra)
    v = problem.vmap
    named = {name: float(np.exp(z[i])) for i, name in enumerate(v.names)}
    return GIAResult(
        converged=converged,
        feasible=problem.feasible(K0i, Kni, Bi, extra),
        iterations=len(history), z=z, x=named,
        K0=K0i, Kn=Kni, B=Bi,
        gamma=extra if problem.m is Objective.JOINT else problem.gamma,
        E=ev["E"], T=ev["T"], C=ev["C"], history=list(history))


def min_feasible_K0(problem: ParamOptProblem, Kn, B,
                    extra: Optional[float] = None, K0_lo: int = 1,
                    ctol: float = 1e-9, ttol: float = 1e-9,
                    max_doublings: int = 200):
    """Smallest integer ``K0 >= K0_lo`` with ``C(K0) <= C_max*(1+ctol)``.

    ``C_m`` is non-increasing and ``T`` non-decreasing in ``K0``, so the
    search is exponential bracketing plus monotone bisection (~2 log2(K0*)
    ``evaluate`` calls); a bracket point that already blows the time budget
    while C is still unmet certifies infeasibility.  Returns ``(K0, ok)``
    where ``ok`` additionally requires ``T(K0) <= T_max*(1+ttol)``.
    """
    C_cap = problem.C_max * (1 + ctol)
    T_cap = problem.T_max * (1 + ttol)
    ev = problem.evaluate(K0_lo, Kn, B, extra)
    if ev["C"] <= C_cap:
        return K0_lo, ev["T"] <= T_cap
    lo, hi = K0_lo, K0_lo
    for _ in range(max_doublings):
        if ev["T"] > problem.T_max:
            return hi, False            # time budget dies before C is met
        lo, hi = hi, hi * 2
        ev = problem.evaluate(hi, Kn, B, extra)
        if ev["C"] <= C_cap:
            break
    else:
        return hi, False
    # invariant: C(lo) > C_cap >= C(hi); bisect to the smallest C-ok K0
    while hi - lo > 1:
        mid = (lo + hi) // 2
        if problem.evaluate(mid, Kn, B, extra)["C"] <= C_cap:
            hi = mid
        else:
            lo = mid
    return hi, problem.evaluate(hi, Kn, B, extra)["T"] <= T_cap


def _round_integer(problem: ParamOptProblem, z: np.ndarray,
                   extra: Optional[float]):
    """Construct a feasible integer (K0, Kn, B) near the continuous optimum.

    Rounding happens in the *actual* variable space (so baselines with tied
    variables — e.g. FedAvg's K_n = l·I_n/B — keep their structure), then the
    paper variables are re-derived from the monomial map.  C_m is
    non-increasing in K0 for every rule, so each rounding takes the smallest
    K0 restoring C <= C_max (via :func:`min_feasible_K0` bisection) and the
    least-energy feasible candidate wins.
    """
    v = problem.vmap
    int_idx = [i for i, nm in enumerate(v.names)
               if nm == "K0" or nm.startswith("K") or nm in ("l", "B")]
    best = None
    for mode in (math.floor, round, math.ceil):
        zc = z.copy()
        for i in int_idx:
            zc[i] = np.log(max(1, mode(float(np.exp(z[i])))))
        K0f, Knf, Bf, _ = _extract(problem, zc)
        Kni = np.maximum(1, np.ceil(Knf - 1e-9)).astype(np.int64)
        Bi = max(1, int(round(Bf)))
        K0i, ok = min_feasible_K0(problem, Kni, Bi, extra,
                                  K0_lo=max(1, math.floor(K0f)))
        if not ok:
            continue
        ev = problem.evaluate(K0i, Kni, Bi, extra)
        if best is None or ev["E"] < best[3]:
            best = (K0i, Kni, Bi, ev["E"])
    if best is None:
        # fall back to the ceil point even if (slightly) infeasible
        K0f, Knf, Bf, _ = _extract(problem, z)
        Kni = np.maximum(1, np.ceil(Knf)).astype(np.int64)
        Bi = max(1, math.ceil(Bf))
        K0i = max(1, math.ceil(K0f))
        ev = problem.evaluate(K0i, Kni, Bi, extra)
        best = (K0i, Kni, Bi, ev["E"])
    return best
