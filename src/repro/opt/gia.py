"""GIA outer loops — Algorithms 2, 3, 4, 5 — plus integer recovery.

``solve_param_opt`` runs the successive-GP refinement of a
:class:`~repro.opt.problems.ParamOptProblem` to a KKT point of the continuous
relaxation and then constructs a nearly-optimal integer point (the paper
relaxes K, B to reals and notes integer recovery is straightforward).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional

import numpy as np

from .gp import GP, GPResult, solve_gp
from .problems import ParamOptProblem

__all__ = ["GIAResult", "solve_param_opt"]


@dataclasses.dataclass
class GIAResult:
    converged: bool
    feasible: bool
    iterations: int
    z: np.ndarray                  # final log-space point (continuous)
    x: Dict[str, float]            # named continuous solution
    K0: int
    Kn: np.ndarray                 # integer per-worker local iterations
    B: int
    gamma: Optional[float]         # optimized step size (m="J" only)
    E: float                       # true energy cost at the integer point
    T: float
    C: float
    history: List[float]           # objective per GIA iteration


def _extract(problem: ParamOptProblem, z: np.ndarray):
    v = problem.vmap
    K0 = float(np.exp(v.K0.logvalue(z)))
    Kn = np.array([float(np.exp(k.logvalue(z))) for k in v.Kn])
    B = float(np.exp(v.B.logvalue(z)))
    extra = float(np.exp(v.extra.logvalue(z))) if v.extra is not None else None
    return K0, Kn, B, extra


def solve_param_opt(problem: ParamOptProblem,
                    z0: Optional[np.ndarray] = None,
                    tol: float = 1e-4, max_iter: int = 60,
                    verbose: bool = False) -> GIAResult:
    z = problem.z_init() if z0 is None else np.asarray(z0, dtype=np.float64)
    history: List[float] = []
    converged = False
    res: Optional[GPResult] = None
    stall = 0
    for it in range(max_iter):
        z = problem.project_expansion(z)
        gp = problem.build(z)
        res = solve_gp(gp, z)
        if not res.feasible:
            # The *approximate* problem can be infeasible away from a good
            # expansion point; the phase-I minimizer inside solve_gp is the
            # min-slack point — rebuild the surrogates there and retry.
            z = res.z
            stall += 1
            if stall > 8:
                break
            continue
        stall = 0
        step = float(np.max(np.abs(res.z - z)))
        z = res.z
        history.append(res.obj)
        if verbose:
            print(f"  GIA iter {it}: E={res.obj:.6g} step={step:.3g}")
        if step < tol:
            converged = True
            break

    K0c, Knc, Bc, extra = _extract(problem, z)
    K0i, Kni, Bi, Ei = _round_integer(problem, z, extra)
    ev = problem.evaluate(K0i, Kni, Bi, extra)
    v = problem.vmap
    named = {name: float(np.exp(z[i])) for i, name in enumerate(v.names)}
    return GIAResult(
        converged=converged,
        feasible=problem.feasible(K0i, Kni, Bi, extra),
        iterations=len(history), z=z, x=named,
        K0=K0i, Kn=Kni, B=Bi, gamma=extra if problem.m == "J" else problem.gamma,
        E=ev["E"], T=ev["T"], C=ev["C"], history=history)


def _round_integer(problem: ParamOptProblem, z: np.ndarray,
                   extra: Optional[float]):
    """Construct a feasible integer (K0, Kn, B) near the continuous optimum.

    Rounding happens in the *actual* variable space (so baselines with tied
    variables — e.g. FedAvg's K_n = l·I_n/B — keep their structure), then the
    paper variables are re-derived from the monomial map.  C_m is
    non-increasing in K0 for every rule, so for each rounding we take the
    smallest K0 restoring C <= C_max and keep the least-energy feasible
    candidate.
    """
    v = problem.vmap
    int_idx = [i for i, nm in enumerate(v.names)
               if nm == "K0" or nm.startswith("K") or nm in ("l", "B")]
    best = None
    for mode in (math.floor, round, math.ceil):
        zc = z.copy()
        for i in int_idx:
            zc[i] = np.log(max(1, mode(float(np.exp(z[i])))))
        K0f, Knf, Bf, _ = _extract(problem, zc)
        Kni = np.maximum(1, np.ceil(Knf - 1e-9)).astype(np.int64)
        Bi = max(1, int(round(Bf)))
        K0i = max(1, math.floor(K0f))
        ok = False
        for _ in range(200000):
            ev = problem.evaluate(K0i, Kni, Bi, extra)
            if ev["C"] <= problem.C_max * (1 + 1e-9):
                ok = ev["T"] <= problem.T_max * (1 + 1e-9)
                break
            if ev["T"] > problem.T_max:
                break
            K0i += 1
        if not ok:
            continue
        ev = problem.evaluate(K0i, Kni, Bi, extra)
        if best is None or ev["E"] < best[3]:
            best = (K0i, Kni, Bi, ev["E"])
    if best is None:
        # fall back to the ceil point even if (slightly) infeasible
        K0f, Knf, Bf, _ = _extract(problem, z)
        Kni = np.maximum(1, np.ceil(Knf)).astype(np.int64)
        Bi = max(1, math.ceil(Bf))
        K0i = max(1, math.ceil(K0f))
        ev = problem.evaluate(K0i, Kni, Bi, extra)
        best = (K0i, Kni, Bi, ev["E"])
    return best
