"""GIA outer loops — Algorithms 2, 3, 4, 5 — plus integer recovery.

``solve_param_opt`` runs the successive-GP refinement of a
:class:`~repro.opt.problems.ParamOptProblem` to a KKT point of the continuous
relaxation and then constructs a nearly-optimal integer point (the paper
relaxes K, B to reals and notes integer recovery is straightforward).

``solve_param_opt_batched`` is the same algorithm in lockstep over a batch of
instances sharing one structure signature (same objective m, family varmap,
worker count — e.g. one Fig.-5 sweep line): every outer iteration refreshes
all expansion-point coefficients and performs the whole batch's GP solves in
one :func:`~repro.opt.gp.solve_gp_batch` call, with per-instance
convergence / stall masks freezing finished instances.
``backend="jnp-fused"`` goes further and runs the *entire* outer loop —
coefficient refresh included — as one jitted device program
(:mod:`repro.opt.gia_jax`), compiled once per structure signature.

For m=J (Problem 11) both entry points finish with a Gen-C-seeded restart:
the companion constant-step problem is solved at a few canonical step sizes,
the joint GIA re-runs from each solved point (with log gamma appended), and
the best KKT point wins — the cold-started surrogate sequence can converge
to a point slightly above Gen-C's (Lemma 4 says joint optimization can only
help), and re-expanding around Gen-C's solution repairs exactly that.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Dict, List, Optional, Sequence

import numpy as np

from ..obs import REGISTRY as _METRICS
from ..obs import trace as _trace
from ..obs.metrics import GLOBAL_SWITCH as _OBS_ON
from .gp import GPResult, solve_gp, solve_gp_batch
from .problems import Objective, ParamOptProblem
from .structure import GPStructure, structure_signature

__all__ = ["GIAResult", "solve_param_opt", "solve_param_opt_batched",
           "min_feasible_K0", "min_feasible_K0_joint"]


@dataclasses.dataclass
class GIAResult:
    converged: bool
    feasible: bool
    iterations: int
    z: np.ndarray                  # final log-space point (continuous)
    x: Dict[str, float]            # named continuous solution
    K0: int
    Kn: np.ndarray                 # integer per-worker local iterations
    B: int
    gamma: Optional[float]         # optimized step size (m="J" only)
    E: float                       # true energy cost at the integer point
    T: float
    C: float
    history: List[float]           # objective per GIA iteration
    S: Optional[int] = None        # cohort size (None = full participation)


def _extract(problem: ParamOptProblem, z: np.ndarray):
    v = problem.vmap
    K0 = float(np.exp(v.K0.logvalue(z)))
    Kn = np.array([float(np.exp(k.logvalue(z))) for k in v.Kn])
    B = float(np.exp(v.B.logvalue(z)))
    extra = float(np.exp(v.extra.logvalue(z))) if v.extra is not None else None
    i_S = problem._i_S
    S = float(np.exp(z[i_S])) if i_S is not None else None
    return K0, Kn, B, extra, S


def solve_param_opt(problem: ParamOptProblem,
                    z0: Optional[np.ndarray] = None,
                    tol: float = 1e-4, max_iter: int = 60,
                    verbose: bool = False,
                    joint_restart: bool = True) -> GIAResult:
    z = problem.z_init() if z0 is None else np.asarray(z0, dtype=np.float64)
    history: List[float] = []
    converged = False
    res: Optional[GPResult] = None
    stall = 0
    for it in range(max_iter):
        z = problem.project_expansion(z)
        gp = problem.build(z)
        res = solve_gp(gp, z)
        if not res.feasible:
            # The *approximate* problem can be infeasible away from a good
            # expansion point; the phase-I minimizer inside solve_gp is the
            # min-slack point — rebuild the surrogates there and retry.
            z = res.z
            stall += 1
            if stall > 8:
                break
            continue
        stall = 0
        # convergence is judged between successive *expansion points* — both
        # sides projected.  m=E's surrogates (32)/(33) hold X0 a delta-margin
        # off the X0 = rho^K0 manifold that project_expansion re-imposes, so
        # comparing the raw optimizer output against the projected input
        # bounces by exactly delta forever (historically 60 maxed-out
        # iterations with every other coordinate stable to 1e-13)
        step = float(np.max(np.abs(problem.project_expansion(res.z) - z)))
        z = res.z
        history.append(res.obj)
        if verbose:
            print(f"  GIA iter {it}: E={res.obj:.6g} step={step:.3g}")
        if step < tol:
            converged = True
            break
    result = _finalize(problem, z, history, converged)
    if joint_restart and problem.m is Objective.JOINT:
        for g in _joint_seed_gammas(problem, result):
            comp = _companion_constant(problem, g)
            rc = solve_param_opt(comp, tol=tol, max_iter=max_iter)
            zw = rc.z.copy()
            zw[problem.vmap.names.index("extra")] = np.log(g)
            warm = solve_param_opt(problem, z0=zw, tol=tol,
                                   max_iter=max_iter, joint_restart=False)
            result = _better_kkt(result, warm)
    return result


def _record_solve(backend: str, n_rows: int, results: List["GIAResult"],
                  pad_to: int) -> None:
    """Per-dispatch solver metrics (host-side only; inert when obs is off).

    Each GIA iteration refreshes the surrogate coefficients once, so
    ``GIAResult.iterations`` doubles as the per-row refresh count.
    """
    if not _OBS_ON.on:
        return
    _METRICS.counter("gia.rows_solved", backend=backend).inc(n_rows)
    _METRICS.histogram("gia.batch_rows", backend=backend).observe(n_rows)
    _METRICS.histogram("gia.batch_occupancy", backend=backend).observe(
        n_rows / max(int(pad_to), n_rows))
    it_h = _METRICS.histogram("gia.iterations_per_row", backend=backend)
    refreshes = 0
    for r in results:
        it_h.observe(r.iterations)
        refreshes += r.iterations
    _METRICS.counter("gia.refreshes", backend=backend).inc(refreshes)


def solve_param_opt_batched(problems: Sequence[ParamOptProblem],
                            z0s: Optional[Sequence[Optional[np.ndarray]]]
                            = None,
                            tol: float = 1e-4, max_iter: int = 60,
                            backend: str = "jnp",
                            verbose: bool = False,
                            joint_restart: bool = True,
                            pad_to: int = 0) -> List[GIAResult]:
    """Lockstep-batched ``solve_param_opt`` over same-structure instances.

    Per-instance semantics match the scalar loop exactly: each instance sees
    the same sequence of expansion points, phase-I retries, and stall exits
    it would see standalone (the ``backend="numpy"`` path is bit-identical
    row-for-row); ``backend="jnp"`` performs each iteration's GP solves in
    one jitted, vmapped interior-point call; ``backend="jnp-fused"`` runs
    the whole outer loop — surrogate refresh included — as one jitted
    device program per structure signature (:mod:`repro.opt.gia_jax`;
    nothing to print per iteration, so ``verbose`` is a no-op there).

    ``z0s`` warm-starts individual rows: entries are starting points in
    log-space, or ``None`` for that row's cold ``z_init()`` — warm and cold
    rows mix freely inside one batch (a row warm-started at a previously
    solved KKT point re-converges in 1-3 GIA iterations instead of running
    cold phase-I).  ``pad_to`` (fused backend only) pads the device batch to
    a fixed row count so variable-size micro-batches of one signature share
    a single compiled executable; padding rows are discarded before the
    m=J restart and never finalized.
    """
    problems = list(problems)
    if not problems:
        return []
    sig = structure_signature(problems[0])
    for p in problems[1:]:
        if structure_signature(p) != sig:
            raise ValueError(
                f"batched GIA needs one structure signature, got both {sig} "
                f"and {structure_signature(p)}; group instances by "
                f"(m, family, N) first")
    B = len(problems)
    if z0s is None:
        zs = [p.z_init() for p in problems]
    else:
        zs = [p.z_init() if z is None
              else np.asarray(z, dtype=np.float64).copy()
              for p, z in zip(problems, z0s)]
    _t0 = time.perf_counter() if _OBS_ON.on else 0.0
    if backend == "jnp-fused":
        from .gia_jax import solve_gia_fused
        results = [
            _finalize(p, np.asarray(z, dtype=np.float64), history, conv)
            for p, (z, history, conv)
            in zip(problems, solve_gia_fused(problems, zs, tol, max_iter,
                                             pad_to=pad_to))]
        if _OBS_ON.on:
            _trace.add_span("gia.solve", _t0, time.perf_counter(),
                            backend=backend, rows=B,
                            m=str(problems[0].m.value))
            _record_solve(backend, B, results, pad_to)
        if joint_restart and problems[0].m is Objective.JOINT:
            results = _joint_restart_batched(problems, results, tol,
                                             max_iter, backend,
                                             pad_to=pad_to)
        return results
    structure = GPStructure(problems[0])
    history: List[List[float]] = [[] for _ in range(B)]
    converged = [False] * B
    active = [True] * B
    stall = [0] * B
    for it in range(max_iter):
        if not any(active):
            break
        pack = structure.pack_batch(problems, zs, active=active)
        # projected expansion points (inactive rows keep their final z —
        # their pack rows are stale placeholders the backends skip)
        zs = [pack.z0[i] if active[i] else zs[i] for i in range(B)]
        res = solve_gp_batch(pack, backend=backend)
        for i in range(B):
            if not active[i]:
                continue
            if not res.feasible[i]:
                zs[i] = res.z[i]                # retry from min-slack point
                stall[i] += 1
                if stall[i] > 8:
                    active[i] = False
                continue
            stall[i] = 0
            # projected-vs-projected step, as in the scalar loop
            step = float(np.max(np.abs(
                problems[i].project_expansion(res.z[i]) - zs[i])))
            zs[i] = res.z[i]
            history[i].append(float(res.obj[i]))
            if verbose:
                print(f"  GIA[{i}] iter {it}: E={res.obj[i]:.6g} "
                      f"step={step:.3g}")
            if step < tol:
                converged[i] = True
                active[i] = False
    results = [_finalize(p, np.asarray(zs[i], dtype=np.float64), history[i],
                         converged[i])
               for i, p in enumerate(problems)]
    if _OBS_ON.on:
        _trace.add_span("gia.solve", _t0, time.perf_counter(),
                        backend=backend, rows=B, m=str(problems[0].m.value))
        _record_solve(backend, B, results, pad_to=0)
    if joint_restart and problems[0].m is Objective.JOINT:
        results = _joint_restart_batched(problems, results, tol, max_iter,
                                         backend)
    return results


# ---------------------------------------------------------------------------
# m=J Gen-C-seeded restart (Lemma 4 guard)
# ---------------------------------------------------------------------------
#: canonical companion step sizes, as fractions of the 1/L cap — 1e-3/L sits
#: in the regime the paper's Sec.-VII constant rules operate in
_JOINT_SEED_FRACS = (1e-3,)


def _joint_seed_gammas(problem: ParamOptProblem, cold: GIAResult
                       ) -> List[float]:
    """Candidate fixed step sizes for the companion m=C solves: the cold
    joint solution's gamma plus the canonical fractions of 1/L, clipped to
    (0, 1/L] and de-duplicated."""
    cap = 1.0 / float(problem.consts.L)
    raw = ([] if cold.gamma is None or not np.isfinite(cold.gamma)
           or cold.gamma <= 0 else [float(cold.gamma)])
    raw += [f * cap for f in _JOINT_SEED_FRACS]
    out: List[float] = []
    for g in raw:
        g = min(max(g, 1e-12), cap)
        if all(abs(g / g0 - 1.0) > 1e-6 for g0 in out):
            out.append(g)
    return out


def _companion_constant(problem: ParamOptProblem, g: float) -> ParamOptProblem:
    """The m=C companion of a joint problem at fixed gamma, on the *same*
    varmap — the gamma variable stays as an unconstrained-but-boxed spectator
    so the structure signature is shared by every companion in a batch."""
    return dataclasses.replace(problem, m=Objective.CONSTANT, gamma=float(g))


def _better_kkt(a: GIAResult, b: GIAResult) -> GIAResult:
    """Prefer feasible, then lower true energy; ties keep the incumbent."""
    if a.feasible != b.feasible:
        return a if a.feasible else b
    return b if b.E < a.E else a


def _joint_restart_batched(problems: Sequence[ParamOptProblem],
                           colds: List[GIAResult], tol: float, max_iter: int,
                           backend: str, pad_to: int = 0) -> List[GIAResult]:
    """Batched counterpart of the scalar restart in :func:`solve_param_opt`:
    one batched companion solve + one batched warm re-solve per seed round
    (companions share a signature, so each round stays two compiled calls;
    ``pad_to`` keeps both at the caller's fixed batch shape).
    """
    i_ex = problems[0].vmap.names.index("extra")
    cands = [_joint_seed_gammas(p, r) for p, r in zip(problems, colds)]
    best = list(colds)
    for j in range(max(len(c) for c in cands)):
        idxs = [i for i, c in enumerate(cands) if len(c) > j]
        comps = [_companion_constant(problems[i], cands[i][j]) for i in idxs]
        rcs = solve_param_opt_batched(comps, tol=tol, max_iter=max_iter,
                                      backend=backend, pad_to=pad_to)
        z0s = []
        for i, rc in zip(idxs, rcs):
            zw = rc.z.copy()
            zw[i_ex] = np.log(cands[i][j])
            z0s.append(zw)
        warms = solve_param_opt_batched([problems[i] for i in idxs], z0s=z0s,
                                        tol=tol, max_iter=max_iter,
                                        backend=backend, joint_restart=False,
                                        pad_to=pad_to)
        for i, w in zip(idxs, warms):
            best[i] = _better_kkt(best[i], w)
    return best


def _finalize(problem: ParamOptProblem, z: np.ndarray,
              history: List[float], converged: bool) -> GIAResult:
    """Integer recovery + true-constraint evaluation at the continuous point."""
    _, _, _, extra, _ = _extract(problem, z)
    K0i, Kni, Bi, extra_i, Si, _ = _round_integer(problem, z, extra)
    ev = problem.evaluate(K0i, Kni, Bi, extra_i, S=Si)
    v = problem.vmap
    named = {name: float(np.exp(z[i])) for i, name in enumerate(v.names)}
    # pinned-cohort models have no S variable; report their fixed size so
    # Plan plumbing is uniform (None stays the full-participation marker)
    S_out = Si if Si is not None \
        else problem.sampling.pinned_S(problem.sys.N)
    return GIAResult(
        converged=converged,
        feasible=problem.feasible(K0i, Kni, Bi, extra_i, S=Si),
        iterations=len(history), z=z, x=named,
        K0=K0i, Kn=Kni, B=Bi,
        gamma=extra_i if problem.m is Objective.JOINT else problem.gamma,
        E=ev["E"], T=ev["T"], C=ev["C"], history=list(history), S=S_out)


def min_feasible_K0(problem: ParamOptProblem, Kn, B,
                    extra: Optional[float] = None, K0_lo: int = 1,
                    ctol: float = 1e-9, ttol: float = 1e-9,
                    max_doublings: int = 200, S: Optional[int] = None):
    """Smallest integer ``K0 >= K0_lo`` with ``C(K0) <= C_max*(1+ctol)``.

    ``C_m`` is non-increasing and ``T`` non-decreasing in ``K0``, so the
    search is exponential bracketing plus monotone bisection (~2 log2(K0*)
    ``evaluate`` calls); a bracket point that already blows the time budget
    while C is still unmet certifies infeasibility.  Returns ``(K0, ok)``
    where ``ok`` additionally requires ``T(K0) <= T_max*(1+ttol)``.
    """
    C_cap = problem.C_max * (1 + ctol)
    T_cap = problem.T_max * (1 + ttol)
    ev = problem.evaluate(K0_lo, Kn, B, extra, S=S)
    if ev["C"] <= C_cap:
        return K0_lo, ev["T"] <= T_cap
    lo, hi = K0_lo, K0_lo
    for _ in range(max_doublings):
        if ev["T"] > problem.T_max:
            return hi, False            # time budget dies before C is met
        lo, hi = hi, hi * 2
        ev = problem.evaluate(hi, Kn, B, extra, S=S)
        if ev["C"] <= C_cap:
            break
    else:
        return hi, False
    # invariant: C(lo) > C_cap >= C(hi); bisect to the smallest C-ok K0
    while hi - lo > 1:
        mid = (lo + hi) // 2
        if problem.evaluate(mid, Kn, B, extra, S=S)["C"] <= C_cap:
            hi = mid
        else:
            lo = mid
    return hi, problem.evaluate(hi, Kn, B, extra, S=S)["T"] <= T_cap


def min_feasible_K0_joint(problem: ParamOptProblem, Kn, B, K0_lo: int = 1,
                          ctol: float = 1e-9, ttol: float = 1e-9,
                          S: Optional[int] = None):
    """m=J integer recovery: smallest ``K0 >= K0_lo`` whose *gamma-optimized*
    error meets the budget, ``min_gamma C(K0, gamma) <= C_max*(1+ctol)``.

    Closed form, no scan: for fixed parameters the constant-rule error is
    ``C(K0, g) = a/(g K0) + b g^2 + c g`` with a, b, c >= 0, so three probes
    of the *true* closed form at K0=1 recover the coefficients (no formula
    duplicated from :mod:`repro.core`), feasibility inverts to
    ``K0 >= a / (g C_cap - b g^3 - c g^2)``, and the denominator's maximum
    over the Lemma-4 interval ``(0, 1/L]`` is a quadratic root.  Returns
    ``(K0, gamma, ok)`` — fixing gamma at the continuous optimizer's value
    can round to a worse integer point than a neighbouring (Kn, B) allows;
    re-optimizing the step size per candidate is what keeps Gen-O
    at-or-below every fixed-rule baseline.
    """
    C_cap = problem.C_max * (1 + ctol)
    T_cap = problem.T_max * (1 + ttol)
    probes = (0.5, 1.0, 2.0)
    Cs = np.array([problem.evaluate(1, Kn, B, g, S=S)["C"] for g in probes])
    M = np.array([[1.0 / g, g * g, g] for g in probes])
    a, b, c = np.linalg.solve(M, Cs)
    L_cap = 1.0 / float(problem.consts.L)
    # argmax of slack(g) = C_cap*g - b*g^3 - c*g^2 on (0, L_cap]
    if b > 1e-300:
        g = (-c + math.sqrt(c * c + 3.0 * b * C_cap)) / (3.0 * b)
    elif c > 1e-300:
        g = C_cap / (2.0 * c)
    else:
        g = L_cap
    g = min(g, L_cap)
    slack = g * C_cap - b * g ** 3 - c * g ** 2
    if slack <= 0.0:
        return K0_lo, g, False
    K0 = max(K0_lo, int(math.ceil(a / slack - 1e-12)))
    while problem.evaluate(K0, Kn, B, g, S=S)["C"] > C_cap:   # fp guard
        K0 += 1
    return K0, g, problem.evaluate(K0, Kn, B, g, S=S)["T"] <= T_cap


def _round_S(problem: ParamOptProblem, Sf: Optional[float], mode=round
             ) -> Optional[int]:
    """Integer cohort size clamped to ``[1, floor(s_cap)]`` — rounding can
    never push an inclusion probability above 1.  None stays None."""
    if Sf is None:
        return None
    s_hi = int(math.floor(problem.sampling.s_cap(problem.sys.N) + 1e-9))
    return min(max(1, int(mode(Sf))), max(1, s_hi))


#: uniform integer candidate grids of the m=J polish (z_init's search grids
#: plus the in-between K values integer recovery actually lands on)
_POLISH_B_GRID = (1, 2, 3, 4, 6, 8, 12, 16, 24, 32, 64, 128)
_POLISH_K_GRID = (1, 2, 3, 4, 5, 6, 8, 10, 12, 16, 24, 32)


def _joint_integer_polish(problem: ParamOptProblem, z: np.ndarray, best):
    """m=J global integer fallback: sweep uniform (Kn, B) grid points near
    the continuous optimum with gamma-optimized K0 recovery.

    Rounding the continuous joint optimizer can land in a worse integer
    basin than a neighbouring (Kn, B) — the step size re-optimizes around
    any integer point, so the paper's "integer recovery is straightforward"
    needs candidates beyond the componentwise roundings for Gen-O to stay
    at-or-below every fixed-rule baseline.  Candidates are built in the
    *actual* variable space (family ties respected) and pruned to a
    work-product band around the continuous point.
    """
    v = problem.vmap
    _, Knf, Bf, _, Sf = _extract(problem, z)
    Si = _round_S(problem, Sf)
    prod = float(max(np.mean(Knf) * Bf, 1.0))
    seen = set()
    for Bv in _POLISH_B_GRID:
        for Kv in _POLISH_K_GRID:
            zc = z.copy()
            for i, nm in enumerate(v.names):
                if (nm.startswith("K") and nm != "K0") or nm == "l":
                    zc[i] = np.log(float(Kv))
                elif nm == "B":
                    zc[i] = np.log(float(Bv))
            _, Knf_c, Bf_c, _, _ = _extract(problem, zc)
            Kni = np.maximum(1, np.round(Knf_c)).astype(np.int64)
            Bi = max(1, int(round(Bf_c)))
            key = (tuple(Kni.tolist()), Bi)
            if key in seen:
                continue
            seen.add(key)
            if not prod / 3.0 <= float(np.mean(Kni)) * Bi <= prod * 3.0:
                continue
            K0i, g, ok = min_feasible_K0_joint(problem, Kni, Bi, S=Si)
            if not ok:
                continue
            ev = problem.evaluate(K0i, Kni, Bi, g, S=Si)
            if best is None or ev["E"] < best[5]:
                best = (K0i, Kni, Bi, g, Si, ev["E"])
    return best


def _round_integer(problem: ParamOptProblem, z: np.ndarray,
                   extra: Optional[float]):
    """Construct a feasible integer (K0, Kn, B) near the continuous optimum.

    Rounding happens in the *actual* variable space (so baselines with tied
    variables — e.g. FedAvg's K_n = l·I_n/B — keep their structure), then the
    paper variables are re-derived from the monomial map.  C_m is
    non-increasing in K0 for every rule, so each rounding takes the smallest
    K0 restoring C <= C_max (via :func:`min_feasible_K0` bisection — for m=J
    the gamma-optimizing :func:`min_feasible_K0_joint`) and the least-energy
    feasible candidate wins.  Returns ``(K0, Kn, B, extra, S, E)`` with
    ``extra`` the (re-optimized, for m=J) step size / X0 value and ``S``
    the rounded cohort size (None without a free sampling variable).
    """
    v = problem.vmap
    joint = problem.m is Objective.JOINT
    int_idx = [i for i, nm in enumerate(v.names)
               if nm == "K0" or nm.startswith("K") or nm in ("l", "B", "S")]
    s_hi = (None if problem._i_S is None else
            int(math.floor(problem.sampling.s_cap(problem.sys.N) + 1e-9)))
    best = None
    for mode in (math.floor, round, math.ceil):
        zc = z.copy()
        for i in int_idx:
            iv = max(1, mode(float(np.exp(z[i]))))
            if s_hi is not None and v.names[i] == "S":
                iv = min(iv, s_hi)         # rounding must not breach pi<=1
            zc[i] = np.log(iv)
        K0f, Knf, Bf, _, Sf = _extract(problem, zc)
        Si = _round_S(problem, Sf)
        Kni = np.maximum(1, np.ceil(Knf - 1e-9)).astype(np.int64)
        Bi = max(1, int(round(Bf)))
        K0_lo = max(1, math.floor(K0f))
        if joint:
            K0i, cand_extra, ok = min_feasible_K0_joint(problem, Kni, Bi,
                                                        K0_lo=K0_lo, S=Si)
        else:
            K0i, ok = min_feasible_K0(problem, Kni, Bi, extra, K0_lo=K0_lo,
                                      S=Si)
            cand_extra = extra
        if not ok:
            continue
        ev = problem.evaluate(K0i, Kni, Bi, cand_extra, S=Si)
        if best is None or ev["E"] < best[5]:
            best = (K0i, Kni, Bi, cand_extra, Si, ev["E"])
    if joint:
        best = _joint_integer_polish(problem, z, best)
    if best is None:
        # fall back to the ceil point even if (slightly) infeasible
        K0f, Knf, Bf, _, Sf = _extract(problem, z)
        Si = _round_S(problem, Sf, mode=math.ceil)
        Kni = np.maximum(1, np.ceil(Knf)).astype(np.int64)
        Bi = max(1, math.ceil(Bf))
        K0i = max(1, math.ceil(K0f))
        ev = problem.evaluate(K0i, Kni, Bi, extra, S=Si)
        best = (K0i, Kni, Bi, extra, Si, ev["E"])
    return best
