"""Problems 3, 5, 7, 11 of the paper as GP-sequence generators.

Each ``*_builder`` returns a function ``build(z_prev) -> GP`` producing the
iteration-t approximate GP (Problems 4, 6, 8, 12) at the previous point — the
GIA outer loop (Algorithms 2-5) lives in :mod:`repro.opt.gia`.

Variable space (log-space vector z), in order:
    K0, K_1..K_N, B, T1, T2 [, X0 | gamma]
Baselines (PM-SGD / FedAvg / PR-SGD parameter optimization, Sec. VII) reuse
the same constructors through a ``VarMap`` that pins or ties variables:
  PM:  K_n ≡ 1;   FA:  K_n = l * I_n / B (new var l);   PR:  B ≡ 1.
"""
from __future__ import annotations

import dataclasses
import enum
import functools
import warnings
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from ..core.convergence import MLProblemConstants
from ..core.cost import EdgeSystem
from .condense import amgm_monomial, ratio_to_posy, taylor_logx, taylor_xlog1x
from .gp import GP
from .posy import Posy, const, var

__all__ = ["Objective", "ParamOptProblem", "VarMap", "identity_varmap",
           "pm_varmap", "fa_varmap", "pr_varmap"]


class Objective(str, enum.Enum):
    """The paper's convergence-error measure m — which Problem is solved.

    A ``str`` subclass so member values compare equal to the historical
    one-letter codes (``Objective.CONSTANT == "C"``); the rest of the
    optimizer keeps matching on the letters.
    """

    CONSTANT = "C"        # Problem 3: fixed constant step size (eq. 10)
    EXPONENTIAL = "E"     # Problem 5: exponential step-size rule (eq. 12)
    DIMINISHING = "D"     # Problem 7: diminishing step-size rule (eq. 15)
    JOINT = "J"           # Problem 11: jointly optimized (constant) step size

    @classmethod
    def coerce(cls, m: Union["Objective", str],
               _warn: bool = True) -> "Objective":
        """Accept an Objective or a legacy "C"|"E"|"D"|"J" string.

        Bare strings are the deprecated spelling; they keep working but
        warn once per call site.
        """
        if isinstance(m, cls):
            return m
        try:
            out = cls(m)
        except ValueError:
            raise ValueError(
                f"unknown objective {m!r}; expected one of "
                f"{[o.value for o in cls]} or a repro.api.Objective") from None
        if _warn:
            # caller -> generated __init__ -> __post_init__ -> coerce
            warnings.warn(
                f"stringly-typed m={m!r} is deprecated; use "
                f"repro.api.Objective.{out.name}", DeprecationWarning,
                stacklevel=4)
        return out

    @property
    def needs_rho(self) -> bool:
        return self in (Objective.EXPONENTIAL, Objective.DIMINISHING)

    @property
    def needs_gamma(self) -> bool:
        return self is not Objective.JOINT


# ---------------------------------------------------------------------------
# Variable mapping
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class VarMap:
    """Maps paper variables to monomials over the actual optimization vars."""
    n: int                               # number of actual variables
    names: List[str]                     # debug names of actual variables
    K0: Posy
    Kn: List[Posy]                       # N entries (monomials)
    B: Posy
    T1: Posy
    T2: Posy
    extra: Optional[Posy] = None         # X0 (m=E) or gamma (joint)
    lower: Optional[np.ndarray] = None   # per-actual-var lower bounds (>0)
    upper: Optional[np.ndarray] = None

    def z0_default(self) -> np.ndarray:
        return np.zeros(self.n)


def identity_varmap(N: int, with_extra: bool = False) -> VarMap:
    """K0, K_1..K_N, B, T1, T2 (+extra) all free."""
    n = N + 4 + (1 if with_extra else 0)
    names = (["K0"] + [f"K{i+1}" for i in range(N)] + ["B", "T1", "T2"]
             + (["extra"] if with_extra else []))
    lower = np.full(n, 1e-12)
    upper = np.full(n, 1e12)
    lower[0] = 1.0                       # K0 >= 1
    lower[1:N + 1] = 1.0                 # Kn >= 1
    lower[N + 1] = 1.0                   # B >= 1
    return VarMap(
        n=n, names=names,
        K0=var(0, n), Kn=[var(1 + i, n) for i in range(N)],
        B=var(N + 1, n), T1=var(N + 2, n), T2=var(N + 3, n),
        extra=var(N + 4, n) if with_extra else None,
        lower=lower, upper=upper)


def pm_varmap(N: int, with_extra: bool = False) -> VarMap:
    """PM-SGD: K_n ≡ 1.  Vars: K0, B, T1, T2 (+extra)."""
    n = 4 + (1 if with_extra else 0)
    names = ["K0", "B", "T1", "T2"] + (["extra"] if with_extra else [])
    lower = np.full(n, 1e-12); upper = np.full(n, 1e12)
    lower[0] = 1.0; lower[1] = 1.0
    return VarMap(n=n, names=names, K0=var(0, n),
                  Kn=[const(1.0, n) for _ in range(N)],
                  B=var(1, n), T1=var(2, n), T2=var(3, n),
                  extra=var(4, n) if with_extra else None,
                  lower=lower, upper=upper)


def fa_varmap(N: int, I_n: Sequence[float], with_extra: bool = False) -> VarMap:
    """FedAvg: K_n = l * I_n / B, l a positive (relaxed-integer) variable.

    Vars: K0, l, B, T1, T2 (+extra).
    """
    n = 5 + (1 if with_extra else 0)
    names = ["K0", "l", "B", "T1", "T2"] + (["extra"] if with_extra else [])
    lower = np.full(n, 1e-12); upper = np.full(n, 1e12)
    lower[0] = 1.0; lower[1] = 1.0; lower[2] = 1.0
    l, B = var(1, n), var(2, n)
    return VarMap(n=n, names=names, K0=var(0, n),
                  Kn=[l * float(I_n[i]) / B for i in range(N)],
                  B=B, T1=var(3, n), T2=var(4, n),
                  extra=var(5, n) if with_extra else None,
                  lower=lower, upper=upper)


def pr_varmap(N: int, with_extra: bool = False) -> VarMap:
    """PR-SGD: B ≡ 1.  Vars: K0, K_1..K_N, T1, T2 (+extra)."""
    n = N + 3 + (1 if with_extra else 0)
    names = (["K0"] + [f"K{i+1}" for i in range(N)] + ["T1", "T2"]
             + (["extra"] if with_extra else []))
    lower = np.full(n, 1e-12); upper = np.full(n, 1e12)
    lower[0] = 1.0; lower[1:N + 1] = 1.0
    return VarMap(n=n, names=names, K0=var(0, n),
                  Kn=[var(1 + i, n) for i in range(N)],
                  B=const(1.0, n), T1=var(N + 1, n), T2=var(N + 2, n),
                  extra=var(N + 3, n) if with_extra else None,
                  lower=lower, upper=upper)


def _log_posy_batch(p: Posy, Z: np.ndarray) -> np.ndarray:
    """log p(exp(z)) for a (G, n) batch of log-points — the vectorized
    counterpart of :meth:`Posy.logvalue`."""
    t = np.log(p.c)[None, :] + Z @ p.A.T          # (G, K)
    m = t.max(axis=1, keepdims=True)
    return (m + np.log(np.exp(t - m).sum(axis=1, keepdims=True)))[:, 0]


# ---------------------------------------------------------------------------
# Problem family
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class ParamOptProblem:
    """One instance of the paper's parameter-optimization problem.

    ``m`` selects the convergence-error measure: "C", "E", "D" (Problems
    3/5/7, fixed step-size sequence) or "J" (Problem 11, joint optimization of
    the — by Lemma 4 constant — step size).
    """
    sys: EdgeSystem
    consts: MLProblemConstants
    T_max: float
    C_max: float
    m: Union[Objective, str]             # Objective (or legacy "C"|"E"|"D"|"J")
    gamma: Optional[float] = None        # step size (m in C/E/D)
    rho: Optional[float] = None          # rho_E or rho_D
    vmap: Optional[VarMap] = None
    family: object = "genqsgd"           # repro.families key or instance
    sampling: object = "full"            # repro.sampling key or instance
    faults: object = "none"              # repro.faults key or instance

    def __post_init__(self):
        from ..families import resolve   # lazy: families imports this module
        from ..sampling import resolve as resolve_sampling   # ditto
        from ..faults import resolve as resolve_faults       # ditto
        self.m = Objective.coerce(self.m)
        self.family = resolve(self.family)
        self.family.agg_eps(self.sys.N)  # N-mismatched weights fail loudly
        self.sampling = resolve_sampling(self.sampling)
        self.sampling.validate(self.sys.N)
        self.faults = resolve_faults(self.faults)
        self.faults.validate(self.sys.N)
        if self.vmap is None:
            self.vmap = identity_varmap(
                self.sys.N,
                with_extra=self.m in (Objective.EXPONENTIAL, Objective.JOINT))
        # free-cohort models append the "S" decision variable *after* every
        # family variable (extra included), so positional lookups stay valid
        if self.sampling.free_S and "S" not in self.vmap.names:
            self.vmap = self.sampling.extend_varmap(self.vmap, self.sys.N)
        if self.m is not Objective.JOINT and self.gamma is None:
            raise ValueError(f"m={self.m} requires a fixed gamma")
        if self.m.needs_rho and self.rho is None:
            raise ValueError(f"m={self.m} requires rho")

    # -- family hooks (repro.families): coefficient-only reweighting ---------
    # The family only moves *coefficients* of the convergence block (weights
    # in the aggregation sums, scales on c2/c3); term counts and exponent
    # structure stay family-independent, so every family batches and fuses
    # through repro.opt.refresh / gia_jax unchanged.
    @functools.cached_property
    def _agg_eps(self) -> Optional[np.ndarray]:
        """Effective aggregation weights eps_n = N w_n (None = uniform)."""
        return self.family.agg_eps(self.sys.N)

    # -- fault hooks (repro.faults): availability as coefficients -----------
    # Per-worker availability a_n composes with sampling exactly as
    # pi_n -> a_n pi_n: the same ratio-form machinery carries the joint
    # coefficient, so faulted problems batch and fuse unchanged.  a_n = None
    # leaves every branch below on the historical code path, bitwise.
    @functools.cached_property
    def _an(self) -> Optional[np.ndarray]:
        """Per-worker availability (None = always available, bitwise).
        ``sys.an`` (stamped by Scenario or set directly) wins; otherwise
        the fault model's stationary availability."""
        if self.sys.an is not None:
            return self.sys.an
        return self.faults.availability(self.sys.N)

    @functools.cached_property
    def _c_eff(self):
        """Theorem-1 coefficients with the family's (c2, c3) scales *and*
        the sampling/fault models' c3 inflation folded in; scales of
        exactly 1.0 leave the floats bitwise untouched."""
        c1, c2, c3, c4 = self.consts.c
        c2s, c3s = self.family.c_scales(self.sys.N)
        if c2s != 1.0:
            c2 = c2 * c2s
        if c3s != 1.0:
            c3 = c3 * c3s
        an = self._an
        if an is None:
            s3 = self.sampling.c3_scale(self.sys.N)
            if s3 != 1.0:
                c3 = c3 * s3
        else:
            # joint exact scale (1/N) sum 1/(a_n pi_n) — the sampling form
            # with pi_n -> a_n pi_n (free-S: its S-independent part
            # (1/N) sum 1/(a_n p_n); the caller multiplies by S^{-1})
            N = self.sys.N
            if self.sampling.free_S:
                pe = an * self.sampling.base_p(N)
            else:
                pi = self.sampling.pi(N)
                pe = an if pi is None else an * pi
            c3 = c3 * float(np.sum(1.0 / pe) / N)
        return c1, c2, c3, c4

    # -- sampling hooks (repro.sampling): participation as coefficients ------
    # Pinned cohorts are pure coefficient changes (exact inflation factors);
    # free-S models additionally append the "S" variable and multiply the
    # variance blocks by the S^{-1} monomial — still posynomial, so sampled
    # problems batch and fuse through refresh/gia_jax unchanged.
    @functools.cached_property
    def _i_S(self) -> Optional[int]:
        """Index of the free cohort-size variable (None = pinned/full)."""
        try:
            return self.vmap.names.index("S")
        except ValueError:
            return None

    def _over_S(self, p: Posy) -> Posy:
        """``p / S`` when the cohort size is a free variable (no-op —
        the same object, bitwise — for pinned/full participation)."""
        if self._i_S is None:
            return p
        return p / var(self._i_S, self.vmap.n)

    def _pi_at(self, S: Optional[float] = None) -> Optional[np.ndarray]:
        """Inclusion probabilities at cohort size ``S`` (None = full)."""
        return self.sampling.pi_at(self.sys.N, S)

    def _conv_coeffs(self, S: Optional[float] = None):
        """``(c, q_pairs)`` for the closed-form convergence bound with the
        *exact* sampling inflation at concrete cohort size ``S``.

        This is the bound ``evaluate`` / integer recovery / the feasibility
        flag report.  For free-``S`` models the GP surrogate instead uses
        the conservative posynomial relaxation ``(q+1)/pi >= (q+1-pi)/pi``
        (exactness at ``pi -> 1`` is impossible for a posynomial in ``S``),
        so the surrogate steers and the closed form validates — the same
        split the m=E Taylor constraints already follow.  The ``c3``
        variance-mean scale has no such slack: ``(1/N) sum 1/pi_n`` equals
        the relaxed-part/``S`` exactly, for every builtin model.

        Under availability ``a_n`` (repro.faults) the effective inclusion
        probability is ``a_n pi_n`` and the same exact forms apply with
        that substitution."""
        c = self._c_eff
        qp = self.sys.q_pairs
        an = self._an
        if self._i_S is not None:
            if S is None:
                raise ValueError("free-S sampling problem: pass the cohort "
                                 "size S to evaluate the bound")
            Sf = float(S)
            c = (c[0], c[1], c[2] / Sf, c[3])
            if an is None:
                qp = self.sampling.q_coeffs_at(qp, self.sys.N, Sf)
            else:
                pe = an * self.sampling.pi_at(self.sys.N, Sf)
                qp = (np.asarray(qp, np.float64) + 1.0 - pe) / pe
        elif an is None:
            sq = self.sampling.q_coeffs(qp, self.sys.N)
            if sq is not None:
                qp = sq
        else:
            pi = self.sampling.pi(self.sys.N)
            pe = an if pi is None else an * pi
            qp = (np.asarray(qp, np.float64) + 1.0 - pe) / pe
        return c, qp

    # -- shared pieces ------------------------------------------------------
    def _objective(self) -> Posy:
        v, s = self.vmap, self.sys
        e = s.comp_energy_coeff
        pi = self.sampling.pi(s.N)
        p = self.sampling.base_p(s.N) if self.sampling.free_S else None
        if pi is None and p is None:       # full participation, verbatim
            obj = float(s.const_energy) * v.K0
            for i in range(s.N):
                obj = obj + float(e[i]) * (v.K0 * v.B * v.Kn[i])
            return obj
        comm = s.comm_energy_coeff         # p_n M_sn / r_n per worker
        if p is not None:                  # free S: pi_n = p_n * S
            Sm = var(self._i_S, v.n)
            obj = float(s.server_energy) * v.K0 \
                + float(np.sum(comm * p)) * (v.K0 * Sm)
            for i in range(s.N):
                obj = obj + float(e[i] * p[i]) * (v.K0 * v.B * v.Kn[i] * Sm)
            return obj
        # pinned cohort: constant pi_n folded into the coefficients
        obj = float(s.server_energy + np.sum(comm * pi)) * v.K0
        for i in range(s.N):
            obj = obj + float(e[i] * pi[i]) * (v.K0 * v.B * v.Kn[i])
        return obj

    def _common_constraints(self) -> List[Posy]:
        v, s = self.vmap, self.sys
        cons: List[Posy] = []
        # worst-case-over-the-box capabilities (repro.faults margins);
        # identical objects — bitwise — at zero margins
        ct = s.comp_time_coeff_wc
        for i in range(s.N):                       # (22)
            cons.append(float(ct[i]) * v.Kn[i] / v.T1)
        for i in range(s.N):                       # (23)
            cons.append(v.Kn[i] / v.T2)
        tau = s.comm_time_wc                       # (24)
        cons.append((tau / self.T_max) * v.K0
                    + (1.0 / self.T_max) * (v.K0 * v.B * v.T1))
        # box bounds on the actual variables
        n = v.n
        for i in range(n):
            if v.lower is not None and v.lower[i] > 0:
                cons.append(Posy(np.array([v.lower[i]]), -np.eye(n)[i:i+1]))
            if v.upper is not None and np.isfinite(v.upper[i]):
                cons.append(Posy(np.array([1.0 / v.upper[i]]), np.eye(n)[i:i+1]))
        return cons

    def _sum_Kn(self) -> Posy:
        """sum_n eps_n K_n (eps=None: the unweighted historical sum)."""
        eps = self._agg_eps
        terms = self.vmap.Kn if eps is None else \
            [float(eps[i]) * self.vmap.Kn[i] for i in range(self.sys.N)]
        out = terms[0]
        for k in terms[1:]:
            out = out + k
        return out

    def _sum_q_Kn2(self) -> Posy:
        """sum_n q_n (eps_n K_n)^2 — the quantization-variance block, with
        the sampling model's participation inflation on q_n.

        For a free cohort size this is the *positive* part of the exact
        inflated block: ``q_eff_n = (q_n+1)/(p_n S) - 1`` splits into
        ``(q_n+1)/p_n * S^{-1}`` (returned here, divided by the S monomial)
        minus 1; the negative part (:meth:`_sum_Kn2_eps`) moves to the
        ratio denominator in :meth:`_conv_constraint`, so the GP encodes
        the exact bound — no relaxation slack.  Availability composes as
        ``pi_n -> a_n pi_n`` throughout (the numerator picks up a
        ``1/a_n``; the ``-1`` part is availability-independent)."""
        qp = self.sys.q_pairs
        an = self._an
        if an is None:
            sq = self.sampling.q_coeffs(qp, self.sys.N)
            if sq is not None:
                qp = sq
        elif self._i_S is not None:
            # free S: exact numerator (q+1)/(a_n p_n); the caller's S^{-1}
            # and the -1 denominator part complete the exact joint form
            qp = self.sampling.q_coeffs(qp, self.sys.N) / an
        else:
            pi = self.sampling.pi(self.sys.N)
            pe = an if pi is None else an * pi
            qp = (np.asarray(qp, np.float64) + 1.0 - pe) / pe
        eps = self._agg_eps
        v = self.vmap
        out = None
        for i in range(self.sys.N):
            q = max(qp[i], 1e-300)
            if eps is not None:
                q = q * float(eps[i]) ** 2
            t = float(q) * (v.Kn[i] ** 2)
            out = t if out is None else out + t
        return self._over_S(out)

    def _sum_Kn2_eps(self) -> Posy:
        """sum_n (eps_n K_n)^2 — the negative ("-1") part of the exact
        participation-inflated q-block under a free cohort size."""
        eps = self._agg_eps
        v = self.vmap
        out = None
        for i in range(self.sys.N):
            w = 1.0 if eps is None else float(eps[i]) ** 2
            t = w * (v.Kn[i] ** 2)
            out = t if out is None else out + t
        return out

    # -- convergence-error constraint per m ----------------------------------
    @functools.cached_property
    def _conv_static(self) -> Dict[str, Posy]:
        """The expansion-point-independent pieces of the convergence block.

        A GIA iteration's coefficient refresh then only condenses the
        cached denominators at the new point (AM-GM / Taylor scalars) and
        performs a handful of monomial divisions — no posynomial-algebra
        rebuild in the hot loop.
        """
        c1, c2, c3, c4 = self._c_eff
        v = self.vmap
        Cmax = self.C_max
        sumK = self._sum_Kn()
        sumQ = self._sum_q_Kn2()
        st = {"sumK": sumK}

        # Free cohort size: the exact inflated q-block is a signomial
        # (positive part sumQ/S, negative part -sum (eps K)^2), so the
        # C/J/D constraints are multiplied through by sum_n eps_n K_n and
        # kept as a num/den ratio — the negative part lands in the
        # denominator, which ratio_to_posy AM-GM-condenses per iteration
        # exactly as m=E's (31) always has.  No bound relaxation.
        fs = self._i_S is not None

        if self.m is Objective.CONSTANT:                    # (26)
            g = self.gamma
            if fs:
                st["fs_num"] = (c1 / (Cmax * g)) / v.K0 \
                    + (c2 * g**2 / Cmax) * ((v.T2 ** 2) * sumK) \
                    + self._over_S((c3 * g / Cmax) * (sumK / v.B)) \
                    + (c4 * g / Cmax) * sumQ
                st["fs_den"] = sumK \
                    + (c4 * g / Cmax) * self._sum_Kn2_eps()
            else:
                st["overM_head"] = (c1 / (Cmax * g)) / v.K0
                st["mid"] = (c2 * g**2 / Cmax) * (v.T2 ** 2) \
                    + self._over_S((c3 * g / Cmax) / v.B)
                st["overM_tail"] = (c4 * g / Cmax) * sumQ
        elif self.m is Objective.JOINT:                     # (40)
            gam = v.extra
            if fs:
                st["fs_num"] = (c1 / Cmax) / (gam * v.K0) \
                    + (c2 / Cmax) * ((gam ** 2) * ((v.T2 ** 2) * sumK)) \
                    + self._over_S((c3 / Cmax) * (gam * (sumK / v.B))) \
                    + (c4 / Cmax) * (gam * sumQ)
                st["fs_den"] = sumK \
                    + (c4 / Cmax) * (gam * self._sum_Kn2_eps())
            else:
                st["overM_head"] = (c1 / Cmax) / (gam * v.K0)
                st["mid"] = (c2 / Cmax) * (gam ** 2) * (v.T2 ** 2) \
                    + self._over_S((c3 / Cmax) * gam / v.B)
                st["overM_tail"] = (c4 / Cmax) * (gam * sumQ)
            # (39): gamma <= 1/L  (lower bound comes from the box)
            st["gamma_cap"] = float(self.consts.L) * gam
        elif self.m is Objective.DIMINISHING:               # (35)
            g, rho = self.gamma, self.rho
            b1 = 1.0 / (rho * g)
            b2 = rho**2 * g**2 / (rho + 1.0)**3 \
                + rho**2 * g**2 / (2 * (rho + 1.0)**2)
            b3 = rho * g / (rho + 1.0)**2 + rho * g / (rho + 1.0)
            if fs:
                st["fs_num"] = const(b1 * c1, v.n) \
                    + (b2 * c2) * ((v.T2 ** 2) * sumK) \
                    + self._over_S((b3 * c3) * (sumK / v.B)) \
                    + (b3 * c4) * sumQ
                # scaled by the Taylor(K0) scalars b / a at each refresh
                st["fs_numB"] = Cmax * sumK
                st["fs_denK"] = Cmax * (v.K0 * sumK)
                st["fs_denQ"] = (b3 * c4) * self._sum_Kn2_eps()
            else:
                st["overM_head"] = const(b1 * c1, v.n)
                st["mid"] = b2 * c2 * (v.T2 ** 2) \
                    + self._over_S((b3 * c3) / v.B)
                st["overM_tail"] = b3 * c4 * sumQ
        elif self.m is Objective.EXPONENTIAL:               # (31)-(33)
            g, rho = self.gamma, self.rho
            a1 = (1.0 - rho) / g
            a2 = g**2 / (1.0 + rho + rho**2)
            a3 = g / (1.0 + rho)
            X0 = v.extra
            st["num"] = const(a1 * c1, v.n) \
                + (a2 * c2) * (v.T2 ** 2) * sumK \
                + self._over_S((a3 * c3) * (sumK / v.B)) \
                + Cmax * (X0 * sumK) \
                + a3 * c4 * sumQ
            st["den"] = Cmax * sumK \
                + (a2 * c2) * (v.T2 ** 2) * (X0 ** 3) * sumK \
                + self._over_S((a3 * c3) * ((X0 ** 2) * sumK / v.B)) \
                + (a3 * c4) * (X0 ** 2) * sumQ
            if fs:
                # exact inflated q-block: the -sum (eps K)^2 parts of num
                # and den each move across the inequality to stay posynomial
                sumQm = self._sum_Kn2_eps()
                st["num"] = st["num"] + (a3 * c4) * ((X0 ** 2) * sumQm)
                st["den"] = st["den"] + (a3 * c4) * sumQm
            lam = float(np.log(1.0 / rho))
            st["lam"] = lam
            st["lam_X0K0"] = lam * (X0 * v.K0)
            st["lam_K0"] = lam * v.K0
            # (30): X0 < 1 (strict; use 1 - eps)
            st["x0_cap"] = X0 * (1.0 / (1.0 - 1e-9))
        else:
            raise ValueError(self.m)
        return st

    def _conv_constraint(self, z_prev: np.ndarray) -> List[Posy]:
        v = self.vmap
        Cmax = self.C_max
        st = self._conv_static
        fs = self._i_S is not None

        if fs and self.m in (Objective.CONSTANT, Objective.JOINT):
            con = ratio_to_posy(st["fs_num"], st["fs_den"], z_prev)
            return [con] if self.m is Objective.CONSTANT \
                else [con, st["gamma_cap"]]
        if fs and self.m is Objective.DIMINISHING:
            rho = self.rho
            K0_prev = float(np.exp(z_prev @ v.K0.A[0]) * v.K0.c[0])
            a = float(np.log((K0_prev + rho + 1.0) / (rho + 1.0))
                      + K0_prev / (K0_prev + rho + 1.0))
            b = float(K0_prev**2 / (K0_prev + rho + 1.0))
            num = st["fs_num"] + b * st["fs_numB"]
            den = a * st["fs_denK"] + st["fs_denQ"]
            return [ratio_to_posy(num, den, z_prev)]

        if self.m is not Objective.EXPONENTIAL:
            M = amgm_monomial(st["sumK"], z_prev)  # condensed sum_n K_n

        if self.m in (Objective.CONSTANT, Objective.JOINT):  # (26) / (40)
            con = st["overM_head"] / M + st["mid"] + st["overM_tail"] / M
            return [con] if self.m is Objective.CONSTANT \
                else [con, st["gamma_cap"]]

        if self.m is Objective.DIMINISHING:                 # (35)
            rho = self.rho
            K0_prev = float(np.exp(z_prev @ v.K0.A[0]) * v.K0.c[0])
            # RHS phi(K0) = K0 log((K0+rho+1)/(rho+1)) is convex; Taylor lower
            # bound a*K0 - b tightens the constraint (inner approximation).
            a = float(np.log((K0_prev + rho + 1.0) / (rho + 1.0))
                      + K0_prev / (K0_prev + rho + 1.0))
            b = float(K0_prev**2 / (K0_prev + rho + 1.0))
            lhs = st["overM_head"] / M + st["mid"] \
                + st["overM_tail"] / M + b * Cmax
            return [lhs / ((Cmax * a) * v.K0)]

        if self.m is Objective.EXPONENTIAL:                 # (31)-(33)
            X0 = v.extra
            cons = [ratio_to_posy(st["num"], st["den"], z_prev)]
            # (28)/(29) sandwich X0 = rho^{K0}.  The Taylor surrogates (32),
            # (33) are *active* at a consistent expansion point, so we relax
            # each by a small margin delta to keep a strict interior for the
            # barrier method (the exact equality is re-imposed by
            # ``project_expansion`` every GIA iteration, and the final point
            # is validated with the true C_E).
            delta = np.exp(-3e-3)
            # (28) -> (32):  X0 log(1/X0) <= X0 K0 log(1/rho)
            X0_prev = float(np.exp(z_prev @ X0.A[0]) * X0.c[0])
            lam = st["lam"]
            a_t, b_t = taylor_xlog1x(X0_prev)
            # (a_t X0 + b_t) <= X0 K0 lam  ==>  move negative a_t if needed
            if a_t >= 0:
                lhs32 = a_t * X0 + const(b_t, v.n)
                den32 = st["lam_X0K0"]
            else:
                lhs32 = const(b_t, v.n)
                den32 = st["lam_X0K0"] + (-a_t) * X0
            cons.append(ratio_to_posy(lhs32, den32, z_prev) * delta)
            # (29) -> (33):  K0 log(1/rho) <= log(1/X0); use the affine upper
            # bound log(X0) <= aX*X0 + bX  ==>  K0 lam + aX X0 + bX <= 0
            aX, bX = taylor_logx(X0_prev)
            rhs = -bX  # = 1 + log(1/X0_prev) > 0 since X0_prev < 1
            assert rhs > 0
            cons.append(((st["lam_K0"] + aX * X0) / rhs) * delta)
            cons.append(st["x0_cap"])                       # (30): X0 < 1
            return cons

        raise ValueError(self.m)

    # -- structure / coefficient split ----------------------------------------
    # The GP sequence of one problem shares a fixed *skeleton*: the objective
    # and the common constraints (22)-(24) + box bounds never depend on the
    # expansion point.  Only the convergence-error block (the condensed /
    # Taylor surrogates) is refreshed per GIA iteration, which is what the
    # batched engine (repro.opt.structure + repro.opt.gp backends) exploits.
    @functools.cached_property
    def skeleton(self) -> Tuple[Posy, Tuple[Posy, ...]]:
        """(objective, common constraints) — the z-independent GP parts."""
        return self._objective(), tuple(self._common_constraints())

    @functools.cached_property
    def packed_skeleton(self) -> Tuple[np.ndarray, np.ndarray]:
        """The common constraints concatenated to flat ``(log c, A)`` arrays
        — computed once per problem, reused by every batched-solver pack."""
        _, common = self.skeleton
        logc = np.concatenate([np.log(c.c) for c in common])
        A = np.concatenate([c.A for c in common], axis=0)
        return logc, A

    def conv_block(self, z_prev: np.ndarray) -> List[Posy]:
        """The expansion-point-dependent convergence-error constraints.

        ``z_prev`` must already be a consistent expansion point (see
        :meth:`project_expansion`).
        """
        return self._conv_constraint(z_prev)

    # -- public API -----------------------------------------------------------
    def build(self, z_prev: np.ndarray) -> GP:
        """The iteration-t approximate GP (Problems 4 / 6 / 8 / 12)."""
        z_prev = self.project_expansion(z_prev)
        obj, common = self.skeleton
        return GP(obj, list(common) + self.conv_block(z_prev))

    def project_expansion(self, z: np.ndarray) -> np.ndarray:
        """Make the expansion point consistent before building surrogates.

        For m=E the constraints (28)/(29) sandwich X0 = rho^{K0}; Taylor
        surrogates built at an inconsistent point have (near-)empty interiors,
        so we re-impose the equality exactly at every expansion.
        """
        if self.m is not Objective.EXPONENTIAL:
            return z
        z = z.copy()
        v = self.vmap
        i_x0 = v.names.index("extra")
        K0 = float(np.exp(v.K0.logvalue(z)))
        z[i_x0] = K0 * np.log(self.rho)
        return z

    # the K0 search ladder of z_init: the 1.5x growth sequence the historical
    # per-point loop walked, precomputed (64 rungs reach ~1.1e11 rounds)
    _K0_LADDER = None

    @classmethod
    def _k0_ladder(cls) -> np.ndarray:
        if cls._K0_LADDER is None:
            ks = [1]
            while len(ks) < 64:
                ks.append(int(np.ceil(ks[-1] * 1.5)))
            cls._K0_LADDER = np.asarray(ks, dtype=np.float64)
        return cls._K0_LADDER

    def _grid_CTE(self, ks: np.ndarray, Kn: np.ndarray, B: np.ndarray,
                  gam_arr: Optional[np.ndarray],
                  S0: Optional[float] = None):
        """C/T/E surfaces over (grid point, K0 ladder) — evaluated with the
        very same :mod:`repro.core` closed forms :meth:`evaluate` uses
        (they broadcast over the ladder axis), so the feasibility search
        can never drift from the true cost model.  ``S0`` prices the
        surfaces at a concrete cohort size (free-S problems only)."""
        from ..core import convergence as conv
        from ..core.cost import energy_cost, time_cost
        c, qp = self._conv_coeffs(S0)
        pi = self._pi_at(S0)
        eps = self._agg_eps
        G, L = Kn.shape[0], ks.shape[0]
        C = np.empty((G, L))
        T = np.empty((G, L))
        E = np.empty((G, L))
        for g in range(G):
            if self.m is Objective.EXPONENTIAL:
                C[g] = conv.c_exponential(ks, Kn[g], B[g], self.gamma,
                                          self.rho, c, qp, eps)
            elif self.m is Objective.DIMINISHING:
                C[g] = conv.c_diminishing(ks, Kn[g], B[g], self.gamma,
                                          self.rho, c, qp, eps)
            else:   # CONSTANT, or JOINT at the grid's trial gamma
                gam = (gam_arr[g] if self.m is Objective.JOINT
                       else self.gamma)
                C[g] = conv.c_constant(ks, Kn[g], B[g], gam, c, qp, eps)
            T[g] = time_cost(self.sys, ks, Kn[g], B[g], worst_case=True)
            E[g] = energy_cost(self.sys, ks, Kn[g], B[g], pi=pi)
        return C, T, E

    def z_init(self) -> np.ndarray:
        """Find a *feasible* starting point of the original problem
        (Algorithms 2-5, line 1: "choose any feasible solution").

        Searches a small grid over the integer-ish actual variables and picks
        the smallest K0 restoring C <= C_max (C_m is non-increasing in K0).
        The whole (grid x K0-ladder) search evaluates the closed-form
        C/T/E surfaces as one broadcast NumPy computation; selection
        semantics (ladder walk, first C-feasible rung, first-wins energy
        ties) match the historical per-point loop.
        """
        v = self.vmap
        names = v.names
        z = np.zeros(v.n)
        gamma_grid = ([None] if self.m is not Objective.JOINT
                      else [0.5 / self.consts.L, 0.1 / self.consts.L,
                            0.01 / self.consts.L, 1.0 / self.consts.L])
        B_grid = (1, 2, 4, 8, 16, 32, 64, 128)
        K_grid = (1, 2, 3, 4, 6, 8, 12, 16, 24, 32)
        combos = [(gam, Bv, Kv) for gam in gamma_grid for Bv in B_grid
                  for Kv in K_grid]
        G = len(combos)
        ZC = np.zeros((G, v.n))
        for i, nm in enumerate(names):
            if nm.startswith("K") and nm != "K0" or nm == "l":
                ZC[:, i] = np.log([float(Kv) for _, _, Kv in combos])
            elif nm == "B":
                ZC[:, i] = np.log([float(Bv) for _, Bv, _ in combos])
            elif nm == "extra" and self.m is Objective.JOINT:
                ZC[:, i] = np.log([gam for gam, _, _ in combos])
        # paper variables at every grid point via the monomial map
        Kn = np.stack([np.exp(_log_posy_batch(k, ZC)) for k in v.Kn], axis=1)
        B = np.exp(_log_posy_batch(v.B, ZC))                       # (G,)
        gam_arr = (np.array([g for g, _, _ in combos])
                   if self.m is Objective.JOINT else None)
        ks = self._k0_ladder()                                     # (L,)
        L = ks.shape[0]
        # free-S problems search a halving ladder of cohort sizes too: the
        # local GIA polishes within the basin this seed lands in, so the
        # seed must compare S levels globally (the energy-optimal cohort
        # can sit far below the cap)
        if self._i_S is None:
            S_levels = [None]
        else:
            cap = max(1.0, float(np.floor(
                self.sampling.s_cap(self.sys.N) + 1e-9)))
            S_levels, sv = [], cap
            while True:
                S_levels.append(sv)
                if sv <= 1.0:
                    break
                sv = float(np.ceil(sv / 2.0))
        best = None                    # (E, g, first_c rung, S level)
        for S0 in S_levels:
            C, T, E = self._grid_CTE(ks, Kn, B, gam_arr, S0)       # (G, L)
            c_ok = C <= self.C_max * (1 - 1e-3)                    # (G, L)
            t_viol = T > self.T_max
            first_c = np.where(c_ok.any(axis=1), c_ok.argmax(axis=1), L)
            first_t = np.where(t_viol.any(axis=1), t_viol.argmax(axis=1), L)
            # the ladder walk stops at whichever comes first; C wins ties
            # (the loop checked C before the time break at each rung)
            hit = (first_c < L) & (first_c <= first_t)
            idx = np.where(hit, np.minimum(first_c, L - 1), 0)
            ok = hit & (T[np.arange(G), idx] <= self.T_max * (1 - 1e-3))
            if ok.any():
                E_hit = np.where(ok, E[np.arange(G), idx], np.inf)
                g_best = int(E_hit.argmin())           # first-wins ties
                if best is None or E_hit[g_best] < best[0]:
                    best = (float(E_hit[g_best]), g_best,
                            int(first_c[g_best]), S0)
        if best is not None:
            _, g_best, rung, S_sel = best
            gam, Bv, Kv = combos[g_best]
            K0 = int(ks[rung])
        else:  # no feasible grid point; fall back to a benign interior guess
            K0, Kv, Bv, gam = 64, 4, 4, (0.1 / self.consts.L
                                         if self.m is Objective.JOINT else None)
            S_sel = S_levels[0]
        for i, nm in enumerate(names):
            if nm == "K0":
                z[i] = np.log(float(K0))
            elif nm.startswith("K") or nm == "l":
                z[i] = np.log(float(Kv))
            elif nm == "B":
                z[i] = np.log(float(Bv))
            elif nm == "extra" and self.m is Objective.JOINT:
                z[i] = np.log(gam)
        if self._i_S is not None:          # seed at the grid-best cohort size
            z[self._i_S] = np.log(float(S_sel))
        Kn = np.array([float(np.exp(k.logvalue(z))) for k in v.Kn])
        ct = self.sys.comp_time_coeff_wc
        if "T1" in names:  # keep (22)/(23) strictly slack at the start
            z[names.index("T1")] = float(np.log(np.max(ct * Kn) * 1.5))
        if "T2" in names:
            z[names.index("T2")] = float(np.log(np.max(Kn) * 1.5))
        return self.project_expansion(z)

    # -- true (non-approximate) evaluation ------------------------------------
    def evaluate(self, K0: float, Kn: np.ndarray, B: float,
                 extra: Optional[float] = None,
                 S: Optional[float] = None) -> Dict[str, float]:
        """Closed-form (C, T, E) at a concrete point.  ``S`` is required
        (and only meaningful) when the cohort size is a free variable;
        ``E`` is then the *expected* energy over cohort draws."""
        from ..core import convergence as conv
        from ..core.cost import energy_cost, time_cost
        c, qp = self._conv_coeffs(S)
        eps = self._agg_eps
        if self.m is Objective.CONSTANT:
            C = conv.c_constant(K0, Kn, B, self.gamma, c, qp, eps)
        elif self.m is Objective.EXPONENTIAL:
            C = conv.c_exponential(K0, Kn, B, self.gamma, self.rho, c, qp,
                                   eps)
        elif self.m is Objective.DIMINISHING:
            C = conv.c_diminishing(K0, Kn, B, self.gamma, self.rho, c, qp,
                                   eps)
        elif self.m is Objective.JOINT:
            assert extra is not None
            C = conv.c_constant(K0, Kn, B, extra, c, qp, eps)
        return {
            "E": energy_cost(self.sys, K0, Kn, B, pi=self._pi_at(S)),
            "T": time_cost(self.sys, K0, Kn, B, worst_case=True),
            "C": C,
        }

    def feasible(self, K0, Kn, B, extra=None, rtol: float = 1e-6,
                 S: Optional[float] = None) -> bool:
        ev = self.evaluate(K0, np.asarray(Kn, dtype=np.float64), B, extra,
                           S=S)
        ok = (ev["T"] <= self.T_max * (1 + rtol)
              and ev["C"] <= self.C_max * (1 + rtol))
        if self.m is Objective.JOINT and extra is not None:
            ok = ok and extra <= 1.0 / self.consts.L * (1 + rtol)
        return ok
