"""CGP condensation tricks (Sec. V-B, [23, Lemma 1] + Taylor surrogates).

All surrogates here satisfy Marks-Wright GIA Properties (i)-(iii):
 (i)  surrogate upper-bounds the original constraint function,
 (ii) equality at the expansion point,
 (iii) gradient match at the expansion point.
"""
from __future__ import annotations

import numpy as np

from .posy import Posy, const

__all__ = ["amgm_monomial", "ratio_to_posy", "taylor_xlog1x", "taylor_logx"]


def amgm_monomial(p: Posy, z_prev: np.ndarray) -> Posy:
    """AM-GM condensation: posynomial p(x) >= prod_k (u_k(x)/beta_k)^beta_k,
    with beta_k = u_k(x_prev)/p(x_prev); the RHS is a monomial touching p at
    x_prev (value + gradient).  Used to under-approximate *denominators*.

    The weights are a max-shifted softmax over the term logs, so extreme
    expansion points can neither overflow a term value nor divide by a
    zero sum; terms whose weight underflows to exactly 0.0 are masked out
    of the log-coefficient (``0 * log 0`` must contribute 0, not -inf) and
    contribute exactly 0.0 to the exponent row.  The jnp mirror of this
    arithmetic lives in :mod:`repro.opt.refresh` — keep the two in lockstep
    (the fused-refresh parity suite asserts agreement to 1 ulp).
    """
    t = np.log(p.c) + p.A @ z_prev
    mx = t.max()
    e = np.exp(t - mx)
    beta = e / e.sum()
    # monomial coeff = prod (c_k/beta_k)^beta_k, exponents = sum beta_k A_k
    keep = beta > 0.0
    logc = float(np.sum(np.where(
        keep, beta * (np.log(p.c) - np.log(np.where(keep, beta, 1.0))), 0.0)))
    A = (beta[:, None] * p.A).sum(axis=0, keepdims=True)
    return Posy(np.array([np.exp(logc)]), A)


def ratio_to_posy(num: Posy, den: Posy, z_prev: np.ndarray) -> Posy:
    """Inner-approximate the ratio num/den (den posynomial) by the posynomial
    num / amgm_monomial(den): since M(x) <= den(x), num/M >= num/den —
    Property (i) — with equality and matched gradient at z_prev.
    """
    if den.is_monomial:
        return num / den
    return num / amgm_monomial(den, z_prev)


def taylor_xlog1x(x_prev: float):
    """Affine upper bound of phi(x) = x*log(1/x) (concave) at x_prev:
        phi(x) <= (log(1/x_prev) - 1) * x + x_prev.
    Returns (a, b) with phi(x) <= a*x + b; ``a`` may be negative (x_prev > 1/e)
    — callers must move that term across the inequality.
    """
    a = float(np.log(1.0 / x_prev) - 1.0)
    b = float(x_prev)
    return a, b


def taylor_logx(x_prev: float):
    """Affine upper bound of log(x) (concave) at x_prev:
        log(x) <= log(x_prev) - 1 + x / x_prev.
    Returns (a, b) with log(x) <= a*x + b.
    """
    return 1.0 / x_prev, float(np.log(x_prev) - 1.0)
