"""CGP condensation tricks (Sec. V-B, [23, Lemma 1] + Taylor surrogates).

All surrogates here satisfy Marks-Wright GIA Properties (i)-(iii):
 (i)  surrogate upper-bounds the original constraint function,
 (ii) equality at the expansion point,
 (iii) gradient match at the expansion point.
"""
from __future__ import annotations

import numpy as np

from .posy import Posy, const

__all__ = ["amgm_monomial", "ratio_to_posy", "taylor_xlog1x", "taylor_logx"]


def amgm_monomial(p: Posy, z_prev: np.ndarray) -> Posy:
    """AM-GM condensation: posynomial p(x) >= prod_k (u_k(x)/beta_k)^beta_k,
    with beta_k = u_k(x_prev)/p(x_prev); the RHS is a monomial touching p at
    x_prev (value + gradient).  Used to under-approximate *denominators*.
    """
    u = p.terms(z_prev)
    beta = u / u.sum()
    # monomial coeff = prod (c_k/beta_k)^beta_k, exponents = sum beta_k A_k
    keep = beta > 1e-300
    logc = float(np.sum(beta[keep] * (np.log(p.c[keep]) - np.log(beta[keep]))))
    A = (beta[:, None] * p.A).sum(axis=0, keepdims=True)
    return Posy(np.array([np.exp(logc)]), A)


def ratio_to_posy(num: Posy, den: Posy, z_prev: np.ndarray) -> Posy:
    """Inner-approximate the ratio num/den (den posynomial) by the posynomial
    num / amgm_monomial(den): since M(x) <= den(x), num/M >= num/den —
    Property (i) — with equality and matched gradient at z_prev.
    """
    if den.is_monomial:
        return num / den
    return num / amgm_monomial(den, z_prev)


def taylor_xlog1x(x_prev: float, n: int, idx: int):
    """Affine upper bound of phi(x) = x*log(1/x) (concave) at x_prev:
        phi(x) <= (log(1/x_prev) - 1) * x + x_prev.
    Returns (a, b) with phi(x) <= a*x + b; ``a`` may be negative (x_prev > 1/e)
    — callers must move that term across the inequality.
    """
    a = float(np.log(1.0 / x_prev) - 1.0)
    b = float(x_prev)
    return a, b


def taylor_logx(x_prev: float):
    """Affine upper bound of log(x) (concave) at x_prev:
        log(x) <= log(x_prev) - 1 + x / x_prev.
    Returns (a, b) with log(x) <= a*x + b.
    """
    return 1.0 / x_prev, float(np.log(x_prev) - 1.0)
