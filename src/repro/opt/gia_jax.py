"""Device-resident GIA: the whole outer loop as one jitted ``lax.while_loop``.

``backend="jnp-fused"`` of :func:`repro.opt.gia.solve_param_opt_batched`:
the per-expansion-point coefficient refresh (:mod:`repro.opt.refresh`), the
phase-I/Newton log-barrier interior point, and the per-instance convergence /
stall masking all live inside **one** ``lax.while_loop``, compiled once per
structure signature — a GIA outer iteration performs zero host syncs and
zero Python work, which is what turns 1e3+-point ``Scenario.sweep`` grids
into one compile + one device call per (m, family, N) group.

The loop is a per-row *state machine*, not a nest of per-phase loops: every
body iteration performs exactly one damped-Newton step for every row, and
each row independently advances its own schedule — phase-I stages, barrier
t-ramp, GIA expansion-point transitions (where the surrogate coefficients
refresh on device) — under lockstep masks.  A nested ``vmap``-of-while
formulation pays the *product* of per-level maxima across rows (a batch of
heterogeneously-converging instances runs every row to the slowest row's
iteration count at every nesting level); the flat machine pays only the
maximum of per-row *total* Newton-step counts, which is what makes batched
throughput scale with batch size instead of degrading with it.

Per-row semantics replicate the host loop in :mod:`repro.opt.gia` and the
scalar solver schedule in :mod:`repro.opt.gp` exactly: same Newton tolerance
and per-stage cap, same Armijo backtracking on precomputed term logs, same
damping ramp, same phase-I margins and stage budget, same barrier t-ramp,
same infeasible-retry / 8-strike stall-out bookkeeping.  Objective history
is journaled into a fixed ``(B, max_iter)`` buffer (NaN = no accepted step)
and unpacked host-side after the single device call.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.experimental import enable_x64

from ..obs import REGISTRY as _METRICS
from ..obs import trace as _otrace
from ..obs.metrics import GLOBAL_SWITCH as _OBS_ON
from .gp_jax import (_LS_ALPHA, _LS_BETA, _LS_MAX, _MU, _NEWTON_MAX,
                     _NEWTON_TOL, _P1_MARGIN, _P1_STAGES, _T0, _TOL_GAP)
from .problems import Objective
from .refresh import RefreshPlan, make_project, make_refresh
from .structure import PAD_LOGC

__all__ = ["solve_gia_fused", "trace_count", "TRACE_COUNTS",
           "compile_cache_info", "compile_cache_clear"]
#: host-loop stall budget, verbatim (gia.solve_param_opt_batched)
_STALL_MAX = 8
#: emergency bound on total body iterations (a legitimate solve is ~1e3-1e4
#: Newton steps; this only guards CI against a logic bug hanging the loop)
_IT_CAP = 1_000_000

#: fused-program trace counter per static signature key — the test hook
#: asserting "one compile per structure signature" (the traced body below
#: executes only while jax traces; cache hits never touch it)
TRACE_COUNTS: Dict[tuple, int] = {}


def trace_count(plan_or_key) -> int:
    key = getattr(plan_or_key, "signature_key", plan_or_key)
    return sum(v for k, v in TRACE_COUNTS.items() if k[0] == key)


def compile_cache_info():
    """Hit/miss statistics of the process-level fused-program cache.

    The cache is owned by the module (``functools.lru_cache`` on
    :func:`_compiled`), not by any solver or batch object: every
    ``Scenario.optimize``, sweep, and :class:`~repro.serve.PlanServer`
    micro-batch in the process shares the same traced refresh plans and
    compiled executables, keyed by (structure signature, max_iter) and —
    inside jax.jit — the padded batch shape.
    """
    return _compiled.cache_info()


def compile_cache_clear():
    _compiled.cache_clear()


@functools.lru_cache(maxsize=64)
def _compiled(m_value: str, n: int, m_cons: int, seg_bytes: bytes,
              caps: Tuple[int, ...], i_x0: int, max_iter: int,
              sampled: bool = False):
    seg = jnp.asarray(np.frombuffer(seg_bytes, dtype=np.int32))
    m = Objective(m_value)
    refresh_one = make_refresh(m, n, caps, sampled)
    project_one = make_project(m, i_x0)
    key = (m_value, n, m_cons, caps, seg_bytes, i_x0, sampled)

    def _seg_max(t):
        return jax.ops.segment_max(t, seg, num_segments=m_cons,
                                   indices_are_sorted=True)

    def _seg_sum(x):
        return jax.ops.segment_sum(x, seg, num_segments=m_cons,
                                   indices_are_sorted=True)

    def _expand(s):
        return s[seg]

    def g_of(z, logc, A):
        t = logc + A @ z
        mx = _seg_max(t)
        return mx + jnp.log(_seg_sum(jnp.exp(t - _expand(mx))))

    def f0_of(z, obj_logc, obj_A):
        t0 = obj_logc + obj_A @ z
        mx0 = jnp.max(t0)
        return mx0 + jnp.log(jnp.sum(jnp.exp(t0 - mx0)))

    def g_from_terms(t):
        mx = _seg_max(t)
        return mx + jnp.log(_seg_sum(jnp.exp(t - _expand(mx))))

    def barrier_aug(z, s, p1f, tscale, obj_logc, obj_A, logc, A):
        """(phi, grad, hess, g_main) of the row's current barrier over the
        (n+1) variables (z, S) — the phase-I slack enters *analytically*.

        In phase-I every constraint term carries a ``-S`` (the auxiliary GP
        divides each f_i by S) and the objective is S itself; because the
        per-constraint softmax weights sum to 1, the S-column of every
        per-constraint gradient is exactly -1 and all S-blocks of the
        Hessian reduce to weight sums — no (T, n+1) system is ever
        materialized, which keeps the hot loop's memory traffic to reads of
        the packed (log c, A) tensors.  In main mode (p1f = 0) the spare
        coordinate is ridged so the Newton system stays definite; its step
        component is exactly 0.
        """
        t0 = obj_logc + obj_A @ z
        mx0 = jnp.max(t0)
        e0 = jnp.exp(t0 - mx0)
        s0 = jnp.sum(e0)
        w0 = e0 / s0
        q0 = obj_A.T @ w0
        H0 = (obj_A.T * w0) @ obj_A - jnp.outer(q0, q0)
        f0 = p1f * s + (1.0 - p1f) * (mx0 + jnp.log(s0))
        t_main = logc + A @ z
        g_main = g_from_terms(t_main)
        t = t_main - s * p1f
        mx = _seg_max(t)
        e = jnp.exp(t - _expand(mx))
        ssum = _seg_sum(e)
        g = mx + jnp.log(ssum)
        negg = jnp.where(g < 0.0, -g, 1.0)
        phi = tscale * f0 - jnp.sum(jnp.log(negg))
        w = e / _expand(ssum)
        cinv = 1.0 / negg
        Q = _seg_sum(w[:, None] * A)
        wc = w * _expand(cinv)
        mv = cinv**2 - cinv
        grad_n = (1.0 - p1f) * (tscale * q0) + Q.T @ cinv
        grad_s = p1f * (tscale - jnp.sum(cinv))
        Awc = A.T @ wc
        Qm = Q.T @ mv
        H_nn = (1.0 - p1f) * (tscale * H0) + (A.T * wc) @ A \
            + (Q.T * mv) @ Q
        H_ns = p1f * (-Awc - Qm)
        H_ss = p1f * (jnp.sum(wc) + jnp.sum(mv)) + (1.0 - p1f)
        H = jnp.concatenate(
            [jnp.concatenate([H_nn, H_ns[:, None]], axis=1),
             jnp.concatenate([H_ns[None, :], H_ss[None, None]], axis=1)],
            axis=0)
        grad = jnp.concatenate([grad_n, grad_s[None]])
        phi = jnp.where(jnp.all(g < 0.0), phi, jnp.inf)
        return phi, grad, H, g_main, t_main, t0

    def run(tol, z0, obj_logc, obj_A, skel_logc, skel_A, arrays):
        # this body executes only while jax traces (cache hits never reach
        # it), so both hooks count trace/compile events, not dispatches
        TRACE_COUNTS[(key, z0.shape[0])] = \
            TRACE_COUNTS.get((key, z0.shape[0]), 0) + 1
        if _OBS_ON.on:
            _METRICS.counter("gia.compile_events").inc()
        B = z0.shape[0]
        eye = jnp.eye(n + 1)

        def row_body(z_aug, z_exp, z_out, c_logc, c_A, p1, t, p1_stage,
                     newton_it, gia_it, stall, conv, active, hist, nh,
                     o_logc, o_A, sk_logc, sk_A, a):
            logc = jnp.concatenate([sk_logc, c_logc])
            A = jnp.concatenate([sk_A, c_A], axis=0)
            p1f = jnp.where(p1, 1.0, 0.0)
            z = z_aug[:n]
            s = z_aug[n]
            phi, grad, H, g_main, t_main, t0 = barrier_aug(
                z, s, p1f, t, o_logc, o_A, logc, A)

            def damp_cond(cc):
                lam, L = cc
                return jnp.any(jnp.isnan(L)) & (lam < 1e8)

            def damp_body(cc):
                lam, _ = cc
                lam = jnp.maximum(lam * 10.0, 1e-10)
                return lam, jnp.linalg.cholesky(H + lam * eye)

            _, L = lax.while_loop(
                damp_cond, damp_body,
                (1e-12, jnp.linalg.cholesky(H + 1e-12 * eye)))
            step = -jax.scipy.linalg.cho_solve((L, True), grad)
            dec = -(grad @ step)
            small = dec / 2.0 <= _NEWTON_TOL
            gs = grad @ step
            dz, ds = step[:n], step[n]
            dt_main = A @ dz
            dt0 = o_A @ dz
            t_eff = t_main - s * p1f
            dt_eff = dt_main - ds * p1f

            def ls_cond(c):
                _, k, ok = c
                return (~ok) & (k < _LS_MAX)

            def ls_body(c):
                al, k, _ = c
                # barrier value along the ray from precomputed term logs
                # (the line-search hot path: no matvecs per backtrack)
                t0a = t0 + al * dt0
                mx0 = jnp.max(t0a)
                f0m = mx0 + jnp.log(jnp.sum(jnp.exp(t0a - mx0)))
                ga = g_from_terms(t_eff + al * dt_eff)
                phin = t * (p1f * (s + al * ds) + (1.0 - p1f) * f0m) \
                    - jnp.sum(jnp.log(jnp.where(ga < 0.0, -ga, 1.0)))
                phin = jnp.where(jnp.all(ga < 0.0), phin, jnp.inf)
                ok = jnp.isfinite(phin) & (phin <= phi + _LS_ALPHA * al * gs)
                return jnp.where(ok, al, al * _LS_BETA), k + 1, ok

            al, _, ls_ok = lax.while_loop(ls_cond, ls_body,
                                          (jnp.ones(()), 0, small))
            progressed = active & ~small & ls_ok
            au = jnp.where(progressed, al, 0.0)
            z_aug = jnp.where(progressed, z_aug + al * step, z_aug)
            newton_it = jnp.where(progressed, newton_it + 1, newton_it)
            stage_end = active & (small | ~ls_ok | (newton_it >= _NEWTON_MAX))

            # ---- stage transitions ------------------------------------
            # post-step term logs by linear shift — no fresh matvecs
            z_main = z_aug[:n]
            gmax = jnp.max(g_from_terms(t_main + au * dt_main))
            t0p = t0 + au * dt0
            mx0p = jnp.max(t0p)
            f0m = mx0p + jnp.log(jnp.sum(jnp.exp(t0p - mx0p)))
            s_val = z_aug[n]
            ok_margin = (s_val < -_P1_MARGIN) & (gmax < -_P1_MARGIN)
            p1_finished = ok_margin | (m_cons / t < 1e-9) \
                | (p1_stage + 1 >= _P1_STAGES)
            p1_ok = ok_margin | (gmax < 0.0)
            solve_done = (m_cons / t) < _TOL_GAP

            p1_orig = p1
            ramp = stage_end & jnp.where(p1_orig, ~p1_finished, ~solve_done)
            t = jnp.where(ramp, t * _MU, t)
            p1_stage = jnp.where(ramp & p1_orig, p1_stage + 1, p1_stage)
            newton_it = jnp.where(stage_end, 0, newton_it)

            p1_to_main = stage_end & p1_orig & p1_finished & p1_ok
            t = jnp.where(p1_to_main, _T0, t)

            # ---- GIA expansion-point transition -----------------------
            gia_tr = stage_end & jnp.where(p1_orig, p1_finished & ~p1_ok,
                                           solve_done)
            # feasible only via a completed main solve; a phase-I failure
            # is the infeasible-retry path (min-slack point, stall strike)
            feas = gia_tr & ~p1_orig & (gmax <= 1e-7)
            p1 = p1_orig & ~p1_to_main & ~gia_tr
            zp_next = project_one(z_main, a)
            # projected-vs-projected step, as in the host loop: m=E holds X0
            # a delta-margin off the manifold the projection re-imposes
            gstep = jnp.max(jnp.abs(zp_next - z_exp))
            hist = hist.at[gia_it].set(
                jnp.where(feas, jnp.exp(f0m), hist[gia_it]))
            nh = nh + feas
            stall = jnp.where(gia_tr, jnp.where(feas, 0, stall + 1), stall)
            newly_conv = feas & (gstep < tol)
            gia_next = jnp.where(gia_tr, gia_it + 1, gia_it)
            conv = conv | newly_conv
            active = active & jnp.where(
                gia_tr, ~newly_conv & (stall <= _STALL_MAX)
                & (gia_next < max_iter), True)
            z_out = jnp.where(gia_tr, z_main, z_out)
            z_exp = jnp.where(gia_tr & active, zp_next, z_exp)
            gia_it = gia_next

            # re-entry at the new expansion point: the device-side surrogate
            # refresh (AM-GM / Taylor condensation of repro.opt.refresh),
            # phase-I iff the retry point is not strictly feasible
            cl_new, cA_new = refresh_one(z_exp, a)
            reenter = gia_tr & active
            c_logc = jnp.where(reenter, cl_new, c_logc)
            c_A = jnp.where(reenter, cA_new, c_A)
            t_re = jnp.concatenate([sk_logc + sk_A @ z_exp,
                                    c_logc + c_A @ z_exp])
            g0 = jnp.max(g_from_terms(t_re))
            need_p1 = g0 >= 0.0
            p1 = jnp.where(reenter, need_p1, p1)
            t = jnp.where(reenter, _T0, t)
            p1_stage = jnp.where(reenter, 0, p1_stage)
            z_aug = jnp.where(
                reenter,
                jnp.concatenate([z_exp,
                                 jnp.where(need_p1, g0 + 1.0, 0.0)[None]]),
                z_aug)
            return (z_aug, z_exp, z_out, c_logc, c_A, p1, t, p1_stage,
                    newton_it, gia_it, stall, conv, active, hist, nh)

        row_body_v = jax.vmap(row_body)

        def body(st):
            rows, it = st
            return row_body_v(*rows, obj_logc, obj_A, skel_logc, skel_A,
                              arrays), it + 1

        def cond(st):
            rows, it = st
            return jnp.any(rows[12]) & (it < _IT_CAP)

        # initial GIA entry, identical to every later re-entry
        project_v = jax.vmap(project_one, in_axes=(0, 0))
        zp0 = project_v(z0, arrays)
        cl0, cA0 = jax.vmap(refresh_one, in_axes=(0, 0))(zp0, arrays)

        def g0_row(zp, cl, cA, sk_logc, sk_A):
            t_full = jnp.concatenate([sk_logc + sk_A @ zp, cl + cA @ zp])
            return jnp.max(g_from_terms(t_full))

        g0 = jax.vmap(g0_row)(zp0, cl0, cA0, skel_logc, skel_A)
        need_p1 = g0 >= 0.0
        z_aug0 = jnp.concatenate(
            [zp0, jnp.where(need_p1, g0 + 1.0, 0.0)[:, None]], axis=1)
        rows = (z_aug0, zp0, zp0, cl0, cA0, need_p1,
                jnp.full((B,), _T0), jnp.zeros(B, jnp.int32),
                jnp.zeros(B, jnp.int32), jnp.zeros(B, jnp.int32),
                jnp.zeros(B, jnp.int32), jnp.zeros(B, dtype=bool),
                jnp.ones(B, dtype=bool), jnp.full((B, max_iter), jnp.nan),
                jnp.zeros(B, jnp.int32))
        rows, _ = lax.while_loop(cond, body, (rows, jnp.int32(0)))
        return rows[2], rows[11], rows[13], rows[14]

    # donate the starting points' buffer (a no-op on CPU, which has no
    # donation support — avoid the warning there)
    donate = () if jax.default_backend() == "cpu" else (1,)
    return jax.jit(run, donate_argnums=donate)


def solve_gia_fused(problems: Sequence, z0s: Sequence[np.ndarray],
                    tol: float, max_iter: int, pad_to: int = 0
                    ) -> List[Tuple[np.ndarray, List[float], bool]]:
    """Run the fused lockstep GIA; returns per-instance
    ``(z, history, converged)`` for :func:`repro.opt.gia._finalize`.

    ``pad_to > len(problems)`` pads the device batch to a fixed row count by
    replicating row 0 (padding rows solve normally and are discarded), so
    every dispatch of a structure signature shares one jitted shape — a
    serving loop whose micro-batches vary in size still pays exactly one
    trace/compile per signature.  Padding rows cannot stretch the lockstep:
    the flat state machine's trip count is the max of per-row totals, and a
    duplicate of row 0 finishes exactly when row 0 does.
    """
    plan = RefreshPlan.build(problems)
    fn = _compiled(plan.m.value, plan.n, plan.m_cons, plan.seg.tobytes(),
                   plan.caps, plan.i_x0, int(max_iter), plan.sampled)
    z0 = np.stack([np.asarray(z, dtype=np.float64) for z in z0s])
    pad = int(pad_to) - len(problems)
    if pad > 0:
        def _pad(a):
            return np.concatenate([a, np.repeat(a[:1], pad, axis=0)])
        z0 = _pad(z0)
        plan = dataclasses.replace(
            plan, obj_logc=_pad(plan.obj_logc), obj_A=_pad(plan.obj_A),
            skel_logc=_pad(plan.skel_logc), skel_A=_pad(plan.skel_A),
            arrays={k: _pad(v) for k, v in plan.arrays.items()})
    _t0 = time.perf_counter() if _OBS_ON.on else 0.0
    with enable_x64():
        z, conv, hist, nh = fn(float(tol), z0,
                               plan.obj_logc, plan.obj_A, plan.skel_logc,
                               plan.skel_A, plan.arrays)
        # the single host sync of the whole solve
        z, conv, hist, nh = (np.asarray(z), np.asarray(conv),
                             np.asarray(hist), np.asarray(nh))
    if _OBS_ON.on:
        # stamped strictly after the sync above — the span brackets the
        # dispatch+sync the solve already paid, it never adds one
        _otrace.add_span("gia.fused_dispatch", _t0, time.perf_counter(),
                         rows=len(problems), padded=int(z0.shape[0]),
                         sig=str(plan.signature_key)[:160])
    out = []
    for i in range(len(problems)):
        col = hist[i]
        history = [float(v) for v in col[~np.isnan(col)]]
        assert len(history) == int(nh[i])
        out.append((z[i], history, bool(conv[i])))
    return out
