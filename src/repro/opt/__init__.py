"""The paper's optimization framework: GP solver + GIA/CGP (Algorithms 2-5)."""
from .posy import Posy, const, var, monomial
from .gp import GP, GPResult, solve_gp
from .condense import amgm_monomial, ratio_to_posy
from .problems import (Objective, ParamOptProblem, VarMap, identity_varmap,
                       pm_varmap, fa_varmap, pr_varmap)
from .gia import GIAResult, solve_param_opt
