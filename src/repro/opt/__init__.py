"""The paper's optimization framework: GP solver + GIA/CGP (Algorithms 2-5).

The solver engine is batched and backend-pluggable: problems sharing one
structure signature (same objective m, family varmap, worker count) pack into
fixed-shape systems (:mod:`repro.opt.structure`) that either the NumPy
reference interior point or the jitted+vmapped jnp backend
(:mod:`repro.opt.gp_jax`) solve whole batches of at once —
``solve_param_opt_batched`` is the lockstep GIA over such a batch.
``backend="jnp-fused"`` goes all the way: the surrogate coefficient refresh
itself runs on device (:mod:`repro.opt.refresh` traces a static per-signature
refresh plan from the skeleton) and the entire GIA — condensation, phase-I/
Newton interior point, convergence/stall masks — is one jitted
``lax.while_loop`` per structure signature (:mod:`repro.opt.gia_jax`), with
zero host syncs per outer iteration.
"""
from .posy import Posy, const, var, monomial
from .gp import (GP, GPResult, BatchedGPResult, GP_BACKENDS,
                 register_gp_backend, solve_gp, solve_gp_batch)
from .condense import amgm_monomial, ratio_to_posy
from .problems import (Objective, ParamOptProblem, VarMap, identity_varmap,
                       pm_varmap, fa_varmap, pr_varmap)
from .structure import GPStructure, PackedBatch, structure_signature
from .refresh import RefreshPlan
from .gia import (GIAResult, min_feasible_K0, min_feasible_K0_joint,
                  solve_param_opt, solve_param_opt_batched)
