"""jnp backend of the GP solver: one compiled call per padded structure.

The same log-barrier interior point as :mod:`repro.opt.gp` — phase-I
feasibility GP, damped-Cholesky Newton with Armijo backtracking, geometric
barrier schedule — written over the padded ``(log c, A, segment-id)`` layout
of :class:`~repro.opt.structure.PackedBatch`:

  * loops become ``lax.while_loop`` (Newton, line search, damping ramp,
    phase-I stages, barrier stages), so the whole solve is one XLA program;
  * per-constraint log-sum-exps / gradients / Hessian pieces are
    ``segment_sum``/``segment_max`` reductions over the flat term axis;
  * the program is ``vmap``-ped over a leading batch axis and jitted once per
    structure shape — hundreds of GP instances (a Fig.-5 sweep line, a
    baseline table column) solve in a single compiled call.

Everything runs in float64 (``jax.experimental.enable_x64`` scoped to this
module's calls — the training stack's default f32 is untouched): the barrier
schedule reaches t ~ 1e10, far past f32 resolution.  Parity with the NumPy
reference is asserted test-side across the full (m, family) grid.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.experimental import enable_x64

from .gp import BatchedGPResult, register_gp_backend

__all__ = ["solve_batch_jnp"]

# the NumPy reference's hyper-parameters, verbatim
_NEWTON_TOL = 1e-9
_NEWTON_MAX = 200
_LS_ALPHA, _LS_BETA, _LS_MAX = 0.25, 0.5, 60
_P1_MARGIN = 1e-3
_P1_STAGES = 40
_T0, _MU, _TOL_GAP = 1.0, 20.0, 1e-8


def _make_solver(n: int, m_cons: int, seg: np.ndarray):
    """Single-instance solver over the padded layout; closed over the shared
    segment ids so they compile to constants."""
    seg = jnp.asarray(seg, dtype=jnp.int32)

    def _seg_max(t):
        return jax.ops.segment_max(t, seg, num_segments=m_cons,
                                   indices_are_sorted=True)

    def _seg_sum(x):
        return jax.ops.segment_sum(x, seg, num_segments=m_cons,
                                   indices_are_sorted=True)

    def _expand(s):
        return s[seg]

    def lse_parts(z, logc, A):
        t = logc + A @ z
        mx = _seg_max(t)
        e = jnp.exp(t - _expand(mx))
        return mx, e, _seg_sum(e)

    def g_of(z, logc, A):
        mx, _, s = lse_parts(z, logc, A)
        return mx + jnp.log(s)

    def f0_parts(z, obj_logc, obj_A):
        t0 = obj_logc + obj_A @ z
        mx0 = jnp.max(t0)
        e0 = jnp.exp(t0 - mx0)
        s0 = jnp.sum(e0)
        return mx0 + jnp.log(s0), e0 / s0

    def value_from_terms(t0, t, tscale):
        """Barrier value from precomputed term logs (line-search hot path:
        moving along a fixed direction only shifts the term logs linearly,
        so the matvecs happen once per Newton step, not per backtrack)."""
        mx0 = jnp.max(t0)
        f0 = mx0 + jnp.log(jnp.sum(jnp.exp(t0 - mx0)))
        mx = _seg_max(t)
        g = mx + jnp.log(_seg_sum(jnp.exp(t - _expand(mx))))
        phi = tscale * f0 - jnp.sum(jnp.log(jnp.where(g < 0.0, -g, 1.0)))
        return jnp.where(jnp.all(g < 0.0), phi, jnp.inf)

    def barrier(z, tscale, obj_logc, obj_A, logc, A):
        """(phi, grad, hess) of t*f0 - sum log(-g_i); phi=inf off-domain."""
        f0, w0 = f0_parts(z, obj_logc, obj_A)
        q0 = obj_A.T @ w0
        H = tscale * ((obj_A.T * w0) @ obj_A - jnp.outer(q0, q0))
        grad = tscale * q0
        phi = tscale * f0
        mx, e, s = lse_parts(z, logc, A)
        g = mx + jnp.log(s)
        negg = jnp.where(g < 0.0, -g, 1.0)
        phi = phi - jnp.sum(jnp.log(negg))
        w = e / _expand(s)
        cinv = 1.0 / negg
        Q = _seg_sum(w[:, None] * A)                  # (m, nv) per-con grads
        grad = grad + Q.T @ cinv
        wc = w * _expand(cinv)
        H = H + (A.T * wc) @ A + (Q.T * (cinv**2 - cinv)) @ Q
        return jnp.where(jnp.all(g < 0.0), phi, jnp.inf), grad, H

    def newton(z, tscale, obj_logc, obj_A, logc, A):
        nv = z.shape[0]
        eye = jnp.eye(nv)

        def cond(c):
            _, it, done = c
            return (~done) & (it < _NEWTON_MAX)

        def body(c):
            z, it, done = c
            phi, grad, H = barrier(z, tscale, obj_logc, obj_A, logc, A)

            def damp_cond(cc):
                lam, L = cc
                return jnp.any(jnp.isnan(L)) & (lam < 1e8)

            def damp_body(cc):
                lam, _ = cc
                lam = jnp.maximum(lam * 10.0, 1e-10)
                return lam, jnp.linalg.cholesky(H + lam * eye)

            _, L = lax.while_loop(
                damp_cond, damp_body,
                (1e-12, jnp.linalg.cholesky(H + 1e-12 * eye)))
            step = -jax.scipy.linalg.cho_solve((L, True), grad)
            dec = -(grad @ step)
            small = dec / 2.0 <= _NEWTON_TOL
            gs = grad @ step
            # term logs at z and their per-unit-step increments: one matvec
            # pair here instead of one per backtrack
            t0_z = obj_logc + obj_A @ z
            t_z = logc + A @ z
            dt0 = obj_A @ step
            dt = A @ step

            def ls_cond(s):
                _, k, ok = s
                return (~ok) & (k < _LS_MAX)

            def ls_body(s):
                a, k, _ = s
                phin = value_from_terms(t0_z + a * dt0, t_z + a * dt, tscale)
                ok = jnp.isfinite(phin) & (phin <= phi + _LS_ALPHA * a * gs)
                return jnp.where(ok, a, a * _LS_BETA), k + 1, ok

            a, _, ls_ok = lax.while_loop(ls_cond, ls_body,
                                         (jnp.ones(()), 0, False))
            done_new = small | ~ls_ok                 # converged or stalled
            z_new = jnp.where(done_new, z, z + a * step)
            it_new = jnp.where(done_new, it, it + 1)
            return z_new, it_new, done_new

        z, it, _ = lax.while_loop(cond, body, (z, 0, False))
        return z, it

    def phase_one(z0, g0max, logc, A):
        """Strictly feasible z via the auxiliary GP  min S, f_i/S <= 1."""
        T = A.shape[0]
        A_aug = jnp.concatenate([A, -jnp.ones((T, 1))], axis=1)
        obj_logc1 = jnp.zeros((1,))
        obj_A1 = jnp.zeros((1, n + 1)).at[0, n].set(1.0)
        s0 = g0max + 1.0
        za = jnp.concatenate([z0, s0[None]])

        def cond(c):
            _, _, stage, _, finished, _ = c
            return (~finished) & (stage < _P1_STAGES)

        def body(c):
            za, t, stage, _, _, iters = c
            za, it = newton(za, t, obj_logc1, obj_A1, logc, A_aug)
            ok = ((za[n] < -_P1_MARGIN)
                  & (jnp.max(g_of(za[:n], logc, A)) < -_P1_MARGIN))
            finished = ok | (m_cons / t < 1e-9)
            return za, t * 20.0, stage + 1, ok, finished, iters + it

        # instances already strictly feasible skip phase-I entirely: the
        # stage loop starts finished (under vmap an all-feasible batch never
        # enters the body)
        skip = g0max < 0.0
        za, _, _, success, _, iters = lax.while_loop(
            cond, body, (za, jnp.ones(()), 0, False, skip, 0))
        z1 = za[:n]
        ok = success | (jnp.max(g_of(z1, logc, A)) < 0.0)
        return z1, ok, iters

    def solve_one(obj_logc, obj_A, logc, A, z0, active):
        """``active=False`` rows do no work: every loop starts finished, so
        a frozen GIA instance can't stretch the batch's lockstep iterations
        (its result row is a placeholder the engine never reads)."""
        g0max = jnp.where(active, jnp.max(g_of(z0, logc, A)), -1.0)
        need_p1 = g0max >= 0.0
        z_p1, p1_ok, p1_iters = phase_one(z0, g0max, logc, A)
        z = jnp.where(need_p1, z_p1, z0)
        p1_failed = need_p1 & ~p1_ok
        iters0 = jnp.where(need_p1, p1_iters, 0)

        def cond(c):
            _, _, done, _ = c
            return ~done

        def body(c):
            z, t, _, iters = c
            z, it = newton(z, t, obj_logc, obj_A, logc, A)
            return z, t * _MU, (m_cons / t) < _TOL_GAP, iters + it

        z, _, _, iters = lax.while_loop(
            cond, body, (z, jnp.full((), _T0), p1_failed | ~active, iters0))
        viol = jnp.max(g_of(z, logc, A))
        f0, _ = f0_parts(z, obj_logc, obj_A)
        feasible = jnp.where(p1_failed | ~active, False, viol <= 1e-7)
        return z, jnp.exp(f0), feasible, viol, iters

    return solve_one


@functools.lru_cache(maxsize=64)
def _compiled(n: int, m_cons: int, seg_bytes: bytes):
    seg = np.frombuffer(seg_bytes, dtype=np.int32)
    return jax.jit(jax.vmap(_make_solver(n, m_cons, seg)))


def solve_batch_jnp(pack) -> BatchedGPResult:
    """Solve a :class:`~repro.opt.structure.PackedBatch` in one jitted call."""
    fn = _compiled(pack.n, pack.m_cons,
                   np.ascontiguousarray(pack.seg, dtype=np.int32).tobytes())
    with enable_x64():
        z, obj, feas, viol, iters = fn(pack.obj_logc, pack.obj_A,
                                       pack.con_logc, pack.con_A, pack.z0,
                                       pack.active)
    return BatchedGPResult(z=np.asarray(z), obj=np.asarray(obj),
                           feasible=np.asarray(feas, dtype=bool),
                           max_violation=np.asarray(viol),
                           newton_iters=np.asarray(iters, dtype=np.int64))


register_gp_backend("jnp", solve_batch_jnp)
