"""Posynomial / monomial algebra for geometric programming.

A posynomial  f(x) = sum_k c_k * prod_i x_i^{A_ki}  with c_k > 0 is stored as
``(c, A)``.  In log variables ``z = log x`` its log is the convex function
``logf(z) = LSE(log c + A z)``.
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["Posy", "const", "var", "monomial"]

_F64 = np.dtype(np.float64)


@dataclasses.dataclass
class Posy:
    c: np.ndarray  # (K,) positive coefficients
    A: np.ndarray  # (K, n) exponents

    def __post_init__(self):
        # fast path: the algebra operators below hand in well-formed float64
        # arrays by construction (this constructor is the hot spot of every
        # surrogate refresh in the GIA loop)
        c, A = self.c, self.A
        if not (type(c) is np.ndarray and c.dtype == _F64 and c.ndim == 1):
            self.c = c = np.atleast_1d(np.asarray(c, dtype=np.float64))
        if not (type(A) is np.ndarray and A.dtype == _F64 and A.ndim == 2):
            self.A = A = np.atleast_2d(np.asarray(A, dtype=np.float64))
        assert c.ndim == 1 and A.ndim == 2
        assert c.shape[0] == A.shape[0], (c.shape, A.shape)
        if c.min(initial=np.inf) <= 0:
            raise ValueError(f"posynomial coefficients must be > 0, got {c}")

    # ------------------------------------------------------------------
    @property
    def n(self) -> int:
        return self.A.shape[1]

    @property
    def n_terms(self) -> int:
        return self.c.shape[0]

    @property
    def is_monomial(self) -> bool:
        return self.n_terms == 1

    # ------------------------------------------------------------------
    def __add__(self, other):
        other = _coerce(other, self.n)
        return Posy(np.concatenate([self.c, other.c]),
                    np.concatenate([self.A, other.A], axis=0))

    __radd__ = __add__

    def __mul__(self, other):
        if np.isscalar(other):
            if other <= 0:
                raise ValueError("scalar factor must be > 0")
            return Posy(self.c * float(other), self.A)
        other = _coerce(other, self.n)
        # general product: cross terms (sizes here are tiny)
        c = (self.c[:, None] * other.c[None, :]).reshape(-1)
        A = (self.A[:, None, :] + other.A[None, :, :]).reshape(-1, self.n)
        return Posy(c, A)

    __rmul__ = __mul__

    def __truediv__(self, other):
        if np.isscalar(other):
            return self * (1.0 / float(other))
        other = _coerce(other, self.n)
        if not other.is_monomial:
            raise ValueError("can only divide by a monomial; condense first")
        return self * Posy(1.0 / other.c, -other.A)

    def __rtruediv__(self, other):
        """scalar / monomial."""
        if not self.is_monomial:
            raise ValueError("can only divide by a monomial; condense first")
        if np.isscalar(other):
            return Posy(np.array([float(other)]) / self.c, -self.A)
        return _coerce(other, self.n) / self

    def __pow__(self, p: float):
        if not self.is_monomial:
            if float(p) == int(p) and p >= 1:
                out = self
                for _ in range(int(p) - 1):
                    out = out * self
                return out
            raise ValueError("non-integer powers only for monomials")
        return Posy(self.c ** float(p), self.A * float(p))

    # ------------------------------------------------------------------
    def logvalue(self, z: np.ndarray) -> float:
        t = np.log(self.c) + self.A @ z
        m = t.max()
        return float(m + np.log(np.exp(t - m).sum()))

    def value(self, z: np.ndarray) -> float:
        """Value at log-point z (i.e. at x = exp(z))."""
        return float(np.exp(self.logvalue(z)))

    def terms(self, z: np.ndarray) -> np.ndarray:
        """Per-term values at log-point z."""
        return np.exp(np.log(self.c) + self.A @ z)

    def grad_hess_log(self, z: np.ndarray):
        """(logf, grad, hess) of logf(z) = LSE(log c + A z) — both analytic."""
        t = np.log(self.c) + self.A @ z
        m = t.max()
        e = np.exp(t - m)
        s = e.sum()
        w = e / s
        g = self.A.T @ w
        H = (self.A.T * w) @ self.A - np.outer(g, g)
        return float(m + np.log(s)), g, H


def _coerce(x, n: int) -> Posy:
    if isinstance(x, Posy):
        assert x.n == n, (x.n, n)
        return x
    if np.isscalar(x):
        return const(float(x), n)
    raise TypeError(type(x))


def const(val: float, n: int) -> Posy:
    return Posy(np.array([val]), np.zeros((1, n)))


def var(i: int, n: int, power: float = 1.0, coeff: float = 1.0) -> Posy:
    A = np.zeros((1, n))
    A[0, i] = power
    return Posy(np.array([coeff]), A)


def monomial(coeff: float, powers: dict, n: int) -> Posy:
    A = np.zeros((1, n))
    for i, p in powers.items():
        A[0, i] = p
    return Posy(np.array([coeff]), A)
