"""Padded fixed-shape GP systems: the data layout of the batched solver.

A :class:`ParamOptProblem`'s GP sequence has a fixed *structure* determined by
``(m, family varmap, N)``: the objective and the common constraints (22)-(24)
plus box bounds never change between GIA iterations, and the convergence-error
block always contains the same constraints — only its coefficients (and the
AM-GM-condensed exponent rows) are refreshed at each expansion point.

:class:`GPStructure` freezes that layout into flat ``(log c, A, segment-id)``
arrays padded to per-constraint term capacities, so a whole batch of problem
instances sharing one structure — e.g. every ``C_max`` on a Fig.-5 sweep line
— stacks into dense ``(B, T)`` / ``(B, T, n)`` tensors that one compiled
solver call (see :mod:`repro.opt.gp_jax`) handles at once.  Padding terms
carry ``log c = -1e30``: they contribute exactly ``0.0`` to every
log-sum-exp, gradient, and Hessian in float64, so padded and unpadded systems
solve identically.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import List, Optional, Sequence, Tuple

import numpy as np

from .gp import GP
from .posy import Posy
from .problems import ParamOptProblem

__all__ = ["PAD_LOGC", "GPStructure", "PackedBatch", "structure_signature"]

#: log-coefficient of padding terms — exp(PAD_LOGC + A z) == 0.0 exactly
PAD_LOGC = -1e30


def structure_signature(problem: ParamOptProblem) -> tuple:
    """Hashable key identifying the fixed GP layout of a problem instance.

    Instances with equal signatures (same objective m, same variable map
    shape, same worker count, same algorithm-family key, same sampling
    model) produce GPs of identical constraint counts and can be stacked
    into one :class:`PackedBatch`; budgets, step-size parameters, and
    system constants only change coefficients.  The family key is part of
    the signature even though families never change the packed *shapes*
    (:mod:`repro.families` hooks are coefficient-only) so sweep grouping
    and the fused-program trace counters stay per-family.  The sampling
    element works the same way for pinned-cohort models (coefficient-only
    inflation — shapes match the unsampled problem, but a full-
    participation plan must never key a sampled scenario's cache pool);
    free-``S`` models also grow the varmap, so they differ in shape too.
    Neutral sampling (full participation, ``uniform(S=N)``) reports
    ``("full",)`` and shares the default problems' pools.  The fault
    element (repro.faults) follows the sampling pattern: coefficient-only
    (availability / worst-case margins never change packed shapes), but a
    faulted plan must never key an unfaulted scenario's cache pool;
    neutral fault models report ``("none",)`` and share the default pools.
    """
    v = problem.vmap
    return (problem.m, v.n, tuple(v.names), problem.sys.N,
            problem.family.key, problem.sampling.signature(problem.sys.N),
            problem.faults.signature(problem.sys.N))


@dataclasses.dataclass
class PackedBatch:
    """One batch of same-structure GP instances in solver-ready layout.

    ``active`` marks rows whose solution the caller will read this
    iteration; inactive rows (converged / stalled-out GIA instances) carry
    their last packed coefficients and backends skip the work — their
    result rows are placeholders.
    """

    n: int                     # number of variables
    m_cons: int                # number of constraints (shared)
    seg: np.ndarray            # (T,) int32 constraint id per term (shared)
    obj_logc: np.ndarray       # (B, K_obj)
    obj_A: np.ndarray          # (B, K_obj, n)
    con_logc: np.ndarray       # (B, T)
    con_A: np.ndarray          # (B, T, n)
    z0: np.ndarray             # (B, n) projected expansion points
    active: np.ndarray         # (B,) bool
    convs: List[List[Posy]]    # per-instance convergence blocks
    problems: List[ParamOptProblem]

    @property
    def batch(self) -> int:
        return self.obj_logc.shape[0]

    @functools.cached_property
    def gps(self) -> List[GP]:
        """The unpadded per-instance GPs — built only when a backend
        actually walks them (the reference NumPy path; the jnp backend
        consumes the packed arrays directly)."""
        out = []
        for p, conv in zip(self.problems, self.convs):
            obj, common = p.skeleton
            out.append(GP(obj, list(common) + conv))
        return out


class GPStructure:
    """The fixed layout shared by a batch of same-signature problems.

    Term capacities for the convergence block grow monotonically if an
    expansion point ever needs more terms (the m=E Taylor branch flips
    between 1 and 2 terms); a growth changes the padded shapes and therefore
    triggers one re-compile of the jnp backend, nothing else.
    """

    def __init__(self, template: ParamOptProblem):
        self.signature = structure_signature(template)
        self.n = template.vmap.n
        obj, common = template.skeleton
        self.obj_terms = obj.n_terms
        self.common_sizes: Tuple[int, ...] = tuple(c.n_terms for c in common)
        self.n_common = len(common)
        self.n_common_terms = int(sum(self.common_sizes))
        self.conv_caps: Optional[List[int]] = None
        self._last: dict = {}     # instance idx -> (zp, conv) of last refresh
        self._seg: Optional[np.ndarray] = None     # for the current caps
        self._obj: dict = {}      # instance idx -> (log c, A) of objective

    # ------------------------------------------------------------------
    def _segments(self) -> np.ndarray:
        if self._seg is None:
            sizes = list(self.common_sizes) + list(self.conv_caps)
            self._seg = np.repeat(np.arange(len(sizes), dtype=np.int32),
                                  np.asarray(sizes, dtype=np.int64))
        return self._seg

    def pack_batch(self, problems: Sequence[ParamOptProblem],
                   zs: Sequence[np.ndarray],
                   active: Optional[Sequence[bool]] = None) -> PackedBatch:
        """Refresh coefficients at each instance's expansion point and stack.

        Returns projected expansion points in ``z0`` — callers must carry
        those (not the raw inputs) so step sizes match the scalar GIA loop.
        Inactive instances are not refreshed: they keep their last packed
        coefficients (their current ``z`` may be a stalled phase-I point
        the surrogate constructors were never meant to expand at).
        """
        B = len(problems)
        if active is None:
            active = [True] * B
        builds = []
        for i, (p, z) in enumerate(zip(problems, zs)):
            if structure_signature(p) != self.signature:
                raise ValueError(
                    f"problem signature {structure_signature(p)} does not "
                    f"match batch structure {self.signature}")
            if active[i] or i not in self._last:
                zp = p.project_expansion(np.asarray(z, dtype=np.float64))
                self._last[i] = (zp, p.conv_block(zp))
            zp, conv = self._last[i]
            builds.append((p, zp, conv))

        sizes = [[c.n_terms for c in conv] for _, _, conv in builds]
        n_conv = len(sizes[0])
        caps = [max(s[j] for s in sizes) for j in range(n_conv)]
        if self.conv_caps is None:
            self.conv_caps = caps
        elif any(b > a for a, b in zip(self.conv_caps, caps)):
            self.conv_caps = [max(a, b)
                              for a, b in zip(self.conv_caps, caps)]
            self._seg = None             # padded layout grew: new segments

        n, ncomm = self.n, self.n_common_terms
        T = ncomm + int(sum(self.conv_caps))
        obj_logc = np.empty((B, self.obj_terms))
        obj_A = np.empty((B, self.obj_terms, n))
        con_logc = np.full((B, T), PAD_LOGC)
        con_A = np.zeros((B, T, n))
        z0 = np.empty((B, n))
        for i, (p, zp, conv) in enumerate(builds):
            if i not in self._obj:           # objective is z-independent
                obj = p.skeleton[0]
                self._obj[i] = (np.log(obj.c), obj.A)
            obj_logc[i], obj_A[i] = self._obj[i]
            s_logc, s_A = p.packed_skeleton
            con_logc[i, :ncomm] = s_logc
            con_A[i, :ncomm] = s_A
            off = ncomm
            for cap, c in zip(self.conv_caps, conv):
                k = c.n_terms
                con_logc[i, off:off + k] = np.log(c.c)
                con_A[i, off:off + k] = c.A
                off += cap
            z0[i] = zp
        return PackedBatch(n=n, m_cons=self.n_common + n_conv,
                           seg=self._segments(), obj_logc=obj_logc,
                           obj_A=obj_A, con_logc=con_logc, con_A=con_A,
                           z0=z0, active=np.asarray(active, dtype=bool),
                           convs=[conv for _, _, conv in builds],
                           problems=list(problems))
