"""phi3.5-moe-42b-a6.6b [moe] — 16 experts top-2
[hf:microsoft/Phi-3.5-MoE-instruct]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="phi3.5-moe-42b-a6.6b", family="moe",
    citation="hf:microsoft/Phi-3.5-MoE-instruct",
    n_layers=32, d_model=4096, n_heads=32, n_kv=8, d_ff=6400, vocab=32064,
    d_head=128, pattern=("attn_moe",), n_experts=16, top_k=2, d_expert=6400,
    rope_theta=1e4, capacity_factor=1.0)

SMOKE = ArchConfig(
    name="phi35-moe-smoke", family="moe",
    citation="hf:microsoft/Phi-3.5-MoE-instruct",
    n_layers=2, d_model=256, n_heads=4, n_kv=2, d_ff=256, vocab=512,
    d_head=64, pattern=("attn_moe",), n_experts=4, top_k=2, d_expert=256,
    rope_theta=1e4)
