"""xlstm-1.3b [ssm] — sLSTM + mLSTM blocks, xLSTM[7:1] ratio
[arXiv:2405.04517]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-1.3b", family="ssm", citation="arXiv:2405.04517",
    n_layers=48, d_model=2048, n_heads=4, n_kv=4, d_ff=0, vocab=50304,
    d_head=512, pattern=("mlstm",) * 7 + ("slstm",))

SMOKE = ArchConfig(
    name="xlstm-smoke", family="ssm", citation="arXiv:2405.04517",
    n_layers=2, d_model=256, n_heads=4, n_kv=4, d_ff=0, vocab=512,
    d_head=64, pattern=("mlstm", "slstm"))
