"""llama3-405b [dense] — GQA, 128k vocab [arXiv:2407.21783]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="llama3-405b", family="dense", citation="arXiv:2407.21783",
    n_layers=126, d_model=16384, n_heads=128, n_kv=8, d_ff=53248,
    vocab=128256, d_head=128, pattern=("attn",), rope_theta=5e5)

SMOKE = ArchConfig(
    name="llama3-smoke", family="dense", citation="arXiv:2407.21783",
    n_layers=2, d_model=512, n_heads=8, n_kv=2, d_ff=1024, vocab=512,
    d_head=64, pattern=("attn",), rope_theta=5e5)
