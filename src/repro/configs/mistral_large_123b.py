"""mistral-large-123b [dense] — GQA  [hf:mistralai/Mistral-Large-Instruct-2407]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="mistral-large-123b", family="dense",
    citation="hf:mistralai/Mistral-Large-Instruct-2407",
    n_layers=88, d_model=12288, n_heads=96, n_kv=8, d_ff=28672, vocab=32768,
    d_head=128, pattern=("attn",), rope_theta=1e6)

SMOKE = ArchConfig(
    name="mistral-large-smoke", family="dense",
    citation="hf:mistralai/Mistral-Large-Instruct-2407",
    n_layers=2, d_model=384, n_heads=6, n_kv=2, d_ff=768, vocab=512,
    d_head=64, pattern=("attn",), rope_theta=1e6)
