"""olmoe-1b-7b [moe] — 64 experts top-8, d_expert=1024 [arXiv:2409.02060]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="olmoe-1b-7b", family="moe", citation="arXiv:2409.02060",
    n_layers=16, d_model=2048, n_heads=16, n_kv=16, d_ff=1024, vocab=50304,
    d_head=128, pattern=("attn_moe",), n_experts=64, top_k=8, d_expert=1024,
    rope_theta=1e4)

SMOKE = ArchConfig(
    name="olmoe-smoke", family="moe", citation="arXiv:2409.02060",
    n_layers=2, d_model=256, n_heads=4, n_kv=4, d_ff=128, vocab=512,
    d_head=64, pattern=("attn_moe",), n_experts=4, top_k=2, d_expert=128,
    rope_theta=1e4)
