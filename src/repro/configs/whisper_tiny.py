"""whisper-tiny [audio] — enc-dec, conv frontend STUB [arXiv:2212.04356].
n_layers counts decoder layers; the encoder has enc_layers more."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-tiny", family="audio", citation="arXiv:2212.04356",
    n_layers=4, d_model=384, n_heads=6, n_kv=6, d_ff=1536, vocab=51865,
    d_head=64, encdec=True, enc_layers=4, max_source_positions=1500)

SMOKE = ArchConfig(
    name="whisper-smoke", family="audio", citation="arXiv:2212.04356",
    n_layers=2, d_model=128, n_heads=2, n_kv=2, d_ff=256, vocab=512,
    d_head=64, encdec=True, enc_layers=2, max_source_positions=64)
