"""qwen2-vl-7b [vlm] — M-RoPE, dynamic resolution (ViT frontend STUB)
[arXiv:2409.12191]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-7b", family="vlm", citation="arXiv:2409.12191",
    n_layers=28, d_model=3584, n_heads=28, n_kv=4, d_ff=18944, vocab=152064,
    d_head=128, pattern=("attn",), mrope=True, mrope_sections=(16, 24, 24),
    rope_theta=1e6, vision_patches_frac=0.25)

SMOKE = ArchConfig(
    name="qwen2-vl-smoke", family="vlm", citation="arXiv:2409.12191",
    n_layers=2, d_model=256, n_heads=4, n_kv=2, d_ff=512, vocab=512,
    d_head=64, pattern=("attn",), mrope=True, mrope_sections=(8, 12, 12),
    rope_theta=1e6, vision_patches_frac=0.25)
