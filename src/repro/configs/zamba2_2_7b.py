"""zamba2-2.7b [hybrid] — Mamba2 blocks + one weight-shared attention block
applied every 6th layer, ssm_state=64 [arXiv:2411.15242]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-2.7b", family="hybrid", citation="arXiv:2411.15242",
    n_layers=54, d_model=2560, n_heads=32, n_kv=32, d_ff=10240, vocab=32000,
    d_head=80, pattern=("mamba2",) * 5 + ("shared_attn",),
    ssm_state=64, ssm_expand=2, ssm_head_dim=64)

SMOKE = ArchConfig(
    name="zamba2-smoke", family="hybrid", citation="arXiv:2411.15242",
    n_layers=3, d_model=256, n_heads=4, n_kv=4, d_ff=512, vocab=512,
    d_head=64, pattern=("mamba2", "mamba2", "shared_attn"),
    ssm_state=16, ssm_expand=2, ssm_head_dim=64)
