"""Architecture + run configuration schema.

Every assigned architecture gets one ``<id>.py`` in this package exporting
``CONFIG`` (the exact published shape) and ``SMOKE`` (a reduced variant of the
same family: <=2 pattern repeats, d_model <= 512, <= 4 experts) — the full
config is only ever lowered abstractly (dry-run), the smoke one actually runs
a step on CPU.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

__all__ = ["ArchConfig", "InputShape", "INPUT_SHAPES", "MeshLayout"]


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    citation: str                    # source model card / paper
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    d_head: int = 0                  # 0 -> d_model // n_heads
    # --- layer pattern: cycled to n_layers, then segmented into runs --------
    # block ids: attn | attn_moe | local | global | mamba2 | shared_attn |
    #            mlstm | slstm
    pattern: Tuple[str, ...] = ("attn",)
    # --- attention options ---------------------------------------------------
    qk_norm: bool = False
    window: Optional[int] = None     # sliding-window size for "local" blocks
    rope_theta: float = 1e4
    mrope: bool = False              # M-RoPE (3D positions), qwen2-vl
    mrope_sections: Tuple[int, ...] = (16, 24, 24)  # t/h/w split of d_head/2
    # --- MoE ------------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    d_expert: int = 0                # expert hidden size (olmoe: 1024)
    capacity_factor: float = 1.25
    # --- SSM (mamba2) ----------------------------------------------------------
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    # --- enc-dec (whisper) ------------------------------------------------------
    encdec: bool = False
    enc_layers: int = 0
    max_source_positions: int = 1500  # whisper frame cap (30 s audio)
    # --- vlm stub -----------------------------------------------------------------
    vision_patches_frac: float = 0.25  # fraction of seq filled by patch embeds
    # --- misc ---------------------------------------------------------------------
    tie_embeddings: bool = True
    norm_eps: float = 1e-6

    def __post_init__(self):
        if self.d_head == 0:
            object.__setattr__(self, "d_head", self.d_model // self.n_heads)

    # --- derived ------------------------------------------------------------
    @property
    def layer_types(self) -> Tuple[str, ...]:
        reps = -(-self.n_layers // len(self.pattern))
        return (self.pattern * reps)[: self.n_layers]

    @property
    def segments(self) -> Tuple[Tuple[str, int], ...]:
        """Maximal runs of equal block type — each becomes one scan."""
        segs = []
        for t in self.layer_types:
            if segs and segs[-1][0] == t:
                segs[-1][1] += 1
            else:
                segs.append([t, 1])
        return tuple((t, c) for t, c in segs)

    @property
    def attention_free(self) -> bool:
        return all(t in ("mamba2", "mlstm", "slstm") for t in self.layer_types)

    @property
    def full_attention_only(self) -> bool:
        """True if every attention block is unwindowed full attention."""
        return any(t in ("attn", "attn_moe", "global", "shared_attn")
                   for t in self.layer_types) and self.window is None

    def supports_long_context(self) -> bool:
        """Eligible for long_500k: sub-quadratic per-token decode state growth
        bounded by windows/recurrence, or explicitly windowed + sparse-global.
        """
        if self.encdec:
            return False
        if self.attention_free:
            return True
        # hybrid / windowed archs with only sparse global layers qualify
        types = set(self.layer_types)
        if "mamba2" in types or "mlstm" in types:
            return True
        return self.window is not None and "local" in types

    # --- parameter counting (for MODEL_FLOPS and reporting) -------------------
    def param_count(self, active_only: bool = False) -> int:
        D, H, KV, dh, F, V = (self.d_model, self.n_heads, self.n_kv,
                              self.d_head, self.d_ff, self.vocab)
        total = V * D  # embedding
        if not self.tie_embeddings:
            total += V * D
        for t in self.layer_types:
            if t in ("attn", "local", "global", "shared_attn", "attn_moe"):
                attn = D * H * dh + 2 * D * KV * dh + H * dh * D
                total += attn + 2 * D
                if t == "attn_moe":
                    e = self.top_k if active_only else self.n_experts
                    total += self.n_experts * D  # router always resident
                    total += e * 3 * D * self.d_expert
                else:
                    total += 3 * D * F
            elif t == "mamba2":
                di = self.ssm_expand * D
                nh = di // self.ssm_head_dim
                total += D * (2 * di + 2 * self.ssm_state + nh) + di * D
                total += 2 * D
            elif t in ("mlstm", "slstm"):
                di = 2 * D if t == "mlstm" else D
                total += D * 2 * di + 3 * di * (di // max(self.n_heads, 1)) \
                    + di * D + 2 * D
        return int(total)


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class MeshLayout:
    """How the physical mesh folds into logical (fl, fsdp, tp) axes.

    fl   — federated-worker axis (GenQSGD replica groups; pods fold in here)
    fsdp — parameter/batch sharding inside one worker
    tp   — tensor parallel
    """
    fl_sub: int = 1     # how many FL workers per pod (divides the data axis)
    tp: int = 16

    def logical_shape(self, pods: int, data: int, model: int):
        assert data % self.fl_sub == 0
        assert model == self.tp
        return (pods * self.fl_sub, data // self.fl_sub, model)
