from .base import ArchConfig, InputShape, INPUT_SHAPES, MeshLayout
