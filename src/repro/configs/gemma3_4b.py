"""gemma3-4b [dense] — 5:1 local:global sliding-window, 128k context
[hf:google/gemma-3-1b-pt family].  Single rope_theta (1e6) is used for both
local and global layers (the HF card uses 10k local / 1M global)."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-4b", family="dense", citation="hf:google/gemma-3-1b-pt",
    n_layers=34, d_model=2560, n_heads=8, n_kv=4, d_ff=10240, vocab=262144,
    d_head=256, pattern=("local",) * 5 + ("global",), window=1024,
    qk_norm=True, rope_theta=1e6)

SMOKE = ArchConfig(
    name="gemma3-smoke", family="dense", citation="hf:google/gemma-3-1b-pt",
    n_layers=3, d_model=256, n_heads=4, n_kv=2, d_ff=512, vocab=512,
    d_head=64, pattern=("local", "local", "global"), window=64,
    qk_norm=True, rope_theta=1e6)
