"""qwen3-1.7b [dense] — qk_norm, GQA  [hf:Qwen/Qwen3-8B family]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-1.7b", family="dense", citation="hf:Qwen/Qwen3-8B",
    n_layers=28, d_model=2048, n_heads=16, n_kv=8, d_ff=6144, vocab=151936,
    d_head=128, pattern=("attn",), qk_norm=True, rope_theta=1e6)

SMOKE = ArchConfig(
    name="qwen3-smoke", family="dense", citation="hf:Qwen/Qwen3-8B",
    n_layers=2, d_model=256, n_heads=4, n_kv=2, d_ff=512, vocab=512,
    d_head=64, pattern=("attn",), qk_norm=True, rope_theta=1e6)
