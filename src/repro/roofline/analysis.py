"""Roofline extraction from compiled XLA artifacts.

Three terms per (arch × shape × mesh), in seconds:
    compute    = HLO_FLOPs / (chips × peak_FLOP/s)
    memory     = HLO_bytes  / (chips × HBM_bw)
    collective = collective_bytes / (chips × link_bw)

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()``; collective
bytes are parsed from ``compiled.as_text()`` by summing the result-shape
bytes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute op.

Scan caveat (measured, see DESIGN.md §5): XLA counts a while-loop body once,
so for layer-scanned programs the caller extrapolates using 1-repeat and
2-repeat *unrolled* compiles: per_rep = cost(2) - cost(1);
total = cost(1) + (reps - 1) * per_rep.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

__all__ = ["HW", "TPU_V5E", "cost_summary", "collective_bytes",
           "roofline_terms", "extrapolate", "encode_bytes",
           "achieved_bandwidth", "host_peak_bandwidth"]


@dataclasses.dataclass(frozen=True)
class HW:
    name: str
    peak_flops: float     # bf16 FLOP/s per chip
    hbm_bw: float         # bytes/s per chip
    ici_bw: float         # bytes/s per link per chip


TPU_V5E = HW("tpu_v5e", 197e12, 819e9, 50e9)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s+((?:\([^)]*\))|(?:\w+\[[^\]]*\]))\S*\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """bytes of 'f32[128,256]' or tuple '(f32[2,4], s8[8])'."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict:
    """Sum result bytes per collective op kind over the whole module.

    Note: ops inside while bodies appear once (see scan caveat).
    """
    out = {"all-gather": 0, "all-reduce": 0, "reduce-scatter": 0,
           "all-to-all": 0, "collective-permute": 0}
    counts = dict.fromkeys(out, 0)
    for m in _COLL_RE.finditer(hlo_text):
        shape_str, op = m.group(1), m.group(2)
        out[op] += _shape_bytes(shape_str)
        counts[op] += 1
    return {
        "bytes": out,
        "counts": counts,
        "total_bytes": sum(out.values()),
    }


def cost_summary(ca: Optional[dict]) -> Dict:
    if isinstance(ca, (list, tuple)):  # older jax: one dict per computation
        ca = ca[0] if ca else None
    if not ca:
        return {}
    out = {"flops": float(ca.get("flops", 0.0)),
           "transcendentals": float(ca.get("transcendentals", 0.0)),
           "bytes_accessed": float(ca.get("bytes accessed", 0.0))}
    return out


def roofline_terms(flops: float, bytes_accessed: float, coll_bytes: float,
                   chips: int, hw: HW = TPU_V5E) -> Dict:
    compute = flops / (chips * hw.peak_flops)
    memory = bytes_accessed / (chips * hw.hbm_bw)
    collective = coll_bytes / (chips * hw.ici_bw)
    terms = {"compute_s": compute, "memory_s": memory,
             "collective_s": collective}
    dom = max(terms, key=terms.get)
    terms["dominant"] = dom.replace("_s", "")
    total = max(compute, memory, collective)
    terms["bound_s"] = total
    return terms


_PAYLOAD_BYTES = {"int4": 0.5, "int8": 1.0}


def encode_bytes(n: int, wire: str = "int4",
                 pipeline: str = "fused") -> Dict:
    """Bytes moved through HBM by one QSGD tensor encode of ``n`` f32
    coordinates, attributed per pass — the roofline model the kernel CI
    gates on (``benchmarks/kernel_bench.py``).

    ``pipeline="multipass"`` is the staged reference pipeline (what the
    codec ran before the fused kernel): a sumsq pass (read y), a quantize
    pass (read y + noise, materialize f32 levels — the reference
    backend's contract), and a pack pass (re-read the levels, write the
    wire container).  ``pipeline="fused"`` is the one-pass kernel
    (``repro.kernels.qsgd.fused_encode_call``): a norm grid phase (read
    y) and a quantize+pack phase (read y + noise, write the container
    straight from VMEM) — the f32 level round-trip disappears.

    In the memory-bound regime time ~ bytes / HBM_bw, so the model
    throughput ratio multipass/fused (~1.6x for both int wires) is the
    speedup floor the bench asserts.
    """
    if wire not in _PAYLOAD_BYTES:
        raise ValueError(f"encode_bytes models the packed level wires "
                         f"{sorted(_PAYLOAD_BYTES)}, got {wire!r}")
    out_b = _PAYLOAD_BYTES[wire] * n
    if pipeline == "multipass":
        passes = {"sumsq": {"read": 4.0 * n, "write": 0.0},
                  "quantize": {"read": 8.0 * n, "write": 4.0 * n},
                  "pack": {"read": 4.0 * n, "write": out_b}}
    elif pipeline == "fused":
        passes = {"norm_phase": {"read": 4.0 * n, "write": 0.0},
                  "quantize_pack_phase": {"read": 8.0 * n, "write": out_b}}
    else:
        raise ValueError(f"unknown pipeline {pipeline!r}")
    total = sum(p["read"] + p["write"] for p in passes.values())
    return {"passes": passes, "total_bytes": total}


def achieved_bandwidth(nbytes: float, seconds: float) -> float:
    """bytes/s actually sustained moving ``nbytes`` in ``seconds``."""
    return nbytes / max(seconds, 1e-12)


def host_peak_bandwidth(mib: int = 256, reps: int = 5) -> float:
    """Measured peak memory bandwidth of *this* host (bytes/s): the best
    of ``reps`` large numpy copies — the denominator for achieved-vs-peak
    on CPU runs, where ``HW.hbm_bw`` describes a TPU we are not on."""
    import time

    import numpy as np
    src = np.ones(mib * (1 << 20) // 8, np.float64)
    dst = np.empty_like(src)
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        np.copyto(dst, src)
        best = min(best, time.perf_counter() - t0)
    return 2.0 * src.nbytes / best  # read + write


def extrapolate(cost1: Dict, cost2: Dict, reps: float) -> Dict:
    """total = cost1 + (reps - 1) * (cost2 - cost1), clamped at >= cost1."""
    out = {}
    for k in set(cost1) | set(cost2):
        c1 = float(cost1.get(k, 0.0))
        c2 = float(cost2.get(k, 0.0))
        per = max(c2 - c1, 0.0)
        out[k] = c1 + (reps - 1.0) * per
    return out
