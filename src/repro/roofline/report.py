"""Render EXPERIMENTS.md §Dry-run and §Roofline tables from results JSON."""
from __future__ import annotations

import json
import os

__all__ = ["dryrun_table", "roofline_table"]


def _gib(b):
    return f"{b / 2**30:.2f}"


def dryrun_table(path="results/dryrun/summary.json") -> str:
    rs = json.load(open(path))
    lines = ["| arch | shape | mesh | fl | lower s | compile s | args GiB/dev"
             " | temp GiB/dev | HLO GFLOP/dev | coll MiB/dev | status |",
             "|---|---|---|---|---|---|---|---|---|---|---|"]
    for r in rs:
        if r["status"] != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                         f"| | | | | | | {r['status']}: "
                         f"{r.get('reason', r.get('error', ''))[:70]} |")
            continue
        m = r["memory"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['fl_axis']} | "
            f"{r['lower_s']} | {r['compile_s']} | "
            f"{_gib(m['argument_bytes'])} | {_gib(m['temp_bytes'])} | "
            f"{r['cost'].get('flops', 0)/1e9:.1f} | "
            f"{r['collectives']['total_bytes']/2**20:.0f} | ok |")
    return "\n".join(lines)


def roofline_table(path="results/roofline/summary.json") -> str:
    rs = json.load(open(path))
    lines = ["| arch | shape | chips | compute s | memory s | collective s |"
             " dominant | MODEL_FLOPS | HLO FLOPs (global) | useful ratio |"
             " next move |",
             "|---|---|---|---|---|---|---|---|---|---|---|"]
    for r in rs:
        if r["status"] != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | | | | | skipped |"
                         f" | | | {r.get('reason','')[:60]} |")
            continue
        t = r["terms"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['chips']} | "
            f"{t['compute_s']:.3g} | {t['memory_s']:.3g} | "
            f"{t['collective_s']:.3g} | **{t['dominant']}** | "
            f"{r['model_flops']:.3g} | {r['hlo_flops_global']:.3g} | "
            f"{r['useful_flops_ratio']:.2f} | {r['hint'][:58]} |")
    return "\n".join(lines)


if __name__ == "__main__":
    print("## Dry-run\n")
    print(dryrun_table())
    print("\n## Roofline\n")
    print(roofline_table())
