"""Registries behind the Scenario facade (mirroring the codec registry of
:mod:`repro.compress`): step-size rules keyed by the objective letter, and
algorithm families keyed by name.

A *family* is one of the paper's algorithm parameterizations — GenQSGD with
every variable free, or a baseline obtained by pinning/tying variables
through a :class:`~repro.opt.problems.VarMap` (Sec. VII):

  genqsgd  — K0, K_1..K_N, B all free (Problems 3/5/7/11)
  pm       — PM-SGD: K_n ≡ 1
  fa       — FedAvg: K_n = l * I_n / B (l a shared relaxed-integer variable)
  pr       — PR-SGD: B ≡ 1

New families (e.g. GQFedWAvg's weighted-aggregation variants) register a
varmap factory here and immediately work with ``Scenario.optimize`` and the
whole benchmark suite.
"""
from __future__ import annotations

from typing import Callable, Dict, Optional

from ..core.step_rules import (ConstantRule, DiminishingRule, ExponentialRule,
                               StepRule)
from ..opt.problems import (Objective, VarMap, fa_varmap, identity_varmap,
                            pm_varmap, pr_varmap)

__all__ = [
    "STEP_RULES", "FAMILIES", "register_step_rule", "register_family",
    "make_step_rule", "make_varmap", "family_names",
]

# ---------------------------------------------------------------------------
# step-size rules: objective letter -> rule constructor
# ---------------------------------------------------------------------------
STEP_RULES: Dict[str, Callable[..., StepRule]] = {}


def register_step_rule(name: str, factory: Callable[..., StepRule]) -> None:
    STEP_RULES[str(name)] = factory


register_step_rule("C", ConstantRule)
register_step_rule("E", ExponentialRule)
register_step_rule("D", DiminishingRule)


def make_step_rule(objective, gamma: float,
                   rho: Optional[float] = None) -> StepRule:
    """Construct the step rule matching an objective (J uses the constant
    rule — Lemma 4 shows the jointly-optimal step size is constant)."""
    m = Objective.coerce(objective, _warn=False)
    name = "C" if m is Objective.JOINT else m.value
    factory = STEP_RULES[name]
    if name == "C":
        return factory(gamma)
    return factory(gamma, rho)


# ---------------------------------------------------------------------------
# algorithm families: name -> varmap factory
# ---------------------------------------------------------------------------
# factory(N, with_extra, samples_per_worker) -> VarMap
FamilyFactory = Callable[[int, bool, float], VarMap]

FAMILIES: Dict[str, FamilyFactory] = {}


def register_family(name: str, factory: FamilyFactory) -> None:
    FAMILIES[str(name)] = factory


register_family("genqsgd",
                lambda N, we, spw: identity_varmap(N, with_extra=we))
register_family("pm", lambda N, we, spw: pm_varmap(N, with_extra=we))
register_family("fa",
                lambda N, we, spw: fa_varmap(N, [float(spw)] * N,
                                             with_extra=we))
register_family("pr", lambda N, we, spw: pr_varmap(N, with_extra=we))


def family_names() -> tuple:
    return tuple(FAMILIES)


def make_varmap(family: str, N: int, with_extra: bool,
                samples_per_worker: float) -> VarMap:
    try:
        factory = FAMILIES[family]
    except KeyError:
        raise ValueError(f"unknown family {family!r}; registered: "
                         f"{sorted(FAMILIES)}") from None
    return factory(N, with_extra, samples_per_worker)
