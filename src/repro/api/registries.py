"""Registries behind the Scenario facade: step-size rules keyed by the
objective letter, and a back-compat view of the algorithm-family registry.

Algorithm families now live in :mod:`repro.families` — an
:class:`~repro.families.AlgorithmFamily` owns the varmap *and* the
convergence/runtime/codec hooks the pipeline used to hardcode for GenQSGD:

  genqsgd    — K0, K_1..K_N, B all free (Problems 3/5/7/11)
  pm         — PM-SGD: K_n ≡ 1
  fa         — FedAvg: K_n = l * I_n / B (l a shared relaxed-integer var)
  pr         — PR-SGD: B ≡ 1
  gqfedwavg  — GQFedWAvg: weighted aggregation, normalized momentum local
               updates, rotation-preconditioned quantization

This module keeps the historical surface working: ``FAMILIES`` is a mapping
view whose values are varmap factories (reading goes straight to the new
registry; *mutating* it directly is deprecated and warns), and
``register_family`` accepts either a legacy varmap factory — wrapped into a
:class:`~repro.families.GenQSGDFamily` — or a full ``AlgorithmFamily``.
"""
from __future__ import annotations

import warnings
from collections.abc import MutableMapping
from typing import Callable, Dict, Optional

from ..core.step_rules import (ConstantRule, DiminishingRule, ExponentialRule,
                               StepRule)
from ..families import AlgorithmFamily, GenQSGDFamily, get_family
from ..families import family_names as _family_names
from ..families import register as _register
from ..families import registry as _fam_registry
from ..opt.problems import Objective, VarMap

__all__ = [
    "STEP_RULES", "FAMILIES", "register_step_rule", "register_family",
    "make_step_rule", "make_varmap", "family_names",
]

# ---------------------------------------------------------------------------
# step-size rules: objective letter -> rule constructor
# ---------------------------------------------------------------------------
STEP_RULES: Dict[str, Callable[..., StepRule]] = {}


def register_step_rule(name: str, factory: Callable[..., StepRule]) -> None:
    STEP_RULES[str(name)] = factory


register_step_rule("C", ConstantRule)
register_step_rule("E", ExponentialRule)
register_step_rule("D", DiminishingRule)


def make_step_rule(objective, gamma: float,
                   rho: Optional[float] = None) -> StepRule:
    """Construct the step rule matching an objective (J uses the constant
    rule — Lemma 4 shows the jointly-optimal step size is constant)."""
    m = Objective.coerce(objective, _warn=False)
    name = "C" if m is Objective.JOINT else m.value
    factory = STEP_RULES[name]
    if name == "C":
        return factory(gamma)
    return factory(gamma, rho)


# ---------------------------------------------------------------------------
# algorithm families: back-compat view over repro.families
# ---------------------------------------------------------------------------
# legacy factory signature: factory(N, with_extra, samples_per_worker) -> VarMap
FamilyFactory = Callable[[int, bool, float], VarMap]


def register_family(name: str, factory) -> None:
    """Register an algorithm family under ``name``.

    ``factory`` may be a full :class:`~repro.families.AlgorithmFamily`
    (registered as-is under its own hooks) or a legacy varmap factory
    ``(N, with_extra, samples_per_worker) -> VarMap`` (wrapped into a
    :class:`~repro.families.GenQSGDFamily`, i.e. GenQSGD semantics for
    aggregation / local updates / codec).
    """
    if isinstance(factory, AlgorithmFamily):
        if factory.key != name:
            import dataclasses
            factory = dataclasses.replace(factory, key=str(name))
        _register(factory, overwrite=True)
        return
    _register(GenQSGDFamily(key=str(name), varmap_factory=factory),
              overwrite=True)


class _FamiliesShim(MutableMapping):
    """``FAMILIES`` of old: a name -> varmap-factory mapping.

    Reads delegate to :mod:`repro.families`; direct mutation still works
    but is deprecated — it can only describe a GenQSGD-semantics family, so
    new code should ``repro.families.register`` an ``AlgorithmFamily``
    (or call :func:`register_family`).
    """

    def __getitem__(self, name) -> FamilyFactory:
        try:
            fam = get_family(name)
        except ValueError:
            raise KeyError(name) from None
        return fam.make_varmap

    def __setitem__(self, name, factory) -> None:
        warnings.warn(
            "mutating repro.api.FAMILIES directly is deprecated; use "
            "repro.families.register(AlgorithmFamily(...)) or "
            "repro.api.register_family(name, factory)",
            DeprecationWarning, stacklevel=2)
        register_family(name, factory)

    def __delitem__(self, name) -> None:
        warnings.warn(
            "mutating repro.api.FAMILIES directly is deprecated",
            DeprecationWarning, stacklevel=2)
        del _fam_registry._REGISTRY[name]

    def __iter__(self):
        return iter(_family_names())

    def __len__(self) -> int:
        return len(_family_names())


FAMILIES = _FamiliesShim()


def family_names() -> tuple:
    return _family_names()


def make_varmap(family: str, N: int, with_extra: bool,
                samples_per_worker: float) -> VarMap:
    """The family's decision-variable structure; unknown names raise with a
    nearest-match suggestion pointing at the :mod:`repro.families` registry.
    """
    return get_family(family).make_varmap(N, with_extra, samples_per_worker)
