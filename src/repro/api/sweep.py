"""Scenario sweeps and Pareto fronts: the batched front door of repro.api.

The paper's headline results are sweeps — energy vs. ``C_max``/``T_max``
trade-off surfaces (Fig. 5), baseline tables across step-size rules — and
follow-up work (GQFedWAvg, Cost-Effective Federated Learning) frames the
same design space as budget sweeps and Pareto exploration.  This module
makes that a first-class operation:

    report = scenario.sweep(over={"C_max": [0.2, 0.25, 0.3],
                                  "rule": [ConstantRule(0.01), None]})
    front  = report.pareto_front()          # non-dominated (E, T, C) points
    report.to_csv("results/sweep.csv")

Scenarios are grouped by optimizer structure signature ``(m, family, N)``;
each group solves through one batched GIA call path
(:func:`repro.opt.solve_param_opt_batched` — by default the *fused*
device-resident loop of :mod:`repro.opt.gia_jax`, one compiled program and
one device call per group, surrogate refresh included), and independent
groups can solve concurrently (the GIL is released inside compiled solves).
"""
from __future__ import annotations

import dataclasses
import itertools
import os
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Dict, Iterable, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from ..opt.gia import solve_param_opt_batched
from ..opt.structure import structure_signature
from .plan import Plan

__all__ = ["SweepReport", "sweep_scenarios", "expand_grid"]

#: user-facing spellings of Scenario fields accepted in ``sweep(over=...)``
_ALIASES = {"rule": "step", "cmax": "C_max", "tmax": "T_max"}


def expand_grid(base, over: Mapping[str, Iterable]):
    """Cartesian-expand ``over`` into Scenario variants of ``base``.

    Keys are Scenario field names (``"rule"``/``"cmax"``/``"tmax"`` aliases
    accepted); values are iterables of field values (``step`` values are
    StepRule instances or None for the jointly-optimized objective).

    The special axis ``"N"`` sweeps the worker count: the edge system is
    ceil-tiled to N workers via :meth:`~repro.core.cost.EdgeSystem.resized`
    and the ML-problem constants follow — combined with a free-``S``
    ``sampling`` model this sweeps the energy-vs-N participation frontier
    in one batched call.
    """
    fields = {f.name for f in dataclasses.fields(base)}
    keys, grids = [], []
    for k, vals in over.items():
        canon = _ALIASES.get(k, k)
        if canon != "N" and canon not in fields:
            raise ValueError(
                f"cannot sweep over {k!r}; Scenario fields are "
                f"{sorted(fields)} + ['N'] (aliases: {sorted(_ALIASES)})")
        if canon in keys:
            raise ValueError(f"duplicate sweep axis {canon!r}")
        keys.append(canon)
        grids.append(list(vals))
    scenarios = []
    for combo in itertools.product(*grids):
        kv = dict(zip(keys, combo))
        n_new = kv.pop("N", None)
        s = base
        if n_new is not None:
            s = dataclasses.replace(
                s, system=s.system.resized(int(n_new)),
                consts=dataclasses.replace(s.consts, N=int(n_new)))
        scenarios.append(dataclasses.replace(s, **kv) if kv else s)
    return scenarios


@dataclasses.dataclass(frozen=True)
class SweepReport:
    """Tidy result of one sweep: one row + one :class:`Plan` per scenario.

    Rows are plain dicts (name, family, m, gamma, T_max, C_max, K0, Kn, B,
    E, T, C, feasible, converged, iterations) in sweep order — ready for a
    dataframe, a CSV, or the Pareto filter.
    """

    rows: Tuple[dict, ...]
    plans: Tuple[Plan, ...]
    backend: str
    n_groups: int
    wall_time_s: float

    def __len__(self) -> int:
        return len(self.rows)

    def __iter__(self):
        return iter(self.rows)

    # ------------------------------------------------------------------
    def pareto_front(self, objectives: Sequence[str] = ("E", "T", "C"),
                     feasible_only: bool = True) -> "SweepReport":
        """The non-dominated subset, minimizing every objective column.

        A point is dominated when another point is no worse in every
        objective and strictly better in at least one; ties survive.
        """
        idx = [i for i, r in enumerate(self.rows)
               if r["feasible"] or not feasible_only]
        if not idx:
            return dataclasses.replace(self, rows=(), plans=())
        P = np.array([[float(self.rows[i][k]) for k in objectives]
                      for i in idx])
        le = np.all(P[:, None, :] <= P[None, :, :], axis=-1)
        lt = np.any(P[:, None, :] < P[None, :, :], axis=-1)
        dominated = np.any(le & lt, axis=0)          # [j] : exists i beating j
        keep = [i for i, d in zip(idx, dominated) if not d]
        return dataclasses.replace(
            self, rows=tuple(self.rows[i] for i in keep),
            plans=tuple(self.plans[i] for i in keep))

    def best(self, key: str = "E", feasible_only: bool = True):
        """(row, plan) minimizing ``key`` (among feasible rows by default)."""
        idx = [i for i, r in enumerate(self.rows)
               if r["feasible"] or not feasible_only]
        if not idx:
            raise ValueError("no feasible rows in sweep")
        i = min(idx, key=lambda i: self.rows[i][key])
        return self.rows[i], self.plans[i]

    def to_csv(self, path: str, columns: Optional[Sequence[str]] = None):
        """Write the tidy rows; tuple cells (Kn) are |-joined."""
        cols = list(columns) if columns else list(self.rows[0]) if self.rows \
            else []
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(path, "w") as f:
            f.write(",".join(cols) + "\n")
            for r in self.rows:
                f.write(",".join(
                    "|".join(str(x) for x in v) if isinstance(v, tuple)
                    else str(v) for v in (r.get(c, "") for c in cols)) + "\n")
        return path


def _resolve_backend(backend: str) -> str:
    if backend != "auto":
        return backend
    try:
        import jax  # noqa: F401
        return "jnp-fused"
    except Exception:
        return "numpy"


def sweep_scenarios(scenarios: Sequence, names: Optional[Sequence[str]] = None,
                    backend: str = "auto", tol: float = 1e-4,
                    max_iter: int = 60, parallel: bool = True) -> SweepReport:
    """Optimize many scenarios through the batched solver engine.

    Scenarios are grouped by structure signature; each group is one
    :func:`~repro.opt.gia.solve_param_opt_batched` call — with the default
    ``backend="jnp-fused"`` the group's whole GIA (surrogate refresh +
    interior point + convergence masks) is one jitted device program,
    compiled once per signature, so a 1024-point single-signature grid is
    one compile + one device call (``backend="jnp"`` keeps the per-iteration
    jitted GP solves with a host-side refresh; ``"numpy"`` is the scalar
    reference) — and groups run concurrently on a small thread pool when
    ``parallel``.  Heterogeneous scenario lists (mixed families / step
    rules / systems) are fine — that's what the grouping is for.
    """
    scenarios = list(scenarios)
    if names is not None:
        names = list(names)
        if len(names) != len(scenarios):
            raise ValueError(f"{len(names)} names for {len(scenarios)} "
                             f"scenarios")
    t_start = time.time()
    resolved = _resolve_backend(backend)
    ms = [s.objective for s in scenarios]
    probs = [s.problem() for s in scenarios]
    groups: Dict[tuple, List[int]] = {}
    for i, p in enumerate(probs):
        groups.setdefault(structure_signature(p), []).append(i)

    def solve_group(idxs: List[int]):
        return solve_param_opt_batched([probs[i] for i in idxs], tol=tol,
                                       max_iter=max_iter, backend=resolved)

    results = [None] * len(scenarios)
    group_lists = list(groups.values())
    if parallel and len(group_lists) > 1:
        workers = min(len(group_lists), os.cpu_count() or 1)
        with ThreadPoolExecutor(max_workers=workers) as pool:
            for idxs, rs in zip(group_lists,
                                pool.map(solve_group, group_lists)):
                for i, r in zip(idxs, rs):
                    results[i] = r
    else:
        for idxs in group_lists:
            for i, r in zip(idxs, solve_group(idxs)):
                results[i] = r

    rows, plans = [], []
    for i, (scn, m, r) in enumerate(zip(scenarios, ms, results)):
        plan = scn._plan_from_result(m, r)
        name = (names[i] if names is not None
                else f"{scn.family_key}-{m.value}")
        rows.append({
            "name": name, "family": scn.family_key, "m": m.value,
            "gamma": plan.gamma, "T_max": scn.T_max, "C_max": scn.C_max,
            "K0": plan.K0, "Kn": plan.Kn, "B": plan.B,
            "N": plan.N, "S": plan.cohort_S, "sampling": plan.sampling,
            "E": plan.predicted_E, "T": plan.predicted_T,
            "C": plan.predicted_C, "feasible": plan.feasible,
            "converged": plan.converged, "iterations": r.iterations,
        })
        plans.append(plan)
    return SweepReport(rows=tuple(rows), plans=tuple(plans), backend=resolved,
                       n_groups=len(groups), wall_time_s=time.time() - t_start)
