"""Scenario: the declarative front door of the optimization framework.

One object bundles everything the paper's closed loop needs — the edge
system (cost model), the ML-problem constants, the budgets ``(T_max,
C_max)``, the step-size rule, and the algorithm family — and exposes the
loop as two calls:

    plan   = scenario.optimize()          # GIA/CGP -> frozen Plan
    report = scenario.run(plan, task)     # train -> RunReport vs predictions

plus the batched third call: ``scenario.sweep(over={...})`` expands a
budget / rule grid, solves it through the batched GP engine (one jitted
jnp call path per structure group), and returns a
:class:`~repro.api.sweep.SweepReport` with tidy rows and Pareto-front
extraction.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Optional, Union

import numpy as np

from .. import obs as _obs
from ..core.cost import EdgeSystem, energy_cost, time_cost
from ..core.convergence import MLProblemConstants
from ..core.genqsgd import GenQSGD
from ..core.step_rules import (ConstantRule, DiminishingRule, ExponentialRule,
                               StepRule)
from ..families import AlgorithmFamily, resolve
from ..opt.gia import solve_param_opt, solve_param_opt_batched
from ..opt.problems import Objective, ParamOptProblem, VarMap
from .plan import Plan, RunReport
from .tasks import MNISTTask

__all__ = ["Scenario"]

_RULE_FOR = {Objective.CONSTANT: ConstantRule,
             Objective.EXPONENTIAL: ExponentialRule,
             Objective.DIMINISHING: DiminishingRule}


@dataclasses.dataclass(frozen=True)
class Scenario:
    """A federated-edge-learning scenario: system + problem + budgets +
    algorithm.  Frozen; derive variants with ``dataclasses.replace``."""

    system: EdgeSystem
    consts: MLProblemConstants
    T_max: float                          # time budget (s), constraint (20)
    C_max: float                          # convergence-error budget, (21)
    family: Union[str, AlgorithmFamily] = "genqsgd"  # repro.families key
    step: Optional[StepRule] = None       # None -> jointly optimized (m=J)
    samples_per_worker: float = 6000.0    # I_n (FedAvg's epoch tie)
    sampling: object = "full"             # repro.sampling key or model
    faults: object = "none"               # repro.faults key or model

    def __post_init__(self):
        resolve(self.family)              # unknown names fail here, loudly
        self.sampling_obj.validate(self.system.N)
        self.faults_obj.validate(self.system.N)
        if self.consts.N != self.system.N:
            raise ValueError(
                f"consts describe N={self.consts.N} workers but the system "
                f"has N={self.system.N}")

    # ------------------------------------------------------------------
    @property
    def family_obj(self) -> AlgorithmFamily:
        """The resolved :class:`~repro.families.AlgorithmFamily`."""
        return resolve(self.family)

    @property
    def sampling_obj(self):
        """The resolved :class:`~repro.sampling.SamplingModel`."""
        from ..sampling import resolve as resolve_sampling
        return resolve_sampling(self.sampling)

    @property
    def faults_obj(self):
        """The resolved :class:`~repro.faults.FaultModel`."""
        from ..faults import resolve as resolve_faults
        return resolve_faults(self.faults)

    @property
    def family_key(self) -> str:
        return self.family_obj.key

    @functools.cached_property
    def _priced_system(self) -> EdgeSystem:
        """The system whose M_s / q_s price the *family's* codec — the one
        guarantee of the closed loop: the optimizer and the runtime move
        the same bytes through the same quantizer.  A rotated family on a
        bucketed system drops ``q_dim``: rotation isotropizes the whole
        message, so per-bucket norms are redundant (and the codec rejects
        the combination).  A non-neutral fault model additionally stamps
        its availability / worst-case margins, so the GP plans for the
        fleet the runtime will actually face — neutral fault models leave
        the system object untouched (bitwise)."""
        fam = self.family_obj
        sys = self.system
        fm = self.faults_obj
        if not fm.is_neutral(sys.N):
            an = fm.availability(sys.N) if sys.an is None else sys.an
            fmg = max(float(sys.freq_margin), float(fm.freq_margin))
            rmg = max(float(sys.rate_margin), float(fm.rate_margin))
            if an is not None or fmg != sys.freq_margin \
                    or rmg != sys.rate_margin:
                sys = dataclasses.replace(sys, an=an, freq_margin=fmg,
                                          rate_margin=rmg)
        if fam.codec_kind == sys.codec_kind:
            return sys
        q_dim = None if fam.codec_kind == "rotated" else sys.q_dim
        return dataclasses.replace(sys, codec_kind=fam.codec_kind,
                                   q_dim=q_dim)

    # ------------------------------------------------------------------
    @property
    def objective(self) -> Objective:
        """The convergence-error measure m implied by the step rule."""
        if self.step is None:
            return Objective.JOINT
        return Objective.coerce(self.step.name, _warn=False)

    def _resolve(self, m) -> Objective:
        m = self.objective if m is None else Objective.coerce(m, _warn=False)
        if m is Objective.JOINT:
            if self.step is not None:
                raise ValueError(
                    f"m=J jointly optimizes the step size; this Scenario "
                    f"pins step={self.step!r} — drop it or pick its m")
        else:
            want = _RULE_FOR[m]
            if not isinstance(self.step, want):
                raise ValueError(
                    f"objective {m.name} needs step={want.__name__}, "
                    f"got {type(self.step).__name__ if self.step else None}")
        return m

    def problem(self, m=None, vmap: Optional[VarMap] = None) -> ParamOptProblem:
        """The underlying :class:`ParamOptProblem` (escape hatch for direct
        ``evaluate``/``feasible`` queries and fixed-parameter baselines)."""
        m = self._resolve(m)
        fam = self.family_obj
        if vmap is None:
            vmap = fam.make_varmap(
                self.system.N,
                m in (Objective.EXPONENTIAL, Objective.JOINT),
                self.samples_per_worker)
        gamma = None if self.step is None else float(self.step.gamma)
        rho = getattr(self.step, "rho", None)
        return ParamOptProblem(sys=self._priced_system, consts=self.consts,
                               T_max=self.T_max, C_max=self.C_max, m=m,
                               gamma=gamma, rho=rho, vmap=vmap, family=fam,
                               sampling=self.sampling_obj,
                               faults=self.faults_obj)

    # ------------------------------------------------------------------
    def _plan_from_result(self, m: Objective, r) -> Plan:
        """Freeze a :class:`~repro.opt.gia.GIAResult` into a Plan."""
        if m is Objective.JOINT:
            step = ConstantRule(float(r.gamma))
        else:
            step = self.step
        sys = self._priced_system
        fam = self.family_obj
        samp = self.sampling_obj
        if samp.free_S:                   # integer-recovered cohort size
            cohort_S = None if r.S is None else int(r.S)
        else:
            cohort_S = samp.pinned_S(sys.N)   # None for full / neutral
        sampling_p = samp.plan_p(sys.N) if cohort_S is not None else None
        fault_spec = self._fault_spec(tuple(int(k) for k in r.Kn), int(r.B))
        return Plan(K0=int(r.K0), Kn=tuple(int(k) for k in r.Kn), B=int(r.B),
                    step_rule=step, s0=sys.s0, sn=tuple(sys.sn), dim=sys.dim,
                    q_dim=sys.q_dim, wire=sys.wire, objective=m,
                    family=fam.key, codec_kind=fam.codec_kind,
                    agg_weights=fam.agg_weights(sys.N),
                    momentum=fam.momentum, normalize=fam.normalize,
                    sampling=samp.key if cohort_S is not None else "full",
                    cohort_S=cohort_S, sampling_p=sampling_p,
                    faults=fault_spec,
                    predicted_E=r.E, predicted_T=r.T,
                    predicted_C=r.C, feasible=bool(r.feasible),
                    converged=bool(r.converged))

    def _fault_spec(self, Kn, B):
        """The frozen per-plan fault contract (None when the fault model
        has no runtime behavior): nominal per-worker round times from the
        cost model, deadline ``tau = slack x predicted round time``, and
        the exact delivery probabilities the HT reweighting divides by."""
        fm = self.faults_obj
        sys = self._priced_system
        if not fm.runtime_active(sys.N):
            return None
        from ..faults import FaultSpec
        Kn = np.asarray(Kn, np.float64)
        # worker n's nominal time in one round: compute + its own upload
        wt = B * sys.comp_time_coeff * Kn + sys.M_sn / sys.rn
        # the Plan's predicted round time (eq. 17's per-round bracket)
        round_t = B * float(np.max(sys.comp_time_coeff * Kn)) + sys.comm_time
        deadline = float(fm.deadline_slack) * round_t
        dp = fm.deliver_prob(wt, deadline)
        return FaultSpec(model=fm, worker_times=tuple(float(t) for t in wt),
                         deadline=deadline,
                         deliver_p=tuple(float(p) for p in dp))

    def optimize(self, m=None, z0=None, tol: float = 1e-4,
                 max_iter: int = 60, verbose: bool = False,
                 backend: str = "numpy", server=None) -> Plan:
        """Solve the scenario's parameter-optimization problem (Algorithms
        2-5) and freeze the solution into a :class:`Plan`.

        ``backend`` picks the solver engine: ``"numpy"`` (the scalar
        reference loop) or ``"jnp"``/``"jnp-fused"`` — the fused engine
        compiles once per structure signature into a process-level cache,
        so repeated ``optimize()`` calls across distinct Scenario objects
        reuse the executable.  ``z0`` warm-starts the GIA (e.g. from a
        previously solved neighbor's ``Plan``).  Passing ``server`` (a
        :class:`~repro.serve.PlanServer`) routes the request through its
        micro-batching queue and warm-start cache instead — the server's
        own ``tol``/``max_iter`` govern, and concurrent same-signature
        requests share one fused device call.
        """
        if server is not None:
            return server.solve(self, m=m)
        m = self._resolve(m)
        with _obs.trace.span("scenario.optimize", m=str(m.value),
                             family=str(self.family), backend=backend):
            prob = self.problem(m)
            if backend == "numpy":
                r = solve_param_opt(prob, z0=z0, tol=tol, max_iter=max_iter,
                                    verbose=verbose)
            else:
                r = solve_param_opt_batched(
                    [prob], z0s=None if z0 is None else [z0], tol=tol,
                    max_iter=max_iter, backend=backend, verbose=verbose)[0]
            return self._plan_from_result(m, r)

    def sweep(self, over, names=None, backend: str = "auto",
              tol: float = 1e-4, max_iter: int = 60, parallel: bool = True):
        """Expand ``over`` (field name -> iterable of values; ``rule`` /
        ``cmax`` / ``tmax`` aliases accepted) into Scenario variants, solve
        them all through the batched engine, and return a
        :class:`~repro.api.sweep.SweepReport` (tidy rows, ``pareto_front()``,
        ``to_csv``)."""
        from .sweep import expand_grid, sweep_scenarios
        scenarios = expand_grid(self, over)
        return sweep_scenarios(scenarios, names=names, backend=backend,
                               tol=tol, max_iter=max_iter, parallel=parallel)

    # ------------------------------------------------------------------
    def run(self, plan: Plan, task=None, backend: str = "reference",
            seed: int = 0, max_rounds: Optional[int] = None,
            eval_every: int = 0, wire: str = "f32",
            log_every: int = 0) -> RunReport:
        """Execute training with exactly the Plan's parameters.

        backend="reference" runs Algorithm 1 single-process on a reference
        task (default: the Sec.-VII MNIST-like task); backend="spmd" runs
        the distributed runtime on an :class:`~repro.api.tasks.SpmdTask`,
        moving the Plan's quantized levels over the ``wire`` transport.
        """
        with _obs.trace.span("scenario.run", backend=backend,
                             family=plan.family, rounds=plan.K0):
            if backend == "reference":
                report = self._run_reference(plan, task, seed, max_rounds,
                                             eval_every)
            elif backend == "spmd":
                report = self._run_spmd(plan, task, seed, max_rounds, wire,
                                        log_every)
            else:
                raise ValueError(f"unknown backend {backend!r}; "
                                 f"expected 'reference' or 'spmd'")
        if _obs.enabled():
            # the drift ledger artifact: a pure function of the report (the
            # report itself is bit-identical with obs off; only this file
            # write is added)
            report.drift().to_jsonl(_obs.artifact_path(
                f"ledger_{plan.family}_{backend}_seed{seed}.jsonl"))
        return report

    def _report(self, plan: Plan, backend: str, rounds: int, model_dim: int,
                wall: float, final_metrics: dict, history,
                wire: Optional[str] = None, cohort_trace=None,
                fault_trace=None) -> RunReport:
        # wire=None prices at the Plan's wire (the reference backend has no
        # transport); the spmd path passes the transport it actually used.
        # Cost-model measurements evaluate on the *priced* system — the one
        # whose M_s/q_s describe the family's codec — so measured_E/T are
        # comparable to predicted_E/T within the same report.
        if cohort_trace:
            # sampled run: realized per-round cohort uploads, summed; the
            # modeled energy is the expected energy at the Plan's pi_n —
            # the same energy_cost(pi=...) the optimizer minimized.
            trace = tuple(plan.cohort_round_bits(idx, dim=model_dim,
                                                 wire=wire)
                          for idx in cohort_trace)
            comm = float(sum(trace))
        else:
            trace = ()
            comm = rounds * plan.round_bits(dim=model_dim, wire=wire)
        pi = None
        if plan.cohort_S is not None:
            pi = (np.full(plan.N, float(plan.cohort_S) / plan.N)
                  if plan.sampling_p is None
                  else float(plan.cohort_S) * np.asarray(plan.sampling_p))
        sys = self._priced_system
        return RunReport(
            plan=plan, backend=backend, rounds=rounds, model_dim=model_dim,
            wall_time_s=wall, comm_bits=comm,
            measured_E=energy_cost(sys, rounds, np.asarray(plan.Kn),
                                   plan.B, pi=pi),
            measured_T=time_cost(sys, rounds, np.asarray(plan.Kn),
                                 plan.B),
            final_metrics=dict(final_metrics), history=tuple(history),
            round_bits_trace=trace, fault_trace=fault_trace)

    def _run_reference(self, plan, task, seed, max_rounds, eval_every):
        import jax

        task = MNISTTask() if task is None else task
        cfg = plan.to_genqsgd_config(max_K0=max_rounds, seed=seed)
        alg = GenQSGD(task.loss, task.sample, cfg)
        data = task.make_data(plan.N)
        p0 = task.init_params(jax.random.PRNGKey(seed))
        model_dim = sum(int(np.prod(l.shape)) if l.shape else 1
                        for l in jax.tree.leaves(p0))
        eval_fn = task.metrics if eval_every else None
        t0 = time.time()
        pf, hist = alg.run(p0, data, jax.random.PRNGKey(seed + 1),
                           eval_fn=eval_fn,
                           eval_every=eval_every or max(1, cfg.K0))
        wall = time.time() - t0
        final = task.metrics(pf) if hasattr(task, "metrics") else {}
        return self._report(plan, "reference", cfg.K0, model_dim, wall,
                            final, hist,
                            cohort_trace=getattr(alg, "cohort_trace", None),
                            fault_trace=getattr(alg, "fault_trace", None))

    def _run_spmd(self, plan, task, seed, max_rounds, wire, log_every):
        import jax

        from ..train.trainer import GenQSGDTrainer

        if task is None:
            raise ValueError("backend='spmd' needs an SpmdTask (model api, "
                             "arch config, mesh, batches)")
        fed = plan.to_fed_config(wire=wire, seed=seed)
        trainer = GenQSGDTrainer(task.api, task.arch, fed, task.mesh,
                                 step_rule=plan.step_rule,
                                 checkpoint_dir=task.checkpoint_dir)
        state = trainer.init(jax.random.PRNGKey(seed))
        model_dim = sum(int(np.prod(l.shape)) if l.shape else 1
                        for l in jax.tree.leaves(state.params))
        rounds = plan.K0 if max_rounds is None else min(plan.K0, max_rounds)
        t0 = time.time()
        state = trainer.run(state, task.batches, jax.random.PRNGKey(seed + 1),
                            n_rounds=rounds,
                            log_every=log_every or max(1, rounds // 10),
                            eval_fn=task.eval_fn)
        wall = time.time() - t0
        final = dict(state.history[-1]) if state.history else {}
        return self._report(plan, "spmd", rounds, model_dim, wall, final,
                            state.history, wire=wire,
                            cohort_trace=getattr(trainer, "cohort_trace",
                                                 None),
                            fault_trace=getattr(trainer, "fault_trace",
                                                None))
