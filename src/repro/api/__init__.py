"""repro.api — the one public entry point: Scenario → Plan → Run.

The paper's contribution is a *closed loop*: optimize the algorithm
parameters ``(K0, Kn, B, Γ)`` against the edge-system cost model
(Sec. V), then run federated learning with exactly those parameters.
This package is that loop as three objects:

  :class:`Scenario`   what you have — an :class:`EdgeSystem` (cost model),
                      :class:`MLProblemConstants`, budgets ``(T_max,
                      C_max)``, a step-size rule, an algorithm family;
  :class:`Plan`       what to run — the frozen optimizer output
                      ``(K0, Kn, B, Γ, s0, sn)`` plus predicted
                      energy/time/error, from which both runtime configs
                      (`to_genqsgd_config`, `to_fed_config`) derive;
  :class:`RunReport`  what happened — measured communication bits (through
                      the same ``codec.wire_bits`` table the optimizer
                      priced), cost-model energy/time at the executed round
                      count, and task metrics, next to the predictions.

    from repro.api import EdgeSystem, MNISTTask, Scenario

    task = MNISTTask()
    scenario = Scenario(system=EdgeSystem.paper_sec_vii(dim=task.dim),
                        consts=task.estimate_constants(N=10),
                        T_max=1e5, C_max=0.25)
    plan = scenario.optimize()            # Algorithms 2-5
    report = scenario.run(plan, task=task)  # Algorithm 1
    print(plan.describe()); print(report.summary())

Algorithm families (``genqsgd`` | ``pm`` | ``fa`` | ``pr`` |
``gqfedwavg``) are full :class:`~repro.families.AlgorithmFamily` objects
(:mod:`repro.families`): a family owns its decision-variable map, its
convergence-block reweighting, its runtime aggregation / local-update
hooks, and its codec preconditioner — so successor algorithm variants plug
in without touching the facade.  Step rules live in the small registry in
:mod:`repro.api.registries`.

Participation models (``full`` | ``uniform`` | ``importance``) plug in the
same way (:mod:`repro.sampling`): ``Scenario(sampling=uniform())`` makes
the per-round cohort size ``S`` a GP decision variable (``uniform(S=k)``
pins it), the frozen Plan carries the cohort decision, and both runtimes
draw seeded cohorts with unbiased Horvitz-Thompson reweighting.

Fault models (``none`` | ``edge``) complete the robustness loop
(:mod:`repro.faults`): ``Scenario(faults=edge_faults(...))`` makes the
optimizer plan for per-worker availability and worst-case capability
margins, the frozen Plan carries the fault contract (deadline, delivery
probabilities), and both runtimes inject seeded faults — stragglers,
multi-round crashes, corrupted payloads — aggregating the survivors of
each round's deadline with unbiased HT reweighting.
"""
from ..core.convergence import MLProblemConstants
from ..core.cost import EdgeSystem
from ..core.step_rules import (ConstantRule, DiminishingRule, ExponentialRule,
                               StepRule, make_rule)
from ..families import AlgorithmFamily, GQFedWAvgFamily, get_family
from ..faults import FaultModel, FaultTrace, edge_faults
from ..opt.problems import Objective
from ..sampling import SamplingModel, importance, uniform
from .plan import Plan, RunReport
from .registries import (FAMILIES, STEP_RULES, family_names, make_step_rule,
                         make_varmap, register_family, register_step_rule)
from .scenario import Scenario
from .sweep import SweepReport, sweep_scenarios
from .tasks import MNISTTask, QuadraticTask, SpmdTask

__all__ = [
    "Scenario", "Plan", "RunReport", "Objective",
    "SweepReport", "sweep_scenarios",
    "EdgeSystem", "MLProblemConstants",
    "ConstantRule", "ExponentialRule", "DiminishingRule", "StepRule",
    "make_rule", "make_step_rule", "make_varmap",
    "STEP_RULES", "FAMILIES", "register_step_rule", "register_family",
    "family_names", "AlgorithmFamily", "GQFedWAvgFamily", "get_family",
    "SamplingModel", "uniform", "importance",
    "FaultModel", "FaultTrace", "edge_faults",
    "MNISTTask", "QuadraticTask", "SpmdTask",
    "GenQSGDTrainer", "round_comm_bits", "PlanServer",
]


def __getattr__(name):
    # lazy: the trainer pulls the SPMD runtime stack, which optimizer-only
    # consumers (e.g. benchmarks/tpu_autotune) never need; the PlanServer
    # lives in repro.serve (Scenario.optimize(server=...) accepts one)
    if name in ("GenQSGDTrainer", "round_comm_bits"):
        from ..train import trainer
        return getattr(trainer, name)
    if name == "PlanServer":
        from ..serve.planserver import PlanServer
        return PlanServer
    raise AttributeError(f"module 'repro.api' has no attribute {name!r}")
