"""Training tasks for ``Scenario.run``.

A *reference task* bundles everything the single-process reference runtime
(Algorithm 1) needs:

  dim                     model dimension (what ``EdgeSystem.dim`` should be)
  init_params(key)        fresh model pytree
  loss(params, batch)     scalar training loss
  sample(worker_data, key, B)   one mini-batch from one worker's shard
  make_data(N)            per-worker data pytree with leading axis N
  metrics(params)         evaluation dict (used for history + final report)

Provided: :class:`MNISTTask` (the paper's Sec.-VII 784-128-10 MLP on the
synthetic MNIST-like set) and :class:`QuadraticTask` (a tiny linear
regression for tests/smoke runs).  :class:`SpmdTask` carries the extra
pieces the distributed runtime needs (model api, arch config, mesh, batch
iterator).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.convergence import MLProblemConstants
from ..data.federated import partition_iid, sample_minibatch
from ..models import mlp

__all__ = ["MNISTTask", "QuadraticTask", "SpmdTask"]


class MNISTTask:
    """The Sec.-VII task: two-layer MLP on the 60k-sample MNIST-like set."""

    dim = mlp.PARAM_DIM

    def __init__(self, n_train: int = 50000, seed: int = 0,
                 eval_samples: int = 2048):
        self.n_train = n_train
        self.seed = seed
        self.eval_samples = eval_samples
        self._data = None
        self._full = None

    # -- data ----------------------------------------------------------
    def _load(self):
        if self._data is None:
            from ..data.synthetic import mnist_like
            X, y = mnist_like(seed=self.seed)
            n = self.n_train
            self._full = (X, y)
            self._data = (X[:n], y[:n], jnp.asarray(X[n:]), jnp.asarray(y[n:]))
        return self._data

    def make_data(self, N: int):
        Xtr, ytr, _, _ = self._load()
        Xw, yw = partition_iid(Xtr, ytr, N)
        return (jnp.stack([jnp.asarray(a) for a in Xw]),
                jnp.stack([jnp.asarray(a) for a in yw]))

    # -- model ---------------------------------------------------------
    def init_params(self, key):
        return mlp.init_params(key)

    loss = staticmethod(mlp.loss)
    sample = staticmethod(sample_minibatch)

    def metrics(self, params) -> dict:
        _, _, Xte, yte = self._load()
        k = self.eval_samples
        return {"eval_loss": float(mlp.loss(params, (Xte[:k], yte[:k]))),
                "test_acc": mlp.accuracy(params, Xte, yte)}

    # -- pre-training constants (Sec. IV-A) ----------------------------
    def estimate_constants(self, N: int, key=None,
                           n_iters: int = 300) -> MLProblemConstants:
        """Probe (L, sigma, G, f_gap) by pre-training (Sec. IV-A) on the
        full dataset — the same probe set the benchmarks have always used."""
        self._load()
        X, y = self._full
        key = jax.random.PRNGKey(0) if key is None else key
        d = mlp.estimate_constants(np.asarray(X), np.asarray(y), key,
                                   n_iters=n_iters)
        return MLProblemConstants(L=d["L"], sigma=d["sigma"], G=d["G"],
                                  f_gap=d["f_gap"], N=N)


class QuadraticTask:
    """Noisy linear regression: params {"w": (dim,)}, closed-form optimum.

    Small enough that a full optimized K0 executes in seconds — the task the
    end-to-end Plan→RunReport tests drive.
    """

    def __init__(self, dim: int = 8, per_worker: int = 64,
                 noise: float = 0.01, seed: int = 0):
        self.dim = dim
        self.per_worker = per_worker
        self.noise = noise
        self.seed = seed
        self.true_w = jax.random.normal(jax.random.PRNGKey(seed), (dim,))

    def make_data(self, N: int):
        key = jax.random.PRNGKey(self.seed)
        X = jax.random.normal(jax.random.fold_in(key, 1),
                              (N, self.per_worker, self.dim))
        T = X @ self.true_w + self.noise * jax.random.normal(
            jax.random.fold_in(key, 2), (N, self.per_worker))
        return (X, T)

    def init_params(self, key):
        del key
        return {"w": jnp.zeros(self.dim)}

    @staticmethod
    def loss(params, batch):
        X, t = batch
        return ((X @ params["w"] - t) ** 2).mean()

    @staticmethod
    def sample(worker_data, key, B):
        X, t = worker_data
        idx = jax.random.randint(key, (B,), 0, X.shape[0])
        return X[idx], t[idx]

    def metrics(self, params) -> dict:
        return {"err": float(jnp.linalg.norm(params["w"] - self.true_w))}


@dataclasses.dataclass
class SpmdTask:
    """What ``Scenario.run(backend="spmd")`` needs beyond the Plan: a model
    api (``init_params``/``loss_train``), its arch config, the device mesh,
    and an iterator of round batches shaped (fl, K_max, B_local, ...)."""

    api: object
    arch: object                 # repro.configs.base.ArchConfig
    mesh: object                 # jax Mesh with (fl, fsdp, tp) axes
    batches: Iterator
    eval_fn: Optional[Callable] = None
    checkpoint_dir: Optional[str] = None
