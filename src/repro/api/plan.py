"""Plan and RunReport: the two value objects of the optimize→train loop.

A :class:`Plan` is the frozen output of ``Scenario.optimize`` — the single
source of truth for the paper's decision variables ``(K0, Kn, B, Γ)`` plus
the quantizer parameters ``(s0, sn, q_dim, wire)`` they were optimized
against.  Both runtime configurations (the single-process reference
:class:`~repro.core.genqsgd.GenQSGDConfig` and the SPMD
:class:`~repro.fed.runtime.FedConfig`) derive from it, so the parameters can
never disagree between the optimizer and the training run.

A :class:`RunReport` closes the loop: it compares what a training run
actually moved/cost (communication bits through the
``repro.compress`` ``codec.wire_bits`` accounting, cost-model energy/time at
the executed round count, wall-clock) against the Plan's predictions.
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Optional, Tuple

from ..compress import RUNTIME_WIRES, elias, make_codec, wire_max_s
from ..core.genqsgd import GenQSGDConfig
from ..core.step_rules import StepRule
from ..opt.problems import Objective

if TYPE_CHECKING:
    from ..fed.runtime import FedConfig

__all__ = ["Plan", "RunReport"]


@dataclasses.dataclass(frozen=True)
class Plan:
    """Frozen, validated parameterization of one GenQSGD training job.

    Produced by ``Scenario.optimize`` (predictions filled in from the GIA
    solution) or hand-built via :meth:`manual` for runs that skip the
    optimizer but still want one source of truth for their configs.
    """

    K0: int                              # global iterations
    Kn: Tuple[int, ...]                  # per-worker local iterations
    B: int                               # mini-batch size
    step_rule: StepRule                  # Γ (optimized gamma for m=J)
    s0: Optional[int] = None             # server quantizer
    sn: Tuple[Optional[int], ...] = ()   # per-worker quantizers (len N)
    dim: int = 0                         # model dimension priced by M_s
    q_dim: Optional[int] = None          # per-bucket-norm size (None = whole)
    wire: str = "packed"                 # pricing wire format (EdgeSystem's)
    objective: Objective = Objective.CONSTANT
    family: str = "genqsgd"
    # family runtime hooks (repro.families), frozen into the Plan so both
    # runtime configs derive the same aggregation/local-update/codec rules
    codec_kind: str = "qsgd"             # make_codec preconditioner kind
    agg_weights: Optional[Tuple[float, ...]] = None  # w_n (None = mean)
    momentum: float = 0.0                # local-update momentum beta
    normalize: bool = False              # normalized local updates
    # client sampling (repro.sampling), frozen into the Plan so both
    # runtimes draw the cohorts the optimizer priced
    sampling: str = "full"               # participation-model key
    cohort_S: Optional[int] = None       # per-round cohort size (None = full)
    sampling_p: Optional[Tuple[float, ...]] = None  # base probs (None = unif)
    # fault injection (repro.faults), frozen into the Plan so both runtimes
    # draw the faults — and divide by the delivery probabilities — the
    # optimizer planned for.  A FaultSpec (model + per-worker nominal round
    # times + deadline tau + delivery probabilities); None = fault-free.
    faults: Optional[object] = None
    # predictions at (K0, Kn, B) — NaN for manual plans
    predicted_E: float = float("nan")    # energy (J), eq. (18)
    predicted_T: float = float("nan")    # time (s), eq. (17)
    predicted_C: float = float("nan")    # convergence error bound
    feasible: bool = True
    converged: bool = True

    def __post_init__(self):
        object.__setattr__(self, "Kn", tuple(int(k) for k in self.Kn))
        # default: exact communication (s = infinity) for every worker
        object.__setattr__(self, "sn", tuple(self.sn) if self.sn
                           else (None,) * len(self.Kn))
        object.__setattr__(self, "objective",
                           Objective.coerce(self.objective, _warn=False))
        if self.K0 < 1 or self.B < 1 or any(k < 1 for k in self.Kn):
            raise ValueError(f"K0, Kn, B must be >= 1, got "
                             f"K0={self.K0} Kn={self.Kn} B={self.B}")
        if len(self.sn) != len(self.Kn):
            raise ValueError(f"sn has {len(self.sn)} entries for "
                             f"{len(self.Kn)} workers")
        if self.agg_weights is not None:
            from ..families import check_agg_weights
            object.__setattr__(self, "agg_weights",
                               check_agg_weights(self.agg_weights,
                                                 len(self.Kn)))
        if self.codec_kind == "rotated" and self.q_dim is not None:
            raise ValueError(
                "rotation preconditioning and per-bucket norms are mutually "
                "exclusive (the rotation already isotropizes the message); "
                "a rotated Plan must carry q_dim=None")
        if self.sampling_p is not None and self.cohort_S is None:
            raise ValueError("sampling_p given without cohort_S")
        if self.cohort_S is not None:
            from ..sampling.base import check_probs
            S = int(self.cohort_S)
            if not 1 <= S <= self.N:
                raise ValueError(f"cohort_S={S} outside [1, N={self.N}]")
            object.__setattr__(self, "cohort_S", S)
            if self.sampling_p is not None:
                p = check_probs(self.sampling_p, self.N)
                if S * max(p) > 1.0 + 1e-9:
                    raise ValueError(
                        f"inclusion probability S*max(p)={S * max(p):.4g} "
                        f"exceeds 1")
                object.__setattr__(self, "sampling_p", p)
        if self.faults is not None:
            from ..faults import FaultSpec
            if not isinstance(self.faults, FaultSpec):
                raise TypeError(
                    f"Plan.faults must be a repro.faults.FaultSpec (built by "
                    f"Scenario from the fault model + the plan's round "
                    f"times), got {type(self.faults)}")
            if self.faults.N != self.N:
                raise ValueError(
                    f"FaultSpec describes {self.faults.N} workers, plan "
                    f"has {self.N}")

    # ------------------------------------------------------------------
    @classmethod
    def manual(cls, K0: int, Kn, B: int, step_rule: StepRule,
               s0: Optional[int] = None, sn=None, dim: int = 0,
               q_dim: Optional[int] = None, wire: str = "packed",
               family: str = "genqsgd", codec_kind: str = "qsgd",
               agg_weights=None, momentum: float = 0.0,
               normalize: bool = False, faults=None) -> "Plan":
        """A Plan not produced by the optimizer (predictions are NaN)."""
        Kn = tuple(int(k) for k in Kn)
        if isinstance(sn, (int, type(None))):
            sn = (sn,) * len(Kn)
        try:  # custom registered rules default to the constant objective
            obj = Objective.coerce(getattr(step_rule, "name", "C"),
                                   _warn=False)
        except ValueError:
            obj = Objective.CONSTANT
        return cls(K0=int(K0), Kn=Kn, B=int(B), step_rule=step_rule,
                   s0=s0, sn=tuple(sn), dim=int(dim), q_dim=q_dim, wire=wire,
                   objective=obj, family=family, codec_kind=codec_kind,
                   agg_weights=agg_weights, momentum=momentum,
                   normalize=normalize, faults=faults)

    @property
    def N(self) -> int:
        return len(self.Kn)

    @property
    def gamma(self) -> float:
        return float(self.step_rule.gamma)

    @property
    def K_max(self) -> int:
        return int(max(self.Kn))

    # -- bit accounting (the same codec table EdgeSystem.M_s prices) ----
    def round_bits(self, dim: Optional[int] = None,
                   wire: Optional[str] = None) -> float:
        """Wire bits one global iteration moves: N worker uploads plus the
        server multicast, priced by ``codec.wire_bits``."""
        d = self.dim if dim is None else int(dim)
        w = self.wire if wire is None else wire
        # an explicit wire naming a runtime aggregation transport prices
        # what the SPMD runtime actually moves: per-tensor QSGD levels —
        # rotation is a whole-model-vector preconditioner the sharded
        # transports cannot carry (see to_fed_config).  Everything else
        # (wire=None, or a pure pricing format like "packed") uses the
        # Plan's own codec kind, whether passed explicitly or defaulted.
        transport = wire is not None and w in RUNTIME_WIRES
        kind = "qsgd" if transport else self.codec_kind
        up = sum(make_codec(s, wire=w, bucket=self.q_dim,
                            kind=kind).wire_bits(d)
                 for s in self.sn)
        # mirror FedConfig.server_codec: an exact multicast (s0=None) is raw
        # f32 regardless of the worker wire (the packing wire can't carry it)
        down_w = "f32" if (self.s0 is None and w == "int4") else w
        down = make_codec(self.s0, wire=down_w, bucket=self.q_dim,
                          kind=kind).wire_bits(d)
        return up + down

    def _up_down(self, dim: Optional[int] = None,
                 wire: Optional[str] = None):
        """Per-worker upload bits + the server multicast bits, the same
        codec/wire resolution as :meth:`round_bits` (client sampling needs
        the per-worker granularity: uploads scale by pi_n, the multicast
        does not)."""
        d = self.dim if dim is None else int(dim)
        w = self.wire if wire is None else wire
        transport = wire is not None and w in RUNTIME_WIRES
        kind = "qsgd" if transport else self.codec_kind
        ups = [make_codec(s, wire=w, bucket=self.q_dim,
                          kind=kind).wire_bits(d) for s in self.sn]
        down_w = "f32" if (self.s0 is None and w == "int4") else w
        down = make_codec(self.s0, wire=down_w, bucket=self.q_dim,
                          kind=kind).wire_bits(d)
        return ups, down

    def expected_round_bits(self, dim: Optional[int] = None,
                            wire: Optional[str] = None) -> float:
        """E[wire bits] of one round under the Plan's participation model:
        each worker's upload scales by its inclusion probability pi_n
        (uniform: ``S * sum_n M_{s_n} / N``), the server multicast by 1.
        Without sampling this IS :meth:`round_bits`, bitwise."""
        if self.cohort_S is None:
            return self.round_bits(dim=dim, wire=wire)
        ups, down = self._up_down(dim, wire)
        S = float(self.cohort_S)
        if self.sampling_p is None:        # uniform: pi_n = S/N for all n
            return S * sum(ups) / self.N + down
        return sum(S * p * u for p, u in zip(self.sampling_p, ups)) + down

    def cohort_round_bits(self, idx, dim: Optional[int] = None,
                          wire: Optional[str] = None) -> float:
        """Realized wire bits of one sampled round: the uploads of the
        cohort ``idx`` actually drawn, plus the server multicast."""
        ups, down = self._up_down(dim, wire)
        return sum(ups[int(i)] for i in idx) + down

    @property
    def predicted_comm_bits(self) -> float:
        """K0 * E[per-round bits] — total bits the cost model budgeted for
        the whole run (the historical N-upload sum without sampling)."""
        return self.K0 * self.expected_round_bits()

    # -- runtime configs (the tentpole: one source of truth) ------------
    def to_genqsgd_config(self, max_K0: Optional[int] = None,
                          seed: Optional[int] = None) -> GenQSGDConfig:
        """The single-process reference runtime's config (Algorithm 1, plus
        the Plan's family hooks: aggregation weights, momentum/normalized
        local updates, codec preconditioner — and, under client sampling,
        the cohort size/probabilities with ``seed`` driving the per-round
        cohort draws)."""
        K0 = self.K0 if max_K0 is None else min(self.K0, int(max_K0))
        return GenQSGDConfig(K0=K0, Kn=self.Kn, B=self.B,
                             step_rule=self.step_rule, s0=self.s0,
                             sn=list(self.sn), bucket=self.q_dim,
                             agg_weights=self.agg_weights,
                             momentum=self.momentum,
                             normalize=self.normalize,
                             codec_kind=self.codec_kind,
                             sampling_S=self.cohort_S,
                             sampling_p=self.sampling_p, seed=seed,
                             faults=self.faults)

    def to_fed_config(self, wire: str = "f32", microbatch: int = 1,
                      aux_weight: float = 0.01,
                      seed: Optional[int] = None) -> FedConfig:
        """The SPMD runtime's config, cross-validated against the Plan.

        ``wire`` is the aggregation *transport* (how the quantized levels
        travel); the Plan's ``s0/sn/q_dim`` decide *what* is sent.  Pairs
        the transport cannot carry — e.g. ``wire="int4"`` with s > 7 — are
        rejected here, before any mesh work starts.

        The family's aggregation weights and momentum/normalized local
        updates carry through; the rotation preconditioner does **not** —
        it acts on the whole flattened model vector, while the sharded
        runtime quantizes per tensor, so SPMD transports always move plain
        QSGD levels (the reference backend runs the rotated codec; the
        RunReport's measured comm-bits are priced at the transport actually
        used either way).
        """
        from ..fed.runtime import FedConfig  # lazy: SPMD runtime stack

        if wire not in RUNTIME_WIRES:
            raise ValueError(f"wire must be one of {RUNTIME_WIRES}, "
                             f"got {wire!r}")
        cap = wire_max_s(wire)
        if wire == "elias":
            # elias *pricing* is unbounded in s, but the runtime coder reads
            # levels from an int8 container like the other level transports
            cap = elias.MAX_RUNTIME_S
        for role, s in [("s0", self.s0)] + [(f"sn[{i}]", s)
                                            for i, s in enumerate(self.sn)]:
            if s is not None and cap is not None and s > cap:
                raise ValueError(
                    f"plan {role}={s} cannot ride the {wire!r} transport "
                    f"(carries s <= {cap}); re-optimize the Scenario with "
                    f"quantizers the wire supports or pick a wider wire")
        return FedConfig(n_workers=self.N, Kn=self.Kn, s0=self.s0,
                         sn=self.sn, wire=wire, bucket=self.q_dim,
                         microbatch=microbatch, aux_weight=aux_weight,
                         agg_weights=self.agg_weights,
                         momentum=self.momentum, normalize=self.normalize,
                         sampling_S=self.cohort_S,
                         sampling_p=self.sampling_p, seed=seed,
                         faults=self.faults)

    def describe(self) -> str:
        sn = set(self.sn)
        sn_txt = str(next(iter(sn))) if len(sn) == 1 else str(list(self.sn))
        samp = ("" if self.cohort_S is None
                else f" S={self.cohort_S}/{self.N} ({self.sampling})")
        if self.faults is not None:
            dl = self.faults.deadline
            samp += (f" faults={self.faults.model.key}"
                     f"(tau={'inf' if dl == float('inf') else f'{dl:.3g}s'})")
        return (f"Plan[{self.family}/{self.objective.value}]{samp} "
                f"K0={self.K0} Kn={list(self.Kn)} B={self.B} "
                f"gamma={self.gamma:.4g} s0={self.s0} sn={sn_txt} | "
                f"E={self.predicted_E:.4g} J, T={self.predicted_T:.4g} s, "
                f"C={self.predicted_C:.4g} "
                f"({'feasible' if self.feasible else 'INFEASIBLE'})")


@dataclasses.dataclass(frozen=True)
class RunReport:
    """What a training run measured, next to what its Plan predicted.

    ``comm_bits`` is measured through the same ``codec.wire_bits`` table the
    optimizer priced (executed rounds x per-round message bits at the
    *actual* model dimension); ``measured_E`` / ``measured_T`` evaluate the
    closed-form cost models at the executed round count, while
    ``wall_time_s`` is the real clock.
    """

    plan: Plan
    backend: str                     # "reference" | "spmd"
    rounds: int                      # global iterations actually executed
    model_dim: int                   # flattened dimension of the live model
    wall_time_s: float
    comm_bits: float                 # measured total wire bits
    measured_E: float                # cost-model energy over executed rounds
    measured_T: float                # cost-model time over executed rounds
    final_metrics: dict = dataclasses.field(default_factory=dict)
    history: tuple = ()
    round_bits_trace: tuple = ()     # per-round realized wire bits (sampled
                                     # runs only; empty = uniform K0 rounds)
    fault_trace: Optional[object] = None  # repro.faults.FaultTrace (faulted
                                          # runs only; None = fault-free)

    @property
    def predicted_comm_bits(self) -> float:
        return self.plan.predicted_comm_bits

    @property
    def comm_bits_match(self) -> bool:
        """Exact closure of the loop: did the run move exactly the bits the
        optimizer budgeted?  True when the full K0 executed on a model of
        the dimension the Scenario priced (under client sampling: when the
        realized cohort bits sum to K0 times the expected per-round bits —
        exact for uniform cohorts over homogeneous quantizers)."""
        return self.comm_bits == self.predicted_comm_bits

    def drift(self):
        """The per-round predicted-vs-measured timeline for energy, time
        and comm-bits: a :class:`~repro.obs.ledger.RunLedger` with one row
        per executed round and running cumulative drift ratios.  A pure
        function of this frozen report — identical whether ``repro.obs``
        is enabled or not."""
        from ..obs.ledger import RunLedger
        return RunLedger.from_report(self)

    def summary(self) -> str:
        p = self.plan
        lines = [
            f"RunReport[{self.backend}] {self.rounds}/{p.K0} rounds, "
            f"model dim {self.model_dim} (planned {p.dim}), "
            f"wall {self.wall_time_s:.1f}s",
            f"  comm bits: measured {self.comm_bits:.6g} vs predicted "
            f"{self.predicted_comm_bits:.6g} "
            f"({'EXACT' if self.comm_bits_match else 'differs'})",
            f"  energy:    modeled {self.measured_E:.4g} J vs predicted "
            f"{p.predicted_E:.4g} J",
            f"  time:      modeled {self.measured_T:.4g} s vs predicted "
            f"{p.predicted_T:.4g} s",
        ]
        ft = self.fault_trace
        if ft is not None and len(ft):
            pred_round = p.predicted_T / p.K0
            lines.append(
                f"  faults:    {ft.rounds_degraded}/{len(ft)} rounds "
                f"degraded, {ft.workers_dropped} worker-rounds dropped, "
                f"realized {ft.mean_round_time:.4g} s/round vs predicted "
                f"{pred_round:.4g} s/round")
        if self.final_metrics:
            kv = " ".join(f"{k}={v:.4g}" if isinstance(v, float)
                          else f"{k}={v}" for k, v in
                          self.final_metrics.items())
            lines.append(f"  metrics:   {kv}")
        return "\n".join(lines)
