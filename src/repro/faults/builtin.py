"""Built-in fault models: the fault-free fleet and the edge-fleet model
(stragglers + multi-round crashes + payload corruption)."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

from .base import FaultModel, RoundFaults

__all__ = ["NoFaults", "EdgeFaults", "edge_faults"]


@dataclasses.dataclass(frozen=True)
class NoFaults(FaultModel):
    """The fault-free fleet — every hook neutral; selecting it is
    bit-identical to configuring no fault model at all."""

    key: str = "none"


@dataclasses.dataclass(frozen=True)
class EdgeFaults(FaultModel):
    """The edge-fleet fault process — three independent mechanisms, all
    driven by one seeded stream with a fixed per-round draw order (crash,
    straggle, corrupt; every mask drawn every round regardless of state,
    so traces replay deterministically from the seed alone):

    stragglers   each attempted worker independently inflates its round
                 latency by ``straggler_factor`` with probability
                 ``straggler_prob`` (i.i.d. across rounds and workers);
    crashes      a worker goes down with probability ``crash_prob`` per
                 up-round and stays down for ``crash_rounds`` consecutive
                 rounds (a Markov chain whose state is the remaining
                 down-rounds; ``crash_rounds=1`` is i.i.d. Bernoulli
                 dropout).  Stationary up-fraction
                 ``(1-q) / (1-q + q R)``;
    corruption   a delivered payload independently fails its checksum
                 with probability ``corrupt_prob``.

    ``availability`` reports the stationary up-fraction x checksum
    survival (the chain *starts* all-up, so early rounds of a long-R model
    are slightly more available than the stationary value the GP plans
    with — exact for ``crash_rounds=1``).  Straggler-deadline exclusion
    deliberately stays out of availability and enters ``deliver_prob``
    instead; see :mod:`repro.faults.base` for why.
    """

    key: str = "edge"
    straggler_prob: float = 0.0
    straggler_factor: float = 1.0
    crash_prob: float = 0.0
    crash_rounds: int = 1
    corrupt_prob: float = 0.0

    # -- identity --------------------------------------------------------
    def validate(self, N: int) -> None:
        super().validate(N)
        for name in ("straggler_prob", "crash_prob", "corrupt_prob"):
            v = getattr(self, name)
            if not 0.0 <= v < 1.0:
                raise ValueError(f"{name}={v} outside [0, 1)")
        if not self.straggler_factor >= 1.0:
            raise ValueError(
                f"straggler_factor={self.straggler_factor} must be >= 1 "
                f"(a straggler is slower than nominal, not faster)")
        if not (isinstance(self.crash_rounds, (int, np.integer))
                and self.crash_rounds >= 1):
            raise ValueError(
                f"crash_rounds={self.crash_rounds} must be an int >= 1")

    def is_neutral(self, N: int) -> bool:
        return (not self.runtime_active(N)
                and self.freq_margin == 0.0 and self.rate_margin == 0.0)

    def signature(self, N: int) -> tuple:
        if self.is_neutral(N):
            return ("none",)
        return (self.key, float(self.straggler_prob),
                float(self.straggler_factor), float(self.crash_prob),
                int(self.crash_rounds), float(self.corrupt_prob),
                float(self.deadline_slack), float(self.freq_margin),
                float(self.rate_margin))

    def runtime_active(self, N: int) -> bool:
        del N
        return (self.straggler_prob > 0.0 and self.straggler_factor > 1.0) \
            or self.crash_prob > 0.0 or self.corrupt_prob > 0.0

    # -- optimizer coefficients ------------------------------------------
    @property
    def _up_frac(self) -> float:
        q, R = self.crash_prob, self.crash_rounds
        return (1.0 - q) / (1.0 - q + q * R)

    def availability(self, N: int) -> Optional[np.ndarray]:
        a = self._up_frac * (1.0 - self.corrupt_prob)
        if a == 1.0:
            return None          # straggler-only models don't touch the GP
        return np.full(N, a)

    # -- runtime draws ---------------------------------------------------
    def init_state(self, N: int):
        return np.zeros(N, np.int64)       # remaining down-rounds: all up

    def draw_round(self, rng: np.random.Generator, N: int, state
                   ) -> Tuple[RoundFaults, object]:
        # fixed draw order + unconditional draws: the stream position after
        # a round never depends on what was drawn, so a trace is a pure
        # function of (seed, round count)
        r_crash = rng.random(N)
        r_straggle = rng.random(N)
        r_corrupt = rng.random(N)
        down_now = state > 0
        nxt = np.maximum(state - 1, 0)
        newly = (~down_now) & (r_crash < self.crash_prob)
        crashed = down_now | newly
        nxt = np.where(newly, self.crash_rounds - 1, nxt)
        straggle = r_straggle < self.straggler_prob
        mult = np.where(straggle, self.straggler_factor, 1.0)
        corrupt = r_corrupt < self.corrupt_prob
        return RoundFaults(latency_mult=mult, crashed=crashed,
                           corrupt=corrupt), nxt

    def deliver_prob(self, worker_times, deadline: float) -> np.ndarray:
        t = np.asarray(worker_times, np.float64)
        p_up = self._up_frac
        p_ok = 1.0 - self.corrupt_prob
        # arrival = mult * t_n with mult in {1, factor}; slack >= 1
        # guarantees t_n <= deadline, so only the straggled arrival can miss
        p_time = np.where(self.straggler_factor * t <= deadline, 1.0,
                          np.where(t <= deadline,
                                   1.0 - self.straggler_prob, 0.0))
        return p_up * p_ok * p_time


def edge_faults(straggler_prob: float = 0.0, straggler_factor: float = 1.0,
                crash_prob: float = 0.0, crash_rounds: int = 1,
                corrupt_prob: float = 0.0,
                deadline_slack: float = float("inf"),
                freq_margin: float = 0.0,
                rate_margin: float = 0.0,
                deadline: str = "frozen",
                ema_alpha: float = 0.25) -> EdgeFaults:
    """Factory for :class:`EdgeFaults` (keyword-friendly mirror of
    :func:`repro.sampling.uniform` / ``importance``).

    ``deadline="adaptive"`` makes the runtime's :class:`FaultDriver`
    re-estimate tau each round from an EMA (weight ``ema_alpha``) of the
    realized round times; the default ``"frozen"`` keeps the plan's tau
    for every round, bitwise the historical behavior."""
    return EdgeFaults(straggler_prob=float(straggler_prob),
                      straggler_factor=float(straggler_factor),
                      crash_prob=float(crash_prob),
                      crash_rounds=int(crash_rounds),
                      corrupt_prob=float(corrupt_prob),
                      deadline_slack=float(deadline_slack),
                      freq_margin=float(freq_margin),
                      rate_margin=float(rate_margin),
                      deadline=str(deadline),
                      ema_alpha=float(ema_alpha))
