"""repro.faults: seeded fault injection + deadline-HT aggregation.

Pluggable fault models (mirroring :mod:`repro.sampling` /
:mod:`repro.families`): each model contributes availability / worst-case
margin coefficients to the optimizer, and a seeded per-round fault draw
(straggler latency inflation, multi-round crashes, checksum-failing
payload corruption) to both runtimes, aggregated past a per-round
deadline with unbiased Horvitz-Thompson reweighting of the survivors.

    from repro.api import Scenario
    from repro.faults import edge_faults

    fm = edge_faults(straggler_prob=0.2, straggler_factor=4.0,
                     crash_prob=0.05, deadline_slack=1.5)
    plan = Scenario(..., faults=fm).optimize()   # plans for availability
    report = scn.run(plan, backend="reference")  # report.fault_trace
"""
from .base import (FaultDriver, FaultModel, FaultSpec, FaultTrace,
                   RoundFaultRecord, RoundFaults, fault_rng, flip_bits,
                   payload_checksum)
from .builtin import EdgeFaults, NoFaults, edge_faults
from .registry import fault_names, get_faults, register, resolve

__all__ = [
    "FaultModel", "NoFaults", "EdgeFaults", "edge_faults",
    "register", "get_faults", "fault_names", "resolve",
    "FaultDriver", "FaultSpec", "FaultTrace", "RoundFaults",
    "RoundFaultRecord", "fault_rng", "payload_checksum", "flip_bits",
]

#: the named models: "none" (the neutral default) and "edge" (all-zero
#: probabilities until configured — use the edge_faults factory)
BUILTIN_FAULTS = (NoFaults(), EdgeFaults())
for _f in BUILTIN_FAULTS:
    register(_f, overwrite=True)
del _f
