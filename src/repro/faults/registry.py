"""The fault registry: name -> :class:`FaultModel` instance.

The authoritative registry behind ``Scenario(faults=...)``.  Unknown
names fail with a nearest-match suggestion, mirroring
:mod:`repro.sampling.registry` / :mod:`repro.families.registry`.
"""
from __future__ import annotations

import difflib
from typing import Dict, Tuple, Union

from .base import FaultModel

__all__ = ["register", "get_faults", "fault_names", "resolve"]

_REGISTRY: Dict[str, FaultModel] = {}


def register(model: FaultModel, overwrite: bool = False) -> None:
    """Register a fault model under ``model.key``."""
    if not isinstance(model, FaultModel):
        raise TypeError(f"expected a FaultModel, got {type(model)}")
    if model.key in _REGISTRY and not overwrite:
        raise ValueError(f"fault model {model.key!r} is already "
                         f"registered; pass overwrite=True to replace it")
    _REGISTRY[str(model.key)] = model


def fault_names() -> Tuple[str, ...]:
    return tuple(_REGISTRY)


def get_faults(name: str) -> FaultModel:
    try:
        return _REGISTRY[name]
    except KeyError:
        close = difflib.get_close_matches(str(name), _REGISTRY, n=1)
        hint = f" — did you mean {close[0]!r}?" if close else ""
        raise ValueError(
            f"unknown fault model {name!r}{hint}; registered in "
            f"repro.faults: {sorted(_REGISTRY)} (add one with "
            f"repro.faults.register, or pass a FaultModel instance — "
            f"e.g. repro.faults.edge_faults(straggler_prob=0.2, "
            f"straggler_factor=4.0, deadline_slack=1.5))") from None


def resolve(model: Union[str, FaultModel]) -> FaultModel:
    """Accept a registry key or an (unregistered) model instance."""
    if isinstance(model, FaultModel):
        return model
    return get_faults(model)
